"""Shared pytest configuration.

Hypothesis runs under a fixed, seeded profile so the property suites
are deterministic in CI: ``derandomize=True`` makes example generation a
pure function of the test body (no flaky seeds), and the deadline is
disabled because CI boxes stall unpredictably under load.  Select an
exploratory profile locally with ``HYPOTHESIS_PROFILE=dev``.
"""
import os

try:
    from hypothesis import settings
except ImportError:                     # optional dev dependency
    pass
else:
    settings.register_profile("ci", deadline=None, derandomize=True,
                              max_examples=60, print_blob=True)
    settings.register_profile("dev", deadline=None)
    settings.load_profile(os.environ.get("HYPOTHESIS_PROFILE", "ci"))
