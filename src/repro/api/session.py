"""Session — one manifest-driven control plane for every workload kind.

The paper's users never drive subsystems by hand: they declare a
workload and the platform schedules, places, measures and heals it
(§II, §VI).  ``Session`` is that surface here.  Construct it from any
backend —

    Session(cluster=Cluster(...))              # one bare cluster
    Session(fabric=fabric, planner=planner)    # the multi-site federation
    Session(tenant=virtual_cluster)            # one tenant's fair share

— then drive every workload kind with one verb set:

    handle = session.apply(TrainJob(name="t", steps=20))   # or a manifest
    handle.status()        # observed state (phase + live probes)
    handle.wait()          # block for the result
    handle.events()        # the lifecycle stream so far
    handle.cancel()        # cooperative drain -> CANCELLED

Each ``Handle`` owns a desired->observed reconcile loop in a background
thread: the workload moves PENDING -> PLACING -> RUNNING -> one of
{SUCCEEDED, FAILED, PREEMPTED, CANCELLED}, every transition is recorded
on the handle AND published to the session's ``EventBus`` (kind
``"workload"``), so ``repro.launch.monitor`` renders train / serve /
batch / workflow workloads uniformly.  ``cancel()`` reuses the
platform's cooperative drain primitives (``Cluster.preempt_pod``, the
serving engine's ``should_stop``, the workflow's step boundary), so a
cancelled training job keeps its checkpoint.
"""
from __future__ import annotations

import threading
import time
import traceback
from dataclasses import dataclass, field
from enum import Enum
from typing import Any, Callable, Dict, List, Mapping, Optional

from repro.api.resources import (BatchJob, ManifestError, RLJob, ServeJob,
                                 TrainJob, WorkflowRun, WorkloadSpec,
                                 from_manifest, load_manifest)


class WorkloadState(str, Enum):
    PENDING = "Pending"        # applied, reconcile loop not yet placing
    PLACING = "Placing"        # resolving configs / choosing a site
    RUNNING = "Running"        # the subsystem is executing the workload
    SUCCEEDED = "Succeeded"
    FAILED = "Failed"
    PREEMPTED = "Preempted"    # evicted by the platform, not by the user
    CANCELLED = "Cancelled"    # user-requested cooperative drain finished


TERMINAL_STATES = (WorkloadState.SUCCEEDED, WorkloadState.FAILED,
                   WorkloadState.PREEMPTED, WorkloadState.CANCELLED)


@dataclass
class WorkloadStatus:
    """One observed snapshot of a workload."""
    name: str
    kind: str
    backend: str
    state: WorkloadState
    error: Optional[str] = None
    observed: Dict[str, Any] = field(default_factory=dict)

    def brief(self) -> str:
        obs = " ".join(f"{k}={v}" for k, v in self.observed.items())
        return (f"{self.kind:<12} {self.name:<20} {self.state.value:<10} "
                f"{obs}").rstrip()


class Handle:
    """The live handle on one applied workload (see module docstring)."""

    def __init__(self, spec: WorkloadSpec, backend: str, bus=None):
        self.spec = spec
        self.backend = backend
        self._bus = bus
        self._lock = threading.Lock()
        self._state = WorkloadState.PENDING
        self._result: Any = None
        self._error: Optional[str] = None
        self._events: List[Dict[str, Any]] = []
        self._probes: Dict[str, Callable[[], Any]] = {}
        self._cancel = threading.Event()
        self._cancel_hooks: List[Callable[[], None]] = []
        self._done = threading.Event()
        self._final_override: Optional[WorkloadState] = None
        self._thread: Optional[threading.Thread] = None
        self._record(self._state)

    # ----------------------------------------------------------- lifecycle
    def _record(self, state: WorkloadState, **detail) -> None:
        ev = {"ts": time.time(), "state": state.value, **detail}
        self._events.append(ev)
        if self._bus is not None:
            self._bus.publish("workload", source=self.spec.name,
                              resource=self.spec.KIND,
                              backend=self.backend, state=state.value,
                              **detail)

    def _transition(self, state: WorkloadState, **detail) -> None:
        with self._lock:
            if self._state in TERMINAL_STATES:
                return
            self._state = state
            self._record(state, **detail)
        if state in TERMINAL_STATES:
            self._done.set()

    def _finish(self, state: WorkloadState, *, result: Any = None,
                error: Optional[str] = None) -> None:
        with self._lock:
            if self._state in TERMINAL_STATES:
                return
            self._result = result
            self._error = error
            self._state = state
            self._record(state, **({"error": error.splitlines()[0]}
                                   if error else {}))
        self._done.set()

    def _set_final(self, state: WorkloadState) -> None:
        """A runner observed a platform-driven terminal outcome (e.g. the
        job was preempted and will not be resubmitted)."""
        self._final_override = state

    # ---------------------------------------------------------- the verbs
    @property
    def state(self) -> WorkloadState:
        with self._lock:
            return self._state

    @property
    def cancel_requested(self) -> bool:
        return self._cancel.is_set()

    def should_stop(self) -> bool:
        """The cooperative drain signal runners thread into subsystems."""
        return self._cancel.is_set()

    def status(self) -> WorkloadStatus:
        observed = {}
        for name, probe in list(self._probes.items()):
            try:
                observed[name] = probe()
            except Exception:       # a probe must never break status()
                pass
        with self._lock:
            return WorkloadStatus(name=self.spec.name, kind=self.spec.KIND,
                                  backend=self.backend, state=self._state,
                                  error=self._error, observed=observed)

    def wait(self, timeout: float = 600.0) -> Any:
        """Block until terminal.  Returns the result (partial results for
        CANCELLED / PREEMPTED); raises for FAILED."""
        if not self._done.wait(timeout):
            raise TimeoutError(
                f"workload {self.spec.name!r} ({self.state.value}) "
                f"not terminal within {timeout}s")
        if self.state == WorkloadState.FAILED:
            raise RuntimeError(
                f"workload {self.spec.name!r} failed: {self._error}")
        return self._result

    def result(self) -> Any:
        return self._result

    def events(self) -> List[Dict[str, Any]]:
        """The recorded lifecycle transitions (oldest first)."""
        with self._lock:
            return [dict(e) for e in self._events]

    def cancel(self, *, wait: bool = False, timeout: float = 600.0) -> bool:
        """Request a cooperative drain.  Training checkpoints and exits,
        serving stops between fused decode steps, batch pods get the
        preempt signal, workflows stop at the next step boundary.
        Returns False when the workload is already terminal."""
        with self._lock:
            if self._state in TERMINAL_STATES:
                return False
            self._cancel.set()
            self._record(self._state, event="cancel-requested")
        for hook in list(self._cancel_hooks):
            try:
                hook()
            except Exception:
                pass
        if wait:
            self._done.wait(timeout)
        return True

    # ------------------------------------------------------- runner wiring
    def add_cancel_hook(self, hook: Callable[[], None]) -> None:
        self._cancel_hooks.append(hook)
        if self._cancel.is_set():       # cancel() already ran: fire now
            try:
                hook()
            except Exception:
                pass

    def probe(self, name: str, fn: Callable[[], Any]) -> None:
        """Expose a live observed value (e.g. the trainer's step) through
        ``status()`` without leaking the subsystem object."""
        self._probes[name] = fn

    def _launch(self, run_fn: Callable[["Handle"], Any]) -> "Handle":
        def loop():
            try:
                if self.cancel_requested:
                    self._finish(WorkloadState.CANCELLED)
                    return
                result = run_fn(self)
            except Exception as e:
                if self.cancel_requested:
                    self._finish(WorkloadState.CANCELLED, error=str(e))
                else:
                    self._finish(WorkloadState.FAILED,
                                 error=f"{e}\n{traceback.format_exc()}")
            else:
                if self.cancel_requested:
                    self._finish(WorkloadState.CANCELLED, result=result)
                elif self._final_override is not None:
                    self._finish(self._final_override, result=result)
                else:
                    self._finish(WorkloadState.SUCCEEDED, result=result)

        self._thread = threading.Thread(target=loop, daemon=True,
                                        name=f"api-{self.spec.name}")
        self._thread.start()
        return self


class Session:
    """The unified control plane over one backend (see module docstring).

    Exactly one backend must be given:

    ``cluster``
        A bare ``repro.core.orchestrator.Cluster`` (plus an optional
        ``store`` for checkpoints / workflow state).
    ``fabric`` / ``planner``
        The multi-site federation.  A ``planner``
        (``repro.fabric.PlacementPlanner``) enables placed workflows and
        cross-site failover; a bare fabric routes by queue depth.
    ``tenant``
        A ``repro.vcluster.VirtualCluster`` — every workload runs inside
        the tenant's fair share, placed by its scheduler.
    """

    def __init__(self, *, cluster=None, store=None, fabric=None,
                 planner=None, tenant=None, metrics=None, bus=None,
                 namespace: Optional[str] = None):
        from repro.api import runners
        backends = [b for b in
                    ("cluster" if cluster is not None else None,
                     "fabric" if (fabric is not None or planner is not None)
                     else None,
                     "tenant" if tenant is not None else None)
                    if b is not None]
        if len(backends) != 1:
            raise TypeError(
                "Session needs exactly one backend: cluster=..., "
                f"fabric=.../planner=..., or tenant=... (got {backends})")
        self.namespace = namespace
        self.workloads: List[Handle] = []
        if cluster is not None:
            self.metrics = metrics or cluster.metrics
            self.bus = bus or self._own_bus(cluster=cluster)
            self._backend = runners.ClusterBackend(self, cluster, store)
        elif tenant is not None:
            self.metrics = metrics or tenant.sched.metrics
            self.bus = bus or tenant.sched.bus
            self._backend = runners.TenantBackend(self, tenant, store)
        else:
            fabric = fabric if fabric is not None else planner.fabric
            self.metrics = metrics or fabric.metrics
            self.bus = bus or self._own_bus(fabric=fabric)
            self._backend = runners.FabricBackend(self, fabric, planner,
                                                  store)

    def _own_bus(self, cluster=None, fabric=None):
        from repro.vcluster.monitor import EventBus
        bus = EventBus(metrics=self.metrics)
        if cluster is not None:
            bus.attach_cluster(cluster)
        if fabric is not None:
            bus.attach_fabric(fabric)
        return bus

    # -------------------------------------------------------------- verbs
    def apply(self, spec, **runtime) -> Handle:
        """Apply one workload spec (or manifest dict) and return its
        Handle.  ``runtime`` attaches runtime-only fields that cannot
        ride in a manifest: ``fn=`` (BatchJob), ``define=``
        (WorkflowRun)."""
        if isinstance(spec, Mapping):
            spec = from_manifest(spec)
        if runtime:
            import dataclasses
            spec = dataclasses.replace(spec, **runtime)
        runner = {
            TrainJob: self._backend.run_train,
            ServeJob: self._backend.run_serve,
            BatchJob: self._backend.run_batch,
            WorkflowRun: self._backend.run_workflow,
            RLJob: self._backend.run_rl,
        }.get(type(spec))
        if runner is None:
            raise ManifestError(
                f"Session.apply got {type(spec).__name__}; expected one "
                f"of TrainJob/ServeJob/BatchJob/WorkflowRun/RLJob or a "
                f"manifest")
        handle = Handle(spec, self._backend.kind, bus=self.bus)
        self.workloads.append(handle)
        return handle._launch(lambda h: runner(h, spec))

    def apply_manifest(self, path: str, **runtime) -> Handle:
        """``apply`` for a manifest file on disk (the kubectl path)."""
        return self.apply(load_manifest(path), **runtime)

    def status(self) -> List[WorkloadStatus]:
        """Observed state of every workload applied on this session."""
        return [h.status() for h in self.workloads]

    def wait(self, timeout: float = 600.0) -> List[Any]:
        """Block until every applied workload is terminal; returns their
        results in apply order (raises on the first FAILED one)."""
        return [h.wait(timeout) for h in self.workloads]

    def events(self) -> List[Dict[str, Any]]:
        """Every workload's lifecycle events, merged, oldest first."""
        out: List[Dict[str, Any]] = []
        for h in self.workloads:
            for e in h.events():
                out.append({"workload": h.spec.name, **e})
        return sorted(out, key=lambda e: e["ts"])

    def cancel(self, *, wait: bool = False, timeout: float = 600.0) -> int:
        """Cancel every non-terminal workload; returns how many."""
        return sum(1 for h in self.workloads
                   if h.cancel(wait=wait, timeout=timeout))
