"""Backend runners — how each workload kind lands on each backend.

One runner method per (kind, backend) cell, all routing into the
EXISTING machinery: ``repro.elastic`` / ``repro.fabric.failover`` /
``VirtualCluster.run_elastic`` for TrainJob, ``repro.serving`` for
ServeJob, the orchestrator / fair-share scheduler for BatchJob,
``repro.core.workflow`` for WorkflowRun, and ``repro.rl`` (actor fleet
+ elastic learner) for RLJob.  Runners execute inside the
Handle's reconcile thread: they move the handle PLACING -> RUNNING,
thread its cooperative ``should_stop`` into the subsystem, and return
the workload's result dict.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Any, Callable, Dict, List, Optional

from repro.api.resources import (BatchJob, ManifestError, RLJob, ServeJob,
                                 TrainJob, WorkflowRun)
from repro.api.session import Handle, WorkloadState
from repro.configs import registry
from repro.configs.base import ModelConfig, OptimizerConfig
from repro.core.metrics import Registry
from repro.core.orchestrator import JobSpec, PodState
from repro.core.workflow import Workflow
from repro.data.objectstore import ObjectStore
from repro.serving.report import GAUGES, make_requests, serving_report


# ----------------------------------------------------------- shared builders
def dataclass_kwargs(obj) -> Dict[str, Any]:
    """A dataclass instance's init kwargs — the declarative ``config``
    dict for a TrainJob built from an existing ModelConfig."""
    return {f.name: getattr(obj, f.name)
            for f in dataclasses.fields(obj) if f.init}


def _resolve_pieces(job, steps: int):
    """Shared (ModelConfig, ParallelConfig, OptimizerConfig) resolution
    for any training-flavoured job (TrainJob / RLJob)."""
    if job.config is not None:
        cfg = ModelConfig(**job.config)
        base = OptimizerConfig()
        try:
            par = registry.get_parallel(job.arch)
        except KeyError:
            # a custom model name rode in as the arch (the pre-API
            # train(cfg.name, cfg_override=cfg) pattern): the config IS
            # the model, so fall back to default parallelism
            par = registry.get_parallel("phi4-mini-3.8b")
    else:
        cfg = registry.get_smoke(job.arch) if job.smoke \
            else registry.get_config(job.arch)
        base = registry.get_optimizer(job.arch)
        par = registry.get_parallel(job.arch)
    okw: Dict[str, Any] = dict(
        lr=1e-3, warmup_steps=max(steps // 20, 1),
        decay_steps=steps, moment_dtype=base.moment_dtype,
        second_moment=base.second_moment)
    if job.optimizer:
        okw.update(job.optimizer)
    return cfg, par, OptimizerConfig(**okw)


def train_pieces(job: TrainJob):
    """(ModelConfig, ParallelConfig, OptimizerConfig) for a TrainJob —
    ONE resolution shared by the Session path and the deprecated
    ``launch.train`` shim, so both train the same model identically."""
    return _resolve_pieces(job, job.steps)


def rl_pieces(job: RLJob):
    """(ModelConfig, ParallelConfig, OptimizerConfig) for an RLJob.
    The optimizer schedule spans the LEARNER's steps; the actors share
    the same ModelConfig so version-0 weights (seeded identically on
    both planes) and every published version stay schema-compatible."""
    return _resolve_pieces(job, job.learner_steps)


def elastic_spec(job: TrainJob, *, namespace: Optional[str] = None):
    """The ElasticTrainSpec a TrainJob declares."""
    from repro.elastic.trainer import ElasticTrainSpec
    cfg, par, ocfg = train_pieces(job)
    kw: Dict[str, Any] = {}
    if namespace or job.namespace:
        kw["namespace"] = namespace or job.namespace
    return ElasticTrainSpec(
        cfg, par, ocfg, steps=job.steps, seq_len=job.seq_len,
        global_batch=job.global_batch, base_shape=tuple(job.base_shape),
        max_data=job.max_data, name=job.name, ckpt_every=job.ckpt_every,
        keep=job.keep, log_every=job.log_every,
        device_steps=job.device_steps, seed=job.seed,
        data_seed=job.data_seed, fail_at=job.fail_at,
        rejoin_timeout_s=job.rejoin_timeout_s, verbose=job.verbose, **kw)


def trainer_probe(handle: Handle):
    """A ``step`` status probe bound to THIS workload's live trainer (not
    a shared metrics series another run may have written).  Returns the
    ``on_trainer`` hook that binds each (re)created trainer."""
    holder: Dict[str, Any] = {}
    # before the first trainer exists the probe raises and status() just
    # omits the key — never another workload's step
    handle.probe("step", lambda: holder["trainer"].progress)
    return lambda trainer: holder.__setitem__("trainer", trainer)


def train_result(out: Dict[str, Any]) -> Dict[str, Any]:
    return {"losses": out["losses"], "loss_by_step": out["loss_by_step"],
            "params": out["params"], "opt": out.get("opt"),
            "report": out["report"]}


def resolve_serve_cfg(job: ServeJob):
    return registry.get_smoke(job.arch) if job.smoke \
        else registry.get_config(job.arch)


def build_engine(job: ServeJob, *, registry_out: Optional[Registry] = None):
    """Construct the continuous-batching engine a ServeJob declares.
    Called inside the serving pod on tenant/fabric backends so
    compilation lands on the pod's clock."""
    from repro.launch.mesh import single_device_mesh
    from repro.serving import ServingEngine
    cfg = resolve_serve_cfg(job)
    return ServingEngine(cfg, registry.get_parallel(job.arch),
                         single_device_mesh(), num_slots=job.slots,
                         prompt_len=job.prompt_len,
                         max_new_tokens=job.max_new_tokens, seed=job.seed,
                         registry=registry_out, paged=job.paged,
                         block_size=job.block_size,
                         pool_blocks=job.pool_blocks,
                         prefix_cache=job.prefix_cache)


def run_serve_replicated(handle: Handle, job: ServeJob, metrics: Registry,
                         *, capacity=None):
    """Shared multi-replica ServeJob driver: N engines behind the
    session-affine router, scaled by the HPA-style reconciler.  Scale
    decisions surface on the Handle as ``replicas: desired→observed``
    detail (the PR-5 reconcile-loop contract); ``capacity`` optionally
    gates scale-up through a fair-share claim."""
    from repro.serving import serve_replicated

    def factory(name, reg):
        engine = build_engine(job, registry_out=reg)
        if job.warmup:
            with engine.mesh:
                engine.warmup()
        return engine

    def on_scale(desired, observed, reason):
        handle._transition(WorkloadState.RUNNING,
                           replicas=f"{desired}→{observed}",
                           reason=reason)

    handle.probe("completed",
                 lambda: int(metrics.series(GAUGES.COMPLETED).total))
    handle.probe("replicas",
                 lambda: int(metrics.series(GAUGES.REPLICAS).last))
    handle._transition(WorkloadState.RUNNING, slots=job.slots,
                       replicas=f"{job.min_replicas}→0")
    results, metrics, events = serve_replicated(
        factory, serve_requests(job), min_replicas=job.min_replicas,
        max_replicas=job.max_replicas, target_backlog=job.target_backlog,
        ttft_slo_s=job.ttft_slo_s, lease_timeout=job.lease_timeout,
        registry=metrics, should_stop=handle.should_stop,
        on_scale=on_scale, capacity=capacity)
    return {"results": results, "metrics": metrics,
            "scale_events": events,
            "report": serving_report(metrics, step=job.name)}


def serve_requests(job: ServeJob) -> List[dict]:
    if job.requests is not None:
        return [dict(r) for r in job.requests]
    return make_requests(job.n_requests, job.prompt_len, job.max_new_tokens,
                         vocab_size=resolve_serve_cfg(job).vocab_size,
                         seed=job.seed, gen_lens=job.gen_lens)


def build_rl_engine(job: RLJob, cfg, par, *, registry_out=None):
    """One actor's continuous-batching engine, built from the SAME
    resolved ModelConfig as the learner (never re-resolved from the
    arch) so published weight trees always match the engine schema."""
    from repro.launch.mesh import single_device_mesh
    from repro.serving import ServingEngine
    return ServingEngine(cfg, par, single_device_mesh(),
                         num_slots=job.slots, prompt_len=job.prompt_len,
                         max_new_tokens=job.max_new_tokens, seed=job.seed,
                         registry=registry_out, paged=job.paged,
                         block_size=job.block_size,
                         pool_blocks=job.pool_blocks,
                         prefix_cache=job.prefix_cache)


def run_rl_fleet(handle: Handle, job: RLJob, *, learner_store,
                 actor_store=None, metrics: Registry, capacity=None):
    """Shared RLJob driver: ticket feeder + actor fleet + learner.

    All three backends land here; they differ only in which stores the
    two planes see (one ObjectStore, or per-site federated views whose
    cross-link weight pulls are metered) and whether fleet width is
    gated by a fair-share ``capacity`` callable (``resize_claim``).

    The feeder emits rollout tickets in *waves*: a burst is enqueued
    only once the shared ticket queue is fully idle (no pending AND no
    leased), which is exactly when every actor has exited its engine
    wave and polled the policy store — so actors observe version bumps
    between waves and the replay backlog (capped at ~2 learner chunks)
    cannot age past ``max_policy_lag`` in steady state."""
    import numpy as np

    from repro.rl import (ActorFleet, PolicyStore, RLLearner, RLLearnerSpec,
                          RolloutActor, RolloutQueue, ticket_queue)

    cfg, par, ocfg = rl_pieces(job)
    spec = RLLearnerSpec(
        cfg, par, ocfg, steps=job.learner_steps, seq_len=job.seq_len,
        batch=job.rollouts_per_step, device_steps=job.device_steps,
        ckpt_every=job.ckpt_every, broadcast_every=job.broadcast_every,
        max_policy_lag=job.max_policy_lag, seed=job.seed, keep=job.keep,
        fail_at=job.fail_at)
    tickets = ticket_queue(lease_timeout=job.lease_timeout)
    rollouts = RolloutQueue(lease_timeout=job.lease_timeout,
                            registry=metrics)
    publish = PolicyStore(learner_store, registry=metrics)
    subscribe = publish if actor_store is None \
        else PolicyStore(actor_store, registry=metrics)
    prompts: Dict[Any, List[int]] = {}

    def make_actor(name):
        return RolloutActor(name, build_rl_engine(job, cfg, par),
                            tickets, rollouts, subscribe, prompts=prompts,
                            registry=metrics)

    fleet = ActorFleet(make_actor, width=job.actors, capacity=capacity,
                       registry=metrics, name=f"{job.name}-actor")
    learner = RLLearner(spec, rollouts, publish, store=learner_store,
                        registry=metrics, name=job.name)
    handle.probe("learner_step", lambda: learner.report.steps_done)
    handle.probe("policy_version", lambda: learner.version)
    handle.probe("actors", lambda: fleet.width)
    handle.probe("rollouts_trained", lambda: rollouts.trained)

    stop_feed = threading.Event()
    handle.add_cancel_hook(stop_feed.set)
    rng = np.random.default_rng(job.seed + 101)
    burst = max(job.rollouts_per_step, job.actors * job.slots)
    backlog_cap = 2 * job.rollouts_per_step * max(job.device_steps, 1)
    n_fed = [0]

    def feed():
        while not stop_feed.is_set():
            if (tickets.pending > 0 or tickets.leased > 0
                    or rollouts.pending >= backlog_cap):
                time.sleep(2e-3)
                continue
            for _ in range(burst):
                rid = f"t{n_fed[0]:05d}"
                n_fed[0] += 1
                prompt = [int(x) for x in rng.integers(
                    1, cfg.vocab_size, size=job.prompt_len)]
                prompts[rid] = prompt
                tickets.put({"id": rid, "prompt": prompt,
                             "max_new_tokens": job.max_new_tokens})

    feeder = threading.Thread(target=feed, name=f"{job.name}-feeder",
                              daemon=True)
    handle._transition(WorkloadState.RUNNING, actors=job.actors,
                       steps=job.learner_steps)
    granted = fleet.start()
    feeder.start()
    min_syncs = 0
    try:
        out = learner.run_supervised(handle.should_stop)
        # the final version is published after the last step: give the
        # (now idle) actors one beat to observe it before teardown
        deadline = time.monotonic() + 10.0
        while fleet.min_syncs() < 1 and time.monotonic() < deadline \
                and fleet.width > 0:
            time.sleep(5e-3)
        min_syncs = fleet.min_syncs()
    finally:
        stop_feed.set()
        fleet.stop_all()
        feeder.join(timeout=10.0)
    rep = learner.report
    return {
        "done": bool(out.get("done")),
        "preempted": bool(out.get("preempted")),
        "report": dataclasses.asdict(rep),
        "losses": list(rep.losses),
        "steps_done": rep.steps_done,
        "steps_lost": rep.steps_lost,
        "recoveries": rep.recoveries,
        "publishes": rep.publishes,
        "final_version": rep.final_version,
        "trained": rollouts.trained,
        "stale_dropped": rollouts.stale_dropped,
        "max_lag_trained": rollouts.max_lag_trained(),
        "rollouts_pushed": rollouts.pushed,
        "tickets_fed": n_fed[0],
        "actors_granted": granted,
        "min_actor_syncs": min_syncs,
        "actor_syncs": {n: a.syncs for n, a in fleet.actors.items()},
        "metrics": metrics,
    }


def _watch_job(handle: Handle, cluster, job, *, poll_s: float = 0.01,
               grace_s: float = 10.0):
    """The batch-job reconcile loop: respawn failures via the cluster
    controller, drain cooperatively on cancel (preempt -> grace ->
    hard-evict), surface platform preemption as a terminal state."""
    preempted_at: Optional[float] = None
    while True:
        if handle.cancel_requested:
            now = time.monotonic()
            if preempted_at is None:
                preempted_at = now
                for pod in job.pods:
                    if pod.state in (PodState.PENDING, PodState.RUNNING):
                        cluster.preempt_pod(
                            pod, reason=f"api cancel: {handle.spec.name}")
            elif now - preempted_at > grace_s:
                for pod in job.pods:
                    cluster.finish_preempt(pod)
        if job.succeeded:
            return job.results()
        if job.terminal and job.preempted:
            if not handle.cancel_requested:
                handle._set_final(WorkloadState.PREEMPTED)
            return job.results()
        if job.failed:
            errs = [p.error for p in job.pods if p.error]
            raise RuntimeError(
                f"job {job.spec.name} failed after backoff: {errs[:1]}")
        if not handle.cancel_requested:
            cluster.reconcile()
        time.sleep(poll_s)


def _run_workflow(handle: Handle, run: WorkflowRun, wf: Workflow):
    handle.probe("steps_done", lambda: len(wf.reports))
    if run.graph is not None:
        # workflow program: compile the declarative graph and run ready
        # branches concurrently over the backend (repro.flow)
        from repro.flow import GraphRunner
        runner = GraphRunner(wf, run.graph, max_workers=run.max_workers)
        handle._transition(WorkloadState.RUNNING, mode="graph",
                           steps=runner.program.size)
        results = runner.run(resume=run.resume, only=run.only,
                             should_stop=handle.should_stop)
    else:
        define = run.resolve_define()
        define(wf)
        handle._transition(WorkloadState.RUNNING, steps=len(wf.steps))
        results = wf.run(resume=run.resume, only=run.only,
                         should_stop=handle.should_stop)
    return {"results": results, "reports": wf.reports,
            "table": wf.table_one()}


# ----------------------------------------------------------------- backends
class ClusterBackend:
    """One bare orchestrator Cluster (+ optional ObjectStore)."""

    kind = "cluster"

    def __init__(self, session, cluster, store: Optional[ObjectStore]):
        self.session = session
        self.cluster = cluster
        self.store = store
        self.metrics = session.metrics

    # ------------------------------------------------------------ TrainJob
    def run_train(self, handle: Handle, job: TrainJob):
        from repro.elastic.trainer import ElasticTrainer
        handle._transition(WorkloadState.PLACING)
        tspec = elastic_spec(job)
        store = ObjectStore(job.ckpt_dir) if job.ckpt_dir else None
        stop = threading.Event()
        trainer = ElasticTrainer(self.cluster, tspec, store=store,
                                 metrics=self.metrics, stop=stop)
        handle.add_cancel_hook(stop.set)
        handle.probe("step", lambda: trainer.progress)
        handle._transition(WorkloadState.RUNNING,
                           devices=len(self.cluster.online_devices))
        return train_result(trainer.run())

    # ------------------------------------------------------------ ServeJob
    def run_serve(self, handle: Handle, job: ServeJob):
        from repro.core.queue import WorkQueue
        handle._transition(WorkloadState.PLACING)
        metrics = Registry()
        if job.max_replicas > 1:
            return run_serve_replicated(handle, job, metrics)
        engine = build_engine(job, registry_out=metrics)
        queue = WorkQueue(serve_requests(job),
                          lease_timeout=job.lease_timeout)
        if job.warmup:
            with engine.mesh:
                engine.warmup()
        handle.probe("completed",
                     lambda: int(metrics.series(GAUGES.COMPLETED).total))
        handle._transition(WorkloadState.RUNNING, slots=job.slots)
        results, metrics = engine.run(queue,
                                      default_max_new=job.max_new_tokens,
                                      should_stop=handle.should_stop)
        return {"results": results, "metrics": metrics,
                "report": serving_report(metrics, step=job.name)}

    # ------------------------------------------------------------ BatchJob
    def run_batch(self, handle: Handle, job: BatchJob):
        fn = job.resolve_fn()
        ns = job.namespace or self.session.namespace or "default"
        if ns not in self.cluster.namespaces:
            self.cluster.create_namespace(ns)
        handle._transition(WorkloadState.PLACING, namespace=ns)
        kjob = self.cluster.submit(ns, JobSpec(
            job.name, fn, replicas=job.replicas,
            devices_per_pod=job.devices_per_pod,
            backoff_limit=job.backoff_limit, priority=job.priority))
        handle._transition(WorkloadState.RUNNING, replicas=job.replicas)
        return {"results": _watch_job(handle, self.cluster, kjob)}

    # --------------------------------------------------------------- RLJob
    def run_rl(self, handle: Handle, job: RLJob):
        handle._transition(WorkloadState.PLACING)
        if job.ckpt_dir:
            store = ObjectStore(job.ckpt_dir)
        elif self.store is not None:
            store = self.store
        else:
            import tempfile
            store = ObjectStore(tempfile.mkdtemp(prefix="rl-ckpt-"))
        return run_rl_fleet(handle, job, learner_store=store,
                            metrics=Registry())

    # --------------------------------------------------------- WorkflowRun
    def run_workflow(self, handle: Handle, run: WorkflowRun):
        if self.store is None:
            raise ManifestError(
                "WorkflowRun on a bare cluster needs Session(cluster=..., "
                "store=ObjectStore(...)) for step markers")
        handle._transition(WorkloadState.PLACING)
        wf = Workflow(run.name, cluster=self.cluster, store=self.store,
                      metrics=self.metrics,
                      namespace=run.namespace or self.session.namespace
                      or "default", bus=self.session.bus)
        return _run_workflow(handle, run, wf)


class FabricBackend:
    """The multi-site federation (``repro.fabric``) — placed workloads,
    cross-site failover."""

    kind = "fabric"

    def __init__(self, session, fabric, planner, store):
        self.session = session
        self.fabric = fabric
        self.planner = planner
        self.store = store
        self.metrics = session.metrics

    def _need_planner(self, what: str):
        if self.planner is None:
            raise ManifestError(
                f"{what} on a fabric session needs "
                f"Session(planner=PlacementPlanner(FederatedStore(...))) "
                f"for placement + replica tracking")
        return self.planner

    def _pick_site(self, job, need: int):
        if job.site is not None:
            site = self.fabric.sites[job.site]
            if not site.up:
                raise RuntimeError(f"site {job.site!r} is down")
            return site
        cands = [s for s in self.fabric.up_sites()
                 if len(s.cluster.online_devices) >= max(need, 1)]
        if not cands:
            raise RuntimeError(
                f"no live site can host {job.name!r} ({need} devices)")
        return min(cands, key=lambda s: (s.queue_depth(), -s.capacity,
                                         s.name))

    # ------------------------------------------------------------ TrainJob
    def run_train(self, handle: Handle, job: TrainJob):
        from repro.fabric.failover import run_elastic_federated
        planner = self._need_planner("TrainJob")
        handle._transition(WorkloadState.PLACING)
        stop = threading.Event()
        handle.add_cancel_hook(stop.set)
        on_trainer = trainer_probe(handle)
        handle._transition(WorkloadState.RUNNING)
        result = run_elastic_federated(planner, elastic_spec(job),
                                       metrics=self.metrics, stop=stop,
                                       on_trainer=on_trainer)
        out = train_result(result.out) if result.out else {}
        out.update({"sites": result.sites,
                    "migrations": result.migrations,
                    "report": result.report})
        return out

    # ------------------------------------------------------------ ServeJob
    def run_serve(self, handle: Handle, job: ServeJob):
        handle._transition(WorkloadState.PLACING)
        from repro.core.queue import WorkQueue
        site = self._pick_site(job, 1)
        ns = self.session.namespace or "serve"
        if ns not in site.cluster.namespaces:
            site.cluster.create_namespace(ns)
        queue = WorkQueue(serve_requests(job),
                          lease_timeout=job.lease_timeout)

        def serve_pod(ctx):
            engine = build_engine(job)    # compiled on the pod's clock
            results, metrics = engine.run(
                queue, default_max_new=job.max_new_tokens,
                should_stop=lambda: ctx.should_stop() or
                handle.should_stop())
            return {"results": results,
                    "report": serving_report(metrics, step=job.name)}

        kjob = site.cluster.submit(ns, JobSpec(
            job.name, serve_pod, replicas=1, devices_per_pod=1,
            backoff_limit=1))
        handle._transition(WorkloadState.RUNNING, site=site.name)
        pods = _watch_job(handle, site.cluster, kjob)
        out = pods[0] if pods and pods[0] is not None \
            else {"results": {}, "report": None}
        out["site"] = site.name
        return out

    # ------------------------------------------------------------ BatchJob
    def run_batch(self, handle: Handle, job: BatchJob):
        fn = job.resolve_fn()
        ns = job.namespace or self.session.namespace or "default"
        handle._transition(WorkloadState.PLACING)
        site = self._pick_site(job, job.devices_per_pod * job.replicas)
        if ns not in site.cluster.namespaces:
            site.cluster.create_namespace(ns)
        kjob = site.cluster.submit(ns, JobSpec(
            job.name, fn, replicas=job.replicas,
            devices_per_pod=job.devices_per_pod,
            backoff_limit=job.backoff_limit, priority=job.priority))
        handle._transition(WorkloadState.RUNNING, site=site.name)
        return {"results": _watch_job(handle, site.cluster, kjob),
                "site": site.name}

    # --------------------------------------------------------------- RLJob
    def run_rl(self, handle: Handle, job: RLJob):
        """Actors and learner at (possibly) different sites of the
        federation: the learner publishes weight versions into its
        site's store view, actors fetch through THEIR site's view, so
        every pull-on-bump is a metered cross-link transfer."""
        planner = self._need_planner("RLJob")
        handle._transition(WorkloadState.PLACING)
        actor_site = self._pick_site(job, job.actors)
        if job.learner_site is not None:
            learner_site = self.fabric.sites[job.learner_site]
            if not learner_site.up:
                raise RuntimeError(f"site {job.learner_site!r} is down")
        else:
            learner_site = actor_site
        handle._transition(WorkloadState.PLACING, site=actor_site.name,
                           learner_site=learner_site.name)
        fed = planner.fed
        learner_store = fed.view(learner_site.name)
        actor_store = None if learner_site.name == actor_site.name \
            else fed.view(actor_site.name)
        out = run_rl_fleet(handle, job, learner_store=learner_store,
                           actor_store=actor_store, metrics=Registry())
        out["site"] = actor_site.name
        out["learner_site"] = learner_site.name
        return out

    # --------------------------------------------------------- WorkflowRun
    def run_workflow(self, handle: Handle, run: WorkflowRun):
        planner = self._need_planner("WorkflowRun")
        handle._transition(WorkloadState.PLACING)
        wf = Workflow(run.name, planner=planner, metrics=self.metrics,
                      namespace=run.namespace or self.session.namespace
                      or "default", bus=self.session.bus)
        return _run_workflow(handle, run, wf)


class TenantBackend:
    """One tenant's fair share of the federation (``repro.vcluster``) —
    every workload rides the FairShareScheduler.  The scheduler's
    reconcile loop must be running (``sched.start()`` / ``with sched:``)
    for queued workloads to place."""

    kind = "tenant"

    def __init__(self, session, tenant, store):
        self.session = session
        self.tenant = tenant            # a VirtualCluster
        self.sched = tenant.sched
        self.store = store
        self.metrics = session.metrics

    def _watch_tenant_job(self, handle: Handle, tj, *,
                          poll_s: float = 0.01):
        """Reconcile loop over a fair-share TenantJob: observe placement,
        cancel cooperatively (queued jobs dequeue, running pods drain)."""
        cancelled = False
        running_seen = False
        while tj.state in ("queued", "running"):
            if handle.cancel_requested and not cancelled:
                cancelled = True
                self.sched.cancel(tj)
            if tj.state == "running" and not running_seen:
                running_seen = True
                handle._transition(WorkloadState.RUNNING, site=tj.site)
            time.sleep(poll_s)
        if tj.state == "failed":
            raise RuntimeError(
                f"tenant job {tj.spec.name!r} failed: {tj.error}")
        return tj

    # ------------------------------------------------------------ TrainJob
    def run_train(self, handle: Handle, job: TrainJob):
        if job.site is None:
            raise ManifestError(
                "TrainJob on a tenant session needs the claim site",
                field="spec.site")
        if job.devices is None:
            raise ManifestError(
                "TrainJob on a tenant session needs the claim size",
                field="spec.devices")
        handle._transition(WorkloadState.PLACING, site=job.site,
                           devices=job.devices)
        stop = threading.Event()
        handle.add_cancel_hook(stop.set)
        on_trainer = trainer_probe(handle)
        store = ObjectStore(job.ckpt_dir) if job.ckpt_dir else None
        handle._transition(WorkloadState.RUNNING, site=job.site)
        out = self.tenant.run_elastic(
            elastic_spec(job), site=job.site, devices=job.devices,
            store=store, min_devices=job.min_devices, stop=stop,
            on_trainer=on_trainer)
        return train_result(out)

    # ------------------------------------------------------------ ServeJob
    def run_serve(self, handle: Handle, job: ServeJob):
        handle._transition(WorkloadState.PLACING, site=job.site or "auto")
        # the workload's own Registry rides into the engine so the raw
        # TTFT/latency series survive per wave — the SLO grader
        # (repro.scenarios.grade) needs the samples, not just the report
        metrics = Registry()
        if job.max_replicas > 1:
            # replicated fleet inside the tenant's fair share: one device
            # per replica, claimed up front and elastically resized by the
            # autoscaler through resize_claim — another tenant's load caps
            # the scale-up at the granted count
            site = job.site or next(iter(self.sched.fabric.sites))
            claim = self.tenant.claim(site, job.min_replicas,
                                      min_devices=job.min_replicas)
            try:
                out = run_serve_replicated(
                    handle, job, metrics,
                    capacity=lambda want: self.sched.resize_claim(
                        claim, want))
            finally:
                claim.release()
            out["site"] = site
            return out
        tj, queue = self.tenant.serve(
            lambda: build_engine(job, registry_out=metrics),
            serve_requests(job), site=job.site,
            lease_timeout=job.lease_timeout,
            default_max_new=job.max_new_tokens,
            should_stop=handle.should_stop)
        tj = self._watch_tenant_job(handle, tj)
        # a cancelled pod still drained cooperatively and returned its
        # completed requests: partial results survive, like the other
        # backends' CANCELLED contract
        pods = tj.results() if tj.job is not None else []
        results = pods[0] if pods and pods[0] is not None else {}
        return {"results": results, "site": tj.site, "job": tj,
                "metrics": metrics,
                "report": serving_report(metrics, step=job.name)}

    # ------------------------------------------------------------ BatchJob
    def run_batch(self, handle: Handle, job: BatchJob):
        fn = job.resolve_fn()
        handle._transition(WorkloadState.PLACING, site=job.site or "auto")
        tj = self.tenant.submit(JobSpec(
            job.name, fn, replicas=job.replicas,
            devices_per_pod=job.devices_per_pod,
            backoff_limit=job.backoff_limit, priority=job.priority),
            site=job.site)
        tj = self._watch_tenant_job(handle, tj)
        return {"results": tj.results() if tj.state == "done" else [],
                "site": tj.site, "preemptions": tj.preemptions}

    # --------------------------------------------------------------- RLJob
    def run_rl(self, handle: Handle, job: RLJob):
        """Actors and learner inside the tenant's fair share: one device
        per actor is claimed up front and the fleet resizes through
        ``resize_claim`` — another tenant's load caps the granted width.
        Weight traffic moves through tenant-billed store views."""
        site = job.site or next(iter(self.sched.fabric.sites))
        learner_site = job.learner_site or site
        handle._transition(WorkloadState.PLACING, site=site,
                           learner_site=learner_site)
        want = job.devices or job.actors
        claim = self.tenant.claim(site, want,
                                  min_devices=job.min_devices or 1)
        learner_store = self.tenant.store(learner_site)
        actor_store = None if learner_site == site \
            else self.tenant.store(site)
        try:
            out = run_rl_fleet(
                handle, job, learner_store=learner_store,
                actor_store=actor_store, metrics=Registry(),
                capacity=lambda w: self.sched.resize_claim(claim, w))
        finally:
            claim.release()
        out["site"] = site
        out["learner_site"] = learner_site
        return out

    # --------------------------------------------------------- WorkflowRun
    def run_workflow(self, handle: Handle, run: WorkflowRun):
        handle._transition(WorkloadState.PLACING)
        kw: Dict[str, Any] = {}
        if run.namespace:
            kw["namespace"] = run.namespace
        wf = self.tenant.workflow(run.name, **kw)
        return _run_workflow(handle, run, wf)
