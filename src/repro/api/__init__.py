"""The unified declarative workload API — one manifest-driven control
plane for train / serve / batch / workflow / RL across cluster, fabric
and tenants (see docs/api.md).

    from repro.api import Session, TrainJob

    session = Session(cluster=Cluster())
    handle = session.apply(TrainJob(name="demo", steps=20))
    out = handle.wait()
"""
from repro.api.resources import (API_VERSION, BatchJob, KINDS, ManifestError,
                                 RLJob, ServeJob, TrainJob, WorkflowRun,
                                 WorkloadSpec, from_json, from_manifest,
                                 load_manifest, resolve_entrypoint)
from repro.api.session import (Handle, Session, TERMINAL_STATES,
                               WorkloadState, WorkloadStatus)

__all__ = [
    "API_VERSION", "BatchJob", "Handle", "KINDS", "ManifestError", "RLJob",
    "ServeJob", "Session", "TERMINAL_STATES", "TrainJob", "WorkflowRun",
    "WorkloadSpec", "WorkloadState", "WorkloadStatus", "from_json",
    "from_manifest", "load_manifest", "resolve_entrypoint",
]
