"""Typed, versioned workload resources — the kubectl-manifest analogue.

The paper's platform is *declarative*: users hand Kubernetes a manifest
describing what should run, and the controllers make it so (§II, §VI).
This module is that surface for the repro: five workload kinds —

  * ``TrainJob``     — self-healing elastic training (repro.elastic);
  * ``ServeJob``     — continuous-batching inference (repro.serving);
  * ``BatchJob``     — a plain orchestrator Job (repro.core.orchestrator);
  * ``WorkflowRun``  — a measured, resumable step DAG (repro.core.workflow);
  * ``RLJob``        — actor fleet + elastic RL learner (repro.rl);

each a frozen dataclass with a lossless ``to_manifest()`` /
``from_manifest()`` pair (plain dict/JSON — the YAML analogue), defaults
for everything a smoke run doesn't care about, and validation that names
the offending field instead of exploding somewhere downstream.

Two fields are *runtime-only* (callables cannot ride in a manifest):
``BatchJob.fn`` and ``WorkflowRun.define``.  Their declarative twins are
``entrypoint`` strings (``"pkg.module:attr"``) resolved at apply time, so
a manifest on disk can still describe every kind end to end.  Runtime
fields are excluded from manifests AND from equality, so the round-trip
law ``from_manifest(to_manifest(spec)) == spec`` holds for every spec.

``repro.api.Session.apply`` accepts any of these and routes it to the
matching subsystem on whichever backend the session wraps.
"""
from __future__ import annotations

import dataclasses
import importlib
import json
import typing
from dataclasses import dataclass, field
from typing import (Any, Callable, ClassVar, Dict, List, Mapping, Optional,
                    Tuple, Type, Union)

API_VERSION = "repro/v1"


class ManifestError(ValueError):
    """A manifest (or a directly constructed spec) failed validation.

    ``field`` names the offending field as a manifest path
    (``"spec.steps"``, ``"metadata.name"``, ``"kind"``) so callers — and
    error messages — can point at exactly what to fix."""

    def __init__(self, message: str, *, field: Optional[str] = None):
        self.field = field
        super().__init__(message if field is None
                         else f"{field}: {message}")


def _require(cond: bool, message: str, field: str) -> None:
    if not cond:
        raise ManifestError(message, field=field)


# --------------------------------------------------------------- coercion
def _type_name(hint) -> str:
    return getattr(hint, "__name__", str(hint).replace("typing.", ""))


def _coerce(path: str, value, hint):
    """Check ``value`` against the dataclass type ``hint`` (converting
    JSON lists back to tuples where the field wants tuples) or raise a
    ManifestError naming ``path``."""
    if hint is Any:
        return value
    origin = typing.get_origin(hint)
    if origin is Union:
        args = typing.get_args(hint)
        if value is None:
            _require(type(None) in args, "may not be null", path)
            return None
        non_none = [a for a in args if a is not type(None)]
        if len(non_none) == 1:
            # Optional[X]: X's own (element-precise) error is the message
            return _coerce(path, value, non_none[0])
        for a in non_none:
            try:
                return _coerce(path, value, a)
            except ManifestError:
                continue
        raise ManifestError(
            f"expected {_type_name(hint)}, got {type(value).__name__}",
            field=path)
    if origin is tuple:
        _require(isinstance(value, (list, tuple)),
                 f"expected a list, got {type(value).__name__}", path)
        args = typing.get_args(hint)
        if len(args) == 2 and args[1] is Ellipsis:
            return tuple(_coerce(f"{path}[{i}]", v, args[0])
                         for i, v in enumerate(value))
        _require(len(value) == len(args),
                 f"expected {len(args)} items, got {len(value)}", path)
        return tuple(_coerce(f"{path}[{i}]", v, a)
                     for i, (v, a) in enumerate(zip(value, args)))
    if origin is list:
        _require(isinstance(value, (list, tuple)),
                 f"expected a list, got {type(value).__name__}", path)
        (item_t,) = typing.get_args(hint) or (Any,)
        return [_coerce(f"{path}[{i}]", v, item_t)
                for i, v in enumerate(value)]
    if origin is dict or hint is dict:
        _require(isinstance(value, Mapping),
                 f"expected an object, got {type(value).__name__}", path)
        args = typing.get_args(hint)
        val_t = args[1] if args else Any
        out = {}
        for k, v in value.items():
            _require(isinstance(k, str), "object keys must be strings",
                     path)
            out[k] = _coerce(f"{path}.{k}", v, val_t)
        return out
    if hint is int:
        _require(isinstance(value, int) and not isinstance(value, bool),
                 f"expected an int, got {type(value).__name__}", path)
        return value
    if hint is float:
        _require(isinstance(value, (int, float)) and
                 not isinstance(value, bool),
                 f"expected a number, got {type(value).__name__}", path)
        return float(value)
    if hint is bool:
        _require(isinstance(value, bool),
                 f"expected a bool, got {type(value).__name__}", path)
        return value
    if hint is str:
        _require(isinstance(value, str),
                 f"expected a string, got {type(value).__name__}", path)
        return value
    return value


def _jsonable(value):
    """Dataclass field value -> plain JSON value (tuples become lists)."""
    if isinstance(value, (list, tuple)):
        return [_jsonable(v) for v in value]
    if isinstance(value, Mapping):
        return {k: _jsonable(v) for k, v in value.items()}
    return value


def resolve_entrypoint(path: str) -> Callable:
    """``"pkg.module:attr"`` -> the attr, imported.  The declarative twin
    of a runtime callable field."""
    mod, sep, attr = path.partition(":")
    if not sep or not mod or not attr:
        raise ManifestError(
            f"entrypoint {path!r} must look like 'pkg.module:attr'",
            field="spec.entrypoint")
    try:
        target = importlib.import_module(mod)
    except ImportError as e:
        raise ManifestError(f"cannot import {mod!r}: {e}",
                            field="spec.entrypoint") from e
    try:
        for part in attr.split("."):
            target = getattr(target, part)
    except AttributeError as e:
        raise ManifestError(f"{mod!r} has no attribute {attr!r}",
                            field="spec.entrypoint") from e
    return target


# -------------------------------------------------------------- resources
def _runtime_field(**kw):
    """A callable slot excluded from manifests and equality."""
    return field(default=None, compare=False, repr=False,
                 metadata={"manifest": False}, **kw)


class WorkloadResource:
    """Shared manifest plumbing for the workload kinds."""

    KIND: ClassVar[str] = ""

    def _canonicalize(self, *names: str) -> None:
        """Normalize free-form (Any-typed) fields to their JSON shape at
        construction — tuples nested inside ``config``/``params`` dicts
        become lists — so ``from_manifest(to_manifest(spec)) == spec``
        holds even for specs built with Python tuples."""
        for n in names:
            v = getattr(self, n)
            if v is not None:
                object.__setattr__(self, n, _jsonable(v))

    @classmethod
    def _spec_fields(cls) -> List[dataclasses.Field]:
        return [f for f in dataclasses.fields(cls)
                if f.name != "name" and f.metadata.get("manifest", True)]

    def to_manifest(self) -> Dict[str, Any]:
        spec = {f.name: _jsonable(getattr(self, f.name))
                for f in self._spec_fields()}
        return {"apiVersion": API_VERSION, "kind": self.KIND,
                "metadata": {"name": self.name}, "spec": spec}

    def to_json(self, *, indent: Optional[int] = 1) -> str:
        return json.dumps(self.to_manifest(), indent=indent)

    @classmethod
    def _from_spec(cls, name: str, spec: Mapping[str, Any]):
        hints = typing.get_type_hints(cls)
        known = {f.name: f for f in cls._spec_fields()}
        kwargs: Dict[str, Any] = {"name": name}
        for key, value in spec.items():
            if key not in known:
                raise ManifestError(
                    f"unknown field for kind {cls.KIND!r}; known: "
                    f"{sorted(known)}", field=f"spec.{key}")
            kwargs[key] = _coerce(f"spec.{key}", value, hints[key])
        for f in known.values():
            if f.name not in kwargs and \
                    f.default is dataclasses.MISSING and \
                    f.default_factory is dataclasses.MISSING:
                raise ManifestError("required field missing",
                                    field=f"spec.{f.name}")
        return cls(**kwargs)


@dataclass(frozen=True)
class TrainJob(WorkloadResource):
    """Self-healing elastic training (routes to ``repro.elastic``; on a
    fabric backend, ``repro.fabric.failover``; on a tenant backend, a
    capacity claim inside the tenant's slice)."""

    KIND: ClassVar[str] = "TrainJob"

    name: str
    steps: int
    arch: str = "phi4-mini-3.8b"
    smoke: bool = True
    seq_len: int = 64
    global_batch: int = 4
    base_shape: Tuple[int, int] = (1, 1)
    max_data: Optional[int] = 1
    ckpt_dir: str = ""                  # "" = trainer-owned throwaway store
    ckpt_every: int = 0
    keep: Optional[int] = 2
    log_every: int = 10
    # optimizer steps fused into ONE device dispatch (lax.scan hot loop);
    # ckpt/log cadences snap UP to multiples, preemption latency is
    # bounded by one chunk (see repro.elastic.ElasticTrainSpec)
    device_steps: int = 1
    fail_at: int = -1                   # inject ONE crash at this step
    seed: int = 0
    data_seed: int = 17
    rejoin_timeout_s: float = 60.0
    verbose: bool = True
    namespace: Optional[str] = None     # default: "elastic" / the tenant's
    # model / optimizer overrides: kwargs for ModelConfig / the launch
    # schedule defaults (lr, warmup_steps, decay_steps, ...)
    config: Optional[Dict[str, Any]] = None
    optimizer: Optional[Dict[str, Any]] = None
    # tenant / fabric routing
    site: Optional[str] = None          # tenant backend: claim site
    devices: Optional[int] = None       # tenant backend: claim size
    min_devices: Optional[int] = None   # tenant backend: claim floor

    def __post_init__(self):
        self._canonicalize("config", "optimizer")
        _require(bool(self.name), "must be a non-empty string",
                 "metadata.name")
        _require(self.steps >= 1, "must be >= 1", "spec.steps")
        _require(self.seq_len >= 1, "must be >= 1", "spec.seq_len")
        _require(self.global_batch >= 1, "must be >= 1",
                 "spec.global_batch")
        _require(len(self.base_shape) == 2 and
                 all(s >= 1 for s in self.base_shape),
                 "must be two positive ints (data, model)",
                 "spec.base_shape")
        _require(self.ckpt_every >= 0, "must be >= 0", "spec.ckpt_every")
        _require(self.device_steps >= 1, "must be >= 1",
                 "spec.device_steps")
        _require(self.devices is None or self.devices >= 1,
                 "must be >= 1 when set", "spec.devices")


@dataclass(frozen=True)
class ServeJob(WorkloadResource):
    """Continuous-batching inference over a request queue (routes to
    ``repro.serving.ServingEngine``; tenant/fabric backends run it as a
    preemptible pod at a placed site)."""

    KIND: ClassVar[str] = "ServeJob"

    name: str
    arch: str = "phi4-mini-3.8b"
    smoke: bool = True
    n_requests: int = 8                 # synthetic stream when no requests
    prompt_len: int = 32
    max_new_tokens: int = 16
    slots: int = 4                      # decode-slot pool size
    seed: int = 0
    gen_lens: Optional[Tuple[int, ...]] = None   # heterogeneous stops
    lease_timeout: float = 30.0
    warmup: bool = False
    # explicit request stream: [{"id": ..., "prompt": [...], ...}, ...]
    requests: Optional[List[Dict[str, Any]]] = None
    site: Optional[str] = None          # tenant/fabric routing
    # paged KV pool + prefix cache (None = auto when the family supports it)
    paged: Optional[bool] = None
    block_size: int = 8
    pool_blocks: Optional[int] = None
    prefix_cache: bool = True
    # multi-replica serving: min==max pins the fleet size; min<max enables
    # the HPA-style autoscaler (serving.router) between the bounds
    min_replicas: int = 1
    max_replicas: int = 1
    target_backlog: float = 4.0         # autoscaler queue depth / replica
    ttft_slo_s: Optional[float] = None  # p99 service-TTFT scale-up trigger

    def __post_init__(self):
        self._canonicalize("requests")
        _require(bool(self.name), "must be a non-empty string",
                 "metadata.name")
        _require(self.slots >= 1, "must be >= 1", "spec.slots")
        _require(self.prompt_len >= 1, "must be >= 1", "spec.prompt_len")
        _require(self.max_new_tokens >= 1, "must be >= 1",
                 "spec.max_new_tokens")
        _require(self.n_requests >= 0, "must be >= 0", "spec.n_requests")
        _require(self.block_size >= 1, "must be >= 1", "spec.block_size")
        _require(self.pool_blocks is None or self.pool_blocks >= 2,
                 "must be >= 2 (one data block + the null block)",
                 "spec.pool_blocks")
        _require(1 <= self.min_replicas <= self.max_replicas,
                 "need 1 <= min_replicas <= max_replicas",
                 "spec.min_replicas")
        _require(self.target_backlog > 0, "must be > 0",
                 "spec.target_backlog")
        if self.gen_lens is not None:
            _require(len(self.gen_lens) > 0 and
                     all(g >= 1 for g in self.gen_lens),
                     "must be a non-empty list of ints >= 1",
                     "spec.gen_lens")
        if self.requests is not None:
            for i, r in enumerate(self.requests):
                _require(isinstance(r, Mapping) and "id" in r and
                         "prompt" in r,
                         "each request needs 'id' and 'prompt'",
                         f"spec.requests[{i}]")


@dataclass(frozen=True)
class BatchJob(WorkloadResource):
    """A plain orchestrator Job: N pod replicas running one function.

    The function arrives either as a runtime callable (``fn``, excluded
    from manifests) or declaratively as ``entrypoint`` —
    ``"pkg.module:attr"`` resolved at apply time and called as
    ``fn(ctx)`` (or ``fn(ctx, **params)`` when ``params`` is set)."""

    KIND: ClassVar[str] = "BatchJob"

    name: str
    replicas: int = 1
    devices_per_pod: int = 0
    backoff_limit: int = 3
    priority: Optional[int] = None
    namespace: Optional[str] = None
    site: Optional[str] = None          # tenant/fabric routing
    entrypoint: Optional[str] = None
    params: Optional[Dict[str, Any]] = None
    fn: Optional[Callable] = _runtime_field()

    def __post_init__(self):
        self._canonicalize("params")
        _require(bool(self.name), "must be a non-empty string",
                 "metadata.name")
        _require(self.replicas >= 1, "must be >= 1", "spec.replicas")
        _require(self.devices_per_pod >= 0, "must be >= 0",
                 "spec.devices_per_pod")
        _require(self.backoff_limit >= 0, "must be >= 0",
                 "spec.backoff_limit")
        if self.entrypoint is not None:
            _require(":" in self.entrypoint,
                     "must look like 'pkg.module:attr'", "spec.entrypoint")

    def resolve_fn(self) -> Callable:
        if self.fn is not None:
            fn = self.fn
        elif self.entrypoint is not None:
            fn = resolve_entrypoint(self.entrypoint)
        else:
            raise ManifestError(
                "BatchJob needs a runtime fn or a declarative entrypoint",
                field="spec.entrypoint")
        if self.params:
            params = dict(self.params)
            return lambda ctx: fn(ctx, **params)
        return fn


@dataclass(frozen=True)
class WorkflowRun(WorkloadResource):
    """A measured, resumable step DAG (routes to
    ``repro.core.workflow.Workflow`` on the session's backend).

    Steps arrive as a runtime ``define(wf, **params)`` callable (excluded
    from manifests), declaratively via ``entrypoint`` — e.g.
    ``"repro.apps.connect.pipeline:add_connect_steps"`` — or as a
    workflow *program*: a declarative ``graph`` of nodes with deps /
    ``when:`` conditionals / ``repeat:`` loops / ``scatter:`` fan-out /
    nested subworkflows, compiled and run concurrently by ``repro.flow``
    (``max_workers`` bounds the branch pool)."""

    KIND: ClassVar[str] = "WorkflowRun"

    name: str
    namespace: Optional[str] = None
    resume: bool = True
    only: Optional[str] = None          # run a single step in isolation
    entrypoint: Optional[str] = None
    params: Optional[Dict[str, Any]] = None
    graph: Optional[Dict[str, Any]] = None
    max_workers: int = 8                # graph mode: branch pool bound
    define: Optional[Callable] = _runtime_field()

    def __post_init__(self):
        self._canonicalize("params", "graph")
        _require(bool(self.name), "must be a non-empty string",
                 "metadata.name")
        if self.entrypoint is not None:
            _require(":" in self.entrypoint,
                     "must look like 'pkg.module:attr'", "spec.entrypoint")
        _require(isinstance(self.max_workers, int) and
                 not isinstance(self.max_workers, bool) and
                 self.max_workers >= 1,
                 "must be an integer >= 1", "spec.max_workers")
        if self.graph is not None:
            _require(self.entrypoint is None and self.define is None,
                     "a graph workflow cannot also set entrypoint/define",
                     "spec.graph")
            # eager shape validation: bad graphs fail at apply time with
            # a field-naming ManifestError, not mid-run (lazy import —
            # repro.flow imports resolve_entrypoint from this module)
            from repro.flow.spec import validate_graph
            validate_graph(self.graph, field="spec.graph")

    def resolve_define(self) -> Callable:
        if self.define is not None:
            fn = self.define
        elif self.entrypoint is not None:
            fn = resolve_entrypoint(self.entrypoint)
        else:
            raise ManifestError(
                "WorkflowRun needs a runtime define or a declarative "
                "entrypoint", field="spec.entrypoint")
        if self.params:
            params = dict(self.params)
            return lambda wf: fn(wf, **params)
        return fn


@dataclass(frozen=True)
class RLJob(WorkloadResource):
    """Distributed RL: a serving-plane actor fleet feeding an elastic
    policy-gradient learner (routes to ``repro.rl``).

    ``actors`` ServingEngine replicas lease rollout tickets from one
    shared work queue, push version-stamped trajectories into a leased
    replay buffer, and pull fresh weights from a versioned policy store
    every ``broadcast_every`` learner steps.  The learner drains
    ``rollouts_per_step`` trajectories per optimizer step, never trains
    on rollouts staler than ``max_policy_lag`` weight versions (stale
    ones are dropped and metered), and checkpoint-resumes across
    preemption with the replay queue snapshot riding in the manifest."""

    KIND: ClassVar[str] = "RLJob"

    name: str
    learner_steps: int
    arch: str = "phi4-mini-3.8b"
    smoke: bool = True
    actors: int = 2                     # rollout fleet width
    rollouts_per_step: int = 2          # learner batch (trajectories/step)
    prompt_len: int = 8
    max_new_tokens: int = 8
    seq_len: int = 32                   # learner sequence budget
    slots: int = 2                      # decode-slot pool per actor
    max_policy_lag: int = 2             # bounded-staleness contract
    broadcast_every: int = 2            # learner steps between publishes
    ckpt_every: int = 2
    device_steps: int = 1               # fused optimizer steps per dispatch
    keep: int = 3
    seed: int = 0
    fail_at: int = -1                   # inject ONE learner crash here
    lease_timeout: float = 30.0
    ckpt_dir: str = ""                  # "" = job-owned throwaway store
    # model / optimizer overrides (kwargs for ModelConfig / the schedule)
    config: Optional[Dict[str, Any]] = None
    optimizer: Optional[Dict[str, Any]] = None
    # paged KV pool on the actor engines
    paged: Optional[bool] = None
    block_size: int = 8
    pool_blocks: Optional[int] = None
    prefix_cache: bool = True
    # tenant / fabric routing: actors serve at `site`, the learner trains
    # at `learner_site` (default: same site), weights cross the fabric
    site: Optional[str] = None
    learner_site: Optional[str] = None
    devices: Optional[int] = None       # tenant backend: actor claim size
    min_devices: Optional[int] = None   # tenant backend: actor claim floor

    def __post_init__(self):
        self._canonicalize("config", "optimizer")
        _require(bool(self.name), "must be a non-empty string",
                 "metadata.name")
        _require(self.learner_steps >= 1, "must be >= 1",
                 "spec.learner_steps")
        _require(self.actors >= 1, "must be >= 1", "spec.actors")
        _require(self.rollouts_per_step >= 1, "must be >= 1",
                 "spec.rollouts_per_step")
        _require(self.prompt_len >= 1, "must be >= 1", "spec.prompt_len")
        _require(self.max_new_tokens >= 1, "must be >= 1",
                 "spec.max_new_tokens")
        _require(self.seq_len >= 2, "must be >= 2 (one shifted pair)",
                 "spec.seq_len")
        _require(self.slots >= 1, "must be >= 1", "spec.slots")
        _require(self.max_policy_lag >= 0, "must be >= 0",
                 "spec.max_policy_lag")
        _require(self.broadcast_every >= 1, "must be >= 1",
                 "spec.broadcast_every")
        _require(self.ckpt_every >= 0, "must be >= 0", "spec.ckpt_every")
        _require(self.device_steps >= 1, "must be >= 1",
                 "spec.device_steps")
        _require(self.keep >= 1, "must be >= 1", "spec.keep")
        _require(self.lease_timeout > 0, "must be > 0",
                 "spec.lease_timeout")
        _require(self.block_size >= 1, "must be >= 1", "spec.block_size")
        _require(self.pool_blocks is None or self.pool_blocks >= 2,
                 "must be >= 2 (one data block + the null block)",
                 "spec.pool_blocks")
        _require(self.devices is None or self.devices >= 1,
                 "must be >= 1 when set", "spec.devices")


KINDS: Dict[str, Type[WorkloadResource]] = {
    cls.KIND: cls
    for cls in (TrainJob, ServeJob, BatchJob, WorkflowRun, RLJob)}

WorkloadSpec = Union[TrainJob, ServeJob, BatchJob, WorkflowRun, RLJob]


# ------------------------------------------------------------- entrypoints
def from_manifest(manifest: Mapping[str, Any]) -> WorkloadSpec:
    """Parse + validate one manifest dict into a typed workload spec."""
    if not isinstance(manifest, Mapping):
        raise ManifestError(
            f"manifest must be an object, got {type(manifest).__name__}")
    version = manifest.get("apiVersion", API_VERSION)
    _require(version == API_VERSION,
             f"unsupported version {version!r}; this build speaks "
             f"{API_VERSION!r}", "apiVersion")
    kind = manifest.get("kind")
    if kind not in KINDS:
        raise ManifestError(
            f"unknown kind {kind!r}; known kinds: {sorted(KINDS)}",
            field="kind")
    meta = manifest.get("metadata") or {}
    _require(isinstance(meta, Mapping), "must be an object", "metadata")
    name = meta.get("name")
    _require(isinstance(name, str) and bool(name),
             "required field missing (a non-empty string)",
             "metadata.name")
    spec = manifest.get("spec") or {}
    _require(isinstance(spec, Mapping), "must be an object", "spec")
    return KINDS[kind]._from_spec(name, spec)


def from_json(text: str) -> WorkloadSpec:
    try:
        manifest = json.loads(text)
    except json.JSONDecodeError as e:
        raise ManifestError(f"manifest is not valid JSON: {e}") from e
    return from_manifest(manifest)


def load_manifest(path: str) -> WorkloadSpec:
    """Read + parse a manifest file (JSON — the kubectl-YAML analogue)."""
    with open(path, "r", encoding="utf-8") as f:
        text = f.read()
    return from_json(text)
