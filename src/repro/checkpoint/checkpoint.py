"""Sharded, async, atomic checkpointing with auto-resume.

Fault-tolerance contract (DESIGN.md §5):
  * atomic: a checkpoint directory is COMMITted (manifest written last,
    via ObjectStore's tmp+rename) — a crash mid-save never corrupts resume;
  * sharded: each leaf is saved per-shard by the host(s) that own it (this
    container owns all shards; the addressing scheme is multi-host ready:
    ``<leaf>/shard<k>.npy`` keyed by shard index);
  * async: ``save_async`` snapshots to host memory synchronously (cheap)
    and writes in a background thread, overlapping I/O with the next steps;
  * resume: ``latest_step`` + ``restore`` rebuild the state tree onto ANY
    mesh/sharding (elastic rescale re-shards through here);
  * GC: keep the last N checkpoints.
"""
from __future__ import annotations

import json
import threading
from typing import Any, Dict, List, Optional

import jax
import numpy as np

from repro.data.objectstore import ObjectStore


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out.append((key, leaf))
    return out, treedef


class Checkpointer:
    """``keep`` semantics: ``keep=N`` (N>=1) retains the newest N checkpoints
    after every save; ``keep=0`` retains NOTHING (every checkpoint is deleted
    by the GC pass that follows its own save — useful when a run only wants
    the side effects of saving, e.g. mirroring to another store); ``keep=None``
    disables GC entirely.  The seed treated ``keep=0`` as "GC off", which is
    what ``keep=None`` now means."""

    def __init__(self, store: ObjectStore, prefix: str = "checkpoints",
                 keep: Optional[int] = 3):
        self.store = store
        self.prefix = prefix
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # ----------------------------------------------------------------- save
    def _step_dir(self, step: int) -> str:
        return f"{self.prefix}/step_{step:010d}"

    def save(self, step: int, tree: Any, extra: Optional[Dict] = None) -> None:
        """Synchronous sharded save + atomic manifest commit + GC."""
        leaves, _ = _flatten_with_paths(tree)
        base = self._step_dir(step)
        manifest = {"step": step, "extra": extra or {}, "leaves": []}
        for key, leaf in leaves:
            arr = np.asarray(jax.device_get(leaf))
            true_dtype = str(arr.dtype)
            # numpy can't serialize extension dtypes (bfloat16/float8):
            # store a same-width unsigned view; the manifest keeps truth
            if arr.dtype.kind not in "biufc":
                arr = arr.view({1: np.uint8, 2: np.uint16,
                                4: np.uint32}[arr.dtype.itemsize])
            shard_key = f"{base}/{key.replace('/', '.')}/shard0.npy"
            self.store.put_array(shard_key, arr)
            manifest["leaves"].append({
                "key": key, "shards": [shard_key],
                "shape": list(arr.shape), "dtype": true_dtype})
        # manifest written LAST == commit point
        self.store.put_json(f"{base}/MANIFEST.json", manifest)
        self._gc()

    def save_async(self, step: int, tree: Any,
                   extra: Optional[Dict] = None) -> None:
        """Snapshot to host now; write in the background."""
        self.wait()
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)

        def work():
            try:
                self.save(step, host_tree, extra)
            except BaseException as e:   # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _gc(self) -> None:
        if self.keep is None:
            return
        steps = self.all_steps()
        for s in steps[:-self.keep] if self.keep > 0 else steps:
            base = self._step_dir(s)
            # Delete MANIFEST.json FIRST — the mirror of save()'s write-last
            # commit rule.  A reader racing this GC either sees the manifest
            # (and therefore every shard it names, since none are deleted
            # yet) or sees no manifest and skips the step entirely.  The
            # seed deleted in store.list order, so a racing restore could
            # read a manifest whose shards were already gone.
            self.store.delete(f"{base}/MANIFEST.json")
            for key in self.store.list(base + "/"):
                self.store.delete(key)
        # Orphan sweep: a GC pass killed between the manifest delete and
        # the shard deletes leaves shards that all_steps() can never see
        # again.  Sweep manifest-less step dirs OLDER than the newest
        # committed step only — a crashed or in-flight save writes shards
        # before its manifest at a NEWER step and must stay untouched.
        if not steps:
            return
        newest = steps[-1]
        on_disk = set()
        plen = len(self.prefix) + 1
        for key in self.store.list(self.prefix + "/"):
            name = key[plen:].split("/", 1)[0]
            if name.startswith("step_"):
                try:
                    on_disk.add(int(name.split("_")[1]))
                except ValueError:
                    pass
        for s in on_disk - set(steps):
            if s < newest:
                for key in self.store.list(self._step_dir(s) + "/"):
                    self.store.delete(key)

    # -------------------------------------------------------------- restore
    def all_steps(self) -> List[int]:
        steps = set()
        for key in self.store.list(self.prefix):
            if key.endswith("MANIFEST.json"):
                name = key.split("/")[-2]
                steps.add(int(name.split("_")[1]))
        return sorted(steps)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, abstract_tree: Any,
                shardings: Optional[Any] = None) -> Any:
        """Rebuild `abstract_tree`-shaped state; device_put onto `shardings`
        (which may target a DIFFERENT mesh than the one that saved)."""
        base = self._step_dir(step)
        manifest = self.store.get_json(f"{base}/MANIFEST.json")
        by_key = {l["key"]: l for l in manifest["leaves"]}
        leaves, treedef = _flatten_with_paths(abstract_tree)
        shd_leaves = (jax.tree_util.tree_leaves(shardings)
                      if shardings is not None else [None] * len(leaves))
        out = []
        for (key, ab), shd in zip(leaves, shd_leaves):
            entry = by_key[key]
            arr = self.store.get_array(entry["shards"][0])
            true_dtype = jax.numpy.dtype(entry["dtype"])
            if arr.dtype != true_dtype and arr.dtype.kind == "u" and \
                    arr.dtype.itemsize == true_dtype.itemsize:
                arr = arr.view(true_dtype)      # extension-dtype roundtrip
            arr = arr.astype(ab.dtype) if ab.dtype != arr.dtype else arr
            if shd is not None:
                out.append(jax.device_put(arr, shd))
            else:
                out.append(jax.numpy.asarray(arr))
        return jax.tree_util.tree_unflatten(treedef, out)

    def restore_latest(self, abstract_tree: Any,
                       shardings: Optional[Any] = None, *,
                       retries: int = 4):
        """Restore the newest checkpoint, tolerating a concurrent writer.

        Manifest-first GC deletion guarantees a manifest always names live
        shards *at any instant*, but a reader whose restore spans a GC pass
        can still lose the step it picked — on FileNotFound it re-lists and
        retries on whatever is newest then (a newer save has always
        committed before GC collects an older step, so progress is
        guaranteed).

        An EMPTY listing can be transient too: ``list`` walks the store
        directory-by-directory, so a scan racing save+GC may visit the
        new step before its manifest commits and the old step after GC
        removed its manifest — seeing no checkpoint at all while one
        always exists.  ``None`` is therefore only returned after the
        full retry budget agrees the store is empty."""
        err: Optional[BaseException] = None
        for _ in range(retries + 1):
            step = self.latest_step()
            if step is None:
                continue                     # possibly a racing re-list
            try:
                manifest = self.store.get_json(
                    f"{self._step_dir(step)}/MANIFEST.json")
                return self.restore(step, abstract_tree, shardings), \
                    {"step": step, **manifest.get("extra", {})}
            except FileNotFoundError as e:   # lost a GC race; re-list
                err = e
        if err is not None:
            raise err
        return None, None
