"""SLO grading and chargeback — the scenario's report card.

The AI_INFN operations papers grade a federated platform per tenant:
did serving hold its latency SLOs while the infrastructure churned, how
much offered load became goodput, what did co-tenant training lose to
preemption, and what does each tenant owe for the bytes it moved and
the devices it leased.  ``grade_tenant`` computes exactly that from the
raw samples the run produced:

  * **attainment** — p99 TTFT / p99 request latency (nearest-rank, the
    same percentile rule as ``Series.stats``) against the tenant's
    ``SLO`` targets, plus a goodput floor (served / offered);
  * **goodput** — served request rate vs. offered load over the sim
    horizon; waves the platform failed count as *rejected*, never
    silently dropped (served + rejected == offered, asserted by the
    chaos regression);
  * **training collateral** — ``steps_lost`` / ``recoveries`` straight
    from the ``ElasticRunReport``;
  * **chargeback** — $-style cost from the platform's own meters:
    ``fabric/tenant/<t>/bytes_moved`` x ``Price.per_gb`` plus
    ``lease_device_s/tenant-<t>`` x ``Price.per_device_s``.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence


def percentile(values: Sequence[float], q: float) -> float:
    """Nearest-rank percentile, identical to ``Series.stats`` so a grade
    recomputed from raw samples matches the serving report."""
    vals = sorted(values)
    if not vals:
        return 0.0
    n = len(vals)
    return vals[min(n - 1, max(0, int(round(q / 100 * (n - 1)))))]


@dataclass(frozen=True)
class SLO:
    """One tenant's targets.  ``None`` disables a latency verdict (a
    training-only tenant has no TTFT); ``min_goodput`` is the fraction
    of offered requests that must be served (0 disables)."""
    p99_ttft_s: Optional[float] = None
    p99_latency_s: Optional[float] = None
    min_goodput: float = 0.0


@dataclass(frozen=True)
class Price:
    """The chargeback rate card (arbitrary currency units)."""
    per_gb: float = 0.09          # egress-style $/GB moved across sites
    per_device_s: float = 0.004   # accelerator lease $/device-second


@dataclass(frozen=True)
class ScenarioSpec:
    """What a scenario promises: how long it runs (sim seconds), how
    many serve waves the horizon splits into, and each tenant's SLO."""
    name: str
    horizon_s: float
    windows: int
    slos: Dict[str, SLO] = field(default_factory=dict)
    price: Price = Price()

    def __post_init__(self):
        if self.horizon_s <= 0 or self.windows < 1:
            raise ValueError("need horizon_s > 0 and windows >= 1")

    @property
    def window_s(self) -> float:
        return self.horizon_s / self.windows


@dataclass
class TenantGrade:
    """One tenant's verdicts for one scenario run."""
    tenant: str
    offered: int = 0
    served: int = 0
    rejected: int = 0
    goodput_rps: float = 0.0
    goodput_ratio: float = 1.0
    p99_ttft_s: float = 0.0
    p99_latency_s: float = 0.0
    verdicts: Dict[str, bool] = field(default_factory=dict)
    slo_pass: bool = True
    steps_lost: int = 0
    recoveries: int = 0
    makespan_s: float = 0.0
    chargeback: Dict[str, float] = field(default_factory=dict)

    def to_json(self) -> Dict[str, Any]:
        return {
            "tenant": self.tenant, "offered": self.offered,
            "served": self.served, "rejected": self.rejected,
            "goodput_rps": round(self.goodput_rps, 4),
            "goodput_ratio": round(self.goodput_ratio, 4),
            "p99_ttft_s": round(self.p99_ttft_s, 4),
            "p99_latency_s": round(self.p99_latency_s, 4),
            "verdicts": dict(self.verdicts), "slo_pass": self.slo_pass,
            "steps_lost": self.steps_lost, "recoveries": self.recoveries,
            "makespan_s": round(self.makespan_s, 3),
            "chargeback": {k: round(v, 6)
                           for k, v in self.chargeback.items()},
        }


def chargeback(price: Price, *, bytes_moved: float,
               device_s: float) -> Dict[str, float]:
    gb = bytes_moved / 1e9
    transfer_cost = gb * price.per_gb
    device_cost = device_s * price.per_device_s
    return {"gb_moved": gb, "transfer_cost": transfer_cost,
            "device_s": device_s, "device_cost": device_cost,
            "total": transfer_cost + device_cost}


def grade_tenant(tenant: str, slo: SLO, *, offered: int, served: int,
                 ttft_s: Sequence[float] = (),
                 latency_s: Sequence[float] = (),
                 horizon_s: float, price: Price = Price(),
                 bytes_moved: float = 0.0, device_s: float = 0.0,
                 steps_lost: int = 0, recoveries: int = 0,
                 makespan_s: float = 0.0) -> TenantGrade:
    """Grade one tenant.  ``offered``/``served`` count requests over the
    whole scenario; ``ttft_s``/``latency_s`` are the raw per-request
    samples (all waves concatenated)."""
    if served > offered:
        raise ValueError(f"served {served} > offered {offered}")
    g = TenantGrade(tenant=tenant, offered=offered, served=served,
                    rejected=offered - served,
                    steps_lost=steps_lost, recoveries=recoveries,
                    makespan_s=makespan_s)
    g.goodput_rps = served / horizon_s if horizon_s > 0 else 0.0
    g.goodput_ratio = served / offered if offered else 1.0
    g.p99_ttft_s = percentile(ttft_s, 99)
    g.p99_latency_s = percentile(latency_s, 99)
    if slo.p99_ttft_s is not None:
        g.verdicts["p99_ttft"] = g.p99_ttft_s <= slo.p99_ttft_s
    if slo.p99_latency_s is not None:
        g.verdicts["p99_latency"] = g.p99_latency_s <= slo.p99_latency_s
    if slo.min_goodput > 0:
        g.verdicts["goodput"] = g.goodput_ratio >= slo.min_goodput
    g.slo_pass = all(g.verdicts.values()) if g.verdicts else True
    g.chargeback = chargeback(price, bytes_moved=bytes_moved,
                              device_s=device_s)
    return g


def grade_table(grades: List[TenantGrade]) -> str:
    """The report card as markdown — one row per tenant."""
    head = ("| tenant | offered | served | goodput | p99 TTFT | p99 lat "
            "| SLO | steps lost | bill |")
    sep = "|---" * 9 + "|"
    rows = []
    for g in sorted(grades, key=lambda g: g.tenant):
        rows.append(
            f"| {g.tenant} | {g.offered} | {g.served} "
            f"| {g.goodput_ratio:.0%} | {g.p99_ttft_s * 1e3:.1f}ms "
            f"| {g.p99_latency_s * 1e3:.1f}ms "
            f"| {'PASS' if g.slo_pass else 'FAIL'} | {g.steps_lost} "
            f"| ${g.chargeback.get('total', 0.0):.4f} |")
    return "\n".join([head, sep] + rows)
