"""The scenario driver — replay traffic, inject chaos, grade the run.

One ``run_scenario`` call is the paper's whole measurement loop: a
``ScenarioSpec`` splits the sim horizon into windows; each window's
slice of every tenant's ``TrafficShape`` trace is dispatched as one
ServeJob wave — a *manifest dict* applied through the tenant's PR-5
``Session``, so the scenario exercises the same declarative surface a
user would.  Training plans run across the whole horizon, burst plans
fire BatchJobs at their scheduled sim-times, and the ``ChaosInjector``
fires *after* a window's waves launch but before the driver waits on
them — so failures land mid-wave and the stack must actually survive
them (site-stranded waves requeue onto survivors, degraded links shift
placement), not merely between them.

Sim-time here is window-granular: window ``w`` spans sim
``[w, w+1) * spec.window_s`` regardless of how long the wave takes on
the wall clock.  That keeps the replay deterministic — the same spec,
shapes and schedule grade the same traffic against the same failures on
any machine speed.
"""
from __future__ import annotations

import copy
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence

from repro.api.resources import from_manifest
from repro.api.session import Session
from repro.scenarios.chaos import ChaosInjector, ChaosSchedule
from repro.scenarios.grade import (SLO, ScenarioSpec, TenantGrade,
                                   grade_tenant)
from repro.scenarios.traffic import TrafficShape, slice_window
from repro.serving.report import GAUGES


@dataclass
class ServePlan:
    """One serving tenant's scenario role: a traffic shape plus the base
    ServeJob manifest dict its waves are stamped from (the driver fills
    ``metadata.name`` and ``spec.requests`` per window)."""
    shape: TrafficShape
    manifest: Dict[str, Any]


@dataclass
class TrainPlan:
    """One training tenant's scenario role: a TrainJob manifest applied
    once, riding through the whole horizon (and all of its chaos)."""
    manifest: Dict[str, Any]


@dataclass
class BurstPlan:
    """Scheduled batch surges: the BatchJob manifest is applied (with
    the runtime ``fn``) at each sim-time in ``times`` — the
    high-priority interlopers that force fair-share preemption."""
    times: Sequence[float]
    manifest: Dict[str, Any]
    fn: Callable


@dataclass
class ScenarioResult:
    spec: ScenarioSpec
    grades: Dict[str, TenantGrade]
    chaos_fired: List[Dict[str, Any]]
    makespans: Dict[str, float]
    fairshare_skew: float
    wall_s: float
    waves: List[Dict[str, Any]] = field(default_factory=list)
    train_results: Dict[str, Any] = field(default_factory=dict)
    burst_states: List[str] = field(default_factory=list)

    def report(self) -> Dict[str, Any]:
        """The JSON-able run summary (what BENCH_scenarios.json rows and
        the SCENARIO_REPORT stdout line carry)."""
        return {
            "scenario": self.spec.name,
            "horizon_s": self.spec.horizon_s,
            "windows": self.spec.windows,
            "wall_s": round(self.wall_s, 3),
            "fairshare_skew": round(self.fairshare_skew, 4),
            "chaos": [{k: v for k, v in rec.items() if v is not None}
                      for rec in self.chaos_fired],
            "tenants": {t: g.to_json() for t, g in self.grades.items()},
        }


def _wave_manifest(plan: ServePlan, window: int,
                   requests: List[Dict]) -> Dict[str, Any]:
    m = copy.deepcopy(plan.manifest)
    m.setdefault("metadata", {})
    m["metadata"]["name"] = (f"{m['metadata'].get('name', plan.shape.name)}"
                             f"-w{window}")
    m.setdefault("spec", {})["requests"] = requests
    return m


def run_scenario(sched, spec: ScenarioSpec, *,
                 serve: Dict[str, ServePlan],
                 train: Optional[Dict[str, TrainPlan]] = None,
                 bursts: Optional[Dict[str, BurstPlan]] = None,
                 chaos: Optional[ChaosSchedule] = None,
                 wave_timeout_s: float = 600.0,
                 train_timeout_s: float = 600.0) -> ScenarioResult:
    """Drive one scenario against a running ``FairShareScheduler``
    (its reconcile loop must be live: ``sched.start()`` / ``with
    sched:``).  Keys of ``serve``/``train``/``bursts`` are tenant names
    already created on the scheduler."""
    train = train or {}
    bursts = bursts or {}
    tenants = sorted(set(serve) | set(train) | set(bursts))
    for t in tenants:
        if t not in sched.tenants:
            raise KeyError(f"scenario tenant {t!r} not on the scheduler")
    sessions = {t: Session(tenant=sched.tenants[t]) for t in tenants}
    injector = ChaosInjector(sched.fabric, chaos, bus=sched.bus) \
        if chaos is not None else None

    # pre-render every serve tenant's full trace once (deterministic)
    traces: Dict[str, List[Dict]] = {}
    for t, plan in serve.items():
        job = from_manifest(plan.manifest)     # validates the base manifest
        from repro.api.runners import resolve_serve_cfg
        traces[t] = plan.shape.requests(
            spec.horizon_s, vocab_size=resolve_serve_cfg(job).vocab_size)

    t_start = time.monotonic()
    train_handles = {t: sessions[t].apply(plan.manifest)
                     for t, plan in train.items()}
    burst_handles: List[Any] = []

    offered = {t: 0 for t in tenants}
    served = {t: 0 for t in tenants}
    ttft: Dict[str, List[float]] = {t: [] for t in tenants}
    latency: Dict[str, List[float]] = {t: [] for t in tenants}
    serve_busy = {t: 0.0 for t in tenants}
    waves_log: List[Dict[str, Any]] = []

    for w in range(spec.windows):
        t0, t1 = w * spec.window_s, (w + 1) * spec.window_s
        if injector is not None:
            injector.fire_due(t0)
        # launch this window's waves and due bursts...
        wave_handles: Dict[str, Any] = {}
        wave_sizes: Dict[str, int] = {}
        wave_t0: Dict[str, float] = {}
        for t, plan in serve.items():
            reqs = slice_window(traces[t], t0, t1)
            if not reqs:
                continue
            offered[t] += len(reqs)
            wave_sizes[t] = len(reqs)
            wave_t0[t] = time.time()
            wave_handles[t] = sessions[t].apply(
                _wave_manifest(plan, w, reqs))
        for t, plan in bursts.items():
            for i, bt in enumerate(plan.times):
                if t0 <= bt < t1:
                    m = copy.deepcopy(plan.manifest)
                    m.setdefault("metadata", {})
                    m["metadata"]["name"] = \
                        f"{m['metadata'].get('name', 'burst')}-{i}"
                    burst_handles.append(
                        sessions[t].apply(m, fn=plan.fn))
        # ...then the window's chaos, so failures land MID-wave
        if injector is not None:
            injector.fire_due(t1)
        for t, h in wave_handles.items():
            ok, n_ok = True, 0
            try:
                out = h.wait(wave_timeout_s)
            except TimeoutError:
                h.cancel(wait=True, timeout=30.0)
                out, ok = h.result(), False
            except RuntimeError:
                out, ok = None, False      # wave FAILED => all rejected
            if isinstance(out, dict):
                n_ok = len(out.get("results") or {})
                m = out.get("metrics")
                if m is not None:
                    ttft[t] += [v for _, v in
                                m.series(GAUGES.TTFT_S).snapshot()]
                    latency[t] += [v for _, v in
                                   m.series(GAUGES.LATENCY_S).snapshot()]
            served[t] += min(n_ok, wave_sizes[t])
            # each wave's span runs from ITS OWN apply to ITS terminal
            # transition (the handle's last lifecycle event) — waves of
            # one window run concurrently, so timing them from this wait
            # loop would bill the first-waited tenant for every
            # co-tenant's wall time
            end_ts = (h.events() or [{}])[-1].get("ts", time.time())
            serve_busy[t] += max(0.0, end_ts - wave_t0[t])
            waves_log.append({"window": w, "tenant": t,
                              "offered": wave_sizes[t], "served": n_ok,
                              "ok": ok})

    if injector is not None:       # trailing restores past the last window
        injector.fire_due(spec.horizon_s + 1e9)
    burst_states = []
    for h in burst_handles:
        try:
            h.wait(wave_timeout_s)
        except (TimeoutError, RuntimeError):
            pass
        burst_states.append(h.state.value)
    train_reports: Dict[str, Any] = {}
    train_results: Dict[str, Any] = {}
    for t, h in train_handles.items():
        out = h.wait(train_timeout_s)
        train_results[t] = out
        train_reports[t] = out.get("report") if isinstance(out, dict) \
            else None
    wall_s = time.monotonic() - t_start

    makespans: Dict[str, float] = {}
    grades: Dict[str, TenantGrade] = {}
    for t in tenants:
        rep = train_reports.get(t)
        makespans[t] = getattr(rep, "total_wall_s", 0.0) or serve_busy[t]
        grades[t] = grade_tenant(
            t, spec.slos.get(t, SLO()),
            offered=offered[t], served=served[t],
            ttft_s=ttft[t], latency_s=latency[t],
            horizon_s=spec.horizon_s, price=spec.price,
            bytes_moved=sched.metrics.series(
                f"fabric/tenant/{t}/bytes_moved").total,
            device_s=sched.metrics.series(
                f"lease_device_s/tenant-{t}").total,
            steps_lost=getattr(rep, "steps_lost", 0),
            recoveries=getattr(rep, "recoveries", 0),
            makespan_s=makespans[t])

    busy = [serve_busy[t] for t in serve if offered[t] > 0]
    skew = (max(busy) / max(min(busy), 1e-9)) if len(busy) > 1 else 1.0
    return ScenarioResult(
        spec=spec, grades=grades,
        chaos_fired=injector.fired if injector is not None else [],
        makespans=makespans, fairshare_skew=skew, wall_s=wall_s,
        waves=waves_log, train_results=train_results,
        burst_states=burst_states)
