"""Seeded production-shaped traffic — diurnal tides, bursts, heavy tails.

The paper's premise is that a platform is only trusted after it has been
driven with production-shaped load (PPoDS: measure step by step under a
dynamic network).  Internet-facing serving does not offer uniform load:
request *rates* ride a diurnal sinusoid (a multi-site deployment sees
each region's day shifted in phase), flash crowds arrive as Poisson
bursts on top of the tide, and request *sizes* are heavy-tailed — most
prompts are short, a few are enormous (Zipf), generation lengths spread
lognormally.

Everything here is deterministic from an integer seed: the same
``TrafficShape`` replays the same arrival trace, the property the replay
harness (and the hypothesis tests) depends on.  Child RNG streams are
derived from the seed with fixed offsets so arrivals, bursts and length
draws stay independent but reproducible.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, List, Optional

import numpy as np

# fixed child-stream offsets: one RandomState per concern, all derived
# from TrafficShape.seed, so adding draws to one stream never shifts
# another (arrival determinism survives feature growth)
_ARRIVALS, _BURSTS, _PROMPTS, _GENS = 101, 211, 307, 401


@dataclass(frozen=True)
class DiurnalRate:
    """A sinusoidal request rate between ``base_rps`` (trough) and
    ``peak_rps`` (crest) with period ``period_s``.  ``phase_s`` shifts
    the crest — two tenants with opposite phases model regions whose
    days alternate on the shared fabric."""
    base_rps: float
    peak_rps: float
    period_s: float = 86400.0
    phase_s: float = 0.0

    def __post_init__(self):
        if self.base_rps < 0 or self.peak_rps < self.base_rps:
            raise ValueError("need 0 <= base_rps <= peak_rps")
        if self.period_s <= 0:
            raise ValueError("period_s must be positive")

    def rate(self, t: float) -> float:
        mid = 0.5 * (self.base_rps + self.peak_rps)
        amp = 0.5 * (self.peak_rps - self.base_rps)
        return mid + amp * math.cos(
            2 * math.pi * (t - self.phase_s) / self.period_s)

    @property
    def mean_rps(self) -> float:
        # the sinusoid's average over any whole period
        return 0.5 * (self.base_rps + self.peak_rps)


@dataclass(frozen=True)
class BurstOverlay:
    """Flash crowds: burst onsets arrive as a Poisson process at
    ``rate_per_s``; each burst adds ``extra_rps`` for ``duration_s``."""
    rate_per_s: float
    extra_rps: float
    duration_s: float

    def __post_init__(self):
        if min(self.rate_per_s, self.extra_rps, self.duration_s) < 0:
            raise ValueError("burst parameters must be non-negative")


@dataclass(frozen=True)
class TrafficShape:
    """One tenant's replayable traffic: rate process + size process.

    Prompt lengths are Zipf(``zipf_a``) clamped to [1, max_prompt_len];
    generation lengths are lognormal(``gen_mu``, ``gen_sigma``) clamped
    to [1, max_new_tokens].
    """
    name: str
    rate: DiurnalRate
    bursts: Optional[BurstOverlay] = None
    zipf_a: float = 1.8
    max_prompt_len: int = 32
    gen_mu: float = 1.6          # exp(1.6) ~ 5 tokens median
    gen_sigma: float = 0.6
    max_new_tokens: int = 16
    seed: int = 0

    def __post_init__(self):
        if self.zipf_a <= 1.0:
            raise ValueError("zipf_a must be > 1")
        if self.max_prompt_len < 1 or self.max_new_tokens < 1:
            raise ValueError("length caps must be >= 1")

    def _rng(self, stream: int) -> np.random.RandomState:
        return np.random.RandomState((self.seed * 1_000_003 + stream)
                                     % (2 ** 31 - 1))

    # ------------------------------------------------------------- rates
    def burst_times(self, horizon_s: float) -> List[float]:
        """Deterministic burst onsets in [0, horizon_s)."""
        if self.bursts is None or self.bursts.rate_per_s <= 0:
            return []
        rng = self._rng(_BURSTS)
        out, t = [], 0.0
        while True:
            t += rng.exponential(1.0 / self.bursts.rate_per_s)
            if t >= horizon_s:
                return out
            out.append(t)

    def rate_at(self, t: float, burst_times: Optional[List[float]] = None
                ) -> float:
        """Instantaneous rps: the diurnal tide plus any active bursts."""
        r = self.rate.rate(t)
        if self.bursts is not None:
            if burst_times is None:
                burst_times = self.burst_times(t + 1.0)
            r += self.bursts.extra_rps * sum(
                1 for b in burst_times if b <= t < b + self.bursts.duration_s)
        return r

    def max_rps(self) -> float:
        return self.rate.peak_rps + (
            self.bursts.extra_rps if self.bursts else 0.0)

    def mean_rps(self) -> float:
        """Expected rps over a whole period: diurnal mean + expected
        burst contribution (rate x duration x extra)."""
        extra = 0.0
        if self.bursts is not None:
            extra = (self.bursts.rate_per_s * self.bursts.duration_s *
                     self.bursts.extra_rps)
        return self.rate.mean_rps + extra

    def arrivals(self, horizon_s: float) -> List[float]:
        """Arrival times in [0, horizon_s): a non-homogeneous Poisson
        process sampled by thinning against ``max_rps``.  Same seed,
        same horizon => identical trace."""
        lam = self.max_rps()
        if lam <= 0 or horizon_s <= 0:
            return []
        rng = self._rng(_ARRIVALS)
        bursts = self.burst_times(horizon_s)
        out, t = [], 0.0
        while True:
            t += rng.exponential(1.0 / lam)
            if t >= horizon_s:
                return out
            if rng.uniform() * lam <= self.rate_at(t, bursts):
                out.append(t)

    # ------------------------------------------------------------ lengths
    def prompt_lengths(self, n: int) -> np.ndarray:
        """Heavy-tailed (Zipf) prompt lengths, always in
        [1, max_prompt_len]."""
        if n <= 0:
            return np.zeros(0, dtype=np.int64)
        draws = self._rng(_PROMPTS).zipf(self.zipf_a, size=n)
        return np.minimum(draws, self.max_prompt_len).astype(np.int64)

    def gen_lengths(self, n: int) -> np.ndarray:
        """Lognormal generation lengths, always in [1, max_new_tokens]."""
        if n <= 0:
            return np.zeros(0, dtype=np.int64)
        draws = self._rng(_GENS).lognormal(self.gen_mu, self.gen_sigma,
                                           size=n)
        return np.clip(draws.astype(np.int64), 1,
                       self.max_new_tokens)

    # ----------------------------------------------------------- requests
    def requests(self, horizon_s: float, *, vocab_size: int) -> List[Dict]:
        """The full replayable request trace: one ServeJob-shaped request
        dict per arrival, tagged with its sim-time ``t`` so the driver
        can slice the trace into windows."""
        times = self.arrivals(horizon_s)
        n = len(times)
        plens = self.prompt_lengths(n)
        gens = self.gen_lengths(n)
        rng = self._rng(_PROMPTS + 7)
        out = []
        for i, t in enumerate(times):
            prompt = rng.randint(0, vocab_size,
                                 size=int(plens[i])).tolist()
            out.append({"id": f"{self.name}-{i}", "t": float(t),
                        "prompt": prompt,
                        "max_new_tokens": int(gens[i])})
        return out


def slice_window(requests: List[Dict], t0: float, t1: float) -> List[Dict]:
    """The requests of a trace that arrive in sim-window [t0, t1)."""
    return [r for r in requests if t0 <= r["t"] < t1]
