"""Production-chaos scenario harness — replay, inject, grade.

The closing argument for the stack (ROADMAP item 5): drive
production-shaped traffic (``traffic``) through the multi-site,
multi-tenant fabric while a scheduled failure menu (``chaos``) churns
the infrastructure underneath, then grade every tenant's SLO
attainment, goodput and chargeback (``grade``).  ``driver`` ties the
three together through the declarative ``Session`` API.
"""
from repro.scenarios.chaos import ChaosEvent, ChaosInjector, ChaosSchedule
from repro.scenarios.driver import (BurstPlan, ScenarioResult, ServePlan,
                                    TrainPlan, run_scenario)
from repro.scenarios.grade import (SLO, Price, ScenarioSpec, TenantGrade,
                                   chargeback, grade_table, grade_tenant,
                                   percentile)
from repro.scenarios.traffic import (BurstOverlay, DiurnalRate,
                                     TrafficShape, slice_window)

__all__ = [
    "BurstOverlay", "BurstPlan", "ChaosEvent", "ChaosInjector",
    "ChaosSchedule", "DiurnalRate", "Price", "SLO", "ScenarioResult",
    "ScenarioSpec", "ServePlan", "TenantGrade", "TrafficShape",
    "TrainPlan", "chargeback", "grade_table", "grade_tenant",
    "percentile", "run_scenario", "slice_window",
]
