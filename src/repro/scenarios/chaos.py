"""The failure menu — scheduled chaos driven in sim-time.

The paper's measurements are taken *under a dynamic network*: nodes
drop, whole sites disappear, links brown out while the workflows run.
This module turns that into a declarative, validated schedule:

  * ``node-fail`` / ``node-join``   — single-node churn at a site
    (``Cluster.fail_node`` / ``join_node``);
  * ``site-kill`` / ``site-restore`` — whole-site loss
    (``Fabric.fail_site`` / ``restore_site``);
  * ``link-degrade`` / ``link-restore`` — bandwidth brown-out on one
    inter-site link (``Fabric.degrade_link`` / ``restore_link``).

A ``ChaosSchedule`` validates at construction that no two failures
overlap on the same site (or the same link) unless ``allow_overlap`` is
set — an un-survivable double-failure is almost always a schedule typo,
and the validation is itself a graded property (tests/test_scenarios).
``ChaosInjector.fire_due(sim_now)`` applies everything due exactly once,
so the driver can call it from any window boundary without bookkeeping.
"""
from __future__ import annotations

import threading
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Tuple

KINDS = ("node-fail", "node-join", "site-kill", "site-restore",
         "link-degrade", "link-restore")
# which kinds OPEN a failure window, and which kind CLOSES each
_OPENS = {"node-fail": "node-join", "site-kill": "site-restore",
          "link-degrade": "link-restore"}


@dataclass(frozen=True)
class ChaosEvent:
    """One scheduled infrastructure failure (or recovery) at sim-time
    ``at_s``.  ``site`` targets node/site kinds; ``link`` (a, b) plus
    ``gbps`` target link kinds."""
    at_s: float
    kind: str
    site: Optional[str] = None
    link: Optional[Tuple[str, str]] = None
    gbps: Optional[float] = None

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown chaos kind {self.kind!r}; "
                             f"one of {KINDS}")
        if self.at_s < 0:
            raise ValueError("at_s must be >= 0")
        if self.kind.startswith(("node-", "site-")) and not self.site:
            raise ValueError(f"{self.kind} needs site=")
        if self.kind.startswith("link-") and not self.link:
            raise ValueError(f"{self.kind} needs link=(a, b)")
        if self.kind == "link-degrade" and (self.gbps is None or
                                            self.gbps <= 0):
            raise ValueError("link-degrade needs gbps= > 0")

    @property
    def target(self) -> Tuple[str, ...]:
        """The resource a failure window is tracked against."""
        if self.link is not None:
            return ("link",) + tuple(sorted(self.link))
        return ("site", self.site)


@dataclass(frozen=True)
class ChaosSchedule:
    """A validated, time-ordered failure schedule."""
    events: Tuple[ChaosEvent, ...]
    allow_overlap: bool = False

    def __init__(self, events, *, allow_overlap: bool = False):
        object.__setattr__(self, "events",
                           tuple(sorted(events, key=lambda e: e.at_s)))
        object.__setattr__(self, "allow_overlap", allow_overlap)
        self.validate()

    def validate(self) -> None:
        """Reject two overlapping failure windows on one target.  A
        window opens at a failure kind and closes at its paired recovery
        on the same target; a second failure inside an open window is an
        overlap (site-kill while a node-fail is outstanding, double
        brown-out of one link, ...)."""
        if self.allow_overlap:
            return
        open_kind: Dict[Tuple[str, ...], str] = {}
        for ev in self.events:
            tgt = ev.target
            if ev.kind in _OPENS:
                if tgt in open_kind:
                    raise ValueError(
                        f"overlapping failures on {tgt}: {ev.kind} at "
                        f"t={ev.at_s:g} while {open_kind[tgt]} is "
                        f"outstanding (pass allow_overlap=True to permit)")
                open_kind[tgt] = ev.kind
            else:
                opener = {v: k for k, v in _OPENS.items()}[ev.kind]
                if open_kind.get(tgt) == opener:
                    del open_kind[tgt]

    def due(self, sim_now: float) -> List[ChaosEvent]:
        return [e for e in self.events if e.at_s <= sim_now]


class ChaosInjector:
    """Applies a schedule against a live ``Fabric``, exactly once per
    event, in event order, from whatever thread asks."""

    def __init__(self, fabric, schedule: ChaosSchedule, *, bus=None):
        self.fabric = fabric
        self.schedule = schedule
        self.bus = bus
        self.fired: List[Dict[str, Any]] = []
        self._done: set = set()
        self._failed_nodes: Dict[str, List[Any]] = {}
        self._lock = threading.Lock()

    def fire_due(self, sim_now: float) -> List[Dict[str, Any]]:
        """Apply every not-yet-fired event with ``at_s <= sim_now``.
        Returns the records appended to ``fired`` (each carries the
        event plus ``applied`` and any skip ``reason``)."""
        out = []
        with self._lock:
            for i, ev in enumerate(self.schedule.events):
                if i in self._done or ev.at_s > sim_now:
                    continue
                self._done.add(i)
                rec = self._apply(ev)
                self.fired.append(rec)
                out.append(rec)
                if self.bus is not None:
                    self.bus.publish("chaos", source=ev.site or
                                     "->".join(ev.link), event=ev.kind,
                                     at_s=ev.at_s, applied=rec["applied"])
        return out

    def _apply(self, ev: ChaosEvent) -> Dict[str, Any]:
        rec: Dict[str, Any] = {"at_s": ev.at_s, "kind": ev.kind,
                               "site": ev.site, "link": ev.link,
                               "applied": True}
        try:
            if ev.kind == "node-fail":
                cluster = self.fabric.sites[ev.site].cluster
                online = cluster.online_devices
                if not online:
                    rec.update(applied=False, reason="no online devices")
                    return rec
                dev = online[-1]
                cluster.fail_node(dev)
                self._failed_nodes.setdefault(ev.site, []).append(dev)
            elif ev.kind == "node-join":
                stack = self._failed_nodes.get(ev.site) or []
                if not stack:
                    rec.update(applied=False, reason="no failed node")
                    return rec
                self.fabric.sites[ev.site].cluster.join_node(stack.pop())
            elif ev.kind == "site-kill":
                self.fabric.fail_site(ev.site)
            elif ev.kind == "site-restore":
                self.fabric.restore_site(ev.site)
            elif ev.kind == "link-degrade":
                self.fabric.degrade_link(ev.link[0], ev.link[1],
                                         gbps=ev.gbps)
                rec["gbps"] = ev.gbps
            elif ev.kind == "link-restore":
                applied = self.fabric.restore_link(ev.link[0], ev.link[1])
                if not applied:
                    rec.update(applied=False, reason="link not degraded")
        except (KeyError, ValueError) as e:
            rec.update(applied=False, reason=str(e))
        return rec
