"""Measurement layer — the Prometheus/Grafana analogue.

The paper's methodology is "constantly measuring, learning, and informing
every aspect of a machine learning workflow" (CHASE-CI §VI, Figs 3-6,
Table I).  This registry provides counters / gauges / histograms plus
timestamped series, and renders the paper's Table I (per-step resource
summary) from StepReports.
"""
from __future__ import annotations

import threading
import time
from contextlib import contextmanager
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple


@dataclass
class Series:
    points: List[Tuple[float, float]] = field(default_factory=list)

    def record(self, value: float, ts: Optional[float] = None):
        self.points.append((time.time() if ts is None else ts, float(value)))

    @property
    def last(self) -> float:
        return self.points[-1][1] if self.points else 0.0

    @property
    def total(self) -> float:
        return sum(v for _, v in self.points)

    @property
    def mean(self) -> float:
        return self.total / len(self.points) if self.points else 0.0

    @property
    def max(self) -> float:
        return max((v for _, v in self.points), default=0.0)

    def percentile(self, q: float) -> float:
        """Nearest-rank percentile of recorded values, q in [0, 100]."""
        if not self.points:
            return 0.0
        vals = sorted(v for _, v in self.points)
        rank = min(len(vals) - 1, max(0, int(round(q / 100 * (len(vals) - 1)))))
        return vals[rank]


class Registry:
    def __init__(self):
        self._lock = threading.Lock()
        self._series: Dict[str, Series] = {}

    def series(self, name: str) -> Series:
        with self._lock:
            return self._series.setdefault(name, Series())

    def inc(self, name: str, value: float = 1.0):
        self.series(name).record(value)

    def gauge(self, name: str, value: float):
        self.series(name).record(value)

    @contextmanager
    def timer(self, name: str):
        t0 = time.perf_counter()
        try:
            yield
        finally:
            self.series(name).record(time.perf_counter() - t0)

    def scrape(self) -> Dict[str, float]:
        with self._lock:
            return {k: s.last for k, s in self._series.items()}

    def summary(self) -> Dict[str, Dict[str, float]]:
        """Per-series stats (count/last/mean/max/total/p50/p99) — the
        scrape endpoint a serving dashboard (paper §VI) would poll."""
        with self._lock:
            return {k: {"count": len(s.points), "last": s.last,
                        "mean": s.mean, "max": s.max, "total": s.total,
                        "p50": s.percentile(50), "p99": s.percentile(99)}
                    for k, s in self._series.items()}

    def to_csv(self) -> str:
        lines = ["metric,count,last,mean,max,total"]
        with self._lock:
            for k in sorted(self._series):
                s = self._series[k]
                lines.append(f"{k},{len(s.points)},{s.last:.6g},{s.mean:.6g},"
                             f"{s.max:.6g},{s.total:.6g}")
        return "\n".join(lines)


def record_serving_totals(registry: "Registry", useful_tokens: int,
                          wall_s: float, decode_s: float) -> None:
    """End-of-run serving gauges, shared by every serving driver so the
    continuous-vs-static benchmark always compares identical accounting:
    wall time, useful tokens/s overall, and decode-only tokens/s (omitted
    when the run never decoded, e.g. stop-length-1 workloads)."""
    registry.gauge("serve/wall_s", wall_s)
    registry.gauge("serve/tok_s", useful_tokens / max(wall_s, 1e-9))
    if decode_s > 0:
        registry.gauge("serve/decode_tok_s", useful_tokens / decode_s)


@dataclass
class StepReport:
    """One column of the paper's Table I."""
    step: str
    pods: int = 0
    cpus: int = 0
    devices: int = 0          # "# of GPUs" in the paper
    data_processed_bytes: int = 0
    memory_bytes: int = 0
    total_time_s: float = 0.0
    site: str = ""            # federation site the step ran at (repro.fabric)
    extra: Dict[str, float] = field(default_factory=dict)


def table_one(reports: List[StepReport]) -> str:
    """Render the paper's Table I (Nautilus resource summary) as markdown."""
    def fmt_bytes(b):
        for unit in ("B", "KB", "MB", "GB", "TB"):
            if abs(b) < 1024:
                return f"{b:.1f}{unit}"
            b /= 1024
        return f"{b:.1f}PB"

    head = "| | " + " | ".join(r.step for r in reports) + " |"
    sep = "|---" * (len(reports) + 1) + "|"
    rows = [
        ("# of Pods", [str(r.pods) for r in reports]),
        ("# of CPUs", [str(r.cpus) for r in reports]),
        ("# of Devices", [str(r.devices) for r in reports]),
        ("Data Processed", [fmt_bytes(r.data_processed_bytes) for r in reports]),
        ("Memory", [fmt_bytes(r.memory_bytes) for r in reports]),
        ("Total Time", [f"{r.total_time_s:.1f}s" for r in reports]),
    ]
    out = [head, sep]
    # multi-site runs (repro.fabric) say where each step landed
    if any(r.site for r in reports):
        out.append("| Site | " + " | ".join(r.site or "-" for r in reports)
                   + " |")
    for name, vals in rows:
        out.append("| " + name + " | " + " | ".join(vals) + " |")
    # free-form per-step metrics (e.g. serving tokens/s, slot occupancy)
    # render as additional rows; steps missing a key show "-"
    extra_keys = sorted({k for r in reports for k in r.extra})
    for key in extra_keys:
        vals = [f"{r.extra[key]:.4g}" if key in r.extra else "-"
                for r in reports]
        out.append("| " + key + " | " + " | ".join(vals) + " |")
    return "\n".join(out)
