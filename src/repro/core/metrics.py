"""Measurement layer — the Prometheus/Grafana analogue.

The paper's methodology is "constantly measuring, learning, and informing
every aspect of a machine learning workflow" (CHASE-CI §VI, Figs 3-6,
Table I).  This registry provides counters / gauges / histograms plus
timestamped series, and renders the paper's Table I (per-step resource
summary) from StepReports.
"""
from __future__ import annotations

import threading
import time
from contextlib import contextmanager
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple


@dataclass
class Series:
    """One metric stream.  Pod/worker threads append concurrently while
    dashboards summarize, so every read derives from ONE locked snapshot —
    the registry's dict lock alone cannot make count/mean/total agree."""
    points: List[Tuple[float, float]] = field(default_factory=list)
    _lock: threading.Lock = field(default_factory=threading.Lock,
                                  repr=False, compare=False)

    def record(self, value: float, ts: Optional[float] = None):
        with self._lock:
            self.points.append((time.time() if ts is None else ts,
                                float(value)))

    def snapshot(self) -> List[Tuple[float, float]]:
        """A consistent copy of the points at one instant."""
        with self._lock:
            return list(self.points)

    @property
    def last(self) -> float:
        with self._lock:
            return self.points[-1][1] if self.points else 0.0

    @property
    def total(self) -> float:
        return sum(v for _, v in self.snapshot())

    @property
    def mean(self) -> float:
        pts = self.snapshot()
        return sum(v for _, v in pts) / len(pts) if pts else 0.0

    @property
    def max(self) -> float:
        return max((v for _, v in self.snapshot()), default=0.0)

    def percentile(self, q: float) -> float:
        """Nearest-rank percentile of recorded values, q in [0, 100]."""
        vals = sorted(v for _, v in self.snapshot())
        if not vals:
            return 0.0
        rank = min(len(vals) - 1, max(0, int(round(q / 100 * (len(vals) - 1)))))
        return vals[rank]

    def stats(self) -> Dict[str, float]:
        """count/last/mean/max/total/p50/p99 from a SINGLE snapshot, so
        the numbers are mutually consistent even under concurrent
        ``record`` calls (count * mean == total, always)."""
        pts = self.snapshot()
        vals = sorted(v for _, v in pts)
        n = len(vals)

        def pct(q):
            if not n:
                return 0.0
            return vals[min(n - 1, max(0, int(round(q / 100 * (n - 1)))))]

        total = sum(vals)
        return {"count": n, "last": pts[-1][1] if pts else 0.0,
                "mean": total / n if n else 0.0,
                "max": vals[-1] if n else 0.0, "total": total,
                "p50": pct(50), "p99": pct(99)}


class Registry:
    def __init__(self):
        self._lock = threading.Lock()
        self._series: Dict[str, Series] = {}
        # listeners get (name, value, ts) on every inc/gauge/timer — the
        # near-real-time monitor (repro.vcluster.monitor) taps this to
        # stream throughput gauges without polling
        self._listeners: List[Callable[[str, float, float], None]] = []

    def series(self, name: str) -> Series:
        with self._lock:
            return self._series.setdefault(name, Series())

    def add_listener(self, cb: Callable[[str, float, float], None]) -> None:
        """Register cb(name, value, ts) on every recorded point.
        Listener exceptions are swallowed: observability must never take
        down the thing it observes."""
        with self._lock:
            self._listeners.append(cb)

    def _notify(self, name: str, value: float) -> None:
        for cb in list(self._listeners):
            try:
                cb(name, value, time.time())
            except Exception:
                pass

    def inc(self, name: str, value: float = 1.0):
        self.series(name).record(value)
        self._notify(name, value)

    def gauge(self, name: str, value: float):
        self.series(name).record(value)
        self._notify(name, value)

    @contextmanager
    def timer(self, name: str):
        t0 = time.perf_counter()
        try:
            yield
        finally:
            dt = time.perf_counter() - t0
            self.series(name).record(dt)
            self._notify(name, dt)

    def scrape(self) -> Dict[str, float]:
        with self._lock:
            return {k: s.last for k, s in self._series.items()}

    def summary(self) -> Dict[str, Dict[str, float]]:
        """Per-series stats (count/last/mean/max/total/p50/p99) — the
        scrape endpoint a serving dashboard (paper §VI) would poll.
        Each series is summarized from one atomic snapshot, so its stats
        are mutually consistent under concurrent recording."""
        with self._lock:
            series = dict(self._series)
        return {k: s.stats() for k, s in series.items()}

    def to_csv(self) -> str:
        lines = ["metric,count,last,mean,max,total"]
        for k, st in sorted(self.summary().items()):
            lines.append(f"{k},{st['count']},{st['last']:.6g},"
                         f"{st['mean']:.6g},{st['max']:.6g},"
                         f"{st['total']:.6g}")
        return "\n".join(lines)


def record_serving_totals(registry: "Registry", useful_tokens: int,
                          wall_s: float, decode_s: float) -> None:
    """Deprecated alias — the implementation (and the single source of
    the ``serve/*`` gauge names) moved to ``repro.serving.report``."""
    from repro.serving.report import record_serving_totals as impl
    impl(registry, useful_tokens, wall_s, decode_s)


@dataclass
class StepReport:
    """One column of the paper's Table I."""
    step: str
    pods: int = 0
    cpus: int = 0
    devices: int = 0          # "# of GPUs" in the paper
    data_processed_bytes: int = 0
    memory_bytes: int = 0
    total_time_s: float = 0.0
    site: str = ""            # federation site the step ran at (repro.fabric)
    extra: Dict[str, float] = field(default_factory=dict)


def table_one(reports: List[StepReport]) -> str:
    """Render the paper's Table I (Nautilus resource summary) as markdown."""
    def fmt_bytes(b):
        for unit in ("B", "KB", "MB", "GB", "TB"):
            if abs(b) < 1024:
                return f"{b:.1f}{unit}"
            b /= 1024
        return f"{b:.1f}PB"

    head = "| | " + " | ".join(r.step for r in reports) + " |"
    sep = "|---" * (len(reports) + 1) + "|"
    rows = [
        ("# of Pods", [str(r.pods) for r in reports]),
        ("# of CPUs", [str(r.cpus) for r in reports]),
        ("# of Devices", [str(r.devices) for r in reports]),
        ("Data Processed", [fmt_bytes(r.data_processed_bytes) for r in reports]),
        ("Memory", [fmt_bytes(r.memory_bytes) for r in reports]),
        ("Total Time", [f"{r.total_time_s:.1f}s" for r in reports]),
    ]
    out = [head, sep]
    # multi-site runs (repro.fabric) say where each step landed
    if any(r.site for r in reports):
        out.append("| Site | " + " | ".join(r.site or "-" for r in reports)
                   + " |")
    for name, vals in rows:
        out.append("| " + name + " | " + " | ".join(vals) + " |")
    # free-form per-step metrics (e.g. serving tokens/s, slot occupancy)
    # render as additional rows; steps missing a key show "-"
    extra_keys = sorted({k for r in reports for k in r.extra})
    for key in extra_keys:
        vals = [f"{r.extra[key]:.4g}" if key in r.extra else "-"
                for r in reports]
        out.append("| " + key + " | " + " | ".join(vals) + " |")
    return "\n".join(out)
