"""Workflow engine — the paper's step-by-step, measured, resumable workflows.

CHASE-CI's CONNECT workflow (§III) is four steps (download -> train ->
distributed inference -> visualization), each a Kubernetes Job that is
independently testable, measured in Grafana, and restartable.  The PPoDS
methodology (§VI) demands: separable steps, per-step measurement, and
development of steps in isolation.

Here a ``Workflow`` is a DAG of ``Step``s executed on a ``Cluster``:
  * each step runs as an orchestrator Job (pods = threads, devices = mesh
    slices) and its wall-time / bytes / resource footprint is recorded as a
    StepReport — Table I of the paper falls out of ``wf.table_one()``;
  * steps persist a completion marker + output manifest to the ObjectStore
    (the Ceph analogue), so a crashed / restarted workflow resumes from the
    last completed step (fault tolerance at the workflow level, on top of
    the queue's at-least-once and the checkpointer's auto-resume);
  * ``only=`` runs a single step in isolation (PPoDS independent testing).

Federated mode (paper §IV, ``repro.fabric``): construct the workflow with
a ``planner`` instead of a fixed cluster/store, and annotate steps with
the dataset keys they consume/produce (``inputs=``/``outputs=``).  Every
step is then *placed*: the planner scores each live site by the simulated
cost of moving the step's missing input bytes plus its queue depth, picks
a site, pre-stages missing inputs over the bandwidth-modeled links, and
runs the step on that site's cluster against that site's store view.  The
step report gains ``site``, ``bytes_moved`` and ``transfer_s`` columns.
"""
from __future__ import annotations

import json
import threading
import time
import traceback
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

from repro.core.metrics import Registry, StepReport, table_one
from repro.core.orchestrator import Cluster, JobSpec
from repro.data.objectstore import ObjectStore


@dataclass
class StepCtx:
    """What a step's fn receives."""
    cluster: Cluster
    store: ObjectStore
    metrics: Registry
    namespace: str
    inputs: Dict[str, Any]          # outputs of dependency steps
    report: StepReport              # fill in data_processed / memory etc.


@dataclass
class Step:
    name: str
    fn: Callable[[StepCtx], Any]
    deps: Sequence[str] = ()
    pods: int = 1
    devices_per_pod: int = 0
    # dataset keys this step reads/writes in the (federated) store; the
    # placement planner scores sites by where `inputs` replicas live.  An
    # entry "prefix/*" globs every cataloged key under the prefix.
    inputs: Sequence[str] = ()
    outputs: Sequence[str] = ()

    def marker_key(self, wf: str) -> str:
        return f"workflows/{wf}/{self.name}/_COMPLETE"

    def output_key(self, wf: str) -> str:
        return f"workflows/{wf}/{self.name}/output.json"


class Workflow:
    def __init__(self, name: str, *, cluster: Optional[Cluster] = None,
                 store: Optional[ObjectStore] = None,
                 metrics: Optional[Registry] = None,
                 namespace: str = "default", planner=None, bus=None):
        """Single-cluster mode needs ``cluster`` + ``store``; federated
        mode needs a ``repro.fabric.PlacementPlanner`` and places each
        step on the fabric instead.  ``bus`` (a
        ``repro.vcluster.monitor.EventBus``) streams per-step lifecycle
        events — placed / done / skipped — to live subscribers."""
        self.name = name
        self.planner = planner
        self.bus = bus
        if planner is None and (cluster is None or store is None):
            raise TypeError("Workflow needs cluster+store, or a planner")
        self.cluster = cluster
        self.store = store
        self.metrics = metrics or (cluster.metrics if cluster is not None
                                   else planner.fabric.metrics)
        self.namespace = namespace
        if cluster is not None and namespace not in cluster.namespaces:
            cluster.create_namespace(namespace)
        self.steps: Dict[str, Step] = {}
        self.reports: List[StepReport] = []
        self.results: Dict[str, Any] = {}
        # the graph executor (repro.flow) runs steps from a worker pool:
        # shared mutable state appends under _lock, placement decisions
        # serialize under _place_lock (the planner's scoring + its
        # round-robin cursor are cheap; the staging transfers they
        # trigger still overlap)
        self._lock = threading.RLock()
        self._place_lock = threading.Lock()

    # control-plane reads/writes work in both modes: a plain ObjectStore,
    # or the federated catalog (whole-namespace view)
    def _ctrl(self):
        return self.store if self.store is not None else self.planner.fed

    # ------------------------------------------------------------------ DAG
    def add(self, step: Step) -> "Workflow":
        if step.name in self.steps:
            raise ValueError(f"duplicate step {step.name}")
        self.steps[step.name] = step
        return self

    def step(self, name: str, deps: Sequence[str] = (), pods: int = 1,
             devices_per_pod: int = 0, inputs: Sequence[str] = (),
             outputs: Sequence[str] = ()):
        """Decorator form: @wf.step("train", deps=["download"])"""
        def deco(fn):
            self.add(Step(name, fn, deps, pods, devices_per_pod,
                          inputs, outputs))
            return fn
        return deco

    def _topo_order(self) -> List[Step]:
        order, seen, visiting = [], set(), set()

        def visit(name: str):
            if name in seen:
                return
            if name in visiting:
                raise ValueError(f"cycle at {name}")
            visiting.add(name)
            for d in self.steps[name].deps:
                visit(d)
            visiting.discard(name)
            seen.add(name)
            order.append(self.steps[name])

        for name in self.steps:
            visit(name)
        return order

    # ------------------------------------------------------------------ run
    def run(self, *, resume: bool = True, only: Optional[str] = None,
            should_stop=None) -> Dict:
        """Run the DAG.  ``should_stop`` (a zero-arg callable, e.g. a
        ``repro.api`` Handle's cancel signal) is polled at every step
        boundary: when it goes true the workflow stops cleanly — steps
        already completed keep their markers, so a later ``run`` resumes
        from exactly here.  The cancel is reported as one workflow-level
        ``cancelled`` event plus a ``skipped(reason=cancelled)`` step
        event for EVERY step that will not run — downstream steps that
        were never reached included."""
        order = self._topo_order()
        for i, step in enumerate(order):
            if should_stop is not None and should_stop():
                remaining = [s.name for s in order[i:]
                             if only is None or s.name == only]
                self._emit_workflow("cancelled", remaining=len(remaining))
                for name in remaining:
                    self._emit(name, "skipped", reason="cancelled")
                break
            if only is not None and step.name != only:
                # still load completed deps' outputs for the isolated step
                if self._ctrl().exists(step.marker_key(self.name)):
                    self.results[step.name] = self._load_output(step)
                continue
            self._run_step(step, resume)
        return dict(self.results)

    def _load_output(self, step: Step):
        """A completed step's stored output manifest.  The marker alone
        does not prove the manifest survived (a partially-synced or
        hand-pruned store): missing/corrupt outputs fail HERE, naming
        the step, not as a KeyError inside a downstream consumer."""
        key = step.output_key(self.name)
        ctrl = self._ctrl()
        if not ctrl.exists(key):
            raise RuntimeError(
                f"workflow {self.name!r}: step {step.name!r} has a "
                f"completion marker but its output manifest {key!r} is "
                f"missing from the store; re-run with resume=False (or "
                f"wf.reset()) to re-execute it")
        try:
            return json.loads(ctrl.get(key))
        except (ValueError, OSError) as e:
            raise RuntimeError(
                f"workflow {self.name!r}: step {step.name!r} output "
                f"manifest {key!r} is unreadable ({e}); re-run with "
                f"resume=False to re-execute it") from e

    def _place(self, step: Step):
        """Federated mode: choose the step's site, pre-stage its missing
        inputs, and return (cluster, store_view, placement, staged
        (bytes, sim_s)).  Scoring serializes under ``_place_lock`` (the
        planner's round-robin cursor and queue-depth reads are not
        atomic); the staging transfers themselves overlap freely."""
        with self._place_lock:
            placement = self.planner.place(
                step.inputs,
                devices=step.devices_per_pod * max(1, step.pods))
        site = self.planner.fabric.sites[placement.site]
        with self._lock:
            if self.namespace not in site.cluster.namespaces:
                site.cluster.create_namespace(self.namespace)
        staged = self.planner.prestage(step.inputs, placement.site)
        # reserve the slot so CONCURRENT placements (repro.flow branches,
        # which run pods=1 fns inline and never show up in queue_depth)
        # see this site as loaded; _exec_step releases it when done
        self.planner.reserve(placement.site)
        return (site.cluster, self.planner.fed.view(placement.site),
                placement, staged)

    def _emit(self, step: str, status: str, *, kind: str = "step",
              **data) -> None:
        if self.bus is not None:
            self.bus.publish(kind, source=self.name, step=step,
                             status=status, **data)

    def _emit_workflow(self, status: str, **data) -> None:
        """A workflow-level lifecycle event (kind ``workflow``)."""
        if self.bus is not None:
            self.bus.publish("workflow", source=self.name, status=status,
                             **data)

    def _run_step(self, step: Step, resume: bool) -> None:
        for d in step.deps:
            if d not in self.results:
                raise RuntimeError(
                    f"workflow {self.name!r}: step {step.name!r} depends "
                    f"on {d!r}, which has not completed (running with "
                    f"only={step.name!r}? run the dependency first)")
        out, _ = self._exec_step(
            step, {d: self.results[d] for d in step.deps}, resume)
        self.results[step.name] = out

    def _exec_step(self, step: Step, inputs: Dict[str, Any],
                   resume: bool, *, emit_kind: str = "step",
                   concurrent: bool = False,
                   **emit_extra) -> Tuple[Any, bool]:
        """Execute ONE step against explicit ``inputs`` and return
        ``(output, skipped)``.  This is the unit both executors share:
        the serial ``run`` loop above, and the concurrent graph executor
        (``repro.flow``), which calls it from pool threads —
        ``concurrent=True`` attributes data movement from the step's own
        staging result instead of fabric-meter deltas (globals deltas
        would cross-count parallel steps' transfers)."""
        marker = step.marker_key(self.name)
        if resume and self._ctrl().exists(marker):
            out = self._load_output(step)
            self.metrics.inc(f"workflow/{self.name}/{step.name}/skipped")
            self._emit(step.name, "skipped", kind=emit_kind, **emit_extra)
            return out, True

        report = StepReport(step=step.name, pods=step.pods,
                            cpus=step.pods,
                            devices=step.pods * step.devices_per_pod)
        staged = (0, 0.0)
        if self.planner is not None:
            # snapshot the FABRIC meters (not self.metrics, which a caller
            # may have overridden) so pre-staging AND any on-demand
            # pull-through reads inside the step are attributed to it
            fmetrics = self.planner.fabric.metrics
            if not concurrent:
                moved0 = fmetrics.series("fabric/bytes_moved").total
                sim0 = fmetrics.series("fabric/transfer_s").total
            cluster, store, placement, staged = self._place(step)
            report.site = placement.site
            if placement.migrated:
                report.extra["migrated"] = 1.0
                fmetrics.inc("fabric/migrations")
        else:
            cluster, store, placement = self.cluster, self.store, None
        self._emit(step.name, "placed", kind=emit_kind,
                   site=placement.site if placement else "local",
                   mode=placement.mode if placement else "local",
                   **emit_extra)
        ctx = StepCtx(cluster=cluster, store=store,
                      metrics=self.metrics, namespace=self.namespace,
                      inputs=inputs, report=report)
        t0 = time.perf_counter()
        try:
            with self.metrics.timer(
                    f"workflow/{self.name}/{step.name}/time_s"):
                if step.pods <= 1:
                    out = step.fn(ctx)
                else:
                    # gang of pods; the step fn coordinates via a WorkQueue
                    job = cluster.submit(self.namespace, JobSpec(
                        name=f"{self.name}-{step.name}",
                        fn=lambda pc: step.fn(ctx),
                        replicas=1, devices_per_pod=step.devices_per_pod))
                    cluster.wait(job)
                    out = job.results()[0]
        finally:
            if placement is not None:
                self.planner.release(placement.site)
        report.total_time_s = time.perf_counter() - t0

        store.put(step.output_key(self.name),
                  json.dumps(out, default=str).encode())
        store.put(marker, b"ok")
        if self.planner is not None:
            # control-plane metadata (markers + output manifests, a few
            # bytes) is replicated to every live site, like Ceph metadata:
            # a later site loss must not un-complete finished steps.
            # Batched per site: one link latency, not one per key.
            ctrl_keys = [step.output_key(self.name), marker]
            for s in self.planner.fabric.up_sites():
                if s.name != placement.site:
                    self.planner.fed.replicate_many(ctrl_keys, s.name)
            for key in self.planner.expand(step.outputs):
                if not self.planner.fed.exists(key):   # declared, not written
                    self.metrics.inc(f"workflow/{self.name}/{step.name}"
                                     f"/missing_output")
            if concurrent:
                report.extra["bytes_moved"] = float(staged[0])
                report.extra["transfer_s"] = float(staged[1])
            else:
                report.extra["bytes_moved"] = \
                    fmetrics.series("fabric/bytes_moved").total - moved0
                report.extra["transfer_s"] = \
                    fmetrics.series("fabric/transfer_s").total - sim0
        with self._lock:
            self.reports.append(report)
        self._emit(step.name, "done", kind=emit_kind,
                   site=report.site or "local",
                   seconds=round(report.total_time_s, 4),
                   bytes_moved=int(report.extra.get("bytes_moved", 0)),
                   **emit_extra)
        return out, False

    # ------------------------------------------------------------- reporting
    def table_one(self) -> str:
        """The paper's Table I for this workflow."""
        return table_one(self.reports)

    def reset(self) -> None:
        for step in self.steps.values():
            for key in (step.marker_key(self.name), step.output_key(self.name)):
                self._ctrl().delete(key)
        self.results.clear()
        self.reports.clear()
