"""Workflow engine — the paper's step-by-step, measured, resumable workflows.

CHASE-CI's CONNECT workflow (§III) is four steps (download -> train ->
distributed inference -> visualization), each a Kubernetes Job that is
independently testable, measured in Grafana, and restartable.  The PPoDS
methodology (§VI) demands: separable steps, per-step measurement, and
development of steps in isolation.

Here a ``Workflow`` is a DAG of ``Step``s executed on a ``Cluster``:
  * each step runs as an orchestrator Job (pods = threads, devices = mesh
    slices) and its wall-time / bytes / resource footprint is recorded as a
    StepReport — Table I of the paper falls out of ``wf.table_one()``;
  * steps persist a completion marker + output manifest to the ObjectStore
    (the Ceph analogue), so a crashed / restarted workflow resumes from the
    last completed step (fault tolerance at the workflow level, on top of
    the queue's at-least-once and the checkpointer's auto-resume);
  * ``only=`` runs a single step in isolation (PPoDS independent testing).

Federated mode (paper §IV, ``repro.fabric``): construct the workflow with
a ``planner`` instead of a fixed cluster/store, and annotate steps with
the dataset keys they consume/produce (``inputs=``/``outputs=``).  Every
step is then *placed*: the planner scores each live site by the simulated
cost of moving the step's missing input bytes plus its queue depth, picks
a site, pre-stages missing inputs over the bandwidth-modeled links, and
runs the step on that site's cluster against that site's store view.  The
step report gains ``site``, ``bytes_moved`` and ``transfer_s`` columns.
"""
from __future__ import annotations

import json
import time
import traceback
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence

from repro.core.metrics import Registry, StepReport, table_one
from repro.core.orchestrator import Cluster, JobSpec
from repro.data.objectstore import ObjectStore


@dataclass
class StepCtx:
    """What a step's fn receives."""
    cluster: Cluster
    store: ObjectStore
    metrics: Registry
    namespace: str
    inputs: Dict[str, Any]          # outputs of dependency steps
    report: StepReport              # fill in data_processed / memory etc.


@dataclass
class Step:
    name: str
    fn: Callable[[StepCtx], Any]
    deps: Sequence[str] = ()
    pods: int = 1
    devices_per_pod: int = 0
    # dataset keys this step reads/writes in the (federated) store; the
    # placement planner scores sites by where `inputs` replicas live.  An
    # entry "prefix/*" globs every cataloged key under the prefix.
    inputs: Sequence[str] = ()
    outputs: Sequence[str] = ()

    def marker_key(self, wf: str) -> str:
        return f"workflows/{wf}/{self.name}/_COMPLETE"

    def output_key(self, wf: str) -> str:
        return f"workflows/{wf}/{self.name}/output.json"


class Workflow:
    def __init__(self, name: str, *, cluster: Optional[Cluster] = None,
                 store: Optional[ObjectStore] = None,
                 metrics: Optional[Registry] = None,
                 namespace: str = "default", planner=None, bus=None):
        """Single-cluster mode needs ``cluster`` + ``store``; federated
        mode needs a ``repro.fabric.PlacementPlanner`` and places each
        step on the fabric instead.  ``bus`` (a
        ``repro.vcluster.monitor.EventBus``) streams per-step lifecycle
        events — placed / done / skipped — to live subscribers."""
        self.name = name
        self.planner = planner
        self.bus = bus
        if planner is None and (cluster is None or store is None):
            raise TypeError("Workflow needs cluster+store, or a planner")
        self.cluster = cluster
        self.store = store
        self.metrics = metrics or (cluster.metrics if cluster is not None
                                   else planner.fabric.metrics)
        self.namespace = namespace
        if cluster is not None and namespace not in cluster.namespaces:
            cluster.create_namespace(namespace)
        self.steps: Dict[str, Step] = {}
        self.reports: List[StepReport] = []
        self.results: Dict[str, Any] = {}

    # control-plane reads/writes work in both modes: a plain ObjectStore,
    # or the federated catalog (whole-namespace view)
    def _ctrl(self):
        return self.store if self.store is not None else self.planner.fed

    # ------------------------------------------------------------------ DAG
    def add(self, step: Step) -> "Workflow":
        if step.name in self.steps:
            raise ValueError(f"duplicate step {step.name}")
        self.steps[step.name] = step
        return self

    def step(self, name: str, deps: Sequence[str] = (), pods: int = 1,
             devices_per_pod: int = 0, inputs: Sequence[str] = (),
             outputs: Sequence[str] = ()):
        """Decorator form: @wf.step("train", deps=["download"])"""
        def deco(fn):
            self.add(Step(name, fn, deps, pods, devices_per_pod,
                          inputs, outputs))
            return fn
        return deco

    def _topo_order(self) -> List[Step]:
        order, seen, visiting = [], set(), set()

        def visit(name: str):
            if name in seen:
                return
            if name in visiting:
                raise ValueError(f"cycle at {name}")
            visiting.add(name)
            for d in self.steps[name].deps:
                visit(d)
            visiting.discard(name)
            seen.add(name)
            order.append(self.steps[name])

        for name in self.steps:
            visit(name)
        return order

    # ------------------------------------------------------------------ run
    def run(self, *, resume: bool = True, only: Optional[str] = None,
            should_stop=None) -> Dict:
        """Run the DAG.  ``should_stop`` (a zero-arg callable, e.g. a
        ``repro.api`` Handle's cancel signal) is polled at every step
        boundary: when it goes true the workflow stops cleanly — steps
        already completed keep their markers, so a later ``run`` resumes
        from exactly here."""
        for step in self._topo_order():
            if should_stop is not None and should_stop():
                self._emit(step.name, "cancelled")
                break
            if only is not None and step.name != only:
                # still load completed deps' outputs for the isolated step
                if self._ctrl().exists(step.marker_key(self.name)):
                    self.results[step.name] = json.loads(
                        self._ctrl().get(step.output_key(self.name)))
                continue
            self._run_step(step, resume)
        return dict(self.results)

    def _place(self, step: Step):
        """Federated mode: choose the step's site, pre-stage its missing
        inputs, and return (cluster, store_view, placement)."""
        placement = self.planner.place(
            step.inputs, devices=step.devices_per_pod * max(1, step.pods))
        site = self.planner.fabric.sites[placement.site]
        if self.namespace not in site.cluster.namespaces:
            site.cluster.create_namespace(self.namespace)
        self.planner.prestage(step.inputs, placement.site)
        return site.cluster, self.planner.fed.view(placement.site), placement

    def _emit(self, step: str, status: str, **data) -> None:
        if self.bus is not None:
            self.bus.publish("step", source=self.name, step=step,
                             status=status, **data)

    def _run_step(self, step: Step, resume: bool) -> None:
        marker = step.marker_key(self.name)
        if resume and self._ctrl().exists(marker):
            self.results[step.name] = json.loads(
                self._ctrl().get(step.output_key(self.name)))
            self.metrics.inc(f"workflow/{self.name}/{step.name}/skipped")
            self._emit(step.name, "skipped")
            return

        report = StepReport(step=step.name, pods=step.pods,
                            cpus=step.pods,
                            devices=step.pods * step.devices_per_pod)
        if self.planner is not None:
            # snapshot the FABRIC meters (not self.metrics, which a caller
            # may have overridden) so pre-staging AND any on-demand
            # pull-through reads inside the step are attributed to it
            fmetrics = self.planner.fabric.metrics
            moved0 = fmetrics.series("fabric/bytes_moved").total
            sim0 = fmetrics.series("fabric/transfer_s").total
            cluster, store, placement = self._place(step)
            report.site = placement.site
            if placement.migrated:
                report.extra["migrated"] = 1.0
                fmetrics.inc("fabric/migrations")
        else:
            cluster, store, placement = self.cluster, self.store, None
        self._emit(step.name, "placed",
                   site=placement.site if placement else "local",
                   mode=placement.mode if placement else "local")
        ctx = StepCtx(cluster=cluster, store=store,
                      metrics=self.metrics, namespace=self.namespace,
                      inputs={d: self.results[d] for d in step.deps},
                      report=report)
        t0 = time.perf_counter()
        with self.metrics.timer(f"workflow/{self.name}/{step.name}/time_s"):
            if step.pods <= 1:
                out = step.fn(ctx)
            else:
                # gang of pods; the step fn coordinates via a WorkQueue
                job = cluster.submit(self.namespace, JobSpec(
                    name=f"{self.name}-{step.name}", fn=lambda pc: step.fn(ctx),
                    replicas=1, devices_per_pod=step.devices_per_pod))
                cluster.wait(job)
                out = job.results()[0]
        report.total_time_s = time.perf_counter() - t0
        self.results[step.name] = out

        store.put(step.output_key(self.name),
                  json.dumps(out, default=str).encode())
        store.put(marker, b"ok")
        if self.planner is not None:
            # control-plane metadata (markers + output manifests, a few
            # bytes) is replicated to every live site, like Ceph metadata:
            # a later site loss must not un-complete finished steps.
            # Batched per site: one link latency, not one per key.
            ctrl_keys = [step.output_key(self.name), marker]
            for s in self.planner.fabric.up_sites():
                if s.name != placement.site:
                    self.planner.fed.replicate_many(ctrl_keys, s.name)
            for key in self.planner.expand(step.outputs):
                if not self.planner.fed.exists(key):   # declared, not written
                    self.metrics.inc(f"workflow/{self.name}/{step.name}"
                                     f"/missing_output")
            report.extra["bytes_moved"] = \
                fmetrics.series("fabric/bytes_moved").total - moved0
            report.extra["transfer_s"] = \
                fmetrics.series("fabric/transfer_s").total - sim0
        self.reports.append(report)
        self._emit(step.name, "done", site=report.site or "local",
                   seconds=round(report.total_time_s, 4),
                   bytes_moved=int(report.extra.get("bytes_moved", 0)))

    # ------------------------------------------------------------- reporting
    def table_one(self) -> str:
        """The paper's Table I for this workflow."""
        return table_one(self.reports)

    def reset(self) -> None:
        for step in self.steps.values():
            for key in (step.marker_key(self.name), step.output_key(self.name)):
                self._ctrl().delete(key)
        self.results.clear()
        self.reports.clear()
