"""Workflow engine — the paper's step-by-step, measured, resumable workflows.

CHASE-CI's CONNECT workflow (§III) is four steps (download -> train ->
distributed inference -> visualization), each a Kubernetes Job that is
independently testable, measured in Grafana, and restartable.  The PPoDS
methodology (§VI) demands: separable steps, per-step measurement, and
development of steps in isolation.

Here a ``Workflow`` is a DAG of ``Step``s executed on a ``Cluster``:
  * each step runs as an orchestrator Job (pods = threads, devices = mesh
    slices) and its wall-time / bytes / resource footprint is recorded as a
    StepReport — Table I of the paper falls out of ``wf.table_one()``;
  * steps persist a completion marker + output manifest to the ObjectStore
    (the Ceph analogue), so a crashed / restarted workflow resumes from the
    last completed step (fault tolerance at the workflow level, on top of
    the queue's at-least-once and the checkpointer's auto-resume);
  * ``only=`` runs a single step in isolation (PPoDS independent testing).
"""
from __future__ import annotations

import json
import time
import traceback
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence

from repro.core.metrics import Registry, StepReport, table_one
from repro.core.orchestrator import Cluster, JobSpec
from repro.data.objectstore import ObjectStore


@dataclass
class StepCtx:
    """What a step's fn receives."""
    cluster: Cluster
    store: ObjectStore
    metrics: Registry
    namespace: str
    inputs: Dict[str, Any]          # outputs of dependency steps
    report: StepReport              # fill in data_processed / memory etc.


@dataclass
class Step:
    name: str
    fn: Callable[[StepCtx], Any]
    deps: Sequence[str] = ()
    pods: int = 1
    devices_per_pod: int = 0

    def marker_key(self, wf: str) -> str:
        return f"workflows/{wf}/{self.name}/_COMPLETE"

    def output_key(self, wf: str) -> str:
        return f"workflows/{wf}/{self.name}/output.json"


class Workflow:
    def __init__(self, name: str, *, cluster: Cluster, store: ObjectStore,
                 metrics: Optional[Registry] = None, namespace: str = "default"):
        self.name = name
        self.cluster = cluster
        self.store = store
        self.metrics = metrics or cluster.metrics
        self.namespace = namespace
        if namespace not in cluster.namespaces:
            cluster.create_namespace(namespace)
        self.steps: Dict[str, Step] = {}
        self.reports: List[StepReport] = []
        self.results: Dict[str, Any] = {}

    # ------------------------------------------------------------------ DAG
    def add(self, step: Step) -> "Workflow":
        if step.name in self.steps:
            raise ValueError(f"duplicate step {step.name}")
        self.steps[step.name] = step
        return self

    def step(self, name: str, deps: Sequence[str] = (), pods: int = 1,
             devices_per_pod: int = 0):
        """Decorator form: @wf.step("train", deps=["download"])"""
        def deco(fn):
            self.add(Step(name, fn, deps, pods, devices_per_pod))
            return fn
        return deco

    def _topo_order(self) -> List[Step]:
        order, seen, visiting = [], set(), set()

        def visit(name: str):
            if name in seen:
                return
            if name in visiting:
                raise ValueError(f"cycle at {name}")
            visiting.add(name)
            for d in self.steps[name].deps:
                visit(d)
            visiting.discard(name)
            seen.add(name)
            order.append(self.steps[name])

        for name in self.steps:
            visit(name)
        return order

    # ------------------------------------------------------------------ run
    def run(self, *, resume: bool = True, only: Optional[str] = None) -> Dict:
        for step in self._topo_order():
            if only is not None and step.name != only:
                # still load completed deps' outputs for the isolated step
                if self.store.exists(step.marker_key(self.name)):
                    self.results[step.name] = json.loads(
                        self.store.get(step.output_key(self.name)))
                continue
            self._run_step(step, resume)
        return dict(self.results)

    def _run_step(self, step: Step, resume: bool) -> None:
        marker = step.marker_key(self.name)
        if resume and self.store.exists(marker):
            self.results[step.name] = json.loads(
                self.store.get(step.output_key(self.name)))
            self.metrics.inc(f"workflow/{self.name}/{step.name}/skipped")
            return

        report = StepReport(step=step.name, pods=step.pods,
                            cpus=step.pods,
                            devices=step.pods * step.devices_per_pod)
        ctx = StepCtx(cluster=self.cluster, store=self.store,
                      metrics=self.metrics, namespace=self.namespace,
                      inputs={d: self.results[d] for d in step.deps},
                      report=report)
        t0 = time.perf_counter()
        with self.metrics.timer(f"workflow/{self.name}/{step.name}/time_s"):
            if step.pods <= 1:
                out = step.fn(ctx)
            else:
                # gang of pods; the step fn coordinates via a WorkQueue
                job = self.cluster.submit(self.namespace, JobSpec(
                    name=f"{self.name}-{step.name}", fn=lambda pc: step.fn(ctx),
                    replicas=1, devices_per_pod=step.devices_per_pod))
                self.cluster.wait(job)
                out = job.results()[0]
        report.total_time_s = time.perf_counter() - t0
        self.reports.append(report)
        self.results[step.name] = out

        self.store.put(step.output_key(self.name),
                       json.dumps(out, default=str).encode())
        self.store.put(marker, b"ok")

    # ------------------------------------------------------------- reporting
    def table_one(self) -> str:
        """The paper's Table I for this workflow."""
        return table_one(self.reports)

    def reset(self) -> None:
        for step in self.steps.values():
            for key in (step.marker_key(self.name), step.output_key(self.name)):
                self.store.delete(key)
        self.results.clear()
        self.reports.clear()
