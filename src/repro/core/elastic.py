"""Elastic scaling — nodes join/leave, the mesh reshapes, training resumes.

CHASE-CI §V: "nodes can join and leave the cluster at any time ... if a node
is taken offline the pods on that node will be rescheduled on another node".
For SPMD training the equivalent is: when the device set changes, build a
new mesh (shrinking/growing the data axis, never the model axis — TP/EP
layouts are weight-structural), re-shard the training state onto it (via the
checkpointer, which is mesh-agnostic), rescale the per-step batch, and keep
going.  A lost node therefore costs one checkpoint restore, not a job.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Tuple

import numpy as np
import jax
from jax.sharding import Mesh


@dataclass(frozen=True)
class RescalePlan:
    old_shape: Tuple[int, ...]
    new_shape: Tuple[int, ...]
    axes: Tuple[str, ...]
    devices_used: int
    devices_idle: int

    @property
    def data_parallel_change(self) -> float:
        i = self.axes.index("data")
        return self.new_shape[i] / self.old_shape[i]


def rescale_plan(axes: Tuple[str, ...], old_shape: Tuple[int, ...],
                 n_devices: int, *,
                 max_data: Optional[int] = None) -> RescalePlan:
    """Largest mesh for `n_devices` keeping every non-data axis fixed.

    The data axis absorbs the change (standard elastic-DP policy); if fewer
    devices than one model replica exist, raise — that cluster cannot host
    the model at all.  ``max_data`` caps the data axis (e.g. a launcher that
    wants a fixed single-device layout regardless of spare devices).
    """
    i = axes.index("data")
    fixed = int(np.prod([s for j, s in enumerate(old_shape) if j != i]))
    if n_devices < fixed:
        raise RuntimeError(
            f"{n_devices} devices < one model replica ({fixed})")
    new_data = n_devices // fixed
    # keep power-of-two data axis for even batch sharding
    new_data = 1 << (new_data.bit_length() - 1)
    if max_data is not None:
        new_data = min(new_data, max_data)
    new_shape = tuple(new_data if j == i else s
                      for j, s in enumerate(old_shape))
    used = fixed * new_data
    return RescalePlan(tuple(old_shape), new_shape, tuple(axes),
                       used, n_devices - used)


def make_elastic_mesh(plan: RescalePlan,
                      devices: Optional[List] = None) -> Mesh:
    devs = devices if devices is not None else jax.devices()
    n = int(np.prod(plan.new_shape))
    arr = np.array(devs[:n]).reshape(plan.new_shape)
    return Mesh(arr, plan.axes)


def reshard(tree, shardings):
    """Direct in-memory resharding (same process, live devices)."""
    return jax.tree.map(
        lambda x, s: jax.device_put(x, s), tree, shardings)
