"""Cluster / Namespace / Job / Pod — the Kubernetes constructs of CHASE-CI
(§II-A, §IV, §V) mapped onto a JAX device mesh.

Kubernetes semantics reproduced:
  * declarative jobs: you specify *what* (replicas, work), the controller
    reconciles actual state — crashed pods are respawned (backoff-limited),
    exactly like the paper's "Kubernetes will monitor these jobs which in
    themselves create and run pods ... re-spawn them if any errors occur";
  * namespaces: virtual sub-clusters with device quotas and isolation —
    two namespaces share hardware but not scheduling headroom (§IV);
  * device leases: a pod owns its devices from allocation until it reaches
    a terminal state; two live pods can never hold the same device, and a
    finished (or drained) pod returns quota to its namespace;
  * nodes joining/leaving: a NodeFailure drains the pods running on the
    failed device — they go FAILED, their leases are released, and the
    reconciler reschedules them onto fresh devices (§V), which pairs with
    checkpoint auto-resume in repro.checkpoint for full fault tolerance;
  * preemption: ``preempt_pod`` is the checkpoint-then-evict drain the
    multi-tenant fair-share scheduler (repro.vcluster) uses — cooperative
    like a node drain, but the pod is EXPECTED to save state on the way
    out, lands in the terminal PREEMPTED state, and is never respawned by
    the reconciler (the tenant scheduler owns resubmission).

Pods run python callables in threads (this container is one host); on a real
TPU fleet each pod is a host process pinned to its mesh slice — the Job/Pod
API is identical, which is the point.  Threads cannot be killed, so a drain
sets ``PodCtx.stop`` — long-running pod fns (e.g. repro.elastic's training
segments) poll it to exit cooperatively; the pod's *state* flips to FAILED
immediately either way.
"""
from __future__ import annotations

import threading
import time
import traceback
from dataclasses import dataclass, field
from enum import Enum
from typing import Any, Callable, Dict, List, Optional

from repro.core.metrics import Registry


class PodState(str, Enum):
    PENDING = "Pending"
    RUNNING = "Running"
    SUCCEEDED = "Succeeded"
    FAILED = "Failed"
    # evicted by the fair-share scheduler (repro.vcluster): terminal like
    # FAILED, but the reconciler never respawns it — the tenant scheduler
    # owns the resubmission (the pod checkpointed before exiting)
    PREEMPTED = "Preempted"


TERMINAL_STATES = (PodState.SUCCEEDED, PodState.FAILED, PodState.PREEMPTED)


@dataclass
class Namespace:
    name: str
    device_quota: int
    labels: Dict[str, str] = field(default_factory=dict)
    used_devices: int = 0


@dataclass
class PodCtx:
    pod_id: str
    namespace: str
    devices: List[Any]
    metrics: Registry
    attempt: int = 0
    site: str = "local"       # which federation site's cluster runs this pod
    stop: threading.Event = field(default_factory=threading.Event)
    # graceful eviction (fair-share preemption): unlike ``stop`` — whose
    # node is gone — the hardware is healthy, so the pod is expected to
    # checkpoint before exiting (checkpoint-then-evict)
    preempt: threading.Event = field(default_factory=threading.Event)

    def should_stop(self) -> bool:
        """Cooperative drain signal (set on NodeFailure / preemption)."""
        return self.stop.is_set() or self.preempt.is_set()


@dataclass
class Pod:
    pod_id: str
    fn: Callable[[PodCtx], Any]
    ctx: PodCtx
    state: PodState = PodState.PENDING
    restarts: int = 0
    result: Any = None
    error: Optional[str] = None
    thread: Optional[threading.Thread] = None
    # internal bookkeeping: `gen` fences stale run() threads after a drain +
    # respawn; `holds_devices` makes lease release idempotent.
    gen: int = 0
    holds_devices: bool = False
    lease_t0: float = 0.0        # when the current device lease started


@dataclass
class JobSpec:
    name: str
    fn: Callable[[PodCtx], Any]          # each pod replica runs this
    replicas: int = 1
    devices_per_pod: int = 0             # 0 = CPU-only pod (e.g. download)
    backoff_limit: int = 3
    # scheduling priority (repro.vcluster): higher may preempt strictly
    # lower.  None inherits the submitting tenant's priority.
    priority: Optional[int] = None


class Job:
    def __init__(self, spec: JobSpec, namespace: str):
        self.spec = spec
        self.namespace = namespace
        self.pods: List[Pod] = []

    @property
    def succeeded(self) -> bool:
        return (len(self.pods) == self.spec.replicas and
                all(p.state == PodState.SUCCEEDED for p in self.pods))

    @property
    def failed(self) -> bool:
        return any(p.state == PodState.FAILED and
                   p.restarts >= self.spec.backoff_limit for p in self.pods)

    @property
    def terminal(self) -> bool:
        """Every pod reached a terminal state (no thread is still live)."""
        return (len(self.pods) == self.spec.replicas and
                all(p.state in TERMINAL_STATES for p in self.pods))

    @property
    def preempted(self) -> bool:
        return any(p.state == PodState.PREEMPTED for p in self.pods)

    def results(self) -> List[Any]:
        return [p.result for p in self.pods]


class Cluster:
    """A set of devices ("nodes") + Kubernetes-style controller loop.

    ``site`` tags the cluster (and every device/pod it schedules) with the
    federation site that owns it — one PRP appliance in the paper's terms.
    A standalone cluster is the degenerate single-site case ("local");
    ``repro.fabric`` wires many site-tagged clusters into one fabric.
    """

    def __init__(self, devices: Optional[List[Any]] = None,
                 metrics: Optional[Registry] = None, site: str = "local"):
        if devices is None:
            import jax
            devices = list(jax.devices())
        self._lock = threading.Lock()
        self.site = site
        self.devices = list(devices)
        self.offline: set = set()
        self.leased: set = set()
        self.namespaces: Dict[str, Namespace] = {}
        self.jobs: List[Job] = []
        self.metrics = metrics or Registry()
        self._watchers: List[Callable[[str, Any], None]] = []
        self._pod_watchers: List[Callable[[str, Pod], None]] = []

    # ------------------------------------------------------------ namespaces
    def create_namespace(self, name: str, device_quota: Optional[int] = None,
                         **labels) -> Namespace:
        with self._lock:
            if name in self.namespaces:
                raise ValueError(f"namespace {name!r} exists")
            q = len(self.devices) if device_quota is None else device_quota
            ns = Namespace(name, q, labels)
            self.namespaces[name] = ns
            return ns

    def set_quota(self, namespace: str, device_quota: int) -> None:
        """Adjust a namespace's device quota (the vcluster scheduler's
        per-tenant accounting knob).  May drop below current usage: live
        leases are honored, only future allocations are blocked."""
        with self._lock:
            self.namespaces[namespace].device_quota = device_quota

    def free_devices(self) -> int:
        """Online devices not leased to any live pod."""
        with self._lock:
            return sum(1 for d in self.devices
                       if d not in self.offline and d not in self.leased)

    def _allocate_locked(self, ns: Namespace, n: int) -> List[Any]:
        """Lease `n` devices to a pod.  Caller holds self._lock.

        Devices already leased to a live pod are excluded — the seed's
        ``avail[:n]`` handed the same devices to every concurrent pod.
        """
        avail = [d for d in self.devices
                 if d not in self.offline and d not in self.leased]
        if ns.used_devices + n > ns.device_quota:
            raise RuntimeError(
                f"namespace {ns.name}: quota exceeded "
                f"({ns.used_devices}+{n} > {ns.device_quota})")
        if n > len(avail):
            raise RuntimeError(f"cluster: {n} devices requested, "
                               f"{len(avail)} free")
        take = avail[:n]
        self.leased.update(take)
        ns.used_devices += n
        return take

    def _release_pod_locked(self, pod: Pod) -> None:
        """Return a pod's lease (devices + namespace quota).  Idempotent.

        Bills the lease on the way out: ``lease_device_s/<namespace>``
        accumulates device-seconds held (allocation -> release), the
        per-tenant meter $-style chargeback reads (repro.scenarios)."""
        if not pod.holds_devices:
            return
        pod.holds_devices = False
        ns = self.namespaces[pod.ctx.namespace]
        for d in pod.ctx.devices:
            self.leased.discard(d)
        ns.used_devices = max(0, ns.used_devices - len(pod.ctx.devices))
        held = max(0.0, time.monotonic() - pod.lease_t0)
        self.metrics.inc(f"lease_device_s/{ns.name}",
                         held * len(pod.ctx.devices))

    # ----------------------------------------------------------------- jobs
    def submit(self, namespace: str, spec: JobSpec) -> Job:
        ns = self.namespaces[namespace]
        job = Job(spec, namespace)
        with self._lock:
            pods: List[Pod] = []
            try:
                for i in range(spec.replicas):
                    devs = self._allocate_locked(ns, spec.devices_per_pod) \
                        if spec.devices_per_pod else []
                    ctx = PodCtx(pod_id=f"{spec.name}-{i}",
                                 namespace=namespace, devices=devs,
                                 metrics=self.metrics, site=self.site)
                    pod = Pod(ctx.pod_id, spec.fn, ctx)
                    pod.holds_devices = bool(devs)
                    pod.lease_t0 = time.monotonic()
                    pods.append(pod)
            except Exception:
                for p in pods:           # all-or-nothing: undo partial leases
                    self._release_pod_locked(p)
                raise
            job.pods.extend(pods)
            self.jobs.append(job)
        for pod in job.pods:
            self._start_pod(pod)
        return job

    def _start_pod(self, pod: Pod) -> None:
        with self._lock:
            pod.gen += 1
            gen = pod.gen

        def run():
            with self._lock:
                # superseded (respawned) or drained while still PENDING
                if pod.gen != gen or pod.state != PodState.PENDING:
                    return
                pod.state = PodState.RUNNING
            self.metrics.inc(f"pods_running/{pod.ctx.namespace}")
            self._notify_pod("running", pod)
            try:
                result, err = pod.fn(pod.ctx), None
            except Exception as e:       # reconciler may respawn
                result = None
                err = f"{e}\n{traceback.format_exc()}"
            with self._lock:
                if pod.gen != gen:       # a respawned attempt owns the pod now
                    return
                # only a RUNNING pod changes state here; a drained one was
                # already flipped (and notified) by fail_node/preempt
                changed = pod.state == PodState.RUNNING
                if err is None:
                    pod.result = result
                    # a drained pod may still finish cooperatively — keep the
                    # result (e.g. its "preempted at step k" marker) but do
                    # not resurrect the FAILED state fail_node assigned.
                    if pod.state == PodState.RUNNING:
                        # a preempt-drained pod that exits cleanly made its
                        # checkpoint: terminal PREEMPTED, never respawned
                        pod.state = PodState.PREEMPTED \
                            if pod.ctx.preempt.is_set() else PodState.SUCCEEDED
                else:
                    if pod.state == PodState.RUNNING:
                        pod.error = err
                        if pod.ctx.preempt.is_set():
                            # crashed while winding down from a preempt:
                            # still an eviction, not a respawnable failure
                            pod.state = PodState.PREEMPTED
                        else:
                            pod.state = PodState.FAILED
                            self.metrics.inc(
                                f"pod_failures/{pod.ctx.namespace}")
                self._release_pod_locked(pod)   # terminal -> return the lease
                final = pod.state
            if changed:
                self._notify_pod(final.name.lower(), pod)

        pod.thread = threading.Thread(target=run, name=pod.pod_id)
        pod.thread.start()

    # ------------------------------------------------------------ controller
    def reconcile(self) -> int:
        """One controller pass: respawn failed pods under the backoff limit.

        A respawn re-allocates devices — the failed attempt's lease was
        released at terminal state and its devices may since have gone
        offline.  If the cluster cannot satisfy the allocation right now
        (quota or free devices), the pod stays FAILED and the next pass
        retries.  Returns the number of pods respawned.
        """
        respawned = 0
        for job in self.jobs:
            for pod in job.pods:
                with self._lock:
                    if not (pod.state == PodState.FAILED and
                            pod.restarts < job.spec.backoff_limit):
                        continue
                    self._release_pod_locked(pod)   # no-op unless drained
                    ns = self.namespaces[job.namespace]
                    try:
                        devs = self._allocate_locked(
                            ns, job.spec.devices_per_pod) \
                            if job.spec.devices_per_pod else []
                    except RuntimeError:
                        self.metrics.inc(
                            f"pod_unschedulable/{job.namespace}")
                        continue
                    pod.restarts += 1
                    pod.ctx = PodCtx(pod.pod_id, job.namespace, devs,
                                     self.metrics, attempt=pod.restarts,
                                     site=self.site)
                    pod.holds_devices = bool(devs)
                    pod.lease_t0 = time.monotonic()
                    pod.error = None
                    pod.state = PodState.PENDING
                self._notify_pod("respawned", pod)
                self._start_pod(pod)
                respawned += 1
        return respawned

    def wait(self, job: Job, *, reconcile_every: float = 0.01,
             timeout: float = 600.0) -> Job:
        """Block until the job succeeds or exhausts its backoff limit.

        The deadline is enforced ACROSS the per-pod joins, not just per
        controller pass: with many pods, one outer iteration used to cost
        ``len(pods) * reconcile_every`` seconds, overshooting a short
        timeout by orders of magnitude when pods hang."""
        deadline = time.monotonic() + timeout
        while True:
            for pod in job.pods:
                remaining = deadline - time.monotonic()
                if remaining <= 0:
                    break
                if pod.thread is not None:
                    pod.thread.join(timeout=min(reconcile_every, remaining))
            if job.succeeded:
                return job
            if job.failed:
                errs = [p.error for p in job.pods if p.error]
                raise RuntimeError(
                    f"job {job.spec.name} failed after backoff: {errs[:1]}")
            if time.monotonic() >= deadline:
                raise TimeoutError(f"job {job.spec.name} timed out")
            self.reconcile()

    # ------------------------------------------------------ preemption (§IV)
    def preempt_pod(self, pod: Pod, *, reason: str = "fair-share") -> bool:
        """Checkpoint-then-evict: the cooperative ``preempt`` drain.

        Unlike ``fail_node`` the hardware is healthy, so the pod is ASKED
        to leave: its ``PodCtx.preempt`` event is set, a cooperative fn
        (e.g. an elastic training segment) checkpoints and exits, and the
        pod lands in the terminal PREEMPTED state — which ``reconcile``
        never respawns; whoever preempted it (the repro.vcluster
        fair-share scheduler) owns the resubmission.  A still-PENDING pod
        is evicted immediately.  Returns False if the pod was already
        terminal."""
        with self._lock:
            if pod.state == PodState.PENDING:
                pod.state = PodState.PREEMPTED
                pod.error = f"Preempted: {reason}"
                pod.ctx.preempt.set()
                self._release_pod_locked(pod)
                notify = "preempted"
            elif pod.state == PodState.RUNNING:
                pod.ctx.preempt.set()
                pod.error = f"Preempted: {reason}"
                notify = "preempt-requested"
            else:
                return False
        self.metrics.inc(f"pod_preempted/{pod.ctx.namespace}")
        self._notify_pod(notify, pod)
        return True

    def retire_pod(self, pod: Pod) -> bool:
        """Take a FAILED pod out of the reconciler's respawn set by
        flipping it to terminal PREEMPTED.  Used when an external
        scheduler requeues the whole job: a later ``reconcile`` must not
        ALSO respawn the stale pod, or the work runs twice."""
        with self._lock:
            if pod.state != PodState.FAILED:
                return False
            pod.state = PodState.PREEMPTED
            return True

    def finish_preempt(self, pod: Pod) -> bool:
        """Grace expired: hard-evict a preempt-drained pod that has not
        exited.  The pod goes terminal PREEMPTED and its lease returns;
        the zombie thread is fenced by ``Pod.gen``/state checks and its
        late result, if any, is still recorded."""
        with self._lock:
            if not pod.ctx.preempt.is_set() or \
                    pod.state not in (PodState.PENDING, PodState.RUNNING):
                return False
            pod.state = PodState.PREEMPTED
            pod.ctx.stop.set()
            self._release_pod_locked(pod)
        self.metrics.inc(f"pod_preempt_hard/{pod.ctx.namespace}")
        self._notify_pod("preempted", pod)
        return True

    # ------------------------------------------------------- node churn (§V)
    def add_watcher(self, cb: Callable[[str, Any], None]) -> None:
        """Register cb(event, device) for node churn ("fail" | "join")."""
        self._watchers.append(cb)

    def add_pod_watcher(self, cb: Callable[[str, Pod], None]) -> None:
        """Register cb(event, pod) for pod lifecycle transitions: one of
        "running" | "succeeded" | "failed" | "preempted" |
        "preempt-requested" | "respawned".  Feeds the near-real-time
        monitor (repro.vcluster.monitor); observer errors are swallowed
        so a broken subscriber cannot take down the controller."""
        self._pod_watchers.append(cb)

    def _notify_pod(self, event: str, pod: Pod) -> None:
        for cb in list(self._pod_watchers):
            try:
                cb(event, pod)
            except Exception:       # observers must never break the loop
                pass

    def fail_node(self, device) -> None:
        """A node drops out: mark it offline AND drain the pods on it.

        Draining marks each affected pod FAILED (so ``reconcile`` reschedules
        it onto surviving devices), releases its lease, and sets its
        ``PodCtx.stop`` event so a cooperative fn can checkpoint and exit.
        """
        drained_pods: List[Pod] = []
        with self._lock:
            self.offline.add(device)
            for job in self.jobs:
                for pod in job.pods:
                    if pod.state in (PodState.PENDING, PodState.RUNNING) \
                            and device in pod.ctx.devices:
                        pod.state = PodState.FAILED
                        pod.error = (f"NodeFailure: device {device!r} "
                                     f"went offline")
                        pod.ctx.stop.set()
                        self._release_pod_locked(pod)
                        drained_pods.append(pod)
        if drained_pods:
            self.metrics.inc("node_drained_pods", len(drained_pods))
        for pod in drained_pods:
            self._notify_pod("failed", pod)
        for cb in list(self._watchers):
            cb("fail", device)

    def fail_all_nodes(self) -> None:
        """Whole-appliance outage: every node goes offline, every pod
        drains — INCLUDING device-less (CPU-only) pods, which the
        per-device drain in fail_node never touches.

        The federation layer (repro.fabric) escalates this beyond the
        single-cluster reconciler — surviving *sites* pick up the work."""
        for d in list(self.devices):
            self.fail_node(d)
        drained_pods: List[Pod] = []
        with self._lock:
            for job in self.jobs:
                for pod in job.pods:
                    if pod.state in (PodState.PENDING, PodState.RUNNING):
                        pod.state = PodState.FAILED
                        pod.error = "NodeFailure: whole site went offline"
                        pod.ctx.stop.set()
                        self._release_pod_locked(pod)
                        drained_pods.append(pod)
        if drained_pods:
            self.metrics.inc("node_drained_pods", len(drained_pods))
        for pod in drained_pods:
            self._notify_pod("failed", pod)

    def queue_depth(self) -> int:
        """Pods admitted but not yet terminal — the congestion signal the
        fabric placement planner folds into its site score."""
        with self._lock:
            return sum(1 for job in self.jobs for p in job.pods
                       if p.state in (PodState.PENDING, PodState.RUNNING))

    def join_node(self, device) -> None:
        with self._lock:
            self.offline.discard(device)
            if device not in self.devices:
                self.devices.append(device)
        for cb in list(self._watchers):
            cb("join", device)

    @property
    def online_devices(self) -> List[Any]:
        return [d for d in self.devices if d not in self.offline]
