"""Cluster / Namespace / Job / Pod — the Kubernetes constructs of CHASE-CI
(§II-A, §IV, §V) mapped onto a JAX device mesh.

Kubernetes semantics reproduced:
  * declarative jobs: you specify *what* (replicas, work), the controller
    reconciles actual state — crashed pods are respawned (backoff-limited),
    exactly like the paper's "Kubernetes will monitor these jobs which in
    themselves create and run pods ... re-spawn them if any errors occur";
  * namespaces: virtual sub-clusters with device quotas and isolation —
    two namespaces share hardware but not scheduling headroom (§IV);
  * nodes joining/leaving: device slices are leased from the cluster; a
    NodeFailure drains the affected pods and the controller reschedules
    them elsewhere (§V), which pairs with checkpoint auto-resume in
    repro.checkpoint for full fault tolerance.

Pods run python callables in threads (this container is one host); on a real
TPU fleet each pod is a host process pinned to its mesh slice — the Job/Pod
API is identical, which is the point.
"""
from __future__ import annotations

import threading
import time
import traceback
from dataclasses import dataclass, field
from enum import Enum
from typing import Any, Callable, Dict, List, Optional

from repro.core.metrics import Registry


class PodState(str, Enum):
    PENDING = "Pending"
    RUNNING = "Running"
    SUCCEEDED = "Succeeded"
    FAILED = "Failed"


@dataclass
class Namespace:
    name: str
    device_quota: int
    labels: Dict[str, str] = field(default_factory=dict)
    used_devices: int = 0


@dataclass
class PodCtx:
    pod_id: str
    namespace: str
    devices: List[Any]
    metrics: Registry
    attempt: int = 0


@dataclass
class Pod:
    pod_id: str
    fn: Callable[[PodCtx], Any]
    ctx: PodCtx
    state: PodState = PodState.PENDING
    restarts: int = 0
    result: Any = None
    error: Optional[str] = None
    thread: Optional[threading.Thread] = None


@dataclass
class JobSpec:
    name: str
    fn: Callable[[PodCtx], Any]          # each pod replica runs this
    replicas: int = 1
    devices_per_pod: int = 0             # 0 = CPU-only pod (e.g. download)
    backoff_limit: int = 3


class Job:
    def __init__(self, spec: JobSpec, namespace: str):
        self.spec = spec
        self.namespace = namespace
        self.pods: List[Pod] = []

    @property
    def succeeded(self) -> bool:
        return (len(self.pods) == self.spec.replicas and
                all(p.state == PodState.SUCCEEDED for p in self.pods))

    @property
    def failed(self) -> bool:
        return any(p.state == PodState.FAILED and
                   p.restarts >= self.spec.backoff_limit for p in self.pods)

    def results(self) -> List[Any]:
        return [p.result for p in self.pods]


class Cluster:
    """A set of devices ("nodes") + Kubernetes-style controller loop."""

    def __init__(self, devices: Optional[List[Any]] = None,
                 metrics: Optional[Registry] = None):
        if devices is None:
            import jax
            devices = list(jax.devices())
        self._lock = threading.Lock()
        self.devices = list(devices)
        self.offline: set = set()
        self.namespaces: Dict[str, Namespace] = {}
        self.jobs: List[Job] = []
        self.metrics = metrics or Registry()

    # ------------------------------------------------------------ namespaces
    def create_namespace(self, name: str, device_quota: Optional[int] = None,
                         **labels) -> Namespace:
        with self._lock:
            if name in self.namespaces:
                raise ValueError(f"namespace {name!r} exists")
            q = len(self.devices) if device_quota is None else device_quota
            ns = Namespace(name, q, labels)
            self.namespaces[name] = ns
            return ns

    def _allocate(self, ns: Namespace, n: int) -> List[Any]:
        avail = [d for d in self.devices if d not in self.offline]
        if ns.used_devices + n > ns.device_quota:
            raise RuntimeError(
                f"namespace {ns.name}: quota exceeded "
                f"({ns.used_devices}+{n} > {ns.device_quota})")
        if n > len(avail):
            raise RuntimeError(f"cluster: {n} devices requested, "
                               f"{len(avail)} online")
        ns.used_devices += n
        return avail[:n]

    def _release(self, ns: Namespace, n: int) -> None:
        ns.used_devices = max(0, ns.used_devices - n)

    # ----------------------------------------------------------------- jobs
    def submit(self, namespace: str, spec: JobSpec) -> Job:
        ns = self.namespaces[namespace]
        job = Job(spec, namespace)
        with self._lock:
            self.jobs.append(job)
        for i in range(spec.replicas):
            devs = self._allocate(ns, spec.devices_per_pod) \
                if spec.devices_per_pod else []
            ctx = PodCtx(pod_id=f"{spec.name}-{i}", namespace=namespace,
                         devices=devs, metrics=self.metrics)
            job.pods.append(Pod(ctx.pod_id, spec.fn, ctx))
        for pod in job.pods:
            self._start_pod(pod)
        return job

    def _start_pod(self, pod: Pod) -> None:
        def run():
            pod.state = PodState.RUNNING
            self.metrics.inc(f"pods_running/{pod.ctx.namespace}")
            try:
                pod.result = pod.fn(pod.ctx)
                pod.state = PodState.SUCCEEDED
            except Exception as e:   # reconciler may respawn
                pod.error = f"{e}\n{traceback.format_exc()}"
                pod.state = PodState.FAILED
                self.metrics.inc(f"pod_failures/{pod.ctx.namespace}")

        pod.thread = threading.Thread(target=run, name=pod.pod_id)
        pod.thread.start()

    # ------------------------------------------------------------ controller
    def reconcile(self) -> int:
        """One controller pass: respawn failed pods under the backoff limit.

        Returns the number of pods respawned.
        """
        respawned = 0
        for job in self.jobs:
            for pod in job.pods:
                if pod.state == PodState.FAILED and \
                        pod.restarts < job.spec.backoff_limit:
                    pod.restarts += 1
                    pod.ctx.attempt = pod.restarts
                    pod.error = None
                    self._start_pod(pod)
                    respawned += 1
        return respawned

    def wait(self, job: Job, *, reconcile_every: float = 0.01,
             timeout: float = 600.0) -> Job:
        """Block until the job succeeds or exhausts its backoff limit."""
        deadline = time.monotonic() + timeout
        while time.monotonic() < deadline:
            for pod in job.pods:
                if pod.thread is not None:
                    pod.thread.join(timeout=reconcile_every)
            if job.succeeded:
                return job
            if job.failed:
                errs = [p.error for p in job.pods if p.error]
                raise RuntimeError(
                    f"job {job.spec.name} failed after backoff: {errs[:1]}")
            self.reconcile()
        raise TimeoutError(f"job {job.spec.name} timed out")

    # ------------------------------------------------------- node churn (§V)
    def fail_node(self, device) -> None:
        """Simulate a node dropping out of the cluster."""
        with self._lock:
            self.offline.add(device)

    def join_node(self, device) -> None:
        with self._lock:
            self.offline.discard(device)
            if device not in self.devices:
                self.devices.append(device)

    @property
    def online_devices(self) -> List[Any]:
        return [d for d in self.devices if d not in self.offline]
