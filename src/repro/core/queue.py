"""Lease-based fault-tolerant work queue — the Redis job queue of the paper.

CHASE-CI's download/inference steps pop work from a Redis queue; workers that
die simply stop acking and their work is re-queued.  Semantics reproduced:

  * at-least-once delivery: a leased task that is not acked within
    ``lease_timeout`` becomes leasable again (visibility timeout);
  * idempotent completion: double-acks and acks from stale workers are
    ignored;
  * dead-lettering: tasks failing ``max_attempts`` times park in ``dead``;
  * work stealing == straggler mitigation: fast workers keep leasing while
    slow ones hold only their current lease (no barrier per item).

The queue is transport-agnostic and in-process here (single-container run);
a production deployment backs the same API with Redis.  State is fully
snapshot/restorable so a workflow step can checkpoint queue progress.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple


@dataclass
class _Task:
    task_id: int
    item: Any
    attempts: int = 0
    worker: Optional[str] = None
    lease_expiry: float = 0.0
    done: bool = False
    enqueued_at: float = 0.0


class WorkQueue:
    def __init__(self, items=(), *, lease_timeout: float = 30.0,
                 max_attempts: int = 5,
                 clock: Callable[[], float] = time.monotonic):
        self._lock = threading.Lock()
        self._clock = clock
        self.lease_timeout = lease_timeout
        self.max_attempts = max_attempts
        self._tasks: Dict[int, _Task] = {}
        self._pending: List[int] = []
        self._leased: Dict[int, _Task] = {}
        self._next_id = 0
        self.dead: List[_Task] = []
        for it in items:
            self.put(it)

    # ------------------------------------------------------------------ api
    def put(self, item, *, enqueued_at: Optional[float] = None) -> int:
        """Enqueue an item.  ``enqueued_at`` preserves the original
        submission time when a router migrates a request between queues
        (TTFT must charge the full wait, not restart it)."""
        with self._lock:
            tid = self._next_id
            self._next_id += 1
            self._tasks[tid] = _Task(
                tid, item,
                enqueued_at=self._clock() if enqueued_at is None
                else enqueued_at)
            self._pending.append(tid)
            return tid

    def enqueued_at(self, task_id: int) -> float:
        """Submission timestamp (queue clock) — survives lease/nack cycles,
        so queue wait is measurable from the *first* enqueue even after a
        preempted attempt requeues the task."""
        with self._lock:
            t = self._tasks.get(task_id)
            return t.enqueued_at if t is not None else 0.0

    def _reclaim_expired(self, now: float) -> None:
        # requeues the ORIGINAL _Task (never re-puts): attempts and
        # enqueued_at survive the implicit requeue, so queue-wait metrics
        # charge from the first enqueue even across worker crashes
        expired = [tid for tid, t in self._leased.items()
                   if t.lease_expiry <= now]
        for tid in expired:
            t = self._leased.pop(tid)
            t.worker = None
            if t.attempts >= self.max_attempts:
                self.dead.append(t)
            else:
                self._pending.append(tid)

    def lease(self, worker: str) -> Optional[Tuple[int, Any]]:
        """Pop one task; it must be acked within lease_timeout or it requeues."""
        now = self._clock()
        with self._lock:
            self._reclaim_expired(now)
            if not self._pending:
                return None
            tid = self._pending.pop(0)
            t = self._tasks[tid]
            t.worker = worker
            t.attempts += 1
            t.lease_expiry = now + self.lease_timeout
            self._leased[tid] = t
            return tid, t.item

    def ack(self, task_id: int, worker: str) -> bool:
        """Complete a task.  Idempotent; stale-worker acks are ignored."""
        with self._lock:
            t = self._tasks.get(task_id)
            if t is None or t.done:
                return False
            if t.worker != worker:      # lease expired and someone else owns it
                return False
            t.done = True
            self._leased.pop(task_id, None)
            return True

    def renew(self, task_id: int, worker: str) -> bool:
        """Extend the lease on a task the worker is still making progress on.

        Long-running work (a decode loop holding a slot for thousands of
        steps) outlives any fixed visibility timeout; heartbeating renew()
        keeps the task from being reclaimed and double-served while the
        worker is alive, without giving up crash-recovery: a worker that
        dies stops renewing and the task requeues one timeout later.
        Returns False (and does not extend) if the lease already expired
        or was reclaimed by another worker — the caller must drop the task.
        """
        now = self._clock()
        with self._lock:
            t = self._leased.get(task_id)
            if t is None or t.worker != worker or t.lease_expiry <= now:
                return False
            t.lease_expiry = now + self.lease_timeout
            return True

    def nack(self, task_id: int, worker: str) -> bool:
        """Return a task early (worker noticed it cannot finish).

        Like lease-expiry reclaim, this requeues the same task object:
        ``enqueued_at`` (and the attempt count) are preserved, never
        reset to the nack time."""
        with self._lock:
            t = self._leased.get(task_id)
            if t is None or t.worker != worker:
                return False
            t.worker = None
            self._leased.pop(task_id)
            if t.attempts >= self.max_attempts:
                self.dead.append(t)
            else:
                self._pending.append(task_id)
            return True

    # ------------------------------------------------------------- inspect
    @property
    def pending(self) -> int:
        with self._lock:
            return len(self._pending)

    @property
    def leased(self) -> int:
        with self._lock:
            now = self._clock()
            return sum(1 for t in self._leased.values() if t.lease_expiry > now)

    @property
    def completed(self) -> int:
        with self._lock:
            return sum(1 for t in self._tasks.values() if t.done)

    def leased_by(self, worker: str) -> int:
        """Live leases held by ``worker`` — chaos hooks kill a worker at
        a moment it provably holds work, tests then assert the requeue."""
        now = self._clock()
        with self._lock:
            return sum(1 for t in self._leased.values()
                       if t.worker == worker and t.lease_expiry > now)

    def drained(self) -> bool:
        with self._lock:
            now = self._clock()
            self._reclaim_expired(now)
            return not self._pending and not self._leased

    # ---------------------------------------------------------- checkpoint
    def snapshot(self) -> dict:
        with self._lock:
            return {
                "next_id": self._next_id,
                "lease_timeout": self.lease_timeout,
                "max_attempts": self.max_attempts,
                "tasks": [(t.task_id, t.item, t.attempts, t.done,
                           t.enqueued_at)
                          for t in self._tasks.values()],
                "pending": list(self._pending),
                "dead": [t.task_id for t in self.dead],
            }

    @classmethod
    def restore(cls, snap: dict, *, clock=time.monotonic) -> "WorkQueue":
        q = cls(lease_timeout=snap["lease_timeout"],
                max_attempts=snap["max_attempts"], clock=clock)
        q._next_id = snap["next_id"]
        dead = set(snap["dead"])
        for tid, item, attempts, done, *rest in snap["tasks"]:
            t = _Task(tid, item, attempts=attempts, done=done,
                      enqueued_at=rest[0] if rest else 0.0)
            q._tasks[tid] = t
            if tid in dead:
                q.dead.append(t)
        # Leases do not survive restarts, but FIFO fairness must: replay
        # the snapshotted pending order first (it encodes requeues/nacks),
        # then append tasks that were leased at snapshot time in task-id
        # order.  Old snapshots without "pending" degrade to id order.
        snapped = snap.get("pending")
        order = list(snapped) if snapped is not None else []
        seen = set(order) | dead
        for tid, *_ in snap["tasks"]:
            if tid not in seen and not q._tasks[tid].done:
                order.append(tid)
        q._pending = [tid for tid in order if tid not in dead
                      and not q._tasks[tid].done]
        return q


def run_workers(queue: WorkQueue, fn: Callable[[Any], Any], n_workers: int,
                *, name: str = "worker") -> List[Any]:
    """Drain a queue with n threads (the Kubernetes Job with N pods pattern).

    Returns results in task order.  A worker exception nacks the task so a
    healthy worker retries it — the paper's pod-crash story.  If every
    attempt of some task failed (dead-lettered), raises with the last error
    so failures are not silent.
    """
    results: Dict[int, Any] = {}
    lock = threading.Lock()
    last_error: List[BaseException] = []

    def loop(wid: str):
        while True:
            got = queue.lease(wid)
            if got is None:
                if queue.drained():
                    return
                time.sleep(0.001)
                continue
            tid, item = got
            try:
                out = fn(item)
            except Exception as e:
                with lock:
                    last_error.append(e)
                queue.nack(tid, wid)
                continue
            if queue.ack(tid, wid):
                with lock:
                    results[tid] = out

    threads = [threading.Thread(target=loop, args=(f"{name}-{i}",))
               for i in range(n_workers)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    if queue.dead:
        raise RuntimeError(
            f"{len(queue.dead)} task(s) dead-lettered; last error: "
            f"{last_error[-1]!r}") from (last_error[-1] if last_error else None)
    return [results[k] for k in sorted(results)]
