"""Live text dashboard — the paper's "visualization facility across the
network ... in near real-time" (§I contribution 4) as a terminal view.

    PYTHONPATH=src python -m repro.launch.monitor [--seconds 5]

Renders, at a fixed cadence, the state the monitor stream carries:
per-tenant fair-share accounting (usage, dominant share, priority),
per-site capacity/queue depth, and the tail of the event stream
(scheduling decisions, preemptions, pod churn, transfers, throughput
gauges).  ``render_frame`` is a pure function of (scheduler, events) so
tests can assert on frames without a terminal; ``run_dashboard`` drives
it from a live ``EventBus`` subscription.

Run as a module it stages a small self-contained demo: two tenants
contending for a 2-site fabric while the dashboard streams.
"""
from __future__ import annotations

import threading
import time
from collections import deque
from typing import Deque, List, Optional, Sequence

from repro.vcluster.monitor import Event
from repro.vcluster.scheduler import FairShareScheduler


def render_frame(sched: FairShareScheduler, events: Sequence[Event], *,
                 tail: int = 12, clock=time.time, workloads: Sequence = ()
                 ) -> str:
    """One dashboard frame as text (pure: no I/O, injectable clock).

    ``workloads`` — ``repro.api`` Handles (or their WorkloadStatus
    snapshots): every kind the unified API drives (train / serve /
    batch / workflow) renders as one uniform row, alongside the
    ``workload`` lifecycle events already in the tail."""
    lines: List[str] = []
    lines.append("=" * 72)
    lines.append(f"  virtual clusters @ {time.strftime('%H:%M:%S', time.localtime(clock()))}"
                 f"   policy={sched.policy}  events={sched.bus.published}")
    lines.append("-" * 72)
    lines.append(f"  {'site':<10} {'devices':>8} {'free':>6} {'queue':>6}")
    for site in sched.fabric.sites.values():
        cap = len(site.cluster.online_devices) if site.up else 0
        free = site.cluster.free_devices() if site.up else 0
        state = "" if site.up else "  DOWN"
        lines.append(f"  {site.name:<10} {cap:>8} {free:>6} "
                     f"{site.queue_depth():>6}{state}")
    lines.append("-" * 72)
    lines.append(f"  {'tenant':<10} {'prio':>5} {'weight':>7} {'devices':>8} "
                 f"{'share':>7} {'queued':>7} {'running':>8}")
    with sched._lock:
        pending = list(sched._pending)
        running = list(sched._running)
    for name, vc in sorted(sched.tenants.items()):
        used = sum(vc.usage().values())
        nq = sum(1 for j in pending if j.tenant == name)
        nr = sum(1 for j in running if j.tenant == name)
        lines.append(f"  {name:<10} {vc.spec.priority:>5} "
                     f"{vc.spec.weight:>7.2f} {used:>8} "
                     f"{vc.dominant_share():>7.3f} {nq:>7} {nr:>8}")
    if workloads:
        lines.append("-" * 72)
        lines.append(f"  {'workload':<20} {'kind':<12} {'backend':<8} "
                     f"{'state':<10}")
        for w in workloads:
            st = w.status() if hasattr(w, "status") else w
            lines.append(f"  {st.name:<20} {st.kind:<12} {st.backend:<8} "
                         f"{st.state.value:<10}")
    if events:
        lines.append("-" * 72)
        for ev in list(events)[-tail:]:
            lines.append(f"  [{ev.seq:>5}] {ev.brief()[:66]}")
    lines.append("=" * 72)
    return "\n".join(lines)


def run_dashboard(sched: FairShareScheduler, *, interval_s: float = 0.5,
                  stop: Optional[threading.Event] = None, out=print,
                  tail: int = 12, max_frames: Optional[int] = None) -> int:
    """Stream dashboard frames until ``stop`` is set.  Subscribes to the
    scheduler's bus; returns the number of events seen.  Lag stays below
    one dashboard interval because delivery is synchronous fan-out and
    each frame drains the whole subscription queue."""
    stop = stop or threading.Event()
    sub = sched.bus.subscribe(maxlen=4096)
    window: Deque[Event] = deque(maxlen=max(tail, 64))
    seen = 0
    frames = 0
    try:
        while not stop.is_set():
            got = sub.poll(timeout=interval_s)
            seen += len(got)
            window.extend(got)
            out(render_frame(sched, window, tail=tail))
            frames += 1
            if max_frames is not None and frames >= max_frames:
                break
            stop.wait(interval_s)
    finally:
        sub.close()
    return seen


def _demo(seconds: float) -> None:
    from repro.core.orchestrator import JobSpec
    from repro.fabric import Fabric
    from repro.vcluster import FairShareScheduler, TenantSpec

    fabric = Fabric()
    fabric.add_site("sdsc", devices=list(range(2)))
    fabric.add_site("calit2", devices=list(range(2)))
    fabric.connect("sdsc", "calit2", gbps=10.0, latency_ms=3.0)
    sched = FairShareScheduler(fabric, reconcile_s=0.02)
    sched.bus.attach_fabric(fabric)
    alice = sched.create_tenant(TenantSpec("alice"))
    bob = sched.create_tenant(TenantSpec("bob", weight=2.0))

    def work(ctx):
        end = time.monotonic() + 0.2
        while time.monotonic() < end and not ctx.should_stop():
            time.sleep(0.01)
        return "ok"

    stop = threading.Event()
    with sched:
        for i in range(8):
            alice.submit(JobSpec(f"a{i}", work, devices_per_pod=1))
            bob.submit(JobSpec(f"b{i}", work, devices_per_pod=1))
        t = threading.Timer(seconds, stop.set)
        t.start()
        run_dashboard(sched, interval_s=0.25, stop=stop)
        t.cancel()


def main() -> None:
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--seconds", type=float, default=5.0,
                    help="demo duration")
    args = ap.parse_args()
    _demo(args.seconds)


if __name__ == "__main__":
    main()
