"""RL driver — a thin manifest CLI over the unified workload API.

    PYTHONPATH=src python -m repro.launch.rl --arch phi4-mini-3.8b \
        --smoke --learner-steps 6 --actors 2 --fail-at 2
    PYTHONPATH=src python -m repro.launch.rl \
        --manifest examples/manifests/rl_smoke.json

Both forms declare the SAME ``repro.api.RLJob`` resource and apply it
through a ``Session`` on a one-host cluster: N continuous-batching
rollout actors over a shared ticket queue, a policy-gradient learner
on the fused chunked-scan hot loop, versioned weight broadcast through
the policy store (see docs/rl.md).  ``--fail-at`` injects ONE hard
learner crash; the crash loop restores from the latest periodic
checkpoint within the same invocation (``steps_lost <= ckpt_every``).
"""
from __future__ import annotations

import argparse

import jax

from repro.api import RLJob, Session
from repro.core.metrics import Registry
from repro.core.orchestrator import Cluster
from repro.launch import cli


def rl_job(arch: str, *, learner_steps: int, actors: int = 2,
           rollouts_per_step: int = 2, prompt_len: int = 8,
           max_new_tokens: int = 8, seq_len: int = 24, slots: int = 2,
           max_policy_lag: int = 2, broadcast_every: int = 2,
           ckpt_every: int = 2, device_steps: int = 1, smoke: bool = True,
           fail_at: int = -1, ckpt_dir: str = "", seed: int = 0) -> RLJob:
    """The RLJob resource the flag surface declares."""
    return RLJob(
        name=f"rl-{arch}", learner_steps=learner_steps, arch=arch,
        smoke=smoke, actors=actors, rollouts_per_step=rollouts_per_step,
        prompt_len=prompt_len, max_new_tokens=max_new_tokens,
        seq_len=seq_len, slots=slots, max_policy_lag=max_policy_lag,
        broadcast_every=broadcast_every, ckpt_every=ckpt_every,
        device_steps=device_steps, fail_at=fail_at, ckpt_dir=ckpt_dir,
        seed=seed)


def apply_rl(spec: RLJob, *, timeout: float = 3600.0):
    """Run one RLJob on a fresh one-host cluster Session."""
    metrics = Registry()
    session = Session(cluster=Cluster(devices=jax.devices(),
                                      metrics=metrics))
    return session.apply(spec).wait(timeout)


def main():
    ap = argparse.ArgumentParser()
    cli.add_manifest(ap)
    cli.add_arch(ap)
    cli.add_smoke(ap)
    cli.add_seed(ap)
    ap.add_argument("--learner-steps", type=int, default=6)
    ap.add_argument("--actors", type=int, default=2)
    ap.add_argument("--rollouts-per-step", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--max-new-tokens", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=24)
    ap.add_argument("--slots", type=int, default=2)
    ap.add_argument("--max-policy-lag", type=int, default=2)
    ap.add_argument("--broadcast-every", type=int, default=2)
    ap.add_argument("--ckpt-every", type=int, default=2)
    ap.add_argument("--device-steps", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--fail-at", type=int, default=-1,
                    help="inject one hard learner crash after this step; "
                         "the crash loop restores from the latest "
                         "checkpoint and finishes the run")
    args = ap.parse_args()
    spec = cli.manifest_spec(args, RLJob.KIND)
    if spec is None:
        spec = rl_job(args.arch, learner_steps=args.learner_steps,
                      actors=args.actors,
                      rollouts_per_step=args.rollouts_per_step,
                      prompt_len=args.prompt_len,
                      max_new_tokens=args.max_new_tokens,
                      seq_len=args.seq_len, slots=args.slots,
                      max_policy_lag=args.max_policy_lag,
                      broadcast_every=args.broadcast_every,
                      ckpt_every=args.ckpt_every,
                      device_steps=args.device_steps, smoke=args.smoke,
                      fail_at=args.fail_at, ckpt_dir=args.ckpt_dir,
                      seed=args.seed)
    out = apply_rl(spec)
    print(f"[rl] steps {out['steps_done']}/{spec.learner_steps} "
          f"version {out['final_version']} "
          f"trained {out['trained']} stale {out['stale_dropped']} "
          f"max_lag {out['max_lag_trained']} "
          f"lost {out['steps_lost']} recoveries {out['recoveries']} "
          f"actor_syncs>={out['min_actor_syncs']}")


if __name__ == "__main__":
    main()
