"""Batched serving driver: continuous prefill + decode over a request queue.

    PYTHONPATH=src python -m repro.launch.serve --arch phi4-mini-3.8b \
        --smoke --requests 8 --prompt-len 32 --gen 16

Serving shape: requests arrive in a WorkQueue (the paper's job-queue
pattern); the server batches up to ``--batch`` requests, runs one jitted
prefill to build the KV/state cache, then steps the jitted serve_step
(donated cache) for ``--gen`` tokens.  Greedy decoding over the synthetic
vocab — the point is the runtime, not the text.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import registry
from repro.configs.base import ShapeConfig
from repro.core.metrics import Registry
from repro.core.queue import WorkQueue
from repro.launch.mesh import single_device_mesh
from repro.models import params as pr
from repro.runtime import steps as steps_mod


def serve(arch: str, *, smoke: bool, n_requests: int, prompt_len: int,
          gen: int, batch: int = 4, seed: int = 0):
    cfg = registry.get_smoke(arch) if smoke else registry.get_config(arch)
    par = registry.get_parallel(arch)
    mesh = single_device_mesh()
    # cache sized for prompt + generation
    S = prompt_len + gen
    shape = ShapeConfig("serve", S, batch, "prefill")
    cfg = steps_mod.resolve_cfg(cfg, shape)
    mod = steps_mod._model_module(cfg)
    metrics = Registry()

    schema = mod.lm_schema(cfg)
    params = pr.init_params(schema, jax.random.key(seed), cfg.param_dtype)
    prefill = steps_mod.build_prefill(cfg, par, mesh, shape).jit()
    decode = steps_mod.build_decode(
        cfg, par, mesh, ShapeConfig("serve", S, batch, "decode")).jit()

    rng = np.random.RandomState(seed)
    queue = WorkQueue(
        [{"id": i,
          "prompt": rng.randint(1, cfg.vocab_size, prompt_len).tolist()}
         for i in range(n_requests)])

    T = steps_mod.token_len(cfg, shape) if cfg.family == "audio" else prompt_len
    results = {}
    with mesh:
        while not queue.drained():
            # ---- batch formation
            leased = []
            while len(leased) < batch:
                got = queue.lease("server")
                if got is None:
                    break
                leased.append(got)
            if not leased:
                time.sleep(0.001)
                continue
            prompts = np.ones((batch, T), np.int32)
            for row, (_, req) in enumerate(leased):
                prompts[row, :len(req["prompt"][:T])] = req["prompt"][:T]

            ex_abs, _ = steps_mod.extras_specs(cfg, batch)
            extras = ()
            if ex_abs:
                extras = ({k: jnp.zeros(v.shape, v.dtype)
                           for k, v in ex_abs.items()},)

            # ---- prefill -> first token + cache
            t0 = time.perf_counter()
            last, caches = prefill(params, jnp.asarray(prompts), *extras)
            tok = jnp.argmax(last, -1).astype(jnp.int32)[:, None]
            metrics.gauge("serve/prefill_s", time.perf_counter() - t0)

            # ---- decode loop (donated cache)
            out_tokens = [np.asarray(tok)]
            t1 = time.perf_counter()
            for g in range(gen - 1):
                tok, caches = decode(params, caches, tok,
                                     jnp.int32(T + g))
                out_tokens.append(np.asarray(tok))
            dt = time.perf_counter() - t1
            metrics.gauge("serve/decode_tok_s",
                          batch * max(gen - 1, 1) / max(dt, 1e-9))

            gen_tok = np.concatenate(out_tokens, axis=1)
            for row, (tid, req) in enumerate(leased):
                results[req["id"]] = gen_tok[row].tolist()
                queue.ack(tid, "server")
    return results, metrics


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="phi4-mini-3.8b",
                    choices=list(registry.ARCHS))
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--batch", type=int, default=4)
    args = ap.parse_args()
    results, metrics = serve(args.arch, smoke=args.smoke,
                             n_requests=args.requests,
                             prompt_len=args.prompt_len, gen=args.gen,
                             batch=args.batch)
    print(f"[serve] completed {len(results)} requests")
    print(metrics.to_csv())


if __name__ == "__main__":
    main()
