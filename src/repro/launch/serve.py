"""Serving driver: continuous-batching inference over a request queue.

    PYTHONPATH=src python -m repro.launch.serve --arch phi4-mini-3.8b \
        --smoke --requests 8 --prompt-len 32 --gen 16 --slots 4

Requests arrive in a WorkQueue (the paper's Redis job-queue pattern); the
default scheduler is the continuous batcher (repro.serving): a fixed pool
of decode slots, per-request prefill into a slotted KV/state cache, one
fused per-slot decode step per iteration, and immediate evict/refill when
a request hits its stop length — no inter-request barrier.

``--static`` (or ``serve_static``) keeps the legacy drain-then-refill
batcher: lease a batch, prefill together, decode until the LONGEST request
in the batch finishes, ack, repeat.  It exists as the baseline the
serving benchmark (benchmarks/run.py bench_serve) measures continuous
batching against; short requests idle their decode slots while the
stragglers run, which is exactly the utilization gap continuous batching
closes.

Both paths serve the same queue items — dicts with ``id``, ``prompt`` and
an optional per-request ``max_new_tokens`` — and return
``(results, metrics)`` with ``results[id]`` the generated tokens.
"""
from __future__ import annotations

import argparse
import time
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import registry
from repro.configs.base import ShapeConfig
from repro.core.metrics import (Registry, StepReport, record_serving_totals,
                                table_one)
from repro.core.queue import WorkQueue
from repro.launch.mesh import single_device_mesh
from repro.models import params as pr
from repro.runtime import steps as steps_mod
from repro.serving import ServingEngine


def make_requests(n_requests: int, prompt_len: int, gen: int, *,
                  vocab_size: int, seed: int = 0,
                  gen_lens: Optional[Sequence[int]] = None) -> List[dict]:
    """Synthetic request stream: random prompts, per-request stop lengths.
    ``gen_lens`` (cycled) gives a heterogeneous workload; default is the
    uniform ``gen`` every request."""
    rng = np.random.RandomState(seed)
    out = []
    for i in range(n_requests):
        g = gen if gen_lens is None else int(gen_lens[i % len(gen_lens)])
        out.append({"id": i,
                    "prompt": rng.randint(1, vocab_size, prompt_len).tolist(),
                    "max_new_tokens": g})
    return out


def _request_queue(requests, cfg, *, n_requests, prompt_len, gen, seed,
                   gen_lens, lease_timeout) -> WorkQueue:
    if requests is None:
        requests = make_requests(n_requests, prompt_len, gen,
                                 vocab_size=cfg.vocab_size, seed=seed,
                                 gen_lens=gen_lens)
    return WorkQueue(requests, lease_timeout=lease_timeout)


def serve(arch: str, *, smoke: bool, n_requests: int, prompt_len: int,
          gen: int, batch: int = 4, seed: int = 0,
          gen_lens: Optional[Sequence[int]] = None,
          lease_timeout: float = 30.0, warmup: bool = False,
          requests: Optional[Sequence[dict]] = None):
    """Continuous-batching serve: ``batch`` is the decode-slot pool size.

    Returns ``(results, metrics)``; see module docstring for the request
    item format and docs/serving.md for the metrics fields.
    """
    cfg = registry.get_smoke(arch) if smoke else registry.get_config(arch)
    par = registry.get_parallel(arch)
    mesh = single_device_mesh()
    engine = ServingEngine(cfg, par, mesh, num_slots=batch,
                           prompt_len=prompt_len, max_new_tokens=gen,
                           seed=seed)
    queue = _request_queue(requests, engine.cfg, n_requests=n_requests,
                           prompt_len=prompt_len, gen=gen, seed=seed,
                           gen_lens=gen_lens, lease_timeout=lease_timeout)
    if warmup:
        with mesh:
            engine.warmup()
    return engine.run(queue, default_max_new=gen)


def serve_static(arch: str, *, smoke: bool, n_requests: int, prompt_len: int,
                 gen: int, batch: int = 4, seed: int = 0,
                 gen_lens: Optional[Sequence[int]] = None,
                 lease_timeout: float = 30.0, warmup: bool = False,
                 requests: Optional[Sequence[dict]] = None):
    """Legacy static batcher (benchmark baseline — see module docstring).

    Batches drain-then-refill: each leased batch decodes until its longest
    request's stop length, then every member is acked and the next batch
    forms.  Per-request stop lengths are honored by truncation.
    """
    cfg = registry.get_smoke(arch) if smoke else registry.get_config(arch)
    par = registry.get_parallel(arch)
    mesh = single_device_mesh()
    S = prompt_len + gen
    shape = ShapeConfig("serve", S, batch, "prefill")
    cfg = steps_mod.resolve_cfg(cfg, shape)
    mod = steps_mod._model_module(cfg)
    metrics = Registry()

    schema = mod.lm_schema(cfg)
    params = pr.init_params(schema, jax.random.key(seed), cfg.param_dtype)
    prefill = steps_mod.build_prefill(cfg, par, mesh, shape).jit()
    decode = steps_mod.build_decode(
        cfg, par, mesh, ShapeConfig("serve", S, batch, "decode")).jit()

    queue = _request_queue(requests, cfg, n_requests=n_requests,
                           prompt_len=prompt_len, gen=gen, seed=seed,
                           gen_lens=gen_lens, lease_timeout=lease_timeout)

    T = steps_mod.token_len(cfg, shape) if cfg.family == "audio" else prompt_len
    # prefill caches cover only the prompt; splice them into a full-length
    # cache so decode has real headroom (see cache_prefix_insert)
    pad_cache = jax.jit(steps_mod.cache_prefix_insert, donate_argnums=0)
    ex_abs, _ = steps_mod.extras_specs(cfg, batch)
    extras = ()
    if ex_abs:
        extras = ({k: jnp.zeros(v.shape, v.dtype)
                   for k, v in ex_abs.items()},)

    results: Dict[int, list] = {}
    t_start = time.perf_counter()
    decode_s = 0.0
    with mesh:
        if warmup:
            dummy = jnp.ones((batch, T), jnp.int32)
            last, small = prefill(params, dummy, *extras)
            caches = pad_cache(steps_mod.init_cache(cfg, batch, S), small)
            tok = jnp.argmax(last, -1).astype(jnp.int32)[:, None]
            decode(params, caches, tok, jnp.int32(T))
            t_start = time.perf_counter()
        while not queue.drained():
            # ---- batch formation (drain-then-refill barrier)
            leased = []
            while len(leased) < batch:
                got = queue.lease("server")
                if got is None:
                    break
                leased.append(got)
            if not leased:
                time.sleep(0.001)
                continue
            prompts = np.ones((batch, T), np.int32)
            want = [gen] * len(leased)
            for row, (_, req) in enumerate(leased):
                prompts[row, :len(req["prompt"][:T])] = req["prompt"][:T]
                want[row] = min(int(req.get("max_new_tokens", gen)), gen)

            # ---- prefill -> first token + cache
            t0 = time.perf_counter()
            last, small = prefill(params, jnp.asarray(prompts), *extras)
            caches = pad_cache(steps_mod.init_cache(cfg, batch, S), small)
            tok = jnp.argmax(last, -1).astype(jnp.int32)[:, None]
            metrics.gauge("serve/prefill_s", time.perf_counter() - t0)

            # ---- decode loop: the whole batch runs to max(want)
            out_tokens = [np.asarray(tok)]
            t1 = time.perf_counter()
            for g in range(max(want) - 1):
                tok, caches = decode(params, caches, tok,
                                     jnp.int32(T + g))
                out_tokens.append(np.asarray(tok))
            decode_s += time.perf_counter() - t1

            gen_tok = np.concatenate(out_tokens, axis=1)
            for row, (tid, req) in enumerate(leased):
                results[req["id"]] = gen_tok[row, :want[row]].tolist()
                queue.ack(tid, "server")
                metrics.inc("serve/completed")
                metrics.inc("serve/tokens_generated", want[row])
    wall = time.perf_counter() - t_start
    record_serving_totals(metrics, sum(len(v) for v in results.values()),
                          wall, decode_s)
    return results, metrics


def serving_report(metrics: Registry, *, step: str = "serve",
                   devices: int = 1) -> StepReport:
    """Fold serve metrics into a paper-Table-I-style report column."""
    s = metrics.summary()

    def g(name, stat="last"):
        return s.get(name, {}).get(stat, 0.0)

    return StepReport(
        step=step, pods=1, devices=devices,
        total_time_s=g("serve/wall_s"),
        extra={
            "requests": g("serve/completed", "total"),
            "tokens": g("serve/tokens_generated", "total"),
            "tokens/s": g("serve/tok_s"),
            "decode tokens/s": g("serve/decode_tok_s"),
            "mean slot occupancy": g("serve/slot_occupancy", "mean"),
            "p50 latency (s)": g("serve/request_latency_s", "p50"),
            "p99 latency (s)": g("serve/request_latency_s", "p99"),
            "p50 ttft (s)": g("serve/ttft_s", "p50"),
        })


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="phi4-mini-3.8b",
                    choices=list(registry.ARCHS))
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--slots", "--batch", dest="slots", type=int, default=4)
    ap.add_argument("--static", action="store_true",
                    help="legacy drain-then-refill batcher (baseline)")
    ap.add_argument("--spread", action="store_true",
                    help="heterogeneous stop lengths (gen halved 4x, "
                         "cycled) — the workload continuous batching "
                         "wins on")
    args = ap.parse_args()
    gen_lens = None
    if args.spread:
        gen_lens = [max(1, args.gen // (2 ** i)) for i in range(4)]
    fn = serve_static if args.static else serve
    results, metrics = fn(args.arch, smoke=args.smoke,
                          n_requests=args.requests,
                          prompt_len=args.prompt_len, gen=args.gen,
                          batch=args.slots, gen_lens=gen_lens)
    mode = "static" if args.static else "continuous"
    print(f"[serve:{mode}] completed {len(results)} requests")
    print(metrics.to_csv())
    print()
    print(table_one([serving_report(metrics, step=f"serve ({mode})")]))


if __name__ == "__main__":
    main()
