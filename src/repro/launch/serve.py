"""Serving driver — a thin manifest CLI over the unified workload API.

    PYTHONPATH=src python -m repro.launch.serve --arch phi4-mini-3.8b \
        --smoke --requests 8 --prompt-len 32 --gen 16 --slots 4
    PYTHONPATH=src python -m repro.launch.serve --manifest serve.json

Both forms declare the SAME ``repro.api.ServeJob`` resource and apply it
through a ``Session``: requests ride a WorkQueue (the paper's Redis
job-queue pattern) into the continuous batcher (repro.serving) — a fixed
pool of decode slots, per-request prefill into a slotted KV/state cache,
one fused per-slot decode step per iteration, immediate evict/refill.

``--static`` (or ``serve_static``) keeps the legacy drain-then-refill
batcher: lease a batch, prefill together, decode until the LONGEST
request in the batch finishes, ack, repeat.  It exists as the baseline
the serving benchmark (benchmarks/run.py bench_serve) measures
continuous batching against — it stays a plain function, not an API
workload, on purpose.

``serve(...)`` is kept as a deprecated shim delegating to
``Session.apply`` (pinned by tests/test_api_equivalence.py); the
``serve/*`` gauge names and the Table-I row live in
``repro.serving.report`` now (one copy, shared with the engine and the
scheduler).
"""
from __future__ import annotations

import argparse
import time
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import ServeJob, Session
from repro.configs import registry
from repro.configs.base import ShapeConfig
from repro.core.metrics import Registry, table_one
from repro.core.orchestrator import Cluster
from repro.launch import cli
from repro.launch.mesh import single_device_mesh
from repro.models import params as pr
from repro.runtime import steps as steps_mod
# canonical homes are repro.serving.report; re-exported here for the
# pre-API callers (benchmarks, examples, tests)
from repro.serving.report import (GAUGES, make_requests, record_serving_totals,
                                  request_queue as _request_queue,
                                  serving_report)


def serve_job(arch: str, *, smoke: bool, n_requests: int, prompt_len: int,
              gen: int, batch: int = 4, seed: int = 0,
              gen_lens: Optional[Sequence[int]] = None,
              lease_timeout: float = 30.0, warmup: bool = False,
              requests: Optional[Sequence[dict]] = None) -> ServeJob:
    """The ServeJob resource the legacy flag surface declares."""
    return ServeJob(
        name=f"serve-{arch}", arch=arch, smoke=smoke,
        n_requests=n_requests, prompt_len=prompt_len, max_new_tokens=gen,
        slots=batch, seed=seed,
        gen_lens=tuple(gen_lens) if gen_lens is not None else None,
        lease_timeout=lease_timeout, warmup=warmup,
        requests=[dict(r) for r in requests] if requests is not None
        else None)


def apply_serve(spec: ServeJob, *, timeout: float = 3600.0):
    """Run one ServeJob on a fresh one-host cluster Session."""
    session = Session(cluster=Cluster(devices=jax.devices(),
                                      metrics=Registry()))
    return session.apply(spec).wait(timeout)


def serve(arch: str, *, smoke: bool, n_requests: int, prompt_len: int,
          gen: int, batch: int = 4, seed: int = 0,
          gen_lens: Optional[Sequence[int]] = None,
          lease_timeout: float = 30.0, warmup: bool = False,
          requests: Optional[Sequence[dict]] = None):
    """Deprecated shim — declare a ``repro.api.ServeJob`` and apply it
    through a ``Session`` instead.  Returns ``(results, metrics)`` like
    the pre-API driver."""
    out = apply_serve(serve_job(
        arch, smoke=smoke, n_requests=n_requests, prompt_len=prompt_len,
        gen=gen, batch=batch, seed=seed, gen_lens=gen_lens,
        lease_timeout=lease_timeout, warmup=warmup, requests=requests))
    return out["results"], out["metrics"]


def serve_static(arch: str, *, smoke: bool, n_requests: int, prompt_len: int,
                 gen: int, batch: int = 4, seed: int = 0,
                 gen_lens: Optional[Sequence[int]] = None,
                 lease_timeout: float = 30.0, warmup: bool = False,
                 requests: Optional[Sequence[dict]] = None,
                 cfg_override=None):
    """Legacy static batcher (benchmark baseline — see module docstring).

    Batches drain-then-refill: each leased batch decodes until its longest
    request's stop length, then every member is acked and the next batch
    forms.  Per-request stop lengths are honored by truncation.
    ``cfg_override`` substitutes an explicit ModelConfig so benchmarks can
    compare against the continuous engine on identical custom shapes.
    """
    cfg = cfg_override if cfg_override is not None else (
        registry.get_smoke(arch) if smoke else registry.get_config(arch))
    par = registry.get_parallel(arch)
    mesh = single_device_mesh()
    S = prompt_len + gen
    shape = ShapeConfig("serve", S, batch, "prefill")
    cfg = steps_mod.resolve_cfg(cfg, shape)
    mod = steps_mod._model_module(cfg)
    metrics = Registry()

    schema = mod.lm_schema(cfg)
    params = pr.init_params(schema, jax.random.key(seed), cfg.param_dtype)
    prefill = steps_mod.build_prefill(cfg, par, mesh, shape).jit()
    decode = steps_mod.build_decode(
        cfg, par, mesh, ShapeConfig("serve", S, batch, "decode")).jit()

    T = steps_mod.token_len(cfg, shape) if cfg.family == "audio" else prompt_len
    # prefill caches cover only the prompt; splice them into a full-length
    # cache so decode has real headroom (see cache_prefix_insert)
    pad_cache = jax.jit(steps_mod.cache_prefix_insert, donate_argnums=0)
    ex_abs, _ = steps_mod.extras_specs(cfg, batch)
    extras = ()
    if ex_abs:
        extras = ({k: jnp.zeros(v.shape, v.dtype)
                   for k, v in ex_abs.items()},)

    results: Dict[int, list] = {}
    t_start = time.perf_counter()
    decode_s = 0.0
    with mesh:
        if warmup:
            dummy = jnp.ones((batch, T), jnp.int32)
            last, small = prefill(params, dummy, *extras)
            caches = pad_cache(steps_mod.init_cache(cfg, batch, S), small)
            tok = jnp.argmax(last, -1).astype(jnp.int32)[:, None]
            decode(params, caches, tok, jnp.int32(T))
            t_start = time.perf_counter()
        # requests enqueue after warmup so TTFT (enqueue -> first token,
        # same accounting as the continuous engine) excludes compile time
        queue = _request_queue(requests, cfg, n_requests=n_requests,
                               prompt_len=prompt_len, gen=gen, seed=seed,
                               gen_lens=gen_lens,
                               lease_timeout=lease_timeout)
        while not queue.drained():
            # ---- batch formation (drain-then-refill barrier)
            leased = []
            while len(leased) < batch:
                got = queue.lease("server")
                if got is None:
                    break
                leased.append(got)
            if not leased:
                time.sleep(0.001)
                continue
            prompts = np.ones((batch, T), np.int32)
            want = [gen] * len(leased)
            for row, (_, req) in enumerate(leased):
                prompts[row, :len(req["prompt"][:T])] = req["prompt"][:T]
                want[row] = min(int(req.get("max_new_tokens", gen)), gen)

            # ---- prefill -> first token + cache
            t0 = time.perf_counter()
            last, small = prefill(params, jnp.asarray(prompts), *extras)
            caches = pad_cache(steps_mod.init_cache(cfg, batch, S), small)
            tok = jnp.argmax(last, -1).astype(jnp.int32)[:, None]
            metrics.gauge(GAUGES.PREFILL_S, time.perf_counter() - t0)
            now = time.monotonic()      # the queue's clock, for TTFT
            for tid, _ in leased:
                metrics.gauge(GAUGES.TTFT_S, now - queue.enqueued_at(tid))

            # ---- decode loop: the whole batch runs to max(want)
            out_tokens = [np.asarray(tok)]
            t1 = time.perf_counter()
            for g in range(max(want) - 1):
                tok, caches = decode(params, caches, tok,
                                     jnp.int32(T + g))
                out_tokens.append(np.asarray(tok))
            decode_s += time.perf_counter() - t1

            gen_tok = np.concatenate(out_tokens, axis=1)
            now = time.monotonic()
            for row, (tid, req) in enumerate(leased):
                results[req["id"]] = gen_tok[row, :want[row]].tolist()
                queue.ack(tid, "server")
                metrics.inc(GAUGES.COMPLETED)
                metrics.inc(GAUGES.TOKENS, want[row])
                metrics.gauge(GAUGES.LATENCY_S,
                              now - queue.enqueued_at(tid))
    wall = time.perf_counter() - t_start
    record_serving_totals(metrics, sum(len(v) for v in results.values()),
                          wall, decode_s)
    return results, metrics


def main():
    ap = argparse.ArgumentParser()
    cli.add_manifest(ap)
    cli.add_arch(ap)
    cli.add_smoke(ap)
    cli.add_seed(ap)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--slots", "--batch", dest="slots", type=int, default=4)
    ap.add_argument("--static", action="store_true",
                    help="legacy drain-then-refill batcher (baseline)")
    ap.add_argument("--spread", action="store_true",
                    help="heterogeneous stop lengths (gen halved 4x, "
                         "cycled) — the workload continuous batching "
                         "wins on")
    args = ap.parse_args()
    gen_lens = None
    if args.spread:
        gen_lens = [max(1, args.gen // (2 ** i)) for i in range(4)]
    if args.static:
        if args.manifest:
            raise SystemExit("--static is the benchmark baseline, not an "
                             "API workload: it cannot run a --manifest "
                             "declaration")
        results, metrics = serve_static(
            args.arch, smoke=args.smoke, n_requests=args.requests,
            prompt_len=args.prompt_len, gen=args.gen, batch=args.slots,
            seed=args.seed, gen_lens=gen_lens)
        mode = "static"
    else:
        spec = cli.manifest_spec(args, ServeJob.KIND)
        if spec is None:
            spec = serve_job(args.arch, smoke=args.smoke,
                             n_requests=args.requests,
                             prompt_len=args.prompt_len, gen=args.gen,
                             batch=args.slots, seed=args.seed,
                             gen_lens=gen_lens)
        out = apply_serve(spec)
        results, metrics = out["results"], out["metrics"]
        mode = "continuous"
    print(f"[serve:{mode}] completed {len(results)} requests")
    print(metrics.to_csv())
    print()
    print(table_one([serving_report(metrics, step=f"serve ({mode})")]))


if __name__ == "__main__":
    main()
