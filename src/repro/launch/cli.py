"""Shared launcher flags + manifest loading — one place, no drift.

``launch/train.py``, ``launch/serve.py`` and ``launch/dryrun.py`` used
to each re-declare their own ``--arch``/``--smoke``/``--seed`` argparse
surface (and their defaults had already diverged); the manifest-driven
CLIs declare them here once.  ``--manifest job.json`` short-circuits the
flag surface entirely: the file IS the workload declaration
(``repro.api.resources``), exactly like ``kubectl apply -f``.
"""
from __future__ import annotations

import argparse
from typing import Optional

from repro.api.resources import WorkloadSpec, load_manifest

DEFAULT_ARCH = "phi4-mini-3.8b"


def add_arch(ap: argparse.ArgumentParser, *, default: str = DEFAULT_ARCH,
             restrict: bool = True) -> None:
    """``--arch <id>`` from the config registry.  ``restrict=False``
    (the dry-run sweep) accepts ids the registry resolves lazily."""
    from repro.configs import registry
    kw = {"choices": list(registry.ARCHS)} if restrict else {}
    ap.add_argument("--arch", default=default, **kw)


def add_smoke(ap: argparse.ArgumentParser) -> None:
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-shape config (CPU-sized)")


def add_seed(ap: argparse.ArgumentParser, *, default: int = 0) -> None:
    ap.add_argument("--seed", type=int, default=default)


def add_manifest(ap: argparse.ArgumentParser) -> None:
    ap.add_argument("--manifest", default="",
                    help="workload manifest (JSON, see docs/api.md); "
                         "when given, the other workload flags are "
                         "ignored — the file is the declaration")


def manifest_spec(args, expect_kind: str) -> Optional[WorkloadSpec]:
    """The manifest's spec (validated to ``expect_kind``), or None when
    ``--manifest`` was not passed."""
    path = getattr(args, "manifest", "")
    if not path:
        return None
    spec = load_manifest(path)
    if spec.KIND != expect_kind:
        raise SystemExit(
            f"--manifest {path}: kind {spec.KIND!r} cannot be launched "
            f"by this driver (expects {expect_kind!r})")
    return spec
