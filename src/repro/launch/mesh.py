"""Production meshes.

``make_production_mesh`` is a FUNCTION (not module-level state) so importing
this module never touches jax device state.  The dry-run launcher sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import; everything else (tests, benches) sees the real single CPU device.
"""
from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]) -> Mesh:
    """Arbitrary mesh (smoke tests use (1, 1) / (1,) meshes on one device)."""
    return jax.make_mesh(shape, axes)


def single_device_mesh(axes: tuple[str, ...] = ("data", "model")) -> Mesh:
    devs = np.array(jax.devices()[:1]).reshape((1,) * len(axes))
    return Mesh(devs, axes)


def mesh_num_chips(mesh: Mesh) -> int:
    return int(np.prod(list(mesh.shape.values())))
