"""Production meshes.

``make_production_mesh`` is a FUNCTION (not module-level state) so importing
this module never touches jax device state.  The dry-run launcher sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import; everything else (tests, benches) sees the real single CPU device.
"""
from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh


# single source of truth for the fleet layouts (launch/train derives its
# elastic base_shape from these; dryrun builds them directly)
PRODUCTION_MESH_SHAPE = (16, 16)
PRODUCTION_MESH_SHAPE_MULTI_POD = (2, 16, 16)


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = PRODUCTION_MESH_SHAPE_MULTI_POD if multi_pod \
        else PRODUCTION_MESH_SHAPE
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]) -> Mesh:
    """Arbitrary mesh (smoke tests use (1, 1) / (1,) meshes on one device)."""
    return jax.make_mesh(shape, axes)


def single_device_mesh(axes: tuple[str, ...] = ("data", "model")) -> Mesh:
    devs = np.array(jax.devices()[:1]).reshape((1,) * len(axes))
    return Mesh(devs, axes)


def mesh_num_chips(mesh: Mesh) -> int:
    return int(np.prod(list(mesh.shape.values())))
