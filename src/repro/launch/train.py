"""End-to-end training driver.

    PYTHONPATH=src python -m repro.launch.train --arch phi4-mini-3.8b \
        --steps 50 --batch 4 --seq 128 --smoke --ckpt-dir /tmp/run1

Wires together every substrate: config registry -> mesh -> sharded params/
optimizer -> synthetic token pipeline (double-buffered) -> jitted train step
(donated state) -> metrics -> async sharded checkpointing with auto-resume.
``--smoke`` trains the reduced same-family config (CPU-runnable); without it
the full assigned config is used (real hardware).  ``--fail-at`` injects a
crash to exercise restart/auto-resume (fault tolerance demo; see also
examples/elastic_failover.py).
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.checkpoint.checkpoint import Checkpointer
from repro.configs import registry
from repro.configs.base import OptimizerConfig, ShapeConfig
from repro.core.metrics import Registry
from repro.data.objectstore import ObjectStore
from repro.data.tokens import TokenPipeline
from repro.launch.mesh import make_production_mesh, single_device_mesh
from repro.models import params as pr
from repro.optim import adamw
from repro.runtime import steps as steps_mod
from repro.sharding import specs as sh


def make_state(cfg, ocfg, mesh, rules, key):
    mod = steps_mod._model_module(cfg)
    schema = mod.lm_schema(cfg)
    opt_schema = adamw.opt_state_schema(schema, ocfg)
    with mesh:
        params = jax.jit(
            lambda k: pr.init_params(schema, k, cfg.param_dtype),
            out_shardings=sh.shardings_for_schema(schema, mesh, rules))(key)
        opt = jax.jit(
            lambda: pr.init_params(opt_schema, jax.random.key(0), "float32"),
            out_shardings=sh.shardings_for_schema(opt_schema, mesh, rules))()
    return schema, opt_schema, params, opt


def train(arch: str, *, steps: int, seq: int, batch: int, smoke: bool,
          ckpt_dir: str = "", ckpt_every: int = 0, fail_at: int = -1,
          log_every: int = 10, production_mesh: bool = False,
          cfg_override=None):
    if cfg_override is not None:
        cfg = cfg_override
        par = registry.get_parallel("phi4-mini-3.8b")   # defaults
        ocfg = OptimizerConfig()
    else:
        cfg = registry.get_smoke(arch) if smoke else registry.get_config(arch)
        par = registry.get_parallel(arch)
        ocfg = registry.get_optimizer(arch)
    ocfg = OptimizerConfig(
        lr=1e-3, warmup_steps=max(steps // 20, 1), decay_steps=steps,
        moment_dtype=ocfg.moment_dtype, second_moment=ocfg.second_moment)
    mesh = make_production_mesh() if production_mesh else single_device_mesh()
    rules = sh.logical_rules(par)
    shape = ShapeConfig("train", seq, batch, "train")
    cfg = steps_mod.resolve_cfg(cfg, shape)

    metrics = Registry()
    bundle = steps_mod.build_train(cfg, par, ocfg, mesh, shape)
    schema, opt_schema, params, opt = make_state(
        cfg, ocfg, mesh, rules, jax.random.key(0))

    ckpt = None
    start_step = 0
    if ckpt_dir:
        ckpt = Checkpointer(ObjectStore(ckpt_dir), keep=2)
        restored, meta = ckpt.restore_latest(
            {"params": pr.abstract_params(schema, cfg.param_dtype),
             "opt": pr.abstract_params(opt_schema, "float32")},
            {"params": sh.shardings_for_schema(schema, mesh, rules),
             "opt": sh.shardings_for_schema(opt_schema, mesh, rules)})
        if restored is not None:
            params, opt = restored["params"], restored["opt"]
            start_step = int(meta["step"]) + 1
            print(f"[train] auto-resumed from step {meta['step']}")

    pipe = TokenPipeline(cfg.vocab_size, shape.seq_len, shape.global_batch,
                         seed=17)
    step_fn = bundle.jit()
    losses = []
    with mesh:
        t0 = time.perf_counter()
        for i in range(start_step, steps):
            if i == fail_at:
                raise RuntimeError(f"injected failure at step {i}")
            batch_i = pipe.batch(i)
            params, opt, m = step_fn(params, opt, batch_i)
            loss = float(m["loss"])
            losses.append(loss)
            metrics.gauge("train/loss", loss)
            metrics.gauge("train/grad_norm", float(m["grad_norm"]))
            if ckpt is not None and ckpt_every and (i + 1) % ckpt_every == 0:
                ckpt.save_async(i, {"params": params, "opt": opt})
            if i % log_every == 0 or i == steps - 1:
                dt = time.perf_counter() - t0
                tps = shape.global_batch * shape.seq_len * (i - start_step + 1) / dt
                print(f"[train] step {i} loss {loss:.4f} "
                      f"gnorm {float(m['grad_norm']):.3f} tok/s {tps:,.0f}")
    if ckpt is not None:
        ckpt.wait()
    return {"losses": losses, "params": params, "metrics": metrics}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="phi4-mini-3.8b",
                    choices=list(registry.ARCHS))
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--fail-at", type=int, default=-1)
    args = ap.parse_args()
    out = train(args.arch, steps=args.steps, seq=args.seq, batch=args.batch,
                smoke=args.smoke, ckpt_dir=args.ckpt_dir,
                ckpt_every=args.ckpt_every, fail_at=args.fail_at)
    first, last = out["losses"][0], out["losses"][-1]
    print(f"[train] loss {first:.4f} -> {last:.4f} "
          f"({'improved' if last < first else 'NOT improved'})")


if __name__ == "__main__":
    main()
