"""Training driver — a thin manifest CLI over the unified workload API.

    PYTHONPATH=src python -m repro.launch.train --arch phi4-mini-3.8b \
        --steps 50 --batch 4 --seq 128 --smoke --ckpt-dir /tmp/run1
    PYTHONPATH=src python -m repro.launch.train --manifest train.json

Both forms declare the SAME ``repro.api.TrainJob`` resource and apply it
through a ``Session`` on a one-host cluster; ``--manifest`` is the
kubectl path (the file is the declaration), the flags are sugar that
builds the identical manifest.  A single-device run is the degenerate
case of elastic training (repro.elastic); ``--fail-at`` injects ONE
crash at that step and the supervisor restores from the latest
checkpoint within the same invocation.

``train(...)`` is kept as a deprecated shim for existing callers — it
builds the TrainJob and delegates to ``Session.apply`` (the equivalence
is pinned by tests/test_api_equivalence.py).
"""
from __future__ import annotations

import argparse

import jax

from repro.api import Session, TrainJob
from repro.core.metrics import Registry
from repro.core.orchestrator import Cluster
from repro.launch import cli
from repro.launch.mesh import PRODUCTION_MESH_SHAPE


def train_job(arch: str, *, steps: int, seq: int, batch: int, smoke: bool,
              ckpt_dir: str = "", ckpt_every: int = 0, fail_at: int = -1,
              log_every: int = 10, production_mesh: bool = False,
              cfg_override=None, seed: int = 0,
              device_steps: int = 1) -> TrainJob:
    """The TrainJob resource the legacy flag surface declares."""
    config = None
    if cfg_override is not None:
        from repro.api.runners import dataclass_kwargs
        config = dataclass_kwargs(cfg_override)
    return TrainJob(
        name=f"train-{arch}", steps=steps, arch=arch, smoke=smoke,
        seq_len=seq, global_batch=batch,
        base_shape=PRODUCTION_MESH_SHAPE if production_mesh else (1, 1),
        max_data=None if production_mesh else 1,
        ckpt_dir=ckpt_dir, ckpt_every=ckpt_every, keep=2,
        log_every=log_every, fail_at=fail_at, seed=seed, config=config,
        device_steps=device_steps)


def apply_train(spec: TrainJob, *, timeout: float = 3600.0):
    """Run one TrainJob on a fresh one-host cluster Session."""
    metrics = Registry()
    session = Session(cluster=Cluster(devices=jax.devices(),
                                      metrics=metrics))
    out = session.apply(spec).wait(timeout)
    out["metrics"] = metrics
    return out


def train(arch: str, *, steps: int, seq: int, batch: int, smoke: bool,
          ckpt_dir: str = "", ckpt_every: int = 0, fail_at: int = -1,
          log_every: int = 10, production_mesh: bool = False,
          cfg_override=None, device_steps: int = 1):
    """Deprecated shim — declare a ``repro.api.TrainJob`` and apply it
    through a ``Session`` instead.  Kept so pre-API callers (and the
    equivalence regression) keep working unchanged."""
    spec = train_job(arch, steps=steps, seq=seq, batch=batch, smoke=smoke,
                     ckpt_dir=ckpt_dir, ckpt_every=ckpt_every,
                     fail_at=fail_at, log_every=log_every,
                     production_mesh=production_mesh,
                     cfg_override=cfg_override, device_steps=device_steps)
    out = apply_train(spec)
    return {"losses": out["losses"], "params": out["params"],
            "metrics": out["metrics"], "report": out["report"]}


def main():
    ap = argparse.ArgumentParser()
    cli.add_manifest(ap)
    cli.add_arch(ap)
    cli.add_smoke(ap)
    cli.add_seed(ap)
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--fail-at", type=int, default=-1,
                    help="inject one crash at this step; the elastic "
                         "supervisor restores and finishes the run")
    ap.add_argument("--device-steps", type=int, default=1,
                    help="optimizer steps fused into one device dispatch "
                         "(lax.scan hot loop); ckpt/log cadences snap up "
                         "to multiples of this")
    args = ap.parse_args()
    spec = cli.manifest_spec(args, TrainJob.KIND)
    if spec is None:
        spec = train_job(args.arch, steps=args.steps, seq=args.seq,
                         batch=args.batch, smoke=args.smoke,
                         ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
                         fail_at=args.fail_at, seed=args.seed,
                         device_steps=args.device_steps)
    out = apply_train(spec)
    first, last = out["losses"][0], out["losses"][-1]
    print(f"[train] loss {first:.4f} -> {last:.4f} "
          f"({'improved' if last < first else 'NOT improved'})")


if __name__ == "__main__":
    main()
