"""End-to-end training driver — a thin wrapper over the ElasticTrainer.

    PYTHONPATH=src python -m repro.launch.train --arch phi4-mini-3.8b \
        --steps 50 --batch 4 --seq 128 --smoke --ckpt-dir /tmp/run1

A single-device run is just the degenerate case of elastic training: a
1-node cluster hosting one supervised Job (repro.elastic).  Everything the
seed driver wired by hand — mesh, sharded state init, auto-resume, async
checkpointing, metrics — is the trainer's segment logic, so this launcher
only resolves configs and shapes.  ``--fail-at`` injects ONE crash at that
step: the supervisor restores from the latest checkpoint and finishes the
run in the same invocation (the seed raised and made you re-run by hand).

Losses stay on device inside the step loop; the host syncs only on the
``log_every`` cadence (the seed's per-step ``float(m["loss"])`` serialized
dispatch — see repro.elastic.trainer).
"""
from __future__ import annotations

import argparse

import jax

from repro.configs import registry
from repro.configs.base import OptimizerConfig
from repro.core.metrics import Registry
from repro.core.orchestrator import Cluster
from repro.data.objectstore import ObjectStore
from repro.elastic import ElasticTrainer, ElasticTrainSpec
from repro.launch.mesh import PRODUCTION_MESH_SHAPE


def train(arch: str, *, steps: int, seq: int, batch: int, smoke: bool,
          ckpt_dir: str = "", ckpt_every: int = 0, fail_at: int = -1,
          log_every: int = 10, production_mesh: bool = False,
          cfg_override=None):
    if cfg_override is not None:
        cfg = cfg_override
        par = registry.get_parallel("phi4-mini-3.8b")   # defaults
        ocfg = OptimizerConfig()
    else:
        cfg = registry.get_smoke(arch) if smoke else registry.get_config(arch)
        par = registry.get_parallel(arch)
        ocfg = registry.get_optimizer(arch)
    ocfg = OptimizerConfig(
        lr=1e-3, warmup_steps=max(steps // 20, 1), decay_steps=steps,
        moment_dtype=ocfg.moment_dtype, second_moment=ocfg.second_moment)

    metrics = Registry()
    cluster = Cluster(devices=jax.devices(), metrics=metrics)
    spec = ElasticTrainSpec(
        cfg, par, ocfg, steps=steps, seq_len=seq, global_batch=batch,
        name=f"train-{arch}",
        base_shape=PRODUCTION_MESH_SHAPE if production_mesh else (1, 1),
        max_data=None if production_mesh else 1,
        ckpt_every=ckpt_every, keep=2, log_every=log_every,
        fail_at=fail_at, seed=0, data_seed=17)
    store = ObjectStore(ckpt_dir) if ckpt_dir else None
    trainer = ElasticTrainer(cluster, spec, store=store, metrics=metrics)
    out = trainer.run()
    return {"losses": out["losses"], "params": out["params"],
            "metrics": metrics, "report": out["report"]}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="phi4-mini-3.8b",
                    choices=list(registry.ARCHS))
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--fail-at", type=int, default=-1,
                    help="inject one crash at this step; the elastic "
                         "supervisor restores and finishes the run")
    args = ap.parse_args()
    out = train(args.arch, steps=args.steps, seq=args.seq, batch=args.batch,
                smoke=args.smoke, ckpt_dir=args.ckpt_dir,
                ckpt_every=args.ckpt_every, fail_at=args.fail_at)
    first, last = out["losses"][0], out["losses"][-1]
    print(f"[train] loss {first:.4f} -> {last:.4f} "
          f"({'improved' if last < first else 'NOT improved'})")


if __name__ == "__main__":
    main()
