import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512"
                           # XLA:CPU's LICM hoists per-layer f32 converts out
                           # of the update scan (whole-tree f32 temps); the
                           # TPU pipeline's memory-aware passes undo such
                           # hoists, so disable it for parity (EXPERIMENTS
                           # §Dry-run discusses the CPU-backend deltas).
                           " --xla_disable_hlo_passes=while-loop-invariant-code-motion"
                           ).strip()
"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
production meshes, with 512 placeholder host devices (set above, BEFORE any
jax import — jax locks the device count on first init).

    PYTHONPATH=src python -m repro.launch.dryrun --arch phi4-mini-3.8b \
        --shape train_4k [--multi-pod] [--collectives]
    PYTHONPATH=src python -m repro.launch.dryrun --all --out experiments/dryrun

Per cell:
  * memory_analysis()  — per-chip bytes (argument/output/temp) proving fit;
  * cost_analysis()    — recorded as-is (NOTE: XLA does not traverse while
    bodies, so scan-hidden flops are undercounted; §Roofline uses the
    analytic accounting in repro.roofline.flops instead);
  * collective bytes   — G-diff method: the same model is built UNROLLED at
    G=1 and G=2 layer-groups; per-group bytes = C(G2)-C(G1), and
    total = C(G1) + (G_full-1) * per_group.  This recovers true trip counts
    from the compiled artifact (repro.roofline.hlo parses operand bytes).
"""
import argparse          # noqa: E402
import dataclasses       # noqa: E402
import json              # noqa: E402
import time              # noqa: E402
import traceback         # noqa: E402
from pathlib import Path  # noqa: E402

import jax               # noqa: E402

from repro.configs import registry  # noqa: E402
from repro.configs.base import SHAPES  # noqa: E402
from repro.launch import cli  # noqa: E402
from repro.launch.mesh import make_production_mesh, mesh_num_chips  # noqa: E402
from repro.roofline import hlo as hlo_mod  # noqa: E402
from repro.runtime import steps as steps_mod  # noqa: E402


def _mem_dict(compiled) -> dict:
    try:
        ma = compiled.memory_analysis()
    except Exception:
        return {}
    keys = ("argument_size_in_bytes", "output_size_in_bytes",
            "temp_size_in_bytes", "generated_code_size_in_bytes",
            "alias_size_in_bytes")
    out = {}
    for k in keys:
        v = getattr(ma, k, None)
        if v is not None:
            out[k] = int(v)
    return out


def per_device_bytes(mem: dict) -> int:
    return (mem.get("argument_size_in_bytes", 0)
            + mem.get("temp_size_in_bytes", 0)
            + mem.get("output_size_in_bytes", 0)
            - mem.get("alias_size_in_bytes", 0))


def _compile_cell(cfg, par, ocfg, mesh, shape):
    bundle = steps_mod.build_step(cfg, par, ocfg, mesh, shape)
    with mesh:
        lowered = bundle.lower()
        compiled = lowered.compile()
    return compiled


def _reduced_cfg(cfg, groups: int):
    L = len(cfg.block_pattern)
    kw = dict(num_layers=L * groups)
    if cfg.family == "audio":
        kw["encoder_layers"] = groups
    return cfg.replace(**kw)


def gdiff_collectives(cfg, par, ocfg, mesh, shape, verbose=True) -> dict:
    """True per-step collective bytes via the G-diff method (see module doc)."""
    par_u = dataclasses.replace(par, scan_layers=False)
    out = {}
    for g in (1, 2):
        compiled = _compile_cell(_reduced_cfg(cfg, g), par_u, ocfg, mesh,
                                 shape)
        out[g] = hlo_mod.collective_bytes(compiled.as_text())
    G = cfg.num_groups if cfg.family != "audio" else cfg.num_layers
    kinds = set(out[1]) | set(out[2])
    # clamp: compile-to-compile fusion noise can make tiny deltas negative
    per_group = {k: max(out[2].get(k, 0) - out[1].get(k, 0), 0)
                 for k in kinds}
    total = {k: out[1].get(k, 0) + (G - 1) * per_group[k] for k in kinds}
    total["total"] = sum(v for k, v in total.items() if k != "total")
    per_group["total"] = sum(v for k, v in per_group.items() if k != "total")
    if verbose:
        print(f"  [gdiff] per-group {per_group.get('total', 0)/2**20:.0f} MiB"
              f" -> step total {total['total']/2**30:.2f} GiB")
    return {"per_group": per_group, "step_total": total, "groups": int(G)}


def run_cell(arch: str, shape_name: str, *, multi_pod: bool,
             par_override=None, opt_override=None, verbose: bool = True,
             collectives: bool = False) -> dict:
    cfg = registry.get_config(arch)
    par = par_override or registry.get_parallel(arch)
    ocfg = opt_override or registry.get_optimizer(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    bundle = steps_mod.build_step(cfg, par, ocfg, mesh, shape)
    with mesh:
        lowered = bundle.lower()
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
    mem = _mem_dict(compiled)
    cost = hlo_mod.xla_cost(compiled)
    text = compiled.as_text()
    coll = hlo_mod.collective_bytes(text)
    counts = hlo_mod.collective_counts(text)
    rec = {
        "arch": arch, "shape": shape_name, "kind": shape.kind,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": mesh_num_chips(mesh),
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "memory": mem, "per_device_bytes": per_device_bytes(mem),
        "xla_flops_per_device": cost.get("flops", 0.0),
        "xla_bytes_accessed": cost.get("bytes accessed", 0.0),
        "module_collective_bytes": coll, "collective_counts": counts,
    }
    if collectives:
        try:
            rec["gdiff"] = gdiff_collectives(cfg, par, ocfg, mesh, shape,
                                             verbose=verbose)
        except Exception as e:
            rec["gdiff_error"] = repr(e)
            if verbose:
                print(f"  [gdiff] FAILED: {e}")
    if verbose:
        print(f"[dryrun] {arch} x {shape_name} on {rec['mesh']}: "
              f"lower {t_lower:.1f}s compile {t_compile:.1f}s "
              f"args {mem.get('argument_size_in_bytes', 0)/2**30:.2f} "
              f"temp {mem.get('temp_size_in_bytes', 0)/2**30:.2f} GiB")
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    # shared flag helper (launch/cli.py): same --arch surface as the
    # train/serve drivers, unrestricted for sweep configs
    cli.add_arch(ap, restrict=False)
    ap.add_argument("--shape", default="train_4k", choices=list(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--collectives", action="store_true",
                    help="measure true collective bytes via G-diff")
    ap.add_argument("--all", action="store_true",
                    help="sweep every assigned (arch x shape) cell")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    out_dir = Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)

    if args.all:
        cells = registry.cells()
    else:
        cells = [(args.arch, SHAPES[args.shape], False)]
    meshes = [False, True] if (args.both_meshes or args.all) else [args.multi_pod]

    failures = []
    for arch, shape, _ in cells:
        for mp in meshes:
            tag = f"{arch}__{shape.name}__{'2x16x16' if mp else '16x16'}"
            path = out_dir / f"{tag}.json"
            if path.exists():
                print(f"[dryrun] skip cached {tag}")
                continue
            try:
                # G-diff only on the single-pod mesh (roofline is single-pod)
                rec = run_cell(arch, shape.name, multi_pod=mp,
                               collectives=args.collectives and not mp)
                path.write_text(json.dumps(rec, indent=1))
            except Exception as e:  # a failure here is a bug in the system
                failures.append((tag, repr(e)))
                print(f"[dryrun] FAIL {tag}: {e}")
                traceback.print_exc()
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for tag, err in failures:
            print(" ", tag, err)
        raise SystemExit(1)
    print("\nall dry-run cells passed")


if __name__ == "__main__":
    main()
