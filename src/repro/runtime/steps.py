"""Step functions (train / prefill / serve) + their sharding trees.

Everything the launcher, dry-run, and tests need to jit a step:
  build_train(cfg, par, ocfg, mesh)   -> StepBundle
  build_prefill(cfg, par, mesh, shape)-> StepBundle
  build_decode(cfg, par, mesh, shape) -> StepBundle

A StepBundle carries the python fn, abstract inputs, and in/out NamedShardings
so ``jax.jit(fn, in_shardings=..., out_shardings=...).lower(*abstract)`` is
one call (see launch/dryrun.py).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import (ModelConfig, OptimizerConfig, ParallelConfig,
                                ShapeConfig)
from repro.models import params as pr
from repro.models import transformer as tfm
from repro.models.layers import ModelCtx
from repro.optim import adamw
from repro.sharding import specs as sh


@dataclass
class StepBundle:
    fn: Callable
    abstract_args: Tuple[Any, ...]
    in_shardings: Tuple[Any, ...]
    out_shardings: Any
    donate_argnums: Tuple[int, ...] = ()
    accum_steps: int = 1      # microbatches folded into one optimizer step
    device_steps: int = 1     # optimizer steps folded into one dispatch

    def jit(self):
        return jax.jit(self.fn, in_shardings=self.in_shardings,
                       out_shardings=self.out_shardings,
                       donate_argnums=self.donate_argnums)

    def lower(self):
        return self.jit().lower(*self.abstract_args)


def _is_axes_leaf(x):
    return isinstance(x, tuple) and all(isinstance(a, (str, type(None))) for a in x)


def _shardings(tree_abstract, tree_axes, mesh, rules):
    return jax.tree.map(
        lambda sds, ax: sh.sharding_for(sds.shape, ax, mesh, rules),
        tree_abstract, tree_axes, is_leaf=lambda x: _is_axes_leaf(x))


def _replicated(tree, mesh):
    return jax.tree.map(lambda _: NamedSharding(mesh, P()), tree)


def _model_module(cfg: ModelConfig):
    if cfg.family == "audio":
        from repro.models import encdec
        return encdec
    return tfm


def resolve_cfg(cfg: ModelConfig, shape: ShapeConfig) -> ModelConfig:
    """Bind shape-dependent stub dims (whisper frame count) into the config."""
    if cfg.family == "audio" and cfg.encoder_frames == 0:
        return cfg.replace(encoder_frames=shape.seq_len)
    return cfg


def token_len(cfg: ModelConfig, shape: ShapeConfig) -> int:
    """Token-sequence length for train/prefill (enc-dec: decoder length)."""
    return cfg.decoder_len if cfg.family == "audio" else shape.seq_len


def batch_specs(cfg: ModelConfig, shape: ShapeConfig):
    """(abstract, axes) for one global training batch."""
    B, S = shape.global_batch, token_len(cfg, shape)
    abstract = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
                "labels": jax.ShapeDtypeStruct((B, S), jnp.int32)}
    axes = {"tokens": ("batch", "seq"), "labels": ("batch", "seq")}
    ex_abs, ex_axes = extras_specs(cfg, B)
    if ex_abs:
        abstract["extras"], axes["extras"] = ex_abs, ex_axes
    return abstract, axes


def extras_specs(cfg: ModelConfig, B: int):
    """Modality-frontend stubs (precomputed embeddings), per DESIGN.md §4."""
    if cfg.family == "vlm":
        return ({"image_embeds": jax.ShapeDtypeStruct(
                    (B, cfg.num_patches, cfg.vision_dim), jnp.bfloat16)},
                {"image_embeds": ("batch", None, None)})
    if cfg.family == "audio":
        return ({"frames": jax.ShapeDtypeStruct(
                    (B, cfg.encoder_frames, cfg.d_model), jnp.bfloat16)},
                {"frames": ("batch", "seq", None)})
    return None, None


# ---------------------------------------------------------------------------
# train
# ---------------------------------------------------------------------------

@dataclass
class _TrainPieces:
    """Shared setup between build_train / build_train_chunk: ONE place
    resolves the config, accumulation plan and shardings, and ONE
    ``train_step`` body is compiled in both — the chunked dispatch is a
    ``lax.scan`` over the *identical* per-step computation, which is what
    makes the losses bit-identical between the two (pinned by
    tests/test_train_hot_loop.py)."""
    train_step: Callable
    abstract_params: Any
    abstract_opt: Any
    param_shd: Any
    opt_shd: Any
    batch_abs: Any
    batch_axes: Any
    batch_shd: Any
    metrics_abs: Any
    mesh: Mesh
    rules: Any
    accum: int


def _train_pieces(cfg: ModelConfig, par: ParallelConfig,
                  ocfg: OptimizerConfig, mesh: Mesh,
                  shape: ShapeConfig, *, loss_attr: str = "loss_fn",
                  batch_fn: Optional[Callable] = None) -> _TrainPieces:
    cfg = resolve_cfg(cfg, shape)
    accum = max(ocfg.accum_steps, 1)
    if shape.global_batch % accum:
        raise ValueError(
            f"accum_steps={accum} must divide global_batch="
            f"{shape.global_batch} (microbatches must be equal-sized "
            f"for grad averaging to equal the full-batch gradient)")
    if par.pure_fsdp_train and not par.pure_fsdp:
        import dataclasses as _dc
        import numpy as _np
        chips = int(_np.prod(list(mesh.shape.values())))
        if shape.global_batch % chips == 0:
            par = _dc.replace(par, pure_fsdp=True)
    mod = _model_module(cfg)
    ctx = ModelCtx(cfg, par, mesh)
    rules = sh.logical_rules(par)
    schema = mod.lm_schema(cfg)
    opt_schema = adamw.opt_state_schema(schema, ocfg)

    abstract_params = pr.abstract_params(schema, cfg.param_dtype)
    abstract_opt = pr.abstract_params(opt_schema, "float32")
    param_shd = sh.shardings_for_schema(schema, mesh, rules)
    opt_shd = sh.shardings_for_schema(opt_schema, mesh, rules)
    batch_abs, batch_axes = (batch_fn or batch_specs)(cfg, shape)
    batch_shd = _shardings(batch_abs, batch_axes, mesh, rules)
    loss_impl = getattr(mod, loss_attr, None)
    if loss_impl is None:
        raise ValueError(
            f"model family {cfg.family!r} does not define {loss_attr!r}")

    def train_step(params, opt_state, batch):
        def loss_of(p, b):
            return loss_impl(ctx, p, b)

        if accum == 1:
            loss, grads = jax.value_and_grad(loss_of)(params, batch)
        else:
            def micro(carry, mb):
                acc_loss, acc_g = carry
                l, g = jax.value_and_grad(loss_of)(params, mb)
                return (acc_loss + l,
                        jax.tree.map(jnp.add, acc_g, g)), None
            micro_b = jax.tree.map(
                lambda x: x.reshape((accum, x.shape[0] // accum) + x.shape[1:]),
                batch)
            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            # strongly-typed f32 loss carry: scan needs identical carry
            # avals, and grads accumulate in f32 regardless of param dtype
            (loss, grads), _ = jax.lax.scan(
                micro, (jnp.zeros((), jnp.float32), zeros), micro_b)
            loss = loss / accum
            grads = jax.tree.map(lambda g: g / accum, grads)

        new_params, new_opt, stats = adamw.apply_updates(
            schema, params, grads, opt_state, ocfg)
        metrics = {"loss": loss.astype(jnp.float32), **stats}
        return new_params, new_opt, metrics

    metrics_abs = {"loss": jax.ShapeDtypeStruct((), jnp.float32),
                   "grad_norm": jax.ShapeDtypeStruct((), jnp.float32),
                   "lr": jax.ShapeDtypeStruct((), jnp.float32)}
    return _TrainPieces(
        train_step=train_step, abstract_params=abstract_params,
        abstract_opt=abstract_opt, param_shd=param_shd, opt_shd=opt_shd,
        batch_abs=batch_abs, batch_axes=batch_axes, batch_shd=batch_shd,
        metrics_abs=metrics_abs, mesh=mesh, rules=rules, accum=accum)


def build_train(cfg: ModelConfig, par: ParallelConfig, ocfg: OptimizerConfig,
                mesh: Mesh, shape: ShapeConfig) -> StepBundle:
    """Build one jitted optimizer step.

    Gradient accumulation contract (``ocfg.accum_steps``): the step always
    consumes the FULL ``shape.global_batch`` rows per call and splits them
    into ``accum_steps`` sequential microbatches inside the jit, so the
    global batch — and therefore the training trajectory — is independent
    of ``accum_steps``.  Elastic rescale (repro.elastic) relies on this:
    shrinking the data axis and raising ``accum_steps`` keeps batch x accum
    constant while bounding per-device microbatch memory.
    """
    tp = _train_pieces(cfg, par, ocfg, mesh, shape)
    return StepBundle(
        fn=tp.train_step,
        abstract_args=(tp.abstract_params, tp.abstract_opt, tp.batch_abs),
        in_shardings=(tp.param_shd, tp.opt_shd, tp.batch_shd),
        out_shardings=(tp.param_shd, tp.opt_shd,
                       _replicated(tp.metrics_abs, mesh)),
        donate_argnums=(0, 1),
        accum_steps=tp.accum,
    )


def chunk_batch_specs(batch_abs, batch_axes, device_steps: int):
    """Stack ``device_steps`` per-step batches along a new leading axis.

    Returns (abstract, axes) trees whose leaves are (K, ...) with an
    unsharded leading axis — the scan dimension of ``build_train_chunk``.
    """
    K = max(device_steps, 1)
    abstract = jax.tree.map(
        lambda a: jax.ShapeDtypeStruct((K,) + a.shape, a.dtype), batch_abs)
    axes = jax.tree.map(lambda ax: (None,) + ax, batch_axes,
                        is_leaf=_is_axes_leaf)
    return abstract, axes


def build_train_chunk(cfg: ModelConfig, par: ParallelConfig,
                      ocfg: OptimizerConfig, mesh: Mesh, shape: ShapeConfig,
                      device_steps: int) -> StepBundle:
    """Build one jitted dispatch of ``device_steps`` optimizer steps.

    The device-resident hot loop: a ``lax.scan`` over K = ``device_steps``
    full optimizer steps (each still folding ``ocfg.accum_steps``
    microbatches) with the (params, opt_state) carry donated and never
    leaving the device.  The host dispatches once per chunk and receives
    per-step metrics stacked (K,), so host round-trips per optimizer step
    drop from O(1) to O(1/device_steps).

    The batch argument is the per-step batch stacked along a new leading
    K axis (see ``chunk_batch_specs`` / ``TokenPipeline.chunk``); each
    scanned step consumes the same FULL ``shape.global_batch`` rows the
    per-step ``build_train`` would, so the training trajectory is
    independent of ``device_steps`` (and bit-identical to per-step
    dispatch — the scan body IS the per-step ``train_step``).
    """
    tp = _train_pieces(cfg, par, ocfg, mesh, shape)
    return _chunk_bundle(tp, device_steps)


def _chunk_bundle(tp: _TrainPieces, device_steps: int) -> StepBundle:
    """Wrap a per-step ``train_step`` into one K-step lax.scan dispatch —
    shared by the supervised and RL chunk builders, so the RL learner
    rides the identical device-resident hot loop."""
    K = max(device_steps, 1)
    mesh = tp.mesh
    chunk_abs, chunk_axes = chunk_batch_specs(tp.batch_abs, tp.batch_axes, K)
    chunk_shd = _shardings(chunk_abs, chunk_axes, mesh, tp.rules)

    def train_chunk(params, opt_state, batches):
        def one(carry, batch):
            p, o = carry
            p, o, m = tp.train_step(p, o, batch)
            return (p, o), m

        (params, opt_state), ms = jax.lax.scan(one, (params, opt_state),
                                               batches)
        return params, opt_state, ms      # metrics leaves stacked (K,)

    chunk_metrics_abs = jax.tree.map(
        lambda a: jax.ShapeDtypeStruct((K,) + a.shape, a.dtype),
        tp.metrics_abs)
    return StepBundle(
        fn=train_chunk,
        abstract_args=(tp.abstract_params, tp.abstract_opt, chunk_abs),
        in_shardings=(tp.param_shd, tp.opt_shd, chunk_shd),
        out_shardings=(tp.param_shd, tp.opt_shd,
                       _replicated(chunk_metrics_abs, mesh)),
        donate_argnums=(0, 1),
        accum_steps=tp.accum,
        device_steps=K,
    )


# ---------------------------------------------------------------------------
# RL policy-gradient train step (repro.rl learner)
# ---------------------------------------------------------------------------

def rl_batch_specs(cfg: ModelConfig, shape: ShapeConfig):
    """(abstract, axes) for one batch of rollout trajectories: the LM
    batch plus a per-token action mask and a per-trajectory advantage."""
    B, S = shape.global_batch, token_len(cfg, shape)
    abstract = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
                "labels": jax.ShapeDtypeStruct((B, S), jnp.int32),
                "mask": jax.ShapeDtypeStruct((B, S), jnp.float32),
                "advantages": jax.ShapeDtypeStruct((B,), jnp.float32)}
    axes = {"tokens": ("batch", "seq"), "labels": ("batch", "seq"),
            "mask": ("batch", "seq"), "advantages": ("batch",)}
    return abstract, axes


def build_rl_train_chunk(cfg: ModelConfig, par: ParallelConfig,
                         ocfg: OptimizerConfig, mesh: Mesh,
                         shape: ShapeConfig, device_steps: int) -> StepBundle:
    """The RL learner's fused dispatch: ``device_steps`` advantage-weighted
    policy-gradient optimizer steps in one ``lax.scan``, (params, opt)
    carry donated and device-resident — structurally identical to
    ``build_train_chunk`` (same AdamW update, same donation, same (K,)
    stacked metrics), differing only in the loss (``mod.rl_loss_fn``)
    and the batch schema (``rl_batch_specs``: + mask, + advantages)."""
    tp = _train_pieces(cfg, par, ocfg, mesh, shape,
                       loss_attr="rl_loss_fn", batch_fn=rl_batch_specs)
    return _chunk_bundle(tp, device_steps)


# ---------------------------------------------------------------------------
# prefill / decode
# ---------------------------------------------------------------------------

def build_prefill(cfg: ModelConfig, par: ParallelConfig, mesh: Mesh,
                  shape: ShapeConfig) -> StepBundle:
    cfg = resolve_cfg(cfg, shape)
    mod = _model_module(cfg)
    ctx = ModelCtx(cfg, par, mesh)
    rules = sh.logical_rules(par)
    schema = mod.lm_schema(cfg)
    B, S = shape.global_batch, shape.seq_len
    T = token_len(cfg, shape)

    abstract_params = pr.abstract_params(schema, cfg.param_dtype)
    param_shd = sh.shardings_for_schema(schema, mesh, rules)
    tok_abs = jax.ShapeDtypeStruct((B, T), jnp.int32)
    tok_shd = sh.sharding_for((B, T), ("batch", "seq"), mesh, rules)
    cache_schema = mod.cache_schema(cfg, B, S)
    cache_shd = sh.shardings_for_schema(cache_schema, mesh, rules)
    ex_abs, ex_axes = extras_specs(cfg, B)
    extra_args, extra_shd = ((ex_abs,), (_shardings(ex_abs, ex_axes, mesh, rules),)) \
        if ex_abs else ((), ())

    def prefill_step(params, tokens, *extras):
        ex = extras[0] if extras else None
        hidden, caches, _ = mod.forward(ctx, params, tokens, mode="prefill",
                                        extras=ex)
        # unembed only the last position: (B,1,V), not (B,S,V)
        last = mod.lm_logits(ctx, params, hidden[:, -1:, :])[:, 0, :]
        return last, caches

    last_shd = sh.sharding_for((B, cfg.vocab_size), ("batch", "act_vocab"),
                               mesh, rules)
    return StepBundle(
        fn=prefill_step,
        abstract_args=(abstract_params, tok_abs) + extra_args,
        in_shardings=(param_shd, tok_shd) + extra_shd,
        out_shardings=(last_shd, cache_shd),
    )


def build_decode(cfg: ModelConfig, par: ParallelConfig, mesh: Mesh,
                 shape: ShapeConfig, *, per_slot: bool = False) -> StepBundle:
    """One fused greedy decode step over the whole batch.

    ``per_slot=False``: classic whole-batch decode — every row sits at the
    same scalar position ``pos`` (the static drain-then-refill server).

    ``per_slot=True``: continuous-batching decode — ``pos`` is a (B,) int32
    vector, one sequence position per slot.  Cache writes, RoPE and the
    causal mask are all per-row, so a single jitted step advances B
    *independent* requests with no inter-request barrier (repro.serving).
    """
    cfg = resolve_cfg(cfg, shape)
    mod = _model_module(cfg)
    ctx = ModelCtx(cfg, par, mesh)
    rules = sh.logical_rules(par)
    schema = mod.lm_schema(cfg)
    B, S = shape.global_batch, shape.seq_len

    abstract_params = pr.abstract_params(schema, cfg.param_dtype)
    param_shd = sh.shardings_for_schema(schema, mesh, rules)
    cache_schema = mod.cache_schema(cfg, B, S)
    abstract_cache = pr.abstract_params(cache_schema, cfg.param_dtype)
    cache_shd = sh.shardings_for_schema(cache_schema, mesh, rules)
    tok_abs = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    tok_shd = sh.sharding_for((B, 1), ("batch", None), mesh, rules)
    pos_abs = jax.ShapeDtypeStruct((B,) if per_slot else (), jnp.int32)
    pos_shd = NamedSharding(mesh, P())

    def serve_step(params, caches, token, pos):
        hidden, new_caches, _ = mod.forward(ctx, params, token, mode="decode",
                                            caches=caches, pos=pos)
        logits = mod.lm_logits(ctx, params, hidden)
        next_tok = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
        return next_tok[:, None], new_caches

    return StepBundle(
        fn=serve_step,
        abstract_args=(abstract_params, abstract_cache, tok_abs, pos_abs),
        in_shardings=(param_shd, cache_shd, tok_shd, pos_shd),
        out_shardings=(tok_shd, cache_shd),
        donate_argnums=(1,),
    )


def build_slot_decode(cfg: ModelConfig, par: ParallelConfig, mesh: Mesh,
                      shape: ShapeConfig) -> StepBundle:
    """Continuous-batching decode step (see build_decode per_slot=True)."""
    return build_decode(cfg, par, mesh, shape, per_slot=True)


# ---------------------------------------------------------------------------
# slotted KV/state cache: allocation + slot insert/evict
# ---------------------------------------------------------------------------
# Every cache leaf in the repo is laid out (layers, batch, ...), so a "slot"
# is index ``i`` of axis 1 across the whole cache pytree — attention K/V,
# mamba conv/state, rwkv state and encdec self/cross caches alike.

CACHE_BATCH_AXIS = 1


def init_cache(cfg: ModelConfig, B: int, S: int):
    """Allocate an all-zeros decode cache for B slots of S positions."""
    mod = _model_module(cfg)
    abstract = pr.abstract_params(mod.cache_schema(cfg, B, S), cfg.param_dtype)
    return jax.tree.map(lambda a: jnp.zeros(a.shape, a.dtype), abstract)


def cache_batch_insert(dst, src, slot):
    """Copy a 1-slot cache pytree ``src`` into slot ``slot`` of ``dst``.

    ``src`` leaves may have a shorter sequence axis than ``dst`` (a prefill
    cache covers only the prompt); the tail of the slot is left as-is and
    relies on the decode-position mask to stay invisible.  Pure function —
    jit it with ``donate_argnums=0`` so refills are in-place.
    """
    slot = jnp.asarray(slot, jnp.int32)

    def ins(d, s):
        start = (jnp.int32(0), slot) + (jnp.int32(0),) * (d.ndim - 2)
        return jax.lax.dynamic_update_slice(d, s.astype(d.dtype), start)

    return jax.tree.map(ins, dst, src)


def cache_prefix_insert(dst, src):
    """Copy a short-sequence cache pytree into the front of a longer one.

    Prefill emits caches whose sequence axis covers only the prompt;
    decode needs headroom for the generated tokens.  (The seed's static
    server skipped this and decoded against the prompt-length cache, so
    every generated token's K/V write clamped onto the last prompt slot —
    generations were invisible to attention.)
    """
    def ins(d, s):
        start = (jnp.int32(0),) * d.ndim
        return jax.lax.dynamic_update_slice(d, s.astype(d.dtype), start)

    return jax.tree.map(ins, dst, src)


def cache_batch_evict(dst, slot):
    """Zero out one slot (hygiene on eviction; correctness never needs it —
    the next insert overwrites the prompt prefix and masks hide the rest)."""
    slot = jnp.asarray(slot, jnp.int32)

    def ev(d):
        z = jnp.zeros((d.shape[0], 1) + d.shape[2:], d.dtype)
        start = (jnp.int32(0), slot) + (jnp.int32(0),) * (d.ndim - 2)
        return jax.lax.dynamic_update_slice(d, z, start)

    return jax.tree.map(ev, dst)


# ---------------------------------------------------------------------------
# paged KV pool: block tables + gather/scatter decode addressing
# ---------------------------------------------------------------------------
# The slotted cache above dedicates S positions to every slot whether the
# request uses them or not; the paged layout replaces axis 1 (slots) with a
# shared pool of fixed-size blocks — leaf shape (layers, num_blocks,
# block_size, ...) — addressed through per-slot block tables (B, S//bs).
# Block 0 is the NULL block: table entries for not-yet-allocated tail
# positions point at it, and out-of-range scatter writes are clamped onto
# it, so its contents are garbage-but-finite — which the decode mask turns
# into an exact 0.0 contribution (exp(-1e30 - m) == 0.0 in f32), keeping
# paged decode bit-identical to the slotted baseline.

def paged_compatible(cfg: ModelConfig, S: int, block_size: int) -> bool:
    """True iff every cache leaf is a (layers, batch, cache_seq, ...) KV
    layout whose sequence axis is exactly S and divisible into blocks.
    SSM/RWKV state caches and enc-dec cross caches are not paged-able;
    callers fall back to the slotted cache."""
    if block_size < 1 or S % block_size:
        return False
    mod = _model_module(cfg)
    flags = []
    pr.tree_map_schema(
        lambda path, ps: flags.append(
            len(ps.axes) >= 3 and ps.axes[2] == "cache_seq"
            and ps.shape[2] == S),
        mod.cache_schema(cfg, 1, S))
    return bool(flags) and all(flags)


def init_paged_cache(cfg: ModelConfig, num_blocks: int, block_size: int):
    """Allocate an all-zeros block pool: the cache schema instantiated with
    batch=num_blocks, seq=block_size gives exactly the pool leaf layout
    (layers, num_blocks, block_size, ...)."""
    mod = _model_module(cfg)
    abstract = pr.abstract_params(
        mod.cache_schema(cfg, num_blocks, block_size), cfg.param_dtype)
    return jax.tree.map(lambda a: jnp.zeros(a.shape, a.dtype), abstract)


def paged_cache_view(pool, tables):
    """Gather each slot's blocks into a contiguous (layers, B, S, ...) view
    value-identical to the slotted cache — the decode forward runs on it
    unchanged.  ``tables`` is (B, S // block_size) int32 block ids."""
    def gather(leaf):
        g = leaf[:, tables]                       # (G, B, nb, bs, *tail)
        return g.reshape(g.shape[0], g.shape[1], g.shape[2] * g.shape[3],
                         *g.shape[4:])
    return jax.tree.map(gather, pool)


def paged_cache_scatter(pool, views, tables, pos):
    """Write back the one row per slot that the decode step mutated.

    ``views`` is the post-forward gathered cache; slot i wrote position
    ``pos[i]``.  Rows whose position is out of range (free slots parked at
    0 with an all-null table, or finished slots past S-1) land on the null
    block, where duplicate writes are harmless by the masking argument
    above."""
    B = tables.shape[0]

    def scat(pleaf, vleaf):
        bs = pleaf.shape[2]
        S = vleaf.shape[2]
        rows = vleaf[:, jnp.arange(B), pos]       # (G, B, *tail)
        blk = jnp.take_along_axis(tables, (pos // bs)[:, None], axis=1)[:, 0]
        blk = jnp.where(pos < S, blk, 0)
        return pleaf.at[:, blk, pos % bs].set(rows.astype(pleaf.dtype))

    return jax.tree.map(scat, pool, views)


def paged_prompt_insert(pool, src, blocks):
    """Splice a B=1 prefill cache (leaves (layers, 1, P, ...)) into the
    pool at the given (P // block_size,) distinct block ids."""
    def ins(pleaf, sleaf):
        bs = pleaf.shape[2]
        tail = sleaf.shape[3:]
        nb = sleaf.shape[2] // bs
        chunks = sleaf[:, 0].reshape(sleaf.shape[0], nb, bs, *tail)
        return pleaf.at[:, blocks].set(chunks.astype(pleaf.dtype))

    return jax.tree.map(ins, pool, src)


def build_paged_decode(cfg: ModelConfig, par: ParallelConfig, mesh: Mesh,
                       shape: ShapeConfig, *, block_size: int,
                       num_blocks: int) -> StepBundle:
    """One fused per-slot decode step against the paged pool:
    gather block-table views -> identical forward -> scatter the written
    row back.  Signature: (params, pool, tables, token, pos) ->
    (next_token, pool); donate the pool for in-place updates."""
    cfg = resolve_cfg(cfg, shape)
    mod = _model_module(cfg)
    ctx = ModelCtx(cfg, par, mesh)
    rules = sh.logical_rules(par)
    schema = mod.lm_schema(cfg)
    B, S = shape.global_batch, shape.seq_len
    if not paged_compatible(cfg, S, block_size):
        raise ValueError(f"{cfg.family} cache is not paged-compatible "
                         f"for S={S}, block_size={block_size}")

    abstract_params = pr.abstract_params(schema, cfg.param_dtype)
    param_shd = sh.shardings_for_schema(schema, mesh, rules)
    pool_schema = mod.cache_schema(cfg, num_blocks, block_size)
    abstract_pool = pr.abstract_params(pool_schema, cfg.param_dtype)
    # the block axis is an arbitrary permutation of slots x positions —
    # keep it (and the intra-block axis) unsharded; heads/layers shard as
    # in the slotted cache
    pool_shd = pr.tree_map_schema(
        lambda path, ps: sh.sharding_for(
            ps.shape, (ps.axes[0], None, None) + tuple(ps.axes[3:]),
            mesh, rules),
        pool_schema)
    nb = S // block_size
    tab_abs = jax.ShapeDtypeStruct((B, nb), jnp.int32)
    tok_abs = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    pos_abs = jax.ShapeDtypeStruct((B,), jnp.int32)
    tok_shd = sh.sharding_for((B, 1), ("batch", None), mesh, rules)
    repl = NamedSharding(mesh, P())

    def paged_step(params, pool, tables, token, pos):
        views = paged_cache_view(pool, tables)
        hidden, new_views, _ = mod.forward(ctx, params, token, mode="decode",
                                           caches=views, pos=pos)
        logits = mod.lm_logits(ctx, params, hidden)
        next_tok = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
        pool = paged_cache_scatter(pool, new_views, tables, pos)
        return next_tok[:, None], pool

    return StepBundle(
        fn=paged_step,
        abstract_args=(abstract_params, abstract_pool, tab_abs, tok_abs,
                       pos_abs),
        in_shardings=(param_shd, pool_shd, repl, tok_shd, repl),
        out_shardings=(tok_shd, pool_shd),
        donate_argnums=(1,),
    )


def build_step(cfg, par, ocfg, mesh, shape: ShapeConfig) -> StepBundle:
    if shape.kind == "train":
        return build_train(cfg, par, ocfg, mesh, shape)
    if shape.kind == "prefill":
        return build_prefill(cfg, par, mesh, shape)
    return build_decode(cfg, par, mesh, shape)
