"""Versioned policy weight broadcast through the (federated) store.

The learner *publishes* — it never talks to an actor.  Each publish is
one committed version under ``<prefix>/policy``; actors *poll* the
latest version between rollout waves and pull-on-bump.  Both halves are
a thin veneer over ``repro.checkpoint.Checkpointer`` (version == step),
which already provides the properties a weight broadcast needs:

  * **atomic commit** — per-leaf shards first, manifest last, so a
    reader never observes a half-published version;
  * **store agnosticism** — any ``BlobCodecs`` store works: a plain
    ``ObjectStore`` on one host, or a ``FederatedStore`` site view, in
    which case a publisher at the learner's site and fetchers holding
    *their own site's* view turn every pull into a metered (and
    tenant-billed) cross-link replication — the content-addressed
    broadcast of the RLJob design;
  * **GC** — ``keep`` bounds live versions; a reader that loses the GC
    race retries on whatever is newest (``restore_latest`` semantics).

Version numbers are dense ints starting at 0 (the actors' initial
weights, seeded identically from the job seed, count as version 0 and
are never published).
"""
from __future__ import annotations

from typing import Any, Optional

from repro.checkpoint.checkpoint import Checkpointer


class PolicyStore:
    """Publish/fetch versioned policy params over a BlobCodecs store."""

    def __init__(self, store, *, prefix: str = "policy", keep: int = 3,
                 registry=None):
        self.ckpt = Checkpointer(store, prefix=prefix, keep=keep)
        self.metrics = registry

    # --------------------------------------------------------------- learner
    def publish(self, version: int, params: Any, *, step: int = 0) -> None:
        """Commit one new weight version (atomic: manifest lands last)."""
        # NB: restore_latest merges ``extra`` over {"step": version}, so
        # the learner step rides under its own key
        self.ckpt.save(version, {"params": params},
                       extra={"learner_step": step})
        if self.metrics is not None:
            self.metrics.inc("rl/weights_published")
            self.metrics.gauge("rl/policy_version", version)

    # ---------------------------------------------------------------- actors
    def latest_version(self) -> int:
        """Newest committed version, or -1 when nothing was published."""
        v = self.ckpt.latest_step()
        return -1 if v is None else v

    def fetch(self, abstract_params: Any, shardings: Optional[Any] = None):
        """Pull the newest committed version -> (params, version).

        Returns (None, -1) when nothing was published yet.  Fetching
        through a FederatedStore site view replicates the shards to the
        caller's site — the metered broadcast hop."""
        restored, meta = self.ckpt.restore_latest(
            {"params": abstract_params}, shardings)
        if restored is None:
            return None, -1
        if self.metrics is not None:
            self.metrics.inc("rl/weight_syncs")
        return restored["params"], int(meta["step"])
