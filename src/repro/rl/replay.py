"""Rollout replay queue — trajectories from actors to the learner.

The distributed-RL data plane is two ``core.queue.WorkQueue`` leases
deep:

  * **tickets** (built by :func:`ticket_queue`): rollout *requests* the
    whole actor fleet leases from one shared queue.  A killed actor's
    in-flight tickets are nacked by its engine's stop path (or reclaimed
    at lease expiry) and picked up by the surviving actors — actor
    preemption loses zero trajectories by construction;
  * **trajectories** (:class:`RolloutQueue`): finished rollouts pushed
    by actors and drained in leased batches by the learner, with
    renewal heartbeats while a batch is being trained on.  A learner
    that dies stops renewing and its batch requeues one timeout later.

Every trajectory carries the ``policy_version`` the generating actor
held; the learner consumes through :meth:`RolloutQueue.take_fresh`,
which acks-and-drops (never trains on) rollouts staler than
``max_policy_lag`` versions, metering them separately — the bounded
staleness contract of the RLJob.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

from repro.core.queue import WorkQueue


@dataclass(frozen=True)
class Trajectory:
    """One finished rollout.  JSON-able (snapshots ride in checkpoint
    manifests), so token streams are plain int lists."""
    ticket: Any                  # the ticket id this rollout answered
    prompt: Tuple[int, ...]
    tokens: Tuple[int, ...]      # generated (action) tokens
    reward: float
    policy_version: int          # weights the actor held when generating
    actor: str = ""

    def to_item(self) -> dict:
        # int() coercion: generated tokens may arrive as numpy scalars,
        # and items must stay JSON-able for checkpoint-manifest snapshots
        return {"ticket": self.ticket,
                "prompt": [int(t) for t in self.prompt],
                "tokens": [int(t) for t in self.tokens],
                "reward": float(self.reward),
                "policy_version": int(self.policy_version),
                "actor": self.actor}

    @classmethod
    def from_item(cls, d: dict) -> "Trajectory":
        return cls(ticket=d["ticket"], prompt=tuple(d["prompt"]),
                   tokens=tuple(d["tokens"]), reward=float(d["reward"]),
                   policy_version=int(d["policy_version"]),
                   actor=d.get("actor", ""))


def is_stale(policy_version: int, current_version: int,
             max_policy_lag: int) -> bool:
    """The staleness predicate: a rollout generated at ``policy_version``
    may train against learner weights at ``current_version`` iff the
    version gap is <= ``max_policy_lag``."""
    return current_version - policy_version > max_policy_lag


def split_stale(trajs, current_version: int, max_policy_lag: int):
    """Partition trajectories into (fresh, stale) against the bound."""
    fresh = [t for t in trajs
             if not is_stale(t.policy_version, current_version,
                             max_policy_lag)]
    stale = [t for t in trajs
             if is_stale(t.policy_version, current_version, max_policy_lag)]
    return fresh, stale


def ticket_queue(*, lease_timeout: float = 30.0, max_attempts: int = 10,
                 clock: Callable[[], float] = time.monotonic) -> WorkQueue:
    """The shared rollout-request queue the actor fleet serves from."""
    return WorkQueue(lease_timeout=lease_timeout, max_attempts=max_attempts,
                     clock=clock)


class RolloutQueue:
    """Lease-heartbeat trajectory buffer between the actor fleet and the
    learner, with the staleness filter and its accounting built in."""

    def __init__(self, *, lease_timeout: float = 30.0, max_attempts: int = 5,
                 registry=None, clock: Callable[[], float] = time.monotonic):
        self.q = WorkQueue(lease_timeout=lease_timeout,
                           max_attempts=max_attempts, clock=clock)
        self.metrics = registry
        self.pushed = 0
        self.stale_dropped = 0
        self.trained = 0
        self.lag_trained: List[int] = []   # version lag of every trained rollout

    # ---------------------------------------------------------------- actors
    def push(self, traj: Trajectory) -> int:
        tid = self.q.put(traj.to_item())
        self.pushed += 1
        if self.metrics is not None:
            self.metrics.inc("rl/rollouts_enqueued")
            self.metrics.inc("rl/rollout_tokens", len(traj.tokens))
        return tid

    # --------------------------------------------------------------- learner
    def take_fresh(self, n: int, *, worker: str, current_version: int,
                   max_policy_lag: int) -> List[Tuple[int, Trajectory]]:
        """Lease up to ``n`` trainable trajectories.

        Stale rollouts (version gap > ``max_policy_lag``) are acked and
        DROPPED — consumed so they never block the queue, but metered on
        ``rl/stale_dropped`` instead of ever reaching a gradient.
        Returns [(task_id, Trajectory)]; the caller acks via
        :meth:`ack_trained` after the optimizer step lands (at-least-once:
        a learner death before the ack requeues the batch)."""
        out: List[Tuple[int, Trajectory]] = []
        while len(out) < n:
            got = self.q.lease(worker)
            if got is None:
                break
            tid, item = got
            traj = Trajectory.from_item(item)
            if is_stale(traj.policy_version, current_version, max_policy_lag):
                self.q.ack(tid, worker)
                self.stale_dropped += 1
                if self.metrics is not None:
                    self.metrics.inc("rl/stale_dropped")
                continue
            out.append((tid, traj))
        return out

    def renew(self, held: List[Tuple[int, Trajectory]], *, worker: str):
        """Heartbeat the leases on a batch still being accumulated or
        trained on (a compile can outlive any fixed visibility timeout)."""
        for tid, _ in held:
            self.q.renew(tid, worker)

    def ack_trained(self, held: List[Tuple[int, Trajectory]], *,
                    worker: str, current_version: int):
        """Complete a trained-on batch and record its version lag."""
        for tid, traj in held:
            if self.q.ack(tid, worker):
                self.trained += 1
                lag = current_version - traj.policy_version
                self.lag_trained.append(lag)
                if self.metrics is not None:
                    self.metrics.inc("rl/trained_rollouts")
                    self.metrics.gauge("rl/policy_lag", lag)

    def release(self, held: List[Tuple[int, Trajectory]], *, worker: str):
        """Return an untrained batch early (learner preempted mid-drain)."""
        for tid, _ in held:
            self.q.nack(tid, worker)

    # --------------------------------------------------------------- inspect
    @property
    def pending(self) -> int:
        return self.q.pending

    def max_lag_trained(self) -> int:
        return max(self.lag_trained, default=0)

    # ------------------------------------------------------------ checkpoint
    def snapshot(self) -> dict:
        """Queue state + staleness accounting; rides in the learner's
        checkpoint ``extra`` so a preempted learner resumes with the
        rollout buffer (and its audit trail) intact."""
        return {"queue": self.q.snapshot(),
                "pushed": self.pushed,
                "stale_dropped": self.stale_dropped,
                "trained": self.trained,
                "lag_trained": list(self.lag_trained)}

    def restore(self, snap: dict, *,
                clock: Callable[[], float] = time.monotonic) -> None:
        """Rebuild in place from a snapshot (leases do not survive, so
        every in-flight trajectory returns to pending — at-least-once)."""
        self.q = WorkQueue.restore(snap["queue"], clock=clock)
        self.pushed = int(snap.get("pushed", 0))
        self.stale_dropped = int(snap.get("stale_dropped", 0))
        self.trained = int(snap.get("trained", 0))
        self.lag_trained = list(snap.get("lag_trained", ()))
