"""Distributed RL workload: a serving-plane actor fleet feeding an
elastic policy-gradient learner over the federated store.

The first workload that exercises serving + training + data plane +
tenancy simultaneously:

  * actors  — ``ServingEngine`` replicas (continuous batching, paged KV)
              leasing rollout tickets from one shared ``WorkQueue``;
  * replay  — a lease-heartbeat ``RolloutQueue`` of version-stamped
              trajectories (staleness-bounded by ``max_policy_lag``);
  * learner — the chunked-scan hot loop with the advantage-weighted
              policy-gradient loss, checkpoint/resume elastic;
  * weights — versioned ``PolicyStore`` broadcast (publish atomically,
              actors pull-on-version-bump; federated = metered pulls).

Declared through the unified API as an ``RLJob`` (docs/rl.md).
"""
from repro.rl.actor import ActorFleet, RolloutActor, default_reward
from repro.rl.learner import (InjectedLearnerFailure, RLLearner,
                              RLLearnerSpec, RLRunReport)
from repro.rl.replay import (RolloutQueue, Trajectory, is_stale, split_stale,
                             ticket_queue)
from repro.rl.weights import PolicyStore

__all__ = [
    "ActorFleet", "RolloutActor", "default_reward",
    "InjectedLearnerFailure", "RLLearner", "RLLearnerSpec", "RLRunReport",
    "RolloutQueue", "Trajectory", "is_stale", "split_stale", "ticket_queue",
    "PolicyStore",
]
