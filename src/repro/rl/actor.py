"""Rollout actors — the serving plane of the RL workload.

Each :class:`RolloutActor` owns one continuous-batching
``ServingEngine`` (paged KV when the model family supports it) and
serves rollout *tickets* from the fleet-shared ticket queue in waves:
between waves it polls the :class:`~repro.rl.weights.PolicyStore` and
pulls-on-version-bump (hot-swapping ``engine.params`` — the engine
threads weights through every fused step, so the next prefill decodes
under the new policy), then drains the shared queue with continuous
batching, scores each completion with the reward function, and pushes
version-stamped trajectories into the learner's
:class:`~repro.rl.replay.RolloutQueue`.

Preemption tolerance is inherited, not bolted on: a killed actor's
engine nacks its in-flight ticket leases on the stop path (and a hard
crash is reclaimed at lease expiry), so surviving actors lease the same
tickets from the shared queue and finish them — no trajectory is lost.
:class:`ActorFleet` turns that into elasticity: fleet width moves
through a ``capacity`` gate (``FairShareScheduler.resize_claim`` when
running as a tenant), and ``kill()`` is the chaos hook the RLJob
acceptance injects.
"""
from __future__ import annotations

import threading
import time
from typing import Any, Callable, Dict, List, Optional

from repro.core.queue import WorkQueue
from repro.models import params as pr
from repro.rl.replay import RolloutQueue, Trajectory
from repro.rl.weights import PolicyStore
from repro.runtime import steps as steps_mod


def default_reward(prompt, tokens) -> float:
    """Deterministic synthetic reward: distinct-token fraction of the
    generation (a proxy for non-degenerate output; no external judge in
    a single-container run)."""
    return len(set(tokens)) / max(len(tokens), 1)


class RolloutActor:
    """One serving replica generating trajectories in waves."""

    def __init__(self, name: str, engine, tickets: WorkQueue,
                 rollouts: RolloutQueue, policies: PolicyStore, *,
                 prompts: Dict[Any, List[int]],
                 reward_fn: Callable = default_reward,
                 shardings: Optional[Any] = None,
                 registry=None, poll_s: float = 2e-3):
        self.name = name
        self.engine = engine
        self.tickets = tickets
        self.rollouts = rollouts
        self.policies = policies
        self.prompts = prompts          # ticket rid -> prompt tokens (shared)
        self.reward_fn = reward_fn
        self.shardings = shardings
        self.metrics = registry
        self.poll_s = poll_s
        self.version = 0                # initial seeded weights = version 0
        self.syncs = 0                  # observed weight-version bumps
        self.completed = 0
        self._stop = threading.Event()
        mod = steps_mod._model_module(engine.cfg)
        self._abstract = pr.abstract_params(mod.lm_schema(engine.cfg),
                                            engine.cfg.param_dtype)

    # ------------------------------------------------------------ weight sync
    def maybe_sync(self) -> bool:
        """Pull-on-version-bump: swap ``engine.params`` iff the store
        advertises a newer committed version than the one held."""
        latest = self.policies.latest_version()
        if latest <= self.version:
            return False
        params, got = self.policies.fetch(self._abstract, self.shardings)
        if params is None or got <= self.version:
            return False
        self.engine.params = params
        self.version = got
        self.syncs += 1
        if self.metrics is not None:
            self.metrics.gauge(f"rl/actor/{self.name}/version", got)
        return True

    # ------------------------------------------------------------------ waves
    def run(self) -> None:
        """Serve until stopped: sync weights, drain the shared ticket
        queue with continuous batching, push scored trajectories."""
        while not self._stop.is_set():
            self.maybe_sync()
            if self.tickets.pending == 0:
                time.sleep(self.poll_s)
                continue
            version = self.version
            results, _ = self.engine.run(
                self.tickets, worker=self.name,
                should_stop=self._stop.is_set, exit_on_drain=True)
            for rid, toks in results.items():
                prompt = self.prompts.get(rid, [])
                self.rollouts.push(Trajectory(
                    ticket=rid, prompt=tuple(prompt), tokens=tuple(toks),
                    reward=self.reward_fn(prompt, toks),
                    policy_version=version, actor=self.name))
                self.completed += 1

    def stop(self) -> None:
        """Cooperative kill: the engine's stop path nacks in-flight
        ticket leases back to the shared queue for the survivors."""
        self._stop.set()

    @property
    def stopped(self) -> bool:
        return self._stop.is_set()


class ActorFleet:
    """Elastic-width fleet of rollout actors.

    ``make_actor(name)`` builds (and compiles) one actor; ``capacity``
    gates desired width to granted width — under a tenant this is
    ``resize_claim`` on the actor tenant's capacity claim, so the fleet
    only ever runs as wide as the fair-share scheduler allows."""

    def __init__(self, make_actor: Callable[[str], RolloutActor], *,
                 width: int, capacity: Optional[Callable[[int], int]] = None,
                 registry=None, name: str = "actor"):
        self.make_actor = make_actor
        self.capacity = capacity
        self.metrics = registry
        self.name = name
        self.desired = width
        self._n_spawned = 0
        self._actors: Dict[str, RolloutActor] = {}
        self._threads: Dict[str, threading.Thread] = {}
        self.resize_events: List[Dict[str, int]] = []

    # -------------------------------------------------------------- lifecycle
    def start(self) -> int:
        return self.resize(self.desired)

    def _spawn(self) -> str:
        name = f"{self.name}-{self._n_spawned}"
        self._n_spawned += 1
        actor = self.make_actor(name)
        t = threading.Thread(target=actor.run, name=name, daemon=True)
        self._actors[name] = actor
        self._threads[name] = t
        t.start()
        return name

    def resize(self, want: int) -> int:
        """Grow/shrink toward ``want``, clamped by the capacity gate.
        Returns the granted width."""
        granted = self.capacity(want) if self.capacity else want
        while self.width < granted:
            self._spawn()
        while self.width > granted:
            # shrink from the newest actor; its engine nacks in-flight
            name = sorted(self.alive())[-1]
            self._actors[name].stop()
            self._join(name)
        self.resize_events.append({"want": want, "granted": granted})
        if self.metrics is not None:
            self.metrics.gauge("rl/actors", self.width)
        return granted

    def kill(self, name: str, *, join: bool = True) -> None:
        """Chaos hook: stop one actor mid-wave (its leases requeue)."""
        self._actors[name].stop()
        if join:
            self._join(name)

    def _join(self, name: str) -> None:
        t = self._threads.pop(name, None)
        if t is not None:
            t.join(timeout=60.0)

    def stop_all(self) -> None:
        for a in self._actors.values():
            a.stop()
        for name in list(self._threads):
            self._join(name)

    # ---------------------------------------------------------------- inspect
    def alive(self) -> List[str]:
        return [n for n, a in self._actors.items() if not a.stopped]

    @property
    def width(self) -> int:
        return len(self.alive())

    @property
    def actors(self) -> Dict[str, RolloutActor]:
        return dict(self._actors)

    def min_syncs(self) -> int:
        """Weight-version bumps observed by the least-synced actor that
        is still alive (the acceptance wants >= 1 across the fleet)."""
        alive = [self._actors[n] for n in self.alive()]
        return min((a.syncs for a in alive), default=0)
