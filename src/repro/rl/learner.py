"""The RL learner — elastic policy-gradient training off the rollout queue.

One :class:`RLLearner` drains trajectory batches from the
:class:`~repro.rl.replay.RolloutQueue` (lease + heartbeat, staleness
filter applied at the queue), encodes them into advantage-weighted LM
batches, and dispatches fused chunks through
``runtime.steps.build_rl_train_chunk`` — the same device-resident
``lax.scan`` hot loop (donated carry, (K,)-stacked metrics, AdamW) the
supervised trainer runs, with the policy-gradient loss swapped in.

Elasticity mirrors ``repro.elastic.ElasticTrainer``'s segment contract:

  * periodic checkpoints every ``ckpt_every`` steps (snapped up to chunk
    granularity) carry (params, opt) plus the rollout queue snapshot and
    the current policy version in ``extra``;
  * ``run()`` is ONE resumable segment: restore-or-init, train until
    done / preempted / crashed.  Under a tenant it IS the preemptible
    pod body — the fair-share scheduler's checkpoint-then-evict sets
    ``should_stop``, the segment goodbye-saves and returns, the whole
    job requeues, and the next placement restores and continues;
  * ``run_supervised()`` adds the crash loop: an injected hard failure
    (``fail_at``, no goodbye save) loses at most the steps since the
    last periodic checkpoint — ``steps_lost <= ckpt_every`` is the
    acceptance bound, accounted in :class:`RLRunReport`;
  * every ``broadcast_every`` steps the learner publishes a new weight
    version through the :class:`~repro.rl.weights.PolicyStore` — the
    actors' pull-on-bump broadcast.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

import jax
import numpy as np

from repro.checkpoint.checkpoint import Checkpointer
from repro.configs.base import (ModelConfig, OptimizerConfig, ParallelConfig,
                                ShapeConfig)
from repro.elastic.trainer import chunk_schedule, snap_cadence
from repro.models import params as pr
from repro.optim import adamw
from repro.rl.replay import RolloutQueue, Trajectory
from repro.rl.weights import PolicyStore
from repro.runtime import steps as steps_mod


class InjectedLearnerFailure(RuntimeError):
    """The deterministic hard-crash used by tests/benchmarks: raised
    AFTER a step completes, WITHOUT a goodbye save, so the resume path
    pays the real restore-from-periodic-checkpoint cost."""


@dataclass
class RLLearnerSpec:
    cfg: ModelConfig
    par: ParallelConfig
    ocfg: OptimizerConfig
    steps: int
    seq_len: int                 # prompt_pad + max_new_tokens (S)
    batch: int                   # trajectories per optimizer step (B)
    device_steps: int = 1        # optimizer steps fused per dispatch (K)
    ckpt_every: int = 2
    broadcast_every: int = 2
    max_policy_lag: int = 2
    seed: int = 0
    keep: int = 3
    fail_at: int = -1            # inject ONE hard crash after this step
    drain_poll_s: float = 2e-3
    drain_timeout_s: float = 300.0


@dataclass
class RLRunReport:
    steps: int = 0
    steps_done: int = 0          # completed optimizer steps (monotone)
    steps_lost: int = 0          # re-executed after crash/preempt resumes
    recoveries: int = 0          # crash resumes
    preemptions: int = 0         # cooperative (goodbye-saved) stops
    publishes: int = 0
    final_version: int = 0
    host_syncs: int = 0
    losses: List[float] = field(default_factory=list)
    segments: List[Dict[str, Any]] = field(default_factory=list)

    @property
    def completed(self) -> bool:
        return self.steps_done >= self.steps > 0


class RLLearner:
    """Drain -> encode -> fused chunk step -> publish/checkpoint loop."""

    def __init__(self, spec: RLLearnerSpec, rollouts: RolloutQueue,
                 policies: PolicyStore, *, store, registry=None,
                 name: str = "learner", mesh=None):
        self.spec = spec
        self.rollouts = rollouts
        self.policies = policies
        self.metrics = registry
        self.name = name
        if mesh is None:
            from repro.launch.mesh import single_device_mesh
            mesh = single_device_mesh()
        self.mesh = mesh
        self.ckpt = Checkpointer(store, prefix=f"rl/{name}", keep=spec.keep)
        self.report = RLRunReport(steps=spec.steps)
        self.version = 0
        self._failed_once = False
        shape = ShapeConfig("rl", spec.seq_len, spec.batch, "train")
        self._shape = shape
        self._bundles: Dict[int, Any] = {}
        self._fns: Dict[int, Any] = {}
        mod = steps_mod._model_module(spec.cfg)
        self._schema = mod.lm_schema(steps_mod.resolve_cfg(spec.cfg, shape))
        self._opt_schema = adamw.opt_state_schema(self._schema, spec.ocfg)

    # ------------------------------------------------------------- jit pieces
    def _bundle(self, length: int):
        if length not in self._bundles:
            self._bundles[length] = steps_mod.build_rl_train_chunk(
                self.spec.cfg, self.spec.par, self.spec.ocfg, self.mesh,
                self._shape, length)
        return self._bundles[length]

    def _fn(self, length: int):
        if length not in self._fns:
            self._fns[length] = self._bundle(length).jit()
        return self._fns[length]

    def _abstract(self):
        return {"params": pr.abstract_params(self._schema,
                                             self.spec.cfg.param_dtype),
                "opt": pr.abstract_params(self._opt_schema, "float32")}

    def _shardings(self):
        b = self._bundle(max(self.spec.device_steps, 1))
        return {"params": b.in_shardings[0], "opt": b.in_shardings[1]}

    def _init_state(self):
        b = self._bundle(max(self.spec.device_steps, 1))
        with self.mesh:
            params = jax.jit(
                lambda k: pr.init_params(self._schema, k,
                                         self.spec.cfg.param_dtype),
                out_shardings=b.in_shardings[0])(
                    jax.random.key(self.spec.seed))
            opt = jax.jit(
                lambda k: pr.init_params(self._opt_schema, k, "float32"),
                out_shardings=b.in_shardings[1])(
                    jax.random.key(self.spec.seed + 1))
        return params, opt

    # ----------------------------------------------------------------- encode
    def encode(self, trajs: List[Trajectory]) -> Dict[str, np.ndarray]:
        """One optimizer-step batch from B trajectories.

        Row i is prompt+generation left-aligned in S positions;
        ``labels[j] = seq[j+1]`` (next-token), ``mask[j] = 1`` iff the
        label at j is a *generated* token — prompt and pad positions
        carry zero weight and therefore zero gradient.  Advantages are
        batch-normalized rewards (REINFORCE with a mean baseline)."""
        S = self.spec.seq_len
        B = len(trajs)
        tokens = np.zeros((B, S), np.int32)
        labels = np.zeros((B, S), np.int32)
        mask = np.zeros((B, S), np.float32)
        rew = np.array([t.reward for t in trajs], np.float32)
        for i, t in enumerate(trajs):
            seq = (list(t.prompt) + list(t.tokens))[:S + 1]
            L = len(seq)
            tokens[i, :L - 1] = seq[:-1]
            labels[i, :L - 1] = seq[1:]
            lo, hi = max(len(t.prompt) - 1, 0), L - 1
            mask[i, lo:hi] = 1.0
        adv = (rew - rew.mean()) / (rew.std() + 1e-6)
        return {"tokens": tokens, "labels": labels, "mask": mask,
                "advantages": adv.astype(np.float32)}

    # ------------------------------------------------------------------ drain
    def _drain(self, n: int, should_stop) -> Optional[List]:
        """Lease n fresh trajectories (heartbeating held leases while
        waiting); None if preempted mid-drain (held leases released)."""
        held: List = []
        deadline = time.monotonic() + self.spec.drain_timeout_s
        while len(held) < n:
            if should_stop is not None and should_stop():
                self.rollouts.release(held, worker=self.name)
                return None
            got = self.rollouts.take_fresh(
                n - len(held), worker=self.name,
                current_version=self.version,
                max_policy_lag=self.spec.max_policy_lag)
            held.extend(got)
            self.rollouts.renew(held, worker=self.name)
            if len(held) < n:
                if time.monotonic() > deadline:
                    self.rollouts.release(held, worker=self.name)
                    raise RuntimeError(
                        f"learner starved: {len(held)}/{n} trajectories "
                        f"after {self.spec.drain_timeout_s}s (actors dead?)")
                time.sleep(self.spec.drain_poll_s)
        return held

    # -------------------------------------------------------------- segments
    def run(self, should_stop=None) -> Dict[str, Any]:
        """One resumable segment (the preemptible pod body).  Returns
        {"done": bool, "preempted": bool, "step": last_completed}."""
        spec = self.spec
        K = max(spec.device_steps, 1)
        eff_ckpt = snap_cadence(spec.ckpt_every, K)
        eff_pub = snap_cadence(spec.broadcast_every, K)
        shardings = self._shardings()
        restored, meta = self.ckpt.restore_latest(self._abstract(), shardings)
        if restored is not None:
            params, opt = restored["params"], restored["opt"]
            start = int(meta["step"]) + 1
            self.version = int(meta.get("version", self.version))
            lost = max(0, self.report.steps_done - start)
            self.report.steps_lost += lost
        else:
            params, opt = self._init_state()
            start = 0
        seg = {"start": start, "end": start - 1, "outcome": "running"}
        self.report.segments.append(seg)

        def finish(outcome: str, step: int, *, goodbye: bool):
            seg["outcome"], seg["end"] = outcome, step
            if goodbye and step >= start:
                self.ckpt.wait()
                self.ckpt.save(step, {"params": params, "opt": opt},
                               extra=self._extra())
            self.ckpt.wait()
            return {"done": outcome == "done", "preempted":
                    outcome == "preempted", "step": step}

        step = start - 1
        with self.mesh:
            for c_start, length in chunk_schedule(start, spec.steps, K):
                if should_stop is not None and should_stop():
                    self.report.preemptions += 1
                    return finish("preempted", step, goodbye=True)
                held = self._drain(length * spec.batch, should_stop)
                if held is None:
                    self.report.preemptions += 1
                    return finish("preempted", step, goodbye=True)
                batches = [self.encode([t for _, t in
                                        held[i * spec.batch:
                                             (i + 1) * spec.batch]])
                           for i in range(length)]
                stacked = {k: np.stack([b[k] for b in batches])
                           for k in batches[0]}
                params, opt, ms = self._fn(length)(params, opt, stacked)
                losses = np.asarray(ms["loss"])      # one sync per chunk
                self.report.host_syncs += 1
                self.report.losses.extend(float(x) for x in losses)
                self.rollouts.ack_trained(held, worker=self.name,
                                          current_version=self.version)
                step = c_start + length - 1
                self.report.steps_done = max(self.report.steps_done, step + 1)
                if self.metrics is not None:
                    self.metrics.gauge("rl/learner_step", step)
                    self.metrics.gauge("rl/loss", float(losses[-1]))
                done = step + 1
                if eff_pub and done % eff_pub == 0 and done < spec.steps:
                    self.version += 1
                    self.policies.publish(self.version, params, step=done)
                    self.report.publishes += 1
                if eff_ckpt and done % eff_ckpt == 0:
                    self.ckpt.save_async(
                        step, {"params": params, "opt": opt},
                        extra=self._extra())
                if (spec.fail_at >= 0 and step >= spec.fail_at
                        and not self._failed_once):
                    self._failed_once = True
                    seg["outcome"], seg["end"] = "failed", step
                    self.ckpt.wait()     # periodic save may be in flight
                    raise InjectedLearnerFailure(
                        f"injected learner crash after step {step}")
        # final weights always published so actors converge on the last
        # version even when steps % broadcast_every != 0
        self.version += 1
        self.policies.publish(self.version, params, step=spec.steps)
        self.report.publishes += 1
        self.report.final_version = self.version
        self._params = params
        return finish("done", step, goodbye=True)

    def _extra(self) -> dict:
        return {"version": self.version,
                "steps_done": self.report.steps_done,
                "queue": self.rollouts.snapshot()}

    def run_supervised(self, should_stop=None, *,
                       max_failures: int = 3) -> Dict[str, Any]:
        """The crash loop: resume through injected hard failures until
        the segment completes or is cooperatively preempted."""
        failures = 0
        while True:
            try:
                out = self.run(should_stop)
            except InjectedLearnerFailure:
                failures += 1
                self.report.recoveries += 1
                if failures > max_failures:
                    raise
                continue
            return out
