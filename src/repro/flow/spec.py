"""Workflow *program* spec — the declarative ``graph:`` language.

The paper's platform is workflow-driven: Kepler programs — not shell
scripts — orchestrate the fabric (§I, §III).  ``repro.core.workflow``
gave us the measured, resumable step list; this module gives those
steps a *program* structure that a manifest can carry:

  graph:
    nodes:
      - step: plan                      # a task node
        entrypoint: pkg.mod:fn          # called fn(ctx, **params)
        params: {...}                   # plain-JSON kwargs
      - step: fetch
        deps: [plan]
        scatter: {over: plan.chunks}    # fan-out: one placed step/item
        entrypoint: pkg.mod:fetch_one
        outputs: ["{item}/raw.npy"]     # {item}/{index} substituted
      - step: tune
        deps: [fetch]
        repeat: {until: "output.loss < 0.1", max: 5}   # bounded loop
        entrypoint: pkg.mod:tune_once
      - step: publish
        deps: [tune]
        when: "tune.loss < 0.2"         # conditional on upstream outputs
        entrypoint: pkg.mod:publish
      - step: report                    # a nested subworkflow
        deps: [publish]
        graph: {nodes: [...]}

Validation here is *eager* and names the offending field as a manifest
path (``spec.graph.nodes[2].scatter.over``) via
``repro.api.resources.ManifestError`` — a bad program fails at
``apply`` time, not three branches into a fan-out.

Conditions (``when:``/``until:``) are a safe expression subset parsed
with ``ast``: comparisons, boolean/arithmetic operators, literals,
dotted/indexed access into upstream step outputs, and the ``len`` /
``min`` / ``max`` / ``abs`` builtins.  Nothing else parses, so a
manifest can never smuggle arbitrary code through a condition string.
"""
from __future__ import annotations

import ast
import re
from typing import Any, Callable, List, Mapping, Optional, Sequence, Set

# name charset: "#" is reserved for branch shards (``seg#3``), "." for
# nested subworkflow steps (``report.render``) and output references
_NAME_RE = re.compile(r"^[A-Za-z][A-Za-z0-9_-]*$")

NODE_KEYS = frozenset({
    "step", "deps", "entrypoint", "fn", "params", "when", "scatter",
    "repeat", "graph", "pods", "devices_per_pod", "inputs", "outputs"})
SCATTER_KEYS = frozenset({"over"})
REPEAT_KEYS = frozenset({"times", "until", "max"})

# the ``ast`` node types a condition expression may contain
_ALLOWED_EXPR_NODES = (
    ast.Expression, ast.BoolOp, ast.And, ast.Or, ast.UnaryOp, ast.Not,
    ast.USub, ast.UAdd, ast.Compare, ast.Eq, ast.NotEq, ast.Lt, ast.LtE,
    ast.Gt, ast.GtE, ast.In, ast.NotIn, ast.Is, ast.IsNot, ast.BinOp,
    ast.Add, ast.Sub, ast.Mult, ast.Div, ast.FloorDiv, ast.Mod,
    ast.Constant, ast.Name, ast.Load, ast.Attribute, ast.Subscript,
    ast.Index, ast.List, ast.Tuple, ast.Call)

_ALLOWED_CALLS = {"len": len, "min": min, "max": max, "abs": abs,
                  "sum": sum, "round": round}


def _err(message: str, field: str):
    from repro.api.resources import ManifestError
    return ManifestError(message, field=field)


# ------------------------------------------------------------- expressions
def parse_expr(text: str, field: str) -> ast.Expression:
    """Parse a condition string, rejecting anything outside the safe
    subset.  Raises ``ManifestError`` naming ``field``."""
    if not isinstance(text, str) or not text.strip():
        raise _err("must be a non-empty expression string", field)
    try:
        tree = ast.parse(text, mode="eval")
    except SyntaxError as e:
        raise _err(f"invalid expression: {e.msg}", field) from e
    for node in ast.walk(tree):
        if not isinstance(node, _ALLOWED_EXPR_NODES):
            raise _err(
                f"expression may not contain {type(node).__name__}; "
                f"allowed: comparisons, and/or/not, arithmetic, "
                f"literals, name.attr / name[i] access, and "
                f"{sorted(_ALLOWED_CALLS)} calls", field)
        if isinstance(node, ast.Call):
            if not (isinstance(node.func, ast.Name) and
                    node.func.id in _ALLOWED_CALLS) or node.keywords:
                raise _err(
                    f"only {sorted(_ALLOWED_CALLS)} may be called",
                    field)
    return tree


def expr_roots(tree: ast.Expression) -> Set[str]:
    """The root names an expression reads (``train.loss < x`` ->
    ``{"train", "x"}``), excluding the whitelisted builtins."""
    return {n.id for n in ast.walk(tree)
            if isinstance(n, ast.Name) and n.id not in _ALLOWED_CALLS}


def eval_expr(tree: ast.Expression, names: Mapping[str, Any]):
    """Evaluate a parsed condition against a namespace of step outputs.
    Attribute access works on mappings (``train.loss`` reads
    ``names["train"]["loss"]``)."""

    def ev(node):
        if isinstance(node, ast.Expression):
            return ev(node.body)
        if isinstance(node, ast.Constant):
            return node.value
        if isinstance(node, ast.Name):
            if node.id not in names:
                raise KeyError(
                    f"condition references {node.id!r}; available: "
                    f"{sorted(names)}")
            return names[node.id]
        if isinstance(node, ast.Attribute):
            base = ev(node.value)
            if isinstance(base, Mapping):
                if node.attr not in base:
                    raise KeyError(
                        f"output has no key {node.attr!r}; available: "
                        f"{sorted(base)}")
                return base[node.attr]
            return getattr(base, node.attr)
        if isinstance(node, ast.Subscript):
            sl = node.slice
            if isinstance(sl, ast.Index):        # py<3.9 compat shape
                sl = sl.value
            return ev(node.value)[ev(sl)]
        if isinstance(node, (ast.List, ast.Tuple)):
            return [ev(e) for e in node.elts]
        if isinstance(node, ast.UnaryOp):
            v = ev(node.operand)
            if isinstance(node.op, ast.Not):
                return not v
            return -v if isinstance(node.op, ast.USub) else +v
        if isinstance(node, ast.BoolOp):
            if isinstance(node.op, ast.And):
                out = True
                for v in node.values:
                    out = ev(v)
                    if not out:
                        return out
                return out
            for v in node.values:
                out = ev(v)
                if out:
                    return out
            return out
        if isinstance(node, ast.BinOp):
            a, b = ev(node.left), ev(node.right)
            op = type(node.op)
            return {ast.Add: lambda: a + b, ast.Sub: lambda: a - b,
                    ast.Mult: lambda: a * b, ast.Div: lambda: a / b,
                    ast.FloorDiv: lambda: a // b,
                    ast.Mod: lambda: a % b}[op]()
        if isinstance(node, ast.Compare):
            left = ev(node.left)
            for op, cmp in zip(node.ops, node.comparators):
                right = ev(cmp)
                ok = {ast.Eq: lambda: left == right,
                      ast.NotEq: lambda: left != right,
                      ast.Lt: lambda: left < right,
                      ast.LtE: lambda: left <= right,
                      ast.Gt: lambda: left > right,
                      ast.GtE: lambda: left >= right,
                      ast.In: lambda: left in right,
                      ast.NotIn: lambda: left not in right,
                      ast.Is: lambda: left is right,
                      ast.IsNot: lambda: left is not right}[type(op)]()
                if not ok:
                    return False
                left = right
            return True
        if isinstance(node, ast.Call):
            return _ALLOWED_CALLS[node.func.id](*[ev(a) for a in node.args])
        raise TypeError(f"unsupported expression node {type(node).__name__}")

    return ev(tree)


# --------------------------------------------------------------- validation
def _check_ref(ref: str, deps: Sequence[str], field: str) -> None:
    """A ``scatter.over`` output reference: ``dep`` or ``dep.path.to.list``
    whose root must be a declared dependency."""
    if not isinstance(ref, str) or not ref:
        raise _err("must be a non-empty output reference "
                   "('dep' or 'dep.key')", field)
    root = ref.split(".", 1)[0].split("[", 1)[0]
    if root not in deps:
        raise _err(
            f"references {root!r}, which is not in this node's deps "
            f"{sorted(deps)}", field)


def _validate_node(node, idx: int, names: Set[str], field: str) -> None:
    f = f"{field}.nodes[{idx}]"
    if not isinstance(node, Mapping):
        raise _err(f"each node must be an object, got "
                   f"{type(node).__name__}", f)
    unknown = set(node) - NODE_KEYS
    if unknown:
        raise _err(f"unknown node keys {sorted(unknown)}; known: "
                   f"{sorted(NODE_KEYS)}", f"{f}.{sorted(unknown)[0]}")
    name = node.get("step")
    if not isinstance(name, str) or not _NAME_RE.match(name or ""):
        raise _err("step name must match [A-Za-z][A-Za-z0-9_-]* "
                   "('#' and '.' are reserved for branches/subworkflows)",
                   f"{f}.step")
    deps = node.get("deps", [])
    if not isinstance(deps, (list, tuple)):
        raise _err(f"must be a list of step names, got "
                   f"{type(deps).__name__}", f"{f}.deps")
    for j, d in enumerate(deps):
        if not isinstance(d, str) or d not in names:
            raise _err(f"unknown dependency {d!r}; known steps: "
                       f"{sorted(names)}", f"{f}.deps[{j}]")
        if d == name:
            raise _err("a step cannot depend on itself", f"{f}.deps[{j}]")

    # exactly one body: entrypoint | fn | graph
    bodies = [k for k in ("entrypoint", "fn", "graph") if node.get(k)
              is not None]
    if len(bodies) != 1:
        raise _err("each node needs exactly one of entrypoint (manifest), "
                   f"fn (runtime callable) or graph (nested subworkflow); "
                   f"got {bodies or 'none'}", f"{f}.entrypoint")
    if node.get("entrypoint") is not None:
        ep = node["entrypoint"]
        if not isinstance(ep, str) or ":" not in ep:
            raise _err("must look like 'pkg.module:attr'",
                       f"{f}.entrypoint")
    if node.get("fn") is not None and not callable(node["fn"]):
        raise _err("must be a callable (runtime-only; use entrypoint in "
                   "manifests)", f"{f}.fn")
    if node.get("graph") is not None:
        if node.get("scatter") is not None or node.get("repeat") is not None:
            raise _err("scatter/repeat cannot wrap a nested subworkflow",
                       f"{f}.graph")
        validate_graph(node["graph"], field=f"{f}.graph")

    params = node.get("params")
    if params is not None and not isinstance(params, Mapping):
        raise _err(f"must be an object of kwargs, got "
                   f"{type(params).__name__}", f"{f}.params")
    for k, typ, lo in (("pods", int, 1), ("devices_per_pod", int, 0)):
        v = node.get(k)
        if v is not None and (not isinstance(v, int)
                              or isinstance(v, bool) or v < lo):
            raise _err(f"must be an int >= {lo}", f"{f}.{k}")
    for k in ("inputs", "outputs"):
        v = node.get(k, [])
        if not isinstance(v, (list, tuple)) or \
                not all(isinstance(s, str) for s in v):
            raise _err("must be a list of dataset key strings", f"{f}.{k}")

    if node.get("when") is not None:
        tree = parse_expr(node["when"], f"{f}.when")
        for root in expr_roots(tree):
            if root not in deps:
                raise _err(
                    f"reads {root!r}, which is not in this node's deps "
                    f"{sorted(deps)} — conditions see dependency outputs "
                    f"only", f"{f}.when")

    scatter = node.get("scatter")
    if scatter is not None:
        if not isinstance(scatter, Mapping):
            raise _err(f"must be an object {{over: ...}}, got "
                       f"{type(scatter).__name__}", f"{f}.scatter")
        unknown = set(scatter) - SCATTER_KEYS
        if unknown:
            raise _err(f"unknown scatter keys {sorted(unknown)}; known: "
                       f"{sorted(SCATTER_KEYS)}",
                       f"{f}.scatter.{sorted(unknown)[0]}")
        if "over" not in scatter:
            raise _err("required field missing", f"{f}.scatter.over")
        over = scatter["over"]
        if isinstance(over, (list, tuple)):
            if not over:
                raise _err("a literal scatter list may not be empty",
                           f"{f}.scatter.over")
        else:
            _check_ref(over, deps, f"{f}.scatter.over")
        if node.get("repeat") is not None:
            raise _err("scatter and repeat cannot combine on one node; "
                       "nest a subworkflow instead", f"{f}.scatter")

    repeat = node.get("repeat")
    if repeat is not None:
        if not isinstance(repeat, Mapping):
            raise _err(f"must be an object {{times: N}} or "
                       f"{{until: expr, max: N}}, got "
                       f"{type(repeat).__name__}", f"{f}.repeat")
        unknown = set(repeat) - REPEAT_KEYS
        if unknown:
            raise _err(f"unknown repeat keys {sorted(unknown)}; known: "
                       f"{sorted(REPEAT_KEYS)}",
                       f"{f}.repeat.{sorted(unknown)[0]}")
        times, until = repeat.get("times"), repeat.get("until")
        if (times is None) == (until is None):
            raise _err("needs exactly one of times (fixed count) or "
                       "until (stop expression, with max)",
                       f"{f}.repeat")
        if times is not None and (not isinstance(times, int)
                                  or isinstance(times, bool) or times < 1):
            raise _err("must be an int >= 1", f"{f}.repeat.times")
        if until is not None:
            bound = repeat.get("max")
            if not isinstance(bound, int) or isinstance(bound, bool) \
                    or bound < 1:
                raise _err("an until-loop must declare max (an int >= 1): "
                           "every loop in a workflow program is bounded",
                           f"{f}.repeat.max")
            tree = parse_expr(until, f"{f}.repeat.until")
            for root in expr_roots(tree):
                if root not in deps and root not in ("output", "i"):
                    raise _err(
                        f"reads {root!r}; until-conditions see dependency "
                        f"outputs, 'output' (the iteration's result) and "
                        f"'i' (the iteration index)", f"{f}.repeat.until")


def validate_graph(graph, *, field: str = "spec.graph") -> None:
    """Validate a declarative graph spec (see module docstring), raising
    ``ManifestError`` with the offending manifest path.  Checks node
    shapes, name uniqueness, dependency existence, condition/loop/scatter
    well-formedness, and acyclicity."""
    if not isinstance(graph, Mapping):
        raise _err(f"must be an object with a 'nodes' list, got "
                   f"{type(graph).__name__}", field)
    unknown = set(graph) - {"nodes"}
    if unknown:
        raise _err(f"unknown graph keys {sorted(unknown)}; known: "
                   f"['nodes']", f"{field}.{sorted(unknown)[0]}")
    nodes = graph.get("nodes")
    if not isinstance(nodes, (list, tuple)) or not nodes:
        raise _err("must be a non-empty list of nodes", f"{field}.nodes")

    names: Set[str] = set()
    for i, node in enumerate(nodes):
        if isinstance(node, Mapping):
            name = node.get("step")
            if isinstance(name, str):
                if name in names:
                    raise _err(f"duplicate step name {name!r}",
                               f"{field}.nodes[{i}].step")
                names.add(name)
    for i, node in enumerate(nodes):
        _validate_node(node, i, names, field)

    # acyclicity over the declared edges
    deps = {n["step"]: list(n.get("deps", [])) for n in nodes}
    seen: Set[str] = set()
    visiting: List[str] = []

    def visit(name: str) -> None:
        if name in seen:
            return
        if name in visiting:
            cyc = visiting[visiting.index(name):] + [name]
            raise _err(f"dependency cycle: {' -> '.join(cyc)}",
                       f"{field}.nodes")
        visiting.append(name)
        for d in deps[name]:
            visit(d)
        visiting.pop()
        seen.add(name)

    for name in deps:
        visit(name)
