"""Concurrent graph executor — ready-set scheduling over a bounded pool.

``GraphRunner`` executes a compiled :class:`~repro.flow.compiler.GraphProgram`
on top of an existing :class:`repro.core.workflow.Workflow` — the
Workflow contributes the placed-step machinery (planner scoring,
pre-staging, marker/output store semantics, Table-I reports, EventBus
emission); the runner contributes the *program* semantics:

  * independent branches run **concurrently** (a bounded worker pool;
    each ready step is submitted the moment its dependencies resolve);
  * ``when:`` conditionals are evaluated against upstream outputs; a
    false condition skips the node and cascades to its dependents;
  * ``scatter:`` fan-out expands at run time into one placed step per
    item (``seg#0`` … ``seg#N-1``), each individually marked — a
    crashed 50-branch fan-out resumes ONLY its missing branches — and a
    gather step collects shard outputs in index order;
  * ``repeat:`` loops run iterations ``tune#0`` … sequentially (the
    carry is loop-ordered), each iteration marked, ``until:`` stop
    expressions re-evaluated deterministically on resume;
  * nested subworkflows arrive pre-flattened (``report.render``) from
    the compiler's inliner, so they schedule like any other branch;
  * cancellation (``should_stop``) is polled per branch: no new branch
    launches after the signal, queued pool work is revoked, running
    steps finish their unit and keep their markers, and the monitor
    sees one workflow-level ``cancelled`` event plus a ``skipped``
    event for every step that will not run.

Events: logical nodes publish on kind ``step`` (placed / done /
skipped / scatter), scatter shards and loop iterations on kind
``branch`` with ``of=<node>`` and ``branch=<index>``.
"""
from __future__ import annotations

import threading
from concurrent.futures import FIRST_COMPLETED, ThreadPoolExecutor, wait
from typing import Any, Dict, List, Mapping, Optional, Set, Tuple

from repro.core.workflow import Step, Workflow
from repro.flow.compiler import GraphProgram, Node, compile_graph
from repro.flow.spec import eval_expr, parse_expr


def _substitute(keys, item, index) -> List[str]:
    """Placement dataset keys for one scatter shard: ``{item}`` /
    ``{index}`` placeholders become the shard's values."""
    out = []
    for k in keys:
        out.append(k.replace("{item}", str(item))
                    .replace("{index}", str(index)))
    return out


def _deps_namespace(node: Node, outputs: Mapping[str, Any]) -> Dict[str, Any]:
    """Upstream outputs keyed by the node's LOCAL dep names — what its
    fn inputs, ``when:`` and ``scatter.over`` were written against."""
    local = node.local_deps or node.deps
    return {loc: outputs.get(full) for full, loc in zip(node.deps, local)}


def _resolve_ref(ref: str, outputs: Mapping[str, Any], node: str):
    """``scatter.over`` reference -> the runtime list it names."""
    tree = parse_expr(ref, f"graph.nodes[{node}].scatter.over")
    try:
        items = eval_expr(tree, outputs)
    except (KeyError, TypeError) as e:
        raise RuntimeError(
            f"graph node {node!r}: scatter.over {ref!r} did not resolve "
            f"against upstream outputs: {e}") from e
    if not isinstance(items, (list, tuple)):
        raise RuntimeError(
            f"graph node {node!r}: scatter.over {ref!r} must name a "
            f"list, got {type(items).__name__}")
    return list(items)


def _flatten_into(node: Node, prefix: str, extra_deps, inherited_when,
                  flat: Dict[str, Node]) -> None:
    """Inline one (possibly nested-subworkflow) node.  ``extra_deps`` is
    a list of ``(full, local)`` dep pairs the enclosing subworkflow node
    carried — subgraph roots inherit them (and the sub node's ``when:``)
    so the whole subgraph waits on, and can reference, what the sub node
    declared."""
    name = prefix + node.name
    if node.deps:
        pairs = [(prefix + d, d) for d in node.deps]
        when = node.when
    else:
        pairs = list(extra_deps)
        when = node.when if node.when is not None else inherited_when
    deps = tuple(full for full, _ in pairs)
    local = tuple(loc for _, loc in pairs)
    if node.subgraph is None:
        flat[name] = Node(
            name=name, deps=deps, fn=node.fn, params=node.params,
            when=when, scatter_over=node.scatter_over,
            repeat=node.repeat, pods=node.pods,
            devices_per_pod=node.devices_per_pod,
            inputs=node.inputs, outputs=node.outputs, local_deps=local)
        return
    sub_prefix = name + "."
    for child in node.subgraph.nodes.values():
        _flatten_into(child, sub_prefix, pairs, when, flat)
    # synthetic collect node: dependents of the sub node see one dict
    # {child: output}; its deps are fully-qualified on BOTH sides
    children = [sub_prefix + c for c in node.subgraph.nodes]
    flat[name] = Node(name=name, deps=tuple(children),
                      local_deps=tuple(children),
                      params={"_collect": children})


def flatten(prog: GraphProgram) -> GraphProgram:
    """Inline nested subworkflows: child ``c`` of sub node ``s`` becomes
    ``s.c``, scheduling — and resuming — exactly like a top-level node."""
    flat: Dict[str, Node] = {}
    for node in prog.nodes.values():
        _flatten_into(node, "", [], None, flat)
    return GraphProgram(nodes=flat)


class GraphRunner:
    """Execute one compiled graph program on a Workflow substrate."""

    def __init__(self, wf: Workflow, program, *, max_workers: int = 8):
        if isinstance(program, Mapping):
            program = compile_graph(program)
        self.wf = wf
        self.program = flatten(program)
        self.max_workers = max(1, int(max_workers))
        self._lock = threading.Lock()
        self._outputs: Dict[str, Any] = {}

    # ----------------------------------------------------------- plumbing
    def _step_for(self, node: Node, *, name: Optional[str] = None,
                  fn=None, inputs=(), outputs=()) -> Step:
        step = Step(name or node.name, fn, deps=node.deps,
                    pods=node.pods,
                    devices_per_pod=node.devices_per_pod,
                    inputs=tuple(inputs), outputs=tuple(outputs))
        with self.wf._lock:
            self.wf.steps.setdefault(step.name, step)
        return step

    def _marker_done(self, name: str) -> bool:
        return self.wf._ctrl().exists(Step(name, None).marker_key(
            self.wf.name))

    # --------------------------------------------------------- node bodies
    def _task_fn(self, node: Node):
        if node.params.get("_collect") is not None:
            children = node.params["_collect"]
            return lambda ctx: {c[len(node.name) + 1:]: ctx.inputs[c]
                                for c in children}
        fn, params = node.fn, dict(node.params)
        return lambda ctx: fn(ctx, **params)

    def _run_task(self, node: Node, inputs: Dict[str, Any], resume: bool):
        step = self._step_for(node, fn=self._task_fn(node),
                              inputs=node.inputs, outputs=node.outputs)
        out, _ = self.wf._exec_step(step, inputs, resume, concurrent=True)
        return out

    def _run_shard(self, node: Node, index: int, item, deps_out,
                   resume: bool, stop) -> Any:
        if stop():            # revoked-after-start race: skip, no marker
            return _CANCELLED
        fn, params = node.fn, dict(node.params)
        step = self._step_for(
            node, name=f"{node.name}#{index}",
            fn=lambda ctx: fn(ctx, **params),
            inputs=_substitute(node.inputs, item, index),
            outputs=_substitute(node.outputs, item, index))
        inputs = {**deps_out, "item": item, "index": index}
        out, _ = self.wf._exec_step(step, inputs, resume,
                                    emit_kind="branch", concurrent=True,
                                    of=node.name, branch=index)
        return out

    def _run_repeat(self, node: Node, inputs: Dict[str, Any],
                    resume: bool, stop):
        """Bounded loop: iterations are sequential (the carry is
        loop-ordered) but each is its own marked, resumable step; the
        stop signal is honored at every iteration boundary."""
        prev = None
        for i in range(node.repeat.bound):
            if stop():
                return _CANCELLED
            fn, params = node.fn, dict(node.params)
            step = self._step_for(node, name=f"{node.name}#{i}",
                                  fn=lambda ctx: fn(ctx, **params),
                                  inputs=node.inputs,
                                  outputs=node.outputs)
            it_inputs = {**inputs, "i": i, "prev": prev}
            prev, _ = self.wf._exec_step(step, it_inputs, resume,
                                         emit_kind="branch",
                                         concurrent=True, of=node.name,
                                         branch=i)
            if node.repeat.until is not None and eval_expr(
                    node.repeat.until, {**inputs, "output": prev, "i": i}):
                break
        # the logical node's own marked step: its output is the final
        # iteration's, so downstream deps (and when:-conditions) read it
        # like any task output; the marker makes resume skip the loop
        # wholesale once it has converged
        step = self._step_for(node, fn=lambda ctx, out=prev: out)
        out, _ = self.wf._exec_step(step, {}, resume, concurrent=True)
        return out

    # ---------------------------------------------------------------- run
    def run(self, *, resume: bool = True, only: Optional[str] = None,
            should_stop=None) -> Dict[str, Any]:
        stop = should_stop or (lambda: False)
        nodes = self.program.nodes
        if only is not None:
            if only not in nodes:
                raise RuntimeError(
                    f"graph has no step {only!r}; steps: {sorted(nodes)}")
            return self._run_only(nodes[only], resume, stop)

        state: Dict[str, str] = {n: "pending" for n in nodes}
        cond_skipped: Set[str] = set()
        futures: Dict[Any, Tuple[str, Optional[int]]] = {}
        shards: Dict[str, Dict[str, Any]] = {}
        failure: Optional[BaseException] = None
        cancelled = False

        def deps_ready(node: Node) -> bool:
            return all(state[d] in ("done", "skipped") for d in node.deps)

        def deps_out(node: Node) -> Dict[str, Any]:
            return _deps_namespace(node, self._outputs)

        pool = ThreadPoolExecutor(max_workers=self.max_workers,
                                  thread_name_prefix=f"flow-{self.wf.name}")
        try:
            while True:
                if not cancelled and failure is None and stop():
                    cancelled = True
                    self._revoke(futures, nodes, state)
                    remaining = [n for n, s in state.items()
                                 if s in ("pending", "ready")]
                    self.wf._emit_workflow("cancelled",
                                           remaining=len(remaining))
                    for n in remaining:
                        state[n] = "skipped"
                        cond_skipped.add(n)   # do not run dependents
                        self.wf._emit(n, "skipped", reason="cancelled")

                if failure is None and not cancelled:
                    for name, node in nodes.items():
                        if state[name] != "pending" or not deps_ready(node):
                            continue
                        if any(d in cond_skipped for d in node.deps):
                            state[name] = "skipped"
                            cond_skipped.add(name)
                            self.wf._emit(name, "skipped",
                                          reason="when-upstream")
                            continue
                        if node.when is not None and not self._when(
                                node, deps_out(node)):
                            state[name] = "skipped"
                            cond_skipped.add(name)
                            self.wf._emit(name, "skipped", reason="when")
                            continue
                        state[name] = "running"
                        self._launch(pool, futures, shards, node,
                                     deps_out(node), resume, stop)

                if not futures:
                    if cancelled or failure is not None or all(
                            s in ("done", "skipped")
                            for s in state.values()):
                        break
                    # nothing running and nothing launchable: a bug
                    stuck = [n for n, s in state.items() if s == "pending"]
                    raise RuntimeError(
                        f"graph stalled with pending steps {stuck}")

                done_futs, _ = wait(list(futures), timeout=0.05,
                                    return_when=FIRST_COMPLETED)
                for fut in done_futs:
                    name, shard = futures.pop(fut)
                    try:
                        result = fut.result()
                    except BaseException as e:   # first failure wins
                        if failure is None:
                            failure = e
                            self._revoke(futures, nodes, state)
                        continue
                    if shard is None:
                        if result is _CANCELLED:
                            state[name] = "skipped"
                            continue
                        self._finish(name, result, state)
                    else:
                        self._shard_done(
                            pool, futures, shards, nodes[name], shard,
                            result, resume, state,
                            launch_ok=(failure is None and not cancelled))
        finally:
            pool.shutdown(wait=True)
        if failure is not None:
            raise failure
        with self.wf._lock:
            self.wf.results.update(self._outputs)
        return dict(self._outputs)

    # ------------------------------------------------------------ helpers
    def _when(self, node: Node, deps_out: Dict[str, Any]) -> bool:
        try:
            return bool(eval_expr(node.when, deps_out))
        except (KeyError, TypeError) as e:
            raise RuntimeError(
                f"graph node {node.name!r}: when-condition failed to "
                f"evaluate: {e}") from e

    def _finish(self, name: str, output, state) -> None:
        state[name] = "done"
        with self._lock:
            self._outputs[name] = output

    def _launch(self, pool, futures, shards, node: Node,
                deps_out: Dict[str, Any], resume: bool, stop) -> None:
        if node.scatter_over is not None and not (
                resume and self._marker_done(node.name)):
            items = node.scatter_over if isinstance(node.scatter_over, list) \
                else _resolve_ref(node.scatter_over, deps_out, node.name)
            self.wf._emit(node.name, "scatter", width=len(items))
            shards[node.name] = {"items": items, "deps_out": deps_out,
                                 "outs": {}, "left": len(items)}
            for i, item in enumerate(items):
                fut = pool.submit(self._run_shard, node, i, item,
                                  deps_out, resume, stop)
                futures[fut] = (node.name, i)
            return
        if node.scatter_over is not None:
            # whole fan-out already gathered: the logical marker resolves
            # it without expanding a single shard
            fut = pool.submit(self._load_gathered, node)
        elif node.repeat is not None:
            fut = pool.submit(self._run_repeat, node, deps_out, resume,
                              stop)
        else:
            fut = pool.submit(self._run_task, node, deps_out, resume)
        futures[fut] = (node.name, None)

    def _load_gathered(self, node: Node):
        step = self._step_for(node, fn=lambda ctx: None)
        out, _ = self.wf._exec_step(step, {}, True, concurrent=True)
        return out

    def _shard_done(self, pool, futures, shards, node: Node, index: int,
                    result, resume: bool, state, *,
                    launch_ok: bool = True) -> None:
        rec = shards[node.name]
        rec["left"] -= 1
        if result is _CANCELLED:
            rec["cancelled"] = True
        else:
            rec["outs"][index] = result
        if rec["left"] > 0:
            return
        if (not launch_ok or rec.get("cancelled")
                or len(rec["outs"]) != len(rec["items"])):
            state[node.name] = "skipped"   # incomplete fan-out: no gather
            return
        gathered = [rec["outs"][i] for i in range(len(rec["items"]))]
        step = self._step_for(node, fn=lambda ctx: gathered)
        fut = pool.submit(
            lambda: self.wf._exec_step(step, {}, resume,
                                       concurrent=True)[0])
        futures[fut] = (node.name, None)

    def _revoke(self, futures, nodes, state) -> None:
        """Cancel queued-but-unstarted pool work (running steps finish
        their unit and keep their markers)."""
        for fut, (name, shard) in list(futures.items()):
            if fut.cancel():
                del futures[fut]
                if shard is None:
                    state[name] = "skipped"
                else:
                    self.wf._emit(f"{name}#{shard}", "skipped",
                                  kind="branch", of=name, branch=shard,
                                  reason="cancelled")

    def _run_only(self, node: Node, resume: bool, stop) -> Dict[str, Any]:
        """PPoDS isolation: run ONE node, its dependencies resolved from
        their stored outputs (clear error when a dep never completed)."""
        for d in node.deps:
            if not self._marker_done(d):
                raise RuntimeError(
                    f"workflow {self.wf.name!r}: step {node.name!r} "
                    f"depends on {d!r}, which has not completed — run it "
                    f"first or drop only=")
            self._outputs[d] = self.wf._load_output(Step(d, None))
        deps_out = _deps_namespace(node, self._outputs)
        if node.when is not None and not self._when(node, deps_out):
            self.wf._emit(node.name, "skipped", reason="when")
            return dict(self._outputs)
        if node.repeat is not None:
            out = self._run_repeat(node, deps_out, resume, stop)
        elif node.scatter_over is not None:
            out = self._only_scatter(node, deps_out, resume, stop)
        else:
            out = self._run_task(node, deps_out, resume)
        if out is not _CANCELLED:
            self._outputs[node.name] = out
            with self.wf._lock:
                self.wf.results.update(self._outputs)
        return dict(self._outputs)

    def _only_scatter(self, node: Node, deps_out, resume: bool, stop):
        if resume and self._marker_done(node.name):
            return self._load_gathered(node)
        items = node.scatter_over if isinstance(node.scatter_over, list) \
            else _resolve_ref(node.scatter_over, deps_out, node.name)
        self.wf._emit(node.name, "scatter", width=len(items))
        outs = []
        with ThreadPoolExecutor(max_workers=self.max_workers) as pool:
            futs = [pool.submit(self._run_shard, node, i, item, deps_out,
                                resume, stop)
                    for i, item in enumerate(items)]
            for fut in futs:
                outs.append(fut.result())   # submission order == index
        if any(o is _CANCELLED for o in outs):
            return _CANCELLED
        gathered = outs
        step = self._step_for(node, fn=lambda ctx: gathered)
        return self.wf._exec_step(step, {}, resume, concurrent=True)[0]


class _Cancelled:
    __slots__ = ()

    def __repr__(self):
        return "<cancelled>"


_CANCELLED = _Cancelled()


def run_graph(wf: Workflow, graph, *, resume: bool = True,
              only: Optional[str] = None, should_stop=None,
              max_workers: int = 8) -> Dict[str, Any]:
    """One-call form: compile ``graph`` (a declarative spec dict or a
    pre-compiled ``GraphProgram``) and execute it on ``wf``."""
    runner = GraphRunner(wf, graph, max_workers=max_workers)
    return runner.run(resume=resume, only=only, should_stop=should_stop)
