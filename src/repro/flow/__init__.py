"""Workflow programs: declarative graph specs compiled to concurrent,
branch-resumable execution plans over the federated fabric.

Layers: ``spec`` validates the ``graph:`` manifest shape (field-naming
``ManifestError``s) and hosts the safe expression language used by
``when:`` / ``repeat.until:``; ``compiler`` resolves the validated spec
into an immutable ``GraphProgram``; ``executor`` schedules ready nodes
concurrently over a bounded pool on top of a ``Workflow`` substrate,
keeping per-step marker semantics so fan-out branches resume
individually."""
from repro.flow.compiler import GraphProgram, Node, RepeatSpec, compile_graph
from repro.flow.executor import GraphRunner, flatten, run_graph
from repro.flow.spec import eval_expr, expr_roots, parse_expr, validate_graph

__all__ = [
    "GraphProgram", "GraphRunner", "Node", "RepeatSpec", "compile_graph",
    "eval_expr", "expr_roots", "flatten", "parse_expr", "run_graph",
    "validate_graph",
]
