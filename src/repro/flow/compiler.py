"""Compile a validated ``graph:`` spec into an executable program.

The compiler is deliberately thin: validation (``repro.flow.spec``)
already proved the shape, so compilation is resolution — entrypoint
strings become callables, condition strings become parsed ``ast``
trees, nested graphs become nested ``GraphProgram``s — producing
immutable ``Node`` records the executor schedules.  Scatter widths are
*not* resolved here: ``scatter.over`` may reference an upstream output
that only exists at run time, so fan-out expansion belongs to the
executor."""
from __future__ import annotations

import ast
from dataclasses import dataclass, field
from typing import (Any, Callable, Dict, List, Mapping, Optional, Sequence,
                    Tuple, Union)

from repro.flow.spec import parse_expr, validate_graph


@dataclass(frozen=True)
class RepeatSpec:
    times: Optional[int] = None          # fixed iteration count, or
    until: Optional[ast.Expression] = None   # stop expression ...
    max_iters: Optional[int] = None          # ... with its hard bound

    @property
    def bound(self) -> int:
        return self.times if self.times is not None else self.max_iters


@dataclass(frozen=True)
class Node:
    """One compiled program node (a task, fan-out, loop or subworkflow)."""
    name: str
    deps: Tuple[str, ...] = ()
    fn: Optional[Callable] = None        # task body: fn(ctx, **params)
    params: Mapping[str, Any] = field(default_factory=dict)
    when: Optional[ast.Expression] = None
    scatter_over: Optional[Union[str, List[Any]]] = None
    repeat: Optional[RepeatSpec] = None
    subgraph: Optional["GraphProgram"] = None
    pods: int = 1
    devices_per_pod: int = 0
    inputs: Tuple[str, ...] = ()         # placement keys ({item}/{index}
    outputs: Tuple[str, ...] = ()        # substituted per scatter shard)
    # After subworkflow flattening, dep names are fully qualified
    # ("report.render") but the node's fn / when: / scatter.over were
    # written against LOCAL sibling names ("render"): local_deps holds
    # the local alias for each entry of ``deps`` (empty = identical).
    local_deps: Tuple[str, ...] = ()


@dataclass(frozen=True)
class GraphProgram:
    nodes: Dict[str, Node]               # insertion-ordered

    @property
    def size(self) -> int:
        """Static node count, nested subworkflows included (scatter
        widths are run-time values and count as one here)."""
        return sum(1 + (n.subgraph.size if n.subgraph else 0)
                   for n in self.nodes.values())

    def dependents(self) -> Dict[str, List[str]]:
        out: Dict[str, List[str]] = {name: [] for name in self.nodes}
        for n in self.nodes.values():
            for d in n.deps:
                out[d].append(n.name)
        return out


def compile_graph(graph: Mapping[str, Any], *,
                  field_path: str = "spec.graph") -> GraphProgram:
    """Validate + compile one declarative graph spec.  Raises
    ``ManifestError`` (bad shape) or the entrypoint's import error
    surfaced as ``ManifestError`` via ``resolve_entrypoint``."""
    from repro.api.resources import resolve_entrypoint
    validate_graph(graph, field=field_path)
    nodes: Dict[str, Node] = {}
    for i, raw in enumerate(graph["nodes"]):
        name = raw["step"]
        fn = raw.get("fn")
        if fn is None and raw.get("entrypoint") is not None:
            fn = resolve_entrypoint(raw["entrypoint"])
        sub = None
        if raw.get("graph") is not None:
            sub = compile_graph(
                raw["graph"],
                field_path=f"{field_path}.nodes[{i}].graph")
        repeat = None
        if raw.get("repeat") is not None:
            r = raw["repeat"]
            repeat = RepeatSpec(
                times=r.get("times"),
                until=(parse_expr(r["until"],
                                  f"{field_path}.nodes[{i}].repeat.until")
                       if r.get("until") is not None else None),
                max_iters=r.get("max"))
        when = None
        if raw.get("when") is not None:
            when = parse_expr(raw["when"],
                              f"{field_path}.nodes[{i}].when")
        scatter = raw.get("scatter")
        nodes[name] = Node(
            name=name, deps=tuple(raw.get("deps", ())), fn=fn,
            params=dict(raw.get("params") or {}), when=when,
            scatter_over=(list(scatter["over"])
                          if scatter is not None and
                          isinstance(scatter["over"], (list, tuple))
                          else scatter["over"] if scatter is not None
                          else None),
            repeat=repeat, subgraph=sub,
            pods=raw.get("pods", 1),
            devices_per_pod=raw.get("devices_per_pod", 0),
            inputs=tuple(raw.get("inputs", ())),
            outputs=tuple(raw.get("outputs", ())))
    return GraphProgram(nodes=nodes)
