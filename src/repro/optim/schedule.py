"""LR schedules as pure functions of the step counter."""
from __future__ import annotations

import jax.numpy as jnp

from repro.configs.base import OptimizerConfig


def learning_rate(ocfg: OptimizerConfig, step) -> jnp.ndarray:
    step = jnp.asarray(step, jnp.float32)
    warm = jnp.maximum(ocfg.warmup_steps, 1)
    warmup = step / warm
    if ocfg.schedule == "constant":
        decay = jnp.ones_like(step)
    elif ocfg.schedule == "linear":
        t = jnp.clip((step - warm) / jnp.maximum(ocfg.decay_steps - warm, 1), 0, 1)
        decay = 1.0 - t
    else:  # cosine
        t = jnp.clip((step - warm) / jnp.maximum(ocfg.decay_steps - warm, 1), 0, 1)
        decay = 0.5 * (1.0 + jnp.cos(jnp.pi * t))
    return ocfg.lr * jnp.minimum(warmup, 1.0) * jnp.where(step < warm, 1.0, decay)
