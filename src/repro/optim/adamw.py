"""AdamW with memory recipes for 1T-scale state (see DESIGN.md):

  moment_dtype:  float32 | bfloat16 | int8 (blockwise-quantized, bnb-style)
  second_moment: full | factored (Adafactor-style row/col factorization)

Optimizer state is schema-described (like params), so the dry-run can derive
abstract state + NamedShardings without allocating anything; ZeRO sharding is
inherited from the param logical axes.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import OptimizerConfig
from repro.kernels.common import fused_adamw_default, interpret_default
from repro.models.params import PSpec, is_pspec
from repro.optim import quant
from repro.optim.schedule import learning_rate


# ---------------------------------------------------------------------------
# state schema
# ---------------------------------------------------------------------------

def _moment_schema(p: PSpec, ocfg: OptimizerConfig):
    if ocfg.moment_dtype == "int8":
        _, s_shape = quant.quantized_shapes(p.shape)
        s_axes = p.axes[:-1] + (None,) if p.shape else p.axes
        return {"q": PSpec(p.shape, p.axes, "zeros", dtype="int8"),
                "s": PSpec(s_shape, s_axes[:len(s_shape)], "zeros",
                           dtype="float32")}
    return PSpec(p.shape, p.axes, "zeros", dtype=ocfg.moment_dtype)


def _second_moment_schema(p: PSpec, ocfg: OptimizerConfig):
    # Factor the last two dims (Adafactor) — but only when the PER-LAYER
    # slice is >= 2-D (a stacked (G, D) norm scale is effectively 1-D; its
    # "vc" would have a non-layer leading dim and break the layered update
    # scan) and the tensor is big enough to be worth it.
    layered = bool(p.axes) and p.axes[0] == "layers"
    eff_ndim = len(p.shape) - (1 if layered else 0)
    import numpy as _np
    if (ocfg.second_moment == "factored" and eff_ndim >= 2
            and int(_np.prod(p.shape)) >= (1 << 16)):
        return {"vr": PSpec(p.shape[:-1], p.axes[:-1], "zeros", dtype="float32"),
                "vc": PSpec(p.shape[:-2] + (p.shape[-1],),
                            p.axes[:-2] + (p.axes[-1],), "zeros",
                            dtype="float32")}
    return _moment_schema(p, ocfg)


def opt_state_schema(param_schema, ocfg: OptimizerConfig) -> Dict[str, Any]:
    def rec(node, fn):
        if is_pspec(node):
            return fn(node)
        return {k: rec(v, fn) for k, v in node.items()}

    return {
        "m": rec(param_schema, lambda p: _moment_schema(p, ocfg)),
        "v": rec(param_schema, lambda p: _second_moment_schema(p, ocfg)),
        "count": PSpec((), (), "zeros", dtype="int32"),
    }


# ---------------------------------------------------------------------------
# leaf math
# ---------------------------------------------------------------------------

def _load_moment(m):
    return quant.dequantize(m) if isinstance(m, dict) and "q" in m else \
        m.astype(jnp.float32)


def _store_moment(val, like):
    if isinstance(like, dict) and "q" in like:
        return quant.quantize(val)
    return val.astype(like.dtype)


def _update_leaf(pspec: PSpec, param, grad, m, v, lr, ocfg: OptimizerConfig,
                 bc1, bc2):
    g = grad.astype(jnp.float32)
    m_f = _load_moment(m)
    m_new = ocfg.b1 * m_f + (1.0 - ocfg.b1) * g

    factored = isinstance(v, dict) and "vr" in v
    if factored:
        g2 = jnp.square(g) + 1e-30
        vr = ocfg.b2 * v["vr"] + (1.0 - ocfg.b2) * jnp.mean(g2, axis=-1)
        vc = ocfg.b2 * v["vc"] + (1.0 - ocfg.b2) * jnp.mean(g2, axis=-2)
        r = vr / jnp.maximum(jnp.mean(vr, axis=-1, keepdims=True), 1e-30)
        v_hat = r[..., None] * vc[..., None, :]
        v_new = {"vr": vr, "vc": vc}
    else:
        v_f = _load_moment(v)
        v_hat = ocfg.b2 * v_f + (1.0 - ocfg.b2) * jnp.square(g)
        v_new = _store_moment(v_hat, v)

    update = (m_new / bc1) / (jnp.sqrt(v_hat / bc2) + ocfg.eps)
    if ocfg.weight_decay and len(pspec.shape) >= 2:
        update = update + ocfg.weight_decay * param.astype(jnp.float32)
    new_param = (param.astype(jnp.float32) - lr * update).astype(param.dtype)
    return new_param, _store_moment(m_new, m), v_new


# ---------------------------------------------------------------------------
# public API
# ---------------------------------------------------------------------------

def global_norm(tree) -> jax.Array:
    """sqrt(sum of squares), f32-ACCUMULATED without materializing f32
    copies of the leaves, and WITHOUT reshaping (a reshape-to-1D of a
    multi-axis-sharded tensor forces GSPMD to all-gather it; an all-axes
    einsum contraction keeps the shards in place and all-reduces a scalar)."""
    def sumsq(x):
        letters = "abcdefghij"[:x.ndim]
        return jnp.einsum(f"{letters},{letters}->", x, x,
                          preferred_element_type=jnp.float32)
    return jnp.sqrt(sum(sumsq(x) for x in jax.tree.leaves(tree)))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    # multiply in the grad's own dtype: no whole-tree f32 copies
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), norm


def apply_updates(param_schema, params, grads, state, ocfg: OptimizerConfig,
                  *, fused: Optional[bool] = None):
    """One AdamW step.  Returns (new_params, new_state, stats).

    Memory: the elementwise update math runs in f32, so applying it to a
    whole 61-layer-stacked tensor materializes several full-tree f32 temps
    (observed: ~6x params bytes on the 1T arch).  Leaves whose leading axis
    is the stacked "layers" dim are therefore updated with a lax.scan over
    that axis — peak update temps shrink by num_groups.

    ``fused``: route plain float32/full-state leaves through the fused
    Pallas update kernel (``kernels.adamw_update``) — one elementwise
    kernel per leaf, no f32 temp trees AND no layered scan needed.
    None = backend default (TPU on, CPU off; ``REPRO_FUSED_ADAMW=1``
    forces it on CPU under interpret mode).  Quantized / factored state
    always keeps the unfused path.
    """
    if fused is None:
        fused = fused_adamw_default()
    if ocfg.grad_clip:
        grads, gnorm = clip_by_global_norm(grads, ocfg.grad_clip)
    else:
        gnorm = global_norm(grads)
    count = state["count"] + 1
    lr = learning_rate(ocfg, count)
    t = count.astype(jnp.float32)
    bc1 = 1.0 - ocfg.b1 ** t
    bc2 = 1.0 - ocfg.b2 ** t

    def leaf(sch, p, g, m, v):
        # the fused kernel streams tiles through VMEM, so even stacked
        # "layers" leaves go through whole (no scan, no temp blowup)
        if (fused and not isinstance(m, dict) and not isinstance(v, dict)
                and m.dtype == jnp.float32 and v.dtype == jnp.float32):
            from repro.kernels.adamw_update import adamw_update
            wd = ocfg.weight_decay if len(sch.shape) >= 2 else 0.0
            return adamw_update(p, g, m, v, lr, bc1, bc2, b1=ocfg.b1,
                                b2=ocfg.b2, eps=ocfg.eps, weight_decay=wd,
                                interpret=interpret_default())
        layered = (sch.axes and sch.axes[0] == "layers"
                   and len(sch.shape) >= 2 and sch.shape[0] > 1)
        if not layered:
            return _update_leaf(sch, p, g, m, v, lr, ocfg, bc1, bc2)
        inner = PSpec(sch.shape[1:], sch.axes[1:], sch.init, sch.scale,
                      sch.dtype)

        def step(_, xs):
            return None, _update_leaf(inner, *xs, lr, ocfg, bc1, bc2)

        _, (np_, nm, nv) = jax.lax.scan(step, None, (p, g, m, v))
        return np_, nm, nv

    def rec(sch, p, g, m, v):
        if is_pspec(sch):
            return leaf(sch, p, g, m, v)
        out = {k: rec(sch[k], p[k], g[k], m[k], v[k]) for k in sch}
        new_p = {k: out[k][0] for k in out}
        new_m = {k: out[k][1] for k in out}
        new_v = {k: out[k][2] for k in out}
        return new_p, new_m, new_v

    new_params, new_m, new_v = rec(param_schema, params, grads,
                                   state["m"], state["v"])
    new_state = {"m": new_m, "v": new_v, "count": count}
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
