"""Blockwise int8 quantization for optimizer moments / gradient compression.

Block = 128 along the last dim when divisible (TPU-lane aligned), else the
whole last dim.  Symmetric absmax scaling, stored as {"q": int8, "s": f32}.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

BLOCK = 128


def block_size(last_dim: int) -> int:
    return BLOCK if last_dim % BLOCK == 0 else last_dim


def quantize(x: jax.Array) -> dict:
    b = block_size(x.shape[-1]) if x.ndim else 1
    xb = x.reshape(x.shape[:-1] + (x.shape[-1] // b, b)) if x.ndim else x.reshape(1, 1)
    s = jnp.max(jnp.abs(xb), axis=-1, keepdims=True) / 127.0
    s = jnp.maximum(s, 1e-12)
    q = jnp.clip(jnp.round(xb / s), -127, 127).astype(jnp.int8)
    return {"q": q.reshape(x.shape) if x.ndim else q.reshape(()),
            "s": s[..., 0].astype(jnp.float32)}


def dequantize(qs: dict, shape=None) -> jax.Array:
    q, s = qs["q"], qs["s"]
    if q.ndim == 0:
        return q.astype(jnp.float32) * s.reshape(())
    b = q.shape[-1] // max(s.shape[-1], 1)
    qb = q.reshape(q.shape[:-1] + (s.shape[-1], b)).astype(jnp.float32)
    out = qb * s[..., None]
    return out.reshape(q.shape)


def quantized_shapes(shape: tuple, ndim_ok: bool = True):
    """(q_shape, s_shape) for a tensor of `shape`."""
    if not shape:
        return shape, ()
    b = block_size(shape[-1])
    return shape, shape[:-1] + (shape[-1] // b,)
