"""Self-healing elastic training (paper §V: "nodes can join and leave the
cluster at any time").

``ElasticTrainer`` runs training as a *supervised Job* on the Kubernetes-style
``repro.core.orchestrator.Cluster``: a churn controller watches node events;
on failure the affected pods are drained, a rescale plan shrinks the mesh's
data axis over the survivors, state is restored from the latest checkpoint
onto the new shardings, and gradient accumulation is raised so the global
batch stays constant — then the mesh scales back up when nodes rejoin.

Modules:
  * ``batch``      — global-batch-invariant accumulation math (BatchPlan)
  * ``controller`` — ChurnController: node-churn events -> rescale decisions
  * ``trainer``    — ElasticTrainer: the supervised training control loop
"""
from repro.elastic.batch import BatchPlan, batch_plan
from repro.elastic.controller import ChurnController, Decision
from repro.elastic.trainer import (ElasticRunReport, ElasticTrainer,
                                   ElasticTrainSpec, SegmentRecord,
                                   UnschedulableError)

__all__ = [
    "BatchPlan", "batch_plan",
    "ChurnController", "Decision",
    "ElasticRunReport", "ElasticTrainer", "ElasticTrainSpec", "SegmentRecord",
    "UnschedulableError",
]
