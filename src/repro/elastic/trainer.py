"""ElasticTrainer — training as a supervised, self-healing Cluster Job.

The paper's §V contract ("nodes can join and leave the cluster at any time
... pods will be rescheduled ... re-spawn them if any errors occur") applied
to SPMD training, with no human in the loop:

    +-------------------- ElasticTrainer.run() ---------------------+
    |  ChurnController.wait_for_capacity()                          |
    |        |                                                      |
    |        v            submit(JobSpec(segment))                  |
    |  Decision(plan, batch) ------------------> Cluster pod        |
    |        ^                                     |                |
    |        |   supervise: poll pod + decide()    |  train steps   |
    |        |     - node joined & bigger mesh     |  ckpt every k  |
    |        |       -> graceful preempt (save)    |                |
    |        |     - fail_node drained the pod     |                |
    |        |       -> pod FAILED, lease freed    |                |
    |        +---- restore latest ckpt onto the ---+                |
    |              NEW mesh, accum rescaled so                      |
    |              batch x accum stays constant                     |
    +---------------------------------------------------------------+

Each *segment* is one pod: it builds the mesh from its leased devices,
restores the newest checkpoint onto the new shardings (the checkpointer is
mesh-agnostic), and steps until it finishes, is preempted (scale-up), or is
drained (node failure).  The data pipeline is stateless (batch i is a pure
function of the seed), so a restored segment re-sees exactly the batches the
lost one saw — the optimizer trajectory is identical across any churn
schedule, modulo re-executed steps since the last checkpoint (measured as
``steps_lost`` in the run report).
"""
from __future__ import annotations

import dataclasses
import threading
import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

import jax

from repro.checkpoint.checkpoint import Checkpointer
from repro.configs.base import (ModelConfig, OptimizerConfig, ParallelConfig,
                                ShapeConfig)
from repro.core.elastic import make_elastic_mesh
from repro.core.metrics import Registry
from repro.core.orchestrator import Cluster, JobSpec, Pod, PodState
from repro.data.objectstore import ObjectStore
from repro.data.tokens import ChunkPrefetcher, TokenPipeline
from repro.elastic.batch import BatchPlan
from repro.elastic.controller import ChurnController, Decision
from repro.models import params as pr
from repro.optim import adamw
from repro.runtime import steps as steps_mod


@dataclass
class ElasticTrainSpec:
    cfg: ModelConfig
    par: ParallelConfig
    ocfg: OptimizerConfig
    steps: int
    seq_len: int = 64
    global_batch: int = 16
    mesh_axes: Tuple[str, ...] = ("data", "model")
    base_shape: Tuple[int, ...] = (1, 1)   # preferred full-cluster mesh
    max_data: Optional[int] = None         # cap the data axis (launchers)
    name: str = "elastic-train"
    namespace: str = "elastic"
    ckpt_every: int = 5                    # periodic async saves (durability)
    keep: Optional[int] = 3
    log_every: int = 10
    # Device-resident hot loop: optimizer steps fused into ONE dispatch
    # (lax.scan with on-device carry — runtime.steps.build_train_chunk).
    # Host syncs per step drop to O(1/device_steps); the cost is that
    # should_stop/fail/preemption are only observed at chunk boundaries,
    # so preemption latency is bounded by one chunk.  ckpt_every and
    # log_every are snapped UP to multiples of device_steps.
    device_steps: int = 1
    prefetch_depth: int = 2                # chunks in flight beyond current
    seed: int = 0
    data_seed: int = 17
    fail_at: int = -1                      # inject ONE crash at this step
    backoff_limit: int = 2                 # non-churn failures tolerated
    # A drained pod's node is "dead": by default it does NOT write a final
    # checkpoint (recovery cost = steps since the last periodic save, the
    # honest number).  Graceful scale-up preemptions always save.
    save_on_drain: bool = False
    rejoin_timeout_s: float = 60.0
    poll_s: float = 0.02
    join_timeout_s: float = 120.0
    verbose: bool = True


@dataclass
class SegmentRecord:
    index: int
    start: int
    end: int                  # last executed step (start-1 if none ran)
    mesh_shape: Tuple[int, ...]
    accum_steps: int
    microbatch: int
    global_batch: int
    wall_s: float
    outcome: str              # done | preempted | node-failure | error
    # seconds from segment start to the FIRST chunk's results being ready
    # (restore + compile + first dispatch): the preemption-restart latency
    # a rescale pays before producing anything
    t_first_s: float = 0.0

    @property
    def steps_run(self) -> int:
        return max(0, self.end - self.start + 1)


@dataclass
class ElasticRunReport:
    global_batch: int = 0
    seq_len: int = 0
    steps: int = 0
    segments: List[SegmentRecord] = field(default_factory=list)
    recoveries: int = 0               # node-churn induced restarts
    steps_lost: int = 0               # re-executed since last checkpoint
    recovery_s: List[float] = field(default_factory=list)
    total_wall_s: float = 0.0
    # host round-trips during training: one per chunk dispatch + one per
    # loss flush / first-chunk latency probe.  The hot-loop win the bench
    # trajectory tracks: per-step dispatch is O(steps), chunked dispatch
    # is O(steps / device_steps).
    host_syncs: int = 0

    @property
    def tokens_executed(self) -> int:
        return sum(s.steps_run for s in self.segments) * \
            self.global_batch * self.seq_len

    @property
    def tokens_useful(self) -> int:
        return self.steps * self.global_batch * self.seq_len

    @property
    def tokens_per_s(self) -> float:
        """Useful tokens/s: the trained run's throughput including every
        recovery cost (restore, recompile, re-executed steps)."""
        return self.tokens_useful / max(self.total_wall_s, 1e-9)

    @property
    def steps_executed(self) -> int:
        return sum(s.steps_run for s in self.segments)

    @property
    def host_syncs_per_step(self) -> float:
        return self.host_syncs / max(self.steps_executed, 1)

    @property
    def t_first_s(self) -> float:
        """Time-to-first-step of the run: restore + compile + first
        dispatch of the FIRST segment (later segments' t_first_s measure
        per-recovery restart latency instead)."""
        return self.segments[0].t_first_s if self.segments else 0.0

    @property
    def global_batch_constant(self) -> bool:
        return all(s.global_batch == self.global_batch and
                   s.microbatch * s.accum_steps == self.global_batch
                   for s in self.segments)

    def to_json(self) -> Dict[str, Any]:
        return {
            "steps": self.steps,
            "global_batch": self.global_batch,
            "seq_len": self.seq_len,
            "segments": [dataclasses.asdict(s) for s in self.segments],
            "recoveries": self.recoveries,
            "steps_lost": self.steps_lost,
            "recovery_s": [round(r, 3) for r in self.recovery_s],
            "total_wall_s": round(self.total_wall_s, 3),
            "tokens_per_s": round(self.tokens_per_s, 1),
            "tokens_executed": self.tokens_executed,
            "global_batch_constant": self.global_batch_constant,
            "host_syncs": self.host_syncs,
            "host_syncs_per_step": round(self.host_syncs_per_step, 4),
            "t_first_s": round(self.t_first_s, 3),
        }


class UnschedulableError(RuntimeError):
    """A segment's submit was rejected (stale plan, quota, no devices) —
    retryable by replanning, unlike other trainer RuntimeErrors."""


@dataclass
class _SegmentResult:
    start: int
    last: int                 # last executed step (start-1 if none)
    done: bool
    preempted: bool
    # perf_counter after the first CHUNK's results are ready.  One block,
    # once: blocking per step inside a chunk would serialize the scanned
    # dispatch, and blocking a second time would double-count the compile
    # that the first dispatch already paid.
    t_first_done: Optional[float]
    wall_s: float
    host_syncs: int = 0
    t_first_s: float = 0.0    # t_first_done relative to segment start


def snap_cadence(every: int, device_steps: int) -> int:
    """Snap a per-step cadence UP to chunk granularity (0 = off stays off).
    Checkpoint/log actions only happen at chunk boundaries, so the
    effective cadence is the smallest multiple of ``device_steps`` >= the
    requested one."""
    if not every:
        return 0
    k = max(device_steps, 1)
    return ((every + k - 1) // k) * k


def chunk_schedule(start: int, steps: int, device_steps: int):
    """Chunks covering [start, steps), aligned to the ABSOLUTE step grid
    (boundaries at multiples of device_steps from step 0), so snapped
    cadences fire exactly on boundaries no matter where a restore lands.
    First/last chunks may be partial."""
    k = max(device_steps, 1)
    out, i = [], start
    while i < steps:
        bound = min(steps, (i // k + 1) * k)
        out.append((i, bound - i))
        i = bound
    return out


# chunk-cadence helpers are shared with the RL learner (repro.rl.learner
# rides the same device-resident hot loop); the old private names remain
# for in-module callers
_snap = snap_cadence
_chunk_schedule = chunk_schedule


class ElasticTrainer:
    """Supervised elastic training on a Cluster.  See module docstring."""

    def __init__(self, cluster: Cluster, spec: ElasticTrainSpec, *,
                 store: Optional[ObjectStore] = None,
                 metrics: Optional[Registry] = None,
                 report: Optional[ElasticRunReport] = None,
                 stop: Optional[threading.Event] = None):
        self.cluster = cluster
        self.spec = spec
        # cooperative cancel (repro.api Handle.cancel): when set, the
        # supervisor preempt-drains the live segment (which checkpoints on
        # the way out — the hardware is healthy) and run() returns the
        # partial result instead of resubmitting
        self._stop = stop or threading.Event()
        self._ephemeral_store = store is None
        if store is None:
            import tempfile
            store = ObjectStore(tempfile.mkdtemp(prefix="elastic-ckpt-"))
        self.store = store
        self.ckpt = Checkpointer(store, keep=spec.keep)
        self.metrics = metrics or cluster.metrics
        self.controller = ChurnController(
            cluster, axes=spec.mesh_axes, base_shape=spec.base_shape,
            global_batch=spec.global_batch, max_data=spec.max_data)
        # a caller-provided report continues a run that escalated off a
        # dead cluster (repro.fabric cross-site failover): segments, losses
        # lost and wall time keep accumulating across sites
        self.report = report or ElasticRunReport(
            global_batch=spec.global_batch, seq_len=spec.seq_len,
            steps=spec.steps)
        self.shape = ShapeConfig("elastic", spec.seq_len, spec.global_batch,
                                 "train")
        self.cfg = steps_mod.resolve_cfg(spec.cfg, self.shape)
        mod = steps_mod._model_module(self.cfg)
        self.schema = mod.lm_schema(self.cfg)
        self.opt_schema = adamw.opt_state_schema(self.schema, spec.ocfg)
        self.progress = -1                # last completed step, any segment
        self._seg_start = 0               # current segment's restore point
        self._seg_last = -1               # current segment's last step
        self._losses: Dict[int, float] = {}     # step -> loss (host)
        self._injected = False
        self._final: Dict[str, Any] = {}

    # ------------------------------------------------------------- segments
    def _abstract(self):
        return {"params": pr.abstract_params(self.schema,
                                             self.cfg.param_dtype),
                "opt": pr.abstract_params(self.opt_schema, "float32")}

    def _train_segment(self, ctx, plan, bplan: BatchPlan,
                       graceful: threading.Event) -> _SegmentResult:
        """One pod: mesh from leased devices, restore, dispatch CHUNKS of
        ``spec.device_steps`` optimizer steps, checkpoint at boundaries.

        The hot loop is device-resident: each dispatch scans device_steps
        optimizer steps with the (params, opt) carry never leaving the
        device, chunk k+1's batches are prefetched + device_put by a
        background thread while chunk k executes, and the host only
        syncs (loss flush, checkpoint, log, stop/fail checks) at chunk
        boundaries — so preemption latency is bounded by one chunk."""
        spec = self.spec
        t0 = time.perf_counter()
        mesh = make_elastic_mesh(plan, ctx.devices)
        ocfg = dataclasses.replace(spec.ocfg, accum_steps=bplan.accum_steps)
        K = max(spec.device_steps, 1)
        bundle = steps_mod.build_train_chunk(self.cfg, spec.par, ocfg, mesh,
                                             self.shape, K)
        # the bundle's OWN shardings, not a recompute: build_train may flip
        # the layout (e.g. pure-FSDP train) and restore must land state
        # exactly where the jitted step expects it
        shardings = {"params": bundle.in_shardings[0],
                     "opt": bundle.in_shardings[1]}
        restored, meta = self.ckpt.restore_latest(self._abstract(), shardings)
        if restored is not None:
            params, opt = restored["params"], restored["opt"]
            start = int(meta["step"]) + 1
            saved_at = int(meta["step"])
        else:
            start, saved_at = 0, -1
        self._seg_start = start       # supervisor-visible even if we crash
        self._seg_last = start - 1    # this segment's own extent, not the
        # run-global progress: a crashed record must not inherit steps an
        # earlier segment executed
        if restored is None:
            with mesh:
                params = jax.jit(
                    lambda k: pr.init_params(self.schema, k,
                                             self.cfg.param_dtype),
                    out_shardings=shardings["params"])(
                        jax.random.key(spec.seed))
                opt = jax.jit(
                    lambda: pr.init_params(self.opt_schema,
                                           jax.random.key(spec.seed + 1),
                                           "float32"),
                    out_shardings=shardings["opt"])()

        # jitted chunk fns cached by chunk length: the steady-state K
        # chunk plus (at most) a shorter head chunk after an unaligned
        # restore and a tail chunk when K doesn't divide spec.steps
        chunk_fns = {K: bundle.jit()}

        def chunk_fn(k):
            if k not in chunk_fns:
                b = steps_mod.build_train_chunk(self.cfg, spec.par, ocfg,
                                                mesh, self.shape, k)
                chunk_fns[k] = b.jit()
            return chunk_fns[k]

        eff_ckpt = _snap(spec.ckpt_every, K)
        eff_log = _snap(spec.log_every, K)
        pipe = TokenPipeline(self.cfg.vocab_size, spec.seq_len,
                             spec.global_batch, seed=spec.data_seed)
        schedule = _chunk_schedule(start, spec.steps, K)
        last = start - 1
        t_first: Optional[float] = None
        preempted = False
        host_syncs = 0
        pending: Dict[int, Any] = {}    # on-device losses since last flush

        def flush_losses():
            # bulk host transfer at points that already sync (checkpoint
            # snapshots, log prints) — pending stays small, so long runs
            # never pin one device buffer per step
            nonlocal host_syncs
            if pending:
                self._losses.update(
                    {k: float(v)
                     for k, v in jax.device_get(pending).items()})
                pending.clear()
                host_syncs += 1

        prefetch = ChunkPrefetcher(pipe, schedule,
                                   sharding=bundle.in_shardings[2],
                                   depth=spec.prefetch_depth)
        try:
            with mesh:
                for cstart, k in schedule:
                    cend = cstart + k - 1
                    if ctx.should_stop():
                        preempted = True
                        break
                    if (cstart <= spec.fail_at <= cend
                            and not self._injected):
                        self._injected = True
                        raise RuntimeError(
                            f"injected failure at step {spec.fail_at}")
                    _, batches = prefetch.get()
                    params, opt, ms = chunk_fn(k)(params, opt, batches)
                    host_syncs += 1         # one dispatch per chunk
                    # losses stay ON DEVICE: a float() here would host-sync
                    # and serialize dispatch (a wash on the synchronous CPU
                    # backend, a real stall on async TPU/GPU dispatch); the
                    # host syncs only on the ckpt/log cadences below.
                    for j in range(k):
                        pending[cstart + j] = ms["loss"][j]
                    last = cend
                    self.progress = cend
                    self._seg_last = cend
                    if t_first is None:
                        jax.block_until_ready(ms["loss"])
                        host_syncs += 1
                        t_first = time.perf_counter()
                    if eff_ckpt and (cend + 1) % eff_ckpt == 0:
                        flush_losses()  # keeps the loss log >= the restore
                        self.ckpt.save_async(cend, {"params": params,
                                                    "opt": opt})
                        saved_at = cend
                    if eff_log and (cstart % eff_log == 0 or
                                    cend == spec.steps - 1):
                        flush_losses()      # includes this chunk's losses
                        loss = self._losses[cend]
                        self.metrics.gauge("elastic/loss", loss)
                        self.metrics.gauge("elastic/step", cend)
                        if spec.verbose:
                            print(f"[elastic] step {cend} loss {loss:.4f} "
                                  f"mesh {plan.new_shape} "
                                  f"accum {bplan.accum_steps}")
            flush_losses()
        finally:
            prefetch.close()
            # count even a crashed segment's round-trips: the report's
            # host_syncs is the run's honest total, failures included
            self.report.host_syncs += host_syncs
        self.ckpt.wait()
        done = (last == spec.steps - 1 and not preempted) or \
            start >= spec.steps
        # graceful preemptions (scale-up) always persist their last step;
        # drained pods only do so when the spec pretends the node survived.
        # A scheduler preemption (ctx.preempt — fair-share eviction via
        # Cluster.preempt_pod) is checkpoint-then-evict by contract: the
        # hardware is healthy, so the goodbye save always happens.
        # A COMPLETED run skips the terminal save when nobody could ever
        # read it (checkpointing off + trainer-owned throwaway store):
        # that save is a full host transfer of params+opt for nothing.
        want_final_save = (not preempted) or graceful.is_set() \
            or ctx.preempt.is_set() or spec.save_on_drain
        if done and self._ephemeral_store and not spec.ckpt_every:
            want_final_save = False
        if last >= start and saved_at != last and want_final_save:
            self.ckpt.save(last, {"params": params, "opt": opt})
        if done:
            self._final = {"params": params, "opt": opt}
        return _SegmentResult(start=start, last=last, done=done,
                              preempted=preempted, t_first_done=t_first,
                              wall_s=time.perf_counter() - t0,
                              host_syncs=host_syncs,
                              t_first_s=(t_first - t0)
                              if t_first is not None else 0.0)

    def _supervise(self, idx: int, decision: Decision) -> Pod:
        """Submit one segment Job and watch it + the cluster until it ends."""
        spec = self.spec
        graceful = threading.Event()
        plan, bplan = decision.plan, decision.batch

        def segment_fn(ctx):
            return self._train_segment(ctx, plan, bplan, graceful)

        # a node can die between the capacity decision and this submit; the
        # stale plan then over-asks and the caller replans on the survivors
        try:
            job = self.cluster.submit(spec.namespace, JobSpec(
                name=f"{spec.name}-seg{idx}", fn=segment_fn, replicas=1,
                devices_per_pod=plan.devices_used,
                backoff_limit=0))   # respawn is OUR job, on a new mesh
        except RuntimeError as e:
            raise UnschedulableError(str(e)) from e
        pod = job.pods[0]
        while pod.state in (PodState.PENDING, PodState.RUNNING):
            time.sleep(spec.poll_s)
            if pod.ctx.stop.is_set() or pod.ctx.preempt.is_set():
                continue        # draining already — never grow a dying pod
            if self._stop.is_set():
                # external cancel: checkpoint-then-evict the segment
                # (ctx.preempt guarantees the goodbye save), and
                # _run_segments will NOT resubmit
                self.cluster.preempt_pod(pod, reason="stop requested")
                continue
            try:
                grow = self.controller.decide(decision)
            except RuntimeError:
                # total-loss churn mid-poll (fewer devices than one model
                # replica): no grow — the drain path ends this segment and
                # run()'s wait_for_capacity rides out the outage
                grow = None
            if grow is not None:
                # nodes rejoined and a larger mesh fits: preempt gracefully
                graceful.set()
                pod.ctx.stop.set()
        # the segment thread MUST be dead before the next segment starts:
        # two live segments would race on the shared Checkpointer and the
        # trainer's progress/loss state.  A drained thread exits at its next
        # step boundary (or after the in-flight compile), so keep waiting —
        # and if it truly wedges, fail loudly rather than corrupt the run.
        if pod.thread is not None:
            for _ in range(3):
                pod.thread.join(timeout=spec.join_timeout_s)
                if not pod.thread.is_alive():
                    break
                if spec.verbose:
                    print(f"[elastic] segment {idx}: waiting for the "
                          f"drained pod thread to exit...")
            if pod.thread.is_alive():
                raise RuntimeError(
                    f"segment {idx} thread did not exit within "
                    f"{3 * spec.join_timeout_s:.0f}s of its drain — "
                    f"refusing to start a concurrent segment")
        return pod

    # ----------------------------------------------------------------- stop
    def request_stop(self) -> None:
        """Cooperative cancel: the live segment is preempt-drained (it
        checkpoints and exits), no further segment is submitted, and
        ``run()`` returns the partial result."""
        self._stop.set()

    @property
    def stopped(self) -> bool:
        return self._stop.is_set()

    # ------------------------------------------------------------------ run
    def run(self) -> Dict[str, Any]:
        """Train to ``spec.steps`` across any node-churn schedule.

        Raises ``CapacityLostError`` (from the controller) when the whole
        cluster drops below one model replica for longer than the rejoin
        window — partial progress stays in the report/store so a
        federation supervisor can resume the job on another site."""
        spec = self.spec
        if spec.namespace not in self.cluster.namespaces:
            self.cluster.create_namespace(spec.namespace)
        t_run0 = time.perf_counter()
        try:
            self._run_segments(len(self.report.segments))
        finally:
            # wall time ACCUMULATES (not assigns): a job escalated across
            # sites keeps every site's seconds on its clock
            self.report.total_wall_s += time.perf_counter() - t_run0
        assert self.report.global_batch_constant, \
            "elastic invariant violated: global batch changed across meshes"
        if self._ephemeral_store and not self._stop.is_set():
            # trainer-owned throwaway checkpoint dir: don't leak /tmp space
            # run after run (kept on error paths — raises above — and on
            # cancel, so the goodbye checkpoint survives for a resume)
            import shutil
            shutil.rmtree(self.store.root, ignore_errors=True)
        losses = dict(self._losses)
        self.metrics.gauge("elastic/tokens_per_s", self.report.tokens_per_s)
        return {"losses": [losses[i] for i in sorted(losses)],
                "loss_by_step": losses,
                "params": self._final.get("params"),
                "opt": self._final.get("opt"),
                "report": self.report}

    def _run_segments(self, seg_idx: int) -> None:
        spec = self.spec
        failures = 0
        pending_lost_from: Optional[int] = None
        t_fail: Optional[float] = None
        done = False
        unsched_since: Optional[float] = None
        while not done:
            if self._stop.is_set():
                break           # cancelled: the last segment checkpointed
            decision = self.controller.wait_for_capacity(
                spec.rejoin_timeout_s)
            try:
                pod = self._supervise(seg_idx, decision)
            except UnschedulableError as e:  # decision went stale mid-churn
                now = time.monotonic()
                if unsched_since is None:
                    unsched_since = now
                elif now - unsched_since > spec.rejoin_timeout_s:
                    # not transient churn: e.g. a too-small pre-created
                    # namespace quota would otherwise retry forever
                    raise RuntimeError(
                        f"segment unschedulable for "
                        f"{spec.rejoin_timeout_s:.0f}s: {e}") from e
                if spec.verbose:
                    print(f"[elastic] segment {seg_idx} unschedulable "
                          f"({e}) -> replan")
                self.metrics.inc("elastic/replans")
                time.sleep(0.1)     # let the churn settle; never spin hot
                seg_idx += 1
                continue
            unsched_since = None
            res: Optional[_SegmentResult] = pod.result
            if res is not None and pending_lost_from is not None:
                # steps the failure forced us to re-execute
                self.report.steps_lost += max(
                    0, pending_lost_from - res.start + 1)
                if t_fail is not None and res.t_first_done is not None:
                    self.report.recovery_s.append(res.t_first_done - t_fail)
                pending_lost_from, t_fail = None, None
            if pod.state == PodState.FAILED:
                churn = pod.error is not None and "NodeFailure" in pod.error
                if churn:
                    self.report.recoveries += 1
                    self.metrics.inc("elastic/recoveries")
                    if spec.verbose:
                        print(f"[elastic] segment {seg_idx}: {pod.error!s}"
                              .splitlines()[0] + " -> rescale + restore")
                else:
                    failures += 1
                    if failures > spec.backoff_limit:
                        raise RuntimeError(
                            f"elastic training failed after {failures} "
                            f"attempts: {pod.error}")
                    if spec.verbose:
                        print(f"[elastic] segment {seg_idx} failed "
                              f"(attempt {failures}/{spec.backoff_limit}) "
                              f"-> restore + retry")
                pending_lost_from = res.last if res is not None \
                    else self._seg_last
                t_fail = time.perf_counter()
                outcome = "node-failure" if churn else "error"
            elif res is not None and res.done:
                done = True
                outcome = "done"
            else:
                # graceful scale-up preempt OR a fair-share eviction
                # (Cluster.preempt_pod): both checkpointed; the eviction
                # resumes once the vcluster scheduler re-grants devices
                outcome = "preempted"
                if pod.state == PodState.PREEMPTED:
                    self.metrics.inc("elastic/preemptions")
                    if spec.verbose:
                        print(f"[elastic] segment {seg_idx} preempted "
                              f"({pod.error}) -> awaiting re-grant")
            # a crashed pod (res None) is still one segment of history:
            # reconstruct its extent from the trainer-side progress marks
            start = res.start if res is not None else self._seg_start
            end = res.last if res is not None \
                else max(start - 1, self._seg_last)
            self.report.segments.append(SegmentRecord(
                index=seg_idx, start=start, end=end,
                mesh_shape=tuple(decision.plan.new_shape),
                accum_steps=decision.batch.accum_steps,
                microbatch=decision.batch.microbatch,
                global_batch=decision.batch.global_batch,
                wall_s=res.wall_s if res is not None else 0.0,
                outcome=outcome,
                t_first_s=res.t_first_s if res is not None else 0.0))
            seg_idx += 1
