"""ChurnController — node-churn events in, rescale decisions out.

Pure policy, no jax: given the cluster's online device count it computes the
mesh the trainer *should* be on (``repro.core.elastic.rescale_plan`` keeps
every non-data axis fixed — TP/EP layouts are weight-structural) and the
accumulation plan that keeps the global batch constant on it.  The trainer
asks two questions each supervision tick:

  * ``decide(active)`` — is a strictly larger mesh available now (nodes
    rejoined)?  If so, preempt gracefully and rebuild.
  * shrinking never needs polling: a failed node *drains* its pods
    (``Cluster.fail_node``), so the trainer observes the FAILED pod and
    calls ``decide(None)`` to plan the survivor mesh.

It also subscribes to the cluster's watcher hook so every fail/join event is
timestamped in the run report (observability, §VI).
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass
from typing import Any, List, Optional, Tuple

from repro.core.elastic import RescalePlan, rescale_plan
from repro.core.orchestrator import Cluster
from repro.elastic.batch import BatchPlan, batch_plan


class CapacityLostError(RuntimeError):
    """``wait_for_capacity`` exhausted its rejoin window: this cluster can
    no longer host even one model replica (e.g. a whole site unplugged).
    The single-cluster trainer cannot recover from this — it escalates to
    whoever owns more than one cluster (``repro.fabric.failover`` answers
    by moving the job, with its checkpoints, to a surviving site)."""


@dataclass(frozen=True)
class Decision:
    """One controller verdict: the mesh+accum the trainer should run on."""
    plan: RescalePlan
    batch: BatchPlan

    @property
    def n_devices(self) -> int:
        return self.plan.devices_used


@dataclass
class ChurnEvent:
    kind: str                 # "fail" | "join"
    device: Any
    ts: float


class ChurnController:
    def __init__(self, cluster: Cluster, *, axes: Tuple[str, ...],
                 base_shape: Tuple[int, ...], global_batch: int,
                 max_data: Optional[int] = None):
        self.cluster = cluster
        self.axes = tuple(axes)
        self.base_shape = tuple(base_shape)
        self.global_batch = global_batch
        self.max_data = max_data
        self.events: List[ChurnEvent] = []
        self._lock = threading.Lock()
        # per-replica row budget: sized once for the *base* mesh at accum=1,
        # so any smaller mesh raises accumulation instead of its memory use
        i = self.axes.index("data")
        base_data = self.base_shape[i]
        if global_batch % base_data:
            raise ValueError(f"global_batch={global_batch} must tile the "
                             f"base data axis {base_data}")
        self.per_replica = global_batch // base_data
        # the data axis may grow past base_shape when spare nodes join, but
        # never past the largest power-of-two divisor of the global batch —
        # a bigger axis could not shard the batch evenly
        batch_cap = global_batch & -global_batch
        self._data_cap = batch_cap if max_data is None \
            else min(max_data, batch_cap)
        cluster.add_watcher(self._on_event)

    # ------------------------------------------------------------ events
    def _on_event(self, kind: str, device) -> None:
        with self._lock:
            self.events.append(ChurnEvent(kind, device, time.time()))

    # ---------------------------------------------------------- decisions
    def decide(self, active: Optional[Decision] = None) -> Optional[Decision]:
        """The mesh the current cluster supports, or None if unchanged.

        With ``active=None`` always returns a Decision (initial placement or
        post-failure replanning).  With an active Decision, returns a new one
        only when a strictly larger device set is usable — the grow trigger;
        a *smaller* plan is never volunteered here because shrink is driven
        by the drain path (the pod has already failed).
        """
        n = len(self.cluster.online_devices)
        plan = rescale_plan(self.axes, self.base_shape, n,
                            max_data=self._data_cap)
        if active is not None and plan.devices_used <= active.n_devices:
            return None
        i = self.axes.index("data")
        bp = batch_plan(self.global_batch, plan.new_shape[i],
                        per_replica=self.per_replica)
        return Decision(plan, bp)

    def wait_for_capacity(self, timeout: float,
                          poll: float = 0.05) -> Decision:
        """Block until enough nodes exist to host one model replica.

        Covers total-loss churn (every data-parallel rank dead): the paper's
        cluster keeps the Job pending until nodes rejoin; we bound the wait.
        """
        deadline = time.monotonic() + timeout
        while True:
            try:
                return self.decide(None)
            except RuntimeError as e:
                if time.monotonic() >= deadline:
                    raise CapacityLostError(
                        f"no capacity after {timeout:.0f}s: {e}") from e
                time.sleep(poll)
