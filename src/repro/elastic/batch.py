"""Global-batch-invariant gradient accumulation.

The elastic contract: the optimizer trajectory must not depend on how many
nodes happen to be alive.  ``runtime.steps.build_train`` consumes the full
global batch per call and folds it into ``accum_steps`` microbatches, so the
knob that absorbs a mesh reshape is *accumulation*, not batch size:

    global_batch = microbatch x accum_steps            (constant)
    per-replica rows = microbatch / data_axis_size     (bounded by memory)

``batch_plan`` picks the smallest legal ``accum_steps`` for a given data-axis
size so that per-replica microbatch rows never exceed the budget the full
cluster was sized for — shrink the data axis 4 -> 2 and accumulation doubles,
grow it back and accumulation relaxes.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional


@dataclass(frozen=True)
class BatchPlan:
    global_batch: int
    data_size: int            # mesh data-axis size this plan is for
    accum_steps: int

    @property
    def microbatch(self) -> int:
        """Rows per microbatch (across the whole data axis)."""
        return self.global_batch // self.accum_steps

    @property
    def per_replica(self) -> int:
        """Rows per data-parallel replica per microbatch."""
        return self.microbatch // self.data_size

    def check(self) -> "BatchPlan":
        if self.microbatch * self.accum_steps != self.global_batch:
            raise ValueError(f"accum {self.accum_steps} does not divide "
                             f"global batch {self.global_batch}")
        if self.per_replica * self.data_size != self.microbatch:
            raise ValueError(f"data axis {self.data_size} does not divide "
                             f"microbatch {self.microbatch}")
        return self


def batch_plan(global_batch: int, data_size: int, *,
               per_replica: Optional[int] = None) -> BatchPlan:
    """Smallest accumulation keeping per-replica rows <= ``per_replica``.

    ``per_replica=None`` means "no memory bound": accumulation stays at 1
    (the full-cluster case).  Divisibility is enforced by stepping the
    accumulation UP from the bound's minimum — more accumulation only
    shrinks microbatches, so the memory bound is never overshot — until a
    value tiles both the global batch and the data axis; if none exists
    the shapes are simply incompatible and we raise rather than silently
    change the global batch.
    """
    if global_batch % data_size:
        raise ValueError(f"global_batch={global_batch} not divisible by "
                         f"data axis {data_size}")
    if per_replica is None:
        accum = 1
    else:       # ceil: G / (accum * data) <= per_replica
        accum = max(1, -(-global_batch // (per_replica * data_size)))
    while accum <= global_batch and (
            global_batch % accum or (global_batch // accum) % data_size):
        accum += 1
    if accum > global_batch:
        raise ValueError(
            f"no accumulation tiles global_batch={global_batch} over "
            f"data axis {data_size} within per_replica={per_replica}")
    return BatchPlan(global_batch, data_size, accum).check()
