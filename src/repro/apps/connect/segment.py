"""CONNECT — connected-object labeling in time+space (paper §III, refs
[21][22][23]).

CONNECT's insight: earth-science phenomena must be tracked through their
whole life-cycle by connecting pixels in BOTH space and time.  That is 3-D
connected-component labeling over (T, lat, lon) masks with 6-connectivity
(the T links give the life-cycle).

Hardware adaptation (DESIGN.md §2): classic union-find is pointer-chasing
and hostile to TPUs; we use iterative min-label propagation — each voxel
takes the min label of its masked neighbors until fixpoint — expressed as a
``lax.while_loop`` of vectorized shifts: O(diameter) passes of pure
elementwise ops, which is the TPU-idiomatic equivalent.
"""
from __future__ import annotations

from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

_BIG = jnp.int32(2 ** 30)


def _neighbor_min(lbl: jnp.ndarray) -> jnp.ndarray:
    """Min over the 6-neighborhood (T, Y, X), edge-padded with _BIG."""
    out = lbl
    for axis in range(3):
        fwd = jnp.concatenate(
            [jax.lax.slice_in_dim(lbl, 1, lbl.shape[axis], axis=axis),
             jnp.full_like(jax.lax.slice_in_dim(lbl, 0, 1, axis=axis), _BIG)],
            axis=axis)
        bwd = jnp.concatenate(
            [jnp.full_like(jax.lax.slice_in_dim(lbl, 0, 1, axis=axis), _BIG),
             jax.lax.slice_in_dim(lbl, 0, lbl.shape[axis] - 1, axis=axis)],
            axis=axis)
        out = jnp.minimum(out, jnp.minimum(fwd, bwd))
    return out


@jax.jit
def connect_label(mask: jnp.ndarray) -> jnp.ndarray:
    """Label connected objects of a binary (T, Y, X) mask.

    Returns int32 labels: 0 = background, else the (flat-index+1) of the
    object's minimal voxel — stable, order-independent ids.
    """
    mask = mask.astype(bool)
    n = mask.size
    init = jnp.where(mask,
                     jnp.arange(1, n + 1, dtype=jnp.int32).reshape(mask.shape),
                     _BIG)

    def cond(state):
        lbl, changed = state
        return changed

    def body(state):
        lbl, _ = state
        new = jnp.where(mask, jnp.minimum(lbl, _neighbor_min(lbl)), _BIG)
        return new, jnp.any(new != lbl)

    lbl, _ = jax.lax.while_loop(cond, body, (init, jnp.bool_(True)))
    return jnp.where(mask, lbl, 0)


def object_stats(labels: np.ndarray) -> List[Dict]:
    """Life-cycle statistics per object (host-side post-processing, paper
    Step 4): voxels, genesis/termination frame, duration, centroid drift."""
    labels = np.asarray(labels)
    out = []
    for obj in np.unique(labels):
        if obj == 0:
            continue
        t, y, x = np.nonzero(labels == obj)
        out.append({
            "id": int(obj),
            "voxels": int(t.size),
            "genesis_frame": int(t.min()),
            "termination_frame": int(t.max()),
            "duration": int(t.max() - t.min() + 1),
            "centroid": (float(y.mean()), float(x.mean())),
            "drift": float(np.hypot(y[t == t.max()].mean() -
                                    y[t == t.min()].mean(),
                                    x[t == t.max()].mean() -
                                    x[t == t.min()].mean())),
        })
    return sorted(out, key=lambda d: -d["voxels"])
