"""The CONNECT workflow — the paper's §III case study, end to end.

Four steps, exactly the paper's Fig. 2 / Table I structure:

  1. download   N queue-fed worker pods "download" (synthesize) MERRA-like
                IVT chunks into the ObjectStore (THREDDS -> Ceph; Figs 3-4).
  2. train      one device trains the FFN 3-D CNN on labeled subvolumes
                (paper: 1 GPU, 306 min; Fig 5), checkpointed.
  3. inference  M worker pods lease chunks from a queue, run jitted
                flood-fill segmentation, write masks (paper: 50 GPUs,
                Fig 6) — work-stealing == straggler mitigation.
  4. analyze    CONNECT labeling (time+space connected objects) + object
                life-cycle statistics (the JupyterLab step).

``run_connect_workflow`` builds it on a Cluster + ObjectStore; every step
is resumable and measured (wf.table_one() == the paper's Table I).
"""
from __future__ import annotations

import time
from dataclasses import asdict, dataclass, field
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.metrics import Registry
from repro.core.orchestrator import Cluster
from repro.core.queue import WorkQueue, run_workers
from repro.core.workflow import Step, StepCtx, Workflow
from repro.data.objectstore import ObjectStore
from repro.data import volumes
from repro.models import ffn3d
from repro.models.params import init_params, abstract_params
from repro.apps.connect import segment


@dataclass(frozen=True)
class ConnectConfig:
    n_chunks: int = 4
    download_workers: int = 4
    inference_workers: int = 4
    vol: volumes.VolumeSpec = field(default_factory=volumes.VolumeSpec)
    ffn: ffn3d.FFNConfig = field(default_factory=ffn3d.FFNConfig)
    train_steps: int = 60
    train_batch: int = 4
    lr: float = 3e-3
    seed: int = 0


# ---------------------------------------------------------------------------
# step 1: queue-fed "download" (paper: THREDDS -> Redis queue -> aria2 pods)
# ---------------------------------------------------------------------------

def step_download(ctx: StepCtx, cc: ConnectConfig):
    keys = volumes.chunk_keys(cc.n_chunks)
    queue = WorkQueue(list(enumerate(keys)), lease_timeout=60.0)
    t0 = time.perf_counter()
    total = {"bytes": 0}
    # Federated run (repro.fabric): each chunk lands at its "nearest
    # THREDDS mirror" — scattered round-robin across live sites, with one
    # off-site replica so a single site loss never strands the raw data.
    # Single-cluster run: ctx.store has no fabric and writes stay local.
    fed = getattr(ctx.store, "fed", None)
    sites = [s.name for s in fed.fabric.up_sites()] if fed is not None else []

    def fetch(item):
        cid, key = item
        ivt, labels = volumes.generate_chunk(cc.vol, cid)
        if sites:
            home = fed.view(sites[cid % len(sites)])
            n = home.put_array(f"{key}/ivt.npy", ivt)
            n += home.put_array(f"{key}/labels.npy", labels)
            if len(sites) > 1:
                mirror = sites[(cid + 1) % len(sites)]
                for k in (f"{key}/ivt.npy", f"{key}/labels.npy"):
                    fed.replicate(k, mirror)
        else:
            n = ctx.store.put_array(f"{key}/ivt.npy", ivt)
            n += ctx.store.put_array(f"{key}/labels.npy", labels)
        ctx.metrics.inc("download/bytes", n)
        total["bytes"] += n
        return key

    done = run_workers(queue, fetch, cc.download_workers, name="dl")
    dt = time.perf_counter() - t0
    ctx.report.pods = cc.download_workers
    ctx.report.cpus = cc.download_workers
    ctx.report.data_processed_bytes = total["bytes"]
    ctx.metrics.gauge("download/throughput_MBs",
                      total["bytes"] / 2**20 / max(dt, 1e-9))
    return {"chunks": done, "bytes": total["bytes"]}


# ---------------------------------------------------------------------------
# step 2: FFN training (paper: single GPU, Tensorflow; here: JAX, 1 device)
# ---------------------------------------------------------------------------

def step_train(ctx: StepCtx, cc: ConnectConfig):
    return _train_ffn(ctx, cc, volumes.chunk_keys(cc.n_chunks)[0])


def _train_ffn(ctx: StepCtx, cc: ConnectConfig, key0: str):
    ivt = ctx.store.get_array(f"{key0}/ivt.npy")
    labels = ctx.store.get_array(f"{key0}/labels.npy")
    subs = volumes.subvolumes(ivt, labels, cc.ffn.fov,
                              tuple(max(f // 2, 1) for f in cc.ffn.fov))
    xs = np.stack([s[0] for s in subs])
    ys = np.stack([s[1] for s in subs])
    # keep windows that contain some object (FFN seeds on objects)
    frac = ys.mean(axis=(1, 2, 3))
    keep = np.argsort(-frac)[:max(8, len(subs) // 2)]
    xs, ys = xs[keep], ys[keep]

    schema = ffn3d.ffn_schema(cc.ffn)
    params = init_params(schema, jax.random.key(cc.seed), "float32")

    @jax.jit
    def train_step(params, x, y):
        loss, grads = jax.value_and_grad(
            lambda p: ffn3d.bce_loss(cc.ffn, p, x, y))(params)
        gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g))
                             for g in jax.tree.leaves(grads)))
        scale = jnp.minimum(1.0, 1.0 / jnp.maximum(gnorm, 1e-9))
        params = jax.tree.map(lambda p, g: p - cc.lr * scale * g,
                              params, grads)
        return params, loss

    rng = np.random.RandomState(cc.seed)
    losses = []
    for i in range(cc.train_steps):
        idx = rng.randint(0, len(xs), cc.train_batch)
        params, loss = train_step(params, jnp.asarray(xs[idx]),
                                  jnp.asarray(ys[idx]))
        losses.append(float(loss))
        ctx.metrics.gauge("ffn_train/loss", float(loss))
    # persist the trained model (paper: model saved to Ceph for inference)
    flat, _ = jax.tree_util.tree_flatten_with_path(params)
    for path, leaf in flat:
        name = "/".join(str(getattr(p, "key", p)) for p in path)
        ctx.store.put_array(f"models/ffn/{name}.npy", np.asarray(leaf))
    ctx.report.devices = 1
    ctx.report.data_processed_bytes = xs.nbytes
    ctx.report.memory_bytes = xs.nbytes + ys.nbytes
    return {"first_loss": losses[0], "last_loss": losses[-1],
            "n_windows": int(len(xs))}


def _load_ffn_params(store: ObjectStore, cc: ConnectConfig):
    schema = ffn3d.ffn_schema(cc.ffn)
    ab = abstract_params(schema, "float32")
    flat, treedef = jax.tree_util.tree_flatten_with_path(ab)
    leaves = []
    for path, _ in flat:
        name = "/".join(str(getattr(p, "key", p)) for p in path)
        leaves.append(jnp.asarray(store.get_array(f"models/ffn/{name}.npy")))
    return jax.tree_util.tree_unflatten(treedef, leaves)


# ---------------------------------------------------------------------------
# step 3: distributed inference (paper: 50 GPUs, queue of data shards)
# ---------------------------------------------------------------------------

def _ffn_infer(cc: ConnectConfig, params):
    @jax.jit
    def infer(x):   # x (B,ft,fy,fx)
        return jax.nn.sigmoid(ffn3d.flood_fill(cc.ffn, params, x)) > 0.5
    return infer


def _chunk_mask(ctx: StepCtx, cc: ConnectConfig, infer, key: str) -> int:
    """Segment ONE IVT chunk: tile into FOV windows (stride = fov, no
    overlap), flood-fill each, write the mask.  Returns voxels masked."""
    ft, fy, fx = cc.ffn.fov
    ivt = ctx.store.get_array(f"{key}/ivt.npy")
    T, LA, LO = ivt.shape
    tiles, coords = [], []
    for t in range(0, T - ft + 1, ft):
        for y in range(0, LA - fy + 1, fy):
            for x in range(0, LO - fx + 1, fx):
                tiles.append(ivt[t:t + ft, y:y + fy, x:x + fx])
                coords.append((t, y, x))
    mask = np.zeros_like(ivt, dtype=np.uint8)
    bs = 8
    for i in range(0, len(tiles), bs):
        batch = np.stack(tiles[i:i + bs])
        pred = np.asarray(infer(jnp.asarray(batch)))
        for j, (t, y, x) in enumerate(coords[i:i + bs]):
            mask[t:t + ft, y:y + fy, x:x + fx] = pred[j]
    ctx.store.put_array(f"{key}/mask.npy", mask)
    ctx.metrics.inc("inference/voxels", mask.size)
    return int(mask.size)


def step_inference(ctx: StepCtx, cc: ConnectConfig):
    params = _load_ffn_params(ctx.store, cc)
    keys = volumes.chunk_keys(cc.n_chunks)
    queue = WorkQueue(list(keys), lease_timeout=300.0)
    infer = _ffn_infer(cc, params)
    t0 = time.perf_counter()
    voxels = {"n": 0}

    def run_chunk(key):
        voxels["n"] += _chunk_mask(ctx, cc, infer, key)
        return key

    done = run_workers(queue, run_chunk, cc.inference_workers, name="infer")
    dt = time.perf_counter() - t0
    ctx.report.pods = cc.inference_workers
    ctx.report.devices = cc.inference_workers
    ctx.report.data_processed_bytes = voxels["n"] * 4
    ctx.metrics.gauge("inference/voxels_per_s", voxels["n"] / max(dt, 1e-9))
    return {"chunks": done, "voxels": voxels["n"]}


# ---------------------------------------------------------------------------
# step 4: CONNECT labeling + life-cycle stats (the JupyterLab step)
# ---------------------------------------------------------------------------

def step_analyze(ctx: StepCtx, cc: ConnectConfig):
    return _analyze_keys(ctx, volumes.chunk_keys(cc.n_chunks))


def _analyze_keys(ctx: StepCtx, keys: List[str]):
    all_stats = []
    for key in keys:
        mask = ctx.store.get_array(f"{key}/mask.npy")
        labels = np.asarray(segment.connect_label(jnp.asarray(mask)))
        stats = segment.object_stats(labels)
        ctx.store.put_json(f"{key}/objects.json", stats)
        all_stats.extend(stats)
    ctx.report.data_processed_bytes = sum(
        ctx.store.size(f"{k}/mask.npy") for k in keys)
    n_obj = len(all_stats)
    ctx.metrics.gauge("analyze/objects", n_obj)
    longest = max((s["duration"] for s in all_stats), default=0)
    return {"objects": n_obj, "longest_lifecycle": longest}


# ---------------------------------------------------------------------------

def dataset_keys(cc: ConnectConfig) -> Dict[str, List[str]]:
    """The pipeline's dataset keys, per kind — what federated placement
    scores (which site holds the IVT chunks / model / masks)."""
    keys = volumes.chunk_keys(cc.n_chunks)
    return {"ivt": [f"{k}/ivt.npy" for k in keys],
            "labels": [f"{k}/labels.npy" for k in keys],
            "masks": [f"{k}/mask.npy" for k in keys],
            "model": ["models/ffn/*"]}


def _tupled(d: dict) -> dict:
    """JSON round-trips turn tuple fields (``fov``) into lists; restore
    tuples so dataclass configs hash/compare/unpack as designed."""
    return {k: tuple(v) if isinstance(v, list) else v for k, v in d.items()}


def connect_config(**kw) -> ConnectConfig:
    """ConnectConfig from plain (manifest-shaped) kwargs: nested ``vol``
    / ``ffn`` dicts become their dataclasses."""
    if isinstance(kw.get("vol"), dict):
        kw["vol"] = volumes.VolumeSpec(**_tupled(kw["vol"]))
    if isinstance(kw.get("ffn"), dict):
        kw["ffn"] = ffn3d.FFNConfig(**_tupled(kw["ffn"]))
    return ConnectConfig(**kw)


def add_connect_steps(wf: Workflow, cc=None, **kw) -> Workflow:
    """Attach the paper's 4-step CONNECT DAG to an existing workflow.

    This is the ``repro.api.WorkflowRun`` entrypoint
    (``"repro.apps.connect.pipeline:add_connect_steps"``): ``cc`` may be
    a ConnectConfig, a manifest-shaped dict, or omitted — leftover
    kwargs feed ``connect_config`` so a pure-JSON manifest can size the
    run."""
    if cc is None:
        cc = connect_config(**kw)
    elif isinstance(cc, dict):
        cc = connect_config(**{**cc, **kw})
    ds = dataset_keys(cc)
    wf.add(Step("download", lambda ctx: step_download(ctx, cc),
                pods=cc.download_workers,
                outputs=ds["ivt"] + ds["labels"]))
    wf.add(Step("train", lambda ctx: step_train(ctx, cc), deps=["download"],
                inputs=[ds["ivt"][0], ds["labels"][0]], outputs=ds["model"]))
    wf.add(Step("inference", lambda ctx: step_inference(ctx, cc),
                deps=["train"], pods=cc.inference_workers,
                inputs=ds["ivt"] + ds["model"], outputs=ds["masks"]))
    wf.add(Step("analyze", lambda ctx: step_analyze(ctx, cc),
                deps=["inference"], inputs=ds["masks"]))
    return wf


# ---------------------------------------------------------------------------
# CONNECT as a workflow *program* (repro.flow): scatter the chunks, place
# each fetch/segment branch at its own site, gather for analysis.
# ---------------------------------------------------------------------------

def _cc_of(inputs) -> ConnectConfig:
    """Every graph node downstream of ``plan`` reads the run's config
    from plan's output manifest — one source of truth, JSON round-trip
    safe (resume reloads it from the store)."""
    return connect_config(**inputs["plan"]["cc"])


def g_plan(ctx: StepCtx, **kw):
    cc = connect_config(**kw)
    return {"chunks": volumes.chunk_keys(cc.n_chunks), "cc": asdict(cc)}


def g_fetch(ctx: StepCtx):
    """One scatter branch of the download: synthesize ONE IVT chunk at
    whichever site the planner placed this branch (THREDDS mirror
    analogue — the data homes where it lands)."""
    cc = _cc_of(ctx.inputs)
    cid, key = ctx.inputs["index"], ctx.inputs["item"]
    ivt, labels = volumes.generate_chunk(cc.vol, cid)
    n = ctx.store.put_array(f"{key}/ivt.npy", ivt)
    n += ctx.store.put_array(f"{key}/labels.npy", labels)
    ctx.metrics.inc("download/bytes", n)
    ctx.report.data_processed_bytes = n
    return {"chunk": key, "bytes": n}


def g_train(ctx: StepCtx):
    cc = _cc_of(ctx.inputs)
    return _train_ffn(ctx, cc, ctx.inputs["plan"]["chunks"][0])


def g_segment(ctx: StepCtx):
    """One scatter branch of distributed inference: flood-fill ONE chunk
    (paper's 50-GPU fan-out, here one placed step per chunk)."""
    cc = _cc_of(ctx.inputs)
    params = _load_ffn_params(ctx.store, cc)
    key = ctx.inputs["item"]
    voxels = _chunk_mask(ctx, cc, _ffn_infer(cc, params), key)
    ctx.report.devices = 1
    ctx.report.data_processed_bytes = voxels * 4
    return {"chunk": key, "voxels": voxels}


def g_analyze(ctx: StepCtx):
    return _analyze_keys(ctx, ctx.inputs["plan"]["chunks"])


def connect_graph(**kw) -> dict:
    """The CONNECT pipeline as a five-node declarative workflow program
    (the ``WorkflowRun.spec.graph`` shape): plan -> fetch (scatter over
    chunks) -> train -> segment (scatter over chunks, placed at the
    data) -> analyze (gather).  ``kw`` are ``connect_config`` fields and
    ride in plan's params."""
    ep = "repro.apps.connect.pipeline"
    return {"nodes": [
        {"step": "plan", "entrypoint": f"{ep}:g_plan", "params": kw},
        {"step": "fetch", "deps": ["plan"], "entrypoint": f"{ep}:g_fetch",
         "scatter": {"over": "plan.chunks"},
         "outputs": ["{item}/ivt.npy", "{item}/labels.npy"]},
        {"step": "train", "deps": ["plan", "fetch"],
         "entrypoint": f"{ep}:g_train",
         "inputs": ["merra/ivt/chunk_00000/*"],
         "outputs": ["models/ffn/*"]},
        {"step": "segment", "deps": ["plan", "train"],
         "entrypoint": f"{ep}:g_segment",
         "scatter": {"over": "plan.chunks"},
         "inputs": ["{item}/ivt.npy", "models/ffn/*"],
         "outputs": ["{item}/mask.npy"]},
        {"step": "analyze", "deps": ["plan", "segment"],
         "entrypoint": f"{ep}:g_analyze"},
    ]}


def build_workflow(cluster: Optional[Cluster] = None,
                   store: Optional[ObjectStore] = None,
                   cc: Optional[ConnectConfig] = None,
                   metrics: Optional[Registry] = None,
                   planner=None) -> Workflow:
    wf = Workflow("connect", cluster=cluster, store=store, metrics=metrics,
                  namespace="atmos-science", planner=planner)
    return add_connect_steps(wf, cc or ConnectConfig())


def run_connect_workflow(root: str, cc: Optional[ConnectConfig] = None):
    cluster = Cluster()
    cluster.create_namespace("atmos-science")
    store = ObjectStore(root)
    wf = build_workflow(cluster, store, cc)
    results = wf.run()
    return wf, results
