"""Virtual clusters — per-tenant slices of the shared federation.

The paper's platform is a *shared appliance*: ~30 institutions on one
fabric, each expecting "virtual cluster management ... in a dynamically
scalable fashion" (§I contribution 4, §IV).  A ``VirtualCluster`` is one
tenant's handle on the fabric: a ``TenantSpec`` (fair-share weight,
priority, elastic min/max devices), a namespace on every site cluster
(the orchestrator's per-tenant quota accounting), and tenant-scoped
entry points for each workload family —

  * ``submit``       — batch jobs through the fair-share scheduler;
  * ``run_elastic``  — self-healing training on a preemptible capacity
                       claim (checkpoint-then-evict, auto-resume);
  * ``serve``        — a continuous-batching inference pod that yields
                       its slot cooperatively when preempted;
  * ``workflow``     — a placed, measured step DAG whose staging is
                       billed to the tenant and scored against other
                       tenants' link backlog.

``TenantClusterView`` is the trick that lets the EXISTING elastic stack
run multi-tenant unchanged: it forwards everything to the real site
cluster but clamps ``online_devices`` to the tenant's live grant, so the
churn controller plans meshes inside the tenant's slice and a grant
shrink looks exactly like node churn (drain -> re-mesh -> restore).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Dict, Optional

from repro.core.orchestrator import Cluster, JobSpec


@dataclass(frozen=True)
class TenantSpec:
    """One tenant's contract with the shared fabric."""
    name: str
    weight: float = 1.0          # fair-share weight (2.0 = twice the share)
    priority: int = 0            # higher may preempt strictly lower
    preemptible: bool = True     # may THIS tenant's pods be evicted
    min_devices: int = 0         # floor a capacity claim never drops below
    max_devices: Optional[int] = None   # fabric-wide ceiling (elastic max)
    site_quota: Optional[int] = None    # per-site namespace device quota

    def __post_init__(self):
        if self.weight <= 0:
            raise ValueError(f"tenant {self.name!r}: weight must be > 0")

    @property
    def namespace(self) -> str:
        return f"tenant-{self.name}"


class TenantClusterView:
    """One tenant's window onto a site ``Cluster``.

    Forwards every attribute to the real cluster; only
    ``online_devices`` is clamped to the tenant's live device grant, so
    mesh planning (ChurnController / rescale_plan) stays inside the
    tenant's slice and grant changes read as node churn.
    """

    def __init__(self, cluster: Cluster, grant_fn):
        self._cluster = cluster
        self._grant = grant_fn

    @property
    def online_devices(self):
        return self._cluster.online_devices[:max(0, int(self._grant()))]

    def __getattr__(self, name):
        return getattr(self._cluster, name)

    def __repr__(self):
        return (f"TenantClusterView(site={self._cluster.site!r}, "
                f"grant={int(self._grant())})")


class VirtualCluster:
    """A tenant's handle — constructed by FairShareScheduler.create_tenant."""

    def __init__(self, sched, spec: TenantSpec):
        self.sched = sched
        self.spec = spec

    @property
    def name(self) -> str:
        return self.spec.name

    @property
    def namespace(self) -> str:
        return self.spec.namespace

    # ------------------------------------------------------------ accounting
    def usage(self) -> Dict[str, int]:
        """Devices currently leased to this tenant, per site."""
        return self.sched.usage(self.name)

    def dominant_share(self) -> float:
        """This tenant's dominant share: its most-contended per-site
        device fraction, divided by its weight (DRF accounting)."""
        return self.sched.dominant_share(self.name)

    # -------------------------------------------------------------- workloads
    def submit(self, spec: JobSpec, *, site: Optional[str] = None):
        """Queue a batch job; the fair-share scheduler places it."""
        return self.sched.submit(self.name, spec, site=site)

    def claim(self, site: str, devices: int, *,
              min_devices: Optional[int] = None):
        """Register an elastic capacity claim at a site (see scheduler)."""
        floor = self.spec.min_devices if min_devices is None else min_devices
        return self.sched.claim(self.name, site, want=devices,
                                min_devices=floor)

    def view(self, site: str, claim=None) -> TenantClusterView:
        """The tenant's clamped view of a site cluster.  With a claim the
        grant is the claim's; otherwise the namespace quota."""
        cluster = self.sched.fabric.sites[site].cluster
        if claim is not None:
            return TenantClusterView(cluster, lambda: claim.granted)
        ns = self.namespace
        return TenantClusterView(
            cluster,
            lambda: cluster.namespaces[ns].device_quota
            if ns in cluster.namespaces else 0)

    def planner(self, **kw):
        """A tenant-tagged PlacementPlanner: staging billed to this
        tenant, scoring penalized by other tenants' link backlog."""
        from repro.fabric.placement import PlacementPlanner
        if self.sched.fed is None:
            raise RuntimeError("scheduler has no FederatedStore: construct "
                               "FairShareScheduler(fed=...) for placement")
        return PlacementPlanner(self.sched.fed, tenant=self.name, **kw)

    def store(self, site: str, **kw):
        """A tenant-billed SiteStore view at ``site``."""
        if self.sched.fed is None:
            raise RuntimeError("scheduler has no FederatedStore")
        return self.sched.fed.view(site, tenant=self.name, **kw)

    def workflow(self, name: str, **kw):
        """A measured step DAG running as this tenant (placed by the
        tenant planner, events on the scheduler's bus)."""
        from repro.core.workflow import Workflow
        if "planner" not in kw and not ("cluster" in kw and "store" in kw):
            kw["planner"] = self.planner()   # lazy: a caller-supplied
            # planner (or cluster+store) must not require a fed store
        kw.setdefault("namespace", self.namespace)
        kw.setdefault("bus", self.sched.bus)
        return Workflow(name, **kw)

    def run_elastic(self, tspec, *, site: str, devices: int,
                    store=None, min_devices: Optional[int] = None,
                    stop=None, on_trainer=None) -> Dict[str, Any]:
        """Self-healing elastic training inside this tenant's slice.

        Registers a capacity claim for up to ``devices`` at ``site`` and
        runs an ``ElasticTrainer`` on the tenant's clamped cluster view.
        Fair-share preemption (the scheduler shrinking the grant and
        preempt-draining the segment pod) reads exactly like node churn:
        the segment checkpoints on the way out, the trainer's
        ``wait_for_capacity`` rides out the eviction (bounded by the
        spec's ``rejoin_timeout_s``), and training resumes from the last
        checkpoint when the grant returns — steps lost stay within the
        elastic path's existing ``ckpt_every`` bound.

        ``stop`` (a ``threading.Event``, e.g. a ``repro.api`` Handle's
        cancel signal) ends the run cooperatively: the live segment
        checkpoints and exits, and the partial result is returned.
        """
        from repro.elastic.trainer import ElasticTrainer
        claim = self.claim(site, devices, min_devices=min_devices)
        view = self.view(site, claim)
        spec = dataclasses.replace(tspec, namespace=self.namespace)
        trainer = ElasticTrainer(view, spec, store=store,
                                 metrics=self.sched.metrics, stop=stop)
        if on_trainer is not None:
            on_trainer(trainer)
        try:
            return trainer.run()
        finally:
            claim.release()

    def serve(self, build_engine, requests, *, site: Optional[str] = None,
              lease_timeout: float = 30.0, default_max_new: int = 16,
              should_stop=None):
        """Submit a preemptible continuous-batching serving pod.

        ``build_engine()`` must return a ``repro.serving.ServingEngine``
        (constructed inside the pod so compilation happens on the pod's
        clock).  The engine polls the pod's ``should_stop`` between fused
        decode steps: a preemption exits cleanly and unacked requests'
        leases expire back to the queue for the next placement.  An
        extra ``should_stop`` callable (e.g. a ``repro.api`` Handle's
        cancel signal) is OR-ed in, so an API cancel drains the engine
        the same cooperative way a fair-share eviction does.
        Returns (TenantJob, WorkQueue).
        """
        from repro.core.queue import WorkQueue
        queue = WorkQueue(list(requests), lease_timeout=lease_timeout)

        def serve_pod(ctx):
            engine = build_engine()

            def stop():
                return ctx.should_stop() or (should_stop is not None and
                                             should_stop())

            results, _ = engine.run(queue, default_max_new=default_max_new,
                                    should_stop=stop)
            return results

        job = self.submit(JobSpec(f"serve-{self.name}", serve_pod,
                                  devices_per_pod=1), site=site)
        return job, queue
