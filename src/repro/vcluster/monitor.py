"""Near-real-time monitoring stream — the paper's "visualization across
the network ... in near real-time" facility (§I contribution 4, §VI).

A single in-process ``EventBus`` carries everything that happens on the
shared fabric as typed, timestamped events:

  * ``sched``    — tenant job queued / placed / preempted / requeued /
                   done / failed, capacity grants (FairShareScheduler);
  * ``pod``      — pod lifecycle transitions (orchestrator pod watchers);
  * ``node``     — node churn: fail / join (orchestrator churn watchers);
  * ``transfer`` — metered cross-site byte movements (fabric watchers);
  * ``metric``   — selected throughput gauges (Registry listeners);
  * ``step``     — workflow step placed / done / skipped / scatter;
  * ``branch``   — workflow-program branch lifecycle: one event per
                   scatter shard or repeat iteration (``of=<step>``,
                   ``branch=<index>``), from ``repro.flow``;
  * ``workflow`` — workflow-level lifecycle (e.g. ``cancelled`` with the
                   count of steps that will not run).

Delivery is synchronous fan-out into per-subscriber bounded deques: a
publisher appends and signals, a subscriber drains with ``poll``.  Lag is
therefore bounded by the subscriber's own polling cadence, not by any
broker — and when a slow subscriber's queue overflows, the OLDEST events
drop and are counted (``Subscription.dropped``, ``monitor/dropped``), so
a dashboard degrades to "recent window" instead of stalling publishers —
the paper's near-real-time contract over a lossy window.

``repro.launch.monitor`` renders the stream as a live text dashboard.
"""
from __future__ import annotations

import itertools
import threading
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Dict, List, Mapping, Optional, Sequence


@dataclass(frozen=True)
class Event:
    """One monitoring event: a kind, an origin, and a payload."""
    seq: int                    # bus-global, gap-free ordering
    ts: float                   # publish wall-clock time
    kind: str       # sched | pod | node | transfer | metric | step |
                    # branch | workflow
    source: str                 # site / component / tenant that emitted it
    data: Mapping[str, Any] = field(default_factory=dict)

    def brief(self) -> str:
        payload = " ".join(f"{k}={v}" for k, v in self.data.items())
        return f"{self.kind:>8} {self.source:<12} {payload}"


class Subscription:
    """One subscriber's bounded view of the stream."""

    def __init__(self, bus: "EventBus", maxlen: int):
        self._bus = bus
        self._maxlen = maxlen
        self._q: deque = deque()
        self._cond = threading.Condition()
        self.dropped = 0            # events lost to this subscriber's bound
        self.closed = False

    def _push(self, ev: Event) -> bool:
        """Deliver one event; returns True iff the bound forced a drop."""
        with self._cond:
            if self.closed:
                return False
            dropped = False
            if len(self._q) >= self._maxlen:
                self._q.popleft()          # oldest first: keep the window
                self.dropped += 1
                dropped = True
            self._q.append(ev)
            self._cond.notify_all()
            return dropped

    def poll(self, timeout: float = 0.0,
             max_events: Optional[int] = None) -> List[Event]:
        """Drain available events (oldest first).  With ``timeout`` > 0,
        block up to that long for at least one event."""
        with self._cond:
            if not self._q and timeout > 0:
                self._cond.wait(timeout)
            out: List[Event] = []
            while self._q and (max_events is None or len(out) < max_events):
                out.append(self._q.popleft())
            return out

    def close(self) -> None:
        with self._cond:
            self.closed = True
            self._cond.notify_all()
        self._bus._unsubscribe(self)


class EventBus:
    def __init__(self, metrics=None):
        self.metrics = metrics
        self._lock = threading.Lock()
        self._subs: List[Subscription] = []
        self._seq = itertools.count()
        self.published = 0

    # --------------------------------------------------------------- pub/sub
    def subscribe(self, maxlen: int = 1024) -> Subscription:
        sub = Subscription(self, maxlen)
        with self._lock:
            self._subs.append(sub)
        return sub

    def _unsubscribe(self, sub: Subscription) -> None:
        with self._lock:
            if sub in self._subs:
                self._subs.remove(sub)

    def publish(self, kind: str, source: str = "", **data) -> Event:
        ev = Event(seq=next(self._seq), ts=time.time(), kind=kind,
                   source=source, data=data)
        with self._lock:
            subs = list(self._subs)
            self.published += 1     # counted under the lock: publishers
            # race from many threads and received==published must hold
        # each _push reports its own drop so the metric stays exact even
        # when many publisher threads interleave (summing s.dropped
        # before/after here would double-count concurrent drops)
        new_drops = sum(1 for sub in subs if sub._push(ev))
        if self.metrics is not None:
            self.metrics.inc("monitor/published")
            if new_drops:
                self.metrics.inc("monitor/dropped", new_drops)
        return ev

    def stats(self) -> Dict[str, Any]:
        """Bus health snapshot: total published plus, per subscriber,
        its bound, current queue depth, and oldest-drop count — the
        counters a dashboard shows to prove the lossy-window contract
        (drops recorded, publishers never blocked)."""
        with self._lock:
            subs = list(self._subs)
        return {
            "published": self.published,
            "subscribers": [
                {"maxlen": s._maxlen, "queued": len(s._q),
                 "dropped": s.dropped}
                for s in subs
            ],
        }

    # ------------------------------------------------------------- watchers
    def attach_cluster(self, cluster, site: str = "") -> None:
        """Tap one orchestrator: node churn + pod lifecycle events."""
        name = site or getattr(cluster, "site", "local")

        def on_node(event, device):
            self.publish("node", source=name, event=event,
                         device=repr(device))

        def on_pod(event, pod):
            self.publish("pod", source=name, event=event,
                         pod=pod.ctx.pod_id, namespace=pod.ctx.namespace,
                         devices=len(pod.ctx.devices))

        cluster.add_watcher(on_node)
        cluster.add_pod_watcher(on_pod)

    def attach_fabric(self, fabric) -> None:
        """Tap a federation: every site's cluster + the transfer meter."""
        for site in fabric.sites.values():
            self.attach_cluster(site.cluster, site.name)

        def on_transfer(src, dst, nbytes, sim_s, tenant):
            self.publish("transfer", source=src, dst=dst, bytes=nbytes,
                         sim_s=round(sim_s, 4), tenant=tenant or "-")

        fabric.add_watcher(on_transfer)

    def attach_registry(self, registry,
                        prefixes: Sequence[str] = ("elastic/", "serve/",
                                                   "vcluster/")) -> None:
        """Stream matching throughput/SLO gauges as ``metric`` events."""
        prefixes = tuple(prefixes)

        def on_record(name, value, ts):
            if name.startswith(prefixes):
                self.publish("metric", source="registry", name=name,
                             value=round(float(value), 6))

        registry.add_listener(on_record)
