"""Fair-share preemptive scheduling over the federation.

The arbiter the shared fabric was missing: every tenant used to own the
whole fabric; now a ``FairShareScheduler`` decides whose pods run where,
using dominant-share accounting (DRF applied to the per-site device
pools) plus Borg-style priority preemption:

  * **queued jobs** are placed in rounds: among equal priorities the
    tenant with the LOWEST dominant share goes first, recomputed after
    every placement, so two equal-weight tenants hammering a saturated
    fabric interleave wave by wave instead of head-of-line blocking
    (the >2x FIFO skew measured by ``bench_vcluster_fairness``);
  * **capacity claims** are the elastic tenancy primitive: a training
    tenant claims "up to N devices at site S" and runs inside a
    ``TenantClusterView`` clamped to the claim's live ``granted`` count.
    Spare devices re-grow shrunk claims each reconcile pass (highest
    priority, then lowest share first);
  * **preemption** is checkpoint-then-evict: when a higher-priority
    tenant's job cannot fit, the scheduler shrinks lower-priority
    claims / jobs at the chosen site via the orchestrator's cooperative
    ``preempt_pod`` drain.  Victim training segments save a checkpoint
    and exit; the preempted batch job is requeued whole; a pod that
    ignores the drain past ``preempt_grace_s`` is hard-evicted.  Every
    decision is published to the monitor ``EventBus``.

The scheduler is deterministic when stepped manually (``step()``), and
self-driving with ``start()`` (a reconcile thread, period
``reconcile_s`` — the "one reconcile interval" that bounds monitor lag).
"""
from __future__ import annotations

import itertools
import threading
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from repro.core.orchestrator import Job, JobSpec, Pod, PodState
from repro.fabric.topology import Fabric, Site
from repro.vcluster.monitor import EventBus
from repro.vcluster.tenant import TenantSpec, VirtualCluster


@dataclass
class TenantJob:
    """One tenant's batch job riding through the scheduler."""
    seq: int
    tenant: str
    spec: JobSpec
    site_hint: Optional[str]
    submitted: float
    state: str = "queued"     # queued | running | done | failed | cancelled
    placements: List[Tuple[str, Job]] = field(default_factory=list)
    preemptions: int = 0
    done_ts: Optional[float] = None
    error: Optional[str] = None
    _event: threading.Event = field(default_factory=threading.Event)
    _preempting: bool = False    # a preemption was fired on its behalf
    _cancelled: bool = False     # user cancel: drained pods don't requeue

    @property
    def need(self) -> int:
        return self.spec.devices_per_pod * self.spec.replicas

    @property
    def site(self) -> Optional[str]:
        return self.placements[-1][0] if self.placements else None

    @property
    def job(self) -> Optional[Job]:
        return self.placements[-1][1] if self.placements else None

    def results(self):
        return self.job.results() if self.job else []

    def wait(self, timeout: float = 60.0) -> "TenantJob":
        if not self._event.wait(timeout):
            raise TimeoutError(f"tenant job {self.spec.name!r} "
                               f"({self.state}) not finished in {timeout}s")
        if self.state == "failed":
            raise RuntimeError(f"tenant job {self.spec.name!r} failed: "
                               f"{self.error}")
        return self


@dataclass(eq=False)        # identity semantics: claims are live handles
class CapacityClaim:
    """An elastic 'up to N devices at site S' reservation.

    ``granted`` is the live grant the tenant's ``TenantClusterView``
    clamps to; the scheduler shrinks it on preemption and re-grows it
    from spare capacity each pass.  ``min_devices`` is the floor
    preemption never crosses."""
    tenant: str
    site: str
    want: int
    min_devices: int = 0
    granted: int = 0
    released: bool = False
    _sched: Optional["FairShareScheduler"] = field(default=None, repr=False)

    def release(self) -> None:
        if self._sched is not None:
            self._sched.release_claim(self)


class FairShareScheduler:
    def __init__(self, fabric: Optional[Fabric] = None, *, fed=None,
                 bus: Optional[EventBus] = None, policy: str = "fair",
                 reconcile_s: float = 0.02, preempt_grace_s: float = 10.0):
        """``policy`` is "fair" (dominant-share + priority) or "fifo"
        (strict arrival order — the data-blind baseline the fairness
        benchmark measures against).  Pass ``fed`` (a FederatedStore) to
        enable tenant planners/stores; its fabric is used."""
        if fed is not None:
            fabric = fed.fabric
        if fabric is None:
            raise TypeError("FairShareScheduler needs a fabric or fed")
        if policy not in ("fair", "fifo"):
            raise ValueError(f"unknown policy {policy!r}")
        self.fabric = fabric
        self.fed = fed
        self.metrics = fabric.metrics
        self.bus = bus or EventBus(metrics=self.metrics)
        self.policy = policy
        self.reconcile_s = reconcile_s
        self.preempt_grace_s = preempt_grace_s
        self.tenants: Dict[str, VirtualCluster] = {}
        self._lock = threading.RLock()
        self._seq = itertools.count()
        self._pending: List[TenantJob] = []
        self._running: List[TenantJob] = []
        self._claims: List[CapacityClaim] = []
        # (cluster, pod, hard-evict deadline) for in-flight preemptions
        self._graces: List[Tuple[object, Pod, float]] = []
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None

    # -------------------------------------------------------------- tenants
    def create_tenant(self, spec: TenantSpec) -> VirtualCluster:
        with self._lock:
            if spec.name in self.tenants:
                raise ValueError(f"tenant {spec.name!r} exists")
            vc = VirtualCluster(self, spec)
            self.tenants[spec.name] = vc
        for site in self.fabric.sites.values():
            self._ensure_ns(site, spec)
        self.bus.publish("sched", source=spec.name, action="tenant-created",
                         weight=spec.weight, priority=spec.priority)
        return vc

    def _ensure_ns(self, site: Site, spec: TenantSpec) -> None:
        quota = spec.site_quota
        if quota is None:
            quota = len(site.cluster.devices)
        if spec.namespace not in site.cluster.namespaces:
            site.cluster.create_namespace(spec.namespace, quota,
                                          tenant=spec.name)
        else:
            site.cluster.set_quota(spec.namespace, quota)

    # ----------------------------------------------------------- accounting
    def usage(self, tenant: str) -> Dict[str, int]:
        ns = self.tenants[tenant].spec.namespace
        out = {}
        for site in self.fabric.sites.values():
            n = site.cluster.namespaces.get(ns)
            out[site.name] = n.used_devices if n else 0
        return out

    def dominant_share(self, tenant: str) -> float:
        """DRF over per-site device pools: the tenant's most-contended
        site fraction, normalized by its fair-share weight."""
        spec = self.tenants[tenant].spec
        usage = self.usage(tenant)
        share = 0.0
        for site in self.fabric.up_sites():
            cap = len(site.cluster.online_devices)
            if cap <= 0:
                continue
            share = max(share, usage.get(site.name, 0) / cap)
        return share / spec.weight

    def _free(self, site: Site) -> int:
        return site.cluster.free_devices() if site.up else 0

    def _reserved_unused(self, site: Site, *,
                         exclude_tenant: Optional[str] = None) -> int:
        """Granted-but-unleased claim headroom at a site: devices a
        restarting elastic segment is about to reclaim.  Placement must
        not hand these to another tenant mid-restore."""
        out = 0
        ns = {name: site.cluster.namespaces.get(vc.spec.namespace)
              for name, vc in self.tenants.items()}
        for c in self._claims:
            if c.site != site.name or c.tenant == exclude_tenant:
                continue
            n = ns.get(c.tenant)
            out += max(0, c.granted - (n.used_devices if n else 0))
        return out

    def _available(self, site: Site, tenant: str) -> int:
        return self._free(site) - self._reserved_unused(
            site, exclude_tenant=tenant)

    def _total_usage(self, tenant: str) -> int:
        return sum(self.usage(tenant).values())

    def _priority(self, job: TenantJob) -> int:
        if job.spec.priority is not None:
            return job.spec.priority
        return self.tenants[job.tenant].spec.priority

    # -------------------------------------------------------------- submits
    def submit(self, tenant: str, spec: JobSpec, *,
               site: Optional[str] = None) -> TenantJob:
        if tenant not in self.tenants:
            raise KeyError(f"unknown tenant {tenant!r}")
        job = TenantJob(seq=next(self._seq), tenant=tenant, spec=spec,
                        site_hint=site, submitted=time.monotonic())
        with self._lock:
            self._pending.append(job)
        self.metrics.inc(f"vcluster/queued/{tenant}")
        self.bus.publish("sched", source=tenant, action="queued",
                         job=spec.name, need=job.need)
        return job

    def claim(self, tenant: str, site: str, *, want: int,
              min_devices: int = 0) -> CapacityClaim:
        if tenant not in self.tenants:
            raise KeyError(f"unknown tenant {tenant!r}")
        spec = self.tenants[tenant].spec
        self._ensure_ns(self.fabric.sites[site], spec)
        c = CapacityClaim(tenant=tenant, site=site, want=want,
                          min_devices=min_devices, _sched=self)
        with self._lock:
            self._claims.append(c)
            give = min(want, max(0, self._available(
                self.fabric.sites[site], tenant)))
            ceiling = spec.max_devices
            if ceiling is not None:
                give = min(give, max(0, ceiling - self._total_usage(tenant)))
            c.granted = give
        self.bus.publish("sched", source=tenant, action="claimed",
                         site=site, want=want, granted=c.granted)
        return c

    def resize_claim(self, claim: CapacityClaim, want: int) -> int:
        """Elastically regrow or shrink a live claim in place — the
        serving autoscaler's capacity path: replicas scale up only as far
        as the tenant's fair share allows, and scale-down returns the
        devices to the pool immediately.  Shrinking always succeeds;
        growth is clamped by site availability (excluding the claim's own
        unleased headroom) and the tenant's ``max_devices`` ceiling.
        Returns the new grant."""
        if claim.released:
            raise ValueError("cannot resize a released claim")
        spec = self.tenants[claim.tenant].spec
        with self._lock:
            claim.want = want
            if want <= claim.granted:
                claim.granted = want
            else:
                site = self.fabric.sites[claim.site]
                used = self.usage(claim.tenant).get(claim.site, 0)
                own_headroom = max(0, claim.granted - used)
                avail = max(0, self._available(site, claim.tenant)
                            - own_headroom)
                grow = min(want - claim.granted, avail)
                ceiling = spec.max_devices
                if ceiling is not None:
                    grow = min(grow, max(0, ceiling
                                         - self._total_usage(claim.tenant)
                                         - own_headroom))
                claim.granted += max(0, grow)
        self.bus.publish("sched", source=claim.tenant, action="resized",
                         site=claim.site, want=want, granted=claim.granted)
        return claim.granted

    def release_claim(self, claim: CapacityClaim) -> None:
        with self._lock:
            claim.released = True
            claim.granted = 0
            if claim in self._claims:
                self._claims.remove(claim)
        self.bus.publish("sched", source=claim.tenant, action="released",
                         site=claim.site)

    def cancel(self, tj: TenantJob, *, reason: str = "cancelled") -> bool:
        """Cancel one tenant job.  A queued job dequeues immediately; a
        running one is checkpoint-then-evict drained (cooperative
        ``preempt_pod`` + the usual hard-evict grace) and ``_reap``
        marks it terminal ``cancelled`` instead of requeueing.  Returns
        False when the job is already terminal."""
        with self._lock:
            if tj.state in ("done", "failed", "cancelled"):
                return False
            tj._cancelled = True
            if tj in self._pending:
                self._pending.remove(tj)
                tj.state, tj.done_ts = "cancelled", time.monotonic()
                tj._event.set()
                cluster, job = None, None
            else:
                cluster = self.fabric.sites[tj.site].cluster \
                    if tj.site else None
                job = tj.job
        if cluster is None or job is None:
            self.metrics.inc(f"vcluster/cancelled/{tj.tenant}")
            self.bus.publish("sched", source=tj.tenant, action="cancelled",
                             job=tj.spec.name)
            return True
        deadline = time.monotonic() + self.preempt_grace_s
        for pod in job.pods:
            if pod.state in (PodState.PENDING, PodState.RUNNING):
                cluster.preempt_pod(pod, reason=reason)
                with self._lock:
                    self._graces.append((cluster, pod, deadline))
        self.bus.publish("sched", source=tj.tenant,
                         action="cancel-requested", job=tj.spec.name)
        return True

    # ------------------------------------------------------------ reconcile
    def step(self) -> int:
        """One reconcile pass: reap, expire preempt graces, place queued
        jobs fairly, re-grow claims, run site controllers.  Returns the
        number of placements made."""
        with self._lock:
            self._reap()
            self._expire_graces()
            placed = self._place_pending()
            self._regrow_claims()
        for site in self.fabric.up_sites():
            site.cluster.reconcile()
        return placed

    def _stranded(self, tj: TenantJob) -> bool:
        """A placed job whose site can no longer run it: the whole site
        is down, or node churn shrank it below the job's device need.
        ``step()`` only reconciles UP sites, so a drained pod at a dead
        site would otherwise sit FAILED-under-backoff forever — the
        cross-layer deadlock the chaos scenarios flush out."""
        if tj.site is None:
            return False
        site = self.fabric.sites[tj.site]
        return (not site.up or
                len(site.cluster.online_devices) < tj.spec.devices_per_pod)

    def _reap(self) -> None:
        still = []
        for tj in self._running:
            job = tj.job
            if job.succeeded:
                tj.state, tj.done_ts = "done", time.monotonic()
                tj._event.set()
                self.metrics.inc(f"vcluster/done/{tj.tenant}")
                self.bus.publish("sched", source=tj.tenant, action="done",
                                 job=tj.spec.name, site=tj.site)
            elif job.terminal and (job.preempted or self._stranded(tj)):
                # evicted — or stranded on a dead/shrunken site: requeue
                # the whole job on the survivors.  Its fn is expected to
                # be resumable (at-least-once, like the work queue).
                # Any FAILED-under-backoff sibling pod must be retired
                # first, or the site reconciler would respawn it while
                # the requeued job runs the same fn again.
                cluster = self.fabric.sites[tj.site].cluster
                for p in job.pods:
                    if p.state == PodState.FAILED and \
                            p.restarts < job.spec.backoff_limit:
                        cluster.retire_pod(p)
                if tj._cancelled:
                    # the drain was a user cancel (FairShareScheduler.
                    # cancel), not a fair-share eviction: terminal, with
                    # whatever the pods checkpointed preserved
                    tj.state, tj.done_ts = "cancelled", time.monotonic()
                    tj._event.set()
                    self.metrics.inc(f"vcluster/cancelled/{tj.tenant}")
                    self.bus.publish("sched", source=tj.tenant,
                                     action="cancelled", job=tj.spec.name)
                    continue
                tj.state = "queued"
                tj.preemptions += 1
                tj._preempting = False
                self._pending.append(tj)
                self.metrics.inc(f"vcluster/requeued/{tj.tenant}")
                self.bus.publish("sched", source=tj.tenant,
                                 action="requeued", job=tj.spec.name,
                                 preemptions=tj.preemptions)
            elif job.failed:
                tj.state, tj.done_ts = "failed", time.monotonic()
                tj.error = next((p.error for p in job.pods if p.error), None)
                tj._event.set()
                self.metrics.inc(f"vcluster/failed/{tj.tenant}")
                self.bus.publish("sched", source=tj.tenant, action="failed",
                                 job=tj.spec.name)
            else:
                still.append(tj)     # running, or FAILED under backoff
        self._running = still

    def _expire_graces(self) -> None:
        now = time.monotonic()
        keep = []
        for cluster, pod, deadline in self._graces:
            if pod.state not in (PodState.PENDING, PodState.RUNNING):
                continue                      # exited on its own
            if now >= deadline:
                cluster.finish_preempt(pod)   # hard evict
                self.metrics.inc("vcluster/preempt_hard")
            else:
                keep.append((cluster, pod, deadline))
        self._graces = keep

    def _order(self, jobs: List[TenantJob]) -> List[TenantJob]:
        if self.policy == "fifo":
            return sorted(jobs, key=lambda j: j.seq)
        share = {t: self.dominant_share(t)
                 for t in {j.tenant for j in jobs}}
        return sorted(jobs, key=lambda j: (-self._priority(j),
                                           share[j.tenant], j.seq))

    def _site_candidates(self, tj: TenantJob) -> List[Site]:
        if tj.site_hint is not None:
            s = self.fabric.sites[tj.site_hint]
            return [s] if s.up else []
        cands = [s for s in self.fabric.up_sites()
                 if len(s.cluster.online_devices) >= max(tj.need, 1)]
        cands.sort(key=lambda s: (-self._available(s, tj.tenant),
                                  s.queue_depth(), s.name))
        return cands

    def _place_pending(self) -> int:
        placed = 0
        while self._pending:
            # re-rank every round: each placement moves dominant shares
            order = self._order(self._pending)
            launched = False
            for tj in order:
                site = self._fit(tj)
                if site is not None and self._launch(tj, site):
                    placed += 1
                    launched = True
                    break
            if not launched:
                # nothing fits; let the HIGHEST-ranked stuck job try to
                # preempt (one preemption wave per pass, no storms)
                for tj in order:
                    if not tj._preempting and self._preempt_for(tj):
                        break
                break
        return placed

    def _fit(self, tj: TenantJob) -> Optional[Site]:
        spec = self.tenants[tj.tenant].spec
        if spec.max_devices is not None and \
                self._total_usage(tj.tenant) + tj.need > spec.max_devices:
            return None
        for site in self._site_candidates(tj):
            if self._available(site, tj.tenant) >= tj.need:
                return site
        return None

    def _launch(self, tj: TenantJob, site: Site) -> bool:
        spec = self.tenants[tj.tenant].spec
        self._ensure_ns(site, spec)
        try:
            job = site.cluster.submit(spec.namespace, tj.spec)
        except RuntimeError:
            return False      # lost an allocation race; stays pending
        tj.placements.append((site.name, job))
        tj.state = "running"
        tj._preempting = False
        self._pending.remove(tj)
        self._running.append(tj)
        self.metrics.inc(f"vcluster/placed/{tj.tenant}")
        self.bus.publish("sched", source=tj.tenant, action="placed",
                         job=tj.spec.name, site=site.name, need=tj.need)
        return True

    # ------------------------------------------------------------ preemption
    def _victims_at(self, site: Site, prio: int,
                    requester: str) -> List[Tuple[int, float, Pod, str]]:
        """Live pods at a site owned by preemptible tenants of strictly
        lower priority, worst-first (lowest priority, highest share)."""
        out = []
        for name, vc in self.tenants.items():
            vspec = vc.spec
            if name == requester or not vspec.preemptible or \
                    vspec.priority >= prio:
                continue
            vshare = self.dominant_share(name)
            for job in site.cluster.jobs:
                for pod in job.pods:
                    if pod.ctx.namespace == vspec.namespace and \
                            pod.state in (PodState.PENDING,
                                          PodState.RUNNING) and \
                            not pod.ctx.preempt.is_set():
                        out.append((vspec.priority, -vshare, pod, name))
        out.sort(key=lambda v: (v[0], v[1]))
        return out

    def _claim_of(self, pod: Pod, tenant: str,
                  site: Site) -> Optional[CapacityClaim]:
        """The capacity claim a victim pod runs under, if any.  Pods of
        scheduler-placed batch jobs are NOT claim pods even when their
        tenant also holds a claim at the site — evicting them must not
        shrink the (untouched) training grant."""
        for tj in self._running:
            if tj.tenant == tenant and tj.job is not None and \
                    any(p is pod for p in tj.job.pods):
                return None
        return next((c for c in self._claims
                     if c.tenant == tenant and c.site == site.name), None)

    def _preempt_for(self, tj: TenantJob) -> bool:
        """Checkpoint-then-evict enough lower-priority devices for ``tj``."""
        prio = self._priority(tj)
        for site in self._site_candidates(tj):
            victims = self._victims_at(site, prio, tj.tenant)
            # claim floors: never shrink a claim below its min_devices
            floor_left = {id(c): c.granted - c.min_devices
                          for c in self._claims if c.site == site.name}
            have = self._available(site, tj.tenant)
            chosen = []               # (pod, tenant, claim-or-None)
            for _, _, pod, tenant in victims:
                if have >= tj.need:
                    break
                take = len(pod.ctx.devices)
                if take == 0:
                    continue          # evicting a CPU pod frees nothing
                claim = self._claim_of(pod, tenant, site)
                if claim is not None:
                    if floor_left.get(id(claim), 0) < take:
                        continue          # would pierce the claim floor
                    floor_left[id(claim)] -= take
                have += take
                chosen.append((pod, tenant, claim))
            if have < tj.need:
                continue
            deadline = time.monotonic() + self.preempt_grace_s
            for pod, tenant, claim in chosen:
                if claim is not None:
                    claim.granted = max(claim.min_devices,
                                        claim.granted -
                                        len(pod.ctx.devices))
                site.cluster.preempt_pod(
                    pod, reason=f"fair-share: {tj.tenant} "
                                f"(prio {prio}) needs {tj.need} devices")
                self._graces.append((site.cluster, pod, deadline))
                self.metrics.inc(f"vcluster/preemptions/{tenant}")
                self.bus.publish("sched", source=tenant, action="preempt",
                                 pod=pod.ctx.pod_id, site=site.name,
                                 for_tenant=tj.tenant)
            if chosen:
                tj._preempting = True
                return True
        return False

    # --------------------------------------------------------------- claims
    def _regrow_claims(self) -> None:
        """Hand spare devices back to shrunk claims (priority desc, then
        lowest dominant share) — but never devices a queued job could
        use: pending work outranks elastic headroom."""
        for site in self.fabric.up_sites():
            spare = self._free(site) - self._reserved_unused(site)
            spare -= sum(tj.need for tj in self._pending
                         if tj.site_hint in (None, site.name))
            if spare <= 0:
                continue
            claims = [c for c in self._claims
                      if c.site == site.name and c.granted < c.want]
            claims.sort(key=lambda c: (
                -self.tenants[c.tenant].spec.priority,
                self.dominant_share(c.tenant)))
            for c in claims:
                if spare <= 0:
                    break
                ceiling = self.tenants[c.tenant].spec.max_devices
                add = min(c.want - c.granted, spare)
                if ceiling is not None:
                    # committed = everything leased plus the grant's
                    # still-unleased headroom (don't double-count the
                    # leased part of the grant)
                    used_here = self.usage(c.tenant).get(c.site, 0)
                    committed = self._total_usage(c.tenant) + \
                        max(0, c.granted - used_here)
                    add = min(add, max(0, ceiling - committed))
                if add > 0:
                    c.granted += add
                    spare -= add
                    self.metrics.inc(f"vcluster/grants/{c.tenant}", add)
                    self.bus.publish("sched", source=c.tenant,
                                     action="grant", site=site.name,
                                     granted=c.granted)

    # ----------------------------------------------------------------- loop
    def start(self) -> "FairShareScheduler":
        """Run the reconcile loop in a daemon thread."""
        if self._thread is not None:
            return self
        self._stop.clear()

        def loop():
            while not self._stop.is_set():
                self.step()
                self._stop.wait(self.reconcile_s)

        self._thread = threading.Thread(target=loop, daemon=True,
                                        name="fair-share-scheduler")
        self._thread.start()
        return self

    def stop(self) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=10)
            self._thread = None

    def __enter__(self) -> "FairShareScheduler":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()
