"""Multi-tenant virtual clusters (paper §I contribution 4, §IV): tenant
slices of the federation, a dominant-share fair scheduler with
checkpoint-then-evict preemption, and the near-real-time monitor bus."""
from repro.vcluster.monitor import Event, EventBus, Subscription
from repro.vcluster.scheduler import (CapacityClaim, FairShareScheduler,
                                      TenantJob)
from repro.vcluster.tenant import (TenantClusterView, TenantSpec,
                                   VirtualCluster)

__all__ = [
    "Event", "EventBus", "Subscription",
    "CapacityClaim", "FairShareScheduler", "TenantJob",
    "TenantClusterView", "TenantSpec", "VirtualCluster",
]
