"""Gated cross-attention image blocks (llama-3.2-vision style).

The vision tower is a STUB per the assignment: ``input_specs()`` provides
precomputed patch embeddings (B, P, vision_dim); this block projects them to
K/V and cross-attends with tanh-gated residuals.  During decode the cross
K/V are constants — they live in the cache (built at prefill or supplied as
an input spec for decode-only cells).
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn_mod
from repro.models.layers import ModelCtx, rms_norm, swiglu
from repro.models.params import PSpec


def cross_schema(cfg: ModelConfig, G: int) -> Dict[str, PSpec]:
    D, H, KV, dh, F = (cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
                       cfg.resolved_head_dim, cfg.d_ff)
    Vd = cfg.vision_dim
    heads_div = H % 16 == 0
    hq = "tp_heads" if heads_div else None
    hd_ax = "head_dim" if heads_div else "tp_head_dim"
    return {
        "ln1": PSpec((G, D), ("layers", None), "zeros"),
        "wq": PSpec((G, D, H, dh), ("layers", "fsdp", hq, hd_ax)),
        "wk": PSpec((G, Vd, KV, dh), ("layers", None, "tp_kv_heads", hd_ax)),
        "wv": PSpec((G, Vd, KV, dh), ("layers", None, "tp_kv_heads", hd_ax)),
        "k_norm": PSpec((G, dh), ("layers", None), "zeros"),
        "q_norm": PSpec((G, dh), ("layers", None), "zeros"),
        "wo": PSpec((G, H, dh, D), ("layers", hq, hd_ax, "fsdp")),
        "gate_attn": PSpec((G,), ("layers",), "zeros"),
        "ln2": PSpec((G, D), ("layers", None), "zeros"),
        "wg": PSpec((G, D, F), ("layers", "fsdp", "tp_ff")),
        "wu": PSpec((G, D, F), ("layers", "fsdp", "tp_ff")),
        "wo_mlp": PSpec((G, F, D), ("layers", "tp_ff", "fsdp")),
        "gate_mlp": PSpec((G,), ("layers",), "zeros"),
    }


def cross_cache_schema(cfg: ModelConfig, B: int, S: int, G: int):
    KV, dh, P = cfg.num_kv_heads, cfg.resolved_head_dim, cfg.num_patches
    ax = ("layers", "batch", "cache_seq", "kv_heads", "head_dim")
    return {"ck": PSpec((G, B, P, KV, dh), ax, "zeros"),
            "cv": PSpec((G, B, P, KV, dh), ax, "zeros")}


def _cross_attention(ctx: ModelCtx, q, k, v):
    """Full (unmasked) attention over patches.  q (B,S,H,dh); k/v (B,P,KV,dh).

    Same GQA-sharding note as models.attention: KV < tp would replicate, so
    repeat K/V to H heads (patch count is small; the repeat is sharded)."""
    B, S, H, dh = q.shape
    KV = k.shape[2]
    tp = ctx.mesh.shape.get("model", 1) if ctx.mesh is not None else 1
    hax = ("batch", "seq", "heads", "head_dim")
    q = ctx.cons(q, hax)
    if 1 < KV < tp and H % tp == 0:
        k = ctx.cons(jnp.repeat(k, H // KV, axis=2), ("batch", None, "heads",
                                                      "head_dim"))
        v = ctx.cons(jnp.repeat(v, H // KV, axis=2), ("batch", None, "heads",
                                                      "head_dim"))
        KV = H
    g = H // KV
    qr = q.reshape(B, S, KV, g, dh)
    # q-chunked (non-causal) so per-chunk (c, P) scores bound live memory
    out = attn_mod._qchunk_attention(
        qr, k, v, scale=dh ** -0.5, window=None, cap=None, chunk=512,
        causal=False)
    return out.reshape(B, S, H, dh)


def apply_cross(ctx: ModelCtx, p, x, *, mode, positions, cache, pos, shared,
                extras):
    cfg = ctx.cfg
    cd = ctx.compute_dtype
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    q = jnp.einsum("bsd,dhk->bshk", h, p["wq"].astype(cd))
    q = rms_norm(q, p["q_norm"], cfg.norm_eps)

    if mode == "decode":
        k, v = cache["ck"].astype(cd), cache["cv"].astype(cd)
        new_cache = {"ck": cache["ck"], "cv": cache["cv"]}
    else:
        img = extras["image_embeds"].astype(cd)      # (B, P, Vd)
        k = jnp.einsum("bpv,vhk->bphk", img, p["wk"].astype(cd))
        v = jnp.einsum("bpv,vhk->bphk", img, p["wv"].astype(cd))
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
        cax = ("batch", "cache_seq", "kv_heads", "head_dim")
        new_cache = {"ck": ctx.cons(k, cax), "cv": ctx.cons(v, cax)} \
            if mode == "prefill" else {}

    out = _cross_attention(ctx, q, k, v)
    out = attn_mod.attn_out(ctx, p, out)
    x = x + jnp.tanh(p["gate_attn"].astype(jnp.float32)).astype(cd) * out

    h2 = rms_norm(x, p["ln2"], cfg.norm_eps)
    mlp = swiglu(ctx, {"wg": p["wg"], "wu": p["wu"], "wo": p["wo_mlp"]}, h2)
    x = x + jnp.tanh(p["gate_mlp"].astype(jnp.float32)).astype(cd) * mlp
    return x, new_cache, 0.0
