"""Decoder-only LM over heterogeneous *layer groups*.

``cfg.block_pattern`` is the repeating unit (e.g. gemma2: ("local","global"),
llama-3.2-vision: ("attn",)*4 + ("cross",)); parameters for each position are
stacked along a leading group axis and the model scans over groups — the HLO
is depth-independent, which keeps 512-way dry-run compiles tractable.

Each block *kind* registers (schema, cache_schema, apply) in KINDS; dense
attention kinds live here, MoE in models.moe, Mamba2/RWKV6 in models.ssm.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Optional

import jax
import jax.ad_checkpoint
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn_mod
from repro.models import losses
from repro.models.layers import (ModelCtx, cross_entropy, embed_tokens,
                                 rms_norm, swiglu, unembed)
from repro.models.params import PSpec

# ---------------------------------------------------------------------------
# kind registry
# ---------------------------------------------------------------------------
# kind -> dict with:
#   schema(cfg, G)        -> {name: PSpec}           (leading G dim, axes[0]="layers")
#   cache(cfg, B, S)      -> {name: PSpec} or {}     (leading G dim)
#   apply(ctx, p, x, *, mode, positions, cache, pos, shared, extras)
#         -> (x, new_cache, aux_loss)
KINDS: Dict[str, Dict[str, Callable]] = {}


def register_kind(name: str, schema, cache, apply):
    KINDS[name] = {"schema": schema, "cache": cache, "apply": apply}


# ---------------------------------------------------------------------------
# dense attention block (kinds: attn / local / global)
# ---------------------------------------------------------------------------

def _attn_mlp_schema(cfg: ModelConfig, G: int) -> Dict[str, PSpec]:
    D, H, KV, dh, F = (cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
                       cfg.resolved_head_dim, cfg.d_ff)
    # When the head count cannot divide the production model axis (phi4: 24,
    # whisper: 12), TP-shard attention weights along head_dim instead so they
    # are not merely 16-way (FSDP-only) sharded.
    heads_div = H % 16 == 0
    hq = "tp_heads" if heads_div else None
    hd = "head_dim" if heads_div else "tp_head_dim"
    s: Dict[str, PSpec] = {
        "ln1": PSpec((G, D), ("layers", None), "zeros"),
        "wq": PSpec((G, D, H, dh), ("layers", "fsdp", hq, hd)),
        "wk": PSpec((G, D, KV, dh), ("layers", "fsdp", "tp_kv_heads", hd)),
        "wv": PSpec((G, D, KV, dh), ("layers", "fsdp", "tp_kv_heads", hd)),
        "wo": PSpec((G, H, dh, D), ("layers", hq, hd, "fsdp")),
        "ln2": PSpec((G, D), ("layers", None), "zeros"),
        "wg": PSpec((G, D, F), ("layers", "fsdp", "tp_ff")),
        "wu": PSpec((G, D, F), ("layers", "fsdp", "tp_ff")),
        "wo_mlp": PSpec((G, F, D), ("layers", "tp_ff", "fsdp")),
    }
    if cfg.attn.qkv_bias:
        s["bq"] = PSpec((G, H, dh), ("layers", "tp_heads", "head_dim"), "zeros")
        s["bk"] = PSpec((G, KV, dh), ("layers", "tp_kv_heads", "head_dim"), "zeros")
        s["bv"] = PSpec((G, KV, dh), ("layers", "tp_kv_heads", "head_dim"), "zeros")
    if cfg.post_norm:
        s["ln1_post"] = PSpec((G, D), ("layers", None), "zeros")
        s["ln2_post"] = PSpec((G, D), ("layers", None), "zeros")
    return s


def _attn_cache_schema(cfg: ModelConfig, B: int, S: int, G: int) -> Dict[str, PSpec]:
    KV, dh = cfg.num_kv_heads, cfg.resolved_head_dim
    ax = ("layers", "batch", "cache_seq", "kv_heads", "head_dim")
    return {"k": PSpec((G, B, S, KV, dh), ax, "zeros"),
            "v": PSpec((G, B, S, KV, dh), ax, "zeros")}


def insert_kv(cache, k, v, pos):
    """Write this step's k/v (B,1,KV,dh) into the cache at ``pos``.

    ``pos`` is a scalar (whole-batch decode, all rows at the same position)
    or a (B,) vector (slot-based continuous batching: every row of the
    batch is a different request at its own sequence position).  A vector
    entry >= cache length writes nothing — a free/overflowed slot is a
    no-op rather than an out-of-bounds clamp.
    """
    pos = jnp.asarray(pos)
    if pos.ndim == 0:
        k_cache = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, pos, axis=1)
        v_cache = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, pos, axis=1)
        return k_cache, v_cache
    S = cache["k"].shape[1]
    hit = (jnp.arange(S)[None, :] == pos[:, None])[..., None, None]  # (B,S,1,1)
    return (jnp.where(hit, k, cache["k"]),
            jnp.where(hit, v, cache["v"]))


def _tp_boundary(ctx: ModelCtx, h, mode: str, tag: str):
    """Make the Megatron-SP all-gather an explicit, NAMED value so the
    remat policy (save_only_these_names) can keep it for backward instead
    of re-gathering 3x (remat recompute + two transposes)."""
    if (mode == "train" and ctx.par.sequence_parallel
            and ctx.par.remat_save_gathered):
        h = ctx.cons(h, ("batch", "seq", None))
        h = jax.ad_checkpoint.checkpoint_name(h, "tp_gather")
    return h


def attention_part(ctx: ModelCtx, p, x, *, window, mode, positions, cache, pos):
    """Pre-norm attention sub-block shared by dense/moe/hybrid kinds."""
    cfg = ctx.cfg
    strategy = attn_mod.attn_strategy(ctx)
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    if strategy == "heads":
        h = _tp_boundary(ctx, h, mode, "attn_in")
    q, k, v = attn_mod.qkv_proj(ctx, p, h, positions, strategy)
    new_cache = {}
    if mode == "decode":
        k_cache, v_cache = insert_kv(cache, k, v, pos)
        out = attn_mod.decode_attention(
            ctx, q, k_cache, v_cache, pos, window=window,
            logit_softcap=cfg.attn.logit_softcap)
        new_cache = {"k": k_cache, "v": v_cache}
    else:
        out = attn_mod.causal_attention(
            ctx, q, k, v, window=window, logit_softcap=cfg.attn.logit_softcap,
            strategy=strategy, mode=mode)
        if mode == "prefill":
            cax = ("batch", "cache_seq", "kv_heads", "head_dim")
            new_cache = {"k": ctx.cons(k, cax), "v": ctx.cons(v, cax)}
    out = attn_mod.attn_out(ctx, p, out)
    # NOTE: an explicit seq-sharded constraint on this output was tried to
    # convert the combine AR into a reduce-scatter — REFUTED: GSPMD added a
    # resharding pair instead (+53% collective bytes); see EXPERIMENTS §Perf.
    if cfg.post_norm:
        out = rms_norm(out, p["ln1_post"], cfg.norm_eps)
    return x + out, new_cache


def mlp_part(ctx: ModelCtx, p, x, mode: str = "train"):
    cfg = ctx.cfg
    h = rms_norm(x, p["ln2"], cfg.norm_eps)
    h = _tp_boundary(ctx, h, mode, "mlp_in")
    out = swiglu(ctx, {"wg": p["wg"], "wu": p["wu"], "wo": p["wo_mlp"]}, h)
    if cfg.post_norm:
        out = rms_norm(out, p["ln2_post"], cfg.norm_eps)
    return x + out


def _make_attn_apply(window_of: Callable[[ModelConfig], Optional[int]]):
    def apply(ctx, p, x, *, mode, positions, cache, pos, shared, extras):
        x, new_cache = attention_part(
            ctx, p, x, window=window_of(ctx.cfg), mode=mode,
            positions=positions, cache=cache, pos=pos)
        x = mlp_part(ctx, p, x, mode)
        return x, new_cache, 0.0
    return apply


register_kind(
    "attn",
    schema=_attn_mlp_schema,
    cache=lambda cfg, B, S, G: _attn_cache_schema(cfg, B, S, G),
    apply=_make_attn_apply(lambda cfg: None),
)
register_kind(
    "global",
    schema=_attn_mlp_schema,
    cache=lambda cfg, B, S, G: _attn_cache_schema(cfg, B, S, G),
    apply=_make_attn_apply(lambda cfg: None),
)
register_kind(
    "local",
    schema=_attn_mlp_schema,
    cache=lambda cfg, B, S, G: _attn_cache_schema(cfg, B, S, G),
    apply=_make_attn_apply(lambda cfg: cfg.attn.window),
)


# ---------------------------------------------------------------------------
# model schema / caches
# ---------------------------------------------------------------------------

def lm_schema(cfg: ModelConfig) -> Dict[str, Any]:
    G = cfg.num_groups
    blocks = {f"{i}_{kind}": KINDS[kind]["schema"](cfg, G)
              for i, kind in enumerate(cfg.block_pattern)}
    schema: Dict[str, Any] = {
        "embed": PSpec((cfg.vocab_size, cfg.d_model), ("tp_vocab", "fsdp"),
                       scale=0.02),
        "blocks": blocks,
        "final_norm": PSpec((cfg.d_model,), (None,), "zeros"),
    }
    if not cfg.tie_embeddings:
        schema["lm_head"] = PSpec((cfg.vocab_size, cfg.d_model),
                                  ("tp_vocab", "fsdp"))
    if "mamba_attn" in cfg.block_pattern:   # zamba2 shared attention weights
        from repro.models import ssm
        schema["shared_attn"] = ssm.shared_attn_schema(cfg)
    if "cross" in cfg.block_pattern:        # vlm: vision projection is in-block
        pass
    return schema


def cache_schema(cfg: ModelConfig, B: int, S: int) -> Dict[str, Any]:
    G = cfg.num_groups
    return {f"{i}_{kind}": KINDS[kind]["cache"](cfg, B, S, G)
            for i, kind in enumerate(cfg.block_pattern)}


# ---------------------------------------------------------------------------
# forward passes
# ---------------------------------------------------------------------------

def _scan_groups(ctx: ModelCtx, params, x, *, mode, positions, caches, pos,
                 extras):
    """Scan (or unrolled loop) over layer groups; returns (x, new_caches)."""
    cfg, par = ctx.cfg, ctx.par
    shared = params.get("shared_attn")
    blocks = params["blocks"]

    multi = len(cfg.block_pattern) > 1
    policy = (jax.checkpoint_policies.save_only_these_names("tp_gather")
              if par.remat_save_gathered else None)

    def one_layer(kind):
        def fn(x, p, cache):
            return KINDS[kind]["apply"](
                ctx, p, x, mode=mode, positions=positions, cache=cache,
                pos=pos, shared=shared, extras=extras)
        if mode == "train" and par.remat and multi:
            # nested remat (multi-layer groups only): backward holds ONE
            # layer's activations, not a whole pattern-group's (the vlm
            # group is 5 layers).  Costs one extra fwd (3 fwd + 2 bwd);
            # len-1 patterns use just the outer body checkpoint (2 fwd).
            fn = jax.checkpoint(fn, prevent_cse=False, policy=policy)
        return fn

    layer_fns = {f"{i}_{kind}": one_layer(kind)
                 for i, kind in enumerate(cfg.block_pattern)}

    def body(carry, xs):
        x, aux = carry
        gp, gc = xs
        new_gc = {}
        for i, kind in enumerate(cfg.block_pattern):
            key = f"{i}_{kind}"
            x, nc, a = layer_fns[key](
                x, gp[key], None if gc is None else gc[key])
            new_gc[key] = nc
            aux = aux + a
            if mode == "train" and par.sequence_parallel:
                # saved per-layer inputs stay seq-sharded under remat
                x = ctx.cons(x, ("batch", "act_seq_sharded", None))
        return (x, aux), new_gc

    if mode == "train" and par.remat:
        body = jax.checkpoint(body, prevent_cse=False, policy=policy)

    aux0 = jnp.zeros((), jnp.float32)
    if par.scan_layers:
        xs = (blocks, caches)
        (x, aux), new_caches = jax.lax.scan(body, (x, aux0), xs)
    else:
        G = cfg.num_groups
        ncs = []
        aux = aux0
        for gi in range(G):
            gp = jax.tree.map(lambda a: a[gi], blocks)
            gc = None if caches is None else jax.tree.map(lambda a: a[gi], caches)
            (x, aux), nc = body((x, aux), (gp, gc))
            ncs.append(nc)
        new_caches = (jax.tree.map(lambda *a: jnp.stack(a), *ncs)
                      if ncs and ncs[0] else None)
    return x, new_caches, aux


def forward(ctx: ModelCtx, params, tokens, *, mode: str = "train",
            caches=None, pos=None, extras=None):
    """tokens (B,St) int32.  mode train|prefill: St=S; decode: St=1.

    Returns (final hidden states (B,St,D), new_caches, aux_loss) — callers
    pick the head: chunked xent for training, last-token logits for serving.
    """
    cfg = ctx.cfg
    x = embed_tokens(ctx, params["embed"], tokens)
    if mode == "train" and ctx.par.sequence_parallel:
        x = ctx.cons(x, ("batch", "act_seq_sharded", None))
    if mode == "decode":
        # pos: scalar (whole-batch) or (B,) per-slot positions (continuous
        # batching) — rope() takes (S,) or (B,S) position grids.
        p = jnp.asarray(pos)
        positions = p[:, None] if p.ndim == 1 else jnp.reshape(p, (1,))
    else:
        positions = jnp.arange(tokens.shape[1], dtype=jnp.int32)
    x, new_caches, aux = _scan_groups(ctx, params, x, mode=mode,
                                      positions=positions, caches=caches,
                                      pos=pos, extras=extras)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    x = ctx.cons(x, ("batch", "act_seq_sharded" if mode == "train"
                     and ctx.par.sequence_parallel else "seq", None))
    return x, new_caches, aux


def lm_head(cfg: ModelConfig, params):
    return params["embed"] if cfg.tie_embeddings else params["lm_head"]


def lm_logits(ctx: ModelCtx, params, x) -> jax.Array:
    """Logits for a few positions (serving) — NOT for full-seq training."""
    return unembed(ctx, lm_head(ctx.cfg, params), x, transpose=True)


def loss_fn(ctx: ModelCtx, params, batch) -> jax.Array:
    x, _, aux = forward(ctx, params, batch["tokens"], mode="train",
                        extras=batch.get("extras"))
    head = lm_head(ctx.cfg, params).astype(ctx.compute_dtype)
    S = x.shape[1]
    # sharded xent needs the vocab on the model axis; under pure-FSDP the
    # model axis carries batch, so chunked (per-chunk remat) is the
    # memory-safe head there and for non-divisible vocabs
    if (ctx.cfg.vocab_size % 16 == 0 and S % 16 == 0
            and not ctx.par.pure_fsdp):
        nll = losses.sharded_cross_entropy(
            ctx, x, batch["labels"], head,
            softcap=ctx.cfg.final_logit_softcap)
    else:
        nll = losses.chunked_cross_entropy(
            x, batch["labels"], head, softcap=ctx.cfg.final_logit_softcap)
    return nll + aux


def rl_loss_fn(ctx: ModelCtx, params, batch) -> jax.Array:
    """Advantage-weighted policy-gradient loss (the repro.rl learner).

    batch: tokens/labels (B,S) int32 as in ``loss_fn``, plus
    mask (B,S) f32 — 1.0 on generated (action) label positions — and
    advantages (B,) f32, one normalized return per trajectory.  The
    surrogate sum_t A * -log pi(label_t) / sum(mask) is exactly
    mask*advantage-weighted cross entropy, so the chunked/fused xent
    path is reused unchanged; prompt and pad positions get weight 0 and
    contribute no gradient.
    """
    x, _, aux = forward(ctx, params, batch["tokens"], mode="train",
                        extras=batch.get("extras"))
    head = lm_head(ctx.cfg, params).astype(ctx.compute_dtype)
    w = batch["mask"] * batch["advantages"][:, None]
    denom = jnp.maximum(jnp.sum(batch["mask"]), 1.0)
    pg = losses.weighted_cross_entropy(
        x, batch["labels"], head, w, denom=denom,
        softcap=ctx.cfg.final_logit_softcap)
    return pg + aux


# register the MoE kind (module import avoids a cycle at definition time)
from repro.models import moe as _moe  # noqa: E402

register_kind("moe", schema=_moe.moe_block_schema,
              cache=lambda cfg, B, S, G: _attn_cache_schema(cfg, B, S, G),
              apply=_moe.apply_moe_block)

from repro.models import ssm as _ssm  # noqa: E402

register_kind("mamba", schema=_ssm.mamba_schema, cache=_ssm.mamba_cache_schema,
              apply=_ssm.apply_mamba)
register_kind("mamba_attn", schema=_ssm.mamba_attn_schema,
              cache=_ssm.mamba_attn_cache_schema, apply=_ssm.apply_mamba_attn)
register_kind("rwkv", schema=_ssm.rwkv_schema, cache=_ssm.rwkv_cache_schema,
              apply=_ssm.apply_rwkv)

from repro.models import vlm as _vlm  # noqa: E402

register_kind("cross", schema=_vlm.cross_schema, cache=_vlm.cross_cache_schema,
              apply=_vlm.apply_cross)
