"""Whisper-style encoder-decoder backbone (family="audio").

The conv/mel frontend is a STUB per the assignment: ``extras["frames"]``
supplies precomputed frame embeddings (B, T_enc, d_model).  The encoder is
bidirectional; the decoder is causal self-attn + cross-attn to the encoder
output.  Shape semantics for the assigned cells:

  train_4k / prefill_32k : T_enc = shape.seq_len frames, decoder_len tokens
  decode_32k             : 1 new decoder token vs a cross K/V cache of
                           T_enc = seq_len (the seq_len-sized cache) plus a
                           decoder self cache of decoder_len.

This module mirrors repro.models.transformer's API (lm_schema, cache_schema,
forward, lm_logits, loss_fn) so runtime/steps.py can dispatch by family.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn_mod
from repro.models import losses
from repro.models.layers import ModelCtx, rms_norm, swiglu, unembed
from repro.models.params import PSpec
from repro.models.transformer import _attn_mlp_schema


def _enc_frames(cfg: ModelConfig) -> int:
    assert cfg.encoder_frames > 0, "set encoder_frames from shape.seq_len"
    return cfg.encoder_frames


def lm_schema(cfg: ModelConfig) -> Dict[str, Any]:
    Ge = cfg.encoder_layers
    Gd = cfg.num_layers
    dec_blocks = _attn_mlp_schema(cfg, Gd)
    D, KV, dh = cfg.d_model, cfg.num_kv_heads, cfg.resolved_head_dim
    H = cfg.num_heads
    heads_div = H % 16 == 0
    hq = "tp_heads" if heads_div else None
    hd_ax = "head_dim" if heads_div else "tp_head_dim"
    dec_blocks.update({
        "ln_x": PSpec((Gd, D), ("layers", None), "zeros"),
        "xwq": PSpec((Gd, D, H, dh), ("layers", "fsdp", hq, hd_ax)),
        "xwk": PSpec((Gd, D, KV, dh), ("layers", "fsdp", "tp_kv_heads", hd_ax)),
        "xwv": PSpec((Gd, D, KV, dh), ("layers", "fsdp", "tp_kv_heads", hd_ax)),
        "xwo": PSpec((Gd, H, dh, D), ("layers", hq, hd_ax, "fsdp")),
    })
    return {
        "embed": PSpec((cfg.vocab_size, D), ("tp_vocab", "fsdp"), scale=0.02),
        "pos_dec": PSpec((cfg.decoder_len, D), (None, None), scale=0.02),
        "enc_blocks": _attn_mlp_schema(cfg, Ge),
        "enc_norm": PSpec((D,), (None,), "zeros"),
        "dec_blocks": dec_blocks,
        "final_norm": PSpec((D,), (None,), "zeros"),
    }


def cache_schema(cfg: ModelConfig, B: int, S: int) -> Dict[str, Any]:
    G = cfg.num_layers
    KV, dh = cfg.num_kv_heads, cfg.resolved_head_dim
    ax = ("layers", "batch", "cache_seq", "kv_heads", "head_dim")
    return {
        "self": {"k": PSpec((G, B, cfg.decoder_len, KV, dh),
                            ("layers", "batch", None, "kv_heads", "head_dim"),
                            "zeros"),
                 "v": PSpec((G, B, cfg.decoder_len, KV, dh),
                            ("layers", "batch", None, "kv_heads", "head_dim"),
                            "zeros")},
        "cross": {"ck": PSpec((G, B, S, KV, dh), ax, "zeros"),
                  "cv": PSpec((G, B, S, KV, dh), ax, "zeros")},
    }


def _sinusoid(S: int, D: int, dtype) -> jax.Array:
    pos = jnp.arange(S)[:, None].astype(jnp.float32)
    dim = jnp.arange(D // 2)[None, :].astype(jnp.float32)
    ang = pos / jnp.power(10000.0, 2 * dim / D)
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], -1).astype(dtype)


def _self_block(ctx, p, x, *, causal, mode="train", cache=None, pos=None):
    cfg = ctx.cfg
    strategy = attn_mod.attn_strategy(ctx)
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    q, k, v = attn_mod.qkv_proj(ctx, p, h, jnp.arange(x.shape[1]), strategy)
    new_cache = {}
    if mode == "decode":
        from repro.models.transformer import insert_kv
        k_cache, v_cache = insert_kv(cache, k, v, pos)
        out = attn_mod.decode_attention(ctx, q, k_cache, v_cache, pos)
        new_cache = {"k": k_cache, "v": v_cache}
    else:
        out = attn_mod.causal_attention(ctx, q, k, v, strategy=strategy,
                                        mode=mode, causal=causal)
        if mode == "prefill":
            new_cache = {"k": k, "v": v}
    x = x + attn_mod.attn_out(ctx, p, out)
    h2 = rms_norm(x, p["ln2"], cfg.norm_eps)
    x = x + swiglu(ctx, {"wg": p["wg"], "wu": p["wu"], "wo": p["wo_mlp"]}, h2)
    return x, new_cache


def _cross_part(ctx, p, x, enc_out=None, cache=None, mode="train"):
    """Decoder cross-attention vs encoder output (or its cached K/V)."""
    cfg = ctx.cfg
    cd = ctx.compute_dtype
    strategy = attn_mod.attn_strategy(ctx)
    h = rms_norm(x, p["ln_x"], cfg.norm_eps)
    q = jnp.einsum("bsd,dhk->bshk", h, p["xwq"].astype(cd))
    if mode == "decode":
        k, v = cache["ck"].astype(cd), cache["cv"].astype(cd)
        out = attn_mod.decode_attention(ctx, q, k, v, jnp.int32(0),
                                        causal=False)
        new_cache = {"ck": cache["ck"], "cv": cache["cv"]}
    else:
        k = jnp.einsum("bsd,dhk->bshk", enc_out, p["xwk"].astype(cd))
        v = jnp.einsum("bsd,dhk->bshk", enc_out, p["xwv"].astype(cd))
        out = attn_mod.causal_attention(ctx, q, k, v, strategy=strategy,
                                        mode=mode, causal=False)
        cax = ("batch", "cache_seq", "kv_heads", "head_dim")
        new_cache = {"ck": ctx.cons(k, cax), "cv": ctx.cons(v, cax)} \
            if mode == "prefill" else {}
    out = jnp.einsum("bshk,hkd->bsd", out, p["xwo"].astype(cd))
    return x + out, new_cache


def _encode(ctx: ModelCtx, params, frames: jax.Array,
            mode: str = "train") -> jax.Array:
    cfg = ctx.cfg
    x = frames.astype(ctx.compute_dtype)
    x = x + _sinusoid(x.shape[1], cfg.d_model, x.dtype)[None]
    x = ctx.cons(x, ("batch", "act_seq_sharded", None))

    def body(carry, gp):
        y, _ = _self_block(ctx, gp, carry, causal=False, mode=mode)
        y = ctx.cons(y, ("batch", "act_seq_sharded", None))
        return y, None

    if mode == "train" and ctx.par.remat:
        body = jax.checkpoint(body, prevent_cse=False)
    x, _ = jax.lax.scan(body, x, params["enc_blocks"])
    return rms_norm(x, params["enc_norm"], cfg.norm_eps)


def forward(ctx: ModelCtx, params, tokens, *, mode: str = "train",
            caches=None, pos=None, extras=None):
    """tokens: decoder tokens (B, Td) (Td=1 for decode).

    Returns (decoder hidden states, new caches, aux=0).
    """
    cfg = ctx.cfg
    cd = ctx.compute_dtype
    B, Td = tokens.shape
    x = jnp.take(params["embed"].astype(cd), tokens, axis=0)
    if mode == "decode":
        p = jnp.asarray(pos)
        if p.ndim == 1:        # per-slot positions (continuous batching)
            pvec = jnp.take(params["pos_dec"], p, axis=0)[:, None]   # (B,1,D)
            x = x + pvec.astype(cd)
        else:
            pvec = jax.lax.dynamic_slice_in_dim(params["pos_dec"], pos, 1, 0)
            x = x + pvec.astype(cd)[None]
        enc_out = None
    else:
        x = x + params["pos_dec"].astype(cd)[None, :Td]
        enc_out = _encode(ctx, params, extras["frames"], mode=mode)

    def body(carry, xs):
        h = carry
        gp, gc = xs
        h, nc_self = _self_block(ctx, gp, h, causal=True, mode=mode,
                                 cache=None if gc is None else gc["self"],
                                 pos=pos)
        h, nc_cross = _cross_part(ctx, gp, h, enc_out=enc_out,
                                  cache=None if gc is None else gc["cross"],
                                  mode=mode)
        return h, {"self": nc_self, "cross": nc_cross}

    if mode == "train" and ctx.par.remat:
        body = jax.checkpoint(body, prevent_cse=False)
    x, new_caches = jax.lax.scan(body, x, (params["dec_blocks"], caches))
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return x, new_caches, jnp.zeros((), jnp.float32)


def lm_head(cfg: ModelConfig, params):
    return params["embed"]


def lm_logits(ctx: ModelCtx, params, x) -> jax.Array:
    return unembed(ctx, params["embed"], x, transpose=True)


def loss_fn(ctx: ModelCtx, params, batch) -> jax.Array:
    x, _, _ = forward(ctx, params, batch["tokens"], mode="train",
                      extras=batch["extras"])
    head = params["embed"].astype(ctx.compute_dtype)
    return losses.chunked_cross_entropy(x, batch["labels"], head)
