"""Top-k MoE with expert parallelism (EP): sort-based dispatch + all_to_all.

The routed-MLP pipeline per model-axis rank (megablocks/MaxText-style):

  router + top-k (outside shard_map, GSPMD-parallel)
  -> shard_map over the full mesh:
       sort assignments by destination rank -> capacity-bounded send buffer
       all_to_all (model axis)  [tokens -> their experts' ranks]
       sort received slots by local expert -> (E_local, cap_e, D) buckets
       grouped matmul (repro.kernels.moe_gmm is the Pallas hot-spot;
       this XLA einsum path is what the dry-run lowers)
       inverse all_to_all -> weighted combine at the source rank

Capacity-dropped tokens fall back to identity (standard Switch behavior);
the load-balance aux loss (Shazeer et al.) discourages the imbalance that
causes drops.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models.layers import ModelCtx, rms_norm
from repro.models.params import PSpec


def moe_schema(cfg: ModelConfig, G: int) -> Dict[str, PSpec]:
    D, F, E = cfg.d_model, cfg.d_ff, cfg.moe.num_experts
    return {
        "router": PSpec((G, D, E), ("layers", "fsdp", None), scale=0.02),
        "moe_wg": PSpec((G, E, D, F), ("layers", "expert", "fsdp", None)),
        "moe_wu": PSpec((G, E, D, F), ("layers", "expert", "fsdp", None)),
        "moe_wo": PSpec((G, E, F, D), ("layers", "expert", None, "fsdp")),
    }


def _local_mesh_size(ctx: ModelCtx, axis: str) -> int:
    return ctx.mesh.shape.get(axis, 1) if ctx.mesh is not None else 1


def _dispatch_compute_combine(x2d, top_idx, top_w, wg, wu, wo, *, E: int,
                              tp: int, cf: float, compute_dtype):
    """Per-rank routed MLP.  Runs inside shard_map (axis 'model' manual).

    x2d (T, D) local tokens; top_idx/top_w (T, K); wg/wu (E_local, D, F),
    wo (E_local, F, D).  Returns (T, D).
    """
    T, D = x2d.shape
    K = top_idx.shape[-1]
    E_local = E // tp
    TK = T * K

    flat_e = top_idx.reshape(TK)                       # global expert id
    flat_w = top_w.reshape(TK)
    flat_tok = jnp.repeat(jnp.arange(T), K)

    # ---- sort by destination rank, scatter into (tp, cap, D) send buffer
    dst = flat_e // E_local
    order = jnp.argsort(dst)                           # stable
    cap = int(-(-TK // tp) * cf)
    sorted_dst = dst[order]
    counts = jnp.bincount(dst, length=tp)
    seg_start = jnp.cumsum(counts) - counts
    pos = jnp.arange(TK) - seg_start[sorted_dst]       # position within segment
    keep = pos < cap
    slot_r = jnp.where(keep, sorted_dst, tp - 1)
    slot_c = jnp.where(keep, pos, cap - 1)

    src_tok = flat_tok[order]
    src_w = flat_w[order]
    local_e = (flat_e % E_local)[order]

    send = jnp.zeros((tp, cap, D), compute_dtype)
    send = send.at[slot_r, slot_c].set(
        jnp.where(keep[:, None], x2d[src_tok], 0), mode="drop")
    send_e = jnp.full((tp, cap), E_local, jnp.int32)   # E_local = invalid
    send_e = send_e.at[slot_r, slot_c].set(
        jnp.where(keep, local_e, E_local), mode="drop")

    # ---- exchange: rows -> their experts' ranks
    if tp > 1:
        recv = jax.lax.all_to_all(send, "model", 0, 0, tiled=False)
        recv_e = jax.lax.all_to_all(send_e, "model", 0, 0, tiled=False)
    else:
        recv, recv_e = send, send_e
    recv = recv.reshape(tp * cap, D)
    recv_e = recv_e.reshape(tp * cap)

    # ---- bucket received slots by local expert
    cap_e = int(-(-tp * cap // E_local) * cf)
    order2 = jnp.argsort(recv_e)
    sorted_e = recv_e[order2]
    counts_e = jnp.bincount(recv_e, length=E_local + 1)[:E_local]
    seg2 = jnp.cumsum(counts_e) - counts_e
    pos2 = jnp.arange(tp * cap) - jnp.concatenate(
        [seg2, jnp.zeros((1,), seg2.dtype)])[jnp.minimum(sorted_e, E_local)]
    keep2 = (pos2 < cap_e) & (sorted_e < E_local)
    be = jnp.where(keep2, sorted_e, 0)
    bc = jnp.where(keep2, pos2, cap_e - 1)

    bucket = jnp.zeros((E_local, cap_e, D), compute_dtype)
    bucket = bucket.at[be, bc].set(
        jnp.where(keep2[:, None], recv[order2], 0), mode="drop")

    # ---- grouped expert MLP (XLA batched matmul == kernels/moe_gmm oracle)
    gate = jnp.einsum("ecd,edf->ecf", bucket, wg.astype(compute_dtype))
    up = jnp.einsum("ecd,edf->ecf", bucket, wu.astype(compute_dtype))
    h = jax.nn.silu(gate.astype(jnp.float32)).astype(compute_dtype) * up
    y = jnp.einsum("ecf,efd->ecd", h, wo.astype(compute_dtype))

    # ---- un-bucket -> slots -> inverse exchange -> weighted combine
    slots_y = jnp.zeros((tp * cap, D), compute_dtype)
    slots_y = slots_y.at[order2].set(
        jnp.where(keep2[:, None], y[be, bc], 0))
    if tp > 1:
        back = jax.lax.all_to_all(slots_y.reshape(tp, cap, D), "model", 0, 0,
                                  tiled=False)
    else:
        back = slots_y.reshape(tp, cap, D)
    out = jnp.zeros((T, D), jnp.float32)
    gathered = back[slot_r, slot_c]                    # (TK, D) in sorted order
    out = out.at[src_tok].add(
        jnp.where(keep[:, None], gathered.astype(jnp.float32)
                  * src_w[:, None].astype(jnp.float32), 0))
    return out.astype(compute_dtype)


def moe_mlp(ctx: ModelCtx, p, x: jax.Array):
    """x (B,S,D) -> (B,S,D), plus the load-balance aux loss (f32 scalar)."""
    cfg = ctx.cfg
    mcfg = cfg.moe
    E, K = mcfg.num_experts, mcfg.top_k
    cd = ctx.compute_dtype
    B, S, D = x.shape

    logits = jnp.einsum("bsd,de->bse", x, p["router"].astype(cd))
    logits = logits.astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_idx = jax.lax.top_k(probs, K)
    top_w = top_w / jnp.maximum(jnp.sum(top_w, -1, keepdims=True), 1e-9)

    # load-balance aux loss: E * sum_e f_e * p_e
    ass = jax.nn.one_hot(top_idx, E, dtype=jnp.float32).sum(2)   # (B,S,E)
    f = jnp.mean(ass, axis=(0, 1))
    pbar = jnp.mean(probs, axis=(0, 1))
    aux = mcfg.router_aux_weight * E * jnp.sum(f * pbar)

    tp = _local_mesh_size(ctx, "model")
    if ctx.mesh is None or tp == 1:
        # single-rank path (smoke tests / reference): dense gather per expert
        out = _dispatch_compute_combine(
            x.reshape(B * S, D), top_idx.reshape(B * S, K),
            top_w.reshape(B * S, K), p["moe_wg"], p["moe_wu"], p["moe_wo"],
            E=E, tp=1, cf=mcfg.capacity_factor, compute_dtype=cd)
        return out.reshape(B, S, D), aux

    mesh = ctx.mesh
    dp_axes = tuple(a for a in ("pod", "data") if a in mesh.shape)

    if S % tp == 0:
        # main path: tokens seq-sharded across the model axis, sort-based
        # dispatch + all_to_all (training / prefill volumes)
        xspec = P(dp_axes, "model", None)

        def ranked(x_, ti, tw, wg, wu, wo):
            b, s, _ = x_.shape
            out = _dispatch_compute_combine(
                x_.reshape(b * s, D), ti.reshape(b * s, K),
                tw.reshape(b * s, K), wg, wu, wo, E=E, tp=tp,
                cf=mcfg.capacity_factor, compute_dtype=cd)
            return out.reshape(b, s, D)

        out = jax.shard_map(
            ranked, mesh=mesh,
            in_specs=(xspec, P(dp_axes, "model", None),
                      P(dp_axes, "model", None),
                      P("model", None, None), P("model", None, None),
                      P("model", None, None)),
            out_specs=xspec, check_vma=False,
        )(x, top_idx, top_w, p["moe_wg"], p["moe_wu"], p["moe_wo"])
        return out, aux

    # decode path (S == 1): token count per chip is tiny, so each model
    # rank runs ALL its local tokens through ALL its local experts densely,
    # weight-masks non-selected experts, and psums across ranks — exact
    # (no capacity drops), no sort/a2a, negligible overcompute at S=1.
    xspec = P(dp_axes, None, None)

    def local_experts(x_, ti, tw, wg, wu, wo):
        b, s, _ = x_.shape
        T = b * s
        x2 = x_.reshape(T, D)
        e_local = E // tp
        rank = jax.lax.axis_index("model")
        base = rank * e_local
        gate = jnp.einsum("td,edf->tef", x2, wg.astype(cd))
        up = jnp.einsum("td,edf->tef", x2, wu.astype(cd))
        h = jax.nn.silu(gate.astype(jnp.float32)).astype(cd) * up
        y = jnp.einsum("tef,efd->ted", h, wo.astype(cd))
        eids = base + jnp.arange(e_local)                   # (e,)
        w_te = jnp.sum(jnp.where(ti.reshape(T, K, 1) == eids[None, None],
                                 tw.reshape(T, K, 1), 0.0), axis=1)  # (T,e)
        out = jnp.einsum("ted,te->td", y.astype(jnp.float32), w_te)
        out = jax.lax.psum(out, "model")
        return out.reshape(b, s, D).astype(cd)

    out = jax.shard_map(
        local_experts, mesh=mesh,
        in_specs=(xspec, P(dp_axes, None, None), P(dp_axes, None, None),
                  P("model", None, None), P("model", None, None),
                  P("model", None, None)),
        out_specs=xspec, check_vma=False,
    )(x, top_idx, top_w, p["moe_wg"], p["moe_wu"], p["moe_wo"])
    return out, aux


def moe_block_schema(cfg: ModelConfig, G: int) -> Dict[str, PSpec]:
    from repro.models.transformer import _attn_mlp_schema
    s = _attn_mlp_schema(cfg, G)
    del s["wg"], s["wu"], s["wo_mlp"]  # replaced by routed experts
    s.update(moe_schema(cfg, G))
    return s


def apply_moe_block(ctx, p, x, *, mode, positions, cache, pos, shared, extras):
    from repro.models.transformer import attention_part
    cfg = ctx.cfg
    x, new_cache = attention_part(ctx, p, x, window=None, mode=mode,
                                  positions=positions, cache=cache, pos=pos)
    h = rms_norm(x, p["ln2"], cfg.norm_eps)
    if ctx.par.sequence_parallel and mode == "train":
        h = ctx.cons(h, ("batch", "act_seq_sharded", None))
    out, aux = moe_mlp(ctx, p, h)
    if cfg.post_norm:
        out = rms_norm(out, p["ln2_post"], cfg.norm_eps)
    return x + out, new_cache, aux
