"""Shared layers: RMSNorm, RoPE, SwiGLU MLP, embeddings + a ModelCtx carrying
mesh/rules so every module can place activations with logical axes."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.configs.base import ModelConfig, ParallelConfig
from repro.sharding import specs as sh


@dataclass(frozen=True)
class ModelCtx:
    cfg: ModelConfig
    par: ParallelConfig
    mesh: Optional[Mesh] = None

    @property
    def rules(self):
        return sh.logical_rules(self.par)

    def cons(self, x: jax.Array, axes) -> jax.Array:
        """with_sharding_constraint by logical axes (no-op without a mesh)."""
        if self.mesh is None:
            return x
        return sh.constrain(x, axes, self.mesh, self.rules)

    @property
    def compute_dtype(self):
        return jnp.dtype(self.cfg.compute_dtype)


def rms_norm(x: jax.Array, scale: jax.Array, eps: float) -> jax.Array:
    """RMSNorm with f32 *accumulation* but no f32 copy of x.

    x.astype(f32) materializes a 2x-bytes activation copy per call (and its
    backward another) — measured as the dominant per-layer temp at 90B
    scale.  An einsum with preferred_element_type=f32 accumulates the
    variance in f32 while reading bf16, and the scale-multiply stays in the
    input dtype.
    """
    var = jnp.einsum("...d,...d->...", x, x,
                     preferred_element_type=jnp.float32) / x.shape[-1]
    inv = jax.lax.rsqrt(var + eps)[..., None].astype(x.dtype)
    return x * inv * (1.0 + scale.astype(x.dtype))


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotary embedding.  x: (B, S, N, dh); positions: (S,) or (B, S)."""
    dh = x.shape[-1]
    half = dh // 2
    freqs = theta ** (-jnp.arange(half, dtype=jnp.float32) / half)  # (half,)
    if positions.ndim == 1:
        ang = positions[None, :, None].astype(jnp.float32) * freqs  # (1,S,half)
    else:
        ang = positions[:, :, None].astype(jnp.float32) * freqs     # (B,S,half)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([xf1 * cos - xf2 * sin, xf1 * sin + xf2 * cos], axis=-1)
    return out.astype(x.dtype)


def softcap(x: jax.Array, cap: Optional[float]) -> jax.Array:
    if cap is None:
        return x
    return (cap * jnp.tanh(x.astype(jnp.float32) / cap)).astype(x.dtype)


def swiglu(ctx: ModelCtx, p, x: jax.Array) -> jax.Array:
    """p: {"wg","wu": (D, F), "wo": (F, D)} (leading layer dims indexed).

    Gate/up are separate tensors (not a fused (D,2,F)): fused layouts either
    break TP-sharding of F on a slice or degenerate Adafactor row/col
    factoring (observed on the 1T MoE — see DESIGN.md §5).
    """
    cd = ctx.compute_dtype
    gate = jnp.einsum("bsd,df->bsf", x, p["wg"].astype(cd))
    up = jnp.einsum("bsd,df->bsf", x, p["wu"].astype(cd))
    gate = ctx.cons(gate, ("batch", "seq", "act_ff"))
    up = ctx.cons(up, ("batch", "seq", "act_ff"))
    h = jax.nn.silu(gate.astype(jnp.float32)).astype(cd) * up
    return jnp.einsum("bsf,fd->bsd", h, p["wo"].astype(cd))


def embed_tokens(ctx: ModelCtx, embed: jax.Array, tokens: jax.Array) -> jax.Array:
    x = jnp.take(embed.astype(ctx.compute_dtype), tokens, axis=0)
    if getattr(ctx.cfg, "embed_scale", False):
        x = x * jnp.asarray(ctx.cfg.d_model ** 0.5, ctx.compute_dtype)
    return ctx.cons(x, ("batch", "seq", None))


def unembed(ctx: ModelCtx, embed_or_head: jax.Array, x: jax.Array,
            transpose: bool) -> jax.Array:
    """Logits, sharded on vocab (model axis) to avoid replicated (B,S,V)."""
    w = embed_or_head.astype(ctx.compute_dtype)
    eq = "bsd,vd->bsv" if transpose else "bsd,dv->bsv"
    logits = jnp.einsum(eq, x, w)
    logits = ctx.cons(logits, ("batch", "seq", "act_vocab"))
    return softcap(logits, ctx.cfg.final_logit_softcap)


def cross_entropy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Mean token NLL; stable in f32.  logits (B,S,V) may be vocab-sharded."""
    lf = logits.astype(jnp.float32)
    m = jnp.max(lf, axis=-1, keepdims=True)
    lse = m[..., 0] + jnp.log(jnp.sum(jnp.exp(lf - m), axis=-1))
    gold = jnp.take_along_axis(lf, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - gold)
