"""Sequence-mixing recurrences: Mamba2 (SSD), RWKV6 (WKV), zamba2 hybrid.

Recurrent state crosses the sequence dim, so these blocks do NOT sequence-
shard; the inner/head dims shard on the model axis instead ("tp_inner").
Both use a *chunked* formulation: exact intra-chunk pairwise math + a
sequential inter-chunk state scan (S/chunk steps), which is the standard
sub-quadratic TPU-friendly decomposition (and what the Pallas ssm_scan /
wkv6 kernels implement for the hot inner part; ref oracle = the naive
recurrence in repro.kernels.*_ref).

Numerical care: decays live in log space; pairwise (t, s, channel) decay
differences are computed inside the exp (never exp(+cumlog) alone), so
chunked == naive to fp tolerance even for strong decay.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import ModelCtx, rms_norm
from repro.models.params import PSpec


# ===========================================================================
# Mamba2 (SSD)
# ===========================================================================

def _mamba_dims(cfg: ModelConfig):
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    nheads = d_in // s.head_dim
    return d_in, nheads, s.head_dim, s.state_dim, s.conv_kernel


def mamba_schema(cfg: ModelConfig, G: int) -> Dict[str, PSpec]:
    D = cfg.d_model
    d_in, H, hd, N, K = _mamba_dims(cfg)
    return {
        "ln": PSpec((G, D), ("layers", None), "zeros"),
        "wz": PSpec((G, D, d_in), ("layers", "fsdp", "tp_inner")),
        "wx": PSpec((G, D, d_in), ("layers", "fsdp", "tp_inner")),
        "wB": PSpec((G, D, N), ("layers", "fsdp", None)),
        "wC": PSpec((G, D, N), ("layers", "fsdp", None)),
        "wdt": PSpec((G, D, H), ("layers", "fsdp", "tp_inner_heads")),
        "dt_bias": PSpec((G, H), ("layers", "tp_inner_heads"), "zeros"),
        "A_log": PSpec((G, H), ("layers", "tp_inner_heads"), "zeros"),
        "D_skip": PSpec((G, H), ("layers", "tp_inner_heads"), "ones"),
        "conv_w": PSpec((G, K, d_in), ("layers", "conv_k", "tp_inner"),
                        scale=0.5),
        "ln_y": PSpec((G, d_in), ("layers", "tp_inner"), "zeros"),
        "wout": PSpec((G, d_in, D), ("layers", "tp_inner", "fsdp")),
    }


def mamba_cache_schema(cfg: ModelConfig, B: int, S: int, G: int):
    d_in, H, hd, N, K = _mamba_dims(cfg)
    return {
        "conv": PSpec((G, B, K - 1, d_in),
                      ("layers", "batch", None, "tp_inner"), "zeros"),
        "state": PSpec((G, B, H, hd, N),
                       ("layers", "batch", "tp_inner_heads", None, None),
                       "zeros", dtype="float32"),
    }


def _causal_conv(x, w, cache=None):
    """Depthwise causal conv.  x (B,S,C); w (K,C); cache (B,K-1,C) | None."""
    K = w.shape[0]
    if cache is None:
        pad = jnp.zeros(x.shape[:1] + (K - 1,) + x.shape[2:], x.dtype)
        xp = jnp.concatenate([pad, x], axis=1)
    else:
        xp = jnp.concatenate([cache.astype(x.dtype), x], axis=1)
    out = sum(w[k] * jax.lax.dynamic_slice_in_dim(xp, k, x.shape[1], axis=1)
              for k in range(K))
    new_cache = jax.lax.dynamic_slice_in_dim(
        xp, xp.shape[1] - (K - 1), K - 1, axis=1)
    return out, new_cache


def _ssd_chunked(xh, dt, a, Bm, Cm, h0, chunk: int):
    """Chunked SSD scan.

    xh (B,S,H,hd) conv'd inputs; dt (B,S,H) >0; a (H,) <0; Bm/Cm (B,S,N);
    h0 (B,H,hd,N) initial state.  Returns (y (B,S,H,hd), h_last).
    """
    Bsz, S, H, hd = xh.shape
    N = Bm.shape[-1]
    chunk = min(chunk, S)
    if S % chunk:
        chunk = S
    nc = S // chunk

    def r(t):  # (B,S,...) -> (nc,B,c,...)
        return jnp.moveaxis(t.reshape(Bsz, nc, chunk, *t.shape[2:]), 1, 0)

    xh_c, dt_c, B_c, C_c = r(xh), r(dt), r(Bm), r(Cm)
    da_c = dt_c * a                      # (nc,B,c,H)  negative
    cum = jnp.cumsum(da_c, axis=2)       # within-chunk cumulative log-decay

    @jax.checkpoint
    def step(h, xs):
        xc, dtc, bc, cc, dac, cumc = xs  # (B,c,...)
        # intra-chunk: y[t] += sum_{s<=t} C_t.B_s exp(cum[t]-cum[s]) dt_s x_s
        cb = jnp.einsum("btn,bsn->bts", cc, bc).astype(jnp.float32)
        delta = cumc[:, :, None, :] - cumc[:, None, :, :]        # (B,t,s,H)
        tri = jnp.tril(jnp.ones((chunk, chunk), bool))
        L = jnp.where(tri[None, :, :, None], jnp.exp(delta), 0.0)
        w = cb[..., None] * L                                     # (B,t,s,H)
        dx = dtc[..., None] * xc.astype(jnp.float32)              # (B,s,H,hd)
        y = jnp.einsum("btsh,bshd->bthd", w, dx)
        # inter-chunk: contribution of the carried state
        y = y + jnp.einsum("btn,bth,bhdn->bthd",
                           cc.astype(jnp.float32), jnp.exp(cumc), h)
        # new chunk state
        decay_to_end = jnp.exp(cumc[:, -1:, :] - cumc)            # (B,s,H)
        S_chunk = jnp.einsum("bsh,bsn,bshd->bhdn",
                             (dtc * decay_to_end).astype(jnp.float32),
                             bc.astype(jnp.float32),
                             xc.astype(jnp.float32))
        h_new = jnp.exp(cumc[:, -1, :])[..., None, None] * h + S_chunk
        return h_new, y

    h_last, ys = jax.lax.scan(step, h0.astype(jnp.float32),
                              (xh_c, dt_c, B_c, C_c, da_c, cum))
    y = jnp.moveaxis(ys, 0, 1).reshape(Bsz, S, H, hd)
    return y.astype(xh.dtype), h_last


def apply_mamba(ctx: ModelCtx, p, x, *, mode, positions, cache, pos, shared,
                extras):
    cfg = ctx.cfg
    d_in, H, hd, N, K = _mamba_dims(cfg)
    cd = ctx.compute_dtype
    h = rms_norm(x, p["ln"], cfg.norm_eps)

    z = jnp.einsum("bsd,de->bse", h, p["wz"].astype(cd))
    xs = jnp.einsum("bsd,de->bse", h, p["wx"].astype(cd))
    Bm = jnp.einsum("bsd,dn->bsn", h, p["wB"].astype(cd))
    Cm = jnp.einsum("bsd,dn->bsn", h, p["wC"].astype(cd))
    dt_raw = jnp.einsum("bsd,dh->bsh", h, p["wdt"].astype(cd))
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))
    a = -jnp.exp(p["A_log"].astype(jnp.float32))

    new_cache = {}
    if mode == "decode":
        xs_c, conv_cache = _causal_conv(xs, p["conv_w"].astype(cd),
                                        cache["conv"])
        xs_c = jax.nn.silu(xs_c.astype(jnp.float32)).astype(cd)
        xh = xs_c.reshape(*xs_c.shape[:2], H, hd)
        st = cache["state"].astype(jnp.float32)        # (B,H,hd,N)
        da = jnp.exp(dt[:, 0] * a)                     # (B,H)
        upd = jnp.einsum("bh,bn,bhd->bhdn", dt[:, 0].astype(jnp.float32),
                         Bm[:, 0].astype(jnp.float32),
                         xh[:, 0].astype(jnp.float32))
        st = da[..., None, None] * st + upd
        y = jnp.einsum("bn,bhdn->bhd", Cm[:, 0].astype(jnp.float32), st)
        y = y[:, None].astype(cd)                      # (B,1,H,hd)
        new_cache = {"conv": conv_cache, "state": st}
    else:
        xs_c, conv_cache = _causal_conv(xs, p["conv_w"].astype(cd))
        xs_c = jax.nn.silu(xs_c.astype(jnp.float32)).astype(cd)
        xh = xs_c.reshape(*xs_c.shape[:2], H, hd)
        xh = ctx.cons(xh, ("batch", None, "act_inner_heads", None))
        h0 = jnp.zeros((x.shape[0], H, hd, N), jnp.float32)
        y, h_last = _ssd_chunked(xh, dt, a, Bm, Cm, h0, cfg.ssm.chunk)
        if mode == "prefill":
            new_cache = {"conv": conv_cache, "state": h_last}
    y = y + p["D_skip"].astype(cd)[None, None, :, None] * \
        (xh if mode != "decode" else xh[:, :1])
    y = y.reshape(*y.shape[:2], d_in)
    y = rms_norm(y, p["ln_y"], cfg.norm_eps)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(cd)
    out = jnp.einsum("bse,ed->bsd", y, p["wout"].astype(cd))
    return x + out, new_cache, 0.0


# --- zamba2 hybrid: mamba + SHARED attention block (weights stored once) ---

def shared_attn_schema(cfg: ModelConfig):
    from repro.models.transformer import _attn_mlp_schema
    s = _attn_mlp_schema(cfg, 1)
    return {k: PSpec(v.shape[1:], v.axes[1:], v.init, v.scale, v.dtype)
            for k, v in s.items()}


def mamba_attn_schema(cfg: ModelConfig, G: int) -> Dict[str, PSpec]:
    return mamba_schema(cfg, G)


def mamba_attn_cache_schema(cfg: ModelConfig, B: int, S: int, G: int):
    from repro.models.transformer import _attn_cache_schema
    out = dict(mamba_cache_schema(cfg, B, S, G))
    out["attn"] = _attn_cache_schema(cfg, B, S, G)
    return out


def apply_mamba_attn(ctx: ModelCtx, p, x, *, mode, positions, cache, pos,
                     shared, extras):
    """Mamba block followed by the *shared* attention block (zamba2)."""
    from repro.models.transformer import attention_part, mlp_part
    mcache = None if cache is None else {k: cache[k] for k in ("conv", "state")}
    x, new_mcache, _ = apply_mamba(ctx, p, x, mode=mode, positions=positions,
                                   cache=mcache, pos=pos, shared=None,
                                   extras=extras)
    x, new_attn = attention_part(ctx, shared, x, window=None, mode=mode,
                                 positions=positions,
                                 cache=None if cache is None else cache["attn"],
                                 pos=pos)
    x = mlp_part(ctx, shared, x, mode)
    new_cache = dict(new_mcache)
    if new_attn:
        new_cache["attn"] = new_attn
    return x, new_cache, 0.0


# ===========================================================================
# RWKV6 (Finch): data-dependent per-channel decay
# ===========================================================================

def _rwkv_dims(cfg: ModelConfig):
    hd = cfg.rwkv.head_dim
    H = cfg.d_model // hd
    return H, hd


def rwkv_schema(cfg: ModelConfig, G: int) -> Dict[str, PSpec]:
    D, F = cfg.d_model, cfg.d_ff
    lora = 64
    tm = {
        "ln1": PSpec((G, D), ("layers", None), "zeros"),
        "mu_r": PSpec((G, D), ("layers", None), "ones", scale=0.5),
        "mu_k": PSpec((G, D), ("layers", None), "ones", scale=0.5),
        "mu_v": PSpec((G, D), ("layers", None), "ones", scale=0.5),
        "mu_w": PSpec((G, D), ("layers", None), "ones", scale=0.5),
        "mu_g": PSpec((G, D), ("layers", None), "ones", scale=0.5),
        "wr": PSpec((G, D, D), ("layers", "fsdp", "tp_inner")),
        "wk": PSpec((G, D, D), ("layers", "fsdp", "tp_inner")),
        "wv": PSpec((G, D, D), ("layers", "fsdp", "tp_inner")),
        "wg": PSpec((G, D, D), ("layers", "fsdp", "tp_inner")),
        "w0": PSpec((G, D), ("layers", None), "zeros"),
        "wA": PSpec((G, D, lora), ("layers", "fsdp", None), scale=0.01),
        "wB": PSpec((G, lora, D), ("layers", None, "tp_inner"), scale=0.01),
        "u": PSpec((G, D), ("layers", None), "zeros"),
        "ln_x": PSpec((G, D), ("layers", None), "zeros"),
        "wout": PSpec((G, D, D), ("layers", "tp_inner", "fsdp")),
        # channel mix
        "ln2": PSpec((G, D), ("layers", None), "zeros"),
        "mu_ck": PSpec((G, D), ("layers", None), "ones", scale=0.5),
        "mu_cr": PSpec((G, D), ("layers", None), "ones", scale=0.5),
        "wk_c": PSpec((G, D, F), ("layers", "fsdp", "tp_ff")),
        "wv_c": PSpec((G, F, D), ("layers", "tp_ff", "fsdp")),
        "wr_c": PSpec((G, D, D), ("layers", "fsdp", "tp_inner")),
    }
    return tm


def rwkv_cache_schema(cfg: ModelConfig, B: int, S: int, G: int):
    H, hd = _rwkv_dims(cfg)
    return {
        "shift1": PSpec((G, B, 1, cfg.d_model), ("layers", "batch", None, None),
                        "zeros"),
        "shift2": PSpec((G, B, 1, cfg.d_model), ("layers", "batch", None, None),
                        "zeros"),
        "state": PSpec((G, B, H, hd, hd),
                       ("layers", "batch", "act_inner_heads", None, None),
                       "zeros", dtype="float32"),
    }


def _token_shift(x, prev):
    """x (B,S,D); prev (B,1,D) last token of the previous segment."""
    return jnp.concatenate([prev.astype(x.dtype), x[:, :-1]], axis=1)


def _wkv_chunked(r, k, v, logw, u, s0, chunk: int):
    """Chunked WKV6.  r/k/v (B,S,H,hd); logw (B,S,H,hd) <0; u (H,hd);
    s0 (B,H,hd,hd).  Returns (y (B,S,H,hd), s_last)."""
    B, S, H, hd = r.shape
    chunk = min(chunk, S)
    if S % chunk:
        chunk = S
    nc = S // chunk

    def rs(t):
        return jnp.moveaxis(t.reshape(B, nc, chunk, H, hd), 1, 0)

    r_c, k_c, v_c, w_c = rs(r), rs(k), rs(v), rs(logw)
    cum = jnp.cumsum(w_c, axis=2)        # (nc,B,c,H,hd)

    tri_lt = jnp.tril(jnp.ones((chunk, chunk), bool), k=-1)

    @jax.checkpoint
    def step(s, xs):
        rc, kc, vc, cumc, wc = xs        # (B,c,H,hd)
        rf = rc.astype(jnp.float32)
        kf = kc.astype(jnp.float32)
        vf = vc.astype(jnp.float32)
        # y_t reads S_{t-1}: pair (s<t) decays by w_{s+1..t-1} =
        # exp(cum[t] - w[t] - cum[s]) — one-step shift vs the state update.
        cum_prev = cumc - wc.astype(jnp.float32)
        delta = cum_prev[:, :, None] - cumc[:, None, :, :]       # (B,t,s,H,hd)
        att = jnp.einsum("bthi,bshi,btshi->btsh",
                         rf, kf, jnp.where(tri_lt[None, :, :, None, None],
                                           jnp.exp(delta), 0.0))
        y = jnp.einsum("btsh,bshj->bthj", att, vf)
        # current-token bonus: y[t,j] += (sum_i r[t,i] u[i] k[t,i]) v[t,j]
        y = y + jnp.einsum("bthi,bthj->bthj",
                           rf * u.astype(jnp.float32)[None, None] * kf, vf)
        # carried state contribution: r_t exp(cum[t-1]) @ S
        y = y + jnp.einsum("bthi,bhij->bthj", rf * jnp.exp(cum_prev), s)
        # new state: S' = exp(cum[last]) S + sum_s exp(cum[last]-cum[s]) k_s v_s
        dec_end = jnp.exp(cumc[:, -1:] - cumc)                   # (B,s,H,hd)
        s_new = jnp.exp(cumc[:, -1])[..., None] * s + \
            jnp.einsum("bshi,bshj->bhij", kf * dec_end, vf)
        return s_new, y

    s_last, ys = jax.lax.scan(step, s0.astype(jnp.float32),
                              (r_c, k_c, v_c, cum, w_c))
    y = jnp.moveaxis(ys, 0, 1).reshape(B, S, H, hd)
    return y, s_last


def apply_rwkv(ctx: ModelCtx, p, x, *, mode, positions, cache, pos, shared,
               extras):
    cfg = ctx.cfg
    H, hd = _rwkv_dims(cfg)
    cd = ctx.compute_dtype
    B, S, D = x.shape
    new_cache = {}

    # ---- time mix ----
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    if mode == "decode":
        hs = cache["shift1"].astype(h.dtype)
    else:
        hs = _token_shift(h, jnp.zeros((B, 1, D), h.dtype))

    def mix(mu):
        return h * mu.astype(cd) + hs * (1.0 - mu.astype(cd))

    r = jnp.einsum("bsd,de->bse", mix(p["mu_r"]), p["wr"].astype(cd))
    k = jnp.einsum("bsd,de->bse", mix(p["mu_k"]), p["wk"].astype(cd))
    v = jnp.einsum("bsd,de->bse", mix(p["mu_v"]), p["wv"].astype(cd))
    g = jnp.einsum("bsd,de->bse", mix(p["mu_g"]), p["wg"].astype(cd))
    wx = mix(p["mu_w"])
    lora = jnp.einsum("bsd,dl->bsl", wx, p["wA"].astype(cd))
    lora = jnp.einsum("bsl,ld->bsd", jnp.tanh(lora), p["wB"].astype(cd))
    logw = -jnp.exp(p["w0"].astype(jnp.float32)
                    + lora.astype(jnp.float32))          # (B,S,D) < 0
    logw = jnp.maximum(logw, -8.0)                       # numerical floor

    ax = ("batch", None, "act_inner_heads", None)
    rh = ctx.cons(r.reshape(B, S, H, hd), ax)
    kh = ctx.cons(k.reshape(B, S, H, hd), ax)
    vh = ctx.cons(v.reshape(B, S, H, hd), ax)
    wh = ctx.cons(logw.reshape(B, S, H, hd), ax)
    uh = p["u"].astype(jnp.float32).reshape(H, hd)

    if mode == "decode":
        st = cache["state"].astype(jnp.float32)          # (B,H,hd,hd)
        rf, kf, vf = (t[:, 0].astype(jnp.float32) for t in (rh, kh, vh))
        kv = jnp.einsum("bhi,bhj->bhij", kf, vf)
        y = jnp.einsum("bhi,bhij->bhj", rf, st + uh[None, :, :, None] * kv)
        st = jnp.exp(wh[:, 0].astype(jnp.float32))[..., None] * st + kv
        y = y[:, None]                                   # (B,1,H,hd)
        new_cache = {"shift1": h, "state": st}
    else:
        s0 = jnp.zeros((B, H, hd, hd), jnp.float32)
        y, s_last = _wkv_chunked(rh, kh, vh, wh, uh, s0, cfg.rwkv.chunk)
        if mode == "prefill":
            new_cache = {"shift1": h[:, -1:], "state": s_last}
    y = y.reshape(B, S if mode != "decode" else 1, D).astype(cd)
    y = rms_norm(y, p["ln_x"], cfg.norm_eps)
    y = y * jax.nn.silu(g.astype(jnp.float32)).astype(cd)
    out = jnp.einsum("bse,ed->bsd", y, p["wout"].astype(cd))
    x = x + out

    # ---- channel mix ----
    h2 = rms_norm(x, p["ln2"], cfg.norm_eps)
    prev2 = cache["shift2"].astype(h2.dtype) if mode == "decode" else \
        jnp.zeros((B, 1, D), h2.dtype)
    hs2 = _token_shift(h2, prev2) if mode != "decode" else prev2

    def mix2(mu):
        return h2 * mu.astype(cd) + hs2 * (1.0 - mu.astype(cd))

    kc = jnp.einsum("bsd,df->bsf", mix2(p["mu_ck"]), p["wk_c"].astype(cd))
    kc = jnp.square(jax.nn.relu(kc.astype(jnp.float32))).astype(cd)
    vc = jnp.einsum("bsf,fd->bsd", kc, p["wv_c"].astype(cd))
    rc = jax.nn.sigmoid(jnp.einsum(
        "bsd,de->bse", mix2(p["mu_cr"]), p["wr_c"].astype(cd)
    ).astype(jnp.float32)).astype(cd)
    x = x + rc * vc
    if mode == "decode":
        new_cache["shift2"] = h2
    elif mode == "prefill":
        new_cache["shift2"] = h2[:, -1:]
    return x, new_cache, 0.0
