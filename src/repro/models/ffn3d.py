"""Flood-Filling Network (FFN) — 3-D CNN for object segmentation (paper §III).

The paper adapts Google's FFN (Januszewski et al., Nature Methods 2018) from
connectomics to NASA MERRA-2 IVT volumes: a deep residual stack of 3x3x3
convolutions that, given the raw volume AND the current object-mask belief,
predicts an updated mask; inference iterates this until the mask converges
("flood filling").  We reproduce that design: input channels = [ivt, mask],
K residual conv blocks, logit output; ``flood_fill_step`` is one belief
update, ``flood_fill`` iterates it.

The FFN trains on one device (paper: 1 GPU) and serves tiled over many
workers (paper: 50 GPUs) — the distribution lives in the *workflow* layer
(apps/connect/pipeline.py), faithful to the paper's architecture.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict

import jax
import jax.numpy as jnp

from repro.models.params import PSpec

CONV_DN = ("NDHWC", "DHWIO", "NDHWC")


@dataclass(frozen=True)
class FFNConfig:
    depth: int = 8              # residual blocks (paper's "deep stack")
    width: int = 32             # feature maps
    kernel: int = 3
    fov: tuple = (16, 32, 32)   # (t, lat, lon) field of view
    flood_iters: int = 4
    mask_init: float = 0.05     # initial belief inside the seed


def ffn_schema(cfg: FFNConfig) -> Dict[str, PSpec]:
    k, w = cfg.kernel, cfg.width
    fan_stem = (k ** 3 * 2) ** -0.5
    fan_blk = (k ** 3 * w) ** -0.5
    schema: Dict[str, PSpec] = {
        "stem": PSpec((k, k, k, 2, w), (None,) * 5, scale=fan_stem),
        "head": PSpec((1, 1, 1, w, 1), (None,) * 5, scale=0.05),
        "head_b": PSpec((1,), (None,), "zeros"),
    }
    for i in range(cfg.depth):
        schema[f"block{i}_a"] = PSpec((k, k, k, w, w), (None,) * 5,
                                      scale=fan_blk)
        # zero-init the second conv: each residual block starts as identity
        schema[f"block{i}_b"] = PSpec((k, k, k, w, w), (None,) * 5, "zeros")
    return schema


def _conv(x, w):
    return jax.lax.conv_general_dilated(
        x, w, window_strides=(1, 1, 1), padding="SAME",
        dimension_numbers=CONV_DN)


def ffn_apply(cfg: FFNConfig, params, ivt, mask_logit):
    """One FFN belief update.  ivt (B,T,H,W); mask_logit (B,T,H,W) ->
    updated mask logits (residual, as in the original FFN)."""
    x = jnp.stack([ivt, jax.nn.sigmoid(mask_logit)], axis=-1)   # (B,T,H,W,2)
    h = jax.nn.relu(_conv(x, params["stem"]))
    for i in range(cfg.depth):
        r = jax.nn.relu(_conv(h, params[f"block{i}_a"]))
        r = _conv(r, params[f"block{i}_b"])
        h = jax.nn.relu(h + r)
    delta = _conv(h, params["head"])[..., 0] + params["head_b"]
    return mask_logit + delta          # FFN updates its belief residually


def seed_mask(cfg: FFNConfig, shape) -> jnp.ndarray:
    """Center-seeded initial belief (logit space), as in FFN inference."""
    B, T, H, W = shape
    logit0 = jnp.log(cfg.mask_init / (1 - cfg.mask_init))
    m = jnp.full((B, T, H, W), logit0, jnp.float32)
    return m.at[:, :, H // 2, W // 2].set(-logit0)


def flood_fill(cfg: FFNConfig, params, ivt, iters: int | None = None):
    """Iterated belief updates (the 'flood fill')."""
    it = cfg.flood_iters if iters is None else iters

    def body(i, m):
        return ffn_apply(cfg, params, ivt, m)

    return jax.lax.fori_loop(0, it, body, seed_mask(cfg, ivt.shape))


def bce_loss(cfg: FFNConfig, params, ivt, labels):
    """Train objective: BCE of the one-step update from the seed belief
    (+ a final-belief term so flood-filling converges toward labels)."""
    logits = ffn_apply(cfg, params, ivt, seed_mask(cfg, ivt.shape))
    z = labels.astype(jnp.float32)
    bce = jnp.maximum(logits, 0) - logits * z + \
        jnp.log1p(jnp.exp(-jnp.abs(logits)))
    return jnp.mean(bce)


def iou(pred_mask: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    p = pred_mask.astype(bool)
    l = labels.astype(bool)
    inter = jnp.sum(p & l)
    union = jnp.maximum(jnp.sum(p | l), 1)
    return inter / union
