"""GQA attention with two TP strategies + context-parallel decode.

Strategies (picked per arch by head divisibility vs the model axis):

  "heads" — Megatron-style: q/k/v head-sharded on "model"; q-chunked causal
            scan (never materializes (S,S) scores).  Needs H % tp == 0.
  "seq"   — sequence-sharded attention for archs whose head count does not
            divide the model axis (phi4: 24 heads, whisper: 12).  q stays
            seq-sharded; the small GQA k/v are all-gathered (2*KV*dh ≪ D
            bytes/token).  Training uses one full-scores block per layer
            (transient, remat'd); no-grad prefill uses a k-chunked
            online-softmax scan (flash recurrence) to bound live memory.

  decode  — one token vs a seq-sharded KV cache: explicit partial-max /
            partial-sum reductions (flash-decode) so GSPMD emits tiny stat
            all-reduces, never an all-gather of the cache.

The Pallas flash kernel (repro.kernels.flash_attention) is the TPU hot-spot
implementation validated against repro.kernels.ref; these XLA paths are what
the dry-run lowers (DESIGN.md §2).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.layers import ModelCtx, rope, softcap

NEG_INF = -1e30


def attn_strategy(ctx: ModelCtx) -> str:
    tp = ctx.mesh.shape.get("model", 1) if ctx.mesh is not None else 1
    return "heads" if ctx.cfg.num_heads % tp == 0 else "seq"


def qkv_proj(ctx: ModelCtx, p, x: jax.Array, positions: jax.Array,
             strategy: str = "heads"):
    """x (B,S,D) -> q (B,S,H,dh), k/v (B,S,KV,dh), RoPE'd, strategy-placed."""
    cd = ctx.compute_dtype
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(cd))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(cd))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(cd))
    if "bq" in p:
        q = q + p["bq"].astype(cd)
        k = k + p["bk"].astype(cd)
        v = v + p["bv"].astype(cd)
    if ctx.cfg.attn.use_rope:
        q = rope(q, positions, ctx.cfg.attn.rope_theta)
        k = rope(k, positions, ctx.cfg.attn.rope_theta)
    if strategy == "seq":
        q = ctx.cons(q, ("batch", "act_seq_sharded", None, None))
        k = ctx.cons(k, ("batch", None, None, None))   # replicated == AG(kv)
        v = ctx.cons(v, ("batch", None, None, None))
    else:
        q = ctx.cons(q, ("batch", "seq", "heads", "head_dim"))
        k = ctx.cons(k, ("batch", "seq", "kv_heads", "head_dim"))
        v = ctx.cons(v, ("batch", "seq", "kv_heads", "head_dim"))
    return q, k, v


def _mask(qpos, kpos, window, causal=True):
    if not causal:
        return jnp.ones((qpos.shape[0], kpos.shape[0]), bool)
    m = kpos[None, :] <= qpos[:, None]
    if window is not None:
        m &= kpos[None, :] > qpos[:, None] - window
    return m


def _qchunk_attention(q, k, v, *, scale, window, cap, chunk, causal=True):
    """Scan over q chunks; q seq dim unsharded ("heads" strategy).

    The per-chunk fn is checkpointed so backward recomputes each chunk's
    probabilities instead of saving (Sq, Sk)-worth of residuals.
    """
    B, Sq, KV, g, dh = q.shape
    Sk = k.shape[1]
    chunk = min(chunk, Sq)
    if Sq % chunk:
        chunk = Sq
    nq = Sq // chunk
    kpos = jnp.arange(Sk)

    @jax.checkpoint
    def one(i):
        qs = jax.lax.dynamic_slice_in_dim(q, i * chunk, chunk, axis=1)
        s = jnp.einsum("bckgd,bskd->bkgcs", qs, k).astype(jnp.float32) * scale
        s = softcap(s, cap)
        qpos = i * chunk + jnp.arange(chunk)
        s = jnp.where(_mask(qpos, kpos, window, causal)[None, None, None],
                      s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1).astype(v.dtype)
        return jnp.einsum("bkgcs,bskd->bckgd", p, v)

    if nq == 1:
        return one(jnp.int32(0))
    _, ys = jax.lax.scan(lambda c, i: (c, one(i)), None, jnp.arange(nq))
    return jnp.moveaxis(ys, 0, 1).reshape(B, Sq, KV, g, dh)


def _full_attention(q, k, v, *, scale, window, cap, causal=True):
    """One scores block — used when q's seq dim is sharded (training)."""
    B, Sq, KV, g, dh = q.shape
    Sk = k.shape[1]
    s = jnp.einsum("bqkgd,bskd->bkgqs", q, k).astype(jnp.float32) * scale
    s = softcap(s, cap)
    m = _mask(jnp.arange(Sq), jnp.arange(Sk), window, causal)
    s = jnp.where(m[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1).astype(v.dtype)
    return jnp.einsum("bkgqs,bskd->bqkgd", p, v)


def _kchunk_flash(q, k, v, *, scale, window, cap, chunk, causal=True):
    """Online-softmax scan over k chunks (no-grad prefill, seq-sharded q)."""
    B, Sq, KV, g, dh = q.shape
    Sk = k.shape[1]
    chunk = min(chunk, Sk)
    if Sk % chunk:
        chunk = Sk
    nk = Sk // chunk
    kr = jnp.moveaxis(k.reshape(B, nk, chunk, KV, dh), 1, 0)
    vr = jnp.moveaxis(v.reshape(B, nk, chunk, KV, dh), 1, 0)
    qpos = jnp.arange(Sq)

    m0 = jnp.full((B, KV, g, Sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, KV, g, Sq), jnp.float32)
    a0 = jnp.zeros((B, KV, g, Sq, dh), jnp.float32)

    def step(carry, xs):
        m, l, acc = carry
        kc, vc, i = xs
        s = jnp.einsum("bqkgd,bskd->bkgqs", q, kc).astype(jnp.float32) * scale
        s = softcap(s, cap)
        kpos = i * chunk + jnp.arange(chunk)
        s = jnp.where(_mask(qpos, kpos, window, causal)[None, None, None],
                      s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        l_new = l * alpha + jnp.sum(p, axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bkgqs,bskd->bkgqd", p.astype(v.dtype), vc).astype(jnp.float32)
        return (m_new, l_new, acc_new), None

    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0),
                                  (kr, vr, jnp.arange(nk)))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return jnp.moveaxis(out, -2, 1).astype(q.dtype)   # (B,Sq,KV,g,dh)


def causal_attention(ctx: ModelCtx, q: jax.Array, k: jax.Array, v: jax.Array,
                     *, window: Optional[int] = None,
                     logit_softcap: Optional[float] = None,
                     strategy: str = "heads", mode: str = "train",
                     chunk: int = 512, causal: bool = True) -> jax.Array:
    """Chunked (optionally causal) GQA.
    q (B,Sq,H,dh); k,v (B,Sk,KV,dh) -> (B,Sq,H,dh).

    GQA sharding note: reshaping H -> (KV, g) makes BOTH factors too small to
    shard on a 16-way model axis when KV < 16 (gemma2/kimi/vlm: KV=8), which
    would force GSPMD to replicate attention.  When KV < tp we instead repeat
    K/V up to H heads (repeat is sharded, (B,S,H/tp,dh) per chip) and run
    plain MHA einsums sharded on H.
    """
    B, Sq, H, dh = q.shape
    KV = k.shape[2]
    tp = ctx.mesh.shape.get("model", 1) if ctx.mesh is not None else 1
    if strategy == "heads" and 1 < KV < tp and H % tp == 0:
        g = H // KV
        k = ctx.cons(jnp.repeat(k, g, axis=2),
                     ("batch", "seq", "heads", "head_dim"))
        v = ctx.cons(jnp.repeat(v, g, axis=2),
                     ("batch", "seq", "heads", "head_dim"))
        KV = H
    qr = q.reshape(B, Sq, KV, H // KV, dh)
    scale = dh ** -0.5
    if strategy == "seq":
        if mode == "train":
            out = _full_attention(qr, k, v, scale=scale, window=window,
                                  cap=logit_softcap, causal=causal)
        else:
            out = _kchunk_flash(qr, k, v, scale=scale, window=window,
                                cap=logit_softcap, chunk=max(chunk, 1024),
                                causal=causal)
        out = out.reshape(B, Sq, H, dh)
        return ctx.cons(out, ("batch", "act_seq_sharded", None, None))
    out = _qchunk_attention(qr, k, v, scale=scale, window=window,
                            cap=logit_softcap, chunk=chunk, causal=causal)
    out = out.reshape(B, Sq, H, dh)
    return ctx.cons(out, ("batch", "seq", "heads", "head_dim"))


def decode_attention(ctx: ModelCtx, q: jax.Array, k_cache: jax.Array,
                     v_cache: jax.Array, pos: jax.Array,
                     *, window: Optional[int] = None,
                     logit_softcap: Optional[float] = None,
                     causal: bool = True) -> jax.Array:
    """One-token attention vs a (possibly seq-sharded) KV cache — flash-decode
    via explicit partial reductions; see module docstring."""
    B, _, H, dh = q.shape
    S, KV = k_cache.shape[1], k_cache.shape[2]
    g = H // KV
    scale = dh ** -0.5
    qr = q.reshape(B, KV, g, dh)
    s = jnp.einsum("bkgd,bskd->bkgs", qr, k_cache).astype(jnp.float32) * scale
    s = softcap(s, logit_softcap)
    kpos = jnp.arange(S)
    pos_col = jnp.reshape(pos, (-1, 1))              # scalar or (B,) position
    mask = kpos[None] <= pos_col                     # (1|B, S) valid history
    if not causal:
        mask = jnp.ones_like(mask)
    if window is not None and causal:
        mask &= kpos[None] > pos_col - window
    s = jnp.where(mask[:, None, None], s, NEG_INF)
    m = jnp.max(s, axis=-1, keepdims=True)           # reduce over S -> AR(max)
    p = jnp.exp(s - jax.lax.stop_gradient(m))
    num = jnp.einsum("bkgs,bskd->bkgd", p.astype(q.dtype), v_cache)
    den = jnp.sum(p, axis=-1)                        # (B,KV,g) -> AR(sum)
    out = num.astype(jnp.float32) / den[..., None]
    return out.reshape(B, 1, H, dh).astype(q.dtype)


def attn_out(ctx: ModelCtx, p, attn: jax.Array) -> jax.Array:
    return jnp.einsum("bshk,hkd->bsd", attn, p["wo"].astype(ctx.compute_dtype))
