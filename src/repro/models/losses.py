"""Memory-aware LM loss.

Full logits are (B, S, V) — replicated f32 copies dominate training HBM
(gemma2: 256k vocab).  Two strategies:

  * ``sharded_cross_entropy`` (preferred when vocab AND seq divide the model
    axis): logits stay (B, S/tp, V/tp) — both dims sharded — and the
    softmax/gold reductions are tiny all-reduces.  No gather of logits, no
    scan machinery; peak is one small f32 block per chip.
  * ``chunked_cross_entropy`` (fallback for non-divisible vocabs, e.g.
    granite's 49155): scan over sequence chunks with per-chunk remat.

The chunked path can route its softmax/gold math through the fused Pallas
kernel (``kernels.xent.softmax_xent``: online-logsumexp over vocab tiles,
fused backward, never materializes the f32 softmax).  Gate: ``fused=None``
defaults to on for the TPU backend and off elsewhere; set
``REPRO_FUSED_XENT=1`` to force it on CPU, where it runs under Pallas
interpret mode (correct but slow — parity is pinned by tests/test_kernels.py
against kernels.ref.softmax_xent_ref).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.common import fused_xent_default, interpret_default


def sharded_cross_entropy(ctx, x, labels, head, *, softcap=None):
    """Mean token NLL with (seq x vocab)-sharded logits.

    x (B,S,D) hidden states; labels (B,S) int32; head (V,D).
    The gold logit is extracted with a one-hot einsum (elementwise + reduce
    partitions cleanly; a gather over a sharded vocab would not).
    """
    logits = jnp.einsum("bsd,vd->bsv", x, head)            # bf16 compute
    logits = ctx.cons(logits, ("batch", "act_seq_sharded", "act_vocab"))
    lf = logits.astype(jnp.float32)
    if softcap is not None:
        lf = softcap * jnp.tanh(lf / softcap)
    m = jnp.max(lf, axis=-1, keepdims=True)                # AR(max) over V
    lse = m[..., 0] + jnp.log(jnp.sum(jnp.exp(lf - m), axis=-1))
    # gather over the sharded vocab dim -> local masked take + tiny AR
    gold = jnp.take_along_axis(lf, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - gold)


def weighted_cross_entropy(x, labels, head, weights, *, denom=None,
                           softcap=None, chunk: int = 512,
                           fused: Optional[bool] = None):
    """Per-token-weighted NLL — the policy-gradient form of the LM loss.

    x (B,S,D) final hidden states; labels (B,S) int32; head (V,D);
    weights (B,S) f32 — each token's NLL is scaled by its weight before
    the reduction.  RL callers (repro.rl) fold ``mask * advantage`` into
    ``weights``: REINFORCE's surrogate sum_t A_t * -log pi(a_t|s_<t) IS
    advantage-weighted cross entropy, so the same chunked scan (and the
    same fused Pallas softmax-xent kernel, which already returns
    per-token NLL) serves both supervised and RL training.

    ``denom`` normalizes the weighted sum (default: token count B*S;
    RL passes the action-token count sum(mask)).  Zero weights make a
    token's contribution — and its gradient — exactly zero, so padding
    and prompt positions never train.
    """
    B, S, D = x.shape
    if fused is None:
        fused = fused_xent_default()

    def fn(xc, lc, wc):
        logits = jnp.einsum("bcd,vd->bcv", xc, head).astype(jnp.float32)
        if fused:
            from repro.kernels.xent import softmax_xent
            V = head.shape[0]
            nll = softmax_xent(logits.reshape(-1, V), lc.reshape(-1),
                               softcap=softcap,
                               interpret=interpret_default())
            return jnp.sum(nll * wc.reshape(-1))
        if softcap is not None:
            logits = softcap * jnp.tanh(logits / softcap)
        m = jnp.max(logits, axis=-1)
        lse = m + jnp.log(jnp.sum(jnp.exp(logits - m[..., None]), axis=-1))
        gold = jnp.take_along_axis(logits, lc[..., None], axis=-1)[..., 0]
        return jnp.sum((lse - gold) * wc)

    fn = jax.checkpoint(fn, prevent_cse=False)
    weights = weights.astype(jnp.float32)
    chunk = min(chunk, S)
    if S % chunk:
        chunk = S
    n = S // chunk
    if n == 1:
        total = fn(x, labels, weights)
    else:
        xr = x.reshape(B, n, chunk, D).swapaxes(0, 1)          # (n,B,c,D)
        lr = labels.reshape(B, n, chunk).swapaxes(0, 1)        # (n,B,c)
        wr = weights.reshape(B, n, chunk).swapaxes(0, 1)       # (n,B,c)
        total, _ = jax.lax.scan(
            lambda acc, xs: (acc + fn(*xs), None), 0.0, (xr, lr, wr))
    if denom is None:
        denom = jnp.float32(B * S)
    return total / denom


def _chunk_nll(x_chunk, labels_chunk, head):
    """x (B,c,D) @ head (V,D) -> mean-able NLL terms for one chunk (f32)."""
    logits = jnp.einsum("bcd,vd->bcv", x_chunk, head).astype(jnp.float32)
    m = jnp.max(logits, axis=-1)
    lse = m + jnp.log(jnp.sum(jnp.exp(logits - m[..., None]), axis=-1))
    gold = jnp.take_along_axis(logits, labels_chunk[..., None], axis=-1)[..., 0]
    return jnp.sum(lse - gold)


def chunked_cross_entropy(x, labels, head, *, softcap=None, chunk: int = 512,
                          fused: Optional[bool] = None):
    """Mean token NLL from final hidden states, seq-chunked.

    x (B,S,D) final hidden states; labels (B,S) int32; head (V,D).
    softcap: final-logit softcap (gemma2) — folded into the chunk fn.
    fused: route the per-chunk softmax/gold math through the fused Pallas
    kernel (None = backend default, see module docstring).
    """
    B, S, D = x.shape
    if fused is None:
        fused = fused_xent_default()

    def fn(xc, lc):
        if fused:
            from repro.kernels.xent import softmax_xent
            V = head.shape[0]
            logits = jnp.einsum("bcd,vd->bcv", xc,
                                head).astype(jnp.float32)
            nll = softmax_xent(logits.reshape(-1, V), lc.reshape(-1),
                               softcap=softcap,
                               interpret=interpret_default())
            return jnp.sum(nll)
        logits = jnp.einsum("bcd,vd->bcv", xc, head).astype(jnp.float32)
        if softcap is not None:
            logits = softcap * jnp.tanh(logits / softcap)
        m = jnp.max(logits, axis=-1)
        lse = m + jnp.log(jnp.sum(jnp.exp(logits - m[..., None]), axis=-1))
        gold = jnp.take_along_axis(logits, lc[..., None], axis=-1)[..., 0]
        return jnp.sum(lse - gold)

    fn = jax.checkpoint(fn, prevent_cse=False)
    chunk = min(chunk, S)
    if S % chunk:
        chunk = S
    n = S // chunk
    if n == 1:
        total = fn(x, labels)
    else:
        xr = x.reshape(B, n, chunk, D).swapaxes(0, 1)          # (n,B,c,D)
        lr = labels.reshape(B, n, chunk).swapaxes(0, 1)        # (n,B,c)
        total, _ = jax.lax.scan(
            lambda acc, xs: (acc + fn(xs[0], xs[1]), None), 0.0, (xr, lr))
    return total / (B * S)
