"""Single-source-of-truth parameter schemas.

A model's parameters are described once as a nested dict of ``PSpec``
(shape + logical axes + init).  From the schema we derive, consistently:
  * materialized params            (``init_params``)
  * abstract params for dry-runs   (``abstract_params`` — no allocation)
  * logical-axis tree              (``axes_tree``)
  * NamedSharding tree             (repro.sharding.specs.shardings_for)
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class PSpec:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]   # logical axis names, len == len(shape)
    init: str = "normal"              # normal | zeros | ones | scaled
    scale: Optional[float] = None     # stddev override for "normal"/"scaled"
    dtype: Optional[str] = None       # override model param dtype

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def is_pspec(x) -> bool:
    return isinstance(x, PSpec)


def _leaves(schema) -> list[tuple[str, PSpec]]:
    out: list[tuple[str, PSpec]] = []

    def rec(node, path):
        if is_pspec(node):
            out.append((path, node))
            return
        for k in sorted(node.keys()):
            rec(node[k], f"{path}/{k}" if path else k)

    rec(schema, "")
    return out


def tree_map_schema(fn, schema):
    """Map fn(path, PSpec) over a schema, preserving structure."""
    def rec(node, path):
        if is_pspec(node):
            return fn(path, node)
        return {k: rec(v, f"{path}/{k}" if path else k) for k, v in node.items()}
    return rec(schema, "")


def _init_one(path: str, p: PSpec, key, dtype) -> jax.Array:
    dt = jnp.dtype(p.dtype or dtype)
    if p.init == "zeros":
        return jnp.zeros(p.shape, dt)
    if p.init == "ones":
        return jnp.ones(p.shape, dt)
    fan_in = p.shape[-2] if len(p.shape) >= 2 else p.shape[-1]
    std = p.scale if p.scale is not None else 1.0 / np.sqrt(max(fan_in, 1))
    return (jax.random.normal(key, p.shape, jnp.float32) * std).astype(dt)


def init_params(schema, key, dtype: str):
    leaves = _leaves(schema)
    keys = jax.random.split(key, max(len(leaves), 1))
    key_of = {path: keys[i] for i, (path, _) in enumerate(leaves)}
    return tree_map_schema(lambda path, p: _init_one(path, p, key_of[path], dtype), schema)


def abstract_params(schema, dtype: str):
    return tree_map_schema(
        lambda _p, p: jax.ShapeDtypeStruct(p.shape, jnp.dtype(p.dtype or dtype)),
        schema)


def axes_tree(schema):
    return tree_map_schema(lambda _p, p: p.axes, schema)


def param_count(schema) -> int:
    return int(sum(int(np.prod(p.shape)) for _, p in _leaves(schema)))


def param_bytes(schema, dtype: str) -> int:
    return int(sum(int(np.prod(p.shape)) * jnp.dtype(p.dtype or dtype).itemsize
                   for _, p in _leaves(schema)))
