"""rwkv6-1.6b (Finch) [ssm] — attention-free, data-dependent decay
[arXiv:2404.05892; unverified]."""
from repro.configs.base import AttnConfig, ModelConfig, RWKVConfig

CONFIG = ModelConfig(
    name="rwkv6-1.6b", family="ssm",
    num_layers=24, d_model=2048, num_heads=32, num_kv_heads=32,
    d_ff=7168, vocab_size=65_536, head_dim=64,
    block_pattern=("rwkv",),
    attn=AttnConfig(use_rope=False),
    rwkv=RWKVConfig(head_dim=64, chunk=64),
    tie_embeddings=True,
)

# §Perf note: sequence_parallel=False was tried for the recurrent
# archs (seq cannot shard) and REFUTED — collectives worsened (rwkv 10x:
# full-seq replicated residuals make backward dgrad ARs full-size) and
# memory grew (full-seq residual checkpoints).  See EXPERIMENTS §Perf.

# §Perf (beyond-paper, CONFIRMED): pure-FSDP training layout — measured
# zamba2: collectives 224 -> 16.6 GB/chip raw (5.5 bf16-adj), temp 21 ->
# 8.2 GiB; rwkv6: 93 -> 8.7 GB raw, temp 5.5 -> 1.9 GiB.  The recurrent
# blocks cannot shard seq, so removing inner-dim TP removes their
# partial-sum ARs entirely; batch covers the full mesh instead.
from repro.configs.base import ParallelConfig  # noqa: E402

PARALLEL = ParallelConfig(pure_fsdp_train=True)
