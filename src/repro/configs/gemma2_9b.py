"""gemma2-9b [dense] — local+global alternating, logit softcaps
[arXiv:2408.00118; hf]."""
from repro.configs.base import AttnConfig, ModelConfig

CONFIG = ModelConfig(
    name="gemma2-9b", family="dense",
    num_layers=42, d_model=3584, num_heads=16, num_kv_heads=8,
    d_ff=14_336, vocab_size=256_000, head_dim=256,
    block_pattern=("local", "global"),
    attn=AttnConfig(rope_theta=10_000.0, window=4096, logit_softcap=50.0),
    post_norm=True, embed_scale=True,
    final_logit_softcap=30.0,
    tie_embeddings=True,
)

# §Perf (beyond-paper): pure-FSDP training layout — batch over all 256
# chips, ZeRO-3 weights over (data, model), no TP.  Measured on codeqwen
# train_4k: collective bytes 150 -> 11.3 GB/chip (bf16-adj), temp 11.6 ->
# 7.2 GiB, roofline fraction 0.18 -> ~0.69.  Serving shapes keep the
# hybrid FSDP x TP layout (KV cache wants the model axis).
from repro.configs.base import ParallelConfig  # noqa: E402

PARALLEL = ParallelConfig(pure_fsdp_train=True)
