"""whisper-small [audio] — enc-dec transformer backbone; the conv/mel
frontend is a STUB: input_specs() provides precomputed frame embeddings
(B, T_enc, d_model) [arXiv:2212.04356; unverified]."""
from repro.configs.base import AttnConfig, ModelConfig

CONFIG = ModelConfig(
    name="whisper-small", family="audio",
    num_layers=12, d_model=768, num_heads=12, num_kv_heads=12,
    d_ff=3072, vocab_size=51_865, head_dim=64,
    block_pattern=("attn",),       # decoder pattern; encoder built separately
    attn=AttnConfig(use_rope=False),
    encoder_layers=12, decoder_len=448,
    tie_embeddings=True,
)
