"""zamba2-2.7b [hybrid] — Mamba2 backbone + shared attention blocks
[arXiv:2411.15242; hf].  Shared-attn weights are stored once (not scanned);
every 6th layer applies mamba + the shared attention block."""
from repro.configs.base import AttnConfig, ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b", family="hybrid",
    num_layers=54, d_model=2560, num_heads=32, num_kv_heads=32,
    d_ff=10_240, vocab_size=32_000, head_dim=80,
    block_pattern=("mamba", "mamba", "mamba", "mamba", "mamba", "mamba_attn"),
    attn=AttnConfig(rope_theta=10_000.0),
    ssm=SSMConfig(state_dim=64, head_dim=64, expand=2, chunk=256),
    tie_embeddings=True,
)

# §Perf note: sequence_parallel=False was tried for the recurrent
# archs (seq cannot shard) and REFUTED — collectives worsened (rwkv 10x:
# full-seq replicated residuals make backward dgrad ARs full-size) and
# memory grew (full-seq residual checkpoints).  See EXPERIMENTS §Perf.

# §Perf (beyond-paper, CONFIRMED): pure-FSDP training layout — measured
# zamba2: collectives 224 -> 16.6 GB/chip raw (5.5 bf16-adj), temp 21 ->
# 8.2 GiB; rwkv6: 93 -> 8.7 GB raw, temp 5.5 -> 1.9 GiB.  The recurrent
# blocks cannot shard seq, so removing inner-dim TP removes their
# partial-sum ARs entirely; batch covers the full mesh instead.
from repro.configs.base import ParallelConfig  # noqa: E402

PARALLEL = ParallelConfig(pure_fsdp_train=True)
