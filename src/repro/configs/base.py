"""Config system: model / parallelism / training recipe dataclasses.

Every assigned architecture is a ``ModelConfig`` in ``repro.configs.<id>``;
``repro.configs.registry`` maps ``--arch <id>`` to it.  Shapes (the four
assigned input shapes) are ``ShapeConfig``s shared across archs.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Optional, Tuple


@dataclass(frozen=True)
class AttnConfig:
    """Self-attention variant knobs."""
    rope_theta: float = 10_000.0
    window: Optional[int] = None          # sliding-window size (gemma2 local)
    logit_softcap: Optional[float] = None  # attn-score softcap (gemma2: 50.0)
    qkv_bias: bool = False                 # qwen-family bias on q/k/v
    use_rope: bool = True                  # whisper uses learned/sinusoidal


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int = 8
    top_k: int = 2
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    # tokens are routed with an all_to_all over this logical axis
    expert_axis: str = "expert"


@dataclass(frozen=True)
class SSMConfig:
    """Mamba2 (zamba2) / linear-recurrence knobs."""
    state_dim: int = 64
    head_dim: int = 64
    expand: int = 2
    chunk: int = 256          # chunked-scan block length
    conv_kernel: int = 4


@dataclass(frozen=True)
class RWKVConfig:
    head_dim: int = 64
    chunk: int = 256


@dataclass(frozen=True)
class ModelConfig:
    name: str = "model"
    family: str = "dense"     # dense | moe | hybrid | audio | ssm | vlm
    num_layers: int = 2
    d_model: int = 128
    num_heads: int = 2
    num_kv_heads: int = 2
    d_ff: int = 256
    vocab_size: int = 256
    head_dim: int = 0         # 0 -> d_model // num_heads
    # One scanned "layer group" applies this pattern of block kinds in order.
    # num_layers must equal len(block_pattern) * num_groups.
    # kinds: attn | local | global | moe | mamba | mamba_attn | rwkv | cross
    block_pattern: Tuple[str, ...] = ("attn",)
    attn: AttnConfig = field(default_factory=AttnConfig)
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    rwkv: Optional[RWKVConfig] = None
    norm_eps: float = 1e-6
    post_norm: bool = False                 # gemma2 sandwich norms
    embed_scale: bool = False               # gemma2 sqrt(d_model) embed scaling
    final_logit_softcap: Optional[float] = None
    tie_embeddings: bool = True
    # --- audio (whisper): encoder-decoder ---
    encoder_layers: int = 0
    decoder_len: int = 448                  # whisper text positions
    encoder_frames: int = 0                 # 0 -> use shape.seq_len at build time
    # --- vlm: stubbed modality frontend ---
    vision_dim: int = 0                     # patch-embedding dim (stub input)
    num_patches: int = 0
    # dtypes
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // self.num_heads)

    @property
    def num_groups(self) -> int:
        assert self.num_layers % len(self.block_pattern) == 0, (
            self.name, self.num_layers, self.block_pattern)
        return self.num_layers // len(self.block_pattern)

    @property
    def q_per_kv(self) -> int:
        assert self.num_heads % max(self.num_kv_heads, 1) == 0
        return self.num_heads // max(self.num_kv_heads, 1)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class ParallelConfig:
    """How a step function is laid out on the mesh.

    Mesh axes are ("pod",) "data", "model".  Logical->mesh rules live in
    repro.sharding.specs; these knobs gate which rules are active.
    """
    fsdp: bool = True                 # ZeRO-3 weight sharding on "data"
    tensor_parallel: bool = True      # heads/ffn/vocab on "model"
    # pure-FSDP layout (beyond-paper §Perf): batch shards over data AND
    # model axes (1 seq/chip at B=256 on one pod), weights ZeRO-3 over both
    # — no TP, so NO activation gathers; only weight AG + grad RS traffic.
    # Wins for <=10B dense models where tokens/chip * D >= layer weights.
    # Measured (codeqwen train_4k): collectives 150 -> 11.3 GB/chip
    # (bf16-adj), temp 11.6 -> 7.2 GiB.
    pure_fsdp: bool = False
    # apply pure_fsdp to TRAIN steps when global_batch % mesh size == 0
    # (decode/prefill keep the hybrid layout: their batch can't cover
    # the full mesh and the KV cache wants the model axis)
    pure_fsdp_train: bool = False
    expert_parallel: bool = True      # MoE experts on "model"
    sequence_parallel: bool = True    # residual/checkpoint seq on "model"
    context_parallel_decode: bool = True   # KV cache seq on "model" + partial softmax
    remat: bool = True
    remat_period: int = 1             # checkpoint every N layer-groups
    # save the TP-gathered activations instead of re-gathering them in the
    # backward pass (trades (B,S,D)/layer HBM for 4 AGs/layer of traffic)
    remat_save_gathered: bool = False
    scan_layers: bool = True
    hierarchical_allreduce: bool = True    # in-pod RS -> cross-pod AR -> in-pod AG
    grad_compression: Optional[str] = None  # None | "int8"
    moe_microbatch: int = 1           # split tokens in MoE layer to bound a2a buffers


@dataclass(frozen=True)
class OptimizerConfig:
    name: str = "adamw"               # adamw | adafactor | sgd
    lr: float = 3e-4
    warmup_steps: int = 100
    decay_steps: int = 10_000
    schedule: str = "cosine"          # cosine | linear | constant
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    # memory recipe (1T-scale models need sub-fp32 state; see DESIGN.md)
    moment_dtype: str = "float32"     # float32 | bfloat16 | int8
    second_moment: str = "full"       # full | factored  (factored = adafactor-style)
    accum_steps: int = 1


@dataclass(frozen=True)
class TrainConfig:
    model: ModelConfig = field(default_factory=ModelConfig)
    parallel: ParallelConfig = field(default_factory=ParallelConfig)
    optimizer: OptimizerConfig = field(default_factory=OptimizerConfig)
    seq_len: int = 128
    global_batch: int = 4
    steps: int = 10
    seed: int = 0
    log_every: int = 1
    checkpoint_every: int = 0         # 0 = off
    checkpoint_dir: str = ""
    keep_checkpoints: int = 3


@dataclass(frozen=True)
class ShapeConfig:
    """One assigned (input-shape) cell."""
    name: str
    seq_len: int
    global_batch: int
    kind: str                 # train | prefill | decode

    def __str__(self) -> str:
        return f"{self.name}(S={self.seq_len},B={self.global_batch},{self.kind})"


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}

# archs allowed to lower long_500k (sub-quadratic sequence mixing).  All other
# archs are pure full-attention: skipped per spec, noted in DESIGN.md §4.
LONG_CONTEXT_ARCHS = ("zamba2-2.7b", "rwkv6-1.6b")


def smoke_config(cfg: ModelConfig) -> ModelConfig:
    """A tiny same-family config for CPU smoke tests (1 fwd/train step)."""
    kw: dict[str, Any] = dict(
        num_layers=len(cfg.block_pattern),
        d_model=64,
        num_heads=4,
        num_kv_heads=min(cfg.num_kv_heads, 2) if cfg.num_kv_heads else 0,
        d_ff=128,
        vocab_size=512,
        head_dim=16,
    )
    if cfg.moe is not None:
        kw["moe"] = dataclasses.replace(cfg.moe, num_experts=4, top_k=2)
    if cfg.ssm is not None:
        kw["ssm"] = dataclasses.replace(cfg.ssm, state_dim=16, head_dim=16, chunk=8)
    if cfg.rwkv is not None:
        kw["rwkv"] = dataclasses.replace(cfg.rwkv, head_dim=16, chunk=8)
    if cfg.encoder_layers:
        kw["encoder_layers"] = 2
        kw["decoder_len"] = 16
    if cfg.vision_dim:
        kw["vision_dim"] = 32
        kw["num_patches"] = 8
    return cfg.replace(**kw)
