"""``--arch <id>`` registry: the 10 assigned architectures + the paper's own
FFN/CONNECT case-study model.  Each module exports CONFIG (ModelConfig) and
optionally OPTIMIZER / PARALLEL overrides (1T-scale memory recipes)."""
from __future__ import annotations

import importlib
from typing import Optional, Tuple

from repro.configs.base import (ModelConfig, OptimizerConfig, ParallelConfig,
                                SHAPES, LONG_CONTEXT_ARCHS, ShapeConfig,
                                smoke_config)

ARCHS: Tuple[str, ...] = (
    "phi4-mini-3.8b",
    "codeqwen1.5-7b",
    "deepseek-7b",
    "gemma2-9b",
    "granite-moe-1b-a400m",
    "kimi-k2-1t-a32b",
    "zamba2-2.7b",
    "whisper-small",
    "rwkv6-1.6b",
    "llama-3.2-vision-90b",
)

_MODULES = {
    "phi4-mini-3.8b": "phi4_mini_3_8b",
    "codeqwen1.5-7b": "codeqwen1_5_7b",
    "deepseek-7b": "deepseek_7b",
    "gemma2-9b": "gemma2_9b",
    "granite-moe-1b-a400m": "granite_moe_1b_a400m",
    "kimi-k2-1t-a32b": "kimi_k2_1t_a32b",
    "zamba2-2.7b": "zamba2_2_7b",
    "whisper-small": "whisper_small",
    "rwkv6-1.6b": "rwkv6_1_6b",
    "llama-3.2-vision-90b": "llama_3_2_vision_90b",
    "ffn-connect": "ffn_connect",
}


def _module(arch: str):
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_MODULES)}")
    return importlib.import_module(f"repro.configs.{_MODULES[arch]}")


def get_config(arch: str) -> ModelConfig:
    return _module(arch).CONFIG


def get_optimizer(arch: str) -> OptimizerConfig:
    return getattr(_module(arch), "OPTIMIZER", OptimizerConfig())


def get_parallel(arch: str) -> ParallelConfig:
    return getattr(_module(arch), "PARALLEL", ParallelConfig())


def get_smoke(arch: str) -> ModelConfig:
    return smoke_config(get_config(arch))


def cells(include_skipped: bool = False):
    """All assigned (arch, shape) dry-run cells, honoring the long_500k rule."""
    out = []
    for arch in ARCHS:
        for shape in SHAPES.values():
            skipped = (shape.name == "long_500k"
                       and arch not in LONG_CONTEXT_ARCHS)
            if skipped and not include_skipped:
                continue
            out.append((arch, shape, skipped))
    return out
