"""codeqwen1.5-7b [dense] — qwen1.5 arch (qkv bias) [hf:Qwen/CodeQwen1.5-7B]."""
from repro.configs.base import AttnConfig, ModelConfig

CONFIG = ModelConfig(
    name="codeqwen1.5-7b", family="dense",
    num_layers=32, d_model=4096, num_heads=32, num_kv_heads=32,
    d_ff=13_440, vocab_size=92_416, head_dim=128,
    block_pattern=("attn",),
    attn=AttnConfig(rope_theta=1_000_000.0, qkv_bias=True),
    tie_embeddings=False,
)

# §Perf (beyond-paper): pure-FSDP training layout — batch over all 256
# chips, ZeRO-3 weights over (data, model), no TP.  Measured on codeqwen
# train_4k: collective bytes 150 -> 11.3 GB/chip (bf16-adj), temp 11.6 ->
# 7.2 GiB, roofline fraction 0.18 -> ~0.69.  Serving shapes keep the
# hybrid FSDP x TP layout (KV cache wants the model axis).
from repro.configs.base import ParallelConfig  # noqa: E402

PARALLEL = ParallelConfig(pure_fsdp_train=True)
