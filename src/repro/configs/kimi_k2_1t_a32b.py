"""kimi-k2-1t-a32b [moe] — trillion-param MoE, 384 experts top-8
(paper-table config) [arXiv:2501.kimi2; unverified].

1T of parameters forces the memory recipe (DESIGN.md §5 / EXPERIMENTS §Perf):
int8 blockwise first moment + factored second moment, FSDP+EP sharding.
"""
from repro.configs.base import (AttnConfig, ModelConfig, MoEConfig,
                                OptimizerConfig, ParallelConfig)

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b", family="moe",
    num_layers=61, d_model=7168, num_heads=64, num_kv_heads=8,
    d_ff=2048, vocab_size=163_840, head_dim=112,
    block_pattern=("moe",),
    attn=AttnConfig(rope_theta=50_000.0),
    moe=MoEConfig(num_experts=384, top_k=8, capacity_factor=1.25),
    tie_embeddings=True,
)

OPTIMIZER = OptimizerConfig(moment_dtype="int8", second_moment="factored")
PARALLEL = ParallelConfig(remat_period=1, moe_microbatch=4)
