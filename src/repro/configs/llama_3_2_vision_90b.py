"""llama-3.2-vision-90b [vlm] — text backbone with gated cross-attn image
layers every 5th layer; the vision tower is a STUB (input_specs() provides
precomputed patch embeddings) [hf:meta-llama/Llama-3.2-11B-Vision; unverified]."""
from repro.configs.base import AttnConfig, ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-90b", family="vlm",
    num_layers=100, d_model=8192, num_heads=64, num_kv_heads=8,
    d_ff=28_672, vocab_size=128_256, head_dim=128,
    block_pattern=("attn", "attn", "attn", "attn", "cross"),
    attn=AttnConfig(rope_theta=500_000.0),
    vision_dim=1280, num_patches=1600,
    tie_embeddings=False,
)
