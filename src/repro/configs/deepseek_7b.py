"""deepseek-7b [dense] — llama-arch [arXiv:2401.02954; hf]."""
from repro.configs.base import AttnConfig, ModelConfig

CONFIG = ModelConfig(
    name="deepseek-7b", family="dense",
    num_layers=30, d_model=4096, num_heads=32, num_kv_heads=32,
    d_ff=11_008, vocab_size=102_400, head_dim=128,
    block_pattern=("attn",),
    attn=AttnConfig(rope_theta=10_000.0),
    tie_embeddings=False,
)

# §Perf (beyond-paper): pure-FSDP training layout — batch over all 256
# chips, ZeRO-3 weights over (data, model), no TP.  Measured on codeqwen
# train_4k: collective bytes 150 -> 11.3 GB/chip (bf16-adj), temp 11.6 ->
# 7.2 GiB, roofline fraction 0.18 -> ~0.69.  Serving shapes keep the
# hybrid FSDP x TP layout (KV cache wants the model axis).
from repro.configs.base import ParallelConfig  # noqa: E402

PARALLEL = ParallelConfig(pure_fsdp_train=True)
