"""Pallas TPU chunked WKV6 kernel (RWKV6 data-dependent decay).

TARGET: TPU v5e.  Grid = (batch*heads, num_chunks), chunk axis sequential;
the (hd, hd) WKV state is VMEM scratch carried across chunks.  Per-channel
pairwise decays are computed exactly as in models.ssm._wkv_chunked (log-
space differences inside the exp).  Chunk defaults to 64 — the (c, c, hd)
pairwise tensor must fit VMEM: 64*64*64*4B = 1 MiB.

Validated via interpret=True against kernels.ref.wkv6_ref.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _wkv_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, y_ref, s_scr, *,
                chunk: int):
    cb = pl.program_id(1)

    @pl.when(cb == 0)
    def _init():
        s_scr[...] = jnp.zeros_like(s_scr)

    r = r_ref[0].astype(jnp.float32)          # (c, hd)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    logw = w_ref[0].astype(jnp.float32)       # (c, hd) < 0
    u = u_ref[0].astype(jnp.float32)          # (1?, hd) -> (hd,)
    u = u.reshape(-1)

    cum = jnp.cumsum(logw, axis=0)            # (c, hd)
    # y_t reads S_{t-1}: the k_s v_s (s<t) term decays by w_{s+1..t-1},
    # i.e. exp(cum[t] - logw[t] - cum[s]) — note the one-step shift.
    cum_prev = cum - logw                     # cum[t-1] (0 for t=0)
    delta = cum_prev[:, None, :] - cum[None, :, :]         # (t,s,hd)
    t_idx = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    s_idx = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    strictly_lower = (s_idx < t_idx)[:, :, None]
    att = jnp.sum(r[:, None, :] * k[None, :, :] *
                  jnp.where(strictly_lower, jnp.exp(delta), 0.0), axis=-1)
    y = jax.lax.dot_general(att, v, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    # current-token bonus
    y = y + jnp.sum(r * u[None, :] * k, axis=-1, keepdims=True) * v
    # carried state: y[t] += (r[t] * exp(cum[t-1])) @ S
    s = s_scr[...]                            # (hd, hd)
    y = y + jax.lax.dot_general(r * jnp.exp(cum_prev), s,
                                (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
    # state update: S' = exp(cum[-1]) * S + sum_s (k_s exp(cum[-1]-cum[s]))^T v_s
    dec_end = jnp.exp(cum[-1:] - cum)         # (s, hd)
    s_new = jnp.exp(cum[-1])[:, None] * s + jax.lax.dot_general(
        k * dec_end, v, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    s_scr[...] = s_new
    y_ref[0] = y.astype(y_ref.dtype)


def wkv6(r, k, v, logw, u, *, chunk: int = 64, interpret: bool = False):
    """Chunked WKV6.  r/k/v/logw (B,S,H,hd); u (H,hd) -> y (B,S,H,hd) f32."""
    B, S, H, hd = r.shape
    chunk = min(chunk, S)
    assert S % chunk == 0, (S, chunk)
    nc = S // chunk

    def flat(t):
        return jnp.moveaxis(t, 2, 1).reshape(B * H, S, hd)

    ur = jnp.broadcast_to(u[None], (B, H, hd)).reshape(B * H, 1, hd)
    kernel = functools.partial(_wkv_kernel, chunk=chunk)
    out = pl.pallas_call(
        kernel,
        grid=(B * H, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, hd), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, hd), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, hd), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, hd), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, 1, hd), lambda b, c: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, hd), lambda b, c: (b, c, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, S, hd), jnp.float32),
        scratch_shapes=[pltpu.VMEM((hd, hd), jnp.float32)],
        interpret=interpret,
    )(flat(r), flat(k), flat(v), flat(logw), ur)
    return jnp.moveaxis(out.reshape(B, H, S, hd), 1, 2)
