"""Pallas TPU fused softmax cross-entropy (per-row NLL over a tiled vocab).

TARGET: TPU v5e VPU/VMEM.  Grid = (num_row_blocks, num_vocab_blocks) with
the vocab axis innermost ("arbitrary"), so the online-logsumexp running
statistics (m, l) and the gold-logit accumulator live in VMEM scratch
across vocab tiles and each (row, vocab) tile of the logits is streamed
through VMEM exactly once — the full (rows, V) f32 softmax is never
materialized.  The backward pass is a second Pallas kernel with no
cross-tile state (softmax recomputed per tile from the saved lse), wired
up via ``jax.custom_vjp`` so the fused loss is trainable.

Accumulation is f32 regardless of logits dtype (bf16 logits are upcast
per tile).  ``softcap`` (gemma2 final-logit cap) is folded into both
kernels, including its ``1 - tanh^2`` chain-rule factor in the backward.

Validated on CPU via interpret=True against kernels.ref.softmax_xent_ref
(tests/test_kernels.py sweeps shapes/dtypes/softcap, values and grads).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import NEG_INF


def _capped(s, softcap: Optional[float]):
    return s if softcap is None else softcap * jnp.tanh(s / softcap)


def _xent_fwd_kernel(logits_ref, labels_ref, nll_ref, lse_ref,
                     m_scr, l_scr, g_scr, *, softcap: Optional[float],
                     block_r: int, block_v: int, num_vb: int, true_v: int):
    vb = pl.program_id(1)

    @pl.when(vb == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        g_scr[...] = jnp.zeros_like(g_scr)

    s = _capped(logits_ref[...].astype(jnp.float32), softcap)
    cols = vb * block_v + jax.lax.broadcasted_iota(jnp.int32,
                                                   (block_r, block_v), 1)
    s = jnp.where(cols < true_v, s, NEG_INF)      # mask vocab padding
    lab = labels_ref[...]                          # (block_r,) int32
    g_scr[...] += jnp.sum(jnp.where(cols == lab[:, None], s, 0.0), axis=1)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    alpha = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * alpha + jnp.sum(jnp.exp(s - m_new[:, None]),
                                              axis=1)
    m_scr[...] = m_new

    @pl.when(vb == num_vb - 1)
    def _finalize():
        lse = m_scr[...] + jnp.log(jnp.maximum(l_scr[...], 1e-30))
        lse_ref[...] = lse
        nll_ref[...] = lse - g_scr[...]


def _xent_bwd_kernel(logits_ref, labels_ref, lse_ref, dy_ref, dlogits_ref, *,
                     softcap: Optional[float], block_r: int, block_v: int,
                     true_v: int):
    vb = pl.program_id(1)
    s = logits_ref[...].astype(jnp.float32)
    if softcap is None:
        sc, dsc = s, 1.0
    else:
        t = jnp.tanh(s / softcap)
        sc, dsc = softcap * t, 1.0 - t * t
    cols = vb * block_v + jax.lax.broadcasted_iota(jnp.int32,
                                                   (block_r, block_v), 1)
    sc = jnp.where(cols < true_v, sc, NEG_INF)
    p = jnp.exp(sc - lse_ref[...][:, None])
    onehot = (cols == labels_ref[...][:, None]).astype(jnp.float32)
    d = dy_ref[...][:, None] * (p - onehot) * dsc
    d = jnp.where(cols < true_v, d, 0.0)
    dlogits_ref[...] = d.astype(dlogits_ref.dtype)


def _pad_rows(x, rp, fill=0):
    return x if x.shape[0] == rp else \
        jnp.pad(x, [(0, rp - x.shape[0])] + [(0, 0)] * (x.ndim - 1),
                constant_values=fill)


def _fwd_call(logits, labels, softcap, block_r, block_v, interpret):
    R, V = logits.shape
    rp = -(-R // block_r) * block_r
    vp = -(-V // block_v) * block_v
    lg = _pad_rows(logits, rp)
    if vp != V:
        lg = jnp.pad(lg, ((0, 0), (0, vp - V)), constant_values=NEG_INF)
    lab = _pad_rows(labels, rp)
    nvb = vp // block_v
    kernel = functools.partial(
        _xent_fwd_kernel, softcap=softcap, block_r=block_r, block_v=block_v,
        num_vb=nvb, true_v=V)
    nll, lse = pl.pallas_call(
        kernel,
        grid=(rp // block_r, nvb),
        in_specs=[
            pl.BlockSpec((block_r, block_v), lambda i, j: (i, j)),
            pl.BlockSpec((block_r,), lambda i, j: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((block_r,), lambda i, j: (i,)),
            pl.BlockSpec((block_r,), lambda i, j: (i,)),
        ],
        out_shape=[jax.ShapeDtypeStruct((rp,), jnp.float32),
                   jax.ShapeDtypeStruct((rp,), jnp.float32)],
        scratch_shapes=[
            pltpu.VMEM((block_r,), jnp.float32),      # running max m
            pltpu.VMEM((block_r,), jnp.float32),      # running sum l
            pltpu.VMEM((block_r,), jnp.float32),      # gold-logit accum
        ],
        interpret=interpret,
    )(lg, lab)
    return nll[:R], lse[:R]


def _bwd_call(logits, labels, lse, dy, softcap, block_r, block_v, interpret):
    R, V = logits.shape
    rp = -(-R // block_r) * block_r
    vp = -(-V // block_v) * block_v
    lg = _pad_rows(logits, rp)
    if vp != V:
        lg = jnp.pad(lg, ((0, 0), (0, vp - V)), constant_values=NEG_INF)
    lab, lsep, dyp = (_pad_rows(labels, rp), _pad_rows(lse, rp),
                      _pad_rows(dy, rp))
    kernel = functools.partial(
        _xent_bwd_kernel, softcap=softcap, block_r=block_r, block_v=block_v,
        true_v=V)
    dlg = pl.pallas_call(
        kernel,
        grid=(rp // block_r, vp // block_v),
        in_specs=[
            pl.BlockSpec((block_r, block_v), lambda i, j: (i, j)),
            pl.BlockSpec((block_r,), lambda i, j: (i,)),
            pl.BlockSpec((block_r,), lambda i, j: (i,)),
            pl.BlockSpec((block_r,), lambda i, j: (i,)),
        ],
        out_specs=pl.BlockSpec((block_r, block_v), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((rp, vp), logits.dtype),
        interpret=interpret,
    )(lg, lab, lsep, dyp)
    return dlg[:R, :V]


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4, 5))
def _xent_core(logits, labels, softcap, block_r, block_v, interpret):
    nll, _ = _fwd_call(logits, labels, softcap, block_r, block_v, interpret)
    return nll


def _xent_core_fwd(logits, labels, softcap, block_r, block_v, interpret):
    nll, lse = _fwd_call(logits, labels, softcap, block_r, block_v,
                         interpret)
    return nll, (logits, labels, lse)


def _xent_core_bwd(softcap, block_r, block_v, interpret, res, dy):
    logits, labels, lse = res
    dlogits = _bwd_call(logits, labels, lse, dy.astype(jnp.float32),
                        softcap, block_r, block_v, interpret)
    # labels are integral: their cotangent is float0 (no gradient)
    return dlogits, np.zeros(labels.shape, jax.dtypes.float0)


_xent_core.defvjp(_xent_core_fwd, _xent_core_bwd)


def softmax_xent(logits: jax.Array, labels: jax.Array, *,
                 softcap: Optional[float] = None, block_r: int = 128,
                 block_v: int = 512, interpret: bool = False) -> jax.Array:
    """Per-row softmax cross-entropy: logits (R, V), labels (R,) int32
    -> NLL (R,) f32.  Differentiable w.r.t. ``logits`` (fused Pallas
    forward + backward); caller reduces (sum/mean) as needed."""
    R, V = logits.shape
    block_r = min(block_r, max(R, 1))
    block_v = min(block_v, max(V, 1))
    return _xent_core(logits, labels.astype(jnp.int32), softcap, block_r,
                      block_v, interpret)
