"""Pallas TPU chunked SSD scan (Mamba2 inner loop).

TARGET: TPU v5e.  Grid = (batch*heads, num_chunks) with the chunk axis
sequential ("arbitrary") so the (hd, N) SSM state lives in VMEM scratch and
carries across chunk steps — the inter-chunk recurrence never leaves VMEM.
Within a chunk the intra-chunk pairwise decay matrix is exact (same math as
models.ssm._ssd_chunked); chunk length defaults to 128 (lane-aligned).

Validated via interpret=True against kernels.ref.ssd_ref.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, h_scr, *,
                chunk: int):
    cb = pl.program_id(1)

    @pl.when(cb == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    x = x_ref[0].astype(jnp.float32)          # (c, hd)
    dt = dt_ref[0].astype(jnp.float32)        # (c,)
    a = a_ref[0, 0]                           # scalar decay rate (<0)
    bm = b_ref[0].astype(jnp.float32)         # (c, N)
    cm = c_ref[0].astype(jnp.float32)         # (c, N)

    da = dt * a                               # (c,) negative
    cum = jnp.cumsum(da)                      # within-chunk log decay

    # intra-chunk: y[t] = sum_{s<=t} (C_t.B_s) exp(cum[t]-cum[s]) dt_s x_s
    cb_mat = jax.lax.dot_general(cm, bm, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    delta = cum[:, None] - cum[None, :]
    t_idx = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    s_idx = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    L = jnp.where(s_idx <= t_idx, jnp.exp(delta), 0.0)
    w = cb_mat * L                            # (t, s)
    dx = dt[:, None] * x                      # (s, hd)
    y = jax.lax.dot_general(w, dx, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)

    # carried-state contribution: y[t] += C_t exp(cum[t]) @ h
    h = h_scr[...]                            # (N, hd)
    y = y + jnp.exp(cum)[:, None] * jax.lax.dot_general(
        cm, h, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    # chunk state update: h' = exp(cum[-1]) h + sum_s exp(cum[-1]-cum[s]) B_s dt_s x_s
    dec_end = jnp.exp(cum[-1] - cum)          # (s,)
    sB = bm * (dt * dec_end)[:, None]         # (s, N)
    h_new = jnp.exp(cum[-1]) * h + jax.lax.dot_general(
        sB, x, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    h_scr[...] = h_new
    y_ref[0] = y.astype(y_ref.dtype)


def ssd_scan(x, dt, a, B_, C, *, chunk: int = 128,
             interpret: bool = False):
    """Chunked SSD.  x (B,S,H,hd); dt (B,S,H); a (H,); B_/C (B,S,N).

    Returns y (B,S,H,hd) f32 (h_last is recomputed by callers that need it
    via the ref path; the kernel targets the training hot loop).
    """
    Bs, S, H, hd = x.shape
    N = B_.shape[-1]
    chunk = min(chunk, S)
    assert S % chunk == 0, (S, chunk)
    nc = S // chunk

    # (B,S,H,*) -> (B*H, S, *): each grid row owns one (batch, head) stream
    xr = jnp.moveaxis(x, 2, 1).reshape(Bs * H, S, hd)
    dtr = jnp.moveaxis(dt, 2, 1).reshape(Bs * H, S)
    ar = jnp.broadcast_to(a[None, :], (Bs, H)).reshape(Bs * H, 1)
    br = jnp.broadcast_to(B_[:, None], (Bs, H, S, N)).reshape(Bs * H, S, N)
    cr = jnp.broadcast_to(C[:, None], (Bs, H, S, N)).reshape(Bs * H, S, N)

    kernel = functools.partial(_ssd_kernel, chunk=chunk)
    out = pl.pallas_call(
        kernel,
        grid=(Bs * H, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, hd), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk), lambda b, c: (b, c)),
            pl.BlockSpec((1, 1), lambda b, c: (b, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, c: (b, c, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, hd), lambda b, c: (b, c, 0)),
        out_shape=jax.ShapeDtypeStruct((Bs * H, S, hd), jnp.float32),
        scratch_shapes=[pltpu.VMEM((N, hd), jnp.float32)],
        interpret=interpret,
    )(xr, dtr, ar, br, cr)
    return jnp.moveaxis(out.reshape(Bs, H, S, hd), 1, 2)
