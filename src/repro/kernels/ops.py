"""Jitted public wrappers for the Pallas kernels.

On this CPU container the wrappers run interpret=True (the kernel body
executes in Python, validating the BlockSpec/grid logic); on a TPU runtime
set ``REPRO_PALLAS_COMPILE=1`` (or pass interpret=False) to compile them.
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import flash_attention
from repro.kernels.moe_gmm import gmm
from repro.kernels.ssm_scan import ssd_scan
from repro.kernels.wkv6 import wkv6


def _interpret_default() -> bool:
    if os.environ.get("REPRO_PALLAS_COMPILE"):
        return False
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k"))
def flash_attention_op(q, k, v, *, causal: bool = True, block_q: int = 128,
                       block_k: int = 128):
    return flash_attention(q, k, v, causal=causal, block_q=block_q,
                           block_k=block_k, interpret=_interpret_default())


@functools.partial(jax.jit, static_argnames=("chunk",))
def ssd_scan_op(x, dt, a, B_, C, *, chunk: int = 128):
    return ssd_scan(x, dt, a, B_, C, chunk=chunk,
                    interpret=_interpret_default())


@functools.partial(jax.jit, static_argnames=("chunk",))
def wkv6_op(r, k, v, logw, u, *, chunk: int = 64):
    return wkv6(r, k, v, logw, u, chunk=chunk,
                interpret=_interpret_default())


@functools.partial(jax.jit, static_argnames=("block_c", "block_f", "block_d"))
def gmm_op(x, w, *, block_c: int = 128, block_f: int = 128,
           block_d: int = 128):
    return gmm(x, w, block_c=block_c, block_f=block_f, block_d=block_d,
               interpret=_interpret_default())
