"""Jitted public wrappers for the Pallas kernels.

On this CPU container the wrappers run interpret=True (the kernel body
executes in Python, validating the BlockSpec/grid logic); on a TPU runtime
set ``REPRO_PALLAS_COMPILE=1`` (or pass interpret=False) to compile them.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.adamw_update import adamw_update
from repro.kernels.common import interpret_default as _interpret_default
from repro.kernels.flash_attention import flash_attention
from repro.kernels.moe_gmm import gmm
from repro.kernels.ssm_scan import ssd_scan
from repro.kernels.wkv6 import wkv6
from repro.kernels.xent import softmax_xent


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k"))
def flash_attention_op(q, k, v, *, causal: bool = True, block_q: int = 128,
                       block_k: int = 128):
    return flash_attention(q, k, v, causal=causal, block_q=block_q,
                           block_k=block_k, interpret=_interpret_default())


@functools.partial(jax.jit, static_argnames=("chunk",))
def ssd_scan_op(x, dt, a, B_, C, *, chunk: int = 128):
    return ssd_scan(x, dt, a, B_, C, chunk=chunk,
                    interpret=_interpret_default())


@functools.partial(jax.jit, static_argnames=("chunk",))
def wkv6_op(r, k, v, logw, u, *, chunk: int = 64):
    return wkv6(r, k, v, logw, u, chunk=chunk,
                interpret=_interpret_default())


@functools.partial(jax.jit, static_argnames=("block_c", "block_f", "block_d"))
def gmm_op(x, w, *, block_c: int = 128, block_f: int = 128,
           block_d: int = 128):
    return gmm(x, w, block_c=block_c, block_f=block_f, block_d=block_d,
               interpret=_interpret_default())


@functools.partial(jax.jit, static_argnames=("softcap", "block_r", "block_v"))
def softmax_xent_op(logits, labels, *, softcap=None, block_r: int = 128,
                    block_v: int = 512):
    return softmax_xent(logits, labels, softcap=softcap, block_r=block_r,
                        block_v=block_v, interpret=_interpret_default())


@functools.partial(jax.jit,
                   static_argnames=("b1", "b2", "eps", "weight_decay",
                                    "block_rows"))
def adamw_update_op(p, g, m, v, lr, bc1, bc2, *, b1: float, b2: float,
                    eps: float, weight_decay: float = 0.0,
                    block_rows: int = 256):
    return adamw_update(p, g, m, v, lr, bc1, bc2, b1=b1, b2=b2, eps=eps,
                        weight_decay=weight_decay, block_rows=block_rows,
                        interpret=_interpret_default())
