"""Shared kernel-runtime knobs.

On this CPU container every Pallas wrapper defaults to interpret=True
(the kernel body runs in Python, validating BlockSpec/grid logic); on a
TPU runtime set ``REPRO_PALLAS_COMPILE=1`` (or pass interpret=False) to
compile.  Train-hot-loop kernels (fused xent / fused AdamW) are
additionally gated by their own env switches because interpret mode is
far too slow to sit inside every CPU test's train step.
"""
from __future__ import annotations

import os

import jax

NEG_INF = -1e30


def interpret_default() -> bool:
    if os.environ.get("REPRO_PALLAS_COMPILE"):
        return False
    return jax.default_backend() != "tpu"


def _env_gate(var: str) -> bool:
    """Fused-train-kernel gate: explicit env wins, else TPU-only."""
    val = os.environ.get(var)
    if val is not None:
        return val not in ("", "0")
    return jax.default_backend() == "tpu"


def fused_xent_default() -> bool:
    return _env_gate("REPRO_FUSED_XENT")


def fused_adamw_default() -> bool:
    return _env_gate("REPRO_FUSED_ADAMW")
