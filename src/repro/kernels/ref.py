"""Pure-jnp oracles for every Pallas kernel (the correctness contract).

Each ref is the NAIVE, obviously-correct formulation; kernel tests sweep
shapes/dtypes and assert_allclose kernel(interpret=True) vs these.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def attention_ref(q, k, v, *, causal: bool = True, scale=None):
    """q (B,H,Sq,dh); k/v (B,H,Sk,dh)."""
    B, H, Sq, dh = q.shape
    Sk = k.shape[2]
    scale = dh ** -0.5 if scale is None else scale
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if causal:
        mask = jnp.tril(jnp.ones((Sq, Sk), bool), k=Sk - Sq)
        s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p,
                      v.astype(jnp.float32)).astype(q.dtype)


def ssd_ref(x, dt, a, B_, C, h0):
    """Naive Mamba2/SSD recurrence, step by step.

    x (B,S,H,hd); dt (B,S,H) > 0; a (H,) < 0; B_/C (B,S,N); h0 (B,H,hd,N).
    Returns (y (B,S,H,hd) f32, h_last (B,H,hd,N) f32).
    """
    Bs, S, H, hd = x.shape

    def step(h, t):
        da = jnp.exp(dt[:, t] * a)                       # (B,H)
        upd = jnp.einsum("bh,bn,bhd->bhdn", dt[:, t],
                         B_[:, t].astype(jnp.float32),
                         x[:, t].astype(jnp.float32))
        h = da[..., None, None] * h + upd
        y = jnp.einsum("bn,bhdn->bhd", C[:, t].astype(jnp.float32), h)
        return h, y

    h, ys = jax.lax.scan(step, h0.astype(jnp.float32), jnp.arange(S))
    return jnp.moveaxis(ys, 0, 1), h


def wkv6_ref(r, k, v, logw, u, s0):
    """Naive RWKV6 recurrence: S_t = diag(w_t) S_{t-1} + k_t^T v_t,
    y_t = r_t (S_{t-1} + diag(u) k_t^T v_t).

    r/k/v/logw (B,S,H,hd); u (H,hd); s0 (B,H,hd,hd).
    """
    def step(s, t):
        rf = r[:, t].astype(jnp.float32)
        kf = k[:, t].astype(jnp.float32)
        vf = v[:, t].astype(jnp.float32)
        kv = jnp.einsum("bhi,bhj->bhij", kf, vf)
        y = jnp.einsum("bhi,bhij->bhj", rf,
                       s + u[None, :, :, None] * kv)
        s = jnp.exp(logw[:, t].astype(jnp.float32))[..., None] * s + kv
        return s, y

    s, ys = jax.lax.scan(step, s0.astype(jnp.float32),
                         jnp.arange(r.shape[1]))
    return jnp.moveaxis(ys, 0, 1), s


def gmm_ref(x, w):
    """Grouped matmul: x (E,C,D) @ w (E,D,F) -> (E,C,F) in x.dtype."""
    return jnp.einsum("ecd,edf->ecf", x.astype(jnp.float32),
                      w.astype(jnp.float32)).astype(x.dtype)


def softmax_xent_ref(logits, labels, *, softcap=None):
    """Per-row NLL, f32: logits (R,V); labels (R,) int32 -> (R,) f32."""
    lf = logits.astype(jnp.float32)
    if softcap is not None:
        lf = softcap * jnp.tanh(lf / softcap)
    m = jnp.max(lf, axis=-1)
    lse = m + jnp.log(jnp.sum(jnp.exp(lf - m[:, None]), axis=-1))
    gold = jnp.take_along_axis(lf, labels[:, None], axis=-1)[:, 0]
    return lse - gold


def adamw_update_ref(p, g, m, v, lr, bc1, bc2, *, b1, b2, eps,
                     weight_decay=0.0):
    """Unfused AdamW leaf update (mirrors optim.adamw._update_leaf for the
    float32/full state recipe): f32 math, params back in p.dtype."""
    g32 = g.astype(jnp.float32)
    m_new = b1 * m.astype(jnp.float32) + (1.0 - b1) * g32
    v_new = b2 * v.astype(jnp.float32) + (1.0 - b2) * jnp.square(g32)
    update = (m_new / bc1) / (jnp.sqrt(v_new / bc2) + eps)
    if weight_decay:
        update = update + weight_decay * p.astype(jnp.float32)
    new_p = (p.astype(jnp.float32) - lr * update).astype(p.dtype)
    return new_p, m_new, v_new
