"""Pallas TPU flash attention (tiled online softmax).

TARGET: TPU v5e MXU/VMEM.  Grid = (batch*kv_heads*q_groups, num_q_blocks,
num_k_blocks); the k dimension is the innermost ("arbitrary") axis so the
(m, l, acc) running statistics live in VMEM scratch across k steps and the
output block is written once on the last step.  Block shapes default to
(128, head_dim) — MXU-aligned (multiples of 128 on the matmul dims).

Validated on CPU via interpret=True against kernels.ref.attention_ref
(tests/test_kernels_attention.py sweeps shapes/dtypes/causality).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, causal: bool, block_q: int, block_k: int,
                  num_kb: int):
    qb = pl.program_id(1)
    kb = pl.program_id(2)

    @pl.when(kb == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)          # (bq, dh)
    k = k_ref[0].astype(jnp.float32)          # (bk, dh)
    v = v_ref[0].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if causal:
        qpos = qb * block_q + jax.lax.broadcasted_iota(jnp.int32,
                                                       (block_q, block_k), 0)
        kpos = kb * block_k + jax.lax.broadcasted_iota(jnp.int32,
                                                       (block_q, block_k), 1)
        s = jnp.where(kpos <= qpos, s, NEG_INF)

    m_prev = m_scr[...]
    l_prev = l_scr[...]
    m_cur = jnp.max(s, axis=1)
    m_new = jnp.maximum(m_prev, m_cur)
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    l_new = l_prev * alpha + jnp.sum(p, axis=1)
    acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(kb == num_kb - 1)
    def _finalize():
        o_ref[0] = (acc_scr[...] /
                    jnp.maximum(l_scr[...], 1e-30)[:, None]).astype(o_ref.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, block_q: int = 128,
                    block_k: int = 128, scale: Optional[float] = None,
                    interpret: bool = False) -> jax.Array:
    """q (B,H,Sq,dh); k/v (B,H,Sk,dh) — GQA callers repeat kv first.

    Returns (B,H,Sq,dh) in q.dtype.
    """
    B, H, Sq, dh = q.shape
    Sk = k.shape[2]
    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    assert Sq % block_q == 0 and Sk % block_k == 0, (Sq, Sk, block_q, block_k)
    nqb, nkb = Sq // block_q, Sk // block_k
    scale = dh ** -0.5 if scale is None else scale

    qr = q.reshape(B * H, Sq, dh)
    kr = k.reshape(B * H, Sk, dh)
    vr = v.reshape(B * H, Sk, dh)

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, block_q=block_q,
        block_k=block_k, num_kb=nkb)

    out = pl.pallas_call(
        kernel,
        grid=(B * H, nqb, nkb),
        in_specs=[
            pl.BlockSpec((1, block_q, dh), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, dh), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_k, dh), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, dh), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, Sq, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),        # running max m
            pltpu.VMEM((block_q,), jnp.float32),        # running sum l
            pltpu.VMEM((block_q, dh), jnp.float32),     # output accumulator
        ],
        interpret=interpret,
    )(qr, kr, vr)
    return out.reshape(B, H, Sq, dh)
