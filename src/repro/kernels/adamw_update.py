"""Pallas TPU fused AdamW leaf update (one kernel, zero f32 temp trees).

The unfused ``optim.adamw._update_leaf`` materializes several full-leaf
f32 temporaries (g32, m_new, v_hat, update) per tensor; on 1T-scale
stacked leaves that peaks at ~6x params bytes, which is why the unfused
path scans over the layer axis.  This kernel streams the four state
tensors through VMEM one (block_rows, 128) tile at a time and fuses the
whole elementwise chain — moment updates, bias correction, decoupled
weight decay, parameter write — so peak temp memory is one tile and the
layered scan becomes unnecessary.

Schedule hyperparameters that change every step (lr, bias corrections)
ride in SMEM as a tiny scalar vector; (b1, b2, eps, weight_decay) are
compile-time constants.  Math matches ``_update_leaf`` exactly: f32
accumulation regardless of param dtype, params written back in their own
dtype, moments in f32 (the fused path is only engaged for the
float32/full state recipe — quantized or factored state keeps the
unfused path).

Validated on CPU via interpret=True against kernels.ref.adamw_update_ref
(tests/test_kernels.py: dtype sweep, weight-decay on/off, padding tails).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

LANE = 128                     # TPU lane width: tiles are (rows, 128)


def _adamw_kernel(sc_ref, p_ref, g_ref, m_ref, v_ref,
                  np_ref, nm_ref, nv_ref, *, b1: float, b2: float,
                  eps: float, weight_decay: float):
    lr, bc1, bc2 = sc_ref[0], sc_ref[1], sc_ref[2]
    g = g_ref[...].astype(jnp.float32)
    p = p_ref[...].astype(jnp.float32)
    m_new = b1 * m_ref[...] + (1.0 - b1) * g
    v_new = b2 * v_ref[...] + (1.0 - b2) * g * g
    update = (m_new / bc1) / (jnp.sqrt(v_new / bc2) + eps)
    if weight_decay:
        update = update + weight_decay * p
    np_ref[...] = (p - lr * update).astype(np_ref.dtype)
    nm_ref[...] = m_new
    nv_ref[...] = v_new


def adamw_update(p: jax.Array, g: jax.Array, m: jax.Array, v: jax.Array,
                 lr: jax.Array, bc1: jax.Array, bc2: jax.Array, *,
                 b1: float, b2: float, eps: float, weight_decay: float = 0.0,
                 block_rows: int = 256, interpret: bool = False):
    """One fused AdamW update for a leaf of any shape.

    p (param dtype), g (grad dtype), m/v (f32) all share p.shape; lr and
    the bias corrections bc1 = 1-b1^t, bc2 = 1-b2^t are traced scalars.
    Returns (new_p p.dtype, new_m f32, new_v f32) with p.shape.
    """
    shape = p.shape
    n = int(p.size)
    if n == 0:
        return p, m, v
    tile = block_rows * LANE
    npad = -(-n // tile) * tile
    rows = npad // LANE

    def flat(x, dtype=None):
        x = x.reshape(-1)
        if dtype is not None:
            x = x.astype(dtype)
        if npad != n:
            x = jnp.pad(x, (0, npad - n))
        return x.reshape(rows, LANE)

    scalars = jnp.stack([jnp.asarray(lr, jnp.float32),
                         jnp.asarray(bc1, jnp.float32),
                         jnp.asarray(bc2, jnp.float32)])
    kernel = functools.partial(_adamw_kernel, b1=b1, b2=b2, eps=eps,
                               weight_decay=weight_decay)
    tile_spec = pl.BlockSpec((block_rows, LANE), lambda i: (i, 0))
    new_p, new_m, new_v = pl.pallas_call(
        kernel,
        grid=(rows // block_rows,),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            tile_spec, tile_spec, tile_spec, tile_spec,
        ],
        out_specs=[tile_spec, tile_spec, tile_spec],
        out_shape=[jax.ShapeDtypeStruct((rows, LANE), p.dtype),
                   jax.ShapeDtypeStruct((rows, LANE), jnp.float32),
                   jax.ShapeDtypeStruct((rows, LANE), jnp.float32)],
        interpret=interpret,
    )(scalars, flat(p), flat(g), flat(m, jnp.float32),
      flat(v, jnp.float32))

    def unflat(x):
        return x.reshape(-1)[:n].reshape(shape)

    return unflat(new_p), unflat(new_m), unflat(new_v)
