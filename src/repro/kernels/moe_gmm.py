"""Pallas TPU grouped matmul (MoE expert compute).

TARGET: TPU v5e MXU.  x (E, C, D) @ w (E, D, F) -> (E, C, F): grid
(E, C/bc, F/bf, D/bd) with the contraction axis innermost and a VMEM f32
accumulator; block shapes are 128-aligned for the MXU.  This is the
per-expert bucket matmul of models.moe (its XLA einsum is the lowered path;
this kernel is the TPU hot-spot form).

Validated via interpret=True against kernels.ref.gmm_ref.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gmm_kernel(x_ref, w_ref, o_ref, acc_scr, *, num_db: int):
    db = pl.program_id(3)

    @pl.when(db == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    x = x_ref[0]                               # (bc, bd)
    w = w_ref[0]                               # (bd, bf)
    acc_scr[...] += jax.lax.dot_general(
        x, w, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(db == num_db - 1)
    def _done():
        o_ref[0] = acc_scr[...].astype(o_ref.dtype)


def gmm(x: jax.Array, w: jax.Array, *, block_c: int = 128,
        block_f: int = 128, block_d: int = 128,
        interpret: bool = False) -> jax.Array:
    """Grouped matmul.  x (E,C,D); w (E,D,F) -> (E,C,F) in x.dtype."""
    E, C, D = x.shape
    F = w.shape[-1]
    block_c = min(block_c, C)
    block_f = min(block_f, F)
    block_d = min(block_d, D)
    assert C % block_c == 0 and F % block_f == 0 and D % block_d == 0
    nc, nf, nd = C // block_c, F // block_f, D // block_d

    kernel = functools.partial(_gmm_kernel, num_db=nd)
    return pl.pallas_call(
        kernel,
        grid=(E, nc, nf, nd),
        in_specs=[
            pl.BlockSpec((1, block_c, block_d), lambda e, i, j, d: (e, i, d)),
            pl.BlockSpec((1, block_d, block_f), lambda e, i, j, d: (e, d, j)),
        ],
        out_specs=pl.BlockSpec((1, block_c, block_f),
                               lambda e, i, j, d: (e, i, j)),
        out_shape=jax.ShapeDtypeStruct((E, C, F), x.dtype),
        scratch_shapes=[pltpu.VMEM((block_c, block_f), jnp.float32)],
        interpret=interpret,
    )(x, w)
