"""Deterministic synthetic LM token pipeline, sharded + double-buffered.

Training substrate for the assigned LM architectures: a seeded, stateless
stream — batch `i` is a pure function of (seed, i), so a restarted job
regenerates exactly the batches it would have seen (checkpoint/resume does
not need data-state).  Tokens follow a Zipf-ish marginal with Markov
structure so the loss actually decreases (unlike uniform noise).

Multi-host note: each host materializes only its batch shard
(jax.make_array_from_callback addressing); on one host that degrades to a
device_put of the full batch with the requested NamedSharding.
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Iterator, Optional

import jax
import numpy as np


@dataclass
class TokenPipeline:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    sharding: Optional[jax.sharding.NamedSharding] = None
    prefetch: int = 2

    def _host_batch(self, index: int) -> dict:
        rng = np.random.RandomState((self.seed * 1_000_003 + index) % 2**31)
        B, S, V = self.global_batch, self.seq_len, self.vocab_size
        # zipf-ish unigrams + first-order structure: x[t+1] ~ f(x[t])
        base = rng.zipf(1.3, size=(B, S + 1)).astype(np.int64)
        tok = (base + 7919 * np.roll(base, 1, axis=1)) % max(V - 2, 1) + 1
        tok = tok.astype(np.int32)
        return {"tokens": tok[:, :S], "labels": tok[:, 1:S + 1]}

    def batch(self, index: int) -> dict:
        host = self._host_batch(index)
        if self.sharding is None:
            return {k: jax.numpy.asarray(v) for k, v in host.items()}
        return {k: jax.device_put(v, self.sharding) for k, v in host.items()}

    def chunk_host(self, start: int, device_steps: int) -> dict:
        """Batches ``start .. start+device_steps-1`` stacked (K, B, S) on the
        host — the scan axis of ``runtime.steps.build_train_chunk``."""
        per = [self._host_batch(start + j) for j in range(device_steps)]
        return {k: np.stack([b[k] for b in per]) for k in per[0]}

    def chunk(self, start: int, device_steps: int, sharding=None) -> dict:
        """Device-resident stacked chunk (one ``device_put`` per leaf).

        ``sharding`` is the chunk-batch sharding tree from the bundle
        (``build_train_chunk(...).in_shardings[2]``) — a dict of
        NamedShardings, shape-agnostic so partial tail chunks reuse it.
        """
        host = self.chunk_host(start, device_steps)
        if sharding is None:
            return {k: jax.device_put(v) for k, v in host.items()}
        return {k: jax.device_put(v, sharding[k]) for k, v in host.items()}

    def __iter__(self) -> Iterator[dict]:
        """Double-buffered iterator: host-side generation of batch i+1
        overlaps device compute on batch i."""
        q: "queue.Queue" = queue.Queue(maxsize=self.prefetch)
        stop = threading.Event()

        def producer():
            i = 0
            while not stop.is_set():
                try:
                    q.put(self.batch(i), timeout=0.1)
                    i += 1
                except queue.Full:
                    continue

        t = threading.Thread(target=producer, daemon=True)
        t.start()
        try:
            while True:
                yield q.get()
        finally:
            stop.set()


class ChunkPrefetcher:
    """Double-buffered chunk feeder for the device-resident hot loop.

    While chunk k executes on device, the background thread builds chunk
    k+1 on the host AND ``device_put``s it — by the time the trainer asks
    for the next chunk its transfer has already overlapped the previous
    dispatch.  ``schedule`` is the ordered list of ``(start, device_steps)``
    chunks the run will consume (tail chunks may be shorter); ``depth`` is
    the number of chunks allowed in flight beyond the one executing.

    ``get()`` returns ``(start, batches)`` in schedule order and raises
    ``StopIteration`` past the end.  Always ``close()`` (or use as a
    context manager) so a preempted segment doesn't leak the thread.
    """

    _END = object()

    def __init__(self, pipe: TokenPipeline, schedule, sharding=None,
                 depth: int = 2):
        self._q: "queue.Queue" = queue.Queue(maxsize=max(depth, 1))
        self._stop = threading.Event()
        self._error: Optional[Exception] = None
        self._thread = threading.Thread(
            target=self._fill, args=(pipe, list(schedule), sharding),
            daemon=True)
        self._thread.start()

    def _fill(self, pipe, schedule, sharding):
        for entry in schedule + [self._END]:
            try:
                item = entry if entry is self._END else \
                    (entry[0], pipe.chunk(entry[0], entry[1], sharding))
            except Exception as e:      # surface in get(), don't hang it
                self._error = e
                item = self._END
            while not self._stop.is_set():
                try:
                    self._q.put(item, timeout=0.1)
                    break
                except queue.Full:
                    continue
            if self._stop.is_set() or item is self._END:
                return

    def get(self, timeout: float = 120.0):
        item = self._q.get(timeout=timeout)
        if item is self._END:
            if self._error is not None:
                raise self._error
            raise StopIteration
        return item

    def close(self) -> None:
        self._stop.set()
        # drain so a producer blocked on put() sees the stop flag
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=5.0)

    def __enter__(self) -> "ChunkPrefetcher":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
