"""Deterministic synthetic LM token pipeline, sharded + double-buffered.

Training substrate for the assigned LM architectures: a seeded, stateless
stream — batch `i` is a pure function of (seed, i), so a restarted job
regenerates exactly the batches it would have seen (checkpoint/resume does
not need data-state).  Tokens follow a Zipf-ish marginal with Markov
structure so the loss actually decreases (unlike uniform noise).

Multi-host note: each host materializes only its batch shard
(jax.make_array_from_callback addressing); on one host that degrades to a
device_put of the full batch with the requested NamedSharding.
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Iterator, Optional

import jax
import numpy as np


@dataclass
class TokenPipeline:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    sharding: Optional[jax.sharding.NamedSharding] = None
    prefetch: int = 2

    def _host_batch(self, index: int) -> dict:
        rng = np.random.RandomState((self.seed * 1_000_003 + index) % 2**31)
        B, S, V = self.global_batch, self.seq_len, self.vocab_size
        # zipf-ish unigrams + first-order structure: x[t+1] ~ f(x[t])
        base = rng.zipf(1.3, size=(B, S + 1)).astype(np.int64)
        tok = (base + 7919 * np.roll(base, 1, axis=1)) % max(V - 2, 1) + 1
        tok = tok.astype(np.int32)
        return {"tokens": tok[:, :S], "labels": tok[:, 1:S + 1]}

    def batch(self, index: int) -> dict:
        host = self._host_batch(index)
        if self.sharding is None:
            return {k: jax.numpy.asarray(v) for k, v in host.items()}
        return {k: jax.device_put(v, self.sharding) for k, v in host.items()}

    def __iter__(self) -> Iterator[dict]:
        """Double-buffered iterator: host-side generation of batch i+1
        overlaps device compute on batch i."""
        q: "queue.Queue" = queue.Queue(maxsize=self.prefetch)
        stop = threading.Event()

        def producer():
            i = 0
            while not stop.is_set():
                try:
                    q.put(self.batch(i), timeout=0.1)
                    i += 1
                except queue.Full:
                    continue

        t = threading.Thread(target=producer, daemon=True)
        t.start()
        try:
            while True:
                yield q.get()
        finally:
            stop.set()
