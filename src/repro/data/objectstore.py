"""ObjectStore — the Ceph/Rook analogue (paper §II-A).

CHASE-CI mounts a distributed Ceph object store visible to every pod; the
workflow moves data through it between steps.  This is the same interface
backed by a local directory with ATOMIC writes (tmp + rename), so a real
deployment swaps in a Ceph/S3 client without touching callers.  Arrays go
through ``put_array``/``get_array`` (npy bytes); manifests are JSON.
"""
from __future__ import annotations

import io
import json
import os
import tempfile
import threading
from pathlib import Path
from typing import Iterator, List, Optional

import numpy as np


class BlobCodecs:
    """Typed serialization over the raw blob API (``put``/``get``/``list``/
    ``size``) — shared by the local ObjectStore and the federated SiteStore
    facade (repro.fabric), so callers never care which one they hold."""

    def put_array(self, key: str, arr: np.ndarray) -> int:
        buf = io.BytesIO()
        np.save(buf, np.asarray(arr), allow_pickle=False)
        data = buf.getvalue()
        self.put(key, data)
        return len(data)

    def get_array(self, key: str) -> np.ndarray:
        return np.load(io.BytesIO(self.get(key)), allow_pickle=False)

    def put_json(self, key: str, obj) -> None:
        self.put(key, json.dumps(obj, indent=1, default=str).encode())

    def get_json(self, key: str):
        return json.loads(self.get(key))

    def total_bytes(self, prefix: str = "") -> int:
        return sum(self.size(k) for k in self.list(prefix))


class ObjectStore(BlobCodecs):
    def __init__(self, root: str):
        # resolve once so _path containment and list's relative_to agree
        # even when `root` itself is relative or reached via a symlink
        self.root = Path(root).resolve()
        self.root.mkdir(parents=True, exist_ok=True)
        self._lock = threading.Lock()

    def _path(self, key: str) -> Path:
        p = (self.root / key).resolve()
        # Path.relative_to is the component-wise containment check: a plain
        # string startswith() admitted sibling dirs with a common prefix
        # (root /x/store accepted /x/store2/...).
        try:
            p.relative_to(self.root)
        except ValueError:
            raise ValueError(f"key escapes store: {key}") from None
        return p

    # ------------------------------------------------------------------ api
    def put(self, key: str, data: bytes) -> None:
        path = self._path(key)
        path.parent.mkdir(parents=True, exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=str(path.parent), prefix=".tmp-")
        try:
            with os.fdopen(fd, "wb") as f:
                f.write(data)
            os.replace(tmp, path)          # atomic commit
        except BaseException:
            if os.path.exists(tmp):
                os.unlink(tmp)
            raise

    def get(self, key: str) -> bytes:
        return self._path(key).read_bytes()

    def exists(self, key: str) -> bool:
        return self._path(key).is_file()

    def delete(self, key: str) -> bool:
        p = self._path(key)
        if p.is_file():
            p.unlink()
            return True
        return False

    def list(self, prefix: str = "") -> List[str]:
        """Keys under ``prefix``, path-aware: the prefix names an exact key
        or a key-path subtree — ``"ab"`` matches ``ab`` and ``ab/x`` but
        never ``abc/...``.  Only the prefix subtree is walked, so listing
        one workflow's keys is O(that subtree), not O(total objects)."""
        if not prefix:
            base = self.root
        else:
            base = self._path(prefix.rstrip("/"))
            if base.is_file():
                return [] if prefix.endswith("/") \
                    else [str(base.relative_to(self.root))]
        if not base.is_dir():
            return []
        out = [str(p.relative_to(self.root)) for p in base.rglob("*")
               if p.is_file() and not p.name.startswith(".tmp-")]
        return sorted(out)

    def size(self, key: str) -> int:
        return self._path(key).stat().st_size
