"""Synthetic MERRA-2-like IVT volumes — the case study's data substrate.

The paper's Step 1 downloads 3-hourly NASA MERRA-2 reanalysis (576 x 361
global grid) and derives Integrated Water Vapor Transport (IVT); intense
filament-shaped IVT structures ("atmospheric rivers") are what CONNECT/FFN
segment.  Offline we synthesize statistically similar volumes: smooth
correlated background + advecting filament events, seeded per time-chunk so
any worker can (re)generate any chunk — which is exactly what makes the
queue-driven download step idempotent.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Tuple

import numpy as np

GRID_LAT, GRID_LON = 361, 576     # MERRA-2 full horizontal resolution


def _smooth(a: np.ndarray, k: int, axis: int) -> np.ndarray:
    """Box-smooth along axis (cheap separable correlation)."""
    n = a.shape[axis]
    out = np.cumsum(a, axis=axis, dtype=np.float32)
    lo = np.take(out, np.maximum(np.arange(n) - k, 0), axis=axis)
    out = (np.take(out, np.minimum(np.arange(n) + k, n - 1), axis=axis) - lo)
    return out / (2 * k + 1)


@dataclass(frozen=True)
class VolumeSpec:
    lat: int = 96                 # reduced grid for CPU tests; 361 at scale
    lon: int = 144                # 576 at scale
    frames: int = 24              # 3-hourly steps per chunk
    events: int = 3               # filament events per chunk
    threshold: float = 0.55      # IVT intensity -> binary CONNECT label


def generate_chunk(spec: VolumeSpec, chunk_id: int) -> Tuple[np.ndarray,
                                                             np.ndarray]:
    """Returns (ivt (T,lat,lon) f32 in [0,1], labels (T,lat,lon) uint8)."""
    rng = np.random.RandomState(chunk_id % 2**31)
    T, LA, LO = spec.frames, spec.lat, spec.lon
    base = rng.randn(T, LA, LO).astype(np.float32)
    for ax, k in ((0, 2), (1, 6), (2, 6)):
        base = _smooth(base, k, ax)
    base = (base - base.min()) / (np.ptp(base) + 1e-6) * 0.45

    yy, xx = np.mgrid[0:LA, 0:LO].astype(np.float32)
    for _ in range(spec.events):
        # an advecting, rotating filament (atmospheric-river analogue)
        cy, cx = rng.uniform(0.2, 0.8) * LA, rng.uniform(0.1, 0.5) * LO
        vy, vx = rng.uniform(-1, 1), rng.uniform(1.0, 3.0)
        ang = rng.uniform(0, np.pi)
        length, width = rng.uniform(0.2, 0.4) * LO, rng.uniform(2, 5)
        amp = rng.uniform(0.5, 0.9)
        for t in range(T):
            oy, ox = cy + vy * t, cx + vx * t
            dy, dx = yy - oy, xx - ox
            u = dx * np.cos(ang) + dy * np.sin(ang)
            w = -dx * np.sin(ang) + dy * np.cos(ang)
            blob = np.exp(-(u / length) ** 2 - (w / width) ** 2)
            base[t] += amp * blob.astype(np.float32)
    ivt = np.clip(base, 0, 1.5) / 1.5
    labels = (ivt > spec.threshold).astype(np.uint8)
    return ivt.astype(np.float32), labels


def chunk_keys(n_chunks: int, prefix: str = "merra/ivt") -> List[str]:
    return [f"{prefix}/chunk_{i:05d}" for i in range(n_chunks)]


def subvolumes(ivt: np.ndarray, labels: np.ndarray, fov: Tuple[int, int, int],
               stride: Tuple[int, int, int]):
    """Sliding (t, lat, lon) training windows for the FFN (paper Step 2)."""
    T, LA, LO = ivt.shape
    ft, fy, fx = fov
    st, sy, sx = stride
    out = []
    for t0 in range(0, max(T - ft + 1, 1), st):
        for y0 in range(0, max(LA - fy + 1, 1), sy):
            for x0 in range(0, max(LO - fx + 1, 1), sx):
                out.append((ivt[t0:t0 + ft, y0:y0 + fy, x0:x0 + fx],
                            labels[t0:t0 + ft, y0:y0 + fy, x0:x0 + fx]))
    return out
