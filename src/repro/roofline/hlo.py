"""Parse compiled HLO text for collective traffic.

cost_analysis() has no collective-bytes term, so we derive per-kind operand
bytes from each collective instruction's *result* type (compiled CPU HLO does
not print operand types inline):

    all-gather:        operand = result / group_size
    reduce-scatter:    operand = result * group_size
    all-reduce / all-to-all / collective-permute: operand = result

group_size comes from ``replica_groups=[G,N]<=...`` (iota form) or the first
explicit ``{{...}}`` group.  Tuple-typed results (variadic / -start forms)
sum their element types.

NOTE (trip counts): cost/HLO analysis sees a lax.scan body ONCE.  The
roofline driver therefore measures collectives with the G-diff method —
lowering unrolled G=1 and G=2 variants of each model: per-layer-group bytes
= (G2 - G1), outside-scan bytes = G1 - per_layer, total = outside + G * per
(see repro.roofline.report).
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")


def xla_cost(compiled) -> Dict[str, float]:
    """``compiled.cost_analysis()`` across jax versions: older releases
    return a per-computation list of dicts, newer ones a flat dict."""
    try:
        cost = compiled.cost_analysis()
    except Exception:
        return {}
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return dict(cost)

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_TYPE_RE = re.compile(r"\b([a-z]+\d+(?:e\d+m\d+(?:fn)?)?|pred)\[([\d,]*)\]")
_INSTR_RE = re.compile(
    r"=\s*(\([^=]*?\)|[a-z0-9]+\[[\d,]*\][^\s]*)\s+([a-z0-9-]+)\(")
_IOTA_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=")
_EXPLICIT_GROUPS_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")


def _type_bytes(type_str: str) -> int:
    total = 0
    for m in _TYPE_RE.finditer(type_str):
        n = 1
        if m.group(2):
            for d in m.group(2).split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES.get(m.group(1), 4)
    return total


def _group_size(line: str) -> int:
    m = _IOTA_GROUPS_RE.search(line)
    if m:
        return int(m.group(2))
    m = _EXPLICIT_GROUPS_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return 1


def _classify(op: str):
    for kind in COLLECTIVES:
        if op == kind or op.startswith(kind + "-"):
            if op.endswith("-done"):       # -start carries the traffic
                return None
            return kind
    return None


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """operand bytes per collective kind (+ 'total', 'total_bf16adj').

    total_bf16adj halves f32 collective bytes: XLA:CPU legalizes bf16 dots
    by upcasting operands to f32, and the partitioner then moves the f32
    tensor — on TPU (native bf16 MXU) the same collectives are bf16.  All
    jax-level activations/weights here are bf16 (verified in §Perf), so the
    adjusted number is the TPU-equivalent traffic.
    """
    out: Dict[str, int] = defaultdict(int)
    adj = 0
    for line in hlo_text.splitlines():
        m = _INSTR_RE.search(line)
        if not m:
            continue
        kind = _classify(m.group(2))
        if kind is None:
            continue
        ty = m.group(1)
        rb = _type_bytes(ty)
        if kind == "all-gather":
            rb //= max(_group_size(line), 1)
        elif kind == "reduce-scatter":
            rb *= _group_size(line)
        out[kind] += rb
        adj += rb // 2 if "f32[" in ty else rb
    out["total"] = sum(v for k, v in out.items() if k != "total")
    out["total_bf16adj"] = adj
    return dict(out)


def collective_counts(hlo_text: str) -> Dict[str, int]:
    out: Dict[str, int] = defaultdict(int)
    for line in hlo_text.splitlines():
        m = _INSTR_RE.search(line)
        if m:
            kind = _classify(m.group(2))
            if kind:
                out[kind] += 1
    return dict(out)
