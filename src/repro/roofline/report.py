"""§Roofline: three-term analysis per (arch x shape) from the dry-run.

    compute term    = step_FLOPs        / (chips * 197e12)   [bf16 MXU]
    memory term     = HBM bytes moved   / (chips * 819e9)
    collective term = collective bytes  / (chips * 50e9)     [per-link ICI]

FLOPs/bytes come from the analytic implementation-exact accounting
(repro.roofline.flops — XLA's cost_analysis cannot see through scan bodies;
the G-diff collective bytes DO come from the compiled artifact).  Also
reported per cell: MODEL_FLOPS = 6·N_active·D, the useful/HLO-equivalent
ratio, the dominant term, and what would move it (the §Perf hillclimb
hypotheses start from this table).

    PYTHONPATH=src python -m repro.roofline.report --dir experiments/dryrun
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path
from typing import Dict, List, Optional

from repro.configs import registry
from repro.configs.base import SHAPES
from repro.roofline import flops as flops_mod

PEAK_FLOPS = 197e12       # bf16 / chip (v5e)
HBM_BW = 819e9            # bytes/s / chip
LINK_BW = 50e9            # bytes/s / link (ICI)


def cell_roofline(arch: str, shape_name: str, rec: Optional[dict],
                  chips: int = 256) -> Dict:
    cfg = registry.get_config(arch)
    ocfg = registry.get_optimizer(arch)
    shape = SHAPES[shape_name]
    acc = flops_mod.accounting(cfg, shape, chips, ocfg)

    flops_chip = acc.step_flops_global / chips
    bytes_chip = acc.act_bytes_global / chips
    coll_chip = 0.0
    coll_kinds = {}
    if rec and "gdiff" in rec and "step_total" in rec["gdiff"]:
        coll_kinds = rec["gdiff"]["step_total"]
        # prefer the TPU-dtype-corrected number (XLA:CPU upcasts bf16 dot
        # operands to f32 before the partitioner places collectives)
        coll_chip = coll_kinds.get("total_bf16adj",
                                   coll_kinds.get("total", 0))
    compute_t = flops_chip / PEAK_FLOPS
    memory_t = bytes_chip / HBM_BW
    coll_t = coll_chip / LINK_BW
    terms = {"compute": compute_t, "memory": memory_t, "collective": coll_t}
    dominant = max(terms, key=terms.get)
    bound = max(terms.values())
    useful_t = (acc.model_flops / chips) / PEAK_FLOPS
    out = {
        "arch": arch, "shape": shape_name, "chips": chips,
        "params": acc.params, "active_params": acc.active_params,
        "step_flops": acc.step_flops_global,
        "model_flops": acc.model_flops,
        "useful_ratio": acc.model_flops / max(acc.step_flops_global, 1),
        "compute_s": compute_t, "memory_s": memory_t, "collective_s": coll_t,
        "collective_kinds": coll_kinds,
        "dominant": dominant,
        "roofline_fraction": useful_t / max(bound, 1e-30),
        "mfu_upper_bound": useful_t / max(sum(terms.values()), 1e-30),
    }
    if rec:
        out["xla_temp_bytes"] = rec.get("memory", {}).get(
            "temp_size_in_bytes", 0)
        out["xla_args_bytes"] = rec.get("memory", {}).get(
            "argument_size_in_bytes", 0)
        out["compile_s"] = rec.get("compile_s")
    return out


def _advice(row: Dict) -> str:
    d = row["dominant"]
    if d == "compute":
        if row["useful_ratio"] < 0.4:
            return ("compute-bound with low useful ratio: cut remat "
                    "recompute / masked-attention waste / MoE padding")
        return "compute-bound near-useful: increase per-chip batch or accept"
    if d == "memory":
        return ("HBM-bound: fuse/avoid activation round-trips; decode -> "
                "bigger batch amortizes weight reads")
    return ("collective-bound: resharding or FSDP gathers dominate — change "
            "layouts (seq vs heads), hierarchical/overlapped collectives")


def build_table(dry_dir: str, chips: int = 256) -> List[Dict]:
    d = Path(dry_dir)
    rows = []
    for arch, shape, skipped in registry.cells(include_skipped=True):
        if skipped:
            rows.append({"arch": arch, "shape": shape.name,
                         "skipped": "long_500k needs sub-quadratic attention"
                                    " (pure full-attention arch)"})
            continue
        path = d / f"{arch}__{shape.name}__16x16.json"
        rec = json.loads(path.read_text()) if path.exists() else None
        row = cell_roofline(arch, shape.name, rec, chips)
        row["advice"] = _advice(row)
        rows.append(row)
    return rows


def to_markdown(rows: List[Dict]) -> str:
    head = ("| arch | shape | compute s | memory s | collective s | dominant "
            "| MODEL/HLO | roofline frac | next lever |")
    sep = "|" + "---|" * 9
    lines = [head, sep]
    for r in rows:
        if "skipped" in r:
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | — | — "
                         f"| — | SKIP: {r['skipped']} |")
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3e} "
            f"| {r['memory_s']:.3e} | {r['collective_s']:.3e} "
            f"| **{r['dominant']}** | {r['useful_ratio']:.2f} "
            f"| {r['roofline_fraction']:.2f} | {r['advice']} |")
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--json-out", default="experiments/roofline.json")
    args = ap.parse_args()
    rows = build_table(args.dir)
    Path(args.json_out).parent.mkdir(parents=True, exist_ok=True)
    Path(args.json_out).write_text(json.dumps(rows, indent=1))
    print(to_markdown(rows))


if __name__ == "__main__":
    main()
