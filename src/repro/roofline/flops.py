"""Analytic FLOP/byte accounting for §Roofline.

Why analytic: XLA's cost_analysis does not traverse control-flow bodies —
with scan-over-layers (and inner attention/SSD chunk scans) it undercounts
by orders of magnitude (measured: 1000x; see EXPERIMENTS.md §Dry-run).  The
formulas below mirror THIS implementation op-for-op (full-score attention
incl. masked waste, MoE capacity padding, remat recompute multipliers), so
they are "HLO-equivalent" counts, not idealized ones.  MODEL_FLOPS = 6·N·D
(6·N_active·D for MoE) is reported alongside as the useful-work yardstick.

Cross-checked in tests/test_roofline.py against XLA cost_analysis on small
UNROLLED configs (where XLA counts everything).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models.params import param_count
from repro.runtime import steps as steps_mod


def _ceil(a, b):
    return -(-a // b)


@dataclass
class Accounting:
    fwd_flops_global: float = 0.0       # one forward pass, whole step
    step_flops_global: float = 0.0      # incl. bwd + remat recompute
    model_flops: float = 0.0            # 6 N_active D
    params: int = 0
    active_params: int = 0
    weight_bytes: int = 0
    opt_state_bytes: int = 0
    act_bytes_global: float = 0.0       # activation HBM traffic (approx)
    cache_bytes: int = 0                # KV/state cache (decode/prefill)

    def as_dict(self) -> Dict[str, float]:
        return {k: float(v) for k, v in self.__dict__.items()}


def _attn_block_flops(cfg, tokens, ctx_len, *, window=None):
    """Per-step global flops of one dense attention+mlp layer."""
    D, H, KV, dh, F = (cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
                       cfg.resolved_head_dim, cfg.d_ff)
    proj = 2 * D * dh * (H + 2 * KV) + 2 * H * dh * D
    # our kernels compute FULL ctx scores (causality by masking): no /2
    mix = 4 * ctx_len * H * dh
    mlp = 6 * D * F
    return tokens * (proj + mix + mlp)


def _moe_slot_factor(cfg, tokens_per_chip, tp=16):
    m = cfg.moe
    TK = tokens_per_chip * m.top_k
    cap = _ceil(TK, tp) * m.capacity_factor
    slots = tp * int(cap)
    e_local = max(m.num_experts // tp, 1)
    cap_e = _ceil(slots, e_local) * m.capacity_factor
    padded = e_local * int(cap_e)
    return padded / max(tokens_per_chip, 1)


def _moe_block_flops(cfg, tokens, ctx_len, tokens_per_chip):
    D, F, E = cfg.d_model, cfg.d_ff, cfg.moe.num_experts
    attn = _attn_block_flops(cfg, tokens, ctx_len) - tokens * 6 * D * F
    router = tokens * 2 * D * E
    sf = _moe_slot_factor(cfg, tokens_per_chip)
    experts = tokens * sf * 6 * D * F
    return attn + router + experts


def _mamba_block_flops(cfg, tokens):
    D = cfg.d_model
    s = cfg.ssm
    d_in = s.expand * D
    Hs = d_in // s.head_dim
    hd, N, c, K = s.head_dim, s.state_dim, s.chunk, s.conv_kernel
    proj = 2 * D * (2 * d_in + 2 * N + Hs) + 2 * d_in * D
    mix = Hs * (2 * c * N + 2 * c * hd + 4 * N * hd) + 2 * K * d_in
    return tokens * (proj + mix)


def _rwkv_block_flops(cfg, tokens):
    D, F = cfg.d_model, cfg.d_ff
    hd = cfg.rwkv.head_dim
    H = D // hd
    c = cfg.rwkv.chunk
    proj = 2 * D * D * 6 + 2 * D * 64 * 2 + 4 * D * F
    mix = H * (5 * c * hd + 4 * hd * hd)
    return tokens * (proj + mix)


def _cross_block_flops(cfg, tokens, batch):
    D, H, KV, dh, F = (cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
                       cfg.resolved_head_dim, cfg.d_ff)
    P, Vd = cfg.num_patches, cfg.vision_dim
    qo = tokens * (2 * D * H * dh + 2 * H * dh * D)
    kv = batch * 2 * P * Vd * 2 * KV * dh
    mix = tokens * 4 * P * H * dh
    mlp = tokens * 6 * D * F
    return qo + kv + mix + mlp


def forward_flops(cfg: ModelConfig, shape: ShapeConfig, chips: int) -> float:
    """One forward pass, global flops, for THIS implementation."""
    B = shape.global_batch
    if shape.kind == "decode":
        tokens, ctx = B, shape.seq_len
    else:
        tokens, ctx = B * shape.seq_len, shape.seq_len
    if cfg.family == "audio":
        return _whisper_forward(cfg, shape)
    tokens_per_chip = max(tokens // chips * 16, 1)   # per model-row tokens
    total = 0.0
    G = cfg.num_groups
    for kind in cfg.block_pattern:
        if kind in ("attn", "global"):
            total += G * _attn_block_flops(cfg, tokens, ctx)
        elif kind == "local":
            w = cfg.attn.window or ctx
            total += G * _attn_block_flops(cfg, tokens, min(w, ctx))
        elif kind == "moe":
            total += G * _moe_block_flops(cfg, tokens, ctx, tokens_per_chip)
        elif kind == "mamba":
            total += G * _mamba_block_flops(cfg, tokens)
        elif kind == "mamba_attn":
            total += G * (_mamba_block_flops(cfg, tokens)
                          + _attn_block_flops(cfg, tokens, ctx))
        elif kind == "rwkv":
            total += G * _rwkv_block_flops(cfg, tokens)
        elif kind == "cross":
            total += G * _cross_block_flops(cfg, tokens, B)
        else:
            raise ValueError(kind)
    # head (train computes it on all tokens; serving on the last/new token)
    head_tokens = tokens if shape.kind == "train" else B
    total += head_tokens * 2 * cfg.d_model * cfg.vocab_size
    return total


def _whisper_forward(cfg: ModelConfig, shape: ShapeConfig) -> float:
    B = shape.global_batch
    S_enc = shape.seq_len
    Td = 1 if shape.kind == "decode" else cfg.decoder_len
    enc_tokens = 0 if shape.kind == "decode" else B * S_enc
    enc = cfg.encoder_layers * _attn_block_flops(cfg, enc_tokens, S_enc)
    dec_self = cfg.num_layers * _attn_block_flops(
        cfg, B * Td, cfg.decoder_len)
    D, H, KV, dh = (cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
                    cfg.resolved_head_dim)
    kv_proj = 0 if shape.kind == "decode" else \
        cfg.num_layers * B * S_enc * 2 * D * 2 * KV * dh
    cross_mix = cfg.num_layers * B * Td * (
        2 * D * H * dh + 2 * H * dh * D + 4 * S_enc * H * dh)
    head = B * (Td if shape.kind == "train" else 1) * \
        2 * cfg.d_model * cfg.vocab_size
    return enc + dec_self + kv_proj + cross_mix + head


def train_multiplier(cfg: ModelConfig) -> float:
    """fwd-equivalents per train step: 1 fwd + 2 bwd + remat recompute
    (1 extra fwd; multi-layer groups pay a second recompute — nested)."""
    return 5.0 if len(cfg.block_pattern) > 1 else 4.0


def accounting(cfg: ModelConfig, shape: ShapeConfig, chips: int,
               ocfg=None) -> Accounting:
    from repro.models import transformer as tfm
    from repro.optim import adamw

    cfg = steps_mod.resolve_cfg(cfg, shape)
    mod = steps_mod._model_module(cfg)
    schema = mod.lm_schema(cfg)
    acc = Accounting()
    acc.params = param_count(schema)
    if cfg.moe is not None:
        # active = total - (non-routed fraction of experts)
        expert_params = (cfg.num_groups * cfg.moe.num_experts *
                         3 * cfg.d_model * cfg.d_ff)
        active_experts = (cfg.num_groups * cfg.moe.top_k *
                          3 * cfg.d_model * cfg.d_ff)
        acc.active_params = acc.params - expert_params + active_experts
    else:
        acc.active_params = acc.params
    acc.weight_bytes = acc.params * 2                     # bf16

    if ocfg is not None:
        opt_schema = adamw.opt_state_schema(schema, ocfg)
        from repro.models.params import param_bytes
        acc.opt_state_bytes = param_bytes(opt_schema, "float32")

    acc.fwd_flops_global = forward_flops(cfg, shape, chips)
    if shape.kind == "train":
        acc.step_flops_global = acc.fwd_flops_global * train_multiplier(cfg)
        tokens = shape.global_batch * shape.seq_len
        acc.model_flops = 6.0 * acc.active_params * tokens   # fwd+bwd
    else:
        acc.step_flops_global = acc.fwd_flops_global
        tokens = (shape.global_batch if shape.kind == "decode"
                  else shape.global_batch * shape.seq_len)
        acc.model_flops = 2.0 * acc.active_params * tokens   # inference fwd

    # --- HBM traffic (approx): weights read once per fwd-equivalent pass;
    # optimizer state read+write; activations ~ 12 (B,S,D)-sized tensors
    # per layer per pass (projection inputs/outputs, norms, residuals).
    D = cfg.d_model
    passes = train_multiplier(cfg) if shape.kind == "train" else 1.0
    act_pass = 12 * tokens * D * 2 * cfg.num_layers
    acc.act_bytes_global = passes * (acc.weight_bytes + act_pass)
    if shape.kind == "train":
        acc.act_bytes_global += 2 * acc.opt_state_bytes + acc.weight_bytes
    if shape.kind != "train":
        try:
            cache_schema = mod.cache_schema(cfg, shape.global_batch,
                                            shape.seq_len)
            from repro.models.params import param_bytes as pb
            acc.cache_bytes = pb(cache_schema, cfg.param_dtype)
        except Exception:
            acc.cache_bytes = 0
        acc.act_bytes_global += acc.cache_bytes  # decode reads whole cache
    return acc
