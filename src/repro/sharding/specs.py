"""Logical-axis -> mesh-axis rules (MaxText-style), divisibility-aware.

A logical axis names *what* a tensor dimension is; the rules decide *where*
it lives on the mesh.  Rules silently drop to replication when the dimension
size does not divide the mesh axis (e.g. 24 q-heads on a 16-way model axis,
8 kv-heads on 16) — GSPMD supports uneven shardings but padded shards waste
memory + collective bytes, so divisible-only keeps the roofline honest.
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ParallelConfig
from repro.models.params import PSpec, tree_map_schema

Axis = Union[str, Tuple[str, ...], None]


def logical_rules(par: ParallelConfig) -> dict[str, Axis]:
    """Active logical->mesh mapping for a ParallelConfig."""
    if par.pure_fsdp:
        return {
            # batch over every mesh axis; weights ZeRO-3 over (data, model);
            # no tensor/sequence parallelism -> zero activation collectives
            "batch": ("pod", "data", "model"),
            "seq": None, "act_seq_sharded": None,
            "heads": None, "kv_heads": None, "act_ff": None,
            "act_vocab": None, "act_inner_heads": None,
            "cache_seq": "model" if par.context_parallel_decode else None,
            "head_dim": None, "state": None,
            "fsdp": ("data", "model"), "tp_heads": None, "tp_kv_heads": None,
            "tp_head_dim": None, "tp_ff": None, "tp_vocab": None,
            "expert": None, "tp_inner": None, "tp_inner_heads": None,
            "layers": None, "conv_k": None,
        }
    rules: dict[str, Axis] = {
        # --- activations ---
        "batch": ("pod", "data"),
        "seq": None,
        "act_seq_sharded": "model" if par.sequence_parallel else None,
        "heads": "model" if par.tensor_parallel else None,
        "kv_heads": "model" if par.tensor_parallel else None,
        "act_ff": "model" if par.tensor_parallel else None,
        "act_vocab": "model" if par.tensor_parallel else None,
        "cache_seq": "model" if par.context_parallel_decode else None,
        "head_dim": None,
        "state": None,
        "act_inner_heads": "model" if par.tensor_parallel else None,
        # --- params ---
        "fsdp": "data" if par.fsdp else None,
        "tp_heads": "model" if par.tensor_parallel else None,
        "tp_kv_heads": "model" if par.tensor_parallel else None,
        "tp_head_dim": "model" if par.tensor_parallel else None,
        "tp_ff": "model" if par.tensor_parallel else None,
        "tp_vocab": "model" if par.tensor_parallel else None,
        "expert": "model" if par.expert_parallel else None,
        "tp_inner": "model" if par.tensor_parallel else None,
        "tp_inner_heads": "model" if par.tensor_parallel else None,
        "layers": None,
        "conv_k": None,
    }
    return rules


def _mesh_size(mesh: Mesh, axis: Axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, str):
        return mesh.shape[axis] if axis in mesh.shape else 1
    n = 1
    for a in axis:
        n *= mesh.shape[a] if a in mesh.shape else 1
    return n


def _present(mesh: Mesh, axis: Axis) -> Axis:
    """Restrict a rule to axes actually present in the mesh (pod may be absent)."""
    if axis is None:
        return None
    if isinstance(axis, str):
        return axis if axis in mesh.shape else None
    kept = tuple(a for a in axis if a in mesh.shape)
    return kept if len(kept) > 1 else (kept[0] if kept else None)


def spec_for(shape: Sequence[int], axes: Sequence[Optional[str]],
             mesh: Mesh, rules: dict[str, Axis]) -> P:
    """PartitionSpec for one tensor, dropping non-divisible rules.

    Tuple rules degrade gracefully: ("pod","data","model") that does not
    divide the dim retries without its leading axis before replicating.
    """
    entries = []
    used: set[str] = set()
    for dim, name in zip(shape, axes):
        axis = _present(mesh, rules.get(name)) if name else None
        if axis is not None:
            candidates = [axis]
            if isinstance(axis, tuple):
                candidates += [axis[i:] if len(axis[i:]) > 1 else axis[-1]
                               for i in range(1, len(axis))]
            chosen = None
            for cand in candidates:
                flat = (cand,) if isinstance(cand, str) else cand
                if (not any(a in used for a in flat)
                        and dim % _mesh_size(mesh, cand) == 0):
                    chosen = cand
                    used.update(flat)
                    break
            axis = chosen
        entries.append(axis)
    return P(*entries)


def sharding_for(shape, axes, mesh, rules) -> NamedSharding:
    return NamedSharding(mesh, spec_for(shape, axes, mesh, rules))


def shardings_for_schema(schema, mesh: Mesh, rules: dict[str, Axis]):
    """NamedSharding tree mirroring a param schema."""
    return tree_map_schema(
        lambda _p, p: sharding_for(p.shape, p.axes, mesh, rules), schema)


def shardings_like(tree_of_sds, tree_of_axes, mesh, rules):
    """NamedSharding tree for an arbitrary (ShapeDtypeStruct, axes) pair of trees."""
    return jax.tree.map(
        lambda sds, ax: sharding_for(sds.shape, ax, mesh, rules),
        tree_of_sds, tree_of_axes,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(a, (str, type(None))) for a in x))


def constrain(x: jax.Array, axes: Sequence[Optional[str]], mesh: Mesh,
              rules: dict[str, Axis]) -> jax.Array:
    """with_sharding_constraint by logical axes (no-op outside jit tracing)."""
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, spec_for(x.shape, axes, mesh, rules)))
