"""Multi-site federation (paper §I, §IV): sites + bandwidth-modeled links,
a single content-addressed namespace with per-site replicas, locality-aware
placement, and cross-site elastic failover."""
from repro.fabric.topology import Fabric, Link, Site
from repro.fabric.federated import FederatedStore, SiteStore
from repro.fabric.placement import Placement, PlacementPlanner
from repro.fabric.failover import (FederatedTrainResult, Migration,
                                   run_elastic_federated)

__all__ = [
    "Fabric", "Link", "Site",
    "FederatedStore", "SiteStore",
    "Placement", "PlacementPlanner",
    "FederatedTrainResult", "Migration", "run_elastic_federated",
]
