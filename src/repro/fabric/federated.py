"""FederatedStore — one content-addressed namespace over per-site stores.

The paper's Ceph is *distributed*: every pod sees one namespace, but the
bytes live somewhere, and moving them across the PRP costs real link
time.  This module keeps that honest:

  * a catalog maps ``key -> {site: nbytes}`` — the single namespace with
    per-site replicas; ``exists``/``list`` answer over every *live*
    replica (a dead site's unreplicated keys vanish until it returns);
  * ``replicate(key, dst)`` is an explicit, metered transfer over the
    best live link; concurrent replications of the same (key, dst) are
    deduped against an in-flight table (one copy moves, everyone waits);
  * ``replicate_many`` batches keys by source site so N small objects
    pay one link latency, not N;
  * ``SiteStore`` is the ObjectStore-compatible view a pod at one site
    holds: reads of non-local keys pull them across the link (metered
    pull-through cache — this is exactly what data-blind placement pays),
    writes land locally and register in the catalog, and an optional
    ``mirror`` site synchronously replicates matching prefixes (how
    elastic training keeps its checkpoints alive across a site loss).
"""
from __future__ import annotations

import threading
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

from repro.data.objectstore import BlobCodecs
from repro.fabric.topology import Fabric


def _under(key: str, prefix: str) -> bool:
    """Path-aware prefix match, mirroring ObjectStore.list semantics."""
    if not prefix:
        return True
    p = prefix.rstrip("/")
    if prefix.endswith("/"):
        return key.startswith(p + "/")
    return key == p or key.startswith(p + "/")


class FederatedStore:
    def __init__(self, fabric: Fabric):
        self.fabric = fabric
        self.metrics = fabric.metrics
        self._lock = threading.Lock()
        self._catalog: Dict[str, Dict[str, int]] = {}
        self._inflight: Dict[Tuple[str, str], threading.Event] = {}

    # -------------------------------------------------------------- catalog
    def register(self, key: str, site: str, nbytes: int) -> None:
        with self._lock:
            self._catalog.setdefault(key, {})[site] = nbytes

    def where(self, key: str, *, up_only: bool = True) -> List[str]:
        """Sites holding a replica (live sites only, by default)."""
        with self._lock:
            sites = list(self._catalog.get(key, ()))
        if up_only:
            sites = [s for s in sites if self.fabric.sites[s].up]
        return sorted(sites)

    def exists(self, key: str) -> bool:
        return bool(self.where(key))

    def nbytes(self, key: str) -> int:
        with self._lock:
            reps = self._catalog.get(key)
            return next(iter(reps.values())) if reps else 0

    def list(self, prefix: str = "") -> List[str]:
        with self._lock:
            keys = list(self._catalog)
        return sorted(k for k in keys if _under(k, prefix) and self.where(k))

    def total_bytes(self, prefix: str = "") -> int:
        return sum(self.nbytes(k) for k in self.list(prefix))

    # ------------------------------------------------------------------ io
    def put(self, key: str, data: bytes, site: str,
            replicate_to: Sequence[str] = ()) -> None:
        # a write to a dead site would "succeed" into a black hole (its
        # replicas are unreadable until restore) — fail it loudly instead
        self._require_up(site)
        self.fabric.sites[site].store.put(key, data)
        self.register(key, site, len(data))
        for dst in replicate_to:
            self.replicate(key, dst)

    def get(self, key: str, site: Optional[str] = None) -> bytes:
        """Read a key.  With ``site``, the read happens *at* that site:
        a missing local replica is first pulled over the link (metered).
        Without a site this is an unmetered control-plane read (workflow
        markers/manifests — negligible bytes by design)."""
        if site is not None:
            self.replicate(key, site)
            return self.fabric.sites[site].store.get(key)
        reps = self.where(key)
        if not reps:
            raise FileNotFoundError(key)
        return self.fabric.sites[reps[0]].store.get(key)

    def delete(self, key: str) -> bool:
        """Drop every live replica + the catalog entry (single namespace:
        delete means *gone*, e.g. checkpoint GC must free every mirror)."""
        with self._lock:
            reps = self._catalog.pop(key, {})
        found = False
        for s in reps:
            if self.fabric.sites[s].up:
                found |= self.fabric.sites[s].store.delete(key)
        return found

    # ----------------------------------------------------------- replication
    def best_src(self, key: str, dst: str, *,
                 include_down: bool = False) -> Optional[str]:
        """The replica site the bytes should come FROM for a copy to
        ``dst``: ``dst`` itself if it already holds one, else the source
        with the fastest link.  None when no (reachable) replica exists —
        sites without a configured link are unreachable, not an error,
        so partial topologies score as expensive rather than crash."""
        reps = self.where(key, up_only=not include_down)
        if dst in reps:
            return dst
        best, best_bw = None, -1.0
        for src in reps:
            try:
                link = self.fabric.link(src, dst)
            except ValueError:
                continue                       # no route src -> dst
            bw = link.bytes_per_s if link else float("inf")
            if (bw, src) > (best_bw, best or ""):
                best, best_bw = src, bw
        return best

    def _best_src(self, key: str, dst: str) -> str:
        src = self.best_src(key, dst)
        if src is None:
            raise FileNotFoundError(
                f"no reachable live replica of {key!r} for {dst!r}")
        return src

    def _require_up(self, site: str) -> None:
        if not self.fabric.sites[site].up:
            raise RuntimeError(f"site {site!r} is down")

    def replicate(self, key: str, dst: str, *, tenant: str = "") -> float:
        """Copy ``key`` to ``dst`` (no-op if already there).  Returns the
        simulated transfer seconds.  In-flight copies of the same
        (key, dst) are deduped: the second caller waits on the first
        transfer instead of moving the bytes twice.  ``tenant`` tags the
        link accounting (the mover's tenant is billed; deduped waiters
        pay nothing)."""
        self._require_up(dst)
        while True:
            with self._lock:
                reps = self._catalog.get(key, {})
                if dst in reps:
                    return 0.0
                ev = self._inflight.get((key, dst))
                if ev is None:
                    ev = threading.Event()
                    self._inflight[(key, dst)] = ev
                    mine = True
                else:
                    mine = False
            if not mine:
                self.metrics.inc("fabric/replicate_dedup")
                ev.wait(timeout=60.0)
                continue   # re-check: the owner may have failed
            try:
                src = self._best_src(key, dst)
                data = self.fabric.sites[src].store.get(key)
                sim_s = self.fabric.transfer(src, dst, len(data),
                                             tenant=tenant)
                self.fabric.sites[dst].store.put(key, data)
                self.register(key, dst, len(data))
                return sim_s
            finally:
                with self._lock:
                    self._inflight.pop((key, dst), None)
                ev.set()

    def replicate_many(self, keys: Iterable[str], dst: str, *,
                       tenant: str = "") -> Tuple[int, float]:
        """Pre-stage a set of keys at ``dst``, batched by source site so
        each (src, dst) pair pays ONE link latency for the whole group.
        Unknown/unreachable keys are skipped (outputs yet to be produced,
        or stranded behind a dead link) and counted in
        ``fabric/missing_key``.  Returns (bytes_moved, sim_seconds)."""
        self._require_up(dst)
        by_src: Dict[str, List[str]] = {}
        for key in dict.fromkeys(keys):        # preserve order, dedupe
            with self._lock:
                if dst in self._catalog.get(key, {}):
                    continue
            src = self.best_src(key, dst)
            if src is None:
                self.metrics.inc("fabric/missing_key")
            else:
                by_src.setdefault(src, []).append(key)
        moved, sim_total = 0, 0.0
        for src, group in sorted(by_src.items()):
            blobs = [(k, self.fabric.sites[src].store.get(k)) for k in group]
            nbytes = sum(len(d) for _, d in blobs)
            sim_total += self.fabric.transfer(src, dst, nbytes, transfers=1,
                                              tenant=tenant)
            for k, d in blobs:
                self.fabric.sites[dst].store.put(k, d)
                self.register(k, dst, len(d))
            moved += nbytes
        return moved, sim_total

    # ---------------------------------------------------------------- views
    def view(self, site: str, *, mirror: Optional[str] = None,
             mirror_prefixes: Sequence[str] = ("checkpoints/",),
             tenant: str = "") -> "SiteStore":
        return SiteStore(self, site, mirror=mirror,
                         mirror_prefixes=tuple(mirror_prefixes),
                         tenant=tenant)


class SiteStore(BlobCodecs):
    """What a pod at one site sees: the whole namespace, local-first.

    API-compatible with ``repro.data.objectstore.ObjectStore`` (the
    Checkpointer, workflow and CONNECT steps run on either).  Non-local
    reads are metered pull-through copies; writes register in the
    catalog and, when a ``mirror`` is set, synchronously replicate
    matching prefixes off-site (crash-consistent: copies happen in write
    order, so a mirrored MANIFEST implies its mirrored shards)."""

    def __init__(self, fed: FederatedStore, site: str, *,
                 mirror: Optional[str] = None,
                 mirror_prefixes: Tuple[str, ...] = ("checkpoints/",),
                 tenant: str = ""):
        self.fed = fed
        self.site = site
        self.mirror = mirror
        self.mirror_prefixes = mirror_prefixes
        self.tenant = tenant        # bills this view's pulls/mirrors

    @property
    def root(self):
        return self.fed.fabric.sites[self.site].store.root

    def put(self, key: str, data: bytes) -> None:
        self.fed.put(key, data, self.site)
        if self.mirror and any(_under(key, p) for p in self.mirror_prefixes):
            if self.fed.fabric.sites[self.mirror].up:
                self.fed.replicate(key, self.mirror, tenant=self.tenant)
            else:
                self.fed.metrics.inc("fabric/mirror_skipped")

    def get(self, key: str) -> bytes:
        if not self.fed.exists(key):
            raise FileNotFoundError(key)
        if self.tenant:
            self.fed.replicate(key, self.site, tenant=self.tenant)
        return self.fed.get(key, self.site)

    def exists(self, key: str) -> bool:
        return self.fed.exists(key)

    def delete(self, key: str) -> bool:
        return self.fed.delete(key)

    def list(self, prefix: str = "") -> List[str]:
        return self.fed.list(prefix)

    def size(self, key: str) -> int:
        n = self.fed.nbytes(key)
        if n == 0 and not self.fed.exists(key):
            raise FileNotFoundError(key)
        return n
