"""Sites + links — the Pacific Research Platform as a modeled network.

The paper's infrastructure is not one cluster: it is ~30 GPU appliances
("FIONAs") at PRP member institutions, joined by 10-100 Gbps links, with
"virtual cluster management for data communication" deciding where data
and compute meet (§I, §IV).  This module models that federation:

  * a ``Site`` owns its own site-tagged ``Cluster`` (compute) and
    ``ObjectStore`` (its Ceph pool) — one appliance / campus;
  * a ``Link`` between two sites has configured bandwidth and latency;
    moving bytes across it *costs* simulated wall-time
    ``latency + bytes / bandwidth`` and is metered into the shared
    metrics ``Registry`` (``fabric/bytes_moved``, ``fabric/transfer_s``,
    per-link byte counters) — the §VI measure-everything discipline
    applied to the network;
  * ``Fabric`` is the topology: site registry, link table, the transfer
    cost model, whole-site failure (``fail_site`` drains the site's
    cluster and hides its replicas), and a cross-site ``submit`` that
    places a ``JobSpec`` on the least-loaded live site.

``time_scale`` maps simulated transfer seconds onto real sleeps so a
benchmark's wall-clock *is* its simulated makespan (``time_scale=1.0``),
while unit tests run with ``time_scale=0`` and only the meters move.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from contextlib import contextmanager
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

from repro.core.metrics import Registry
from repro.core.orchestrator import Cluster, Job, JobSpec
from repro.data.objectstore import ObjectStore


@dataclass(frozen=True)
class Link:
    """A directed site-to-site network path with a bandwidth/latency model."""
    src: str
    dst: str
    gbps: float                 # bandwidth, gigabits per second
    latency_s: float = 0.0      # per-transfer setup latency (RTT-ish)

    @property
    def bytes_per_s(self) -> float:
        return self.gbps * 1e9 / 8

    def transfer_s(self, nbytes: int, transfers: int = 1) -> float:
        """Simulated seconds to move ``nbytes`` in ``transfers`` batched
        round-trips — batching N keys into one transfer pays the latency
        once, which is why the federated store coalesces copies."""
        return transfers * self.latency_s + nbytes / self.bytes_per_s


@dataclass
class Site:
    """One PRP appliance: a named cluster + its local object store."""
    name: str
    cluster: Cluster
    store: ObjectStore
    labels: Dict[str, str] = field(default_factory=dict)
    up: bool = True

    @property
    def capacity(self) -> int:
        """Online devices — 0 while the whole site is down."""
        return len(self.cluster.online_devices) if self.up else 0

    def queue_depth(self) -> int:
        return self.cluster.queue_depth()


class Fabric:
    """The federation topology: N sites, bandwidth-modeled links, meters."""

    def __init__(self, metrics: Optional[Registry] = None, *,
                 time_scale: float = 0.0):
        self.metrics = metrics or Registry()
        self.time_scale = time_scale
        self.sites: Dict[str, Site] = {}
        self._links: Dict[Tuple[str, str], Link] = {}
        # original Link per degraded direction, so a restore (explicit or
        # via restore_site) returns the configured bandwidth exactly
        self._degraded: Dict[Tuple[str, str], Link] = {}
        self._lock = threading.Lock()
        # in-flight bytes per (link, tenant) — the backlog a tenant-aware
        # placement scorer reads so one tenant's pre-staging cannot
        # silently starve another tenant's links (repro.vcluster)
        self._inflight: Dict[Tuple[str, str], Dict[str, int]] = {}
        # transfer watchers: cb(src, dst, nbytes, sim_s, tenant) after
        # every metered cross-site move (feeds the monitor event bus)
        self._watchers: List[Callable[[str, str, int, float, str],
                                      None]] = []

    # ------------------------------------------------------------- topology
    def add_site(self, name: str, *, devices: Optional[List[Any]] = None,
                 cluster: Optional[Cluster] = None,
                 store: Optional[ObjectStore] = None,
                 store_root: Optional[str] = None, **labels) -> Site:
        """Register a site.  Pass an existing cluster/store or let the
        fabric build them (``devices`` list, ``store_root`` dir); every
        site cluster shares the fabric's metrics registry."""
        if name in self.sites:
            raise ValueError(f"site {name!r} exists")
        if cluster is None:
            cluster = Cluster(devices=list(devices if devices is not None
                                           else range(1)),
                              metrics=self.metrics, site=name)
        else:
            cluster.site = name
            # adopt the cluster onto the federation's registry so every
            # site meters into ONE scrape surface (per-tenant device-
            # lease billing, pod counters) — otherwise a user-provided
            # cluster's numbers are stranded in its private registry
            cluster.metrics = self.metrics
        if store is None:
            if store_root is None:
                import tempfile
                store_root = tempfile.mkdtemp(prefix=f"fabric-{name}-")
            store = ObjectStore(store_root)
        site = Site(name, cluster, store, labels)
        self.sites[name] = site
        return site

    def connect(self, a: str, b: str, *, gbps: float,
                latency_ms: float = 0.0, symmetric: bool = True) -> None:
        for name in (a, b):
            if name not in self.sites:
                raise ValueError(f"unknown site {name!r}")
        self._links[(a, b)] = Link(a, b, gbps, latency_ms / 1e3)
        if symmetric:
            self._links[(b, a)] = Link(b, a, gbps, latency_ms / 1e3)

    def degrade_link(self, a: str, b: str, *, gbps: float,
                     latency_ms: Optional[float] = None,
                     symmetric: bool = True) -> None:
        """Brown-out a link: replace its bandwidth (and optionally its
        latency) while remembering the configured original, so
        ``restore_link`` / ``restore_site`` can undo it exactly.  The
        degraded cost model is live immediately — placement scoring and
        every subsequent ``transfer`` see the reduced gbps.  Repeated
        degradations keep the FIRST original (a double brown-out still
        restores to the configured link)."""
        if gbps <= 0:
            raise ValueError(f"degraded gbps must be > 0, got {gbps}")
        pairs = [(a, b), (b, a)] if symmetric else [(a, b)]
        with self._lock:
            for key in pairs:
                link = self._links.get(key)
                if link is None:
                    raise ValueError(f"no link {key[0]!r} -> {key[1]!r}")
                self._degraded.setdefault(key, link)
                self._links[key] = dataclasses.replace(
                    link, gbps=gbps,
                    latency_s=link.latency_s if latency_ms is None
                    else latency_ms / 1e3)
        self.metrics.inc("fabric/link_degradations")
        self.metrics.inc(f"fabric/link/{a}->{b}/degradations")

    def restore_link(self, a: str, b: str, *, symmetric: bool = True) -> bool:
        """Return a degraded link to its configured bandwidth/latency.
        Returns False when the link was not degraded."""
        restored = False
        pairs = [(a, b), (b, a)] if symmetric else [(a, b)]
        with self._lock:
            for key in pairs:
                orig = self._degraded.pop(key, None)
                if orig is not None:
                    self._links[key] = orig
                    restored = True
        if restored:
            self.metrics.inc("fabric/link_restores")
        return restored

    def degraded_links(self) -> List[Tuple[str, str]]:
        """The directions currently running below configured bandwidth."""
        with self._lock:
            return sorted(self._degraded)

    def link(self, src: str, dst: str) -> Optional[Link]:
        """The link src->dst; None for a same-site (free) move."""
        if src == dst:
            return None
        try:
            return self._links[(src, dst)]
        except KeyError:
            raise ValueError(f"no link {src!r} -> {dst!r}") from None

    def up_sites(self) -> List[Site]:
        return [s for s in self.sites.values() if s.up]

    # ------------------------------------------------------------ transfers
    def transfer_s(self, src: str, dst: str, nbytes: int,
                   transfers: int = 1) -> float:
        link = self.link(src, dst)
        return 0.0 if link is None else link.transfer_s(nbytes, transfers)

    def add_watcher(self, cb: Callable[[str, str, int, float, str],
                                       None]) -> None:
        """Register cb(src, dst, nbytes, sim_s, tenant) per transfer."""
        self._watchers.append(cb)

    @contextmanager
    def reserve(self, src: str, dst: str, nbytes: int, tenant: str = ""):
        """Mark bytes as in flight on a link for the block's duration —
        the backlog other tenants' placement scoring sees.  ``transfer``
        wraps its sleep in this; tests can use it directly to simulate a
        long-running competing transfer."""
        key = (src, dst)
        with self._lock:
            q = self._inflight.setdefault(key, {})
            q[tenant] = q.get(tenant, 0) + nbytes
        try:
            yield
        finally:
            with self._lock:
                q = self._inflight.get(key, {})
                left = q.get(tenant, 0) - nbytes
                if left > 0:
                    q[tenant] = left
                else:
                    q.pop(tenant, None)
                if not q:
                    self._inflight.pop(key, None)

    def link_backlog_s(self, src: str, dst: str, *,
                       exclude_tenant: Optional[str] = None) -> float:
        """Simulated seconds of OTHER tenants' in-flight bytes queued on
        src->dst — the fair-share penalty a tenant-aware planner adds so
        one tenant's pre-staging cannot starve another's links.  0 for
        same-site or unconfigured routes."""
        if src == dst:
            return 0.0
        try:
            link = self.link(src, dst)
        except ValueError:
            return 0.0
        with self._lock:
            q = self._inflight.get((src, dst), {})
            pending = sum(b for t, b in q.items()
                          if exclude_tenant is None or t != exclude_tenant)
        return pending / link.bytes_per_s

    def transfer(self, src: str, dst: str, nbytes: int, *,
                 transfers: int = 1, tenant: str = "") -> float:
        """Account (and, scaled, *spend*) the cost of moving bytes.

        Returns the simulated seconds.  Same-site moves are free and
        unmetered; cross-site moves bump ``fabric/bytes_moved`` /
        ``fabric/transfer_s`` plus per-link byte counters, then sleep
        ``sim_s * time_scale`` so makespans reflect the network.  A
        ``tenant`` tag additionally meters the tenant's own byte counter
        and registers the bytes as link backlog while they move."""
        sim_s = self.transfer_s(src, dst, nbytes, transfers)
        if src == dst:
            return 0.0
        self.metrics.inc("fabric/bytes_moved", nbytes)
        self.metrics.inc("fabric/transfer_s", sim_s)
        self.metrics.inc("fabric/transfers", transfers)
        self.metrics.inc(f"fabric/link/{src}->{dst}/bytes", nbytes)
        if tenant:
            self.metrics.inc(f"fabric/tenant/{tenant}/bytes_moved", nbytes)
        if sim_s > 0 and self.time_scale > 0:
            with self.reserve(src, dst, nbytes, tenant):
                time.sleep(sim_s * self.time_scale)
        for cb in list(self._watchers):
            try:
                cb(src, dst, nbytes, sim_s, tenant)
            except Exception:   # observers must not break the data plane
                pass
        return sim_s

    # ---------------------------------------------------------- site churn
    def fail_site(self, name: str) -> None:
        """A whole appliance unplugs: its cluster drains every pod, its
        replicas stop being readable, and placement must route around it."""
        site = self.sites[name]
        site.up = False
        site.cluster.fail_all_nodes()
        self.metrics.inc("fabric/site_failures")

    def restore_site(self, name: str) -> None:
        """Bring an appliance back: nodes rejoin AND any degraded link
        touching the site returns to its configured bandwidth (a site
        restore is a power-cycle — its NICs come back clean)."""
        site = self.sites[name]
        site.up = True
        for d in list(site.cluster.devices):
            site.cluster.join_node(d)
        for src, dst in self.degraded_links():
            if name in (src, dst):
                self.restore_link(src, dst, symmetric=False)

    # ------------------------------------------------------------- compute
    def submit(self, namespace: str, spec: JobSpec, *,
               site: Optional[str] = None) -> Tuple[Site, Job]:
        """Cross-site submit: run a Job on ``site``, or on the live site
        with the most free headroom (capacity minus queue depth).  Data
        placement belongs to the planner (repro.fabric.placement); this is
        the compute-only path for site-agnostic jobs."""
        if site is not None:
            cands = [self.sites[site]]
            if not cands[0].up:
                raise RuntimeError(f"site {site!r} is down")
        else:
            need = spec.devices_per_pod * spec.replicas
            cands = [s for s in self.up_sites() if s.capacity >= need]
            if not cands:
                raise RuntimeError(
                    f"no live site has {need} devices for {spec.name!r}")
            cands.sort(key=lambda s: (s.queue_depth() - s.capacity, s.name))
        chosen = cands[0]
        if namespace not in chosen.cluster.namespaces:
            chosen.cluster.create_namespace(namespace)
        return chosen, chosen.cluster.submit(namespace, spec)
