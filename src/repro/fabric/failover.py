"""Cross-site failover — survive the loss of a whole appliance.

Within one site, ``repro.elastic`` already self-heals node churn: drain,
re-mesh, restore, rescale accumulation.  What it cannot survive is the
*site* dying — the cluster drops below one model replica and the churn
controller escalates with ``CapacityLostError``.  This supervisor owns
that case, the paper's multi-appliance contract (§IV-V):

  1. the job trains at its placed site through a ``SiteStore`` whose
     ``mirror`` replicates every checkpoint write to a second site
     (metered over the link — durability is not free);
  2. on escalation, the planner re-places the job over the surviving
     sites using the checkpoint keys as the job's dataset (so it lands
     where the mirror is, if it can);
  3. surviving replicas of ``checkpoints/`` are batch-replicated to the
     new site, a new trainer resumes from the newest *reachable*
     manifest, and the shared run report keeps accumulating.

Steps checkpointed at the dead site but never mirrored are honestly
lost — they show up as ``steps_lost``, exactly like intra-site churn.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

from repro.core.metrics import Registry
from repro.elastic.controller import CapacityLostError
from repro.elastic.trainer import (ElasticRunReport, ElasticTrainer,
                                   ElasticTrainSpec)
from repro.fabric.federated import FederatedStore
from repro.fabric.placement import PlacementPlanner


@dataclass
class Migration:
    """One cross-site move of a training job."""
    from_site: str
    to_site: str
    at_step: int                 # last completed step before the move
    bytes_moved: int
    transfer_s: float


@dataclass
class FederatedTrainResult:
    sites: List[str] = field(default_factory=list)
    migrations: List[Migration] = field(default_factory=list)
    report: Optional[ElasticRunReport] = None
    out: Dict[str, Any] = field(default_factory=dict)

    def to_json(self) -> Dict[str, Any]:
        return {"sites": self.sites,
                "migrations": [dataclasses.asdict(m) for m in self.migrations],
                "report": self.report.to_json() if self.report else None}


def run_elastic_federated(planner: PlacementPlanner, spec: ElasticTrainSpec,
                          *, ckpt_prefix: str = "checkpoints",
                          max_migrations: int = 3,
                          metrics: Optional[Registry] = None,
                          stop=None, on_trainer=None) -> FederatedTrainResult:
    """Run elastic training on the fabric, failing over across sites.

    The spec's ``base_shape`` is the preferred mesh; each site hosts
    whatever slice of it fits (the in-site churn controller shrinks the
    data axis as usual).  ``rejoin_timeout_s`` bounds how long a dead
    site is waited on before the job migrates.  ``stop`` (a
    ``threading.Event``, e.g. a ``repro.api`` Handle's cancel signal)
    drains the current site's trainer cooperatively — it checkpoints
    and exits — and the partial result is returned without migrating.
    ``on_trainer`` (a callable) observes each site's ElasticTrainer as
    it is created (live progress probing across migrations).
    """
    fed: FederatedStore = planner.fed
    fabric = fed.fabric
    metrics = metrics or fabric.metrics
    result = FederatedTrainResult()
    report: Optional[ElasticRunReport] = None
    carried_losses: Dict[int, float] = {}
    ckpt_inputs = [ckpt_prefix + "/*"]
    # smallest cluster that can host one model replica: every non-data axis
    # of the preferred mesh is weight-structural and cannot shrink
    import numpy as np
    di = spec.mesh_axes.index("data")
    replica = int(np.prod([s for j, s in enumerate(spec.base_shape)
                           if j != di]) or 1)

    def _bw(a: str, b: str) -> float:
        try:
            link = fabric.link(a, b)
        except ValueError:
            return -1.0
        return link.bytes_per_s if link else float("inf")

    while True:
        placement = planner.place(ckpt_inputs, devices=replica)
        site = fabric.sites[placement.site]
        # mirror checkpoints to the best-connected OTHER live site (storage
        # only — it need not be able to host the job itself)
        mirrors = sorted((s.name for s in fabric.up_sites()
                          if s.name != site.name),
                         key=lambda n: -_bw(site.name, n))
        store = fed.view(site.name, mirror=mirrors[0] if mirrors else None,
                         mirror_prefixes=(ckpt_prefix + "/",))
        # stage surviving checkpoint replicas at the new home before resuming
        staged_b, staged_s = planner.prestage(ckpt_inputs, site.name)
        if result.sites:
            at = report.segments[-1].end if report and report.segments else -1
            result.migrations.append(Migration(
                from_site=result.sites[-1], to_site=site.name, at_step=at,
                bytes_moved=staged_b, transfer_s=staged_s))
            metrics.inc("fabric/migrations")
        result.sites.append(site.name)
        trainer = ElasticTrainer(site.cluster, spec, store=store,
                                 metrics=metrics, report=report, stop=stop)
        if on_trainer is not None:
            on_trainer(trainer)
        # the loss log is host state, not checkpoint state: carry it over
        # so the finished run has one loss per step across every site
        trainer._losses.update(carried_losses)
        report = trainer.report
        try:
            result.out = trainer.run()
            result.report = report
            metrics.gauge("fabric/train_migrations", len(result.migrations))
            return result
        except CapacityLostError:
            carried_losses.update(trainer._losses)
            if stop is not None and stop.is_set():
                raise           # cancelled mid-outage: don't migrate
            if len(result.migrations) >= max_migrations:
                raise
            if not any(s.name != site.name
                       for s in planner.candidates(replica)):
                raise   # nowhere left to go
            if spec.verbose:
                print(f"[fabric] site {site.name!r} lost capacity -> "
                      f"failing the job over")
