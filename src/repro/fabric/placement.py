"""Locality-aware placement — move the bytes or move the job.

The paper's virtual-cluster management exists to answer one question per
workflow step: run the pods where the data already is, or pre-stage the
data to where the compute is free (§I, §IV).  The planner scores every
live site

    score(site) = est_transfer_s(missing input bytes -> site, best links)
                + queue_cost_s * queue_depth(site)

and places the step at the argmin.  If the chosen site already holds
every input replica the step is ``data-local`` (the job moved); otherwise
the planner ``pre-stage``s the missing keys over the links (batched per
source, metered) before the step runs.  When the *data home* — the site
that would have been free to run at — is down or full, the step records
a migration, which is how a site loss shows up in the Table-I report.

``data_blind=True`` is the strawman the paper warns about: round-robin
over live sites, ignoring where the bytes live.  The federated store's
pull-through reads keep it *correct*; the meters show what it costs
(``benchmarks/run.py::bench_fabric_placement``).
"""
from __future__ import annotations

import threading
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

from repro.fabric.federated import FederatedStore
from repro.fabric.topology import Site


@dataclass(frozen=True)
class Placement:
    """One placement verdict, kept for the step report."""
    site: str
    mode: str                    # "data-local" | "pre-stage"
    bytes_to_move: int
    est_transfer_s: float
    scores: Dict[str, float] = field(default_factory=dict)
    migrated_from: Optional[str] = None   # data home that could not host

    @property
    def migrated(self) -> bool:
        return self.migrated_from is not None


class PlacementPlanner:
    def __init__(self, fed: FederatedStore, *, queue_cost_s: float = 0.05,
                 data_blind: bool = False, tenant: str = ""):
        """``tenant`` makes the planner multi-tenant-aware: staging moves
        are billed to the tenant's byte counters, and site scores include
        the backlog OTHER tenants' in-flight transfers queue on the links
        the staging would use — so one tenant's pre-staging cannot
        starve another tenant's routes (repro.vcluster)."""
        self.fed = fed
        self.fabric = fed.fabric
        self.queue_cost_s = queue_cost_s
        self.data_blind = data_blind
        self.tenant = tenant
        self._rr = 0                     # data-blind round-robin cursor
        # steps placed but not yet finished (reserve/release): inline
        # steps (pods=1, no cluster submission) are otherwise invisible
        # to queue_depth, so concurrent branches would all pile onto the
        # same tie-broken site
        self._inflight: Dict[str, int] = {}
        self._inflight_lock = threading.Lock()

    # ------------------------------------------------------------- in-flight
    def reserve(self, site: str) -> None:
        """Record a step placed at ``site`` but not yet finished."""
        with self._inflight_lock:
            self._inflight[site] = self._inflight.get(site, 0) + 1

    def release(self, site: str) -> None:
        with self._inflight_lock:
            n = self._inflight.get(site, 0) - 1
            if n > 0:
                self._inflight[site] = n
            else:
                self._inflight.pop(site, None)

    def load(self, site: Site) -> int:
        """Pending work at a site: cluster queue depth plus placed-but-
        unfinished steps this planner is tracking."""
        with self._inflight_lock:
            inflight = self._inflight.get(site.name, 0)
        return site.queue_depth() + inflight

    # -------------------------------------------------------------- scoring
    def expand(self, inputs: Sequence[str]) -> List[str]:
        """Dataset keys for a step; ``"prefix/*"`` globs every cataloged
        key under the prefix (e.g. a trained model's whole leaf tree)."""
        keys: List[str] = []
        for k in inputs:
            if k.endswith("/*"):
                keys.extend(self.fed.list(k[:-2]))
            else:
                keys.append(k)
        return keys

    def bytes_missing(self, keys: Sequence[str], site: str, *,
                      include_down: bool = False) -> Tuple[int, float]:
        """(missing bytes, est. simulated seconds to stage them at site),
        grouped by best source so each source pays one link latency —
        the same batching ``FederatedStore.replicate_many`` performs.
        ``include_down`` also counts replicas at dead sites (used to ask
        "where WOULD this step run were every site healthy").  A key that
        exists but is unreachable from ``site`` (no configured link)
        scores the site as infinitely expensive rather than crashing."""
        by_src: Dict[str, int] = {}
        unreachable = False
        for key in keys:
            reps = self.fed.where(key, up_only=not include_down)
            if not reps or site in reps:
                continue        # not produced yet, or already local
            src = self.fed.best_src(key, site, include_down=include_down)
            if src is None:
                unreachable = True
                continue
            by_src[src] = by_src.get(src, 0) + self.fed.nbytes(key)
        missing = sum(by_src.values())
        est_s = sum(self.fabric.transfer_s(src, site, n, transfers=1) +
                    self.fabric.link_backlog_s(
                        src, site, exclude_tenant=self.tenant or None)
                    for src, n in by_src.items())
        if unreachable:
            est_s = float("inf")
        return missing, est_s

    def score(self, keys: Sequence[str], site: Site) -> float:
        _, est_s = self.bytes_missing(keys, site.name)
        return est_s + self.queue_cost_s * self.load(site)

    # ------------------------------------------------------------ placement
    def candidates(self, devices: int = 0) -> List[Site]:
        """Live sites that can host the step.  A zero-capacity site (all
        nodes offline) is never a candidate, even for a device-less step:
        its cluster would drain any pod the moment it landed."""
        return [s for s in self.fabric.up_sites()
                if s.capacity >= max(devices, 1)]

    def place(self, inputs: Sequence[str] = (), *,
              devices: int = 0) -> Placement:
        """Choose the site for a step with the given input dataset keys."""
        keys = self.expand(inputs)
        cands = self.candidates(devices)
        if not cands:
            raise RuntimeError(
                f"no live site can host a step needing {devices} devices")
        sites = list(self.fabric.sites.values())
        stats = {s.name: self.bytes_missing(keys, s.name) for s in sites}
        scores = {s.name: stats[s.name][1] +
                  self.queue_cost_s * self.load(s) for s in sites}
        # the data home: where this step WOULD run were every site healthy
        # (dead sites' replicas count; ties broken toward raw device
        # count) — if the home cannot host it now, this placement is a
        # migration and the report says so
        ideal = {s.name: self.bytes_missing(keys, s.name,
                                            include_down=True)[1] +
                 self.queue_cost_s * s.queue_depth() for s in sites}
        home = min(sites, key=lambda s: (ideal[s.name],
                                         -len(s.cluster.devices), s.name))
        if self.data_blind:
            chosen = cands[self._rr % len(cands)]
            self._rr += 1
        else:
            chosen = min(cands, key=lambda s: (scores[s.name], -s.capacity,
                                               s.name))
        migrated_from = home.name if (home.name != chosen.name and
                                      home not in cands) else None
        missing, est_s = stats[chosen.name]
        return Placement(site=chosen.name,
                         mode="data-local" if missing == 0 else "pre-stage",
                         bytes_to_move=missing, est_transfer_s=est_s,
                         scores={s.name: scores[s.name] for s in cands},
                         migrated_from=migrated_from)

    def prestage(self, inputs: Sequence[str],
                 site: str) -> Tuple[int, float]:
        """Move a step's missing inputs to its site ahead of execution."""
        return self.fed.replicate_many(self.expand(inputs), site,
                                       tenant=self.tenant)
