"""Host-side block-pool bookkeeping for the paged KV cache.

The device side (runtime.steps: ``init_paged_cache`` / ``paged_cache_view``
/ ``paged_cache_scatter``) is pure data movement; everything *policy* —
which block belongs to whom, when it can be reused — lives here, mirroring
the scheduler/engine split.

Blocks are refcounted.  Block 0 is reserved as the NULL block (table
entries for unallocated tail positions point at it; its garbage content is
masked to an exact 0.0 contribution by the decode attention mask, see
runtime/steps.py).  A radix-style prefix cache sits on top: completed
prompts register their block chain under content-derived chain keys
(``key_i = (key_{i-1}, chunk_i_tokens)`` — exact, no hash collisions), so
a later request sharing the prefix retains the cached blocks instead of
re-prefilling them.  Cached blocks at refcount 0 stay resident and
LRU-evictable; ``alloc`` reclaims them only under pressure, which is what
makes the cache free: it occupies only blocks nobody is using.

Shared blocks are never written: the engine block-aligns the shared
prefix and caps it below the padded prompt length, so every write position
of the new request lands in its own freshly allocated blocks — no
copy-on-write machinery needed.
"""
from __future__ import annotations

from collections import OrderedDict
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.metrics import Registry
from repro.serving.report import GAUGES


class BlockPool:
    """Free-list + refcount + prefix-cache bookkeeping for ``num_blocks``
    fixed-size blocks (block 0 reserved as the null block)."""

    def __init__(self, num_blocks: int, block_size: int, *,
                 bytes_per_block: int = 0,
                 registry: Optional[Registry] = None):
        if num_blocks < 2:
            raise ValueError("need at least one non-null block")
        self.num_blocks = num_blocks
        self.block_size = block_size
        self.bytes_per_block = bytes_per_block
        self.metrics = registry if registry is not None else Registry()
        self._free: List[int] = list(range(num_blocks - 1, 0, -1))
        self._ref = [0] * num_blocks
        # chain key -> cached block id, in LRU order (oldest first)
        self._lru: "OrderedDict[Tuple, int]" = OrderedDict()
        self._key_of: Dict[int, Tuple] = {}

    # ------------------------------------------------------------ accounting
    @property
    def free_blocks(self) -> int:
        """Blocks allocatable right now (free list + evictable cached)."""
        evictable = sum(1 for b in self._key_of if self._ref[b] == 0)
        return len(self._free) + evictable

    @property
    def in_use(self) -> int:
        return sum(1 for b in range(1, self.num_blocks) if self._ref[b] > 0)

    def ref(self, block: int) -> int:
        return self._ref[block]

    def _gauge(self) -> None:
        self.metrics.gauge(GAUGES.BLOCKS_IN_USE, self.in_use)

    # ------------------------------------------------------------ alloc/free
    def alloc(self, n: int) -> Optional[List[int]]:
        """Take ``n`` blocks (refcount 1 each), evicting LRU cached
        refcount-0 blocks under pressure.  Returns None — allocating
        nothing — if the pool cannot satisfy the request; the caller
        preempts a slot or retries later."""
        if n == 0:
            return []
        if n > len(self._free):
            self._evict(n - len(self._free))
        if n > len(self._free):
            return None
        blocks = [self._free.pop() for _ in range(n)]
        for b in blocks:
            self._ref[b] = 1
        self._gauge()
        return blocks

    def _evict(self, k: int) -> int:
        evicted = 0
        for key in list(self._lru.keys()):
            if evicted >= k:
                break
            b = self._lru[key]
            if self._ref[b] == 0:
                del self._lru[key]
                del self._key_of[b]
                self._free.append(b)
                evicted += 1
        return evicted

    def release(self, blocks: Sequence[int]) -> None:
        """Drop one reference per block.  At refcount 0 an uncached block
        returns to the free list; a cached one stays resident (evictable)."""
        for b in blocks:
            if self._ref[b] <= 0:
                raise ValueError(f"block {b} released below refcount 0")
            self._ref[b] -= 1
            if self._ref[b] == 0 and b not in self._key_of:
                self._free.append(b)
        self._gauge()

    # ---------------------------------------------------------- prefix cache
    def _chain_keys(self, prompt: Sequence[int], n_chunks: int):
        key = None
        for j in range(n_chunks):
            chunk = tuple(prompt[j * self.block_size:(j + 1) * self.block_size])
            key = (key, chunk)
            yield key

    def match(self, prompt: Sequence[int], *,
              max_blocks: Optional[int] = None) -> List[int]:
        """Longest cached block-aligned prefix of ``prompt`` (capped at
        ``max_blocks``).  Matched blocks are retained (+1 ref) for the
        caller — shared ownership, never written by the new request.
        Records hit/miss/bytes-saved gauges in block units."""
        n_chunks = len(prompt) // self.block_size
        if max_blocks is not None:
            n_chunks = min(n_chunks, max_blocks)
        blocks: List[int] = []
        for key in self._chain_keys(prompt, n_chunks):
            b = self._lru.get(key)
            if b is None:
                break
            self._ref[b] += 1
            self._lru.move_to_end(key)
            blocks.append(b)
        hits, misses = len(blocks), n_chunks - len(blocks)
        if hits:
            self.metrics.inc(GAUGES.PREFIX_HITS, hits)
            self.metrics.inc(GAUGES.PREFIX_BYTES_SAVED,
                             hits * self.bytes_per_block)
        if misses:
            self.metrics.inc(GAUGES.PREFIX_MISSES, misses)
        self._gauge()
        return blocks

    def cache_prefix(self, prompt: Sequence[int],
                     blocks: Sequence[int]) -> int:
        """Register a completed request's prompt blocks under their chain
        keys so later requests can ``match`` them.  A key already cached
        (by an earlier request with the same prefix) keeps its existing
        block; the chain continues regardless — keys are content-derived,
        not block-derived.  Returns the number of newly cached blocks."""
        added = 0
        for key, b in zip(self._chain_keys(prompt, len(blocks)), blocks):
            if key in self._lru:
                self._lru.move_to_end(key)
                continue
            if b in self._key_of:       # already cached under another chain
                continue
            self._lru[key] = b
            self._key_of[b] = key
            added += 1
        return added

    @property
    def cached_blocks(self) -> int:
        return len(self._key_of)
