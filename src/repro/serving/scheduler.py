"""Slot-based continuous-batching scheduler (host-side policy).

The paper's distributed-inference step feeds many short requests through a
Redis-style job queue onto GPU pods (§III, §V).  The static batcher served
them drain-then-refill: lease a batch, decode until the *longest* request
finishes, ack, repeat — every short request idles its decode slot while the
stragglers run.  This module removes that barrier.

The model is a fixed pool of ``num_slots`` decode slots backed by a slotted
KV/state cache (repro.runtime.steps).  The scheduler owns all *policy* and
bookkeeping and never touches an accelerator:

  admission   ``admit()`` leases queued requests into free slots, FIFO.
  prefill     the engine prefills each admitted request alone and reports
              the first generated token via ``start()``.
  decode      the engine runs one fused step over all slots per iteration;
              ``observe()`` records each slot's new token, advances its
              position, and *evicts* any slot whose request just hit its
              stop length — the freed slot is refillable on the very next
              ``admit()``, no inter-request barrier.
  leases      ``renew_leases()`` heartbeats the WorkQueue's visibility
              timeout for long-running requests so a live server is never
              double-served, while a crashed one still requeues its work.

Determinism: every decision is a pure function of (queue contents, injected
clock, observed tokens), so the scheduler is unit-testable with a fake
clock and a fake engine — no devices, no wall time (tests/test_serving.py).

Metrics (repro.core.metrics.Registry):
  serve/admitted          counter — requests admitted into slots
  serve/completed         counter — requests finished and acked
  serve/tokens_generated  counter — useful (acked) tokens recorded
  serve/stale_tokens      counter — tokens from stale-acked duplicates
  serve/decode_steps      counter — fused decode iterations
  serve/slot_occupancy    gauge   — active slots at each decode step
  serve/queue_depth       gauge   — pending backlog sampled at admit()
  serve/ttft_s            series  — per-request enqueue -> first token
  serve/service_ttft_s    series  — per-request admit -> first token
  serve/request_latency_s series  — per-request enqueue -> completion
  serve/lease_renewals    counter — successful lease heartbeats
  serve/lease_lost        counter — slots dropped on an expired lease
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

from repro.core.metrics import Registry
from repro.core.queue import WorkQueue
from repro.serving.report import GAUGES


@dataclass(frozen=True)
class Request:
    """One inference request as it rides through the queue."""
    rid: Any                      # caller-visible id (results key)
    prompt: Tuple[int, ...]       # token ids
    max_new_tokens: int = 16      # stop length, counting the prefill token

    @classmethod
    def from_item(cls, task_id: int, item: Any, *,
                  default_max_new: int = 16) -> "Request":
        """Adapt a queue item: a Request passes through, a dict with
        {"id", "prompt"[, "max_new_tokens"]} is wrapped."""
        if isinstance(item, Request):
            return item
        return cls(rid=item.get("id", task_id),
                   prompt=tuple(item["prompt"]),
                   max_new_tokens=int(item.get("max_new_tokens",
                                               default_max_new)))


@dataclass
class Slot:
    """One decode slot: cache row ``index`` plus its request bookkeeping."""
    index: int
    task_id: Optional[int] = None
    request: Optional[Request] = None
    pos: int = 0                      # cache position the next token writes
    tokens: List[int] = field(default_factory=list)
    replay: List[int] = field(default_factory=list)  # prompt suffix to feed
    enqueued_at: float = 0.0          # queue submission time (queue clock)
    admitted_at: float = 0.0          # lease time
    first_token_at: Optional[float] = None
    lease_renewed_at: float = 0.0

    @property
    def free(self) -> bool:
        return self.request is None

    @property
    def done(self) -> bool:
        return (self.request is not None and not self.replay
                and len(self.tokens) >= self.request.max_new_tokens)

    def clear(self) -> None:
        self.task_id = None
        self.request = None
        self.pos = 0
        self.tokens = []
        self.replay = []
        self.first_token_at = None


class ContinuousScheduler:
    """Admission / eviction / lease policy for a fixed pool of decode slots.

    Parameters
    ----------
    queue:
        The WorkQueue requests arrive on (the paper's Redis job queue).
    num_slots:
        Size of the decode-slot pool == batch dim of the slotted cache.
    worker:
        Lease owner name reported to the queue.
    registry:
        Metrics sink; a fresh Registry if omitted.
    clock:
        Monotonic-time source.  Inject a fake for deterministic tests.
    renew_fraction:
        Heartbeat leases once ``renew_fraction * queue.lease_timeout``
        has elapsed since the last renewal (0.5 => renew at half-life).
    default_max_new:
        Stop length for queue items that don't carry their own.
    """

    def __init__(self, queue: WorkQueue, num_slots: int, *,
                 worker: str = "server", registry: Optional[Registry] = None,
                 clock=time.monotonic, renew_fraction: float = 0.5,
                 default_max_new: int = 16):
        if num_slots < 1:
            raise ValueError("need at least one decode slot")
        self.queue = queue
        self.slots = [Slot(i) for i in range(num_slots)]
        self.worker = worker
        self.metrics = registry if registry is not None else Registry()
        self._clock = clock
        self._renew_after = queue.lease_timeout * renew_fraction
        self._default_max_new = default_max_new
        self._results: Dict[Any, List[int]] = {}
        self.useful_tokens = 0        # acked completions only
        self.stale_tokens = 0         # duplicated work (lease expired)
        # Optional hook fired with (slot, reason) just before a slot is
        # cleared; reason in {"completed", "lease_lost", "released"}.
        # The paged engine frees/caches the slot's KV blocks here without
        # the scheduler knowing anything about paging.
        self.on_release = None

    # ------------------------------------------------------------ admission
    def admit(self) -> List[Slot]:
        """Lease queued requests into free slots (FIFO).  Returns the newly
        filled slots; the engine must prefill each and call ``start()``."""
        filled = []
        for slot in self.slots:
            if not slot.free:
                continue
            got = self.queue.lease(self.worker)
            if got is None:
                break
            tid, item = got
            now = self._clock()
            slot.task_id = tid
            slot.request = Request.from_item(
                tid, item, default_max_new=self._default_max_new)
            slot.pos = 0
            slot.tokens = []
            slot.replay = []
            slot.enqueued_at = self.queue.enqueued_at(tid)
            slot.admitted_at = now
            slot.lease_renewed_at = now
            slot.first_token_at = None
            self.metrics.inc(GAUGES.ADMITTED)
            filled.append(slot)
        # backlog after admission — the autoscaler's primary signal
        self.metrics.gauge(GAUGES.QUEUE_DEPTH, self.queue.pending)
        return filled

    def start(self, slot: Slot, first_token: int, prompt_pos: int
              ) -> List[Tuple[Any, List[int]]]:
        """Record a finished prefill: the first generated token and the cache
        position it will be written at by the next decode step.  A request
        whose stop length is 1 completes here; returns completions."""
        slot.tokens.append(int(first_token))
        slot.pos = int(prompt_pos)
        slot.first_token_at = self._clock()
        # user-visible TTFT includes queue wait (enqueue -> first token);
        # admit -> first token stays visible as the service-time gauge.
        self.metrics.gauge(GAUGES.TTFT_S,
                           slot.first_token_at - slot.enqueued_at)
        self.metrics.gauge(GAUGES.SERVICE_TTFT_S,
                           slot.first_token_at - slot.admitted_at)
        return self._evict_finished([slot])

    def start_replay(self, slot: Slot, suffix: Sequence[int],
                     start_pos: int) -> None:
        """Prefix-cache hit path: the slot's shared prompt blocks are
        already in the pool, so instead of a full prefill the engine feeds
        the non-shared prompt *suffix* through the fused decode step, one
        token per iteration (chunked prefill).  The slot emits nothing
        until the replay drains; the step that consumes the last prompt
        token produces the request's first generated token."""
        if not suffix:
            raise ValueError("replay suffix must be non-empty")
        slot.replay = [int(t) for t in suffix]
        slot.pos = int(start_pos)

    # --------------------------------------------------------- decode step
    def active(self) -> List[Slot]:
        return [s for s in self.slots if not s.free]

    @property
    def occupancy(self) -> int:
        return sum(1 for s in self.slots if not s.free)

    def positions(self) -> List[int]:
        """Per-slot cache write positions for the fused decode step (free
        slots report 0 — their writes land in a region the next prefill
        overwrites, and their tokens are never observed)."""
        return [s.pos for s in self.slots]

    def last_tokens(self) -> List[int]:
        """Per-slot next decode input: the head of a replaying slot's
        prompt suffix, else the last generated token (0 if free)."""
        out = []
        for s in self.slots:
            if s.free:
                out.append(0)
            elif s.replay:
                out.append(s.replay[0])
            else:
                out.append(s.tokens[-1] if s.tokens else 0)
        return out

    def observe(self, step_tokens: Sequence[int]
                ) -> List[Tuple[Any, List[int]]]:
        """Record one fused decode step.  ``step_tokens[i]`` is slot i's new
        token (entries for free slots are ignored).  Advances positions,
        evicts every slot that reached its stop length, acks the queue, and
        returns the completed ``(rid, tokens)`` pairs."""
        if len(step_tokens) != len(self.slots):
            raise ValueError(
                f"expected {len(self.slots)} tokens, got {len(step_tokens)}")
        self.metrics.gauge(GAUGES.SLOT_OCCUPANCY, self.occupancy)
        self.metrics.inc(GAUGES.DECODE_STEPS)
        stepped = []
        for slot, tok in zip(self.slots, step_tokens):
            if slot.free:
                continue
            if slot.replay:
                # chunked-prefill replay: the step consumed one prompt
                # token; its output is discarded unless the replay just
                # drained, in which case it is the first generated token.
                slot.replay.pop(0)
                slot.pos += 1
                if slot.replay:
                    continue
                slot.tokens.append(int(tok))
                now = self._clock()
                slot.first_token_at = now
                self.metrics.gauge(GAUGES.TTFT_S, now - slot.enqueued_at)
                self.metrics.gauge(GAUGES.SERVICE_TTFT_S,
                                   now - slot.admitted_at)
                stepped.append(slot)
                continue
            slot.tokens.append(int(tok))
            slot.pos += 1
            stepped.append(slot)
        return self._evict_finished(stepped)

    def _evict_finished(self, slots: Sequence[Slot]
                        ) -> List[Tuple[Any, List[int]]]:
        done = []
        now = self._clock()
        for slot in slots:
            if not slot.done:
                continue
            req = slot.request
            self._results[req.rid] = list(slot.tokens)
            if self.queue.ack(slot.task_id, self.worker):
                self.metrics.inc(GAUGES.COMPLETED)
                self.metrics.inc(GAUGES.TOKENS, len(slot.tokens))
                self.useful_tokens += len(slot.tokens)
            else:
                # lease expired mid-flight and the task was reclaimed;
                # at-least-once semantics: our result stands, but the
                # tokens are duplicated work — they must not count as
                # useful throughput (they'd inflate tok/s exactly when
                # the autoscaler is deciding off it).
                self.metrics.inc(GAUGES.STALE_ACK)
                self.metrics.inc(GAUGES.STALE_TOKENS, len(slot.tokens))
                self.stale_tokens += len(slot.tokens)
            self.metrics.gauge(GAUGES.LATENCY_S,
                               now - slot.enqueued_at)
            done.append((req.rid, list(slot.tokens)))
            self._release(slot, "completed")
        return done

    # -------------------------------------------------------------- leases
    def renew_leases(self) -> int:
        """Heartbeat the visibility timeout of every active slot that is
        past its renewal half-life.  A slot whose lease was already lost is
        dropped un-acked (the queue will re-serve the request).  Returns
        the number of successful renewals."""
        now = self._clock()
        renewed = 0
        for slot in self.slots:
            if slot.free or now - slot.lease_renewed_at < self._renew_after:
                continue
            if self.queue.renew(slot.task_id, self.worker):
                slot.lease_renewed_at = now
                self.metrics.inc(GAUGES.LEASE_RENEWALS)
                renewed += 1
            else:
                self.metrics.inc(GAUGES.LEASE_LOST)
                self._release(slot, "lease_lost")
        return renewed

    def _release(self, slot: Slot, reason: str) -> None:
        if self.on_release is not None:
            self.on_release(slot, reason)
        slot.clear()

    def release_slot(self, slot: Slot) -> bool:
        """Return a slot's request to the queue un-acked (nack) and free
        the slot — cooperative stop and pool-exhaustion preemption.  The
        request requeues immediately, so a replacement engine re-serves it
        after one decode step instead of one visibility timeout."""
        if slot.free:
            return False
        ok = self.queue.nack(slot.task_id, self.worker)
        self.metrics.inc(GAUGES.PREEMPTED)
        self._release(slot, "released")
        return ok

    def release_all(self) -> int:
        """Nack every in-flight slot (cooperative-stop teardown)."""
        n = 0
        for slot in self.slots:
            if not slot.free:
                self.release_slot(slot)
                n += 1
        return n

    # ------------------------------------------------------------- results
    def finished(self) -> bool:
        """True once every slot is free and the queue has fully drained."""
        return self.occupancy == 0 and self.queue.drained()

    def results(self) -> Dict[Any, List[int]]:
        return dict(self._results)
