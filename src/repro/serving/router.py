"""Multi-replica serving: session-affine router + HPA-style autoscaler.

One continuous-batching engine saturates at ``num_slots`` concurrent
requests; internet-scale traffic needs N of them.  This module runs N
replicas in-process (each an engine thread draining its own WorkQueue),
routes incoming requests across them, and scales N between
``min_replicas``/``max_replicas`` off the same queue-depth and
latency-percentile gauges the engines already record — the serving-side
analogue of Kubernetes' HorizontalPodAutoscaler over the paper's
Redis-queue/GPU-pod fan-out.

Routing policy: session affinity first (an item's ``"session"`` key pins
it to the replica that served the session before — that replica's prefix
cache already holds the session's prompt blocks), least-loaded otherwise.

Scale-down is cooperative and loss-free: the retired replica's
``should_stop`` flips, its engine nacks in-flight slots on the next step
boundary (bounded by ONE decode step, not a visibility timeout), and the
router drains its queue back through ``submit`` — preserving each
request's original enqueue time so TTFT keeps charging the full wait.

Replica lifecycle events surface through ``on_scale(desired, observed,
reason)``; the ServeJob runner forwards them as ``replicas:
desired→observed`` Handle transitions (api/runners.py).
"""
from __future__ import annotations

import math
import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

from repro.core.metrics import Registry
from repro.core.queue import WorkQueue
from repro.serving.report import GAUGES, record_serving_totals


@dataclass
class Replica:
    """One engine behind the router: its queue, thread and stop flag."""
    name: str
    queue: WorkQueue
    stop: threading.Event = field(default_factory=threading.Event)
    thread: Optional[threading.Thread] = None
    engine: Any = None

    @property
    def load(self) -> int:
        return self.queue.pending + self.queue.leased


class ReplicaSet:
    """N live engine replicas + routing + loss-free scale up/down.

    ``engine_factory(name, registry)`` must return an object with
    ``run(queue, worker=..., should_stop=..., exit_on_drain=False)``
    returning ``(results, metrics)`` — a ServingEngine, or a fake in
    tests.  All replicas share one Registry, so the serve gauges
    aggregate across the fleet.
    """

    def __init__(self, engine_factory: Callable[[str, Registry], Any], *,
                 lease_timeout: float = 30.0,
                 registry: Optional[Registry] = None,
                 clock: Callable[[], float] = time.monotonic,
                 affinity_key: str = "session",
                 on_scale: Optional[Callable[[int, int, str], None]] = None,
                 capacity: Optional[Callable[[int], int]] = None):
        self.engine_factory = engine_factory
        self.lease_timeout = lease_timeout
        self.metrics = registry if registry is not None else Registry()
        self.clock = clock
        self.affinity_key = affinity_key
        self.on_scale = on_scale
        # capacity(desired) -> granted: a fair-share adapter (e.g.
        # FairShareScheduler.resize_claim) that bounds scale-up by the
        # tenant's share; scale-down always proceeds and returns devices
        self.capacity = capacity
        self._lock = threading.Lock()
        self._replicas: List[Replica] = []
        self._retired: List[Replica] = []
        self._affinity: Dict[Any, str] = {}
        self._results: Dict[Any, list] = {}
        self._next = 0
        self.scale_events: List[Tuple[float, int, int, str]] = []

    # ------------------------------------------------------------- replicas
    def observed(self) -> int:
        with self._lock:
            return len(self._replicas)

    def total_backlog(self) -> int:
        with self._lock:
            return sum(r.load for r in self._replicas)

    def _spawn(self) -> Replica:
        name = f"replica-{self._next}"
        self._next += 1
        rep = Replica(name, WorkQueue(lease_timeout=self.lease_timeout,
                                      clock=self.clock))

        def serve():
            engine = self.engine_factory(name, self.metrics)
            rep.engine = engine
            results, _ = engine.run(rep.queue, worker=name,
                                    should_stop=rep.stop.is_set,
                                    exit_on_drain=False)
            with self._lock:
                self._results.update(results)

        rep.thread = threading.Thread(target=serve, name=name, daemon=True)
        rep.thread.start()
        return rep

    def scale_to(self, n: int, reason: str = "manual") -> None:
        """Start or cooperatively retire replicas until ``observed == n``.
        Retiring drains the replica's queue back through the router with
        original enqueue times preserved."""
        n = max(0, n)
        if self.capacity is not None:
            n = min(n, max(0, self.capacity(n))) if n > 0 else n
        with self._lock:
            desired, observed = n, len(self._replicas)
        if desired == observed:
            return
        self.scale_events.append((self.clock(), observed, desired, reason))
        self.metrics.inc(GAUGES.SCALE_EVENTS)
        while self.observed() < desired:
            rep = self._spawn()
            with self._lock:
                self._replicas.append(rep)
        retired = []
        with self._lock:
            while len(self._replicas) > desired:
                retired.append(self._replicas.pop())   # youngest first
        for rep in retired:
            self._retire(rep)
        self.metrics.gauge(GAUGES.REPLICAS, self.observed())
        if self.on_scale is not None:
            self.on_scale(desired, self.observed(), reason)

    def _retire(self, rep: Replica) -> None:
        rep.stop.set()
        if rep.thread is not None:
            rep.thread.join()
        # the engine nacked its in-flight slots on the way out; everything
        # left in the queue re-routes to the survivors
        while True:
            got = rep.queue.lease("__drain__")
            if got is None:
                break
            tid, item = got
            t0 = rep.queue.enqueued_at(tid)
            rep.queue.ack(tid, "__drain__")
            if self.observed():
                self.submit(item, enqueued_at=t0)
        with self._lock:
            self._retired.append(rep)

    # --------------------------------------------------------------- routing
    def submit(self, item: Any, *,
               enqueued_at: Optional[float] = None) -> Optional[str]:
        """Route one request: session affinity first (the pinned replica's
        prefix cache is warm for this session), least-loaded otherwise.
        Returns the chosen replica name (None if no replicas are live)."""
        session = item.get(self.affinity_key) if isinstance(item, dict) \
            else None
        with self._lock:
            if not self._replicas:
                return None
            target = None
            if session is not None:
                pinned = self._affinity.get(session)
                target = next((r for r in self._replicas
                               if r.name == pinned), None)
            if target is None:
                target = min(self._replicas, key=lambda r: r.load)
            if session is not None:
                self._affinity[session] = target.name
            target.queue.put(item, enqueued_at=enqueued_at)
            return target.name

    # ------------------------------------------------------------- shutdown
    def stop_all(self) -> Dict[Any, list]:
        """Cooperatively stop every replica and return merged results."""
        self.scale_to(0, reason="shutdown")
        with self._lock:
            return dict(self._results)

    def completed(self) -> float:
        return self.metrics.series(GAUGES.COMPLETED).total


class Autoscaler:
    """HPA-style reconciler: desired replicas from queue backlog and the
    p99 service-TTFT gauge, clamped to [min_replicas, max_replicas].

    ``target_backlog`` is the per-replica queue depth the fleet should
    hold (the HPA's target metric value); breaching ``ttft_slo_s`` at p99
    forces a scale-up by one even when the backlog looks fine — latency
    is the user-facing signal, depth the leading one."""

    def __init__(self, rset: ReplicaSet, *, min_replicas: int = 1,
                 max_replicas: int = 4, target_backlog: float = 4.0,
                 ttft_slo_s: Optional[float] = None):
        if not (1 <= min_replicas <= max_replicas):
            raise ValueError("need 1 <= min_replicas <= max_replicas")
        self.rset = rset
        self.min_replicas = min_replicas
        self.max_replicas = max_replicas
        self.target_backlog = target_backlog
        self.ttft_slo_s = ttft_slo_s

    def recommend(self) -> int:
        backlog = self.rset.total_backlog()
        want = max(1, math.ceil(backlog / self.target_backlog))
        if self.ttft_slo_s is not None:
            p99 = self.rset.metrics.series(
                GAUGES.SERVICE_TTFT_S).percentile(99)
            if p99 > self.ttft_slo_s:    # 0.0 (never recorded) never trips
                want = max(want, self.rset.observed() + 1)
        return min(max(want, self.min_replicas), self.max_replicas)

    def step(self, reason: str = "reconcile") -> Optional[Tuple[int, int]]:
        """One reconcile tick: returns (observed, desired) when it acted,
        None when the fleet is already at the recommendation."""
        desired = self.recommend()
        observed = self.rset.observed()
        if desired == observed:
            return None
        self.rset.scale_to(desired, reason=reason)
        return observed, desired


def serve_replicated(engine_factory, requests, *, min_replicas: int = 1,
                     max_replicas: int = 2, target_backlog: float = 4.0,
                     ttft_slo_s: Optional[float] = None,
                     lease_timeout: float = 30.0,
                     registry: Optional[Registry] = None,
                     clock: Callable[[], float] = time.monotonic,
                     reconcile_interval: float = 0.02,
                     timeout_s: float = 600.0,
                     on_scale=None,
                     should_stop: Optional[Callable[[], bool]] = None,
                     capacity: Optional[Callable[[int], int]] = None):
    """Serve ``requests`` through an autoscaled replica fleet.

    Submits everything up front (the queue-depth signal the autoscaler
    feeds on IS the arrival burst), reconciles until every request has
    been served+acked exactly once, then retires the fleet.  Returns
    ``(results, metrics, scale_events)``.
    """
    metrics = registry if registry is not None else Registry()
    rset = ReplicaSet(engine_factory, lease_timeout=lease_timeout,
                      registry=metrics, clock=clock, on_scale=on_scale,
                      capacity=capacity)
    rset.scale_to(min_replicas, reason="startup")
    scaler = Autoscaler(rset, min_replicas=min_replicas,
                        max_replicas=max_replicas,
                        target_backlog=target_backlog,
                        ttft_slo_s=ttft_slo_s)
    t_start = clock()
    n = 0
    for item in requests:
        rset.submit(item)
        n += 1
    while rset.completed() < n:
        if clock() - t_start > timeout_s:
            break
        if should_stop is not None and should_stop():
            break
        scaler.step()
        time.sleep(reconcile_interval)
    results = rset.stop_all()
    wall = clock() - t_start
    # fleet-level totals overwrite the per-engine records: useful tokens
    # are the acked-only counter aggregated across every replica
    record_serving_totals(metrics, int(metrics.series(GAUGES.TOKENS).total),
                          wall, 0.0)
    return results, metrics, list(rset.scale_events)
