"""Continuous-batching distributed inference (paper §III/§V serving step).

Split host/device: ``scheduler`` is the deterministic slot/lease policy
(no jax — testable with a fake clock), ``engine`` owns the jitted prefill,
the KV cache (slotted, or a paged block pool with radix-style prefix
reuse — ``pool``) and the fused per-slot decode step, ``router`` runs N
replicas behind a session-affine load-aware router with an HPA-style
autoscaler, ``report`` holds the ``serve/*`` gauge namespace, synthetic
request streams and the Table-I row.  ``repro.launch.serve`` is the CLI
driver; docs/serving.md is the usage guide.
"""
from repro.serving.engine import ServingEngine
from repro.serving.pool import BlockPool
from repro.serving.report import (GAUGES, make_requests, record_serving_totals,
                                  request_queue, serving_report,
                                  serving_summary)
from repro.serving.router import (Autoscaler, Replica, ReplicaSet,
                                  serve_replicated)
from repro.serving.scheduler import ContinuousScheduler, Request, Slot

__all__ = ["ServingEngine", "ContinuousScheduler", "Request", "Slot",
           "BlockPool", "Autoscaler", "Replica", "ReplicaSet",
           "serve_replicated",
           "GAUGES", "make_requests", "record_serving_totals",
           "request_queue", "serving_report", "serving_summary"]
