"""Serving metric names, synthetic request streams and the Table-I row.

One place resolves the ``serve/*`` gauge names: the engine, the
scheduler, the static-batcher baseline and the report below all import
``GAUGES`` instead of re-spelling the strings (the old launcher had
three private copies that had already started to drift).  The report is
total-tolerant: a run that never recorded a stat (e.g. a smoke serve
with zero completed requests) still renders a row of zeros instead of
raising.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core.metrics import Registry, StepReport


class GAUGES:
    """The serving metric namespace (see docs/serving.md for semantics)."""
    ADMITTED = "serve/admitted"
    COMPLETED = "serve/completed"
    TOKENS = "serve/tokens_generated"
    DECODE_STEPS = "serve/decode_steps"
    SLOT_OCCUPANCY = "serve/slot_occupancy"
    TTFT_S = "serve/ttft_s"
    SERVICE_TTFT_S = "serve/service_ttft_s"
    LATENCY_S = "serve/request_latency_s"
    QUEUE_DEPTH = "serve/queue_depth"
    LEASE_RENEWALS = "serve/lease_renewals"
    LEASE_LOST = "serve/lease_lost"
    STALE_ACK = "serve/stale_ack"
    STALE_TOKENS = "serve/stale_tokens"
    PREFILL_S = "serve/prefill_s"
    PREEMPTED = "serve/preempted"
    WALL_S = "serve/wall_s"
    TOK_S = "serve/tok_s"
    DECODE_TOK_S = "serve/decode_tok_s"
    PREFIX_HITS = "serve/prefix_hits"
    PREFIX_MISSES = "serve/prefix_misses"
    PREFIX_BYTES_SAVED = "serve/prefix_bytes_saved"
    BLOCKS_IN_USE = "serve/blocks_in_use"
    REPLICAS = "serve/replicas"
    SCALE_EVENTS = "serve/scale_events"


def make_requests(n_requests: int, prompt_len: int, gen: int, *,
                  vocab_size: int, seed: int = 0,
                  gen_lens: Optional[Sequence[int]] = None) -> List[dict]:
    """Synthetic request stream: random prompts, per-request stop lengths.
    ``gen_lens`` (cycled) gives a heterogeneous workload; default is the
    uniform ``gen`` every request."""
    rng = np.random.RandomState(seed)
    out = []
    for i in range(n_requests):
        g = gen if gen_lens is None else int(gen_lens[i % len(gen_lens)])
        out.append({"id": i,
                    "prompt": rng.randint(1, vocab_size, prompt_len).tolist(),
                    "max_new_tokens": g})
    return out


def request_queue(requests, cfg, *, n_requests, prompt_len, gen, seed,
                  gen_lens, lease_timeout):
    """A WorkQueue over explicit ``requests``, or a synthetic stream."""
    from repro.core.queue import WorkQueue
    if requests is None:
        requests = make_requests(n_requests, prompt_len, gen,
                                 vocab_size=cfg.vocab_size, seed=seed,
                                 gen_lens=gen_lens)
    return WorkQueue(requests, lease_timeout=lease_timeout)


def record_serving_totals(registry: Registry, useful_tokens: int,
                          wall_s: float, decode_s: float) -> None:
    """End-of-run serving gauges, shared by every serving driver so the
    continuous-vs-static benchmark always compares identical accounting:
    wall time, useful tokens/s overall, and decode-only tokens/s (omitted
    when the run never decoded, e.g. stop-length-1 workloads)."""
    registry.gauge(GAUGES.WALL_S, wall_s)
    registry.gauge(GAUGES.TOK_S, useful_tokens / max(wall_s, 1e-9))
    if decode_s > 0:
        registry.gauge(GAUGES.DECODE_TOK_S, useful_tokens / decode_s)


def serving_summary(metrics: Registry) -> Dict[str, Dict[str, float]]:
    """Per-gauge stats with every ``GAUGES`` name present — missing
    (never-recorded) series summarize as all-zero stats, so reports and
    dashboards never KeyError on an idle run."""
    s = metrics.summary()
    zero = {"count": 0, "last": 0.0, "mean": 0.0, "max": 0.0,
            "total": 0.0, "p50": 0.0, "p99": 0.0}
    return {name: s.get(name, dict(zero))
            for attr, name in vars(GAUGES).items()
            if not attr.startswith("_") and isinstance(name, str)}


def serving_report(metrics: Registry, *, step: str = "serve",
                   devices: int = 1) -> StepReport:
    """Fold serve metrics into a paper-Table-I-style report column.

    Tolerates never-recorded stats: a 0-request run reports zeros."""
    s = serving_summary(metrics)

    def g(name, stat="last"):
        return s.get(name, {}).get(stat, 0.0)

    hits = g(GAUGES.PREFIX_HITS, "total")
    misses = g(GAUGES.PREFIX_MISSES, "total")
    return StepReport(
        step=step, pods=1, devices=devices,
        total_time_s=g(GAUGES.WALL_S),
        extra={
            "requests": g(GAUGES.COMPLETED, "total"),
            "tokens": g(GAUGES.TOKENS, "total"),
            "stale tokens": g(GAUGES.STALE_TOKENS, "total"),
            "tokens/s": g(GAUGES.TOK_S),
            "decode tokens/s": g(GAUGES.DECODE_TOK_S),
            "mean slot occupancy": g(GAUGES.SLOT_OCCUPANCY, "mean"),
            "p50 latency (s)": g(GAUGES.LATENCY_S, "p50"),
            "p99 latency (s)": g(GAUGES.LATENCY_S, "p99"),
            "p50 ttft (s)": g(GAUGES.TTFT_S, "p50"),
            "p50 service ttft (s)": g(GAUGES.SERVICE_TTFT_S, "p50"),
            "prefix hit rate": hits / max(hits + misses, 1.0),
        })
