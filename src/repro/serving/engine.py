"""Continuous-batching serving engine (the device side).

The ContinuousScheduler (scheduler.py) decides *which* requests occupy
which decode slots; this engine owns everything jitted:

  * a B=1 prefill step — each admitted request is prefilled alone and its
    prompt-length KV/state cache is spliced into its slot of the big cache
    (runtime.steps.cache_batch_insert, donated so the splice is in-place);
  * one fused per-slot decode step (runtime.steps.build_slot_decode) that
    advances ALL active slots one token per call, each at its own sequence
    position — a request admitted mid-flight rides the very next step;
  * the cache itself — either the classic slotted layout (every cache
    leaf is (layers, slots, ...), slot i = row i of axis 1) or, when the
    model family supports it, a paged block pool (runtime.steps
    ``build_paged_decode``): slots address fixed-size blocks through
    per-slot block tables, a host-side ``BlockPool`` refcounts them, and
    a radix-style prefix cache lets requests sharing a system prompt skip
    re-prefilling shared blocks (the suffix replays through the fused
    decode step — chunked prefill).  Paged decode is bit-identical to the
    slotted baseline (tests/test_serving_paged.py).

Request lifecycle (see docs/architecture.md for the full diagram):

    queue --lease--> slot --prefill+insert--> decode step xN --evict/ack-->
      ^                                                          |
      '----------------- slot freed, next request refills <------'

The engine is deterministic given a queue and a clock; ``smoke``-size
configs run it on CPU in seconds (tests/test_serving.py).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ParallelConfig, ShapeConfig
from repro.core.metrics import Registry
from repro.core.queue import WorkQueue
from repro.serving.report import GAUGES, record_serving_totals
from repro.models import params as pr
from repro.runtime import steps as steps_mod
from repro.serving.pool import BlockPool
from repro.serving.scheduler import ContinuousScheduler, Slot


class ServingEngine:
    """Owns params, jitted steps and the slotted cache for one model.

    Parameters
    ----------
    cfg, par, mesh:
        Model / parallelism config and the device mesh to serve on.
    num_slots:
        Decode-slot pool size == batch dim of the fused decode step.
    prompt_len:
        Fixed prompt pad length.  Prompts shorter than this are padded
        (token id 1), longer ones truncated — one prefill compilation.
    max_new_tokens:
        Cache headroom per slot: a slot can decode at most this many
        tokens (requests asking for more are clamped at admission).
    params:
        Optional pre-initialised params (e.g. restored from a
        checkpoint); randomly initialised from ``seed`` if omitted.
    paged:
        True forces the paged block pool (raises if the model family's
        cache is not paged-able), False forces the slotted cache, None
        (default) auto-selects paged whenever compatible.
    block_size:
        Tokens per KV block; must divide both the padded prompt length
        and the cache length for paged mode.
    pool_blocks:
        Total blocks in the pool (incl. the reserved null block).  The
        default sizes for all slots fully generated plus prefix-cache
        headroom; shrink it to exercise pressure eviction/preemption.
    prefix_cache:
        Enable radix-style prefix reuse across requests (paged only).
    """

    def __init__(self, cfg: ModelConfig, par: ParallelConfig, mesh, *,
                 num_slots: int = 4, prompt_len: int = 32,
                 max_new_tokens: int = 16, seed: int = 0, params=None,
                 registry: Optional[Registry] = None, clock=time.monotonic,
                 paged: Optional[bool] = None, block_size: int = 8,
                 pool_blocks: Optional[int] = None,
                 prefix_cache: bool = True):
        self.mesh = mesh
        self.num_slots = num_slots
        self.max_new_tokens = max_new_tokens
        self.metrics = registry if registry is not None else Registry()
        self.clock = clock

        S = prompt_len + max_new_tokens
        shape = ShapeConfig("serve", S, num_slots, "decode")
        self.cfg = cfg = steps_mod.resolve_cfg(cfg, shape)
        if cfg.family == "audio":
            # enc-dec: the decoder-position table IS the self-attn cache
            # (cache_schema sizes it to decoder_len regardless of S), so
            # prompt + generation must fit inside decoder_len — pad the
            # prompt short enough to leave max_new_tokens of headroom
            self.prompt_pad = max(1, min(prompt_len,
                                         cfg.decoder_len - max_new_tokens))
            self.cache_len = cfg.decoder_len
        else:
            self.prompt_pad = prompt_len
            self.cache_len = S

        mod = steps_mod._model_module(cfg)
        if params is None:
            params = pr.init_params(mod.lm_schema(cfg), jax.random.key(seed),
                                    cfg.param_dtype)
        self.params = params
        prefill_fn = steps_mod.build_prefill(
            cfg, par, mesh, ShapeConfig("serve", S, 1, "prefill")).fn

        # prefill + slot splice + argmax fused into ONE dispatch per
        # admission — admission cost is on the serving critical path
        # (every refill happens between fused decode steps)
        def prefill_insert(params, caches, prompt, slot, *extras):
            last, small = prefill_fn(params, prompt, *extras)
            caches = steps_mod.cache_batch_insert(caches, small, slot)
            return jnp.argmax(last[0], -1).astype(jnp.int32), caches

        self._prefill_insert = jax.jit(prefill_insert, donate_argnums=1)
        ex_abs, _ = steps_mod.extras_specs(cfg, 1)
        self._extras = (({k: jnp.zeros(v.shape, v.dtype)
                          for k, v in ex_abs.items()},) if ex_abs else ())

        compatible = (steps_mod.paged_compatible(cfg, self.cache_len,
                                                 block_size)
                      and self.prompt_pad % block_size == 0
                      and self.prompt_pad >= block_size)
        if paged and not compatible:
            raise ValueError(
                f"{cfg.family} cache cannot be paged with "
                f"block_size={block_size} (prompt_pad={self.prompt_pad}, "
                f"cache_len={self.cache_len})")
        self.paged = compatible if paged is None else bool(paged)
        self.block_size = block_size
        self.prefix_cache = bool(prefix_cache) and self.paged

        if self.paged:
            nb_total = self.cache_len // block_size
            nb_prompt = self.prompt_pad // block_size
            if pool_blocks is None:
                # all slots fully generated + prefix-cache headroom + null
                pool_blocks = 1 + num_slots * nb_total + 2 * nb_prompt
            if pool_blocks < 1 + nb_prompt + 1:
                raise ValueError(
                    f"pool_blocks={pool_blocks} cannot admit one request "
                    f"(needs {nb_prompt} prompt blocks + 1 gen + null)")
            self._nb_total = nb_total
            self._nb_prompt = nb_prompt
            self._pool = steps_mod.init_paged_cache(cfg, pool_blocks,
                                                    block_size)
            self._tables = np.zeros((num_slots, nb_total), np.int32)
            bytes_per_block = int(sum(
                leaf.size // pool_blocks * leaf.dtype.itemsize
                for leaf in jax.tree.leaves(self._pool)))
            self.block_pool = BlockPool(pool_blocks, block_size,
                                        bytes_per_block=bytes_per_block,
                                        registry=self.metrics)
            self._slot_meta = [None] * num_slots

            def paged_prefill_insert(params, pool, prompt, blocks, *extras):
                last, small = prefill_fn(params, prompt, *extras)
                pool = steps_mod.paged_prompt_insert(pool, small, blocks)
                return jnp.argmax(last[0], -1).astype(jnp.int32), pool

            self._paged_prefill = jax.jit(paged_prefill_insert,
                                          donate_argnums=1)
            self._paged_decode = steps_mod.build_paged_decode(
                cfg, par, mesh, shape, block_size=block_size,
                num_blocks=pool_blocks).jit()
            self._caches = None
        else:
            self.block_pool = None
            self._decode = steps_mod.build_slot_decode(
                cfg, par, mesh, shape).jit()
            self._caches = steps_mod.init_cache(cfg, num_slots, S)

    # ----------------------------------------------------------- jit steps
    def _pad_prompt(self, prompt) -> np.ndarray:
        row = np.ones((1, self.prompt_pad), np.int32)
        toks = list(prompt)[:self.prompt_pad]
        row[0, :len(toks)] = toks
        return row

    def prefill_into(self, slot_index: int, prompt) -> int:
        """Prefill one request alone and splice its cache into the slot
        (slotted) or its prompt blocks (paged).  Returns the first
        generated token."""
        t0 = self.clock()
        if self.paged:
            blocks = self._tables[slot_index, :self._nb_prompt]
            first, self._pool = self._paged_prefill(
                self.params, self._pool,
                jnp.asarray(self._pad_prompt(prompt)),
                jnp.asarray(blocks), *self._extras)
        else:
            first, self._caches = self._prefill_insert(
                self.params, self._caches,
                jnp.asarray(self._pad_prompt(prompt)), jnp.int32(slot_index),
                *self._extras)
        first = int(first)
        self.metrics.gauge(GAUGES.PREFILL_S, self.clock() - t0)
        return first

    def decode_step(self, tokens, positions) -> np.ndarray:
        """One fused greedy step over all slots.  ``tokens``/``positions``
        are per-slot (num_slots,) host lists; returns the new tokens."""
        tok = jnp.asarray(np.asarray(tokens, np.int32)[:, None])
        pos = jnp.asarray(np.asarray(positions, np.int32))
        if self.paged:
            out, self._pool = self._paged_decode(
                self.params, self._pool, jnp.asarray(self._tables), tok, pos)
        else:
            out, self._caches = self._decode(self.params, self._caches,
                                             tok, pos)
        return np.asarray(out)[:, 0]

    def warmup(self) -> None:
        """Compile the jitted paths (prefill+insert, decode) off the
        clock.  Two rounds: the first insert sees the freshly allocated
        (uncommitted) cache/pool, every later one sees a jit-output
        array — a different sharding signature, so one round would leave
        the second compile on the serving clock.  Paged warmup writes
        into blocks 1..nb_prompt with all-null tables — content that is
        either overwritten by the block's first owner or masked."""
        if self.paged:
            self._tables[0, :self._nb_prompt] = np.arange(
                1, 1 + self._nb_prompt)
        for _ in range(2):
            self.prefill_into(0, [1] * self.prompt_pad)
            self.decode_step([0] * self.num_slots, [0] * self.num_slots)
        if self.paged:
            self._tables[0] = 0

    # ------------------------------------------------------- paged plumbing
    def _admit_paged(self, sched: ContinuousScheduler, slot: Slot) -> bool:
        """Allocate blocks for an admitted request: retain cached shared
        prefix blocks, alloc fresh ones for the rest of the prompt plus
        the first generation block.  On a prefix hit the non-shared
        suffix replays through the fused decode step instead of a full
        prefill.  Returns False (and nacks the request) when the pool
        cannot satisfy the admission."""
        row = self._pad_prompt(slot.request.prompt)[0].tolist()
        shared = []
        if self.prefix_cache:
            # cap the shared prefix one block short of the full prompt so
            # the replay suffix is never empty — and so shared blocks are
            # strictly before every write position (no copy-on-write)
            shared = self.block_pool.match(
                row, max_blocks=self._nb_prompt - 1)
        fresh = self.block_pool.alloc(self._nb_prompt - len(shared) + 1)
        if fresh is None:
            self.block_pool.release(shared)
            sched.release_slot(slot)     # nack: retry when capacity frees
            return False
        blocks = shared + fresh
        self._slot_meta[slot.index] = {
            "blocks": blocks, "n_prompt": self._nb_prompt, "prompt": row}
        trow = np.zeros(self._nb_total, np.int32)
        trow[:len(blocks)] = blocks
        self._tables[slot.index] = trow
        if shared:
            sched.start_replay(slot, row[len(shared) * self.block_size:],
                               len(shared) * self.block_size)
        else:
            first = self.prefill_into(slot.index, slot.request.prompt)
            sched.start(slot, first, self.prompt_pad)
        return True

    def _ensure_paged_capacity(self, sched: ContinuousScheduler) -> None:
        """Lazily allocate each active slot's next generation block at a
        block boundary; under pool exhaustion preempt the *youngest* slot
        (nack — the request requeues) until the write fits."""
        for slot in sorted(sched.active(), key=lambda s: s.admitted_at):
            if slot.free:               # preempted earlier in this sweep
                continue
            bi = slot.pos // self.block_size
            if bi >= self._nb_total or self._tables[slot.index, bi] != 0:
                continue
            got = self.block_pool.alloc(1)
            while got is None:
                victims = [s for s in sched.active() if s is not slot]
                if not victims:
                    break
                sched.release_slot(max(victims, key=lambda s: s.admitted_at))
                got = self.block_pool.alloc(1)
            if got is None:
                sched.release_slot(slot)   # lone slot starved: requeue it
                continue
            self._tables[slot.index, bi] = got[0]
            self._slot_meta[slot.index]["blocks"].append(got[0])

    def _on_slot_release(self, slot: Slot, reason: str) -> None:
        """Scheduler release hook: free the slot's blocks; a completed
        request's prompt blocks go into the prefix cache first."""
        meta = self._slot_meta[slot.index]
        if meta is None:
            return
        self._slot_meta[slot.index] = None
        if reason == "completed" and self.prefix_cache:
            self.block_pool.cache_prefix(meta["prompt"],
                                         meta["blocks"][:meta["n_prompt"]])
        self.block_pool.release(meta["blocks"])
        self._tables[slot.index] = 0

    # ----------------------------------------------------------- main loop
    def run(self, queue: WorkQueue, *, worker: str = "server",
            default_max_new: Optional[int] = None, idle_wait: float = 1e-3,
            should_stop=None, exit_on_drain: bool = True
            ) -> Tuple[Dict[Any, list], Registry]:
        """Serve the queue to exhaustion with continuous batching.

        Admission, eviction and lease heartbeats happen between fused
        decode steps; a request that finishes early frees its slot for the
        next queued request immediately (no drain-then-refill barrier).
        Returns ``(results, metrics)`` with ``results[rid]`` the generated
        tokens (length == the request's stop length).

        ``should_stop`` (a zero-arg callable, e.g. ``PodCtx.should_stop``
        when the engine runs as a preemptible tenant pod under
        repro.vcluster) is polled between fused steps: when it goes true
        the loop nacks every in-flight request back to the queue and
        exits cleanly, so a re-placed engine resumes them immediately
        instead of waiting out the visibility timeout.
        """
        cap = self.cache_len - self.prompt_pad
        sched = ContinuousScheduler(
            queue, self.num_slots, worker=worker, registry=self.metrics,
            clock=self.clock,
            default_max_new=min(default_max_new or self.max_new_tokens, cap))
        if self.paged:
            sched.on_release = self._on_slot_release
        t_start = self.clock()
        decode_s = 0.0
        with self.mesh:
            while True:
                if should_stop is not None and should_stop():
                    # preempted between steps: nack every in-flight slot
                    # so a replacement engine re-serves them after one
                    # decode step, not one visibility timeout
                    self.metrics.inc(GAUGES.PREEMPTED)
                    sched.release_all()
                    break
                for slot in sched.admit():
                    # engine capacity bounds the stop length: past
                    # prompt_pad+cap the cache has no row to write
                    if slot.request.max_new_tokens > cap:
                        slot.request = dataclasses.replace(
                            slot.request, max_new_tokens=cap)
                    if self.paged:
                        self._admit_paged(sched, slot)
                    else:
                        first = self.prefill_into(slot.index,
                                                  slot.request.prompt)
                        sched.start(slot, first, self.prompt_pad)
                if not sched.active():
                    if sched.finished() and exit_on_drain:
                        break
                    # queue momentarily empty — a long-lived replica
                    # (exit_on_drain=False) idles here until its router
                    # feeds it more work or stops it
                    time.sleep(idle_wait)
                    continue
                if self.paged:
                    self._ensure_paged_capacity(sched)
                    if not sched.active():
                        continue
                t0 = self.clock()
                toks = self.decode_step(sched.last_tokens(),
                                        sched.positions())
                decode_s += self.clock() - t0
                sched.observe(toks)
                sched.renew_leases()
        wall = self.clock() - t_start
        results = sched.results()
        # useful throughput counts only acked completions; a stale-acked
        # duplicate's tokens are surfaced separately (serve/stale_tokens)
        record_serving_totals(self.metrics, sched.useful_tokens,
                              wall, decode_s)
        return results, self.metrics
