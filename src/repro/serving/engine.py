"""Continuous-batching serving engine (the device side).

The ContinuousScheduler (scheduler.py) decides *which* requests occupy
which decode slots; this engine owns everything jitted:

  * a B=1 prefill step — each admitted request is prefilled alone and its
    prompt-length KV/state cache is spliced into its slot of the big cache
    (runtime.steps.cache_batch_insert, donated so the splice is in-place);
  * one fused per-slot decode step (runtime.steps.build_slot_decode) that
    advances ALL active slots one token per call, each at its own sequence
    position — a request admitted mid-flight rides the very next step;
  * the slotted cache itself: every cache leaf is (layers, slots, ...), so
    slot i is row i of axis 1 across attention K/V, mamba conv/state and
    encdec caches alike.

Request lifecycle (see docs/architecture.md for the full diagram):

    queue --lease--> slot --prefill+insert--> decode step xN --evict/ack-->
      ^                                                          |
      '----------------- slot freed, next request refills <------'

The engine is deterministic given a queue and a clock; ``smoke``-size
configs run it on CPU in seconds (tests/test_serving.py).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ParallelConfig, ShapeConfig
from repro.core.metrics import Registry
from repro.core.queue import WorkQueue
from repro.serving.report import GAUGES, record_serving_totals
from repro.models import params as pr
from repro.runtime import steps as steps_mod
from repro.serving.scheduler import ContinuousScheduler


class ServingEngine:
    """Owns params, jitted steps and the slotted cache for one model.

    Parameters
    ----------
    cfg, par, mesh:
        Model / parallelism config and the device mesh to serve on.
    num_slots:
        Decode-slot pool size == batch dim of the fused decode step.
    prompt_len:
        Fixed prompt pad length.  Prompts shorter than this are padded
        (token id 1), longer ones truncated — one prefill compilation.
    max_new_tokens:
        Cache headroom per slot: a slot can decode at most this many
        tokens (requests asking for more are clamped at admission).
    params:
        Optional pre-initialised params (e.g. restored from a
        checkpoint); randomly initialised from ``seed`` if omitted.
    """

    def __init__(self, cfg: ModelConfig, par: ParallelConfig, mesh, *,
                 num_slots: int = 4, prompt_len: int = 32,
                 max_new_tokens: int = 16, seed: int = 0, params=None,
                 registry: Optional[Registry] = None, clock=time.monotonic):
        self.mesh = mesh
        self.num_slots = num_slots
        self.max_new_tokens = max_new_tokens
        self.metrics = registry if registry is not None else Registry()
        self.clock = clock

        S = prompt_len + max_new_tokens
        shape = ShapeConfig("serve", S, num_slots, "decode")
        self.cfg = cfg = steps_mod.resolve_cfg(cfg, shape)
        if cfg.family == "audio":
            # enc-dec: the decoder-position table IS the self-attn cache
            # (cache_schema sizes it to decoder_len regardless of S), so
            # prompt + generation must fit inside decoder_len — pad the
            # prompt short enough to leave max_new_tokens of headroom
            self.prompt_pad = max(1, min(prompt_len,
                                         cfg.decoder_len - max_new_tokens))
            self.cache_len = cfg.decoder_len
        else:
            self.prompt_pad = prompt_len
            self.cache_len = S

        mod = steps_mod._model_module(cfg)
        if params is None:
            params = pr.init_params(mod.lm_schema(cfg), jax.random.key(seed),
                                    cfg.param_dtype)
        self.params = params
        prefill_fn = steps_mod.build_prefill(
            cfg, par, mesh, ShapeConfig("serve", S, 1, "prefill")).fn

        # prefill + slot splice + argmax fused into ONE dispatch per
        # admission — admission cost is on the serving critical path
        # (every refill happens between fused decode steps)
        def prefill_insert(params, caches, prompt, slot, *extras):
            last, small = prefill_fn(params, prompt, *extras)
            caches = steps_mod.cache_batch_insert(caches, small, slot)
            return jnp.argmax(last[0], -1).astype(jnp.int32), caches

        self._prefill_insert = jax.jit(prefill_insert, donate_argnums=1)
        self._decode = steps_mod.build_slot_decode(cfg, par, mesh, shape).jit()
        self._caches = steps_mod.init_cache(cfg, num_slots, S)
        ex_abs, _ = steps_mod.extras_specs(cfg, 1)
        self._extras = (({k: jnp.zeros(v.shape, v.dtype)
                          for k, v in ex_abs.items()},) if ex_abs else ())

    # ----------------------------------------------------------- jit steps
    def _pad_prompt(self, prompt) -> np.ndarray:
        row = np.ones((1, self.prompt_pad), np.int32)
        toks = list(prompt)[:self.prompt_pad]
        row[0, :len(toks)] = toks
        return row

    def prefill_into(self, slot_index: int, prompt) -> int:
        """Prefill one request alone and splice its cache into the slot.
        Returns the first generated token."""
        t0 = time.perf_counter()
        first, self._caches = self._prefill_insert(
            self.params, self._caches,
            jnp.asarray(self._pad_prompt(prompt)), jnp.int32(slot_index),
            *self._extras)
        first = int(first)
        self.metrics.gauge(GAUGES.PREFILL_S, time.perf_counter() - t0)
        return first

    def decode_step(self, tokens, positions) -> np.ndarray:
        """One fused greedy step over all slots.  ``tokens``/``positions``
        are per-slot (num_slots,) host lists; returns the new tokens."""
        tok = jnp.asarray(np.asarray(tokens, np.int32)[:, None])
        pos = jnp.asarray(np.asarray(positions, np.int32))
        out, self._caches = self._decode(self.params, self._caches, tok, pos)
        return np.asarray(out)[:, 0]

    def warmup(self) -> None:
        """Compile the three jitted paths (prefill, insert, decode) off the
        clock.  Two rounds: the first insert sees the freshly allocated
        (uncommitted) cache, every later one sees a jit-output cache — a
        different sharding signature, so one round would leave the second
        compile on the serving clock.  Touches only slot 0, which the
        first admission overwrites."""
        for _ in range(2):
            self.prefill_into(0, [1] * self.prompt_pad)
            self.decode_step([0] * self.num_slots, [0] * self.num_slots)

    # ----------------------------------------------------------- main loop
    def run(self, queue: WorkQueue, *, worker: str = "server",
            default_max_new: Optional[int] = None, idle_wait: float = 1e-3,
            should_stop=None) -> Tuple[Dict[Any, list], Registry]:
        """Serve the queue to exhaustion with continuous batching.

        Admission, eviction and lease heartbeats happen between fused
        decode steps; a request that finishes early frees its slot for the
        next queued request immediately (no drain-then-refill barrier).
        Returns ``(results, metrics)`` with ``results[rid]`` the generated
        tokens (length == the request's stop length).

        ``should_stop`` (a zero-arg callable, e.g. ``PodCtx.should_stop``
        when the engine runs as a preemptible tenant pod under
        repro.vcluster) is polled between fused steps: when it goes true
        the loop exits cleanly, in-flight requests' leases expire back to
        the queue, and a re-placed engine resumes serving them.
        """
        cap = self.cache_len - self.prompt_pad
        sched = ContinuousScheduler(
            queue, self.num_slots, worker=worker, registry=self.metrics,
            clock=self.clock,
            default_max_new=min(default_max_new or self.max_new_tokens, cap))
        t_start = time.perf_counter()
        decode_s = 0.0
        with self.mesh:
            while True:
                if should_stop is not None and should_stop():
                    # preempted between steps: unfinished slots are NOT
                    # acked — their queue leases expire and requeue
                    self.metrics.inc(GAUGES.PREEMPTED)
                    break
                for slot in sched.admit():
                    # engine capacity bounds the stop length: past
                    # prompt_pad+cap the cache has no row to write
                    if slot.request.max_new_tokens > cap:
                        slot.request = dataclasses.replace(
                            slot.request, max_new_tokens=cap)
                    first = self.prefill_into(slot.index, slot.request.prompt)
                    sched.start(slot, first, self.prompt_pad)
                if not sched.active():
                    if sched.finished():
                        break
                    time.sleep(idle_wait)   # queue momentarily empty
                    continue
                t0 = time.perf_counter()
                toks = self.decode_step(sched.last_tokens(),
                                        sched.positions())
                decode_s += time.perf_counter() - t0
                sched.observe(toks)
                sched.renew_leases()
        wall = time.perf_counter() - t_start
        results = sched.results()
        record_serving_totals(self.metrics,
                              sum(len(v) for v in results.values()),
                              wall, decode_s)
        return results, self.metrics
