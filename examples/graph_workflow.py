"""Workflow programs on the federation — CONNECT as a declarative graph.

The paper's instrument is *workflow-driven*: Kepler programs compiled
onto the CHASE-CI fabric.  This example runs the CONNECT case study as a
``WorkflowRun.spec.graph`` manifest (``repro.flow``): five declarative
nodes — plan, fetch (scatter over chunks), train, segment (scatter over
chunks, placed at the data), analyze (gather) — executed concurrently
across a 3-site fabric, then exercises the property that makes fan-out
operationally safe:

  1. the graph manifest (`examples/manifests/connect_graph.json`)
     applies through the same ``Session`` as every other workload; the
     monitor stream shows ``branch`` events for every scatter shard;
  2. a second run is **cancelled mid-fan-out** (after the first segment
     branch completes) — the run drains cleanly, CANCELLED, with a
     workflow-level ``cancelled`` event;
  3. re-applying the same manifest resumes ONLY the branches that never
     finished: completed shards skip via their markers (asserted from
     the branch events), and the workflow completes.

    PYTHONPATH=src python examples/graph_workflow.py [--fast]

Emits a ``GRAPH_REPORT {json}`` line for CI logs.
"""
import argparse
import dataclasses
import json
import pathlib
import time

from repro.api import Session
from repro.api.resources import load_manifest
from repro.api.session import TERMINAL_STATES, WorkloadState
from repro.fabric import Fabric, FederatedStore, PlacementPlanner

MANIFEST = pathlib.Path(__file__).parent / "manifests" / "connect_graph.json"


def build_fabric() -> Fabric:
    fabric = Fabric(time_scale=0.0)
    fabric.add_site("sdsc", devices=list(range(4)))
    fabric.add_site("calit2", devices=list(range(2)))
    fabric.add_site("edge", devices=list(range(1)))
    fabric.connect("sdsc", "calit2", gbps=10.0, latency_ms=3.0)
    fabric.connect("sdsc", "edge", gbps=1.0, latency_ms=12.0)
    fabric.connect("calit2", "edge", gbps=1.0, latency_ms=12.0)
    return fabric


def branch_events(events, of, status):
    return [e for e in events
            if e.kind == "branch" and e.data.get("of") == of
            and e.data.get("status") == status]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="kept for CI-flag symmetry; the manifest is "
                         "already CI-sized")
    ap.parse_args()

    spec = load_manifest(str(MANIFEST))
    n_chunks = spec.graph["nodes"][0]["params"]["n_chunks"]

    # --- 1: straight-through run on a fresh 3-site fabric ----------------
    fabric = build_fabric()
    session = Session(fabric=fabric,
                      planner=PlacementPlanner(FederatedStore(fabric)))
    sub = session.bus.subscribe(maxlen=8192)
    t0 = time.perf_counter()
    out = session.apply(spec).wait(timeout=600)
    makespan = time.perf_counter() - t0
    events = sub.poll()
    res = out["results"]
    assert res["analyze"]["objects"] >= 1, res
    assert len(res["segment"]) == n_chunks
    fetched = branch_events(events, "fetch", "done")
    segmented = branch_events(events, "segment", "done")
    assert len(fetched) == n_chunks and len(segmented) == n_chunks, \
        (len(fetched), len(segmented))
    sites = {e.data["site"] for e in fetched}
    print(out["table"])
    print(f"graph run OK: {n_chunks}-way fan-out across sites {sorted(sites)}"
          f" in {makespan:.2f}s")

    # --- 2: cancel mid-fan-out ------------------------------------------
    fabric2 = build_fabric()
    session2 = Session(fabric=fabric2,
                       planner=PlacementPlanner(FederatedStore(fabric2)))
    sub2 = session2.bus.subscribe(maxlen=8192)
    # max_workers=1 serializes the segment branches, so cancelling right
    # after the first one completes deterministically strands the rest
    handle = session2.apply(dataclasses.replace(spec, max_workers=1))
    ev2 = []
    while handle.state not in TERMINAL_STATES:
        for ev in sub2.poll(timeout=0.2):
            ev2.append(ev)
            if (ev.kind == "branch" and ev.data.get("of") == "segment"
                    and ev.data.get("status") == "done"):
                handle.cancel()
    handle.cancel(wait=True, timeout=600)
    ev2.extend(sub2.poll())
    assert handle.state is WorkloadState.CANCELLED, handle.state
    done_first = {e.data["branch"] for e in branch_events(
        ev2, "segment", "done")}
    assert 0 < len(done_first) < n_chunks, \
        f"cancel landed outside the fan-out: {sorted(done_first)}"
    wf_cancelled = [e for e in ev2 if e.kind == "workflow"
                    and e.data.get("status") == "cancelled"]
    assert wf_cancelled, "no workflow-level cancelled event"
    print(f"cancelled mid-fan-out with segment branches "
          f"{sorted(done_first)} of {set(range(n_chunks))} complete")

    # --- 3: resume — only the stranded branches run ----------------------
    sub3 = session2.bus.subscribe(maxlen=8192)
    out3 = session2.apply(spec).wait(timeout=600)
    ev3 = sub3.poll()
    assert out3["results"]["analyze"]["objects"] >= 1
    resumed = {e.data["branch"] for e in branch_events(
        ev3, "segment", "done")}
    skipped = {e.data["branch"] for e in branch_events(
        ev3, "segment", "skipped")}
    assert skipped == done_first, (skipped, done_first)
    assert resumed == set(range(n_chunks)) - done_first, \
        (resumed, done_first)
    print(f"resume re-ran only branches {sorted(resumed)} "
          f"(markers skipped {sorted(skipped)})")

    print("GRAPH_REPORT " + json.dumps({
        "n_chunks": n_chunks, "makespan_s": round(makespan, 3),
        "fanout_sites": sorted(sites),
        "cancelled_after": sorted(done_first),
        "resumed": sorted(resumed)}))
    print("\nOK — graph manifest ran concurrently, cancelled cleanly "
          "mid-fan-out, and resumed only the missing branches.")


if __name__ == "__main__":
    main()
