"""Distributed RL as fair-share co-tenants (paper §I, §IV, §VI).

The first workload that exercises every plane of the repro at once: a
serving-plane **actor fleet** (continuous-batching engines, paged KV)
generates rollouts against the latest policy, a training-plane
**learner** takes fused policy-gradient steps on the chunked-scan hot
loop, and the two planes meet only through platform primitives — a
lease-heartbeat rollout queue and a versioned policy store over the
federated fabric (every weight pull is a metered cross-link transfer
billed to the pulling tenant).

Chaos is injected mid-run and the platform contracts must hold:

  1. **actor kill, zero loss** — one actor is killed while it provably
     holds ticket leases; its engine nacks them back to the shared
     queue and the survivors finish them (requeued attempts > 1);
  2. **elastic fleet width** — the fleet resizes 2 -> 3 through
     ``resize_claim`` on the actor tenant's capacity claim;
  3. **learner preemption** — a high-priority burst tenant
     checkpoint-evicts the learner pod; the fair-share scheduler
     requeues the whole job and the next placement restores from the
     goodbye checkpoint (zero lost steps);
  4. **learner crash** — an injected hard failure (no goodbye save)
     respawns via pod backoff and restores from the latest *periodic*
     checkpoint: ``steps_lost <= ckpt_every``;
  5. **bounded staleness** — zero trained-on rollouts exceed
     ``max_policy_lag`` weight versions; stale ones are dropped and
     metered separately; every surviving actor observes >= 1 weight
     version bump through the federated store.

    PYTHONPATH=src python examples/rl_cotenants.py [--fast]

Emits an ``RL_REPORT {json}`` line consumed by
``benchmarks/run.py::bench_rl`` / CI.
"""
import argparse
import json
import threading
import time

from repro.api import RLJob
from repro.api.runners import build_rl_engine, rl_pieces
from repro.core.metrics import Registry
from repro.core.orchestrator import JobSpec
from repro.fabric import Fabric, FederatedStore
from repro.rl import (ActorFleet, InjectedLearnerFailure, PolicyStore,
                      RLLearner, RLLearnerSpec, RolloutActor, RolloutQueue,
                      ticket_queue)
from repro.vcluster import FairShareScheduler, TenantSpec


def run_scenario(fast: bool) -> dict:
    steps = 6 if fast else 8
    # the declarative carrier: the same resource a Session would apply —
    # here we drive the repro.rl primitives directly so the chaos hooks
    # (kill / resize / burst) can reach into the run
    job = RLJob(name="rl-cotenants", learner_steps=steps, actors=2,
                rollouts_per_step=2, prompt_len=8, max_new_tokens=8,
                seq_len=24, slots=2, max_policy_lag=2, broadcast_every=2,
                ckpt_every=2, fail_at=steps - 2, site="serve",
                learner_site="train")

    fabric = Fabric()
    fabric.add_site("serve", devices=list(range(4)))   # actor appliance
    fabric.add_site("train", devices=[0])              # learner appliance
    fabric.connect("serve", "train", gbps=10.0, latency_ms=1.0)
    fed = FederatedStore(fabric)
    sched = FairShareScheduler(fed=fed, reconcile_s=0.02,
                               preempt_grace_s=60.0)
    actor_t = sched.create_tenant(TenantSpec("actors", priority=0))
    learner_t = sched.create_tenant(TenantSpec("learner", priority=0))
    burst_t = sched.create_tenant(TenantSpec("burst", priority=10,
                                             preemptible=False))

    metrics = Registry()
    cfg, par, ocfg = rl_pieces(job)
    tickets = ticket_queue(lease_timeout=job.lease_timeout)
    rollouts = RolloutQueue(lease_timeout=job.lease_timeout,
                            registry=metrics)
    # the learner publishes into ITS site's tenant-billed store view;
    # actors subscribe through THEIRS — each pull-on-bump crosses the
    # serve<->train link and is metered against the pulling tenant
    publish = PolicyStore(learner_t.store("train"), registry=metrics)
    subscribe = PolicyStore(actor_t.store("serve"), registry=metrics)
    prompts = {}

    def make_actor(name):
        return RolloutActor(name, build_rl_engine(job, cfg, par), tickets,
                            rollouts, subscribe, prompts=prompts,
                            registry=metrics)

    claim = actor_t.claim("serve", job.actors, min_devices=1)
    fleet = ActorFleet(make_actor, width=job.actors,
                       capacity=lambda w: sched.resize_claim(claim, w),
                       registry=metrics, name="actor")
    spec = RLLearnerSpec(cfg, par, ocfg, steps=steps, seq_len=job.seq_len,
                         batch=job.rollouts_per_step,
                         ckpt_every=job.ckpt_every,
                         broadcast_every=job.broadcast_every,
                         max_policy_lag=job.max_policy_lag,
                         fail_at=job.fail_at)
    learner = RLLearner(spec, rollouts, publish,
                        store=learner_t.store("train"), registry=metrics)

    # ---------------------------------------------------- ticket feeder
    import numpy as np
    rng = np.random.default_rng(101)
    stop_feed = threading.Event()
    burst = max(job.rollouts_per_step, 3 * job.slots)
    backlog_cap = 2 * job.rollouts_per_step

    def feed():
        n = 0
        while not stop_feed.is_set():
            if (tickets.pending > 0 or tickets.leased > 0
                    or rollouts.pending >= backlog_cap):
                time.sleep(2e-3)
                continue
            for _ in range(burst):
                rid = f"t{n:05d}"
                n += 1
                prompt = [int(x) for x in rng.integers(
                    1, cfg.vocab_size, size=job.prompt_len)]
                prompts[rid] = prompt
                tickets.put({"id": rid, "prompt": prompt,
                             "max_new_tokens": job.max_new_tokens})

    # ------------------------------------------------- chaos controller
    chaos = {"held_at_kill": 0, "width_after_kill": 0, "granted": 0}

    def controller():
        # (1) kill actor-0 at a moment it PROVABLY holds ticket leases:
        # the engine's stop path nacks them back for the survivors
        while learner.report.steps_done < 1:
            time.sleep(5e-3)
        while tickets.leased_by("actor-0") == 0:
            time.sleep(1e-3)
        chaos["held_at_kill"] = tickets.leased_by("actor-0")
        fleet.kill("actor-0")
        chaos["width_after_kill"] = fleet.width
        # (2) regrow wider than before through the fair-share claim
        chaos["granted"] = fleet.resize(3)
        # (3) burst tenant forces checkpoint-then-evict of the learner
        while learner.report.steps_done < 2:
            time.sleep(5e-3)
        bj = burst_t.submit(JobSpec("burst", lambda ctx: time.sleep(0.3)
                                    or "hi", devices_per_pod=1),
                            site="train")
        bj.wait(120)

    # ------------------------------------------ the learner tenant pod
    # one resumable segment per placement: preemption goodbye-saves and
    # the scheduler requeues the WHOLE job (next placement restores);
    # the injected hard crash propagates and pod backoff respawns it
    def learner_pod(ctx):
        return learner.run(ctx.should_stop)

    t0 = time.monotonic()
    feeder = threading.Thread(target=feed, daemon=True)
    ctrl = threading.Thread(target=controller, daemon=True)
    with sched:
        fleet.start()
        feeder.start()
        ctrl.start()
        tj = learner_t.submit(JobSpec("rl-learner", learner_pod,
                                      devices_per_pod=1, backoff_limit=3),
                              site="train")
        tj.wait(600)
        ctrl.join(timeout=120)
        # let the (now idle) actors observe the final published version
        deadline = time.monotonic() + 10.0
        while fleet.min_syncs() < 1 and time.monotonic() < deadline:
            time.sleep(5e-3)
        min_syncs = fleet.min_syncs()
        stop_feed.set()
        fleet.stop_all()
        feeder.join(timeout=10)
    wall = time.monotonic() - t0
    claim.release()

    # the checkpoint extra carries the rollout-queue snapshot; the same
    # snapshot/restore round-trip rebuilds the buffer with its audit
    # trail intact (lease state intentionally does not survive)
    clone = RolloutQueue()
    clone.restore(rollouts.snapshot())
    assert clone.trained == rollouts.trained
    assert clone.pending == rollouts.pending
    assert clone.stale_dropped == rollouts.stale_dropped

    rep = learner.report
    tsnap = tickets.snapshot()
    requeued = sum(1 for _, _, attempts, _, _ in tsnap["tasks"]
                   if attempts > 1)
    tok_total = metrics.series("rl/rollout_tokens").total
    lag_series = metrics.series("rl/policy_lag")
    return {
        "steps": steps,
        "steps_done": rep.steps_done,
        "steps_lost": rep.steps_lost,
        "ckpt_every": job.ckpt_every,
        "outcomes": [s["outcome"] for s in rep.segments],
        "preemptions": rep.preemptions,
        "crashes": sum(1 for s in rep.segments
                       if s["outcome"] == "failed"),
        "job_preemptions": tj.preemptions,
        "publishes": rep.publishes,
        "final_version": rep.final_version,
        "trained": rollouts.trained,
        "stale_dropped": rollouts.stale_dropped,
        "max_lag_trained": rollouts.max_lag_trained(),
        "policy_lag_p99": lag_series.percentile(99),
        "rollouts_pushed": rollouts.pushed,
        "rollout_tokens": int(tok_total),
        "rollout_tok_s": round(tok_total / wall, 2),
        "learner_steps_s": round(rep.steps_done / wall, 3),
        "held_at_kill": chaos["held_at_kill"],
        "width_after_kill": chaos["width_after_kill"],
        "granted_after_resize": chaos["granted"],
        "requeued_tickets": requeued,
        "dead_tickets": len(tickets.dead),
        "min_actor_syncs": min_syncs,
        "weight_syncs": int(metrics.series("rl/weight_syncs").total),
        "weight_bytes_pulled": int(fabric.metrics.series(
            "fabric/tenant/actors/bytes_moved").total),
        "wall_s": round(wall, 2),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="smaller run (CI smoke / benchmark)")
    args = ap.parse_args()
    out = run_scenario(args.fast)

    # --- 1: actor kill loses no trajectories ----------------------------
    assert out["held_at_kill"] >= 1, out
    assert out["requeued_tickets"] >= 1, \
        f"killed actor's leases must requeue: {out}"
    assert out["dead_tickets"] == 0, out
    # --- 2: elastic fleet width through the fair-share claim ------------
    assert out["width_after_kill"] == 1 and \
        out["granted_after_resize"] == 3, out
    # --- 3+4: learner survives one preemption and one hard crash --------
    assert out["steps_done"] == out["steps"], out
    assert out["preemptions"] >= 1 and "preempted" in out["outcomes"], out
    assert out["crashes"] == 1 and "failed" in out["outcomes"], out
    assert out["steps_lost"] <= out["ckpt_every"], \
        f"crash resume lost more than the checkpoint bound: {out}"
    # --- 5: bounded staleness + observed broadcast ----------------------
    assert out["max_lag_trained"] <= 2, \
        f"trained on a rollout beyond max_policy_lag: {out}"
    assert out["min_actor_syncs"] >= 1, out
    assert out["weight_bytes_pulled"] > 0, out

    print("\nRL_REPORT " + json.dumps(out))
    print(f"\nOK — {out['steps_done']}/{out['steps']} learner steps "
          f"through {out['preemptions']} preemption(s) + "
          f"{out['crashes']} crash(es) (lost {out['steps_lost']} <= "
          f"ckpt_every {out['ckpt_every']}); killed an actor holding "
          f"{out['held_at_kill']} lease(s), {out['requeued_tickets']} "
          f"ticket(s) requeued, fleet regrown to "
          f"{out['granted_after_resize']}; trained {out['trained']} "
          f"rollouts at max lag {out['max_lag_trained']} "
          f"(dropped {out['stale_dropped']} stale), "
          f"{out['rollout_tok_s']} rollout tok/s, "
          f"{out['weight_bytes_pulled']} weight bytes over the fabric.")


if __name__ == "__main__":
    main()
