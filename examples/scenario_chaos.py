"""Production-chaos scenario: diurnal traffic + failure menu + SLO grades.

The paper's closing argument (PPoDS, §VI): the platform is trusted only
after production-shaped load has been driven through it *while the
infrastructure churns underneath*.  This example runs the whole stack
at once, entirely through the declarative ``Session`` API:

  * **3 sites** — a 3-device training appliance (``gpu``), a 1-device
    inference edge (``edge``), a data hub (``hub``) — on a
    bandwidth-modeled fabric;
  * **3 tenants** — ``research`` trains an elastic LM on a capacity
    claim (corpus staged from the hub, billed to it); ``chat`` and
    ``search`` serve phase-shifted diurnal request tides (one's peak is
    the other's trough) with heavy-tailed prompt/gen lengths; ``chat``
    also fires a priority-10 batch surge mid-run that may preempt the
    trainer (checkpoint-then-evict, elastic resume);
  * **the failure menu** — node churn at the edge, a whole-site kill of
    the edge MID-WAVE, a 20x brown-out of the gpu<->hub link, then both
    restored — all injected by the scenario driver in sim-time;
  * **the report card** — per-tenant SLO attainment (p99 TTFT/latency,
    goodput floor), steps_lost for the co-tenant trainer, and $-style
    chargeback from the platform's own byte-moved / device-lease meters.

Asserts: every tenant graded with every SLO verdict computed, no
request silently dropped (served + rejected == offered), the run
survives the site kill and the link brown-out, equal-share serving
tenants stay within 20% makespan skew, and training completes with the
elastic bound honored.

    PYTHONPATH=src python examples/scenario_chaos.py [--fast]

Emits a ``SCENARIO_REPORT {json}`` line consumed by
``benchmarks/run.py::bench_scenarios`` / CI.
"""
import os

if "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                               " --xla_force_host_platform_device_count=8").strip()

import argparse   # noqa: E402
import json       # noqa: E402
import time       # noqa: E402

import jax        # noqa: E402

from repro.api import BatchJob, ServeJob, TrainJob                # noqa: E402
from repro.core.orchestrator import Cluster                       # noqa: E402
from repro.fabric import Fabric, FederatedStore                   # noqa: E402
from repro.launch.monitor import render_frame                     # noqa: E402
from repro.scenarios import (SLO, BurstOverlay, BurstPlan,        # noqa: E402
                             ChaosEvent, ChaosSchedule, DiurnalRate,
                             ScenarioSpec, ServePlan, TrafficShape,
                             TrainPlan, grade_table, run_scenario)
from repro.vcluster import FairShareScheduler, TenantSpec         # noqa: E402


def build_fabric():
    devs = jax.devices()
    assert len(devs) == 8, "expected 8 forced host devices"
    fabric = Fabric()
    fabric.add_site("gpu", cluster=Cluster(devices=list(devs[:3])))
    fabric.add_site("edge", cluster=Cluster(devices=[devs[3]]))
    fabric.add_site("hub", devices=[0])
    fabric.connect("gpu", "edge", gbps=10.0, latency_ms=1.0)
    fabric.connect("gpu", "hub", gbps=1.0, latency_ms=5.0)
    fabric.connect("edge", "hub", gbps=1.0, latency_ms=5.0)
    return fabric


def run(fast: bool) -> dict:
    fabric = build_fabric()
    fed = FederatedStore(fabric)
    sched = FairShareScheduler(fed=fed, reconcile_s=0.02,
                               preempt_grace_s=60.0)
    sched.bus.attach_fabric(fabric)
    research = sched.create_tenant(TenantSpec("research", priority=0))
    sched.create_tenant(TenantSpec("chat", priority=5))
    sched.create_tenant(TenantSpec("search", priority=5))

    horizon = 400.0
    windows = 4 if fast else 6
    mean_each = 0.06 if fast else 0.1      # rps per serving tenant
    spec = ScenarioSpec(
        name="diurnal-chaos", horizon_s=horizon, windows=windows,
        slos={
            "chat": SLO(p99_ttft_s=60.0, p99_latency_s=120.0,
                        min_goodput=0.9),
            "search": SLO(p99_ttft_s=60.0, p99_latency_s=120.0,
                          min_goodput=0.9),
            "research": SLO(),             # graded on steps_lost + bill
        })

    # two regions whose days alternate: chat peaks when search troughs
    def shape(name, phase, seed, bursts=None):
        return TrafficShape(
            name=name,
            rate=DiurnalRate(base_rps=mean_each * 0.4,
                             peak_rps=mean_each * 1.6,
                             period_s=horizon, phase_s=phase),
            bursts=bursts, zipf_a=1.7, max_prompt_len=16,
            gen_mu=1.3, gen_sigma=0.5, max_new_tokens=8, seed=seed)

    chat_shape = shape("chat", 0.0, 7,
                       bursts=BurstOverlay(rate_per_s=1.5 / horizon,
                                           extra_rps=mean_each,
                                           duration_s=horizon / 10))
    search_shape = shape("search", horizon / 2, 11)

    serve_base = {"chat": chat_shape, "search": search_shape}
    serve = {
        t: ServePlan(shape=s, manifest=ServeJob(
            name=t, slots=2, prompt_len=16, max_new_tokens=8,
            lease_timeout=60.0).to_manifest())
        for t, s in serve_base.items()
    }

    steps = 14 if fast else 24
    train = {"research": TrainPlan(manifest=TrainJob(
        name="elastic-train", steps=steps, seq_len=32, global_batch=4,
        base_shape=(2, 1), max_data=1, ckpt_every=2, log_every=4,
        rejoin_timeout_s=300.0, verbose=False, site="gpu", devices=2,
        min_devices=0,
        optimizer={"warmup_steps": 2, "decay_steps": 100}).to_manifest())}

    # chat's flash crowd becomes a priority-10 batch surge on the gpu
    # site: wide enough (2 devices) that fair share must checkpoint-
    # then-evict the trainer if it is mid-run when the surge lands
    bursts = {"chat": BurstPlan(
        times=[0.3 * horizon],
        manifest=BatchJob(name="surge", devices_per_pod=2, priority=10,
                          site="gpu").to_manifest(),
        fn=lambda ctx: time.sleep(0.5) or "surge-done")}

    chaos = ChaosSchedule([
        ChaosEvent(at_s=0.10 * horizon, kind="node-fail", site="edge"),
        ChaosEvent(at_s=0.18 * horizon, kind="node-join", site="edge"),
        ChaosEvent(at_s=0.35 * horizon, kind="site-kill", site="edge"),
        ChaosEvent(at_s=0.50 * horizon, kind="link-degrade",
                   link=("gpu", "hub"), gbps=0.05),
        ChaosEvent(at_s=0.80 * horizon, kind="link-restore",
                   link=("gpu", "hub")),
        ChaosEvent(at_s=0.85 * horizon, kind="site-restore", site="edge"),
    ])

    # tenant-billed staging: the corpus homes at the hub
    fed.put("datasets/corpus.bin", b"x" * (1 << 18 if fast else 1 << 20),
            "hub")
    with sched:
        research.store("gpu").get("datasets/corpus.bin")
        result = run_scenario(sched, spec, serve=serve, train=train,
                              bursts=bursts, chaos=chaos)
        time.sleep(3 * sched.reconcile_s)
        frame = render_frame(sched, [])
    print(frame)
    print(grade_table(list(result.grades.values())))
    return finish(result, spec, train_steps=steps, ckpt_every=2)


def finish(result, spec, *, train_steps: int, ckpt_every: int) -> dict:
    rep = result.report()
    grades = result.grades

    # --- every tenant graded, every configured verdict computed ---------
    assert set(grades) == {"research", "chat", "search"}, rep
    for t in ("chat", "search"):
        assert set(grades[t].verdicts) == \
            {"p99_ttft", "p99_latency", "goodput"}, rep["tenants"][t]
        # no request silently dropped: served + rejected == offered
        g = grades[t]
        assert g.served + g.rejected == g.offered > 0, rep["tenants"][t]
        assert g.slo_pass, f"SLO failed for {t}: {rep['tenants'][t]}"

    # --- the run survived the whole failure menu ------------------------
    applied = {(r["kind"], r.get("site") or tuple(r.get("link") or ()))
               for r in result.chaos_fired if r["applied"]}
    assert ("site-kill", "edge") in applied, rep["chaos"]
    assert ("link-degrade", ("gpu", "hub")) in applied, rep["chaos"]
    assert ("site-restore", "edge") in applied, rep["chaos"]

    # --- equal-share serving tenants: makespan skew within 20% ----------
    assert result.fairshare_skew <= 1.2, rep

    # --- co-tenant training: finished, elastic bound honored ------------
    out = result.train_results["research"]
    assert sorted(out["loss_by_step"]) == list(range(train_steps)), \
        "preempted training must resume and finish"
    g = grades["research"]
    assert g.steps_lost <= ckpt_every * max(1, g.recoveries), rep

    # --- chargeback from the platform's own meters ----------------------
    assert g.chargeback["gb_moved"] > 0, "staging was not billed"
    for t in ("research", "chat", "search"):
        assert grades[t].chargeback["total"] > 0, rep["tenants"][t]

    assert all(s == "Succeeded" for s in result.burst_states), \
        result.burst_states
    return rep


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="smaller run (CI scenario smoke / benchmark)")
    args = ap.parse_args()
    rep = run(args.fast)
    print("\nSCENARIO_REPORT " + json.dumps(rep))
    tenants = rep["tenants"]
    served = sum(t["served"] for t in tenants.values())
    offered = sum(t["offered"] for t in tenants.values())
    print(f"\nOK — {served}/{offered} requests served across "
          f"{rep['windows']} waves under {len(rep['chaos'])} chaos events; "
          f"skew {rep['fairshare_skew']}x; research lost "
          f"{tenants['research']['steps_lost']} steps; bills "
          + ", ".join(f"{t} ${g['chargeback']['total']:.4f}"
                      for t, g in sorted(tenants.items())))


if __name__ == "__main__":
    main()
