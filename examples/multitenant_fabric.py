"""Multi-tenant virtual clusters on one shared fabric (paper §I, §IV).

CHASE-CI is a *shared appliance*: ~30 institutions on one federation.
This example runs the multi-tenant stack end to end — every workload
declared through the unified API (`Session(tenant=...)`) — and asserts
the paper-shaped contracts:

  1. **fair share under contention** — two equal-share tenants submit
     identical job streams to a saturated 2-site fabric.  Under the
     dominant-share scheduler they finish within 20% of each other's
     makespan; under the FIFO baseline the first tenant's backlog
     head-of-line blocks the second (>2x skew in mean completion time);
  2. **preemption + resume** — a low-priority training tenant is
     checkpoint-then-evicted by a high-priority burst, and resumes from
     its checkpoint when the grant returns, while an inference tenant
     keeps serving on its own slice of the SAME fabric (train and serve
     tenants co-exist);
  3. **near-real-time monitor** — every scheduling / churn / transfer /
     workload-lifecycle event reaches a live subscriber with bounded
     lag, rendered by the repro.launch.monitor dashboard.

    PYTHONPATH=src python examples/multitenant_fabric.py [--fast]

Emits a ``VCLUSTER_REPORT {json}`` line consumed by
``benchmarks/run.py::bench_vcluster_fairness`` / CI.
"""
import argparse
import json
import threading
import time

import jax

from repro.api import BatchJob, ServeJob, Session, TrainJob
from repro.core.orchestrator import Cluster, JobSpec
from repro.fabric import Fabric, FederatedStore
from repro.launch.monitor import render_frame
from repro.vcluster import FairShareScheduler, TenantSpec

MONITOR_INTERVAL_S = 0.5        # the lag SLO: one monitor reconcile tick


# ---------------------------------------------------------------- fairness

def run_contention(policy: str, *, n_jobs: int, job_s: float) -> dict:
    """Two equal-share tenants hammer a saturated 2-site fabric."""
    fabric = Fabric()
    fabric.add_site("s0", devices=list(range(2)))
    fabric.add_site("s1", devices=list(range(2)))
    fabric.connect("s0", "s1", gbps=10.0, latency_ms=1.0)
    sched = FairShareScheduler(fabric, policy=policy, reconcile_s=0.01)
    tenants = {n: sched.create_tenant(TenantSpec(n)) for n in ("alice", "bob")}

    def work(ctx):
        end = time.monotonic() + job_s
        while time.monotonic() < end and not ctx.should_stop():
            time.sleep(0.005)
        return "ok"

    t0 = time.monotonic()
    jobs = {n: [vc.submit(JobSpec(f"{n}{i}", work, devices_per_pod=1))
                for i in range(n_jobs)]
            for n, vc in tenants.items()}          # alice's backlog first
    with sched:
        for js in jobs.values():
            for j in js:
                j.wait(120)
    out = {}
    for name, js in jobs.items():
        out[name] = {
            "makespan_s": round(max(j.done_ts for j in js) - t0, 3),
            "mean_completion_s": round(
                sum(j.done_ts - t0 for j in js) / len(js), 3)}
    mk = [v["makespan_s"] for v in out.values()]
    mc = [v["mean_completion_s"] for v in out.values()]
    out["makespan_ratio"] = round(max(mk) / min(mk), 3)
    out["completion_skew"] = round(max(mc) / min(mc), 3)
    return out


# ------------------------------------------------- train+serve+preemption

def run_preemption_scenario(fast: bool) -> dict:
    """Train / serve / burst tenants share one fabric; the burst
    checkpoint-evicts the trainer, which resumes and finishes.  Each
    tenant's workloads go through its own Session on the same API."""
    dev = jax.devices()[0]
    fabric = Fabric()
    # one training appliance, one inference appliance, one data hub
    fabric.add_site("gpu", cluster=Cluster(devices=[dev]))
    fabric.add_site("edge", cluster=Cluster(devices=[dev]))
    fabric.add_site("hub", devices=[0])
    fabric.connect("gpu", "edge", gbps=10.0, latency_ms=1.0)
    fabric.connect("gpu", "hub", gbps=1.0, latency_ms=5.0)
    fabric.connect("edge", "hub", gbps=1.0, latency_ms=5.0)
    fed = FederatedStore(fabric)
    sched = FairShareScheduler(fed=fed, reconcile_s=0.02,
                               preempt_grace_s=60.0)
    sched.bus.attach_fabric(fabric)
    sched.bus.attach_registry(fabric.metrics)

    # a live monitor subscriber measuring end-to-end lag; subscribed
    # BEFORE any event source so received == published holds exactly
    sub = sched.bus.subscribe(maxlen=8192)
    lag = {"max": 0.0, "n": 0, "kinds": set()}
    stop_mon = threading.Event()

    def monitor():
        while True:
            got = sub.poll(timeout=0.05)
            for ev in got:
                lag["max"] = max(lag["max"], time.time() - ev.ts)
                lag["n"] += 1
                lag["kinds"].add(ev.kind)
            if not got and stop_mon.is_set():
                return

    train_t = sched.create_tenant(TenantSpec("train", priority=0))
    serve_t = sched.create_tenant(TenantSpec("serve", priority=5))
    burst_t = sched.create_tenant(TenantSpec("burst", priority=10,
                                             preemptible=False))
    # one Session per tenant: same verbs, tenant-scoped placement
    train_s = Session(tenant=train_t)
    serve_s = Session(tenant=serve_t)
    burst_s = Session(tenant=burst_t)

    mon = threading.Thread(target=monitor, daemon=True)

    # tenant-billed data staging: the training corpus homes at the hub
    fed.put("datasets/corpus.bin", b"x" * (1 << 18 if fast else 1 << 20),
            "hub")

    steps = 10 if fast else 16
    train_job = TrainJob(
        name="elastic-train", steps=steps, seq_len=32, global_batch=4,
        base_shape=(1, 1), max_data=1, ckpt_every=2, log_every=1,
        rejoin_timeout_s=120.0, verbose=False, site="gpu", devices=1,
        optimizer={"warmup_steps": 2, "decay_steps": 100})

    n_req = 4 if fast else 8
    gen = 4 if fast else 8
    serve_job = ServeJob(
        name="serve-edge", slots=2, prompt_len=8, max_new_tokens=gen,
        site="edge",
        requests=[{"id": i, "prompt": [1 + i] * 8, "max_new_tokens": gen}
                  for i in range(n_req)])

    fired = {"burst": False}

    def fire_burst():
        while fabric.metrics.series("elastic/step").last < 3:
            time.sleep(0.005)
        burst_s.apply(BatchJob(name="burst", devices_per_pod=1,
                               site="gpu"),
                      fn=lambda ctx: time.sleep(0.3) or "hi").wait(120)
        fired["burst"] = True

    with sched:
        mon.start()
        # the trainer's inputs are staged from the hub, billed to it
        train_t.store("gpu").get("datasets/corpus.bin")
        serve_handle = serve_s.apply(serve_job)
        burster = threading.Thread(target=fire_burst, daemon=True)
        burster.start()
        out = train_s.apply(train_job).wait(600)
        burster.join(timeout=120)
        serve_out = serve_handle.wait(300)
        # a final pass so "done" events reach the stream before we stop
        time.sleep(3 * sched.reconcile_s)
    stop_mon.set()
    mon.join(timeout=10)

    rep = out["report"]
    results = serve_out["results"]
    frame = render_frame(sched, [],
                         workloads=train_s.workloads + serve_s.workloads +
                         burst_s.workloads)
    print(frame)
    return {
        "steps": steps,
        "outcomes": [s.outcome for s in rep.segments],
        "preemptions": int(
            fabric.metrics.series("elastic/preemptions").total),
        "steps_lost": rep.steps_lost,
        "ckpt_every": train_job.ckpt_every,
        "completed": rep.segments[-1].end == steps - 1,
        "losses_complete": sorted(out["loss_by_step"]) == list(range(steps)),
        "burst_done": fired["burst"],
        "serve_requests": len(results),
        "serve_tokens": sum(len(v) for v in results.values()),
        "train_bytes_staged": int(fabric.metrics.series(
            "fabric/tenant/train/bytes_moved").total),
        "monitor": {
            "published": sched.bus.published,
            "received": lag["n"],
            "dropped": sub.dropped,
            "kinds": sorted(lag["kinds"]),
            "max_lag_s": round(lag["max"], 4),
        },
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="smaller workloads (CI monitor smoke / benchmark)")
    args = ap.parse_args()

    n_jobs, job_s = (10, 0.05) if args.fast else (12, 0.08)

    # --- 1: fair share vs FIFO on identical contention ------------------
    fair = run_contention("fair", n_jobs=n_jobs, job_s=job_s)
    fifo = run_contention("fifo", n_jobs=n_jobs, job_s=job_s)
    assert fair["makespan_ratio"] <= 1.2, \
        f"equal-share tenants must finish within 20%: {fair}"
    assert fifo["completion_skew"] > 2.0, \
        f"FIFO head-of-line blocking should skew >2x: {fifo}"

    # --- 2+3: preemption/resume + co-existence + monitor ----------------
    prem = run_preemption_scenario(args.fast)
    assert prem["preemptions"] >= 1, f"burst never preempted: {prem}"
    assert "preempted" in prem["outcomes"], prem
    assert prem["completed"] and prem["losses_complete"], \
        f"preempted training must resume and finish: {prem}"
    assert prem["steps_lost"] <= prem["ckpt_every"], \
        f"resume lost more than the elastic bound: {prem}"
    assert prem["burst_done"]
    assert prem["serve_requests"] == (4 if args.fast else 8), prem
    mon = prem["monitor"]
    assert mon["received"] == mon["published"] and mon["dropped"] == 0, mon
    assert mon["max_lag_s"] < MONITOR_INTERVAL_S, \
        f"monitor lag exceeded one reconcile interval: {mon}"
    assert {"sched", "pod", "transfer", "metric", "workload"} <= \
        set(mon["kinds"]), mon

    print("\nVCLUSTER_REPORT " + json.dumps(
        {"fair": fair, "fifo": fifo, "preemption": prem}))
    print(f"\nOK — fair makespan ratio {fair['makespan_ratio']}x vs FIFO "
          f"skew {fifo['completion_skew']}x; trainer preempted "
          f"{prem['preemptions']}x, lost {prem['steps_lost']} steps, "
          f"finished all {prem['steps']}; served "
          f"{prem['serve_requests']} requests on the same fabric; "
          f"{mon['received']}/{mon['published']} events at "
          f"max lag {mon['max_lag_s']}s.")


if __name__ == "__main__":
    main()
