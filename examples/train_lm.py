"""End-to-end LM training driver: a ~100M-param decoder LM for a few hundred
steps on the synthetic token pipeline, with checkpoints and auto-resume.

    PYTHONPATH=src python examples/train_lm.py                # ~20M, CPU-sized
    PYTHONPATH=src python examples/train_lm.py --hundred-m    # ~100M config
    PYTHONPATH=src python examples/train_lm.py --resume-demo  # crash+resume

The model is declared INSIDE the ``TrainJob`` manifest (``config`` holds
the ModelConfig kwargs), so the whole run — model, schedule, checkpoint
cadence, even the injected crash — is one declarative resource applied
through the unified Session API.

The --hundred-m config is the deliverable's "train ~100M model for a few
hundred steps" driver; on one CPU core it is slow (use a real accelerator),
so the default is a same-shape smaller model that finishes in minutes.
"""
import argparse
import tempfile

from repro.api import Session, TrainJob
from repro.core.orchestrator import Cluster


def lm_config(hundred_m: bool) -> dict:
    if hundred_m:
        # ~110M params: 12L, d=768, ff=2048, vocab=32768
        return dict(name="lm-100m", family="dense", num_layers=12,
                    d_model=768, num_heads=12, num_kv_heads=4,
                    d_ff=2048, vocab_size=32_768, head_dim=64)
    return dict(name="lm-20m", family="dense", num_layers=6,
                d_model=320, num_heads=8, num_kv_heads=4,
                d_ff=896, vocab_size=16_384, head_dim=40)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--hundred-m", action="store_true")
    ap.add_argument("--steps", type=int, default=0)
    ap.add_argument("--resume-demo", action="store_true")
    args = ap.parse_args()

    config = lm_config(args.hundred_m)
    steps = args.steps or (300 if not args.hundred_m else 200)
    job = TrainJob(name=config["name"], steps=steps, seq_len=64,
                   global_batch=4, smoke=False, config=config,
                   ckpt_dir=tempfile.mkdtemp(prefix="lm-ckpt-"),
                   ckpt_every=25,
                   # one injected crash mid-run: the elastic supervisor
                   # restores from the latest checkpoint and finishes
                   # WITHIN this same apply
                   fail_at=min(45, steps // 2) if args.resume_demo else -1)
    if args.resume_demo:
        print("[demo] training with an injected crash — the supervisor "
              "auto-resumes from the latest checkpoint")
    out = Session(cluster=Cluster()).apply(job).wait(timeout=3600)
    losses = out["losses"]
    print(f"final: first-loss {losses[0]:.3f} last-loss {losses[-1]:.3f}")
    assert losses[-1] < losses[0]


if __name__ == "__main__":
    main()
