"""Quickstart: train a tiny LM for a few steps, then serve it.

    PYTHONPATH=src python examples/quickstart.py

Uses the public API end to end: config registry -> train driver (sharded
step, checkpointing substrate underneath) -> serving driver (prefill +
decode with a KV cache).  Runs in ~a minute on one CPU.
"""
from repro.launch.serve import serve
from repro.launch.train import train


def main():
    print("=== train (reduced phi4-family config) ===")
    out = train("phi4-mini-3.8b", steps=20, seq=64, batch=4, smoke=True,
                log_every=5)
    losses = out["losses"]
    print(f"loss: {losses[0]:.3f} -> {losses[-1]:.3f}")
    assert losses[-1] < losses[0], "training should reduce loss"

    print("\n=== serve (batched requests through the work queue) ===")
    results, metrics = serve("phi4-mini-3.8b", smoke=True, n_requests=6,
                             prompt_len=16, gen=8, batch=2)
    print(f"served {len(results)} requests; "
          f"sample generation: {results[0][:8]}")
    print(metrics.to_csv())


if __name__ == "__main__":
    main()
