"""Quickstart: declare workloads, let the platform run them.

    PYTHONPATH=src python examples/quickstart.py

The paper's platform is manifest-driven: you declare WHAT should run and
the control plane schedules, measures and heals it.  This example does
exactly that end to end — a ``TrainJob`` and a ``ServeJob`` declared as
manifests (the kubectl-JSON analogue), applied through one ``Session``
on a one-host cluster, observed through the same Handle verbs every
workload kind shares.  Runs in ~a minute on one CPU.
"""
from repro.api import ServeJob, Session, TrainJob, from_manifest
from repro.core.orchestrator import Cluster


def main():
    session = Session(cluster=Cluster())

    print("=== train (reduced phi4-family config, declared as a manifest) ===")
    train = TrainJob(name="quickstart-train", steps=20, seq_len=64,
                     global_batch=4, log_every=5)
    manifest = train.to_manifest()          # dict/JSON — the declaration
    assert from_manifest(manifest) == train, "manifest round-trip is lossless"
    out = session.apply(manifest).wait()
    losses = out["losses"]
    print(f"loss: {losses[0]:.3f} -> {losses[-1]:.3f}")
    assert losses[-1] < losses[0], "training should reduce loss"

    print("\n=== serve (batched requests through the work queue) ===")
    handle = session.apply(ServeJob(name="quickstart-serve", n_requests=6,
                                    prompt_len=16, max_new_tokens=8,
                                    slots=2))
    out = handle.wait()
    results, metrics = out["results"], out["metrics"]
    print(f"served {len(results)} requests; "
          f"sample generation: {results[0][:8]}")
    print(metrics.to_csv())

    print("\n=== one lifecycle stream for both workloads ===")
    for status in session.status():
        print("  " + status.brief())
    states = [s.state.value for s in session.status()]
    assert states == ["Succeeded", "Succeeded"], states


if __name__ == "__main__":
    main()
