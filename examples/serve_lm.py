"""Continuous-batching LM serving through the work queue.

    PYTHONPATH=src python examples/serve_lm.py [--arch gemma2-9b]

A ``ServeJob`` declares the stream (requests with different stop
lengths, so slots evict early and refill from the queue mid-flight) and
the Session routes it to the continuous batcher — watch
``serve/slot_occupancy`` stay high while short and long requests mix.
"""
import argparse

from repro.api import ServeJob, Session
from repro.core.metrics import table_one
from repro.core.orchestrator import Cluster


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="phi4-mini-3.8b")
    ap.add_argument("--requests", type=int, default=8)
    args = ap.parse_args()
    job = ServeJob(name=f"serve-{args.arch}", arch=args.arch,
                   n_requests=args.requests, prompt_len=24,
                   max_new_tokens=12, slots=4, gen_lens=(12, 3, 6, 3))
    out = Session(cluster=Cluster()).apply(job).wait()
    results = out["results"]
    print(f"served {len(results)} requests on {args.arch} (reduced config)")
    for rid in sorted(results)[:3]:
        print(f"  request {rid}: generated {results[rid]}")
    print(out["metrics"].to_csv())
    print()
    print(table_one([out["report"]]))
    assert len(results) == args.requests


if __name__ == "__main__":
    main()
