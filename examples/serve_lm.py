"""Continuous-batching LM serving through the work queue.

    PYTHONPATH=src python examples/serve_lm.py [--arch gemma2-9b]

Requests land in the fault-tolerant WorkQueue (the paper's Redis job
queue); a fixed pool of decode slots serves them with per-request prefill
and one fused per-slot decode step per iteration.  Requests ask for
different stop lengths, so slots evict early and refill from the queue
mid-flight — watch ``serve/slot_occupancy`` stay high while short and
long requests mix.
"""
import argparse

from repro.launch.serve import serve, serving_report
from repro.core.metrics import table_one


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="phi4-mini-3.8b")
    ap.add_argument("--requests", type=int, default=8)
    args = ap.parse_args()
    results, metrics = serve(args.arch, smoke=True,
                             n_requests=args.requests, prompt_len=24,
                             gen=12, batch=4, gen_lens=[12, 3, 6, 3])
    print(f"served {len(results)} requests on {args.arch} (reduced config)")
    for rid in sorted(results)[:3]:
        print(f"  request {rid}: generated {results[rid]}")
    print(metrics.to_csv())
    print()
    print(table_one([serving_report(metrics)]))
    assert len(results) == args.requests


if __name__ == "__main__":
    main()
