"""Batched LM serving through the work queue (paper job pattern).

    PYTHONPATH=src python examples/serve_lm.py [--arch gemma2-9b]

Requests land in the fault-tolerant WorkQueue; the server forms batches,
prefills once (KV cache build), then decodes greedily with a donated cache.
"""
import argparse

from repro.launch.serve import serve


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="phi4-mini-3.8b")
    ap.add_argument("--requests", type=int, default=8)
    args = ap.parse_args()
    results, metrics = serve(args.arch, smoke=True,
                             n_requests=args.requests, prompt_len=24,
                             gen=12, batch=4)
    print(f"served {len(results)} requests on {args.arch} (reduced config)")
    for rid in sorted(results)[:3]:
        print(f"  request {rid}: generated {results[rid]}")
    print(metrics.to_csv())
    assert len(results) == args.requests


if __name__ == "__main__":
    main()
