"""The paper's CONNECT case study (§III) end to end: download -> FFN train
-> distributed flood-fill inference -> CONNECT object analysis, as a
measured, resumable 4-step workflow declared through the unified API.
Prints the paper's Table I for this run.

    PYTHONPATH=src python examples/connect_workflow.py [--full]

The DAG itself is attached declaratively: the ``WorkflowRun`` names the
``repro.apps.connect.pipeline:add_connect_steps`` entrypoint and sizes
the run through plain-JSON ``params`` — the whole example could be a
manifest file.  --full uses the paper-shaped grid (361x576); default is
a reduced grid so it finishes in a couple of minutes on one CPU.  Run it
twice with the same --root to see workflow-level resume (all steps skip).
"""
import argparse
import tempfile

from repro.api import Session, WorkflowRun
from repro.core.orchestrator import Cluster
from repro.data.objectstore import ObjectStore


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--root", default="")
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    root = args.root or tempfile.mkdtemp(prefix="connect-")

    if args.full:
        params = dict(n_chunks=4, download_workers=4, inference_workers=4,
                      vol=dict(lat=361, lon=576, frames=24),
                      train_steps=120)
    else:
        params = dict(
            n_chunks=2, download_workers=2, inference_workers=2,
            vol=dict(lat=48, lon=72, frames=16, events=2),
            ffn=dict(depth=3, width=12, fov=(8, 16, 16), flood_iters=3),
            train_steps=30, train_batch=4)

    session = Session(cluster=Cluster(), store=ObjectStore(root))
    out = session.apply(WorkflowRun(
        name="connect", namespace="atmos-science",
        entrypoint="repro.apps.connect.pipeline:add_connect_steps",
        params=params)).wait(timeout=3600)
    results = out["results"]
    print(f"\nworkflow state in {root}")
    for step, res in results.items():
        print(f"  {step}: {res}")
    print("\n" + out["table"])
    tr = results["train"]
    assert tr["last_loss"] < tr["first_loss"], "FFN training must improve"
    assert results["analyze"]["objects"] >= 1, "CONNECT should find objects"
    print("\nOK — objects tracked through time+space:",
          results["analyze"]["objects"])


if __name__ == "__main__":
    main()
