"""The paper's CONNECT case study (§III) end to end: download -> FFN train
-> distributed flood-fill inference -> CONNECT object analysis, as a
measured, resumable 4-step workflow.  Prints the paper's Table I for this
run.

    PYTHONPATH=src python examples/connect_workflow.py [--full]

--full uses the paper-shaped grid (361x576); default is a reduced grid so
the example finishes in a couple of minutes on one CPU.  Run it twice with
the same --root to see workflow-level resume (all steps skip).
"""
import argparse
import tempfile

from repro.apps.connect.pipeline import (ConnectConfig, run_connect_workflow)
from repro.data.volumes import VolumeSpec
from repro.models.ffn3d import FFNConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--root", default="")
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    root = args.root or tempfile.mkdtemp(prefix="connect-")

    if args.full:
        cc = ConnectConfig(n_chunks=4, download_workers=4,
                           inference_workers=4,
                           vol=VolumeSpec(lat=361, lon=576, frames=24),
                           train_steps=120)
    else:
        cc = ConnectConfig(
            n_chunks=2, download_workers=2, inference_workers=2,
            vol=VolumeSpec(lat=48, lon=72, frames=16, events=2),
            ffn=FFNConfig(depth=3, width=12, fov=(8, 16, 16), flood_iters=3),
            train_steps=30, train_batch=4)

    wf, results = run_connect_workflow(root, cc)
    print(f"\nworkflow state in {root}")
    for step, out in results.items():
        print(f"  {step}: {out}")
    print("\n" + wf.table_one())
    tr = results["train"]
    assert tr["last_loss"] < tr["first_loss"], "FFN training must improve"
    assert results["analyze"]["objects"] >= 1, "CONNECT should find objects"
    print("\nOK — objects tracked through time+space:",
          results["analyze"]["objects"])


if __name__ == "__main__":
    main()
