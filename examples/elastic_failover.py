"""Fault tolerance + elastic scaling demo (paper §V: "nodes can join and
leave the cluster at any time").

Scenario, on a simulated 8-device cluster (XLA host devices):
  1. train on a (4 data, 2 model) mesh with periodic checkpoints;
  2. two "nodes" FAIL -> only 6 devices remain; the elastic planner keeps
     the model axis (structural) and shrinks the data axis: new mesh (2, 2);
  3. state is restored from the checkpoint onto the NEW mesh (the
     checkpointer is mesh-agnostic) and training continues;
  4. the nodes come back -> scale up to (4, 2) again.

    PYTHONPATH=src python examples/elastic_failover.py
"""
import os

os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=8").strip()

import tempfile  # noqa: E402

import jax  # noqa: E402

from repro.checkpoint.checkpoint import Checkpointer  # noqa: E402
from repro.configs import registry  # noqa: E402
from repro.configs.base import OptimizerConfig, ShapeConfig  # noqa: E402
from repro.core.elastic import make_elastic_mesh, rescale_plan  # noqa: E402
from repro.core.orchestrator import Cluster  # noqa: E402
from repro.data.objectstore import ObjectStore  # noqa: E402
from repro.data.tokens import TokenPipeline  # noqa: E402
from repro.models import params as pr  # noqa: E402
from repro.optim import adamw  # noqa: E402
from repro.runtime import steps as steps_mod  # noqa: E402
from repro.sharding import specs as sh  # noqa: E402


def run_segment(cfg, par, ocfg, mesh, state, start, n_steps, pipe, ckpt,
                schema, opt_schema):
    rules = sh.logical_rules(par)
    shape = ShapeConfig("t", 64, 8, "train")
    bundle = steps_mod.build_train(cfg, par, ocfg, mesh, shape)
    step_fn = bundle.jit()
    params, opt = state
    with mesh:
        for i in range(start, start + n_steps):
            params, opt, m = step_fn(params, opt, pipe.batch(i))
            if (i + 1) % 5 == 0:
                ckpt.save(i, {"params": params, "opt": opt})
        print(f"  steps {start}..{start + n_steps - 1}: "
              f"loss {float(m['loss']):.4f} on mesh {dict(mesh.shape)}")
    return (params, opt), start + n_steps


def main():
    arch = "phi4-mini-3.8b"
    cfg = registry.get_smoke(arch)
    par = registry.get_parallel(arch)
    ocfg = OptimizerConfig(lr=1e-3, warmup_steps=2, decay_steps=100)
    shape = ShapeConfig("t", 64, 8, "train")
    cfg = steps_mod.resolve_cfg(cfg, shape)
    mod = steps_mod._model_module(cfg)
    schema = mod.lm_schema(cfg)
    opt_schema = adamw.opt_state_schema(schema, ocfg)

    cluster = Cluster(devices=jax.devices())
    store = ObjectStore(tempfile.mkdtemp(prefix="elastic-"))
    ckpt = Checkpointer(store, keep=2)
    pipe = TokenPipeline(cfg.vocab_size, 64, 8, seed=3)

    def abstract():
        return {"params": pr.abstract_params(schema, cfg.param_dtype),
                "opt": pr.abstract_params(opt_schema, "float32")}

    def shardings(mesh):
        rules = sh.logical_rules(par)
        return {"params": sh.shardings_for_schema(schema, mesh, rules),
                "opt": sh.shardings_for_schema(opt_schema, mesh, rules)}

    # --- phase 1: full cluster (4 data x 2 model)
    plan = rescale_plan(("data", "model"), (4, 2), len(cluster.online_devices))
    mesh = make_elastic_mesh(plan, cluster.online_devices)
    rules = sh.logical_rules(par)
    with mesh:
        params = jax.jit(lambda k: pr.init_params(schema, k, cfg.param_dtype),
                         out_shardings=shardings(mesh)["params"])(jax.random.key(0))
        opt = jax.jit(lambda: pr.init_params(opt_schema, jax.random.key(1),
                                             "float32"),
                      out_shardings=shardings(mesh)["opt"])()
    print("phase 1: healthy cluster")
    state, step = run_segment(cfg, par, ocfg, mesh, (params, opt), 0, 10,
                              pipe, ckpt, schema, opt_schema)

    # --- phase 2: two nodes fail -> shrink data axis, restore, continue
    for d in jax.devices()[6:]:
        cluster.fail_node(d)
    print(f"phase 2: {len(cluster.offline)} nodes failed "
          f"({len(cluster.online_devices)} online) -> re-mesh + restore")
    plan = rescale_plan(("data", "model"), (4, 2), len(cluster.online_devices))
    assert plan.new_shape == (2, 2), plan
    mesh2 = make_elastic_mesh(plan, cluster.online_devices)
    restored, meta = ckpt.restore_latest(abstract(), shardings(mesh2))
    state = (restored["params"], restored["opt"])
    state, step = run_segment(cfg, par, ocfg, mesh2, state,
                              int(meta["step"]) + 1, 10, pipe, ckpt,
                              schema, opt_schema)

    # --- phase 3: nodes rejoin -> scale back up
    for d in jax.devices()[6:]:
        cluster.join_node(d)
    print("phase 3: nodes rejoined -> scale up")
    plan = rescale_plan(("data", "model"), (2, 2), len(cluster.online_devices))
    assert plan.new_shape == (4, 2), plan
    mesh3 = make_elastic_mesh(plan, cluster.online_devices)
    restored, meta = ckpt.restore_latest(abstract(), shardings(mesh3))
    state = (restored["params"], restored["opt"])
    state, step = run_segment(cfg, par, ocfg, mesh3, state,
                              int(meta["step"]) + 1, 10, pipe, ckpt,
                              schema, opt_schema)
    print("OK: trained across failure, shrink, and re-grow "
          f"(final step {step - 1})")


if __name__ == "__main__":
    main()
