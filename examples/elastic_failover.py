"""Self-healing elastic training demo (paper §V: "nodes can join and leave
the cluster at any time").

ALL the control lives in the platform: a ``TrainJob`` declared through
``repro.api.Session`` runs as a supervised elastic workload, and this
script only injects a churn schedule against the cluster, exactly like
an unplugged appliance would:

  1. training starts on a (4 data, 2 model) mesh over 8 simulated nodes;
  2. two nodes FAIL mid-run: the cluster drains their pods, the trainer
     restores the latest checkpoint onto a (2, 2) mesh and DOUBLES gradient
     accumulation so the global batch is unchanged;
  3. the nodes REJOIN: the trainer preempts gracefully (checkpointing) and
     scales back up to (4, 2), accumulation relaxing to 1.

Asserts, with no manual intervention anywhere: the run reaches its final
step, every mesh shape kept batch x accum constant, there is a loss value
for every step, and the loss improved end-to-end.  Emits a
``CHURN_REPORT {json}`` line consumed by ``benchmarks/run.py`` (recovery
cost in tokens/s and steps lost is *measured*, not asserted).

    PYTHONPATH=src python examples/elastic_failover.py [--fast]
"""
import os

if "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                               " --xla_force_host_platform_device_count=8").strip()

import argparse   # noqa: E402
import json       # noqa: E402
import threading  # noqa: E402
import time       # noqa: E402

import jax        # noqa: E402

from repro.api import Session, TrainJob                  # noqa: E402
from repro.core.orchestrator import Cluster              # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="shorter run (CI churn smoke / benchmark)")
    ap.add_argument("--steps", type=int, default=0)
    args = ap.parse_args()
    steps = args.steps or (24 if args.fast else 45)
    fail_after = steps // 4          # churn points, in completed steps
    rejoin_after = steps // 2

    cluster = Cluster(devices=jax.devices())
    assert len(cluster.devices) == 8, "expected 8 forced host devices"
    session = Session(cluster=cluster)
    handle = session.apply(TrainJob(
        name="elastic-demo", steps=steps, seq_len=64, global_batch=16,
        base_shape=(4, 2), max_data=None,
        ckpt_every=3 if args.fast else 5, log_every=5,
        rejoin_timeout_s=120.0,
        optimizer={"lr": 1e-3, "warmup_steps": 2, "decay_steps": 200}))

    victims = jax.devices()[6:]

    def progress() -> int:
        return handle.status().observed.get("step", -1)

    def inject_churn():
        """The outside world: two nodes die, then come back."""
        while progress() < fail_after:
            time.sleep(0.02)
        print(f">>> churn: unplugging {len(victims)} nodes")
        for d in victims:
            cluster.fail_node(d)
        while progress() < rejoin_after:
            time.sleep(0.02)
        print(f">>> churn: {len(victims)} nodes rejoin")
        for d in victims:
            cluster.join_node(d)

    churn = threading.Thread(target=inject_churn, daemon=True)
    churn.start()
    out = handle.wait(timeout=3600)
    churn.join(timeout=10)
    report = out["report"]

    # --- the §V contract, checked end to end -----------------------------
    losses = out["loss_by_step"]
    assert sorted(losses) == list(range(steps)), "missing per-step losses"
    assert report.global_batch_constant, \
        "global batch (batch x accum) changed across mesh shapes"
    shapes = [s.mesh_shape for s in report.segments]
    assert (2, 2) in shapes, f"never trained on the shrunk mesh: {shapes}"
    assert shapes[-1] == (4, 2), f"never scaled back up: {shapes}"
    assert report.recoveries >= 1, "node failure was not recovered"
    accums = {s.mesh_shape: s.accum_steps for s in report.segments}
    assert accums[(2, 2)] == 2 * accums[(4, 2)], accums
    assert out["losses"][-1] < out["losses"][0], "loss did not improve"
    assert handle.state.value == "Succeeded", handle.state

    print("CHURN_REPORT " + json.dumps(report.to_json()))
    print(f"OK: self-healed across fail({fail_after})/rejoin({rejoin_after}) "
          f"churn — {report.recoveries} recovery, "
          f"{report.steps_lost} steps lost, "
          f"{report.tokens_per_s:,.0f} tokens/s overall "
          f"(final step {steps - 1}, mesh history {shapes})")


if __name__ == "__main__":
    main()
