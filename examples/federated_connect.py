"""The CONNECT pipeline across a 3-site federation (paper §I, §IV).

CHASE-CI is a *network* of GPU appliances on the Pacific Research
Platform, not one cluster: data lives where it was ingested, links have
real bandwidth, and virtual-cluster management decides whether a step's
pods go to the data or the data comes to the pods.  This example runs
the paper's CONNECT case study through the unified API — a
``WorkflowRun`` applied to a ``Session(fabric=..., planner=...)`` — with
three unequal sites and makes that trade-off measurable:

  1. locality-aware placement: each step lands on the site that
     minimizes  bytes_to_move / link_bw + queue_depth  — the per-step
     Table-I report gains `Site`, `bytes_moved`, `transfer_s` columns;
  2. data-blind placement (round-robin) serves identical results but
     drags chunks across the 1 Gbps links — asserted to move MORE bytes;
  3. a whole-site kill after the download step: the planner routes the
     remaining steps around the dead appliance (raw chunks survive via
     their one off-site replica), the workflow completes on the
     survivors, and the migrated step is recorded in its report.

    PYTHONPATH=src python examples/federated_connect.py [--fast]

Emits a ``FABRIC_REPORT {json}`` line consumed by
``benchmarks/run.py::bench_fabric_placement`` / CI.
"""
import argparse
import json
import time

from repro.api import Session, WorkflowRun
from repro.apps.connect.pipeline import ConnectConfig, add_connect_steps
from repro.data.volumes import VolumeSpec
from repro.fabric import Fabric, FederatedStore, PlacementPlanner
from repro.models.ffn3d import FFNConfig


def build_fabric(time_scale: float) -> Fabric:
    """Three unequal PRP-ish sites: a big hub and two smaller spokes,
    10 Gbps in the core, 1 Gbps to the edge."""
    fabric = Fabric(time_scale=time_scale)
    fabric.add_site("sdsc", devices=list(range(4)))
    fabric.add_site("calit2", devices=list(range(2)))
    fabric.add_site("edge", devices=list(range(1)))
    fabric.connect("sdsc", "calit2", gbps=10.0, latency_ms=3.0)
    fabric.connect("sdsc", "edge", gbps=1.0, latency_ms=12.0)
    fabric.connect("calit2", "edge", gbps=1.0, latency_ms=12.0)
    return fabric


def run_once(cc: ConnectConfig, *, data_blind: bool, kill_site: str = "",
             time_scale: float = 0.0):
    fabric = build_fabric(time_scale)
    planner = PlacementPlanner(FederatedStore(fabric), data_blind=data_blind)
    session = Session(fabric=fabric, planner=planner)

    def run(only=""):
        spec = WorkflowRun(name="connect", namespace="atmos-science",
                           only=only or None,
                           define=lambda wf: add_connect_steps(wf, cc))
        return session.apply(spec).wait(timeout=3600)

    t0 = time.perf_counter()
    if kill_site:
        run(only="download")           # chunks scattered + 1 replica each
        print(f">>> site {kill_site!r} unplugged (whole appliance)")
        fabric.fail_site(kill_site)
        out = run()                    # resume: download skipped, rest placed
    else:
        out = run()
    makespan = time.perf_counter() - t0
    reports = out["reports"]
    stats = {
        "planner": "blind" if data_blind else "locality",
        "bytes_moved": int(fabric.metrics.series("fabric/bytes_moved").total),
        "transfer_s": round(fabric.metrics.series("fabric/transfer_s").total, 4),
        "makespan_s": round(makespan, 3),
        "sites": {r.step: r.site for r in reports},
        "migrated": [r.step for r in reports if "migrated" in r.extra],
    }
    return fabric, out, stats


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="smaller volumes (CI fabric smoke / benchmark)")
    ap.add_argument("--time-scale", type=float, default=0.02,
                    help="real seconds slept per simulated transfer second")
    args = ap.parse_args()

    cc = ConnectConfig(
        n_chunks=3, download_workers=3, inference_workers=2,
        vol=VolumeSpec(lat=32, lon=48, frames=8, events=2) if args.fast
        else VolumeSpec(lat=48, lon=72, frames=16, events=2),
        ffn=FFNConfig(depth=3, width=12, fov=(8, 16, 16), flood_iters=2),
        train_steps=10 if args.fast else 30)

    # --- 1+2: locality-aware vs data-blind on identical inputs -----------
    _, out_loc, loc = run_once(cc, data_blind=False,
                               time_scale=args.time_scale)
    _, out_bld, bld = run_once(cc, data_blind=True,
                               time_scale=args.time_scale)
    res_loc, res_bld = out_loc["results"], out_bld["results"]
    assert res_bld["analyze"]["objects"] == res_loc["analyze"]["objects"], \
        "placement must not change results"
    assert loc["bytes_moved"] < bld["bytes_moved"], \
        f"locality planner must move fewer bytes: {loc} vs {bld}"
    assert loc["transfer_s"] <= bld["transfer_s"]

    # --- 3: whole-site failure after download ----------------------------
    # chunk 0 (the training input) homes at the hub; kill the hub
    fabric_kill, out_kill, kill = run_once(cc, data_blind=False,
                                           kill_site="sdsc",
                                           time_scale=args.time_scale)
    res_kill = out_kill["results"]
    assert res_kill["analyze"]["objects"] >= 1, "workflow must complete"
    post_kill = [r for r in out_kill["reports"] if r.step != "download"]
    assert post_kill and all(r.site != "sdsc" for r in post_kill), \
        f"steps ran on a dead site: {[(r.step, r.site) for r in post_kill]}"
    assert kill["migrated"], "site kill must be recorded as a migration"
    skipped = fabric_kill.metrics.series("workflow/connect/download/skipped")
    assert skipped.points, "download must resume, not rerun, after the kill"

    print("\n--- locality-aware (Table I with Site / bytes_moved rows) ---")
    print(out_loc["table"])
    print("\n--- after killing 'sdsc' mid-workflow ---")
    print(out_kill["table"])
    print("\nFABRIC_REPORT " + json.dumps(
        {"locality": loc, "blind": bld, "site_kill": kill}))
    saved = bld["bytes_moved"] - loc["bytes_moved"]
    print(f"\nOK — locality placement moved {loc['bytes_moved']:,}B vs "
          f"{bld['bytes_moved']:,}B data-blind (saved {saved:,}B, "
          f"{bld['transfer_s'] - loc['transfer_s']:.2f} simulated link-s); "
          f"site-kill migrated {kill['migrated']} and still finished "
          f"({res_kill['analyze']['objects']} objects).")


if __name__ == "__main__":
    main()
