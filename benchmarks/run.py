"""Benchmark harness — one function per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--fast] [--json PATH]

Outputs ``name,us_per_call,derived`` CSV rows:
  table1_*   — paper Table I: per-step resource summary of the CONNECT
               workflow (time per step; derived = data bytes processed).
  fig3_*     — paper Figs 3-4: queue-fed download job, worker scaling
               (derived = MB/s aggregate throughput).
  fig5_*     — paper Fig 5: FFN training step (derived = voxels/s).
  fig6_*     — paper Fig 6: distributed inference worker scaling
               (derived = voxels/s; speedup printed vs 1 worker).
  lm_train_* — LM substrate: one sharded train step on the smoke config
               (derived = tokens/s).
  train_*    — device-resident hot loop: per-step dispatch vs chunked
               lax.scan dispatch on the elastic trainer (derived =
               tokens/s; extras = host syncs/step, time-to-first-step).
  serve_*    — serving: prefill latency + decode steps/s.
  fabric_*   — multi-site federation: locality-aware vs data-blind
               placement (derived = bytes moved over the links).
  workflow_* — workflow programs (repro.flow): diamond-with-fan-out
               graph makespan, serial vs concurrent branches spread
               across a 3-site fabric (derived = makespan + ratio).
  vcluster_* — multi-tenant fair share: dominant-share scheduling vs
               FIFO skew, preemption/resume cost, monitor event lag.
  scenario_* — production-chaos harness: diurnal replay under site
               loss / link brown-out; per-tenant SLO scorecards
               (goodput, p99, steps lost, chargeback).

``--only SUBSTR`` runs only the benches whose name contains SUBSTR
(e.g. ``--only scenarios`` regenerates just BENCH_scenarios.json).

``--json PATH`` additionally writes the whole run as one trajectory
record: every row as an object with its structured extras (``tok_s``,
``bytes_moved``, ``transfer_s``, ...), so cross-PR tooling can track
throughput and data movement in the same file.
"""
from __future__ import annotations

import argparse
import json
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

ROWS = []
JSON_SCHEMA = "repro-bench/v1"

# The documented vocabulary of structured row extras.  Every key a bench
# passes to ``row(**extra)`` must be registered here — the committed
# BENCH_*.json files are validated against this set by
# tests/test_bench_schema.py, so cross-PR tooling can rely on the names.
KNOWN_EXTRA_KEYS = frozenset({
    # data movement / placement
    "bytes", "bytes_moved", "transfer_s", "makespan_s",
    # throughput
    "tok_s",
    # hot-loop dispatch (train_* rows)
    "host_syncs_per_step", "t_first_s", "device_steps",
    # elasticity / preemption
    "steps_lost", "preemptions", "recoveries",
    # fair share / monitoring
    "makespan_ratio", "fifo_skew", "monitor_lag_s", "monitor_events",
    # workflow fan-out (workflow_* rows)
    "width", "fanout_ratio", "branch_sites",
    # chaos scenarios
    "fairshare_skew", "chaos_applied", "windows", "horizon_s",
    "offered", "served", "goodput", "slo_pass",
    "p99_ttft_s", "p99_latency_s", "chargeback_usd",
    # serving at scale (serving_* rows)
    "prefix_hit_rate", "scale_events", "replicas_max", "stale_tokens",
    # distributed RL (rl_* rows)
    "rollout_tok_s", "learner_steps_s", "policy_lag_p99",
    "max_lag_trained", "trained", "stale_dropped", "requeued_tickets",
    "weight_syncs", "crashes",
})


def row(name: str, us_per_call: float, derived: str = "", **extra):
    """One benchmark row.  ``extra`` keys (numbers) land verbatim in the
    JSON trajectory record — bytes_moved / transfer_s / tok_s share one
    schema with the paper-figure timings."""
    ROWS.append({"name": name, "us_per_call": round(us_per_call, 1),
                 "derived": derived, **extra})
    print(f"{name},{us_per_call:.1f},{derived}")


# ---------------------------------------------------------------------------

def bench_connect_workflow(fast: bool):
    """Table I + the 4-step CONNECT workflow, measured end to end."""
    from repro.apps.connect.pipeline import ConnectConfig, run_connect_workflow
    from repro.data.volumes import VolumeSpec
    from repro.models.ffn3d import FFNConfig

    cc = ConnectConfig(
        n_chunks=2, download_workers=2, inference_workers=2,
        vol=VolumeSpec(lat=48, lon=72, frames=16, events=2),
        ffn=FFNConfig(depth=3, width=12, fov=(8, 16, 16), flood_iters=2),
        train_steps=10 if fast else 30)
    with tempfile.TemporaryDirectory() as d:
        wf, results = run_connect_workflow(d, cc)
    for rep in wf.reports:
        row(f"table1_{rep.step}", rep.total_time_s * 1e6,
            f"bytes={rep.data_processed_bytes}",
            bytes=rep.data_processed_bytes)
    return results


def bench_queue_scaling(fast: bool):
    """Figs 3-4: download throughput vs worker count (work-queue scaling)."""
    from repro.core.queue import WorkQueue, run_workers
    from repro.data import volumes
    from repro.data.objectstore import ObjectStore

    spec = volumes.VolumeSpec(lat=48, lon=72, frames=8, events=1)
    n_chunks = 4 if fast else 8
    for workers in (1, 2, 4):
        with tempfile.TemporaryDirectory() as d:
            store = ObjectStore(d)
            q = WorkQueue(list(range(n_chunks)))
            nbytes = {"n": 0}

            def fetch(cid):
                ivt, lab = volumes.generate_chunk(spec, cid)
                nbytes["n"] += store.put_array(f"c{cid}/ivt.npy", ivt)
                nbytes["n"] += store.put_array(f"c{cid}/lab.npy", lab)

            t0 = time.perf_counter()
            run_workers(q, fetch, workers)
            dt = time.perf_counter() - t0
        row(f"fig3_download_w{workers}", dt / n_chunks * 1e6,
            f"MBps={nbytes['n'] / 2**20 / dt:.1f}")


def bench_ffn_train(fast: bool):
    """Fig 5: FFN 3-D CNN training step."""
    from repro.models import ffn3d
    from repro.models.params import init_params

    cfg = ffn3d.FFNConfig(depth=3, width=12, fov=(8, 16, 16))
    params = init_params(ffn3d.ffn_schema(cfg), jax.random.key(0), "float32")
    B = 4
    x = jax.random.uniform(jax.random.key(1), (B,) + cfg.fov)
    y = (x > 0.6).astype(jnp.float32)

    @jax.jit
    def step(p, x, y):
        loss, g = jax.value_and_grad(
            lambda p: ffn3d.bce_loss(cfg, p, x, y))(p)
        return jax.tree.map(lambda a, b: a - 1e-3 * b, p, g), loss

    params, _ = step(params, x, y)          # compile
    n = 3 if fast else 10
    t0 = time.perf_counter()
    for _ in range(n):
        params, loss = step(params, x, y)
    jax.block_until_ready(loss)
    dt = (time.perf_counter() - t0) / n
    vox = B * int(np.prod(cfg.fov))
    row("fig5_ffn_train_step", dt * 1e6, f"voxels_s={vox / dt:.0f}")


def bench_inference_scaling(fast: bool):
    """Fig 6 / §III-C: flood-fill inference scaling with worker count."""
    from repro.core.queue import WorkQueue, run_workers
    from repro.models import ffn3d
    from repro.models.params import init_params

    cfg = ffn3d.FFNConfig(depth=3, width=12, fov=(8, 16, 16), flood_iters=2)
    params = init_params(ffn3d.ffn_schema(cfg), jax.random.key(0), "float32")

    @jax.jit
    def infer(x):
        return jax.nn.sigmoid(ffn3d.flood_fill(cfg, params, x)) > 0.5

    tile = jax.random.uniform(jax.random.key(1), (4,) + cfg.fov)
    np.asarray(infer(tile))                 # compile once
    n_tiles = 8 if fast else 16
    base = None
    for workers in (1, 2, 4):
        q = WorkQueue(list(range(n_tiles)))
        t0 = time.perf_counter()
        run_workers(q, lambda i: np.asarray(infer(tile)).sum(), workers)
        dt = time.perf_counter() - t0
        vox = n_tiles * tile.size
        if base is None:
            base = dt
        row(f"fig6_inference_w{workers}", dt / n_tiles * 1e6,
            f"voxels_s={vox / dt:.0f};speedup={base / dt:.2f}")


def bench_lm_train(fast: bool):
    """LM substrate: sharded train step on the reduced phi4 config."""
    from repro.configs import registry
    from repro.configs.base import OptimizerConfig, ShapeConfig
    from repro.launch.mesh import single_device_mesh
    from repro.models import params as pr
    from repro.optim import adamw
    from repro.runtime import steps as steps_mod

    cfg = registry.get_smoke("phi4-mini-3.8b")
    shape = ShapeConfig("b", 128, 4, "train")
    mesh = single_device_mesh()
    ocfg = OptimizerConfig(warmup_steps=2, decay_steps=100)
    bundle = steps_mod.build_train(cfg, registry.get_parallel("phi4-mini-3.8b"),
                                   ocfg, mesh, shape)
    mod = steps_mod._model_module(cfg)
    schema = mod.lm_schema(cfg)
    params = pr.init_params(schema, jax.random.key(0), cfg.param_dtype)
    opt = pr.init_params(adamw.opt_state_schema(schema, ocfg),
                         jax.random.key(1), "float32")
    batch = {"tokens": jnp.ones((4, 128), jnp.int32),
             "labels": jnp.ones((4, 128), jnp.int32)}
    with mesh:
        step = bundle.jit()
        params, opt, m = step(params, opt, batch)   # compile
        n = 3 if fast else 10
        t0 = time.perf_counter()
        for _ in range(n):
            params, opt, m = step(params, opt, batch)
        jax.block_until_ready(m["loss"])
        dt = (time.perf_counter() - t0) / n
    row("lm_train_step_smoke", dt * 1e6, f"tokens_s={4 * 128 / dt:.0f}")


def bench_train_hot_loop(fast: bool):
    """Device-resident hot loop: per-step vs chunked (lax.scan) dispatch.

    Runs the SAME elastic training job twice — ``device_steps=1`` (one
    host dispatch + loss bookkeeping per optimizer step) and
    ``device_steps=K`` (one dispatch per K steps, losses flushed in bulk
    at chunk boundaries, batches prefetched by a background thread) —
    and records the trajectory numbers the refactor is about: useful
    tokens/s, host round-trips per optimizer step (O(1) vs O(1/K)), and
    time-to-first-step (restore + compile + first dispatch; the chunked
    run compiles a K-step scan, so its t_first is the cost side of the
    trade).  Losses are bit-identical between the two runs (pinned by
    tests/test_train_hot_loop.py), so this is pure dispatch overhead.
    """
    import tempfile as _tf

    from repro.configs import registry
    from repro.configs.base import OptimizerConfig
    from repro.core.orchestrator import Cluster
    from repro.data.objectstore import ObjectStore
    from repro.elastic import ElasticTrainer, ElasticTrainSpec

    cfg = registry.get_smoke("phi4-mini-3.8b")
    par = registry.get_parallel("phi4-mini-3.8b")
    steps = 16 if fast else 48
    K = 4

    def run(device_steps: int):
        spec = ElasticTrainSpec(
            cfg, par, OptimizerConfig(warmup_steps=2, decay_steps=100),
            steps=steps, seq_len=64, global_batch=8, base_shape=(1, 1),
            max_data=1, ckpt_every=0, log_every=0, verbose=False,
            device_steps=device_steps)
        with _tf.TemporaryDirectory() as d:
            trainer = ElasticTrainer(Cluster(devices=jax.devices()), spec,
                                     store=ObjectStore(d))
            out = trainer.run()
        rep = out["report"]
        assert len(out["losses"]) == steps
        return rep

    base = run(1)
    for tag, rep in (("per_step", base), (f"chunked_k{K}", run(K))):
        row(f"train_{tag}", rep.total_wall_s / steps * 1e6,
            f"tok_s={rep.tokens_per_s:.0f};"
            f"syncs_per_step={rep.host_syncs_per_step:.2f};"
            f"t_first_s={rep.t_first_s:.2f}",
            tok_s=round(rep.tokens_per_s, 1),
            host_syncs_per_step=round(rep.host_syncs_per_step, 4),
            t_first_s=round(rep.t_first_s, 3),
            device_steps=1 if tag == "per_step" else K)


def bench_serve(fast: bool):
    """Serving: continuous batching vs the static drain-then-refill batcher.

    The workload is straggler-heavy on purpose (one long request per
    static batch, the rest short): the static batcher's short requests
    idle their decode slots until the long one finishes, while the
    continuous batcher evicts and refills them immediately.  Both paths
    serve identical requests, warmed up so compile time is off the clock;
    ``tok_s`` is useful generated tokens / wall seconds.
    """
    from repro.launch.serve import make_requests, serve, serve_static

    # skew is the point, so --fast keeps the long requests long: the
    # static barrier costs 2 batches x 32 fused steps vs ~33 continuous
    long_g = 32
    kw = dict(smoke=True, n_requests=8, prompt_len=16, gen=long_g,
              batch=4, gen_lens=[long_g, 2, 2, 2], warmup=True)
    reps = 2 if fast else 3

    def best(fn):
        runs = [fn("phi4-mini-3.8b", **kw)[1].scrape() for _ in range(reps)]
        return min(runs, key=lambda m: m["serve/wall_s"])

    s, c = best(serve_static), best(serve)
    row("serve_static", s["serve/wall_s"] * 1e6,
        f"tok_s={s['serve/tok_s']:.1f}", tok_s=s["serve/tok_s"])
    row("serve_continuous", c["serve/wall_s"] * 1e6,
        f"tok_s={c['serve/tok_s']:.1f};"
        f"speedup={c['serve/tok_s'] / max(s['serve/tok_s'], 1e-9):.2f}",
        tok_s=c["serve/tok_s"])


def bench_serving_scale(fast: bool):
    """Serving at scale: static batcher vs an autoscaled paged+prefix
    replica fleet on shared-prefix, straggler-skewed traffic.

    Every request shares one block-aligned system-prompt head (the radix
    prefix cache's case) and stop lengths are skewed (one straggler per
    four requests).  The baseline is the drain-then-refill static
    batcher; the challenger runs the paged-KV engines behind the
    session-affine router with the HPA-style autoscaler (1 -> 2
    replicas off the arrival burst).  Both arms report p99 TTFT measured
    from ENQUEUE and tok/s counting only acked completions — the two
    numbers the serving-loop bug burn-down corrected.  Engines are
    prebuilt+warmed so replica cold-start is process-level, not compile.

    The smoke config is scaled up (2 layers, d_model 256) so a fused
    decode step carries real device work: on the tiny smoke shapes the
    host loop dominates and neither continuous batching nor replication
    can show through.
    """
    import dataclasses
    import threading

    from repro.configs import registry as cfg_registry
    from repro.core.metrics import Registry
    from repro.launch.mesh import single_device_mesh
    from repro.launch.serve import serve_static
    from repro.serving import GAUGES, ServingEngine, serve_replicated

    arch = "phi4-mini-3.8b"
    cfg = dataclasses.replace(
        cfg_registry.get_smoke(arch), num_layers=2, d_model=256, d_ff=512,
        num_heads=8, num_kv_heads=4, head_dim=32,
        block_pattern=("attn", "attn"))
    par = cfg_registry.get_parallel(arch)
    mesh = single_device_mesh()
    Pp, G, slots, bs = 16, 32, 4, 8
    n = 16 if fast else 32
    rng = np.random.RandomState(0)
    head = rng.randint(1, cfg.vocab_size, bs).tolist()   # shared system block
    gens = [G, 2, 2, 2]
    reqs = [{"id": i, "session": f"user-{i % 4}",
             "prompt": head + rng.randint(1, cfg.vocab_size, Pp - bs).tolist(),
             "max_new_tokens": gens[i % len(gens)]}
            for i in range(n)]

    s_res, s_m = serve_static(arch, smoke=True, n_requests=n, prompt_len=Pp,
                              gen=G, batch=slots, warmup=True, requests=reqs,
                              cfg_override=cfg)
    s_tok = s_m.series(GAUGES.TOK_S).last
    s_p99 = s_m.series(GAUGES.TTFT_S).percentile(99)
    row("serving_static", s_m.series(GAUGES.WALL_S).last * 1e6,
        f"tok_s={s_tok:.1f};p99_ttft={s_p99:.3f}",
        tok_s=s_tok, p99_ttft_s=s_p99)

    fleet = Registry()
    prebuilt = [ServingEngine(cfg, par, mesh, num_slots=slots,
                              prompt_len=Pp, max_new_tokens=G, seed=0,
                              registry=fleet, paged=True, block_size=bs)
                for _ in range(2)]
    with mesh:
        for e in prebuilt:
            e.warmup()
    avail, lock = list(prebuilt), threading.Lock()

    class Pooled:
        """Checks a prebuilt engine out for one replica lifetime."""
        def __init__(self):
            with lock:
                self.engine = avail.pop()

        def run(self, *a, **kw):
            try:
                return self.engine.run(*a, **kw)
            finally:
                with lock:
                    avail.append(self.engine)

    results, m, events = serve_replicated(
        lambda name, reg: Pooled(), reqs, min_replicas=1, max_replicas=2,
        target_backlog=2.0, registry=fleet, reconcile_interval=0.01,
        timeout_s=300.0)
    assert sorted(results) == list(range(n)), "fleet dropped requests"
    tok = m.series(GAUGES.TOK_S).last
    p99 = m.series(GAUGES.TTFT_S).percentile(99)
    hits = m.series(GAUGES.PREFIX_HITS).total
    misses = m.series(GAUGES.PREFIX_MISSES).total
    hit_rate = hits / max(hits + misses, 1.0)
    row("serving_paged_autoscaled", m.series(GAUGES.WALL_S).last * 1e6,
        f"tok_s={tok:.1f};p99_ttft={p99:.3f};"
        f"speedup={tok / max(s_tok, 1e-9):.2f};prefix_hit={hit_rate:.2f}",
        tok_s=tok, p99_ttft_s=p99, prefix_hit_rate=hit_rate,
        scale_events=float(len(events)),
        replicas_max=m.series(GAUGES.REPLICAS).max,
        stale_tokens=m.series(GAUGES.STALE_TOKENS).total)


def bench_elastic_churn(fast: bool):
    """Elastic recovery cost across an injected kill/rejoin schedule.

    Runs ``examples/elastic_failover.py`` (8 forced host devices, 2 killed
    mid-run, later rejoining) in a subprocess — the device count is an XLA
    flag that must be set before jax initializes, so it cannot run in this
    process — and parses its ``CHURN_REPORT`` json: overall tokens/s with
    every recovery (restore + recompile + re-executed steps) on the clock,
    steps lost to the failure, and wall-seconds from node death to the
    first step completed on the reshaped mesh.
    """
    import json
    import os
    import subprocess
    import sys

    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(root, "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    cmd = [sys.executable, os.path.join(root, "examples",
                                        "elastic_failover.py")]
    if fast:
        cmd.append("--fast")
    out = subprocess.run(cmd, env=env, capture_output=True, text=True,
                         timeout=900)
    if out.returncode != 0:
        raise RuntimeError(f"elastic churn bench failed:\n{out.stdout}"
                           f"\n{out.stderr}")
    rep = next(json.loads(l.split(" ", 1)[1]) for l in out.stdout.splitlines()
               if l.startswith("CHURN_REPORT "))
    steps = rep["steps"]
    row("elastic_churn_train", rep["total_wall_s"] / steps * 1e6,
        f"tok_s={rep['tokens_per_s']:.1f};recoveries={rep['recoveries']}",
        tok_s=rep["tokens_per_s"])
    recovery = (sum(rep["recovery_s"]) / len(rep["recovery_s"])
                if rep["recovery_s"] else 0.0)
    overhead = rep["tokens_executed"] / max(
        steps * rep["global_batch"] * rep["seq_len"], 1) - 1.0
    row("elastic_churn_recovery", recovery * 1e6,
        f"steps_lost={rep['steps_lost']};reexec_overhead={overhead:.1%}",
        steps_lost=rep["steps_lost"])


def bench_fabric_placement(fast: bool):
    """Multi-site federation (paper §IV): locality-aware vs data-blind
    placement on a 3-site fabric with skewed data.

    Most of the dataset homes at one hub site; the spokes hang off slow
    links.  Both planners run the identical 2-step workflow (a chunk
    "stats" pass, then a reduce over its output) with ``time_scale=1.0``,
    so wall-clock IS the simulated makespan: the data-blind round-robin
    drags chunks across the slow links, the locality planner runs at the
    data.  Locality must move strictly fewer bytes at no makespan cost.
    """
    from repro.core.workflow import Step, Workflow
    from repro.fabric import Fabric, FederatedStore, PlacementPlanner

    n_chunks = 4 if fast else 6
    chunk_mb = 2 if fast else 8

    def run(data_blind: bool):
        fabric = Fabric(time_scale=1.0)
        fabric.add_site("hub", devices=list(range(4)))
        fabric.add_site("spoke-a", devices=list(range(2)))
        fabric.add_site("spoke-b", devices=list(range(1)))
        fabric.connect("hub", "spoke-a", gbps=0.2, latency_ms=10.0)
        fabric.connect("hub", "spoke-b", gbps=0.1, latency_ms=20.0)
        fabric.connect("spoke-a", "spoke-b", gbps=0.1, latency_ms=20.0)
        fed = FederatedStore(fabric)
        rng = np.random.RandomState(0)
        keys = []
        for i in range(n_chunks):
            # skew: all but one chunk homes at the hub
            site = "hub" if i % n_chunks else "spoke-a"
            key = f"chunks/c{i}.npy"
            fed.view(site).put_array(
                key, rng.rand(chunk_mb * 2**20 // 8).astype(np.float64))
            keys.append(key)
        planner = PlacementPlanner(fed, data_blind=data_blind)
        wf = Workflow("fabric-bench", planner=planner)
        for i, key in enumerate(keys):      # one measured pass per chunk
            wf.add(Step(f"stats{i}",
                        lambda ctx, k=key: {
                            "mean": float(ctx.store.get_array(k).mean())},
                        inputs=[key]))
        wf.add(Step("reduce", lambda ctx: {
            "mean": float(np.mean([v["mean"] for v in ctx.inputs.values()]))},
            deps=[f"stats{i}" for i in range(n_chunks)]))
        t0 = time.perf_counter()
        wf.run()
        makespan = time.perf_counter() - t0
        m = fabric.metrics
        return (makespan, int(m.series("fabric/bytes_moved").total),
                m.series("fabric/transfer_s").total)

    for name, blind in (("fabric_locality", False), ("fabric_blind", True)):
        makespan, moved, sim_s = run(blind)
        row(name, makespan * 1e6,
            f"bytes_moved={moved};transfer_s={sim_s:.2f}",
            bytes_moved=moved, transfer_s=round(sim_s, 4),
            makespan_s=round(makespan, 3))


def bench_workflow_fanout(fast: bool):
    """Workflow programs (repro.flow, ISSUE 8): the diamond-with-fan-out
    graph on a 3-site fabric, serial branches vs the concurrent branch
    pool.

    Each scatter branch models an I/O-bound shard (a fixed simulated
    latency — the regime where the paper's Kepler programs win by
    running independent actors at different sites at once).  The SAME
    graph runs twice: ``max_workers=1`` dispatches the branches one at a
    time, ``max_workers=8`` overlaps them across the federation, spread
    by the planner's in-flight load accounting.  The acceptance bar is
    makespan ratio < 0.6; fresh stores per run, so no marker resume
    bleeds between the two."""
    from repro.core.workflow import Workflow
    from repro.fabric import Fabric, FederatedStore, PlacementPlanner
    from repro.flow import GraphRunner
    from repro.vcluster.monitor import EventBus

    width = 8 if fast else 12
    branch_s = 0.05

    def branch(ctx):
        time.sleep(branch_s)                  # simulated shard latency
        return {"i": ctx.inputs["index"]}

    graph = {"nodes": [
        {"step": "plan", "fn": lambda ctx: {
            "chunks": [f"c{i}" for i in range(width)]}},
        {"step": "seg", "deps": ["plan"], "fn": branch,
         "scatter": {"over": "plan.chunks"}},
        {"step": "left", "deps": ["plan"], "fn": lambda ctx: {
            "n": len(ctx.inputs["plan"]["chunks"])}},
        {"step": "join", "deps": ["seg", "left"], "fn": lambda ctx: {
            "segs": len(ctx.inputs["seg"])}},
    ]}

    def run(max_workers):
        fabric = Fabric(time_scale=0.0)
        for i in range(3):
            fabric.add_site(f"s{i}", devices=list(range(2)))
        for a, b in (("s0", "s1"), ("s0", "s2"), ("s1", "s2")):
            fabric.connect(a, b, gbps=1.0, latency_ms=10.0)
        bus = EventBus()
        sub = bus.subscribe(maxlen=4096)
        wf = Workflow("fanout-bench",
                      planner=PlacementPlanner(FederatedStore(fabric)),
                      bus=bus)
        t0 = time.perf_counter()
        out = GraphRunner(wf, graph, max_workers=max_workers).run()
        makespan = time.perf_counter() - t0
        assert out["join"]["segs"] == width
        sites = {e.data["site"] for e in sub.poll()
                 if e.kind == "branch" and e.data.get("status") == "done"}
        return makespan, len(sites)

    serial, _ = run(1)
    conc, n_sites = run(8)
    ratio = conc / serial
    row("workflow_fanout_serial", serial / width * 1e6,
        f"makespan_s={serial:.2f}",
        makespan_s=round(serial, 3), width=width)
    row("workflow_fanout_concurrent", conc / width * 1e6,
        f"makespan_s={conc:.2f};ratio={ratio:.2f};sites={n_sites}",
        makespan_s=round(conc, 3), width=width,
        fanout_ratio=round(ratio, 3), branch_sites=n_sites)


def bench_vcluster_fairness(fast: bool):
    """Multi-tenant fair share (paper §I contribution 4, §IV).

    Runs ``examples/multitenant_fabric.py`` in a subprocess (it builds a
    serving engine and an elastic trainer, so it wants a fresh jax) and
    parses its ``VCLUSTER_REPORT``: two equal-share tenants on a
    saturated fabric under the dominant-share scheduler vs the FIFO
    baseline (makespan ratio vs completion skew), the trainer's
    checkpoint-then-evict preemption cost (steps lost on resume), and
    the monitor stream's end-to-end event lag.
    """
    import json
    import os
    import subprocess
    import sys

    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(root, "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    cmd = [sys.executable, os.path.join(root, "examples",
                                        "multitenant_fabric.py")]
    if fast:
        cmd.append("--fast")
    out = subprocess.run(cmd, env=env, capture_output=True, text=True,
                         timeout=900)
    if out.returncode != 0:
        raise RuntimeError(f"vcluster fairness bench failed:\n{out.stdout}"
                           f"\n{out.stderr}")
    rep = next(json.loads(l.split(" ", 1)[1]) for l in out.stdout.splitlines()
               if l.startswith("VCLUSTER_REPORT "))
    fair, fifo, prem = rep["fair"], rep["fifo"], rep["preemption"]
    mk = max(fair["alice"]["makespan_s"], fair["bob"]["makespan_s"])
    row("vcluster_fair_share", mk * 1e6,
        f"makespan_ratio={fair['makespan_ratio']};"
        f"fifo_skew={fifo['completion_skew']}",
        makespan_ratio=fair["makespan_ratio"],
        fifo_skew=fifo["completion_skew"])
    mon = prem["monitor"]
    row("vcluster_preempt_resume", mon["max_lag_s"] * 1e6,
        f"steps_lost={prem['steps_lost']};"
        f"preemptions={prem['preemptions']};"
        f"monitor_lag_s={mon['max_lag_s']}",
        steps_lost=prem["steps_lost"], preemptions=prem["preemptions"],
        monitor_lag_s=mon["max_lag_s"], monitor_events=mon["received"])


def bench_scenarios(fast: bool):
    """Production-chaos scenario harness (paper §IV measurement loop).

    Runs ``examples/scenario_chaos.py`` in a subprocess (it forces 8 XLA
    host devices before jax initializes) and parses its
    ``SCENARIO_REPORT`` json: three tenants replaying diurnal traffic
    through the declarative API while a site dies, a link browns out and
    nodes churn mid-wave.  One summary row carries the fair-share skew
    and wall time; one row per tenant carries its SLO scorecard —
    goodput ratio, p99 TTFT/latency, steps lost to preemption and the
    $-chargeback total.
    """
    import json
    import os
    import subprocess
    import sys

    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(root, "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    cmd = [sys.executable, os.path.join(root, "examples",
                                        "scenario_chaos.py")]
    if fast:
        cmd.append("--fast")
    out = subprocess.run(cmd, env=env, capture_output=True, text=True,
                         timeout=900)
    if out.returncode != 0:
        raise RuntimeError(f"scenario chaos bench failed:\n{out.stdout}"
                           f"\n{out.stderr}")
    rep = next(json.loads(l.split(" ", 1)[1]) for l in out.stdout.splitlines()
               if l.startswith("SCENARIO_REPORT "))
    chaos_applied = sum(1 for c in rep["chaos"] if c.get("applied"))
    row("scenario_chaos_run", rep["wall_s"] * 1e6,
        f"skew={rep['fairshare_skew']};chaos={chaos_applied}",
        fairshare_skew=rep["fairshare_skew"], chaos_applied=chaos_applied,
        windows=rep["windows"], horizon_s=rep["horizon_s"])
    for name, g in sorted(rep["tenants"].items()):
        row(f"scenario_tenant_{name}", g["makespan_s"] * 1e6,
            f"goodput={g['goodput_ratio']};slo_pass={g['slo_pass']};"
            f"steps_lost={g['steps_lost']}",
            offered=g["offered"], served=g["served"],
            goodput=g["goodput_ratio"], slo_pass=bool(g["slo_pass"]),
            p99_ttft_s=g["p99_ttft_s"], p99_latency_s=g["p99_latency_s"],
            steps_lost=g["steps_lost"],
            chargeback_usd=g["chargeback"]["total"])


def bench_rl(fast: bool):
    """Distributed RL co-tenants (paper §I, §IV, §VI).

    Runs ``examples/rl_cotenants.py`` in a subprocess (two serving
    engines + the learner hot loop want a fresh jax) and parses its
    ``RL_REPORT``: a serving-plane actor fleet feeding the elastic
    learner through the rollout queue while the chaos controller kills
    a lease-holding actor, resizes the fleet through the fair-share
    claim, preempts the learner with a burst tenant and injects one
    hard learner crash.  One row carries rollout generation throughput,
    one the learner's step rate with the staleness audit (p99 policy
    lag, stale drops), one the chaos/recovery accounting (steps lost
    vs the checkpoint bound, tickets requeued by the killed actor).
    """
    import json
    import os
    import subprocess
    import sys

    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(root, "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    cmd = [sys.executable, os.path.join(root, "examples", "rl_cotenants.py")]
    if fast:
        cmd.append("--fast")
    out = subprocess.run(cmd, env=env, capture_output=True, text=True,
                         timeout=900)
    if out.returncode != 0:
        raise RuntimeError(f"rl cotenants bench failed:\n{out.stdout}"
                           f"\n{out.stderr}")
    rep = next(json.loads(l.split(" ", 1)[1]) for l in out.stdout.splitlines()
               if l.startswith("RL_REPORT "))
    row("rl_rollout_fleet", rep["wall_s"] * 1e6 / max(rep["trained"], 1),
        f"tok_s={rep['rollout_tok_s']};rollouts={rep['rollouts_pushed']}",
        rollout_tok_s=rep["rollout_tok_s"], trained=rep["trained"],
        bytes_moved=rep["weight_bytes_pulled"])
    row("rl_learner_steps", rep["wall_s"] * 1e6 / max(rep["steps_done"], 1),
        f"steps_s={rep['learner_steps_s']};"
        f"lag_p99={rep['policy_lag_p99']};stale={rep['stale_dropped']}",
        learner_steps_s=rep["learner_steps_s"],
        policy_lag_p99=rep["policy_lag_p99"],
        max_lag_trained=rep["max_lag_trained"],
        stale_dropped=rep["stale_dropped"],
        weight_syncs=rep["weight_syncs"])
    row("rl_chaos_recovery", rep["wall_s"] * 1e6,
        f"steps_lost={rep['steps_lost']};"
        f"preemptions={rep['preemptions']};crashes={rep['crashes']};"
        f"requeued={rep['requeued_tickets']}",
        steps_lost=rep["steps_lost"], preemptions=rep["preemptions"],
        crashes=rep["crashes"], requeued_tickets=rep["requeued_tickets"])


BENCHES = [
    ("connect_workflow", lambda fast: bench_connect_workflow(fast)),
    ("queue_scaling", lambda fast: bench_queue_scaling(fast)),
    ("ffn_train", lambda fast: bench_ffn_train(fast)),
    ("inference_scaling", lambda fast: bench_inference_scaling(fast)),
    ("lm_train", lambda fast: bench_lm_train(fast)),
    ("train_hot_loop", lambda fast: bench_train_hot_loop(fast)),
    ("serve", lambda fast: bench_serve(fast)),
    ("serving_scale", lambda fast: bench_serving_scale(fast)),
    ("elastic_churn", lambda fast: bench_elastic_churn(fast)),
    ("fabric_placement", lambda fast: bench_fabric_placement(fast)),
    ("workflow_fanout", lambda fast: bench_workflow_fanout(fast)),
    ("vcluster_fairness", lambda fast: bench_vcluster_fairness(fast)),
    ("scenarios", lambda fast: bench_scenarios(fast)),
    ("rl", lambda fast: bench_rl(fast)),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--json", default="",
                    help="also write the rows as a JSON trajectory record")
    ap.add_argument("--only", default="",
                    help="run only benches whose name contains this substring")
    args, _ = ap.parse_known_args()
    print("name,us_per_call,derived")
    for name, fn in BENCHES:
        if args.only and args.only not in name:
            continue
        fn(args.fast)
    print(f"\n# {len(ROWS)} benchmark rows")
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"schema": JSON_SCHEMA, "created_unix": time.time(),
                       "fast": args.fast, "rows": ROWS}, f, indent=1)
        print(f"# json trajectory -> {args.json}")


if __name__ == "__main__":
    main()
