"""Per-kernel correctness: sweep shapes/dtypes, interpret=True vs ref oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.moe_gmm import gmm
from repro.kernels.ssm_scan import ssd_scan
from repro.kernels.wkv6 import wkv6


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else \
        dict(rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("B,H,Sq,Sk,dh", [
    (1, 1, 128, 128, 64),
    (2, 3, 256, 256, 64),
    (1, 2, 128, 384, 32),     # rectangular (prefill-like), Sq < Sk
    (2, 1, 512, 512, 128),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention(B, H, Sq, Sk, dh, dtype, causal):
    if causal and Sq != Sk:
        pytest.skip("causal offset variant covered by equal-length cases")
    k1, k2, k3 = jax.random.split(jax.random.key(0), 3)
    q = jax.random.normal(k1, (B, H, Sq, dh), dtype)
    k = jax.random.normal(k2, (B, H, Sk, dh), dtype)
    v = jax.random.normal(k3, (B, H, Sk, dh), dtype)
    out = flash_attention(q, k, v, causal=causal, block_q=128, block_k=128,
                          interpret=True)
    want = ref.attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


@pytest.mark.parametrize("B,S,H,hd,N,chunk", [
    (1, 64, 1, 16, 8, 16),
    (2, 128, 3, 32, 16, 32),
    (1, 256, 2, 64, 64, 128),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_scan(B, S, H, hd, N, chunk, dtype):
    ks = jax.random.split(jax.random.key(1), 5)
    x = jax.random.normal(ks[0], (B, S, H, hd), dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H))).astype(jnp.float32)
    a = -jnp.exp(jax.random.normal(ks[2], (H,))).astype(jnp.float32)
    B_ = jax.random.normal(ks[3], (B, S, N), dtype)
    C = jax.random.normal(ks[4], (B, S, N), dtype)
    out = ssd_scan(x, dt, a, B_, C, chunk=chunk, interpret=True)
    want, _ = ref.ssd_ref(x, dt, a, B_, C,
                          jnp.zeros((B, H, hd, N), jnp.float32))
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=4e-2 if dtype == jnp.bfloat16 else 1e-4,
                               atol=4e-2 if dtype == jnp.bfloat16 else 1e-4)


@pytest.mark.parametrize("B,S,H,hd,chunk", [
    (1, 64, 1, 16, 16),
    (2, 128, 2, 32, 32),
    (1, 128, 4, 64, 64),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_wkv6(B, S, H, hd, chunk, dtype):
    ks = jax.random.split(jax.random.key(2), 5)
    r = jax.random.normal(ks[0], (B, S, H, hd), dtype)
    k = jax.random.normal(ks[1], (B, S, H, hd), dtype)
    v = jax.random.normal(ks[2], (B, S, H, hd), dtype)
    logw = -jnp.exp(jax.random.normal(ks[3], (B, S, H, hd))).astype(jnp.float32)
    logw = jnp.maximum(logw, -8.0)
    u = jax.random.normal(ks[4], (H, hd), jnp.float32)
    out = wkv6(r, k, v, logw.astype(dtype), u, chunk=chunk, interpret=True)
    want, _ = ref.wkv6_ref(r, k, v, logw.astype(dtype), u,
                           jnp.zeros((B, H, hd, hd), jnp.float32))
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=5e-2 if dtype == jnp.bfloat16 else 2e-3,
                               atol=5e-2 if dtype == jnp.bfloat16 else 2e-3)


@pytest.mark.parametrize("E,C,D,F,bc,bf,bd", [
    (2, 128, 64, 128, 128, 128, 64),
    (4, 256, 128, 256, 128, 128, 128),
    (1, 128, 256, 128, 64, 64, 128),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gmm(E, C, D, F, bc, bf, bd, dtype):
    k1, k2 = jax.random.split(jax.random.key(3))
    x = jax.random.normal(k1, (E, C, D), dtype)
    w = jax.random.normal(k2, (E, D, F), dtype)
    out = gmm(x, w, block_c=bc, block_f=bf, block_d=bd, interpret=True)
    want = ref.gmm_ref(x, w)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))
