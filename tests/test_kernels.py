"""Per-kernel correctness: sweep shapes/dtypes, interpret=True vs ref oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.moe_gmm import gmm
from repro.kernels.ssm_scan import ssd_scan
from repro.kernels.wkv6 import wkv6


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else \
        dict(rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("B,H,Sq,Sk,dh", [
    (1, 1, 128, 128, 64),
    (2, 3, 256, 256, 64),
    (1, 2, 128, 384, 32),     # rectangular (prefill-like), Sq < Sk
    (2, 1, 512, 512, 128),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention(B, H, Sq, Sk, dh, dtype, causal):
    if causal and Sq != Sk:
        pytest.skip("causal offset variant covered by equal-length cases")
    k1, k2, k3 = jax.random.split(jax.random.key(0), 3)
    q = jax.random.normal(k1, (B, H, Sq, dh), dtype)
    k = jax.random.normal(k2, (B, H, Sk, dh), dtype)
    v = jax.random.normal(k3, (B, H, Sk, dh), dtype)
    out = flash_attention(q, k, v, causal=causal, block_q=128, block_k=128,
                          interpret=True)
    want = ref.attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


@pytest.mark.parametrize("B,S,H,hd,N,chunk", [
    (1, 64, 1, 16, 8, 16),
    (2, 128, 3, 32, 16, 32),
    (1, 256, 2, 64, 64, 128),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_scan(B, S, H, hd, N, chunk, dtype):
    ks = jax.random.split(jax.random.key(1), 5)
    x = jax.random.normal(ks[0], (B, S, H, hd), dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H))).astype(jnp.float32)
    a = -jnp.exp(jax.random.normal(ks[2], (H,))).astype(jnp.float32)
    B_ = jax.random.normal(ks[3], (B, S, N), dtype)
    C = jax.random.normal(ks[4], (B, S, N), dtype)
    out = ssd_scan(x, dt, a, B_, C, chunk=chunk, interpret=True)
    want, _ = ref.ssd_ref(x, dt, a, B_, C,
                          jnp.zeros((B, H, hd, N), jnp.float32))
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=4e-2 if dtype == jnp.bfloat16 else 1e-4,
                               atol=4e-2 if dtype == jnp.bfloat16 else 1e-4)


@pytest.mark.parametrize("B,S,H,hd,chunk", [
    (1, 64, 1, 16, 16),
    (2, 128, 2, 32, 32),
    (1, 128, 4, 64, 64),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_wkv6(B, S, H, hd, chunk, dtype):
    ks = jax.random.split(jax.random.key(2), 5)
    r = jax.random.normal(ks[0], (B, S, H, hd), dtype)
    k = jax.random.normal(ks[1], (B, S, H, hd), dtype)
    v = jax.random.normal(ks[2], (B, S, H, hd), dtype)
    logw = -jnp.exp(jax.random.normal(ks[3], (B, S, H, hd))).astype(jnp.float32)
    logw = jnp.maximum(logw, -8.0)
    u = jax.random.normal(ks[4], (H, hd), jnp.float32)
    out = wkv6(r, k, v, logw.astype(dtype), u, chunk=chunk, interpret=True)
    want, _ = ref.wkv6_ref(r, k, v, logw.astype(dtype), u,
                           jnp.zeros((B, H, hd, hd), jnp.float32))
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=5e-2 if dtype == jnp.bfloat16 else 2e-3,
                               atol=5e-2 if dtype == jnp.bfloat16 else 2e-3)


@pytest.mark.parametrize("E,C,D,F,bc,bf,bd", [
    (2, 128, 64, 128, 128, 128, 64),
    (4, 256, 128, 256, 128, 128, 128),
    (1, 128, 256, 128, 64, 64, 128),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gmm(E, C, D, F, bc, bf, bd, dtype):
    k1, k2 = jax.random.split(jax.random.key(3))
    x = jax.random.normal(k1, (E, C, D), dtype)
    w = jax.random.normal(k2, (E, D, F), dtype)
    out = gmm(x, w, block_c=bc, block_f=bf, block_d=bd, interpret=True)
    want = ref.gmm_ref(x, w)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


# ---------------------------------------------------------------------------
# fused softmax cross-entropy
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("R,V,br,bv", [
    (128, 512, 128, 512),      # single tile both ways
    (256, 1024, 128, 256),     # multi-tile vocab sweep
    (100, 777, 64, 256),       # ragged rows AND vocab (padding paths)
    (32, 50, 32, 128),         # vocab smaller than one tile
])
@pytest.mark.parametrize("softcap", [None, 30.0])
def test_softmax_xent(R, V, br, bv, softcap):
    from repro.kernels.xent import softmax_xent
    k1, k2 = jax.random.split(jax.random.key(4))
    logits = 4.0 * jax.random.normal(k1, (R, V), jnp.float32)
    labels = jax.random.randint(k2, (R,), 0, V)
    out = softmax_xent(logits, labels, softcap=softcap, block_r=br,
                       block_v=bv, interpret=True)
    want = ref.softmax_xent_ref(logits, labels, softcap=softcap)
    assert out.shape == (R,) and out.dtype == jnp.float32
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("softcap", [None, 30.0])
def test_softmax_xent_grad(softcap):
    from repro.kernels.xent import softmax_xent
    k1, k2 = jax.random.split(jax.random.key(5))
    logits = 4.0 * jax.random.normal(k1, (96, 300), jnp.float32)
    labels = jax.random.randint(k2, (96,), 0, 300)

    def mean_nll(fn):
        return lambda x: jnp.mean(fn(x))

    g = jax.grad(mean_nll(lambda x: softmax_xent(
        x, labels, softcap=softcap, block_r=64, block_v=128,
        interpret=True)))(logits)
    g_ref = jax.grad(mean_nll(lambda x: ref.softmax_xent_ref(
        x, labels, softcap=softcap)))(logits)
    np.testing.assert_allclose(np.asarray(g), np.asarray(g_ref),
                               rtol=1e-4, atol=1e-5)


def test_softmax_xent_extreme_logits():
    """Online logsumexp must not overflow where naive exp would."""
    from repro.kernels.xent import softmax_xent
    logits = jnp.array([[1000.0, 0.0, -1000.0, 500.0]] * 8, jnp.float32)
    labels = jnp.array([0, 1, 2, 3, 0, 1, 2, 3])
    out = softmax_xent(logits, labels, block_r=8, block_v=128,
                       interpret=True)
    want = ref.softmax_xent_ref(logits, labels)
    assert np.all(np.isfinite(np.asarray(out)))
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------------------
# fused AdamW update
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape", [
    (256, 128),                # exact tiles
    (3, 100, 37),              # ragged flatten -> padding tail
    (5,),                      # tiny 1-D leaf, all padding
])
@pytest.mark.parametrize("pdtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("weight_decay", [0.0, 0.1])
def test_adamw_update(shape, pdtype, weight_decay):
    from repro.kernels.adamw_update import adamw_update
    ks = jax.random.split(jax.random.key(6), 4)
    p = jax.random.normal(ks[0], shape, pdtype)
    g = jax.random.normal(ks[1], shape, jnp.float32)
    m = 0.1 * jax.random.normal(ks[2], shape, jnp.float32)
    v = jnp.abs(jax.random.normal(ks[3], shape)).astype(jnp.float32)
    lr, bc1, bc2 = jnp.float32(3e-4), jnp.float32(0.271), jnp.float32(0.0297)
    hp = dict(b1=0.9, b2=0.95, eps=1e-8, weight_decay=weight_decay)
    new_p, new_m, new_v = adamw_update(p, g, m, v, lr, bc1, bc2,
                                       block_rows=64, interpret=True, **hp)
    want_p, want_m, want_v = ref.adamw_update_ref(p, g, m, v, lr, bc1, bc2,
                                                  **hp)
    assert new_p.shape == shape and new_p.dtype == pdtype
    assert new_m.dtype == jnp.float32 and new_v.dtype == jnp.float32
    # a couple ulp of slack: XLA fuses the ref's multiply-add chains with
    # FMA, the interpreted kernel evaluates them unfused
    np.testing.assert_allclose(np.asarray(new_m), np.asarray(want_m),
                               rtol=1e-6, atol=1e-7)
    np.testing.assert_allclose(np.asarray(new_v), np.asarray(want_v),
                               rtol=1e-6, atol=1e-7)
    np.testing.assert_allclose(np.asarray(new_p, np.float32),
                               np.asarray(want_p, np.float32),
                               rtol=1e-6, atol=1e-6)


def test_adamw_update_f32_accumulation():
    """bf16 params must be updated in f32: a tiny lr*update that underflows
    a pure-bf16 subtract must still match the f32-accumulated ref."""
    from repro.kernels.adamw_update import adamw_update
    p = jnp.full((128,), 1.0, jnp.bfloat16)
    g = jnp.full((128,), 1e-3, jnp.float32)
    m = jnp.zeros((128,), jnp.float32)
    v = jnp.zeros((128,), jnp.float32)
    lr, bc1, bc2 = jnp.float32(1e-5), jnp.float32(0.1), jnp.float32(0.05)
    hp = dict(b1=0.9, b2=0.95, eps=1e-8)
    new_p, _, _ = adamw_update(p, g, m, v, lr, bc1, bc2, block_rows=8,
                               interpret=True, **hp)
    want_p, _, _ = ref.adamw_update_ref(p, g, m, v, lr, bc1, bc2, **hp)
    np.testing.assert_array_equal(np.asarray(new_p, np.float32),
                                  np.asarray(want_p, np.float32))


def test_apply_updates_fused_matches_unfused():
    """The optimizer-level fused gate: full schema tree, stacked layers
    leaf included (fused skips the layered scan entirely)."""
    from repro.configs.base import OptimizerConfig
    from repro.models.params import PSpec
    from repro.optim import adamw as A

    schema = {"w": PSpec((8, 64), (None, None), "normal"),
              "b": PSpec((64,), (None,), "zeros"),
              "stack": PSpec((3, 16, 16), ("layers", None, None), "normal")}
    ks = jax.random.split(jax.random.key(7), 6)
    params = {"w": jax.random.normal(ks[0], (8, 64), jnp.bfloat16),
              "b": jax.random.normal(ks[1], (64,), jnp.bfloat16),
              "stack": jax.random.normal(ks[2], (3, 16, 16), jnp.bfloat16)}
    grads = {"w": jax.random.normal(ks[3], (8, 64), jnp.float32),
             "b": jax.random.normal(ks[4], (64,), jnp.float32),
             "stack": jax.random.normal(ks[5], (3, 16, 16), jnp.float32)}
    state = {"m": jax.tree.map(jnp.zeros_like, grads),
             "v": jax.tree.map(jnp.zeros_like, grads),
             "count": jnp.zeros((), jnp.int32)}
    ocfg = OptimizerConfig(warmup_steps=2, decay_steps=10)
    p_u, s_u, _ = A.apply_updates(schema, params, grads, state, ocfg,
                                  fused=False)
    p_f, s_f, _ = A.apply_updates(schema, params, grads, state, ocfg,
                                  fused=True)
    for k in p_u:
        np.testing.assert_allclose(np.asarray(p_u[k], np.float32),
                                   np.asarray(p_f[k], np.float32),
                                   rtol=1e-2, atol=1e-2)   # bf16 rounding
        np.testing.assert_allclose(np.asarray(s_u["m"][k]),
                                   np.asarray(s_f["m"][k]),
                                   rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(np.asarray(s_u["v"][k]),
                                   np.asarray(s_f["v"][k]),
                                   rtol=1e-5, atol=1e-6)


def test_chunked_cross_entropy_fused_matches_unfused():
    from repro.models import losses
    ks = jax.random.split(jax.random.key(8), 3)
    x = jax.random.normal(ks[0], (2, 32, 16), jnp.float32)
    lab = jax.random.randint(ks[1], (2, 32), 0, 100)
    head = jax.random.normal(ks[2], (100, 16), jnp.float32)
    for cap in (None, 20.0):
        a = losses.chunked_cross_entropy(x, lab, head, softcap=cap,
                                         chunk=16, fused=False)
        b = losses.chunked_cross_entropy(x, lab, head, softcap=cap,
                                         chunk=16, fused=True)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-5)
        ga = jax.grad(lambda t: losses.chunked_cross_entropy(
            t, lab, head, softcap=cap, chunk=16, fused=False))(x)
        gb = jax.grad(lambda t: losses.chunked_cross_entropy(
            t, lab, head, softcap=cap, chunk=16, fused=True))(x)
        np.testing.assert_allclose(np.asarray(ga), np.asarray(gb),
                                   rtol=1e-4, atol=1e-5)
