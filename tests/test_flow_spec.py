"""Graph-spec validation properties for repro.flow.

The contract: every malformed workflow program — dependency cycles,
unknown dep names, malformed ``scatter:`` / ``repeat:`` specs, unsafe
condition expressions — fails EAGERLY with a ``ManifestError`` that
names the offending manifest field, and the safe expression language
evaluates exactly its whitelisted subset."""
import pytest

from repro.api import ManifestError, WorkflowRun
from repro.flow import compile_graph, eval_expr, parse_expr, validate_graph

try:
    from hypothesis import given, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                      # optional dev dependency
    HAVE_HYPOTHESIS = False


def node(step, **kw):
    return {"step": step, "entrypoint": "builtins:repr", **kw}


def graph(*nodes):
    return {"nodes": list(nodes)}


# ------------------------------------------------------------- properties
if HAVE_HYPOTHESIS:
    step_names = st.text(alphabet="abcdefghijklmnopqrstuvwxyz",
                         min_size=1, max_size=6)

    @given(st.lists(step_names, min_size=2, max_size=6, unique=True),
           st.data())
    def test_cycles_always_raise_naming_nodes(names, data):
        """Close any chain into a ring (possibly with extra forward deps
        thrown in): validation must always report the cycle at
        <field>.nodes."""
        nodes = []
        for i, n in enumerate(names):
            deps = [names[(i + 1) % len(names)]]     # the ring edge
            extra = data.draw(st.lists(
                st.sampled_from(names), max_size=2, unique=True))
            deps += [d for d in extra if d not in deps and d != n]
            nodes.append(node(n, deps=deps))
        with pytest.raises(ManifestError) as e:
            validate_graph(graph(*nodes), field="spec.graph")
        assert e.value.field == "spec.graph.nodes"
        assert "cycle" in str(e.value)

    @given(step_names, step_names)
    def test_unknown_deps_always_raise_naming_the_entry(known, ghost):
        """A dep that names no declared step fails at deps[j] whatever
        the names are."""
        if ghost == known:
            ghost = ghost + "x"
        bad = graph(node(known), node(known + "y", deps=[known, ghost]))
        with pytest.raises(ManifestError) as e:
            validate_graph(bad, field="spec.graph")
        assert e.value.field == "spec.graph.nodes[1].deps[1]"
        assert repr(ghost) in str(e.value)

    malformed_scatters = st.one_of(
        st.just(17), st.just("plan.chunks"), st.just([]),
        st.just({}), st.just({"over": []}),
        st.just({"over": "plan.chunks", "width": 4}),
        st.just({"over": "ghost.chunks"}), st.just({"over": ""}),
        st.just({"over": 3}))

    @given(malformed_scatters)
    def test_malformed_scatter_specs_raise_inside_scatter(scatter):
        bad = graph(node("plan"),
                    node("fan", deps=["plan"], scatter=scatter))
        with pytest.raises(ManifestError) as e:
            validate_graph(bad, field="spec.graph")
        assert e.value.field.startswith("spec.graph.nodes[1].scatter"), \
            e.value.field

    @given(st.sampled_from([
        "__import__('os')", "open('/etc/passwd')", "x.__class__",
        "(lambda: 1)()", "[i for i in x]", "f'{x}'", "x := 3",
        "exec('1')", "x ** 9", "{1: 2}"]))
    def test_unsafe_expressions_never_parse(text):
        with pytest.raises(ManifestError) as e:
            parse_expr(text, "spec.graph.nodes[0].when")
        assert e.value.field == "spec.graph.nodes[0].when"


# ------------------------------------------------- deterministic fallbacks
@pytest.mark.parametrize("bad,field,hint", [
    (graph(node("a", deps=["b"]), node("b", deps=["a"])),
     "spec.graph.nodes", "cycle"),
    (graph(node("a", deps=["a"])), "spec.graph.nodes[0].deps[0]",
     "cannot depend on itself"),
    (graph(node("a"), node("b", deps=["ghost"])),
     "spec.graph.nodes[1].deps[0]", "unknown dependency"),
    (graph(node("a"), node("b", deps=["a"], scatter={"over": []})),
     "spec.graph.nodes[1].scatter.over", "may not be empty"),
    (graph(node("a"), node("b", deps=["a"], scatter={"ovr": "a.x"})),
     "spec.graph.nodes[1].scatter.ovr", "unknown scatter keys"),
    (graph(node("a"), node("b", deps=["a"],
                           scatter={"over": "ghost.chunks"})),
     "spec.graph.nodes[1].scatter.over", "not in this node's deps"),
    (graph(node("a"), node("b", deps=["a"], when="ghost.ok")),
     "spec.graph.nodes[1].when", "not in this node's deps"),
    (graph(node("a"), node("b", deps=["a"],
                           repeat={"until": "output.v > 1"})),
     "spec.graph.nodes[1].repeat.max", "bounded"),
    (graph(node("a"), node("b", deps=["a"],
                           repeat={"times": 2, "until": "i > 1",
                                   "max": 3})),
     "spec.graph.nodes[1].repeat", "exactly one"),
    (graph(node("a"), node("b", deps=["a"], scatter={"over": "a.x"},
                           repeat={"times": 2})),
     "spec.graph.nodes[1].scatter", "cannot combine"),
    (graph(node("bad name!")), "spec.graph.nodes[0].step", "must match"),
    (graph(node("a"), node("a")), "spec.graph.nodes[1].step", "duplicate"),
    (graph({"step": "a"}), "spec.graph.nodes[0].entrypoint",
     "exactly one of"),
    (graph(node("a", when="__import__('os').system('x')")),
     "spec.graph.nodes[0].when", "may be called"),
    (graph(node("a", when="[i for i in x]")),
     "spec.graph.nodes[0].when", "may not contain"),
    ({"nodes": []}, "spec.graph.nodes", "non-empty"),
    ({"nodes": [node("a")], "edges": []}, "spec.graph.edges",
     "unknown graph keys"),
])
def test_malformed_graphs_name_the_field(bad, field, hint):
    with pytest.raises(ManifestError) as e:
        validate_graph(bad, field="spec.graph")
    assert e.value.field == field, f"expected {field}, got {e.value.field}"
    assert hint in str(e.value)


def test_workflowrun_validates_graph_eagerly():
    """A bad graph fails at manifest/spec construction (apply time), and
    graph excludes entrypoint/define."""
    with pytest.raises(ManifestError, match=r"spec\.graph\.nodes"):
        WorkflowRun(name="w", graph=graph(node("a", deps=["a"])))
    with pytest.raises(ManifestError, match=r"spec\.graph"):
        WorkflowRun(name="w", graph=graph(node("a")),
                    entrypoint="builtins:repr")
    with pytest.raises(ManifestError, match=r"spec\.max_workers"):
        WorkflowRun(name="w", graph=graph(node("a")), max_workers=0)
    ok = WorkflowRun(name="w", graph=graph(node("a")))
    assert ok.to_manifest()["spec"]["graph"]["nodes"][0]["step"] == "a"


def test_expression_language_evaluates_safe_subset():
    ns = {"train": {"loss": 0.07, "hist": [3, 2, 1]}, "i": 4}
    cases = [("train.loss < 0.1", True),
             ("train.hist[2] == 1 and not (i > 9)", True),
             ("len(train.hist) + i == 7", True),
             ("min(train.hist) <= train.loss", False),
             ("0 < i <= 4", True)]
    for text, want in cases:
        tree = parse_expr(text, "f")
        assert eval_expr(tree, ns) is want, text
    with pytest.raises(KeyError, match="ghost"):
        eval_expr(parse_expr("ghost.x", "f"), ns)


def test_compile_resolves_entrypoints_and_nested_graphs():
    g = graph(
        node("a"),
        {"step": "sub", "deps": ["a"],
         "graph": graph(node("x"), node("y", deps=["x"]))},
        node("fan", deps=["a"], scatter={"over": "a.items"}),
        node("loop", deps=["a"], repeat={"until": "output.v > 1",
                                         "max": 5}))
    prog = compile_graph(g)
    assert prog.nodes["a"].fn is repr
    assert prog.nodes["sub"].subgraph.nodes["y"].deps == ("x",)
    assert prog.nodes["fan"].scatter_over == "a.items"
    assert prog.nodes["loop"].repeat.bound == 5
    assert prog.size == 6
