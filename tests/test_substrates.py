"""Substrate tests: optimizer (incl. int8/factored recipes), losses,
chunked-vs-naive sequence mixers, data pipelines, object store, quant."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis", reason="optional dev dependency (requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.configs.base import OptimizerConfig
from repro.models.params import PSpec, init_params, abstract_params
from repro.optim import adamw, quant
from repro.optim.schedule import learning_rate


# ----------------------------------------------------------------- optimizer

def _quadratic_losses(ocfg, steps=60):
    schema = {"w": PSpec((4, 8), (None, None))}
    params = {"w": jnp.full((4, 8), 3.0)}
    state = init_params(adamw.opt_state_schema(schema, ocfg),
                        jax.random.key(0), "float32")
    losses = []
    for _ in range(steps):
        loss, grads = jax.value_and_grad(
            lambda p: jnp.sum(jnp.square(p["w"])))(params)
        params, state, _ = adamw.apply_updates(schema, params, grads, state,
                                               ocfg)
        losses.append(float(loss))
    return losses


@pytest.mark.parametrize("recipe", [
    dict(),                                                # fp32 adamw
    dict(moment_dtype="bfloat16"),
    dict(moment_dtype="int8"),
    dict(second_moment="factored"),
    dict(moment_dtype="int8", second_moment="factored"),   # the 1T recipe
])
def test_adamw_recipes_descend_quadratic(recipe):
    ocfg = OptimizerConfig(lr=0.1, warmup_steps=1, decay_steps=1000,
                           schedule="constant", weight_decay=0.0, **recipe)
    losses = _quadratic_losses(ocfg)
    assert losses[-1] < losses[0] * 0.05, recipe


def test_layered_update_scan_matches_flat():
    """The per-layer scanned update must equal the unscanned math."""
    ocfg = OptimizerConfig(lr=0.01, warmup_steps=1, decay_steps=100,
                           schedule="constant")
    key = jax.random.key(0)
    w = jax.random.normal(key, (3, 4, 8))           # stacked "layers"
    g = jax.random.normal(jax.random.key(1), (3, 4, 8))
    layered_schema = {"w": PSpec((3, 4, 8), ("layers", None, None))}
    flat_schema = {"w": PSpec((3, 4, 8), (None, None, None))}
    s1 = init_params(adamw.opt_state_schema(layered_schema, ocfg),
                     key, "float32")
    s2 = init_params(adamw.opt_state_schema(flat_schema, ocfg),
                     key, "float32")
    p1, _, _ = adamw.apply_updates(layered_schema, {"w": w}, {"w": g}, s1, ocfg)
    p2, _, _ = adamw.apply_updates(flat_schema, {"w": w}, {"w": g}, s2, ocfg)
    np.testing.assert_allclose(np.asarray(p1["w"]), np.asarray(p2["w"]),
                               rtol=1e-6)


def test_grad_clip_bounds_norm():
    g = {"a": jnp.full((10,), 100.0)}
    clipped, norm = adamw.clip_by_global_norm(g, 1.0)
    assert float(norm) > 100
    assert abs(float(adamw.global_norm(clipped)) - 1.0) < 1e-3


def test_schedule_warmup_and_decay():
    ocfg = OptimizerConfig(lr=1.0, warmup_steps=10, decay_steps=100,
                           schedule="cosine")
    assert float(learning_rate(ocfg, 5)) == pytest.approx(0.5, rel=1e-3)
    assert float(learning_rate(ocfg, 10)) == pytest.approx(1.0, rel=1e-3)
    assert float(learning_rate(ocfg, 100)) < 0.01


# --------------------------------------------------------------------- quant

@settings(max_examples=50, deadline=None)
@given(shape=st.sampled_from([(8,), (4, 128), (3, 5, 256), (2, 7)]),
       scale=st.floats(min_value=1e-3, max_value=1e3))
def test_int8_quant_roundtrip_error_bounded(shape, scale):
    x = np.random.RandomState(0).randn(*shape).astype(np.float32) * scale
    q = quant.quantize(jnp.asarray(x))
    back = np.asarray(quant.dequantize(q))
    blockmax = np.abs(x).max() if x.ndim == 0 else None
    err = np.abs(back - x)
    # error <= half a quantization step per block (127 levels of blockmax)
    b = quant.block_size(shape[-1])
    xb = x.reshape(x.shape[:-1] + (x.shape[-1] // b, b))
    step = np.abs(xb).max(-1, keepdims=True) / 127.0
    assert (err.reshape(xb.shape) <= step * 0.51 + 1e-9).all()


# -------------------------------------------------------------------- losses

def test_chunked_xent_matches_full():
    from repro.models import losses
    key = jax.random.key(0)
    B, S, D, V = 2, 32, 16, 64
    x = jax.random.normal(key, (B, S, D), jnp.float32)
    head = jax.random.normal(jax.random.key(1), (V, D), jnp.float32)
    labels = jax.random.randint(jax.random.key(2), (B, S), 0, V)
    full = losses.chunked_cross_entropy(x, labels, head, chunk=S)
    chunked = losses.chunked_cross_entropy(x, labels, head, chunk=8)
    np.testing.assert_allclose(float(full), float(chunked), rtol=1e-5)


def test_sharded_xent_matches_chunked():
    from repro.models import losses
    from repro.models.layers import ModelCtx
    from repro.configs.base import ModelConfig, ParallelConfig
    key = jax.random.key(0)
    B, S, D, V = 2, 32, 16, 64
    x = jax.random.normal(key, (B, S, D), jnp.float32)
    head = jax.random.normal(jax.random.key(1), (V, D), jnp.float32)
    labels = jax.random.randint(jax.random.key(2), (B, S), 0, V)
    ctx = ModelCtx(ModelConfig(), ParallelConfig(), None)
    a = losses.sharded_cross_entropy(ctx, x, labels, head)
    b = losses.chunked_cross_entropy(x, labels, head, chunk=8)
    np.testing.assert_allclose(float(a), float(b), rtol=1e-5)


# --------------------------------------------- chunked mixers vs naive refs

def test_model_ssd_chunked_matches_ref():
    from repro.models.ssm import _ssd_chunked
    from repro.kernels import ref
    B, S, H, hd, N = 2, 64, 3, 16, 8
    ks = jax.random.split(jax.random.key(5), 5)
    x = jax.random.normal(ks[0], (B, S, H, hd))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    a = -jnp.exp(jax.random.normal(ks[2], (H,)))
    B_ = jax.random.normal(ks[3], (B, S, N))
    C = jax.random.normal(ks[4], (B, S, N))
    h0 = jnp.zeros((B, H, hd, N), jnp.float32)
    y, h_last = _ssd_chunked(x, dt, a, B_, C, h0, chunk=16)
    want_y, want_h = ref.ssd_ref(x, dt, a, B_, C, h0)
    np.testing.assert_allclose(np.asarray(y, np.float32), np.asarray(want_y),
                               rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(h_last), np.asarray(want_h),
                               rtol=2e-3, atol=2e-3)


def test_model_wkv_chunked_matches_ref():
    from repro.models.ssm import _wkv_chunked
    from repro.kernels import ref
    B, S, H, hd = 1, 64, 2, 16
    ks = jax.random.split(jax.random.key(6), 5)
    r = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, H, hd))
    v = jax.random.normal(ks[2], (B, S, H, hd))
    logw = jnp.maximum(-jnp.exp(jax.random.normal(ks[3], (B, S, H, hd))), -8.0)
    u = jax.random.normal(ks[4], (H, hd))
    s0 = jnp.zeros((B, H, hd, hd), jnp.float32)
    y, s_last = _wkv_chunked(r, k, v, logw, u, s0, chunk=16)
    want_y, want_s = ref.wkv6_ref(r, k, v, logw, u, s0)
    np.testing.assert_allclose(np.asarray(y), np.asarray(want_y),
                               rtol=3e-3, atol=3e-3)
    np.testing.assert_allclose(np.asarray(s_last), np.asarray(want_s),
                               rtol=3e-3, atol=3e-3)


# ----------------------------------------------------------------- pipelines

def test_token_pipeline_deterministic():
    from repro.data.tokens import TokenPipeline
    p1 = TokenPipeline(1000, 16, 4, seed=7)
    p2 = TokenPipeline(1000, 16, 4, seed=7)
    b1, b2 = p1.batch(3), p2.batch(3)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b2["tokens"]))
    assert (np.asarray(b1["tokens"]) < 1000).all()
    assert (np.asarray(b1["tokens"]) >= 0).all()
    # labels are next-token shifted
    np.testing.assert_array_equal(np.asarray(b1["labels"])[:, :-1],
                                  np.asarray(p1._host_batch(3)["tokens"])[:, 1:])


def test_volume_chunks_deterministic_and_labeled():
    from repro.data import volumes
    spec = volumes.VolumeSpec(lat=24, lon=32, frames=8)
    a1, l1 = volumes.generate_chunk(spec, 5)
    a2, l2 = volumes.generate_chunk(spec, 5)
    np.testing.assert_array_equal(a1, a2)
    assert a1.shape == (8, 24, 32) and l1.dtype == np.uint8
    assert 0 < l1.mean() < 0.9               # some but not all labeled


def test_objectstore_atomic_and_listing(tmp_path):
    from repro.data.objectstore import ObjectStore
    s = ObjectStore(str(tmp_path))
    s.put("a/b.txt", b"hello")
    assert s.get("a/b.txt") == b"hello"
    assert s.list("a/") == ["a/b.txt"]
    with pytest.raises(ValueError):
        s.put("../escape", b"x")
    arr = np.arange(5)
    s.put_array("x.npy", arr)
    np.testing.assert_array_equal(s.get_array("x.npy"), arr)
