"""Registry/Series thread-safety and listener streaming.

The regression here: ``Series.points`` used to be appended from pod
threads while ``summary()``/``scrape()`` iterated under only the
registry's dict lock — count, mean and total could be computed from
three different instants of the same series.  Now every read derives
from one per-series locked snapshot."""
import threading

from repro.core.metrics import Registry, Series


def test_series_summary_consistent_under_concurrent_records():
    """8 writer threads recording value=1.0 while the main thread
    summarizes: within any single summary draw, total == count and
    mean == 1.0 exactly — only possible if stats come from ONE
    snapshot."""
    reg = Registry()
    n_writers, per_writer = 8, 2000
    start = threading.Barrier(n_writers + 1, timeout=30)

    def writer(w):
        start.wait()
        for i in range(per_writer):
            reg.inc(f"shared/{w % 2}")          # contended series
            reg.gauge("all", 1.0)

    threads = [threading.Thread(target=writer, args=(w,))
               for w in range(n_writers)]
    for t in threads:
        t.start()
    start.wait()
    draws = 0
    while any(t.is_alive() for t in threads):
        for name, st in reg.summary().items():
            assert st["total"] == st["count"], (name, st)
            if st["count"]:
                assert st["mean"] == 1.0, (name, st)
                assert st["max"] == st["last"] == 1.0, (name, st)
        reg.scrape()
        reg.to_csv()
        draws += 1
    for t in threads:
        t.join(timeout=30)
    assert draws > 0
    s = reg.summary()
    assert s["all"]["count"] == n_writers * per_writer
    assert s["shared/0"]["count"] + s["shared/1"]["count"] == \
        n_writers * per_writer


def test_series_snapshot_is_isolated():
    s = Series()
    s.record(1.0)
    snap = s.snapshot()
    s.record(2.0)
    assert len(snap) == 1 and len(s.snapshot()) == 2
    assert s.last == 2.0 and s.total == 3.0 and s.mean == 1.5
    st = s.stats()
    assert st["count"] == 2 and st["p50"] in (1.0, 2.0)


def test_registry_listener_gets_every_record_and_survives_errors():
    reg = Registry()
    got = []
    reg.add_listener(lambda n, v, ts: got.append((n, v)))
    reg.add_listener(lambda n, v, ts: 1 / 0)     # broken observer
    reg.inc("a")
    reg.gauge("b", 2.5)
    with reg.timer("t"):
        pass
    assert got[0] == ("a", 1.0) and got[1] == ("b", 2.5)
    assert got[2][0] == "t" and got[2][1] >= 0.0
    assert reg.series("a").total == 1.0          # broken listener harmless
