"""Hypothesis property tests: PlacementPlanner invariants over generated
federation topologies and replica layouts.

The two §IV invariants every placement must keep, whatever the topology:
  * a step never lands on a dead or zero-capacity site (and when no site
    can host it, place() refuses loudly instead of picking a corpse);
  * the bytes the fabric actually meters for pre-staging equal the
    chosen site's ``bytes_missing`` — the cost model and the data plane
    agree, so Table-I numbers can be trusted.
"""
import shutil
import tempfile

import pytest

pytest.importorskip("hypothesis", reason="optional dev dependency "
                                         "(requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.fabric import Fabric, FederatedStore, PlacementPlanner

NAMES = ["s0", "s1", "s2", "s3"]
BW = [0.1, 1.0, 10.0]


@st.composite
def scenarios(draw):
    n = draw(st.integers(min_value=2, max_value=4))
    names = NAMES[:n]
    devs = {s: draw(st.integers(min_value=0, max_value=3)) for s in names}
    up = {s: draw(st.booleans()) for s in names}
    links = []
    for i in range(n):
        for j in range(i + 1, n):
            if draw(st.booleans()):
                links.append((names[i], names[j],
                              draw(st.sampled_from(BW))))
    keys = []
    for k in range(draw(st.integers(min_value=0, max_value=5))):
        keys.append((f"d/k{k}",
                     draw(st.sampled_from(names)),          # home
                     draw(st.integers(min_value=1, max_value=2048)),
                     draw(st.lists(st.sampled_from(names),  # extra replicas
                                   max_size=n, unique=True))))
    devices = draw(st.integers(min_value=0, max_value=3))
    return names, devs, up, links, keys, devices


def build(names, devs, up, links, keys, root):
    fabric = Fabric()
    for s in names:
        fabric.add_site(s, devices=list(range(devs[s])),
                        store_root=f"{root}/{s}")
    for a, b, gbps in links:
        fabric.connect(a, b, gbps=gbps, latency_ms=1.0)
    fed = FederatedStore(fabric)
    for key, home, size, reps in keys:
        fed.put(key, b"x" * size, home)
        for r in reps:
            if r == home:
                continue
            try:
                fed.replicate(key, r)
            except (FileNotFoundError, ValueError):
                pass                    # no route — partial topologies ok
    for s in names:                     # sites die AFTER the data landed
        if not up[s]:
            fabric.fail_site(s)
    return fabric, fed


@settings(max_examples=60, deadline=None)
@given(scenario=scenarios())
def test_placement_never_lands_on_dead_or_empty_site(scenario):
    names, devs, up, links, keys, devices = scenario
    root = tempfile.mkdtemp(prefix="placement-prop-")
    try:
        fabric, fed = build(names, devs, up, links, keys, root)
        planner = PlacementPlanner(fed)
        inputs = [k for k, *_ in keys]
        hosts = [s for s in names
                 if up[s] and devs[s] >= max(devices, 1)]
        if hosts:
            p = planner.place(inputs, devices=devices)
            site = fabric.sites[p.site]
            assert site.up, f"placed on dead site {p.site}"
            assert site.capacity >= max(devices, 1), \
                f"placed on empty site {p.site}"
        else:
            with pytest.raises(RuntimeError, match="no live site"):
                planner.place(inputs, devices=devices)
    finally:
        shutil.rmtree(root, ignore_errors=True)


@settings(max_examples=60, deadline=None)
@given(scenario=scenarios(), factor=st.floats(min_value=2.0,
                                              max_value=100.0))
def test_brown_out_never_cheapens_and_restores_exactly(scenario, factor):
    """Chaos-link invariant: degrading links can only make placement
    scores worse (staging time is monotone in bandwidth), and restoring
    returns every score to its baseline bit for bit."""
    names, devs, up, links, keys, devices = scenario
    if not links:
        return
    root = tempfile.mkdtemp(prefix="placement-prop-")
    try:
        fabric, fed = build(names, devs, up, links, keys, root)
        planner = PlacementPlanner(fed)
        inputs = [k for k, *_ in keys]
        sites = [fabric.sites[s] for s in names if up[s]]
        before = {s.name: planner.score(inputs, s) for s in sites}
        for a, b, gbps in links:
            fabric.degrade_link(a, b, gbps=gbps / factor)
        degraded = {s.name: planner.score(inputs, s) for s in sites}
        for name in degraded:
            assert degraded[name] >= before[name] - 1e-9, \
                f"brown-out cheapened {name}: {before} -> {degraded}"
        for a, b, _ in links:
            assert fabric.restore_link(a, b) is True
        assert fabric.degraded_links() == []
        after = {s.name: planner.score(inputs, s) for s in sites}
        assert after == before
    finally:
        shutil.rmtree(root, ignore_errors=True)


@settings(max_examples=60, deadline=None)
@given(scenario=scenarios())
def test_metered_bytes_equal_bytes_missing(scenario):
    """fabric/bytes_moved's delta for a pre-stage == the placement's
    bytes_to_move == bytes_missing at the chosen site, for every
    generated topology / replica layout."""
    names, devs, up, links, keys, devices = scenario
    root = tempfile.mkdtemp(prefix="placement-prop-")
    try:
        fabric, fed = build(names, devs, up, links, keys, root)
        planner = PlacementPlanner(fed)
        inputs = [k for k, *_ in keys]
        if not any(up[s] and devs[s] >= max(devices, 1) for s in names):
            return
        p = planner.place(inputs, devices=devices)
        missing, _ = planner.bytes_missing(planner.expand(inputs), p.site)
        assert p.bytes_to_move == missing
        before = fabric.metrics.series("fabric/bytes_moved").total
        moved, _ = planner.prestage(inputs, p.site)
        delta = fabric.metrics.series("fabric/bytes_moved").total - before
        assert delta == moved == missing, \
            (f"meter {delta} != staged {moved} != missing {missing} "
             f"at {p.site}")
        # and afterwards the step is data-local: nothing left to move
        still, _ = planner.bytes_missing(planner.expand(inputs), p.site)
        assert still == 0
    finally:
        shutil.rmtree(root, ignore_errors=True)
