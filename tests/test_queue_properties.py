"""Hypothesis property tests: the work queue's fault-tolerance invariants.

These are the invariants the paper's Redis-queue workflow depends on:
every item is processed at least once, acks are idempotent, crashed
workers' leases are reclaimed, and snapshots restore to an equivalent
queue.
"""
import itertools

import pytest

pytest.importorskip("hypothesis", reason="optional dev dependency "
                                         "(requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.core.queue import WorkQueue, run_workers


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t

    def advance(self, dt):
        self.t += dt


@settings(max_examples=50, deadline=None)
@given(items=st.lists(st.integers(), min_size=0, max_size=30),
       workers=st.integers(min_value=1, max_value=5))
def test_all_items_processed_exactly_once_when_no_failures(items, workers):
    q = WorkQueue(items, lease_timeout=60.0)
    seen = []
    out = run_workers(q, lambda x: seen.append(x) or x, workers)
    assert sorted(out) == sorted(items)
    assert q.completed == len(items)
    assert q.drained()


@settings(max_examples=30, deadline=None)
@given(items=st.lists(st.integers(), min_size=1, max_size=20),
       fail_every=st.integers(min_value=2, max_value=5))
def test_at_least_once_under_worker_crashes(items, fail_every):
    """Workers that crash on some attempts: every item still completes."""
    q = WorkQueue(items, lease_timeout=60.0, max_attempts=50)
    counter = itertools.count()

    def flaky(x):
        if next(counter) % fail_every == 0:
            raise RuntimeError("simulated pod crash")
        return x

    out = run_workers(q, flaky, 3)
    assert sorted(out) == sorted(items)


@settings(max_examples=50, deadline=None)
@given(n=st.integers(min_value=1, max_value=10))
def test_lease_expiry_requeues(n):
    clock = FakeClock()
    q = WorkQueue(range(n), lease_timeout=10.0, clock=clock)
    got = q.lease("w1")
    assert got is not None
    tid, item = got
    # w1 dies; lease expires; another worker gets the same task
    clock.advance(11.0)
    seen = set()
    while True:
        g = q.lease("w2")
        if g is None:
            break
        seen.add(g[0])
        q.ack(g[0], "w2")
    assert tid in seen                      # reclaimed
    assert not q.ack(tid, "w1")             # stale ack rejected
    assert q.drained()


@settings(max_examples=50, deadline=None)
@given(items=st.lists(st.integers(), min_size=0, max_size=20),
       n_done=st.integers(min_value=0, max_value=20))
def test_snapshot_restore_equivalence(items, n_done):
    q = WorkQueue(items, lease_timeout=5.0)
    done = 0
    for _ in range(min(n_done, len(items))):
        g = q.lease("w")
        if g is None:
            break
        q.ack(g[0], "w")
        done += 1
    q2 = WorkQueue.restore(q.snapshot())
    assert q2.completed == done
    assert q2.pending == len(items) - done
    # draining the restored queue completes everything
    run_workers(q2, lambda x: x, 2)
    assert q2.drained()


def test_dead_letter_after_max_attempts():
    clock = FakeClock()
    q = WorkQueue([1], lease_timeout=1.0, max_attempts=3, clock=clock)
    for _ in range(3):
        g = q.lease("w")
        assert g is not None
        clock.advance(2.0)                  # let the lease expire
    assert q.lease("w") is None
    assert len(q.dead) == 1
    assert q.drained()


def test_double_ack_idempotent():
    q = WorkQueue([42])
    tid, _ = q.lease("w")
    assert q.ack(tid, "w") is True
    assert q.ack(tid, "w") is False


# --------------------------------------------------------------------------
# enqueued_at preservation under churn: snapshot/restore must carry the
# ORIGINAL submission stamps — a requeued attempt never resets the clock
# (the RL rollout queue and the serving router's TTFT accounting both
# rely on this; the deterministic nack/lease-expiry cases live in
# tests/test_rl.py so they run without hypothesis installed).

@settings(max_examples=30, deadline=None)
@given(stamps=st.lists(st.floats(min_value=0.0, max_value=1e6,
                                 allow_nan=False), min_size=1, max_size=10))
def test_snapshot_restore_preserves_enqueued_at(stamps):
    clock = FakeClock()
    q = WorkQueue(lease_timeout=5.0, clock=clock)
    tids = []
    for s in stamps:
        tids.append(q.put("x", enqueued_at=s))
    # churn: lease + nack half of them so requeue order differs
    for _ in range(len(tids) // 2):
        g = q.lease("w")
        q.nack(g[0], "w")
    q2 = WorkQueue.restore(q.snapshot(), clock=clock)
    for tid, s in zip(tids, stamps):
        assert q2.enqueued_at(tid) == s
