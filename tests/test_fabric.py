"""Multi-site federation tests: topology/link cost model, federated store
replication (dedupe/batching/site loss), locality-aware placement,
federated workflows, and cross-site elastic failover."""
import threading

import numpy as np
import pytest

from repro.core.metrics import table_one
from repro.core.orchestrator import Cluster, JobSpec, PodState
from repro.core.workflow import Step, Workflow
from repro.fabric import Fabric, FederatedStore, PlacementPlanner


def mk_fabric(tmp_path, time_scale=0.0, devs=(2, 1)):
    fabric = Fabric(time_scale=time_scale)
    for i, n in enumerate(devs):
        name = f"s{i}"
        fabric.add_site(name, devices=list(range(n)),
                        store_root=str(tmp_path / name))
    names = list(fabric.sites)
    for i, a in enumerate(names):
        for b in names[i + 1:]:
            fabric.connect(a, b, gbps=1.0, latency_ms=10.0)
    return fabric


# ---------------------------------------------------------------- topology

def test_link_cost_model():
    from repro.fabric import Link
    link = Link("a", "b", gbps=1.0, latency_s=0.01)
    assert link.bytes_per_s == 1e9 / 8
    # 125 MB over 1 Gbps = 1s + latency; batching pays latency once
    assert link.transfer_s(125_000_000) == pytest.approx(1.01)
    assert link.transfer_s(125_000_000, transfers=5) == pytest.approx(1.05)


def test_fabric_transfer_metering(tmp_path):
    fabric = mk_fabric(tmp_path)
    sim = fabric.transfer("s0", "s1", 125_000_000)
    assert sim == pytest.approx(1.01)
    assert fabric.metrics.series("fabric/bytes_moved").total == 125_000_000
    assert fabric.metrics.series("fabric/transfer_s").total == \
        pytest.approx(1.01)
    # same-site moves are free and unmetered
    assert fabric.transfer("s0", "s0", 10**9) == 0.0
    assert fabric.metrics.series("fabric/bytes_moved").total == 125_000_000


def test_fabric_site_tags_and_cross_site_submit(tmp_path):
    fabric = mk_fabric(tmp_path, devs=(2, 1))
    assert fabric.sites["s0"].cluster.site == "s0"
    site, job = fabric.submit("default", JobSpec(
        "probe", lambda ctx: ctx.site, replicas=1, devices_per_pod=2))
    site.cluster.wait(job, timeout=30)
    assert site.name == "s0"            # only s0 has 2 devices
    assert job.results() == ["s0"]      # pods know their site


def test_fail_site_drains_cluster(tmp_path):
    fabric = mk_fabric(tmp_path)
    release = threading.Event()
    site, job = fabric.submit("default", JobSpec(
        "hold", lambda ctx: release.wait(5), replicas=1, devices_per_pod=1))
    fabric.fail_site(site.name)
    release.set()
    assert job.pods[0].state == PodState.FAILED
    assert site.capacity == 0
    with pytest.raises(RuntimeError, match="no live site"):
        fabric.submit("default", JobSpec("x", lambda ctx: 1,
                                         devices_per_pod=2))


def test_fail_site_drains_deviceless_pods(tmp_path):
    """A whole-site outage must drain CPU-only pods too — fail_node's
    per-device drain never sees them."""
    fabric = mk_fabric(tmp_path)
    release = threading.Event()
    site, job = fabric.submit("default", JobSpec(
        "cpu-only", lambda ctx: release.wait(5), replicas=1,
        devices_per_pod=0))
    for _ in range(200):
        if job.pods[0].state == PodState.RUNNING:
            break
        threading.Event().wait(0.01)
    fabric.fail_site(site.name)
    assert job.pods[0].state == PodState.FAILED
    assert job.pods[0].ctx.should_stop()
    release.set()


def test_degrade_link_scales_transfer_cost(tmp_path):
    """A brown-out is live immediately: transfer_s reflects the reduced
    bandwidth in both directions, and restore returns the CONFIGURED
    link exactly."""
    fabric = mk_fabric(tmp_path)
    nbytes = 125_000_000                       # 1s at the configured 1 Gbps
    base = fabric.transfer_s("s0", "s1", nbytes)
    assert base == pytest.approx(1.01)
    fabric.degrade_link("s0", "s1", gbps=0.1)
    assert fabric.transfer_s("s0", "s1", nbytes) == pytest.approx(10.01)
    assert fabric.transfer_s("s1", "s0", nbytes) == pytest.approx(10.01)
    assert fabric.degraded_links() == [("s0", "s1"), ("s1", "s0")]
    assert fabric.metrics.series("fabric/link_degradations").total == 1
    assert fabric.restore_link("s0", "s1") is True
    assert fabric.transfer_s("s0", "s1", nbytes) == pytest.approx(base)
    assert fabric.degraded_links() == []
    assert fabric.restore_link("s0", "s1") is False    # nothing degraded


def test_degrade_link_latency_override_and_validation(tmp_path):
    fabric = mk_fabric(tmp_path)
    fabric.degrade_link("s0", "s1", gbps=1.0, latency_ms=500.0)
    assert fabric.transfer_s("s0", "s1", 0) == pytest.approx(0.5)
    fabric.restore_link("s0", "s1")
    assert fabric.transfer_s("s0", "s1", 0) == pytest.approx(0.01)
    with pytest.raises(ValueError, match="gbps"):
        fabric.degrade_link("s0", "s1", gbps=0.0)
    with pytest.raises(ValueError, match="no link"):
        fabric.degrade_link("s0", "nope", gbps=0.5)
    assert fabric.degraded_links() == []       # failed calls left no residue


def test_double_degrade_restores_first_original(tmp_path):
    fabric = mk_fabric(tmp_path)
    base = fabric.transfer_s("s0", "s1", 125_000_000)
    fabric.degrade_link("s0", "s1", gbps=0.5)
    fabric.degrade_link("s0", "s1", gbps=0.05)  # brown-out worsens
    assert fabric.transfer_s("s0", "s1", 125_000_000) == \
        pytest.approx(20.01)
    fabric.restore_link("s0", "s1")
    # one restore undoes the stack: back to the CONFIGURED gbps
    assert fabric.transfer_s("s0", "s1", 125_000_000) == \
        pytest.approx(base)


def test_restore_site_clears_degraded_links(tmp_path):
    """A site restore is a power-cycle: every degraded link touching the
    site comes back at configured bandwidth."""
    fabric = mk_fabric(tmp_path)
    base = fabric.transfer_s("s0", "s1", 125_000_000)
    fabric.degrade_link("s0", "s1", gbps=0.1)
    fabric.fail_site("s1")
    fabric.restore_site("s1")
    assert fabric.degraded_links() == []
    assert fabric.transfer_s("s0", "s1", 125_000_000) == \
        pytest.approx(base)


def test_planner_routes_around_browned_out_link(tmp_path):
    """The §IV question under chaos: with the data home unable to host,
    a brown-out on one staging route must shift placement to the
    healthy route — and the restore must make both routes equal again."""
    fabric = Fabric()
    fabric.add_site("home", devices=[0], store_root=str(tmp_path / "h"))
    fabric.add_site("s1", devices=[0, 1], store_root=str(tmp_path / "s1"))
    fabric.add_site("s2", devices=[0, 1], store_root=str(tmp_path / "s2"))
    fabric.connect("home", "s1", gbps=10.0, latency_ms=1.0)
    fabric.connect("home", "s2", gbps=10.0, latency_ms=1.0)
    fed = FederatedStore(fabric)
    fed.put("d/x", b"z" * 10_000_000, "home")
    planner = PlacementPlanner(fed)
    # the step needs 2 devices: home can't host, s1/s2 tie on cost
    scores0 = planner.place(["d/x"], devices=2).scores
    assert scores0["s1"] == pytest.approx(scores0["s2"])
    fabric.degrade_link("home", "s1", gbps=0.001)
    p = planner.place(["d/x"], devices=2)
    assert p.site == "s2", f"placed over the browned-out link: {p.scores}"
    assert p.scores["s1"] > p.scores["s2"]
    fabric.restore_link("home", "s1")
    scores2 = planner.place(["d/x"], devices=2).scores
    assert scores2["s1"] == pytest.approx(scores2["s2"])


# ---------------------------------------------------------- federated store

def test_federated_namespace_and_replicate(tmp_path):
    fabric = mk_fabric(tmp_path)
    fed = FederatedStore(fabric)
    fed.put("a/x", b"hello", "s0")
    assert fed.exists("a/x") and fed.where("a/x") == ["s0"]
    assert fed.list("a") == ["a/x"]
    assert not fabric.sites["s1"].store.exists("a/x")
    sim = fed.replicate("a/x", "s1")
    assert sim > 0
    assert fabric.sites["s1"].store.get("a/x") == b"hello"
    assert fed.replicate("a/x", "s1") == 0.0        # already there
    assert fed.where("a/x") == ["s0", "s1"]


def test_replicate_dedupes_inflight(tmp_path):
    """N concurrent replications of one (key, dst) move the bytes ONCE."""
    fabric = mk_fabric(tmp_path)
    fed = FederatedStore(fabric)
    fed.put("big", b"z" * 1000, "s0")
    start = threading.Barrier(4, timeout=10)

    def pull():
        start.wait()
        fed.replicate("big", "s1")

    threads = [threading.Thread(target=pull) for _ in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=10)
    assert fabric.metrics.series("fabric/bytes_moved").total == 1000
    assert fed.where("big") == ["s0", "s1"]


def test_replicate_concurrent_billing_exactly_once(tmp_path):
    """Regression (in-flight dedup accounting): N threads replicating the
    same (key, dst) must bill exactly ONE transfer — one bytes_moved
    increment, one link transfer — and EVERY caller must observe the
    replica at dst by the time its replicate() returns."""
    fabric = mk_fabric(tmp_path, time_scale=0.001)   # widen the race window
    fed = FederatedStore(fabric)
    fed.put("hot", b"z" * 100_000, "s0")
    n = 8
    start = threading.Barrier(n, timeout=10)
    observed, errors = [], []

    def pull():
        try:
            start.wait()
            fed.replicate("hot", "s1")
            # the caller's contract: after return, the replica exists
            observed.append("s1" in fed.where("hot") and
                            fabric.sites["s1"].store.exists("hot"))
        except Exception as e:          # pragma: no cover - failure capture
            errors.append(e)

    threads = [threading.Thread(target=pull) for _ in range(n)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=30)
    assert not errors, errors[:1]
    assert all(observed) and len(observed) == n
    m = fabric.metrics
    assert m.series("fabric/bytes_moved").total == 100_000   # billed once
    assert m.series("fabric/transfers").total == 1
    assert m.series("fabric/link/s0->s1/bytes").total == 100_000
    # the losers of the race were deduped, not re-transferred
    assert m.series("fabric/replicate_dedup").total <= n - 1


def test_replicate_many_batches_latency(tmp_path):
    fabric = mk_fabric(tmp_path)
    fed = FederatedStore(fabric)
    for i in range(8):
        fed.put(f"d/{i}", b"x" * 100, "s0")
    moved, sim = fed.replicate_many([f"d/{i}" for i in range(8)], "s1")
    assert moved == 800
    # one link latency (10ms) for the whole batch, not 8
    per_key = sum(fabric.transfer_s("s0", "s1", 100) for _ in range(8))
    assert sim < per_key
    assert sim == pytest.approx(fabric.transfer_s("s0", "s1", 800))
    # unknown keys (outputs not yet produced) are skipped, not fatal
    assert fed.replicate_many(["nope"], "s1") == (0, 0.0)


def test_put_to_down_site_fails_loudly(tmp_path):
    """Writing at a dead site would be a black hole (its replicas are
    unreadable) — the write must raise, not silently 'succeed'."""
    fabric = mk_fabric(tmp_path)
    fed = FederatedStore(fabric)
    fed.put("a", b"x", "s0")
    fabric.fail_site("s1")
    with pytest.raises(RuntimeError, match="down"):
        fed.put("k", b"x", "s1")
    with pytest.raises(RuntimeError, match="down"):
        fed.replicate("a", "s1")
    with pytest.raises(RuntimeError, match="down"):
        fed.replicate_many(["a"], "s1")


def test_partial_topology_scores_instead_of_crashing(tmp_path):
    """Hub-and-spoke with NO spoke-spoke link: data at one spoke must
    make the other spoke infinitely expensive, not crash place()."""
    fabric = Fabric()
    for name, n in (("hub", 2), ("s1", 2), ("s2", 2)):
        fabric.add_site(name, devices=list(range(n)),
                        store_root=str(tmp_path / name))
    fabric.connect("hub", "s1", gbps=1.0)
    fabric.connect("hub", "s2", gbps=1.0)      # s1 <-> s2: no route
    fed = FederatedStore(fabric)
    fed.put("d/x", b"z" * 1_000_000, "s1")
    planner = PlacementPlanner(fed)
    assert fed.best_src("d/x", "s2") is None   # unreachable, not an error
    p = planner.place(["d/x"])
    assert p.site == "s1"                      # at the data
    assert planner.score(["d/x"], fabric.sites["s2"]) == float("inf")
    # replicate_many skips the stranded key instead of crashing
    assert fed.replicate_many(["d/x"], "s2") == (0, 0.0)
    assert fabric.metrics.series("fabric/missing_key").points


def test_site_loss_hides_replicas_until_restore(tmp_path):
    fabric = mk_fabric(tmp_path)
    fed = FederatedStore(fabric)
    fed.put("only-s1", b"data", "s1")
    fabric.fail_site("s1")
    assert not fed.exists("only-s1")
    assert fed.list() == []
    with pytest.raises(FileNotFoundError):
        fed.get("only-s1")
    fabric.restore_site("s1")
    assert fed.exists("only-s1")


def test_sitestore_pull_through_and_mirror(tmp_path):
    fabric = mk_fabric(tmp_path)
    fed = FederatedStore(fabric)
    fed.view("s0").put_array("data/a.npy", np.arange(4))
    # read at s1 pulls the bytes over the link (metered), then caches
    view1 = fed.view("s1")
    np.testing.assert_array_equal(view1.get_array("data/a.npy"),
                                  np.arange(4))
    moved = fabric.metrics.series("fabric/bytes_moved").total
    assert moved > 0
    view1.get_array("data/a.npy")                   # cached: no new bytes
    assert fabric.metrics.series("fabric/bytes_moved").total == moved
    # mirrored writes replicate matching prefixes synchronously
    mirrored = fed.view("s0", mirror="s1", mirror_prefixes=("checkpoints/",))
    mirrored.put("checkpoints/c1", b"ck")
    mirrored.put("scratch/tmp", b"no")
    assert fed.where("checkpoints/c1") == ["s0", "s1"]
    assert fed.where("scratch/tmp") == ["s0"]
    # namespace-wide delete drops every replica (checkpoint GC contract)
    assert mirrored.delete("checkpoints/c1")
    assert not fed.exists("checkpoints/c1")
    assert not fabric.sites["s1"].store.exists("checkpoints/c1")


# ---------------------------------------------------------------- placement

def test_planner_places_at_the_data(tmp_path):
    fabric = mk_fabric(tmp_path, devs=(2, 2))
    fed = FederatedStore(fabric)
    fed.put("big/blob", b"z" * 10_000_000, "s1")
    p = PlacementPlanner(fed).place(["big/blob"])
    assert p.site == "s1" and p.mode == "data-local"
    assert p.bytes_to_move == 0 and not p.migrated


def test_planner_prestages_when_data_site_lacks_devices(tmp_path):
    fabric = mk_fabric(tmp_path, devs=(4, 1))
    fed = FederatedStore(fabric)
    fed.put("big/blob", b"z" * 10_000_000, "s1")
    p = PlacementPlanner(fed).place(["big/blob"], devices=2)
    assert p.site == "s0" and p.mode == "pre-stage"
    assert p.bytes_to_move == 10_000_000
    assert p.migrated_from == "s1"      # the data home could not host it
    planner = PlacementPlanner(fed)
    moved, sim = planner.prestage(["big/blob"], "s0")
    assert moved == 10_000_000 and sim > 0


def test_planner_queue_depth_breaks_ties(tmp_path):
    fabric = mk_fabric(tmp_path, devs=(2, 2))
    fed = FederatedStore(fabric)
    hold = threading.Event()
    site, job = fabric.submit("default", JobSpec(
        "busy", lambda ctx: hold.wait(5), replicas=2, devices_per_pod=1))
    assert site.name == "s0"
    try:
        p = PlacementPlanner(fed).place([])     # no data: load decides
        assert p.site == "s1"
    finally:
        hold.set()
        site.cluster.wait(job, timeout=30)


def test_planner_data_blind_round_robin(tmp_path):
    fabric = mk_fabric(tmp_path, devs=(2, 2))
    fed = FederatedStore(fabric)
    fed.put("d/x", b"z" * 1000, "s0")
    planner = PlacementPlanner(fed, data_blind=True)
    assert [planner.place(["d/x"]).site for _ in range(3)] == \
        ["s0", "s1", "s0"]


def test_planner_glob_expansion(tmp_path):
    fabric = mk_fabric(tmp_path)
    fed = FederatedStore(fabric)
    fed.put("models/ffn/w0", b"a" * 100, "s1")
    fed.put("models/ffn/w1", b"b" * 100, "s1")
    planner = PlacementPlanner(fed)
    assert planner.expand(["models/ffn/*", "k"]) == \
        ["models/ffn/w0", "models/ffn/w1", "k"]
    missing, _ = planner.bytes_missing(planner.expand(["models/ffn/*"]), "s0")
    assert missing == 200


def test_planner_never_places_on_zero_capacity_site(tmp_path):
    """A site whose nodes are ALL offline (but which is not formally
    down) must not attract even device-less steps: its cluster would
    drain any pod instantly."""
    fabric = mk_fabric(tmp_path, devs=(2, 1))
    fed = FederatedStore(fabric)
    fed.put("d/x", b"z" * 1000, "s1")            # the data homes at s1
    for d in list(fabric.sites["s1"].cluster.devices):
        fabric.sites["s1"].cluster.fail_node(d)  # s1: up, 0 online devices
    planner = PlacementPlanner(fed)
    assert all(s.name != "s1" for s in planner.candidates(0))
    p = planner.place(["d/x"])                   # pays the link instead
    assert p.site == "s0" and p.mode == "pre-stage"


def test_planner_skips_dead_sites_and_records_migration(tmp_path):
    fabric = mk_fabric(tmp_path, devs=(2, 2))
    fed = FederatedStore(fabric)
    fed.put("d/x", b"z" * 1000, "s0")
    fed.replicate("d/x", "s1")
    fabric.fail_site("s0")
    p = PlacementPlanner(fed).place(["d/x"])
    assert p.site == "s1"
    assert p.migrated_from == "s0"      # home (bigger, had the data) is down


# ------------------------------------------------------- federated workflow

def test_federated_workflow_places_and_reports(tmp_path):
    fabric = mk_fabric(tmp_path, devs=(2, 2))
    fed = FederatedStore(fabric)
    fed.view("s1").put_array("in/x.npy", np.arange(8).astype(np.float64))
    wf = Workflow("w", planner=PlacementPlanner(fed))
    wf.add(Step("sum", lambda ctx: {
        "s": float(ctx.store.get_array("in/x.npy").sum())},
        inputs=["in/x.npy"], outputs=["out/s"]))
    out = wf.run()
    assert out["sum"]["s"] == 28.0
    rep = wf.reports[0]
    assert rep.site == "s1"                       # ran at the data
    assert "bytes_moved" in rep.extra and "transfer_s" in rep.extra
    assert "Site" in wf.table_one()
    # undeclared outputs are surfaced as a metric, not an error
    assert wf.metrics.series("workflow/w/sum/missing_output").points


def test_federated_workflow_resume_skips_across_sites(tmp_path):
    fabric = mk_fabric(tmp_path, devs=(2, 2))
    fed = FederatedStore(fabric)
    calls = {"n": 0}

    def mk_wf():
        wf = Workflow("w", planner=PlacementPlanner(fed))
        def fn(ctx):
            calls["n"] += 1
            return {"ok": True}
        wf.add(Step("a", fn))
        return wf

    mk_wf().run()
    out = mk_wf().run()                 # fresh workflow object: marker skips
    assert calls["n"] == 1 and out["a"]["ok"] is True


def test_federated_workflow_survives_site_kill_between_steps(tmp_path):
    fabric = mk_fabric(tmp_path, devs=(4, 2))
    fed = FederatedStore(fabric)

    def mk_wf():
        wf = Workflow("w", planner=PlacementPlanner(fed))
        wf.add(Step("produce", lambda ctx: (
            ctx.store.put("d/x", b"z" * 1000),
            fed.replicate("d/x", "s1"), {"done": 1})[-1],
            outputs=["d/x"]))
        wf.add(Step("consume", lambda ctx: {
            "n": len(ctx.store.get("d/x"))}, deps=["produce"],
            inputs=["d/x"]))
        return wf

    mk_wf().run(only="produce")
    fabric.fail_site("s0")              # produce ran (and homed) at s0
    wf = mk_wf()
    out = wf.run()
    assert out["consume"]["n"] == 1000
    rep = next(r for r in wf.reports if r.step == "consume")
    assert rep.site == "s1" and rep.extra.get("migrated") == 1.0


# ------------------------------------------------- cross-site elastic train

def test_elastic_federated_failover(tmp_path):
    """Kill the training site mid-run: the churn controller escalates
    CapacityLostError, the supervisor replicates the mirrored checkpoints
    to the survivor, and the run completes there — one migration, every
    step's loss accounted for, wall/segment history spanning both sites."""
    import jax
    from repro.configs import registry
    from repro.configs.base import OptimizerConfig
    from repro.elastic.trainer import ElasticTrainSpec
    from repro.fabric import run_elastic_federated

    fabric = Fabric(time_scale=0.0)
    dev = jax.devices()[0]
    fabric.add_site("alpha", cluster=Cluster(devices=[dev]),
                    store_root=str(tmp_path / "alpha"))
    fabric.add_site("beta", cluster=Cluster(devices=[dev]),
                    store_root=str(tmp_path / "beta"))
    fabric.connect("alpha", "beta", gbps=10.0, latency_ms=1.0)
    fed = FederatedStore(fabric)
    planner = PlacementPlanner(fed)

    steps = 8
    spec = ElasticTrainSpec(
        registry.get_smoke("phi4-mini-3.8b"),
        registry.get_parallel("phi4-mini-3.8b"),
        OptimizerConfig(warmup_steps=2, decay_steps=100),
        steps=steps, seq_len=32, global_batch=4, base_shape=(1, 1),
        max_data=1, ckpt_every=2, log_every=4, rejoin_timeout_s=0.5,
        verbose=False)

    killed = {"done": False}

    def kill_when_halfway():
        import time as _t
        while True:
            prog = fabric.metrics.series("elastic/step").last
            if prog >= steps // 2:
                fabric.fail_site("alpha")
                killed["done"] = True
                return
            _t.sleep(0.01)

    killer = threading.Thread(target=kill_when_halfway, daemon=True)
    killer.start()
    result = run_elastic_federated(planner, spec)
    killer.join(timeout=5)

    assert killed["done"]
    assert result.sites[0] == "alpha" and result.sites[-1] == "beta"
    assert len(result.migrations) == 1
    mig = result.migrations[0]
    assert mig.from_site == "alpha" and mig.to_site == "beta"
    rep = result.report
    assert rep.segments[-1].end == steps - 1            # finished
    losses = result.out["loss_by_step"]
    assert sorted(losses) == list(range(steps))
    assert rep.recoveries >= 0 and rep.total_wall_s > 0
