"""Hypothesis property tests on the sharding-rule layer: specs never
produce non-divisible shardings, never reuse a mesh axis, and degrade to
replication on axes absent from the mesh."""
import numpy as np
import pytest
pytest.importorskip("hypothesis", reason="optional dev dependency (requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

import jax
from jax.sharding import Mesh

from repro.configs.base import ParallelConfig
from repro.sharding import specs as sh

RULES = sh.logical_rules(ParallelConfig())
LOGICAL = list(RULES.keys())


def fake_mesh(shape=(4, 2), axes=("data", "model")):
    devs = np.empty(shape, dtype=object)
    it = np.nditer(devs, flags=["refs_ok", "multi_index"])
    for i, _ in enumerate(it):
        devs[it.multi_index] = i
    # Mesh over fake device ids works for spec computation only
    return Mesh(np.array(jax.devices() * int(np.prod(shape)))[
        :int(np.prod(shape))].reshape(shape), axes)


MESH = fake_mesh()


@settings(max_examples=200, deadline=None)
@given(dims=st.lists(st.integers(min_value=1, max_value=64), min_size=1,
                     max_size=4),
       names=st.lists(st.sampled_from(LOGICAL + [None]), min_size=1,
                      max_size=4))
def test_spec_divisibility_and_axis_uniqueness(dims, names):
    n = min(len(dims), len(names))
    dims, names = tuple(dims[:n]), tuple(names[:n])
    spec = sh.spec_for(dims, names, MESH, RULES)
    used = []
    for dim, entry in zip(dims, spec):
        if entry is None:
            continue
        axes = (entry,) if isinstance(entry, str) else entry
        for a in axes:
            assert a in MESH.shape
            used.append(a)
        size = int(np.prod([MESH.shape[a] for a in axes]))
        assert dim % size == 0, (dims, names, spec)
    assert len(used) == len(set(used)), f"mesh axis reused: {spec}"


def test_pod_axis_dropped_on_single_pod_mesh():
    spec = sh.spec_for((8, 4), ("batch", None), MESH, RULES)
    # "batch" -> ("pod","data"); pod absent -> only data
    assert spec[0] == "data"


def test_non_divisible_falls_back_to_replication():
    spec = sh.spec_for((3, 5), ("batch", "tp_ff"), MESH, RULES)
    assert spec[0] is None and spec[1] is None


def test_shardings_for_schema_tree():
    from repro.models.params import PSpec
    schema = {"w": PSpec((8, 4), ("fsdp", "tp_ff")),
              "b": {"x": PSpec((6,), (None,))}}
    tree = sh.shardings_for_schema(schema, MESH, RULES)
    assert tree["w"].spec == jax.sharding.PartitionSpec("data", "model")
    assert tree["b"]["x"].spec == jax.sharding.PartitionSpec(None)
