"""Device-resident hot loop guardrails.

The load-bearing invariant: ``build_train_chunk`` (lax.scan over
device_steps optimizer steps, one dispatch) must produce EXACTLY the
trajectory of per-step ``build_train`` dispatch — same losses, same params,
bitwise.  Both compile the same ``train_step`` closure (runtime.steps
._train_pieces), so this holds to the bit on the deterministic CPU backend.
Plus: chunk scheduling math, the prefetcher contract, elastic rescale
across a chunk boundary, and the host-sync accounting the bench records.
"""
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.configs.base import OptimizerConfig, ShapeConfig
from repro.core.orchestrator import Cluster
from repro.data.tokens import ChunkPrefetcher, TokenPipeline
from repro.elastic import ElasticTrainer, ElasticTrainSpec
from repro.elastic.trainer import _chunk_schedule, _snap
from repro.launch.mesh import single_device_mesh
from repro.models import params as pr
from repro.optim import adamw
from repro.runtime import steps as steps_mod


# ------------------------------------------------------- scheduling math

def test_snap_rounds_cadence_up_to_chunk_multiples():
    assert _snap(0, 4) == 0                   # off stays off
    assert _snap(5, 1) == 5
    assert _snap(5, 4) == 8
    assert _snap(4, 4) == 4
    assert _snap(1, 8) == 8


def test_chunk_schedule_aligns_to_absolute_grid():
    # aligned start: steady chunks + ragged tail
    assert _chunk_schedule(0, 10, 4) == [(0, 4), (4, 4), (8, 2)]
    # unaligned restore: partial head chunk re-aligns to the global grid,
    # so snapped cadences keep firing on the same absolute boundaries
    assert _chunk_schedule(5, 10, 4) == [(5, 3), (8, 2)]
    assert _chunk_schedule(0, 6, 1) == [(i, 1) for i in range(6)]
    assert _chunk_schedule(6, 6, 4) == []


def test_chunk_batch_specs_stack_leading_axis():
    cfg = registry.get_smoke("phi4-mini-3.8b")
    shape = ShapeConfig("t", 32, 4, "train")
    abs_, axes = steps_mod.batch_specs(cfg, shape)
    cab, cax = steps_mod.chunk_batch_specs(abs_, axes, 3)
    assert cab["tokens"].shape == (3, 4, 32)
    assert cax["tokens"] == (None, "batch", "seq")


# ------------------------------------------------------------ prefetcher

def test_chunk_prefetcher_yields_schedule_in_order():
    pipe = TokenPipeline(97, 16, 2, seed=3)
    schedule = [(0, 2), (2, 2), (4, 1)]
    with ChunkPrefetcher(pipe, schedule, depth=2) as pf:
        for start, k in schedule:
            got_start, batches = pf.get()
            assert got_start == start
            assert batches["tokens"].shape == (k, 2, 16)
            np.testing.assert_array_equal(
                np.asarray(batches["tokens"]),
                pipe.chunk_host(start, k)["tokens"])
        with pytest.raises(StopIteration):
            pf.get()


def test_chunk_prefetcher_propagates_producer_error():
    class Boom(TokenPipeline):
        def chunk(self, start, device_steps, sharding=None):
            raise ValueError("boom at chunk build")

    with ChunkPrefetcher(Boom(97, 16, 2), [(0, 2)], depth=1) as pf:
        with pytest.raises(ValueError, match="boom"):
            pf.get(timeout=10.0)


def test_chunk_prefetcher_close_joins_thread_midstream():
    pipe = TokenPipeline(97, 16, 2)
    pf = ChunkPrefetcher(pipe, [(i, 2) for i in range(0, 40, 2)], depth=1)
    pf.get()                     # consume one, leave the producer blocked
    pf.close()
    assert not pf._thread.is_alive()
    assert threading.active_count() < 50     # no leaked producers


# -------------------------------------- chunked == per-step, bit for bit

def _init_state(cfg, ocfg):
    mod = steps_mod._model_module(cfg)
    schema = mod.lm_schema(cfg)
    params = pr.init_params(schema, jax.random.key(0), cfg.param_dtype)
    opt = pr.init_params(adamw.opt_state_schema(schema, ocfg),
                         jax.random.key(1), "float32")
    return params, opt


def test_chunked_dispatch_matches_per_step_bitwise():
    """6 optimizer steps, accum_steps=2: one per-step run vs two K=3 chunk
    dispatches must agree on every loss and every param BIT — the scan body
    is the identical train_step closure."""
    cfg = registry.get_smoke("phi4-mini-3.8b")
    par = registry.get_parallel("phi4-mini-3.8b")
    ocfg = OptimizerConfig(warmup_steps=2, decay_steps=100, accum_steps=2)
    shape = ShapeConfig("t", 32, 4, "train")
    mesh = single_device_mesh()
    pipe = TokenPipeline(cfg.vocab_size, 32, 4, seed=11)
    STEPS, K = 6, 3

    step_b = steps_mod.build_train(cfg, par, ocfg, mesh, shape)
    chunk_b = steps_mod.build_train_chunk(cfg, par, ocfg, mesh, shape, K)
    assert chunk_b.device_steps == K and chunk_b.accum_steps == 2

    with mesh:
        p1, o1 = _init_state(cfg, ocfg)
        step_fn = step_b.jit()
        losses_step = []
        for i in range(STEPS):
            p1, o1, m = step_fn(p1, o1, pipe.batch(i))
            losses_step.append(jax.device_get(m["loss"]))

        p2, o2 = _init_state(cfg, ocfg)
        chunk_fn = chunk_b.jit()
        losses_chunk = []
        for start in range(0, STEPS, K):
            p2, o2, ms = chunk_fn(p2, o2, pipe.chunk(start, K))
            losses_chunk.extend(jax.device_get(ms["loss"]))

    np.testing.assert_array_equal(np.asarray(losses_step),
                                  np.asarray(losses_chunk))
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


# -------------------------------------------------- elastic chunked runs

def _run_elastic(tmp_path, tag, **kw):
    from repro.data.objectstore import ObjectStore

    cfg = registry.get_smoke("phi4-mini-3.8b")
    par = registry.get_parallel("phi4-mini-3.8b")
    spec = ElasticTrainSpec(cfg, par, OptimizerConfig(warmup_steps=2,
                                                      decay_steps=100),
                            steps=7, seq_len=32, global_batch=4,
                            base_shape=(1, 1), max_data=1, ckpt_every=2,
                            log_every=4, verbose=False, **kw)
    store = ObjectStore(str(tmp_path / tag))
    trainer = ElasticTrainer(Cluster(devices=jax.devices()), spec,
                             store=store)
    return trainer.run()


def test_elastic_chunked_run_matches_per_step_run(tmp_path):
    """The full trainer at device_steps=3 (ragged 7-step run: chunks of
    3/3/1) reproduces the device_steps=1 loss trajectory exactly."""
    out1 = _run_elastic(tmp_path, "k1", device_steps=1)
    out3 = _run_elastic(tmp_path, "k3", device_steps=3)
    assert len(out3["losses"]) == 7
    np.testing.assert_array_equal(np.asarray(out1["losses"]),
                                  np.asarray(out3["losses"]))
    assert out3["report"].global_batch_constant


def test_elastic_rescale_across_chunk_boundary(tmp_path):
    """Crash injected INSIDE chunk [2,3]: the restored segment starts from
    the last checkpoint on an unaligned step, re-aligns to the chunk grid,
    and finishes with every step accounted for and batch x accum constant
    — losses identical to an uninterrupted per-step run (stateless data +
    exact checkpoint restore)."""
    clean = _run_elastic(tmp_path, "clean", device_steps=1)
    out = _run_elastic(tmp_path, "fail", device_steps=2, fail_at=3)
    assert len(out["losses"]) == 7               # every step accounted for
    rep = out["report"]
    outcomes = [s.outcome for s in rep.segments]
    assert outcomes[0] == "error" and outcomes[-1] == "done"
    assert rep.global_batch_constant
    np.testing.assert_array_equal(np.asarray(clean["losses"]),
                                  np.asarray(out["losses"]))


def test_chunked_dispatch_reduces_host_syncs(tmp_path):
    """The point of the hot loop: host round-trips per optimizer step drop
    from O(1) at K=1 to O(1/K)."""
    r1 = _run_elastic(tmp_path, "hs1", device_steps=1)["report"]
    r4 = _run_elastic(tmp_path, "hs4", device_steps=4)["report"]
    assert r1.host_syncs > 0 and r4.host_syncs > 0
    assert r4.host_syncs < r1.host_syncs
    assert r4.host_syncs_per_step < r1.host_syncs_per_step
    assert "host_syncs_per_step" in r4.to_json()
