"""The deprecated launch.train/launch.serve shims and the Session.apply
path must produce identical results — same losses, same generations,
same Table-I rows (modulo wall-clock fields) — on the CPU smoke configs.
Plus the serving-report regression: zero-completed-request runs still
render a row instead of raising."""
import jax

from repro.api import ServeJob, Session, TrainJob
from repro.core.metrics import Registry, table_one
from repro.core.orchestrator import Cluster
from repro.launch.serve import serve, serve_job
from repro.launch.train import train, train_job
from repro.serving.report import serving_report

ARCH = "phi4-mini-3.8b"

# Table-I fields that are a pure function of the workload (not of the
# wall clock): the equivalence contract compares exactly these.
DETERMINISTIC_ROW_FIELDS = ("requests", "tokens")


def test_train_shim_matches_session():
    shim = train(ARCH, steps=6, seq=16, batch=2, smoke=True, log_every=1)
    session = Session(cluster=Cluster(devices=jax.devices(),
                                      metrics=Registry()))
    spec = train_job(ARCH, steps=6, seq=16, batch=2, smoke=True,
                     log_every=1)
    assert isinstance(spec, TrainJob)
    out = session.apply(spec).wait(300)
    assert shim["losses"] == out["losses"], "identical optimizer trajectory"
    for field in ("steps", "global_batch", "seq_len"):
        assert getattr(shim["report"], field) == \
            getattr(out["report"], field)
    assert [s.mesh_shape for s in shim["report"].segments] == \
        [s.mesh_shape for s in out["report"].segments]


def test_serve_shim_matches_session():
    kw = dict(smoke=True, n_requests=4, prompt_len=8, gen=4, batch=2,
              gen_lens=[4, 2])
    shim_results, shim_metrics = serve(ARCH, **kw)
    session = Session(cluster=Cluster(devices=jax.devices(),
                                      metrics=Registry()))
    spec = serve_job(ARCH, **kw)
    assert isinstance(spec, ServeJob)
    out = session.apply(spec).wait(300)
    assert shim_results == out["results"], "identical generations"

    shim_row = serving_report(shim_metrics)
    api_row = out["report"]
    for field in DETERMINISTIC_ROW_FIELDS:
        assert shim_row.extra[field] == api_row.extra[field], field
    # both rows render through the same Table-I machinery
    assert table_one([shim_row]).splitlines()[0]
    assert table_one([api_row]).splitlines()[0]


def test_train_pieces_accepts_custom_arch_with_config():
    """The pre-API pattern train(cfg.name, cfg_override=cfg) names a
    model the registry has never heard of; with a config override the
    arch must not be forced through the registry."""
    from repro.api.runners import train_pieces
    cfg, par, ocfg = train_pieces(TrainJob(
        name="t", steps=4, arch="lm-20m",
        config=dict(name="lm-20m", family="dense", num_layers=2,
                    d_model=32, num_heads=2, num_kv_heads=2, d_ff=64,
                    vocab_size=128, head_dim=16)))
    assert cfg.name == "lm-20m" and cfg.d_model == 32
    assert ocfg.decay_steps == 4


def test_serving_summary_only_contains_gauge_names():
    from repro.core.metrics import Registry
    from repro.serving.report import serving_summary
    keys = set(serving_summary(Registry()))
    assert all(k.startswith("serve/") for k in keys), keys


def test_serving_report_tolerates_never_recorded_stats():
    """A smoke run with 0 completed requests (or a metrics registry that
    never saw a single serve gauge) still reports a row of zeros."""
    empty = Registry()
    row = serving_report(empty)
    assert row.total_time_s == 0.0
    assert row.extra["requests"] == 0.0
    assert row.extra["p99 latency (s)"] == 0.0
    assert "| requests |" in table_one([row]).replace("  ", " ")

    partial = Registry()                 # wall recorded, nothing completed
    partial.gauge("serve/wall_s", 1.5)
    row2 = serving_report(partial)
    assert row2.total_time_s == 1.5
    assert row2.extra["tokens"] == 0.0
