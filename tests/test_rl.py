"""The distributed RL workload: replay staleness, policy store, manifest
surface, and one end-to-end RLJob on a bare cluster.

The load-bearing contract is bounded staleness: a learner at version v
draining rollouts generated at versions v-k must NEVER train on one with
k > max_policy_lag — stale rollouts are acked-and-dropped and metered
on a separate counter (property-tested below), and the lag of every
trained-on rollout is recorded so the bound is auditable after the run.
"""
import numpy as np
import pytest

from repro.api import RLJob, from_manifest
from repro.api.resources import ManifestError
from repro.core.metrics import Registry
from repro.data.objectstore import ObjectStore
from repro.rl import (PolicyStore, RolloutQueue, Trajectory, is_stale,
                      split_stale)


def traj(version: int, *, ticket="t0", reward=1.0) -> Trajectory:
    return Trajectory(ticket=ticket, prompt=(1, 2), tokens=(3, 4),
                      reward=reward, policy_version=version, actor="a")


# ------------------------------------------------------------- staleness
def test_is_stale_boundary():
    assert not is_stale(3, 5, max_policy_lag=2)     # gap == lag: trainable
    assert is_stale(2, 5, max_policy_lag=2)         # gap > lag: stale
    assert not is_stale(5, 5, max_policy_lag=0)


def test_split_stale():
    ts = [traj(0), traj(1), traj(2)]
    fresh, stale = split_stale(ts, current_version=2, max_policy_lag=1)
    assert [t.policy_version for t in fresh] == [1, 2]
    assert [t.policy_version for t in stale] == [0]


def test_take_fresh_drops_and_meters_stale():
    reg = Registry()
    q = RolloutQueue(registry=reg)
    for v in (0, 0, 2, 1):
        q.push(traj(v, ticket=f"t{v}"))
    got = q.take_fresh(10, worker="learner", current_version=2,
                       max_policy_lag=1)
    assert [t.policy_version for _, t in got] == [2, 1]
    assert q.stale_dropped == 2
    assert reg.series("rl/stale_dropped").total == 2
    q.ack_trained(got, worker="learner", current_version=2)
    assert q.trained == 2
    assert q.max_lag_trained() == 1
    assert q.pending == 0                           # stale ones consumed


def test_release_returns_batch_to_pending():
    q = RolloutQueue()
    q.push(traj(0))
    held = q.take_fresh(1, worker="learner", current_version=0,
                        max_policy_lag=2)
    assert len(held) == 1 and q.pending == 0
    q.release(held, worker="learner")               # preempted mid-drain
    assert q.pending == 1
    again = q.take_fresh(1, worker="learner", current_version=0,
                         max_policy_lag=2)
    assert len(again) == 1                          # at-least-once


def test_rollout_queue_snapshot_restore_roundtrip():
    q = RolloutQueue()
    for v in (0, 0, 1):
        q.push(traj(v))
    got = q.take_fresh(1, worker="learner", current_version=1,
                       max_policy_lag=0)            # drops the two v=0
    q.ack_trained(got, worker="learner", current_version=1)
    q.push(traj(1))
    snap = q.snapshot()
    clone = RolloutQueue()
    clone.restore(snap)
    assert clone.pushed == q.pushed == 4
    assert clone.trained == q.trained == 1
    assert clone.stale_dropped == q.stale_dropped == 2
    assert clone.lag_trained == q.lag_trained == [0]
    assert clone.pending == q.pending == 1
    got2 = clone.take_fresh(1, worker="learner", current_version=1,
                            max_policy_lag=0)
    assert [t.policy_version for _, t in got2] == [1]


def test_trajectory_item_roundtrip_is_jsonable():
    import json
    t = Trajectory(ticket="r1", prompt=(np.int32(1), 2),
                   tokens=(np.int32(7),), reward=np.float32(0.5),
                   policy_version=3, actor="a0")
    item = t.to_item()
    json.dumps(item)                                # checkpoint-manifest safe
    assert Trajectory.from_item(item) == Trajectory(
        ticket="r1", prompt=(1, 2), tokens=(7,), reward=0.5,
        policy_version=3, actor="a0")


# -------------------------------------------- queue timestamp preservation
# The rollout queue's wait accounting depends on the WorkQueue invariant
# that implicit requeues (nack on actor kill, lease expiry on actor
# crash) keep the ORIGINAL enqueued_at — a retried trajectory charges
# its queue wait from the first enqueue, never from the requeue.

class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t

    def advance(self, dt):
        self.t += dt


def test_nack_preserves_enqueued_at():
    from repro.core.queue import WorkQueue
    clock = FakeClock()
    q = WorkQueue(lease_timeout=10.0, clock=clock)
    clock.advance(5.0)
    tid = q.put("traj")
    assert q.enqueued_at(tid) == 5.0
    clock.advance(1.0)
    got_tid, _ = q.lease("w1")
    assert got_tid == tid
    clock.advance(2.0)
    assert q.nack(tid, "w1")                    # early return at t=8
    assert q.enqueued_at(tid) == 5.0            # NOT reset to nack time
    got_tid, _ = q.lease("w2")                  # re-leased by a survivor
    assert got_tid == tid
    assert q.enqueued_at(tid) == 5.0


def test_lease_expiry_reclaim_preserves_enqueued_at():
    from repro.core.queue import WorkQueue
    clock = FakeClock()
    q = WorkQueue(lease_timeout=10.0, clock=clock)
    clock.advance(3.0)
    tid = q.put("traj")
    q.lease("w1")
    clock.advance(11.0)                         # w1 died; lease expired
    got = q.lease("w2")                         # reclaim happens here
    assert got is not None and got[0] == tid
    assert q.enqueued_at(tid) == 3.0            # survives the reclaim


def test_leased_by_counts_live_leases_only():
    from repro.core.queue import WorkQueue
    clock = FakeClock()
    q = WorkQueue(["a", "b", "c"], lease_timeout=10.0, clock=clock)
    q.lease("w1")
    q.lease("w1")
    q.lease("w2")
    assert q.leased_by("w1") == 2 and q.leased_by("w2") == 1
    clock.advance(11.0)                         # everything expired
    assert q.leased_by("w1") == 0


# --------------------------------------------------- staleness (property)
def test_staleness_bound_property():
    """Actors holding versions v-k feed a learner at version v: whatever
    the push/bump interleaving, nothing older than max_policy_lag is
    ever trained on, and every drop lands on the stale meter."""
    hypothesis = pytest.importorskip(
        "hypothesis", reason="optional dev dependency")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=60, deadline=None)
    @given(lag=st.integers(min_value=0, max_value=3),
           events=st.lists(
               st.one_of(st.tuples(st.just("push"),
                                   st.integers(min_value=0, max_value=5)),
                         st.tuples(st.just("bump"), st.just(0))),
               min_size=1, max_size=40))
    def prop(lag, events):
        reg = Registry()
        q = RolloutQueue(registry=reg)
        version = 0
        pushed = []
        for kind, k in events:
            if kind == "bump":
                version += 1
            else:                       # an actor holding version - k
                v = max(version - k, 0)
                pushed.append(v)
                q.push(traj(v, ticket=f"t{len(pushed)}"))
        held = q.take_fresh(len(pushed) + 1, worker="learner",
                            current_version=version, max_policy_lag=lag)
        q.ack_trained(held, worker="learner", current_version=version)
        expect_stale = sum(1 for v in pushed if version - v > lag)
        assert q.max_lag_trained() <= lag
        assert all(version - t.policy_version <= lag for _, t in held)
        assert q.stale_dropped == expect_stale
        assert q.trained == len(pushed) - expect_stale
        assert reg.series("rl/stale_dropped").total == expect_stale
        assert reg.series("rl/trained_rollouts").total == q.trained

    prop()


# ----------------------------------------------------------- policy store
def test_policy_store_roundtrip(tmp_path):
    reg = Registry()
    store = ObjectStore(str(tmp_path))
    pub = PolicyStore(store, registry=reg)
    assert pub.latest_version() == -1
    tree = {"w": np.arange(6, dtype=np.float32).reshape(2, 3),
            "b": np.float32(2.5)}
    pub.publish(1, tree, step=4)
    pub.publish(2, {"w": tree["w"] * 2, "b": tree["b"]}, step=8)
    sub = PolicyStore(store)                # a separate subscriber view
    assert sub.latest_version() == 2
    abstract = {"w": np.zeros((2, 3), np.float32), "b": np.zeros((), np.float32)}
    got, version = sub.fetch(abstract)
    assert version == 2                     # learner_step must NOT clobber it
    np.testing.assert_array_equal(np.asarray(got["w"]), tree["w"] * 2)
    assert reg.series("rl/weights_published").total == 2


def test_policy_store_empty_fetch(tmp_path):
    sub = PolicyStore(ObjectStore(str(tmp_path)))
    got, version = sub.fetch({"w": np.zeros((1,), np.float32)})
    assert got is None and version == -1


# ---------------------------------------------------------- RLJob surface
def test_rljob_manifest_roundtrip():
    job = RLJob(name="rl", learner_steps=6, actors=3, max_policy_lag=1,
                site="serve", learner_site="train",
                optimizer={"lr": 1e-4})
    man = job.to_manifest()
    assert man["kind"] == "RLJob"
    assert from_manifest(man) == job


def test_rljob_validation_names_fields():
    with pytest.raises(ManifestError) as e:
        RLJob(name="rl", learner_steps=0)
    assert e.value.field == "spec.learner_steps"
    with pytest.raises(ManifestError) as e:
        RLJob(name="rl", learner_steps=1, max_policy_lag=-1)
    assert e.value.field == "spec.max_policy_lag"
    with pytest.raises(ManifestError) as e:
        RLJob(name="rl", learner_steps=1, actors=0)
    assert e.value.field == "spec.actors"
    with pytest.raises(ManifestError) as e:
        from_manifest({"apiVersion": "repro/v1", "kind": "RLJob",
                       "metadata": {"name": "rl"},
                       "spec": {"learner_steps": 2, "bogus": 1}})
    assert e.value.field == "spec.bogus"


def test_rl_smoke_manifest_parses():
    from repro.api import load_manifest
    spec = load_manifest("examples/manifests/rl_smoke.json")
    assert isinstance(spec, RLJob)
    assert spec.learner_steps == 4 and spec.actors == 2


# ------------------------------------------------------------- end to end
def test_rljob_end_to_end_on_cluster():
    """Two actors + learner on a bare cluster Session: completes, stays
    inside the staleness bound, and every actor observes >= 1 published
    weight version."""
    from repro.api import Session
    from repro.core.orchestrator import Cluster

    job = RLJob(name="rl-e2e", learner_steps=2, actors=2,
                rollouts_per_step=2, prompt_len=4, max_new_tokens=4,
                seq_len=12, slots=2, max_policy_lag=2, broadcast_every=1,
                ckpt_every=2)
    out = Session(cluster=Cluster()).apply(job).wait(timeout=540)
    assert out["done"] and out["steps_done"] == 2
    assert out["trained"] == 4
    assert out["max_lag_trained"] <= job.max_policy_lag
    assert out["min_actor_syncs"] >= 1
    assert out["final_version"] >= 1
    assert out["steps_lost"] == 0
